//! Streaming Monte-Carlo and incremental-snapshot-signature suite
//! (EXPERIMENTS.md §Perf PR 7).
//!
//! * A [`TraceStream`] sweep is **bit-identical** to sweeping the
//!   materialized `Trace` the same stream collects — one event source,
//!   two consumption orders — across all four scenario generators and
//!   the full policy registry, for the sequential, shared-memo, and
//!   parallel (any worker count, including more workers than trials)
//!   entry points, in both exact and grid stepping.
//! * The incremental exact sweep (deficit histogram + dirty-domain set
//!   maintained event-by-event) reproduces the from-scratch rebuild
//!   oracle bit-for-bit, scenario by scenario, spares and transitions
//!   on or off.
//! * `ResponseMemo::begin_point` epochs: hits served across grid-point
//!   boundaries are counted as cross-point hits; hits inside one point
//!   are not.

use ntp::cluster::Topology;
use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::failure::{BlastRadius, FailureModel, ScenarioConfig, ScenarioKind, TrialGen};
use ntp::manager::{MultiPolicySim, ResponseMemo, SparePolicy, StepMode, StrategyTable};
use ntp::parallel::ParallelConfig;
use ntp::policy::{registry, TransitionCosts};
use ntp::power::RackDesign;
use ntp::sim::{IterationModel, SimParams};
use ntp::util::prop::{check, SeedGen};
use ntp::util::prng::Rng;

const DOMAIN_SIZE: usize = 32;
const PER_REPLICA: usize = 4;

const ALL_KINDS: [ScenarioKind; 4] = [
    ScenarioKind::Independent,
    ScenarioKind::Correlated,
    ScenarioKind::Straggler,
    ScenarioKind::Sdc,
];

fn setup() -> (IterationModel, ParallelConfig, StrategyTable) {
    let sim = IterationModel::new(
        presets::model("gpt-480b").unwrap(),
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 2 * 1024 * 1024,
            dtype: Dtype::BF16,
        },
        presets::cluster("paper-32k-nvl32").unwrap(),
        SimParams::default(),
    );
    let cfg = ParallelConfig { tp: DOMAIN_SIZE, pp: PER_REPLICA, dp: 16, microbatch: 1 };
    let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
    let table = StrategyTable::build(&sim, &cfg, &rack);
    (sim, cfg, table)
}

/// Rates hot enough that a ~10-day trace on a few hundred GPUs carries
/// every event type its scenario can produce.
fn hot_scenario(kind: ScenarioKind) -> ScenarioConfig {
    let mut cfg = ScenarioConfig::new(kind);
    cfg.correlated = cfg.correlated.scaled(2_000.0);
    cfg.straggler = cfg.straggler.scaled(200.0);
    cfg.sdc = cfg.sdc.scaled(2_000.0);
    cfg
}

/// Stream-vs-materialized bit-identity over the full registry: every
/// entry point, every scenario kind, exact and grid stepping, workers
/// above and below the trial count.
#[test]
fn streaming_trials_bit_identical_to_materialized() {
    let (sim, cfg, table) = setup();
    let policies = registry::all();
    let job_domains = 20usize;
    let spare_domains = 4usize;
    let topo = Topology::of((job_domains + spare_domains) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(40.0);
    let transition = Some(TransitionCosts::model(&sim, &cfg));
    for (k, &kind) in ALL_KINDS.iter().enumerate() {
        let gen = TrialGen::new(
            &topo,
            &model,
            &hot_scenario(kind),
            24.0 * 10.0,
            0x57AE + k as u64,
            5,
        );
        let traces = gen.traces();
        assert!(
            traces.iter().all(|t| !t.events.is_empty()),
            "{kind:?}: trial traces came out empty — rates too quiet for this test"
        );
        let msim = MultiPolicySim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policies: &policies,
            spares: Some(SparePolicy { spare_domains, cold_domains: 0, min_tp: 28 }),
            packed: true,
            blast: BlastRadius::Single,
            transition,
            detect: None,
        };
        for mode in [StepMode::Exact, StepMode::Grid(2.0)] {
            // Sequential, one shared memo on each side.
            let mut memo_m = msim.memo();
            let mat = msim.run_trials(&traces, mode, &mut memo_m);
            let mut memo_s = msim.memo();
            let streamed = msim.run_trials_stream(&gen, mode, &mut memo_s);
            assert_eq!(
                streamed, mat,
                "{kind:?} {mode:?}: streaming trials diverged from the materialized path"
            );
            // Single-stream entry point against its own collected trace.
            let one = msim.run_stream(gen.stream_for(2), mode, &mut msim.memo());
            assert_eq!(
                one, mat[2],
                "{kind:?} {mode:?}: run_stream diverged from the collected trace"
            );
            // Parallel fan-out at worker counts below, at, and above the
            // trial count (7 and 9 exceed the 5 trials: the clamped and
            // empty-trailing-batch paths).
            for threads in [1usize, 2, 3, 5, 7, 9] {
                let (par_m, _) = msim.run_trials_par(&traces, mode, threads);
                let (par_s, ms) = msim.run_trials_stream_par(&gen, mode, threads);
                assert_eq!(
                    par_s, par_m,
                    "{kind:?} {mode:?} threads={threads}: parallel streaming diverged"
                );
                assert_eq!(par_s, mat, "{kind:?} {mode:?} threads={threads}");
                assert!(ms.hits + ms.misses > 0, "memo never consulted");
            }
        }
    }
}

/// The incremental exact sweep must reproduce the from-scratch rebuild
/// oracle bit-for-bit: random scenario kind, spare budget, blast
/// radius, packing, and transitions per seed.
#[test]
fn incremental_sweep_matches_rebuild_oracle() {
    let (sim, cfg, table) = setup();
    let policies = registry::all();
    let gen = SeedGen;
    check(0x1AC2, 10, &gen, |&seed| {
        let mut rng = Rng::new(seed);
        let kind = ALL_KINDS[rng.index(4)];
        let spare_domains = [0usize, 3, 6][rng.index(3)];
        let job_domains = PER_REPLICA * (5 + rng.index(4));
        let topo =
            Topology::of((job_domains + spare_domains) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
        let model = FailureModel::llama3().scaled(20.0 + rng.f64() * 50.0);
        let horizon = 24.0 * (6.0 + rng.f64() * 8.0);
        let tgen = TrialGen::new(&topo, &model, &hot_scenario(kind), horizon, seed, 2);
        let blast = [BlastRadius::Single, BlastRadius::Node][rng.index(2)];
        let spares = (spare_domains > 0)
            .then_some(SparePolicy { spare_domains, cold_domains: 0, min_tp: 28 });
        let transition = rng
            .chance(0.5)
            .then(|| TransitionCosts::model(&sim, &cfg));
        for packed in [true, false] {
            let msim = MultiPolicySim {
                topo: &topo,
                table: &table,
                domains_per_replica: PER_REPLICA,
                policies: &policies,
                spares,
                packed,
                blast,
                transition,
                detect: None,
            };
            for trace in &tgen.traces() {
                let incremental = msim.run_with(trace, StepMode::Exact, &mut msim.memo());
                let rebuilt = msim.run_rebuild(trace, &mut msim.memo());
                if incremental != rebuilt {
                    return Err(format!(
                        "{kind:?} packed={packed} spares={spare_domains} blast={blast:?} \
                         transition={}: incremental sweep != rebuild oracle",
                        transition.is_some()
                    ));
                }
            }
        }
        Ok(())
    });
}

/// `begin_point` epochs: replaying the same trials against a memo
/// populated by an earlier grid point scores *cross-point* hits; a memo
/// that never crosses a point boundary scores none.
#[test]
fn cross_point_hits_track_point_epochs() {
    let (sim, cfg, table) = setup();
    let policies = registry::all();
    let job_domains = 20usize;
    let max_spares = 4usize;
    let topo = Topology::of((job_domains + max_spares) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(40.0);
    let gen = TrialGen::new(
        &topo,
        &model,
        &hot_scenario(ScenarioKind::Correlated),
        24.0 * 10.0,
        9,
        2,
    );
    let costs = Some(TransitionCosts::model(&sim, &cfg));
    let run_point = |spare_domains: usize, memo: &mut ResponseMemo| {
        let msim = MultiPolicySim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policies: &policies,
            spares: Some(SparePolicy { spare_domains, cold_domains: 0, min_tp: 28 }),
            packed: true,
            blast: BlastRadius::Single,
            transition: costs,
            detect: None,
        };
        msim.run_trials_stream(&gen, StepMode::Exact, memo)
    };
    // One point, no boundary crossed: everything is a same-point hit.
    let mut memo_one = ResponseMemo::new(policies.len());
    memo_one.begin_point();
    let first = run_point(2, &mut memo_one);
    let one = memo_one.stats();
    assert!(one.hits + one.misses > 0);
    assert_eq!(one.cross_hits, 0, "no point boundary was crossed");
    assert_eq!(one.cross_transition_hits, 0);
    assert_eq!(one.cross_hit_rate(), 0.0);
    // Second point replaying the identical streams: its hits come from
    // entries the first point populated, and the stats themselves are
    // unchanged by the sharing.
    memo_one.begin_point();
    let second = run_point(2, &mut memo_one);
    assert_eq!(second, first, "memo sharing across points changed the stats");
    let two = memo_one.stats();
    assert!(two.cross_hits > 0, "replayed point must re-hit earlier-point entries");
    assert!(two.cross_hit_rate() > 0.0);
    // A different spare budget still shares the healthy-fleet entries.
    memo_one.begin_point();
    let _ = run_point(0, &mut memo_one);
    let three = memo_one.stats();
    assert!(three.cross_hits >= two.cross_hits);
}
