//! Integration: hybrid-parallel planner + iteration model consistency
//! across the paper's cluster presets (the machinery behind Fig. 2).

use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::parallel::{best_config, enumerate_legal, MemoryModel, ParallelConfig};
use ntp::sim::{IterationModel, SimParams};

fn work(seq: usize) -> WorkloadConfig {
    WorkloadConfig { seq_len: seq, minibatch_tokens: 16 * 1024 * 1024, dtype: Dtype::BF16 }
}

#[test]
fn fig2a_ordering_nvl_domain_sizes_at_32k() {
    // Fig. 2a: at 32K GPUs, bigger NVL domains win; NVL32 leads NVL8 by
    // a wide margin (paper: 87% vs 68% per-GPU utilization).
    let model = presets::model("gpt-480b").unwrap();
    let w = work(8192);
    let p = SimParams::default();
    let mut tputs = Vec::new();
    for cl in ["paper-32k-nvl8", "paper-32k-nvl16", "paper-32k-nvl32"] {
        let cluster = presets::cluster(cl).unwrap();
        let cap = cluster.domain_size;
        let best = best_config(&model, &w, &cluster, cap, p).unwrap();
        tputs.push((cl, best.tokens_per_sec_per_gpu));
    }
    assert!(tputs[2].1 > tputs[1].1, "{tputs:?}");
    assert!(tputs[1].1 > tputs[0].1, "{tputs:?}");
    // NVL32 vs NVL8 gap should be substantial (>8%)
    assert!(tputs[2].1 / tputs[0].1 > 1.08, "{tputs:?}");
}

#[test]
fn fig14_breakdown_shifts_from_pp_to_tp() {
    // Fig. 14: capping TP inflates the PP-bubble share; raising TP trades
    // it for TP-comm share.
    let model = presets::model("gpt-480b").unwrap();
    let cluster = presets::cluster("paper-32k-nvl32").unwrap();
    let w = work(16_384);
    let p = SimParams::default();
    let low = best_config(&model, &w, &cluster, 8, p).unwrap();
    let high = best_config(&model, &w, &cluster, 32, p).unwrap();
    let bubble_share_low = low.breakdown.pp_bubble / low.breakdown.total();
    let bubble_share_high = high.breakdown.pp_bubble / high.breakdown.total();
    assert!(
        bubble_share_low > bubble_share_high,
        "low {bubble_share_low} high {bubble_share_high}"
    );
    let tp_share_low = low.breakdown.tp_comm / low.breakdown.total();
    let tp_share_high = high.breakdown.tp_comm / high.breakdown.total();
    assert!(tp_share_high > tp_share_low);
}

#[test]
fn all_legal_configs_fit_and_fill() {
    let model = presets::model("gpt-175b").unwrap();
    let cluster = presets::cluster("llama3-16k-nvl8").unwrap();
    let w = work(4096);
    let mm = MemoryModel::default();
    let configs = enumerate_legal(&model, &w, &cluster, 8);
    assert!(!configs.is_empty());
    for cfg in &configs {
        assert_eq!(cfg.n_gpus(), cluster.n_gpus);
        assert!(mm.fits(&model, cfg, &w, cluster.gpu.hbm_gib), "{cfg:?}");
        assert!(cfg.tp <= cluster.domain_size);
    }
}

#[test]
fn iteration_time_decreases_with_cluster_size_at_fixed_batch() {
    // Same workload over more GPUs => shorter iterations (weak check
    // that the pipeline/DP terms do not explode).
    let model = presets::model("gpt-480b").unwrap();
    let w = work(8192);
    let p = SimParams::default();
    let c32k = presets::cluster("paper-32k-nvl32").unwrap();
    let sim = IterationModel::new(model.clone(), w.clone(), c32k.clone(), p);
    let cfg_16k = ParallelConfig { tp: 32, pp: 8, dp: 64, microbatch: 1 };
    let cfg_32k = ParallelConfig { tp: 32, pp: 8, dp: 128, microbatch: 1 };
    let t16 = sim.healthy_iteration(&cfg_16k).total();
    let t32 = sim.healthy_iteration(&cfg_32k).total();
    assert!(t32 < t16, "t32 {t32} vs t16 {t16}");
}

#[test]
fn planner_prefers_fitting_memory_over_raw_speed() {
    // The chosen best config must always fit; a hypothetical TP1/PP1
    // config would be "fast" per-GPU but can't hold the model.
    let model = presets::model("gpt-480b").unwrap();
    let cluster = presets::cluster("paper-32k-nvl32").unwrap();
    let w = work(8192);
    let best = best_config(&model, &w, &cluster, 32, SimParams::default()).unwrap();
    assert!(best.cfg.tp * best.cfg.pp >= 16, "chose {:?}", best.cfg);
}
