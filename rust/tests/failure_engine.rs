//! Integration: failure-engine edge cases and cross-checks between the
//! trace generator, blast expansion, fleet replay and the closed-form
//! availability math.

use ntp::cluster::{FleetHealth, Topology};
use ntp::failure::scenario::{expected_availability_domain_drop, sample_scenario};
use ntp::failure::{BlastRadius, FailureModel, Trace};
use ntp::util::prng::Rng;

#[test]
fn zero_rate_trace_is_empty() {
    let topo = Topology::of(128, 8, 4);
    let model = FailureModel {
        failures_per_gpu_day: 1e-12,
        hw_fraction: 0.5,
        hw_recovery_hours: (1.0, 2.0),
        sw_recovery_hours: 1.0,
    };
    let mut rng = Rng::new(1);
    let trace = Trace::generate(&topo, &model, 24.0, &mut rng);
    assert!(trace.events.is_empty());
    let fleet = trace.replay_to(&topo, BlastRadius::Single, 24.0);
    assert_eq!(fleet.n_failed(), 0);
}

#[test]
fn replay_at_time_zero_is_healthy() {
    let topo = Topology::of(256, 8, 4);
    let model = FailureModel::llama3().scaled(100.0);
    let mut rng = Rng::new(2);
    let trace = Trace::generate(&topo, &model, 24.0 * 5.0, &mut rng);
    assert!(!trace.events.is_empty());
    let fleet = trace.replay_to(&topo, BlastRadius::Single, 0.0);
    assert_eq!(fleet.n_failed(), 0);
}

#[test]
fn everything_recovers_eventually() {
    let topo = Topology::of(256, 8, 4);
    let model = FailureModel {
        failures_per_gpu_day: 0.05,
        hw_fraction: 0.8,
        hw_recovery_hours: (5.0, 10.0),
        sw_recovery_hours: 1.0,
    };
    let mut rng = Rng::new(3);
    let trace = Trace::generate(&topo, &model, 48.0, &mut rng);
    // 10+ hours after the horizon, every failure has recovered
    let fleet = trace.replay_to(&topo, BlastRadius::Single, 48.0 + 11.0);
    assert_eq!(fleet.n_failed(), 0);
}

#[test]
fn domain_blast_kills_whole_domains_in_replay() {
    let topo = Topology::of(256, 16, 4);
    let model = FailureModel::llama3().scaled(300.0);
    let mut rng = Rng::new(4);
    let trace = Trace::generate(&topo, &model, 24.0, &mut rng);
    let fleet = trace.replay_to(&topo, BlastRadius::Domain, 23.9);
    for d in 0..topo.n_domains() {
        let h = fleet.domain_healthy(d);
        assert!(h == 0 || h == 16, "domain {d} partially failed under domain blast: {h}");
    }
    fleet.check_invariants().unwrap();
}

#[test]
fn fleet_health_mass_fail_recover_cycle() {
    let topo = Topology::of(1024, 32, 4);
    let mut fleet = FleetHealth::new(topo);
    let mut rng = Rng::new(5);
    // randomized fail/recover churn, invariants must hold throughout
    for round in 0..50 {
        for _ in 0..20 {
            let g = rng.index(1024);
            fleet.fail(g, round as f64, round as f64 + 1.0 + rng.f64() * 5.0);
        }
        fleet.recover_due(round as f64 + 0.5);
        fleet.check_invariants().unwrap();
    }
    fleet.recover_due(1e9);
    assert_eq!(fleet.n_failed(), 0);
}

#[test]
fn availability_closed_form_extremes() {
    // no failures -> 1.0
    assert_eq!(expected_availability_domain_drop(1024, 8, 0), 1.0);
    // every GPU failed -> 0.0
    assert!(expected_availability_domain_drop(64, 8, 64) < 1e-12);
    // monotone in failures
    let mut prev = 1.0;
    for f in [1usize, 2, 4, 8, 16, 32] {
        let a = expected_availability_domain_drop(1024, 16, f);
        assert!(a < prev);
        prev = a;
    }
    // monotone in domain size (bigger domain, worse availability)
    let a8 = expected_availability_domain_drop(32_768, 8, 33);
    let a64 = expected_availability_domain_drop(32_768, 64, 33);
    assert!(a64 < a8);
}

#[test]
fn scenario_sampler_is_unbiased_at_boundaries() {
    let topo = Topology::of(64, 8, 4);
    let mut rng = Rng::new(6);
    // all GPUs failed
    let s = sample_scenario(&topo, 64, BlastRadius::Single, &mut rng);
    assert_eq!(s.availability_domain_drop(), 0.0);
    assert_eq!(s.availability_ntp(), 0.0);
    // none failed
    let s = sample_scenario(&topo, 0, BlastRadius::Single, &mut rng);
    assert_eq!(s.availability_domain_drop(), 1.0);
    assert_eq!(s.availability_ntp(), 1.0);
}

#[test]
fn overlapping_failures_extend_not_duplicate() {
    let topo = Topology::of(64, 8, 4);
    let mut fleet = FleetHealth::new(topo);
    fleet.fail(5, 0.0, 10.0);
    fleet.fail(5, 1.0, 4.0); // shorter second failure must not shrink recovery
    assert_eq!(fleet.recover_due(5.0), 0);
    assert_eq!(fleet.recover_due(10.0), 1);
    fleet.check_invariants().unwrap();
}
