//! Registry-driven policy conformance suite.
//!
//! Every property below iterates `policy::registry::all()` over a grid
//! of evaluation contexts, so a newly registered policy gets its full
//! coverage — throughput ∈ [0, 1], secondary-channel bounds,
//! `respond_with == respond`, multiset-permutation purity,
//! transition-cost sanity and count-purity, and the degradation layer
//! (`eval_degraded_with == eval_degraded`, zero-degradation collapse to
//! the plain respond path, `degrade_transition_cost` sanity) — by
//! adding one registry entry, with **zero per-policy test code**.
//! Cross-policy claims (the transition-cost ordering, the legacy-oracle
//! bit-identity, the straggler evict-vs-tolerate crossover) are the
//! only policy-named assertions, because they are claims *about*
//! specific policies rather than per-policy boilerplate.
//!
//! * The three legacy ports are **bit-identical** to the pre-refactor
//!   `FtStrategy` evaluation paths (a verbatim copy of the old
//!   `FleetSim::evaluate` is kept below as the oracle) when transition
//!   costs are disabled.
//! * `StrategyTable` invariants: batch nondecreasing in TP,
//!   `batch_pw >= batch`, and the modeled reshard overhead bounded by
//!   the retired `0.995` constant.

use ntp::cluster::Topology;
use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::failure::BlastRadius;
use ntp::manager::packing::pack_domains;
use ntp::manager::spares::{apply_spares, meets_minibatch};
use ntp::manager::{FleetSim, SparePolicy, StrategyTable};
use ntp::parallel::ParallelConfig;
use ntp::policy::{registry, EvalOut, EvalScratch, PolicyCtx, TransitionCosts};
use ntp::power::RackDesign;
use ntp::sim::engine::healthy_reshard_factor;
use ntp::sim::{FtStrategy, IterationModel, SimParams};
use ntp::util::prng::Rng;

const DOMAIN_SIZE: usize = 32;
const PER_REPLICA: usize = 4;
const JOB_DOMAINS: usize = 24;
const SPARE_DOMAINS: usize = 6;

fn setup() -> (IterationModel, ParallelConfig, StrategyTable) {
    let sim = IterationModel::new(
        presets::model("gpt-480b").unwrap(),
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 2 * 1024 * 1024,
            dtype: Dtype::BF16,
        },
        presets::cluster("paper-32k-nvl32").unwrap(),
        SimParams::default(),
    );
    let cfg = ParallelConfig { tp: 32, pp: PER_REPLICA, dp: 16, microbatch: 1 };
    let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
    let table = StrategyTable::build(&sim, &cfg, &rack);
    (sim, cfg, table)
}

/// Random per-domain healthy counts: mostly full, some partially or
/// fully failed (including below-min-TP damage).
fn random_healthy(rng: &mut Rng, n: usize) -> Vec<usize> {
    (0..n)
        .map(|_| {
            if rng.chance(0.35) {
                DOMAIN_SIZE - 1 - rng.index(8) // 23..=31: spans min_tp
            } else if rng.chance(0.05) {
                0
            } else {
                DOMAIN_SIZE
            }
        })
        .collect()
}

fn shuffle(v: &mut [usize], rng: &mut Rng) {
    for i in (1..v.len()).rev() {
        let j = rng.index(i + 1);
        v.swap(i, j);
    }
}

/// The evaluation-context grid every registry property runs over:
/// spares on/off × packed on/off × each supplied transition model.
fn ctx_grid<'a>(
    table: &'a StrategyTable,
    transitions: &[Option<TransitionCosts>],
) -> Vec<PolicyCtx<'a>> {
    let mut out = Vec::new();
    for spares in [None, Some(SparePolicy { spare_domains: 3, cold_domains: 0, min_tp: 28 })] {
        for packed in [false, true] {
            for &transition in transitions {
                out.push(PolicyCtx {
                    table,
                    domain_size: DOMAIN_SIZE,
                    domains_per_replica: PER_REPLICA,
                    packed,
                    spares,
                    n_gpus: JOB_DOMAINS * DOMAIN_SIZE,
                    transition,
                });
            }
        }
    }
    out
}

/// Copy of the pre-policy-layer `FleetSim::evaluate` — the oracle the
/// legacy ports must reproduce bit-for-bit. One deliberate difference
/// for independence: the flexible arm goes through the `pack_domains`
/// reference implementation rather than the `packed_replica_tp` fast
/// path the live code uses (they are equivalence-tested against each
/// other in `manager::packing`), so a regression in the fast path
/// cannot cancel out of this comparison.
fn pre_refactor_evaluate(
    table: &StrategyTable,
    domain_size: usize,
    domains_per_replica: usize,
    packed: bool,
    strategy: FtStrategy,
    spares: Option<SparePolicy>,
    domain_healthy: &[usize],
) -> (f64, bool, usize) {
    match &spares {
        None => {
            let replica_tp =
                pack_domains(domain_healthy, domain_size, domains_per_replica, packed)
                    .replica_tp;
            (table.group_throughput(&replica_tp, strategy), false, 0)
        }
        Some(policy) => {
            let n_job = domain_healthy.len() - policy.spare_domains;
            let job_healthy = &domain_healthy[..n_job];
            let live_spares =
                domain_healthy[n_job..].iter().filter(|&&h| h == domain_size).count();
            let policy = SparePolicy { spare_domains: live_spares, ..*policy };
            let o = apply_spares(job_healthy, domain_size, domains_per_replica, &policy);
            let boosted = strategy == FtStrategy::NtpPw;
            let ok = match strategy {
                FtStrategy::DpDrop => meets_minibatch(&o.assignment, domain_size, false),
                FtStrategy::Ntp => {
                    let frac =
                        table.group_minibatch_frac(&o.assignment.replica_tp, strategy);
                    let shortfall = (1.0 - frac) * o.assignment.replica_tp.len() as f64;
                    shortfall < 1.0
                }
                FtStrategy::NtpPw => meets_minibatch(&o.assignment, policy.min_tp, boosted),
            };
            if !ok {
                return (0.0, true, o.spares_used);
            }
            let tput = table.group_throughput(&o.assignment.replica_tp, strategy);
            (tput, false, o.spares_used)
        }
    }
}

#[test]
fn legacy_ports_bit_identical_to_pre_refactor_paths() {
    let (_sim, _cfg, table) = setup();
    let topo = Topology::of((JOB_DOMAINS + SPARE_DOMAINS) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let mut rng = Rng::new(0x90);
    for trial in 0..300 {
        let healthy = random_healthy(&mut rng, JOB_DOMAINS + SPARE_DOMAINS);
        for strategy in [FtStrategy::DpDrop, FtStrategy::Ntp, FtStrategy::NtpPw] {
            for spares in
                [None, Some(SparePolicy { spare_domains: SPARE_DOMAINS, cold_domains: 0, min_tp: 28 })]
            {
                for packed in [false, true] {
                    let fs = FleetSim {
                        topo: &topo,
                        table: &table,
                        domains_per_replica: PER_REPLICA,
                        policy: strategy.policy(),
                        spares,
                        packed,
                        blast: BlastRadius::Single,
                        transition: None, // costs disabled => bit-identical
                        detect: None,
                    };
                    let got = fs.evaluate(&healthy);
                    let want = pre_refactor_evaluate(
                        &table,
                        DOMAIN_SIZE,
                        PER_REPLICA,
                        packed,
                        strategy,
                        spares,
                        &healthy,
                    );
                    assert_eq!(
                        (got.tput, got.paused, got.spares_used),
                        want,
                        "trial {trial} {strategy:?} spares {spares:?} packed {packed}"
                    );
                    assert_eq!(got.donated, 0.0, "legacy ports have no secondary channel");
                }
            }
        }
    }
}

/// The one registry-driven property pass: for every registered policy,
/// over the full context grid and randomized snapshots —
///
/// * `respond_with` (the memoized sweep hot path) equals `respond`
///   collapsed through `EvalOut::of`, exactly;
/// * throughput and the secondary (donated) channel stay in `[0, 1]`,
///   the spare pool is respected, `paused` implies zero throughput,
///   the overhead factor is a rate factor in `(0, 1]`, and per-replica
///   batches never exceed the full local batch;
/// * in packed mode (and fixed-minibatch mode, which always repacks),
///   the response is a pure function of the damage **multiset** — the
///   soundness contract of the shared sweep's snapshot memo.
#[test]
fn registry_properties_hold_for_every_policy() {
    let (sim, cfg, table) = setup();
    let transitions = [
        None,
        Some(TransitionCosts::model(&sim, &cfg)),
        // an observed failure rate, so rate-adaptive behavior is
        // exercised (Young/Daly interval + write-overhead factor)
        Some(TransitionCosts {
            failure_rate_per_hour: 1.5,
            ..TransitionCosts::model(&sim, &cfg)
        }),
    ];
    let mut rng = Rng::new(0x92);
    let mut scratch = EvalScratch::default();
    let grid = ctx_grid(&table, &transitions);
    for trial in 0..120 {
        let job = random_healthy(&mut rng, JOB_DOMAINS);
        let mut perm = job.clone();
        shuffle(&mut perm, &mut rng);
        for ctx in &grid {
            for policy in registry::all() {
                let name = policy.name();
                let resp = policy.respond(ctx, &job);
                let want = EvalOut::of(&resp, table.full_local_batch);
                let got = policy.respond_with(ctx, &job, &mut scratch);
                assert_eq!(
                    got, want,
                    "trial {trial} {name}: respond_with drifted from respond \
                     (spares {:?} packed {} transition {})",
                    ctx.spares,
                    ctx.packed,
                    ctx.transition.is_some()
                );

                assert!(
                    (0.0..=1.0 + 1e-12).contains(&got.tput),
                    "trial {trial} {name}: throughput {}",
                    got.tput
                );
                assert!(
                    (0.0..=1.0 + 1e-12).contains(&got.donated),
                    "trial {trial} {name}: donated {}",
                    got.donated
                );
                let pool = ctx.spares.map(|p| p.spare_domains).unwrap_or(0);
                assert!(
                    got.spares_used <= pool,
                    "trial {trial} {name}: used {} of {pool}",
                    got.spares_used
                );
                if got.paused {
                    assert_eq!(got.tput, 0.0, "{name}: paused must mean zero throughput");
                }
                assert!(
                    resp.overhead > 0.0 && resp.overhead <= 1.0,
                    "{name}: overhead {} is not a rate factor",
                    resp.overhead
                );
                assert_eq!(
                    resp.replicas.len(),
                    JOB_DOMAINS / PER_REPLICA,
                    "{name}: wrong replica count"
                );
                for r in &resp.replicas {
                    assert!(
                        r.batch <= table.full_local_batch,
                        "{name}: replica batch above full"
                    );
                }

                // Multiset purity — the snapshot-memo soundness contract.
                if ctx.packed || ctx.spares.is_some() {
                    let got_perm = policy.respond_with(ctx, &perm, &mut scratch);
                    assert_eq!(
                        got, got_perm,
                        "trial {trial} {name}: permuting domains changed the \
                         packed-mode response (job={job:?})"
                    );
                }
            }
        }
    }
}

/// Every registered policy on a fully healthy fleet: no pause, no
/// spares, unit throughput (transition model absent or rate-free — an
/// *observed* failure rate legitimately costs CKPT-ADAPTIVE its
/// checkpoint-write overhead even when healthy).
#[test]
fn healthy_fleet_is_lossless_under_every_policy() {
    let (sim, cfg, table) = setup();
    let job = vec![DOMAIN_SIZE; JOB_DOMAINS];
    for transition in [None, Some(TransitionCosts::model(&sim, &cfg))] {
        for policy in registry::all() {
            let ctx = PolicyCtx {
                table: &table,
                domain_size: DOMAIN_SIZE,
                domains_per_replica: PER_REPLICA,
                packed: true,
                spares: None,
                n_gpus: JOB_DOMAINS * DOMAIN_SIZE,
                transition,
            };
            let resp = policy.respond(&ctx, &job);
            assert!(!resp.paused, "{}", policy.name());
            assert_eq!(resp.spares_used, 0, "{}", policy.name());
            assert_eq!(resp.donated, 0.0, "{}: nothing to donate when healthy", policy.name());
            let tput = resp.throughput(table.full_local_batch);
            assert!((tput - 1.0).abs() < 1e-12, "{}: {tput}", policy.name());
            // Zero failures, no spare pool: every GPU at nominal draw
            // is exactly n/n — an exact division, so the fleet power
            // fraction is bit-exactly 1.0 (the "energy off by default"
            // contract the golden pins rest on).
            assert_eq!(resp.power, 1.0, "{}: healthy power", policy.name());
            assert_eq!(resp.rack_power, 1.0, "{}: healthy rack draw", policy.name());
        }
    }
}

/// Registry-driven energy-conformance pass: for every registered policy
/// over the full context grid and randomized snapshots —
///
/// * the fleet power fraction is finite and within
///   `[0, gpu_boost_cap × (job + pool GPUs) / job GPUs]` — the grid's
///   spare contexts provision the pool *on top of* `ctx.n_gpus`, so a
///   warm pool legitimately pushes the job-normalized fraction above 1;
/// * the hottest-domain draw is within `[0, gpu_boost_cap]` (a boosted
///   domain may exceed nominal, never the boost cap);
/// * a paused snapshot draws no more than the idle-power floor
///   ([`RackDesign::idle_frac`] over every provisioned-and-alive GPU).
///
/// `respond_with == respond` on the power channels is already pinned by
/// `registry_properties_hold_for_every_policy`, whose `EvalOut`
/// equality covers `power` and `rack_power` bit-for-bit.
#[test]
fn energy_conformance_for_every_policy() {
    let (sim, cfg, table) = setup();
    let transitions = [None, Some(TransitionCosts::model(&sim, &cfg))];
    let grid = ctx_grid(&table, &transitions);
    let cap = table.rack.gpu_boost_cap;
    let mut rng = Rng::new(0x98);
    let mut scratch = EvalScratch::default();
    for trial in 0..120 {
        let job = random_healthy(&mut rng, JOB_DOMAINS);
        for ctx in &grid {
            let pool_slack = ctx
                .spares
                .map(|p| (p.spare_domains * ctx.domain_size) as f64 / ctx.n_gpus as f64)
                .unwrap_or(0.0);
            for policy in registry::all() {
                let name = policy.name();
                let got = policy.respond_with(ctx, &job, &mut scratch);
                assert!(
                    got.power.is_finite() && got.power >= 0.0,
                    "trial {trial} {name}: power {}",
                    got.power
                );
                assert!(
                    got.power <= cap * (1.0 + pool_slack) + 1e-12,
                    "trial {trial} {name}: power {} above boost cap {cap} \
                     (pool slack {pool_slack})",
                    got.power
                );
                assert!(
                    (0.0..=cap + 1e-12).contains(&got.rack_power),
                    "trial {trial} {name}: rack draw {} outside [0, {cap}]",
                    got.rack_power
                );
                if got.paused {
                    assert!(
                        got.power <= table.rack.idle_frac * (1.0 + pool_slack) + 1e-12,
                        "trial {trial} {name}: paused power {} above the idle floor",
                        got.power
                    );
                    assert!(
                        got.rack_power <= table.rack.idle_frac + 1e-12,
                        "trial {trial} {name}: paused rack draw {}",
                        got.rack_power
                    );
                }
            }
        }
    }
}

/// Fleet power is monotone non-increasing in the failed-GPU count for
/// every non-boosting policy: each additional failure removes one GPU's
/// draw (or pauses the job at the idle floor, lower still). The two
/// exclusions are policy *features*, not violations: NTP-PW boosts
/// surviving reduced replicas (draw may rise with damage), and
/// POWER-SPARES wakes a dark domain when a failure migrates a spare in
/// (standby → nominal draw).
#[test]
fn power_monotone_in_failures_for_non_boosting_policies() {
    let (_sim, _cfg, table) = setup();
    let grid = ctx_grid(&table, &[None]);
    let mut scratch = EvalScratch::default();
    for ctx in &grid {
        for policy in registry::all() {
            let name = policy.name();
            if name == "NTP-PW" || name == "POWER-SPARES" {
                continue;
            }
            let mut job = vec![DOMAIN_SIZE; JOB_DOMAINS];
            let mut prev = policy.respond_with(ctx, &job, &mut scratch).power;
            // Deepen damage one GPU at a time, two whole domains plus a
            // third started — crosses the min-TP reshard and the pause
            // threshold for every policy family.
            for step in 0..(2 * DOMAIN_SIZE + DOMAIN_SIZE / 2) {
                let d = step / DOMAIN_SIZE;
                job[d] -= 1;
                let now = policy.respond_with(ctx, &job, &mut scratch).power;
                assert!(
                    now <= prev + 1e-12,
                    "{name}: power rose {prev} -> {now} at step {step} \
                     (spares {:?} packed {})",
                    ctx.spares,
                    ctx.packed
                );
                prev = now;
            }
        }
    }
}

/// Registry-driven degradation-layer properties, for every policy over
/// the full context grid and randomized straggler snapshots:
///
/// * `eval_degraded_with` (the sweeps' memo-bypassing hot path) equals
///   `eval_degraded`, exactly;
/// * zero degradation collapses **bit-identically** to the plain
///   respond path — the `slowdown >= 1.0` guard in `straggler_drag`
///   makes the multiply a bitwise no-op, so fail-only traces cannot
///   drift when a policy routes through the degradation entry point;
/// * the degraded response respects the same bounds as the healthy one
///   (throughput and donation in `[0, 1]`, pool respected, paused means
///   zero throughput);
/// * `degrade_transition_cost` is free without a cost model, free when
///   the degraded counts did not change, and finite/nonnegative
///   otherwise.
#[test]
fn degraded_path_properties_for_every_policy() {
    let (sim, cfg, table) = setup();
    let transitions = [None, Some(TransitionCosts::model(&sim, &cfg))];
    let grid = ctx_grid(&table, &transitions);
    let zero_deg = vec![0usize; JOB_DOMAINS];
    let unit_slow = vec![1.0f64; JOB_DOMAINS];
    let mut rng = Rng::new(0x96);
    let mut scratch = EvalScratch::default();
    for trial in 0..120 {
        let job = random_healthy(&mut rng, JOB_DOMAINS);
        // Straggler overlay: degraded GPUs are alive (still inside the
        // healthy count), each degraded domain paced by its slowest.
        let deg: Vec<usize> = job
            .iter()
            .map(|&h| if h > 0 && rng.chance(0.4) { 1 + rng.index(h.min(3)) } else { 0 })
            .collect();
        let slow: Vec<f64> =
            deg.iter().map(|&d| if d > 0 { 0.05 + rng.f64() * 0.9 } else { 1.0 }).collect();
        let mut prev_deg = deg.clone();
        shuffle(&mut prev_deg, &mut rng);
        for ctx in &grid {
            for policy in registry::all() {
                let name = policy.name();
                let want = policy.eval_degraded(ctx, &job, &deg, &slow);
                let got = policy.eval_degraded_with(ctx, &job, &deg, &slow, &mut scratch);
                assert_eq!(
                    got, want,
                    "trial {trial} {name}: eval_degraded_with drifted from \
                     eval_degraded (spares {:?} packed {})",
                    ctx.spares, ctx.packed
                );
                assert!(
                    (0.0..=1.0 + 1e-12).contains(&got.tput),
                    "trial {trial} {name}: degraded throughput {}",
                    got.tput
                );
                assert!(
                    (0.0..=1.0 + 1e-12).contains(&got.donated),
                    "trial {trial} {name}: degraded donated {}",
                    got.donated
                );
                let pool = ctx.spares.map(|p| p.spare_domains).unwrap_or(0);
                assert!(got.spares_used <= pool, "trial {trial} {name}");
                if got.paused {
                    assert_eq!(got.tput, 0.0, "{name}: paused must mean zero throughput");
                }

                // No stragglers => exactly the plain respond path.
                let collapsed = policy.eval_degraded(ctx, &job, &zero_deg, &unit_slow);
                assert_eq!(
                    collapsed,
                    EvalOut::of(&policy.respond(ctx, &job), table.full_local_batch),
                    "trial {trial} {name}: zero degradation did not collapse to respond"
                );
                assert_eq!(
                    policy.eval_degraded_with(ctx, &job, &zero_deg, &unit_slow, &mut scratch),
                    policy.respond_with(ctx, &job, &mut scratch),
                    "trial {trial} {name}: zero degradation did not collapse to \
                     respond_with"
                );

                let cost = policy.degrade_transition_cost(ctx, &prev_deg, &deg);
                if ctx.transition.is_none() {
                    assert_eq!(cost, 0.0, "{name} must be free without a cost model");
                } else {
                    assert!(cost.is_finite() && cost >= 0.0, "{name}: degrade cost {cost}");
                }
                assert_eq!(
                    policy.degrade_transition_cost(ctx, &deg, &deg),
                    0.0,
                    "{name}: unchanged degraded counts must charge nothing"
                );
            }
        }
    }
}

/// The cross-policy straggler claim the fig12 bench rests on, at the
/// single-snapshot level: four domains each paced by a deep straggler
/// favor STRAGGLER-EVICT (reshard the slow GPUs away, pay a small
/// capacity loss), while near-healthy stragglers favor
/// STRAGGLER-TOLERATE (the drag is cheaper than any capacity loss).
#[test]
fn straggler_evict_tolerate_crossover() {
    let (_sim, _cfg, table) = setup();
    let ctx = PolicyCtx {
        table: &table,
        domain_size: DOMAIN_SIZE,
        domains_per_replica: PER_REPLICA,
        packed: true,
        spares: None,
        n_gpus: JOB_DOMAINS * DOMAIN_SIZE,
        transition: None,
    };
    let evict = registry::parse("straggler-evict").unwrap();
    let tolerate = registry::parse("straggler-tolerate").unwrap();
    let job = vec![DOMAIN_SIZE; JOB_DOMAINS];
    let mut deg = vec![0usize; JOB_DOMAINS];
    for d in 0..4 {
        deg[d * PER_REPLICA] = 1;
    }
    for (slowdown, evict_wins) in [(0.1, true), (0.999, false)] {
        let slow: Vec<f64> =
            deg.iter().map(|&d| if d > 0 { slowdown } else { 1.0 }).collect();
        let e = evict.eval_degraded(&ctx, &job, &deg, &slow).tput;
        let t = tolerate.eval_degraded(&ctx, &job, &deg, &slow).tput;
        if evict_wins {
            assert!(e > t, "slowdown {slowdown}: evict {e} should beat tolerate {t}");
        } else {
            assert!(t > e, "slowdown {slowdown}: tolerate {t} should beat evict {e}");
        }
    }
}

/// Build a `(prev, next)` health-change pair with exactly `k_deg`
/// degraded and `k_imp` improved domains at randomized positions and
/// magnitudes.
fn random_change_pair(
    rng: &mut Rng,
    n: usize,
    k_deg: usize,
    k_imp: usize,
) -> (Vec<usize>, Vec<usize>) {
    assert!(k_deg + k_imp <= n);
    let mut order: Vec<usize> = (0..n).collect();
    shuffle(&mut order, rng);
    let mut prev = vec![DOMAIN_SIZE; n];
    let mut next = vec![DOMAIN_SIZE; n];
    for &d in order.iter().take(k_deg) {
        next[d] = DOMAIN_SIZE - 1 - rng.index(4); // fresh failure
    }
    for &d in order.iter().skip(k_deg).take(k_imp) {
        prev[d] = DOMAIN_SIZE - 1 - rng.index(4); // recovery
    }
    (prev, next)
}

/// Registry-driven transition-cost properties: free without a model;
/// nonnegative and finite with one; monotone in damage (more changed
/// domains never cost less, for fixed context); and — for policies
/// declaring `transition_cost_is_count_pure` (all in-tree ones) — equal
/// for any two change pairs with equal `(changed, degraded)` counts,
/// which is exactly what makes the shared sweep's transition memo
/// sound.
#[test]
fn transition_cost_properties_for_every_policy() {
    let (sim, cfg, table) = setup();
    let model = TransitionCosts {
        failure_rate_per_hour: 1.5,
        ..TransitionCosts::model(&sim, &cfg)
    };
    let free_grid = ctx_grid(&table, &[None]);
    let cost_grid = ctx_grid(&table, &[Some(model)]);
    let mut rng = Rng::new(0x94);
    for _trial in 0..60 {
        let k_deg = rng.index(4);
        let k_imp = rng.index(4);
        let (prev, next) = random_change_pair(&mut rng, JOB_DOMAINS, k_deg, k_imp);
        let (prev2, next2) = random_change_pair(&mut rng, JOB_DOMAINS, k_deg, k_imp);
        for policy in registry::all() {
            let name = policy.name();
            assert!(
                policy.transition_cost_is_count_pure(),
                "{name}: every in-tree policy must be count-pure (or the shared \
                 sweep loses its transition memo)"
            );
            for ctx in &free_grid {
                assert_eq!(
                    policy.transition_cost(ctx, &prev, &next),
                    0.0,
                    "{name} must be free without a TransitionCosts model"
                );
            }
            for ctx in &cost_grid {
                let cost = policy.transition_cost(ctx, &prev, &next);
                assert!(
                    cost.is_finite() && cost >= 0.0,
                    "{name}: transition cost {cost}"
                );
                // Count purity: same (changed, degraded) counts at
                // different positions/magnitudes, same bill.
                assert_eq!(
                    cost,
                    policy.transition_cost(ctx, &prev2, &next2),
                    "{name}: cost depends on positions/magnitudes, not counts \
                     (k_deg={k_deg} k_imp={k_imp})"
                );
                // Monotone in damage: one extra degraded domain on top of
                // the same change never lowers the bill.
                if k_deg + k_imp < JOB_DOMAINS {
                    let mut next_worse = next.clone();
                    let extra = (0..JOB_DOMAINS)
                        .find(|&d| prev[d] == DOMAIN_SIZE && next[d] == DOMAIN_SIZE)
                        .unwrap();
                    next_worse[extra] = DOMAIN_SIZE - 1;
                    assert!(
                        policy.transition_cost(ctx, &prev, &next_worse) >= cost,
                        "{name}: extra damage lowered the transition bill"
                    );
                }
            }
        }
    }
}

/// The cross-policy cost ordering under the default calibrated model,
/// for a single freshly degraded domain: live resharders (NTP family)
/// < spare migration < dark-spare wake-up < replica-scoped restart <
/// full restart < full restart + rollback; and the adaptive interval
/// degenerates to the fixed one without an observed rate, undercuts it
/// with one.
#[test]
fn transition_cost_ordering_across_policies() {
    let (sim, cfg, table) = setup();
    let prev = vec![DOMAIN_SIZE; JOB_DOMAINS];
    let mut next = prev.clone();
    next[3] = DOMAIN_SIZE - 1; // one domain degraded
    let ctx = PolicyCtx {
        table: &table,
        domain_size: DOMAIN_SIZE,
        domains_per_replica: PER_REPLICA,
        packed: true,
        spares: None,
        n_gpus: JOB_DOMAINS * DOMAIN_SIZE,
        transition: Some(TransitionCosts::model(&sim, &cfg)),
    };
    let cost = |name: &str| registry::parse(name).unwrap().transition_cost(&ctx, &prev, &next);
    let ntp = cost("ntp");
    let pw = cost("ntp-pw");
    let lowpri = cost("lowpri-donate");
    let drop = cost("dp-drop");
    let ckpt = cost("ckpt-restart");
    let adaptive = cost("ckpt-adaptive");
    let mig = cost("spare-mig");
    let power = cost("power-spares");
    let partial = cost("partial-restart");
    assert!(ntp > 0.0 && mig > 0.0);
    // The NTP family reshards only the affected replica; donation adds
    // no primary-job cost.
    assert_eq!(ntp, pw);
    assert_eq!(ntp, lowpri);
    // Migration streams weights on top of the reshard; waking a dark
    // domain adds the power ramp on top of that.
    assert!(mig > ntp, "mig {mig} vs ntp {ntp}");
    assert!(power > mig, "power {power} vs mig {mig}");
    // Replica-scoped restart+rollback beats stopping the world...
    assert!(partial > power, "partial {partial} vs power {power}");
    assert!(drop > partial, "full restart {drop} vs partial {partial}");
    // ...and the checkpoint rollback on top of the restart dwarfs both.
    assert!(ckpt > drop, "ckpt {ckpt} vs restart {drop}");
    // No observed rate -> the adaptive interval IS the fixed interval.
    assert_eq!(adaptive, ckpt);
    // a pure recovery (health restored) costs the restart family no
    // rollback
    let recover = registry::parse("ckpt-restart")
        .unwrap()
        .transition_cost(&ctx, &next, &prev);
    assert!(recover < ckpt && recover > 0.0);
    // With an observed rate making the Young/Daly interval shorter than
    // the fixed 3600 s, the adaptive rollback is strictly cheaper.
    let observed = PolicyCtx {
        transition: Some(TransitionCosts {
            failure_rate_per_hour: 2.0, // MTBF 1800 s => tau* ~ 657 s
            ..TransitionCosts::model(&sim, &cfg)
        }),
        ..ctx
    };
    let adaptive_obs = registry::parse("ckpt-adaptive")
        .unwrap()
        .transition_cost(&observed, &prev, &next);
    let ckpt_obs = registry::parse("ckpt-restart")
        .unwrap()
        .transition_cost(&observed, &prev, &next);
    assert!(
        adaptive_obs < ckpt_obs,
        "adaptive {adaptive_obs} should undercut fixed-interval {ckpt_obs}"
    );
}

#[test]
fn strategy_table_monotonicity_invariants() {
    let (sim, cfg, table) = setup();
    // batch nondecreasing in TP degree
    for w in table.batch.windows(2) {
        assert!(w[0] <= w[1], "batch not monotone: {:?}", table.batch);
    }
    // power boosting never does worse than plain NTP at the same TP
    for (b, bpw) in table.batch.iter().zip(&table.batch_pw) {
        assert!(bpw >= b, "batch_pw {bpw} < batch {b}");
    }
    // the table's modeled reshard overhead is exactly the engine's and
    // is bounded by the retired 0.995 constant
    assert_eq!(table.reshard_overhead, healthy_reshard_factor(&sim, &cfg));
    assert!(
        (0.995..1.0).contains(&table.reshard_overhead),
        "reshard overhead {} outside the old constant's bound",
        table.reshard_overhead
    );
}
