//! Policy-layer conformance suite.
//!
//! * The three legacy ports are **bit-identical** to the pre-refactor
//!   `FtStrategy` evaluation paths (a verbatim copy of the old
//!   `FleetSim::evaluate` is kept below as the oracle) when transition
//!   costs are disabled.
//! * Every registered policy keeps `throughput_frac` in `[0, 1]`,
//!   respects the spare pool, and charges zero transition cost without
//!   a `TransitionCosts` model.
//! * `StrategyTable` invariants: batch nondecreasing in TP,
//!   `batch_pw >= batch`, and the modeled reshard overhead bounded by
//!   the retired `0.995` constant.

use ntp::cluster::Topology;
use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::failure::BlastRadius;
use ntp::manager::packing::pack_domains;
use ntp::manager::spares::{apply_spares, meets_minibatch};
use ntp::manager::{FleetSim, SparePolicy, StrategyTable};
use ntp::parallel::ParallelConfig;
use ntp::policy::{registry, EvalScratch, PolicyCtx, TransitionCosts};
use ntp::power::RackDesign;
use ntp::sim::engine::healthy_reshard_factor;
use ntp::sim::{FtStrategy, IterationModel, SimParams};
use ntp::util::prng::Rng;

const DOMAIN_SIZE: usize = 32;
const PER_REPLICA: usize = 4;
const JOB_DOMAINS: usize = 24;
const SPARE_DOMAINS: usize = 6;

fn setup() -> (IterationModel, ParallelConfig, StrategyTable) {
    let sim = IterationModel::new(
        presets::model("gpt-480b").unwrap(),
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 2 * 1024 * 1024,
            dtype: Dtype::BF16,
        },
        presets::cluster("paper-32k-nvl32").unwrap(),
        SimParams::default(),
    );
    let cfg = ParallelConfig { tp: 32, pp: PER_REPLICA, dp: 16, microbatch: 1 };
    let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
    let table = StrategyTable::build(&sim, &cfg, &rack);
    (sim, cfg, table)
}

/// Random per-domain healthy counts: mostly full, some partially or
/// fully failed (including below-min-TP damage).
fn random_healthy(rng: &mut Rng, n: usize) -> Vec<usize> {
    (0..n)
        .map(|_| {
            if rng.chance(0.35) {
                DOMAIN_SIZE - 1 - rng.index(8) // 23..=31: spans min_tp
            } else if rng.chance(0.05) {
                0
            } else {
                DOMAIN_SIZE
            }
        })
        .collect()
}

/// Copy of the pre-policy-layer `FleetSim::evaluate` — the oracle the
/// legacy ports must reproduce bit-for-bit. One deliberate difference
/// for independence: the flexible arm goes through the `pack_domains`
/// reference implementation rather than the `packed_replica_tp` fast
/// path the live code uses (they are equivalence-tested against each
/// other in `manager::packing`), so a regression in the fast path
/// cannot cancel out of this comparison.
fn pre_refactor_evaluate(
    table: &StrategyTable,
    domain_size: usize,
    domains_per_replica: usize,
    packed: bool,
    strategy: FtStrategy,
    spares: Option<SparePolicy>,
    domain_healthy: &[usize],
) -> (f64, bool, usize) {
    match &spares {
        None => {
            let replica_tp =
                pack_domains(domain_healthy, domain_size, domains_per_replica, packed)
                    .replica_tp;
            (table.group_throughput(&replica_tp, strategy), false, 0)
        }
        Some(policy) => {
            let n_job = domain_healthy.len() - policy.spare_domains;
            let job_healthy = &domain_healthy[..n_job];
            let live_spares =
                domain_healthy[n_job..].iter().filter(|&&h| h == domain_size).count();
            let policy = SparePolicy { spare_domains: live_spares, ..*policy };
            let o = apply_spares(job_healthy, domain_size, domains_per_replica, &policy);
            let boosted = strategy == FtStrategy::NtpPw;
            let ok = match strategy {
                FtStrategy::DpDrop => meets_minibatch(&o.assignment, domain_size, false),
                FtStrategy::Ntp => {
                    let frac =
                        table.group_minibatch_frac(&o.assignment.replica_tp, strategy);
                    let shortfall = (1.0 - frac) * o.assignment.replica_tp.len() as f64;
                    shortfall < 1.0
                }
                FtStrategy::NtpPw => meets_minibatch(&o.assignment, policy.min_tp, boosted),
            };
            if !ok {
                return (0.0, true, o.spares_used);
            }
            let tput = table.group_throughput(&o.assignment.replica_tp, strategy);
            (tput, false, o.spares_used)
        }
    }
}

#[test]
fn legacy_ports_bit_identical_to_pre_refactor_paths() {
    let (_sim, _cfg, table) = setup();
    let topo = Topology::of((JOB_DOMAINS + SPARE_DOMAINS) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let mut rng = Rng::new(0x90);
    for trial in 0..300 {
        let healthy = random_healthy(&mut rng, JOB_DOMAINS + SPARE_DOMAINS);
        for strategy in [FtStrategy::DpDrop, FtStrategy::Ntp, FtStrategy::NtpPw] {
            for spares in
                [None, Some(SparePolicy { spare_domains: SPARE_DOMAINS, min_tp: 28 })]
            {
                for packed in [false, true] {
                    let fs = FleetSim {
                        topo: &topo,
                        table: &table,
                        domains_per_replica: PER_REPLICA,
                        policy: strategy.policy(),
                        spares,
                        packed,
                        blast: BlastRadius::Single,
                        transition: None, // costs disabled => bit-identical
                    };
                    let got = fs.evaluate(&healthy);
                    let want = pre_refactor_evaluate(
                        &table,
                        DOMAIN_SIZE,
                        PER_REPLICA,
                        packed,
                        strategy,
                        spares,
                        &healthy,
                    );
                    assert_eq!(
                        got, want,
                        "trial {trial} {strategy:?} spares {spares:?} packed {packed}"
                    );
                }
            }
        }
    }
}

#[test]
fn respond_with_matches_respond_for_every_policy() {
    // The allocation-free scratch path must collapse to exactly what
    // `respond` + `PolicyResponse::throughput` produce — it is what the
    // shared sweep memoizes, so any drift would silently poison every
    // multi-policy result.
    let (_sim, _cfg, table) = setup();
    let mut rng = Rng::new(0x92);
    let mut scratch = EvalScratch::default();
    for trial in 0..200 {
        let job = random_healthy(&mut rng, JOB_DOMAINS);
        for policy in registry::all() {
            for spares in [None, Some(SparePolicy { spare_domains: 3, min_tp: 28 })] {
                for packed in [false, true] {
                    let ctx = PolicyCtx {
                        table: &table,
                        domain_size: DOMAIN_SIZE,
                        domains_per_replica: PER_REPLICA,
                        packed,
                        spares,
                        n_gpus: JOB_DOMAINS * DOMAIN_SIZE,
                        transition: None,
                    };
                    let resp = policy.respond(&ctx, &job);
                    let want =
                        (resp.throughput(table.full_local_batch), resp.paused, resp.spares_used);
                    let got = policy.respond_with(&ctx, &job, &mut scratch);
                    assert_eq!(
                        got,
                        want,
                        "trial {trial} {} spares {spares:?} packed {packed}",
                        policy.name()
                    );
                }
            }
        }
    }
}

#[test]
fn every_policy_keeps_throughput_in_unit_interval() {
    let (_sim, _cfg, table) = setup();
    let mut rng = Rng::new(0x91);
    for trial in 0..200 {
        let job = random_healthy(&mut rng, JOB_DOMAINS);
        for policy in registry::all() {
            for spares in [None, Some(SparePolicy { spare_domains: 3, min_tp: 28 })] {
                let ctx = PolicyCtx {
                    table: &table,
                    domain_size: DOMAIN_SIZE,
                    domains_per_replica: PER_REPLICA,
                    packed: true,
                    spares,
                    n_gpus: JOB_DOMAINS * DOMAIN_SIZE,
                    transition: None,
                };
                let resp = policy.respond(&ctx, &job);
                let tput = resp.throughput(table.full_local_batch);
                assert!(
                    (0.0..=1.0 + 1e-12).contains(&tput),
                    "trial {trial} {}: throughput {tput}",
                    policy.name()
                );
                assert_eq!(resp.replicas.len(), JOB_DOMAINS / PER_REPLICA, "{}", policy.name());
                let pool = spares.map(|p| p.spare_domains).unwrap_or(0);
                assert!(
                    resp.spares_used <= pool,
                    "trial {trial} {}: used {} of {pool}",
                    policy.name(),
                    resp.spares_used
                );
                for r in &resp.replicas {
                    assert!(r.batch <= table.full_local_batch, "{}", policy.name());
                }
                // overhead is a rate factor, never a boost
                assert!(resp.overhead > 0.0 && resp.overhead <= 1.0, "{}", policy.name());
                // paused implies zero integrated throughput
                if resp.paused {
                    assert_eq!(tput, 0.0);
                }
            }
        }
    }
}

#[test]
fn healthy_fleet_is_lossless_under_every_policy() {
    let (_sim, _cfg, table) = setup();
    let job = vec![DOMAIN_SIZE; JOB_DOMAINS];
    for policy in registry::all() {
        let ctx = PolicyCtx {
            table: &table,
            domain_size: DOMAIN_SIZE,
            domains_per_replica: PER_REPLICA,
            packed: true,
            spares: None,
            n_gpus: JOB_DOMAINS * DOMAIN_SIZE,
            transition: None,
        };
        let resp = policy.respond(&ctx, &job);
        assert!(!resp.paused, "{}", policy.name());
        assert_eq!(resp.spares_used, 0, "{}", policy.name());
        let tput = resp.throughput(table.full_local_batch);
        assert!((tput - 1.0).abs() < 1e-12, "{}: {tput}", policy.name());
    }
}

#[test]
fn transition_costs_zero_without_model_and_sane_with() {
    let (sim, cfg, table) = setup();
    let prev = vec![DOMAIN_SIZE; JOB_DOMAINS];
    let mut next = prev.clone();
    next[3] = DOMAIN_SIZE - 1; // one domain degraded
    let base_ctx = PolicyCtx {
        table: &table,
        domain_size: DOMAIN_SIZE,
        domains_per_replica: PER_REPLICA,
        packed: true,
        spares: None,
        n_gpus: JOB_DOMAINS * DOMAIN_SIZE,
        transition: None,
    };
    for policy in registry::all() {
        assert_eq!(
            policy.transition_cost(&base_ctx, &prev, &next),
            0.0,
            "{} must be free without a TransitionCosts model",
            policy.name()
        );
    }
    let ctx = PolicyCtx {
        transition: Some(TransitionCosts::model(&sim, &cfg)),
        ..base_ctx
    };
    let cost = |name: &str| registry::parse(name).unwrap().transition_cost(&ctx, &prev, &next);
    let ntp = cost("ntp");
    let drop = cost("dp-drop");
    let ckpt = cost("ckpt-restart");
    let mig = cost("spare-mig");
    assert!(ntp > 0.0 && mig > 0.0);
    // full-job restart dwarfs a live reshard of one replica; rollback on
    // top of the restart dwarfs the restart
    assert!(drop > ntp, "restart {drop} vs reshard {ntp}");
    assert!(ckpt > drop, "ckpt {ckpt} vs restart {drop}");
    // a pure recovery (health restored) costs ckpt-restart no rollback
    let recover = registry::parse("ckpt-restart")
        .unwrap()
        .transition_cost(&ctx, &next, &prev);
    assert!(recover < ckpt && recover > 0.0);
}

#[test]
fn strategy_table_monotonicity_invariants() {
    let (sim, cfg, table) = setup();
    // batch nondecreasing in TP degree
    for w in table.batch.windows(2) {
        assert!(w[0] <= w[1], "batch not monotone: {:?}", table.batch);
    }
    // power boosting never does worse than plain NTP at the same TP
    for (b, bpw) in table.batch.iter().zip(&table.batch_pw) {
        assert!(bpw >= b, "batch_pw {bpw} < batch {b}");
    }
    // the table's modeled reshard overhead is exactly the engine's and
    // is bounded by the retired 0.995 constant
    assert_eq!(table.reshard_overhead, healthy_reshard_factor(&sim, &cfg));
    assert!(
        (0.995..1.0).contains(&table.reshard_overhead),
        "reshard overhead {} outside the old constant's bound",
        table.reshard_overhead
    );
}
