//! Golden-trace regression pin: one fixed-seed trace × every registered
//! policy × step mode (exact event-boundary + legacy grid) ×
//! transitions on/off × spares on/off, with the integrated
//! [`FleetStats`] pinned **bit-exactly** (f64s compared by bit pattern,
//! serialized as hex) against `tests/golden/fleet_stats_v1.json`.
//!
//! Purpose: catch silent numeric drift across refactors — a reordered
//! float expression, a changed accumulation order, a "harmless"
//! simplification — that every tolerance-based assertion would wave
//! through.
//!
//! Bless protocol: when the golden file is absent (first run on a new
//! checkout) the test writes it and passes, printing a notice; commit
//! the file to pin the numbers. After an *intentional* numeric change,
//! re-bless with `UPDATE_GOLDEN=1 cargo test --test golden_trace`.
//! Once the file IS committed, CI runs with `GOLDEN_VERIFY=1`, which
//! turns a missing file into a hard failure instead of a bless — the
//! verify-only mode that makes the pin bite on every checkout.
//!
//! Key handling is *additive*: pinned keys always verify bit-exactly,
//! but keys the file has never seen (a freshly registered policy
//! widening the grid) are blessed in place with a notice — growing the
//! registry never forces a manual re-bless of numbers that did not
//! move. Stale pinned keys (no longer produced) still hard-fail.
//!
//! Independent of the file, every entry is cross-checked in-run against
//! the per-step replay path and the shared multi-policy sweep, so all
//! three integration paths must agree bit-for-bit on the golden trace
//! before anything is compared or blessed.

use ntp::cluster::Topology;
use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::failure::{BlastRadius, FailureModel, Trace};
use ntp::manager::{FleetSim, FleetStats, MultiPolicySim, SparePolicy, StepMode, StrategyTable};
use ntp::parallel::ParallelConfig;
use ntp::policy::{registry, TransitionCosts};
use ntp::power::RackDesign;
use ntp::sim::{IterationModel, SimParams};
use ntp::util::json::Value;
use ntp::util::prng::Rng;

const DOMAIN_SIZE: usize = 32;
const PER_REPLICA: usize = 4;
const JOB_DOMAINS: usize = 24;
const SPARE_DOMAINS: usize = 4;
const GOLDEN_PATH: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/fleet_stats_v1.json");

fn hex(x: f64) -> Value {
    Value::Str(format!("{:016x}", x.to_bits()))
}

/// Bit-exact, human-auditable serialization: every f64 as its hex bit
/// pattern plus a lossy decimal echo for the reviewer.
///
/// Deliberately does NOT include the energy channel: the power stats
/// are pinned under their own `…|energy` keys (below) so they ride the
/// additive-verify path — a checkout whose golden file predates the
/// energy channel keeps verifying every existing key bit-exactly and
/// blesses the energy keys in place, proving the default-off energy
/// accounting left the pinned numbers untouched.
fn stats_value(s: &FleetStats) -> Value {
    Value::obj(vec![
        ("mean_throughput", hex(s.mean_throughput)),
        ("paused_frac", hex(s.paused_frac)),
        ("mean_spares_used", hex(s.mean_spares_used)),
        ("throughput_per_gpu", hex(s.throughput_per_gpu)),
        ("downtime_frac", hex(s.downtime_frac)),
        ("mean_donated", hex(s.mean_donated)),
        ("transitions", s.transitions.into()),
        ("echo_mean_throughput", Value::Str(format!("{:.6}", s.mean_throughput))),
    ])
}

/// The energy channel's own pin: the two integrated power stats plus
/// the derived tokens-per-joule ratio, hex-exact.
fn energy_value(s: &FleetStats) -> Value {
    Value::obj(vec![
        ("mean_power_frac", hex(s.mean_power_frac)),
        ("peak_rack_power_frac", hex(s.peak_rack_power_frac)),
        ("energy_per_token", hex(s.energy_per_token())),
        ("echo_mean_power_frac", Value::Str(format!("{:.6}", s.mean_power_frac))),
    ])
}

#[test]
fn golden_trace_pins_fleet_stats_for_every_policy() {
    let sim = IterationModel::new(
        presets::model("gpt-480b").unwrap(),
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 2 * 1024 * 1024,
            dtype: Dtype::BF16,
        },
        presets::cluster("paper-32k-nvl32").unwrap(),
        SimParams::default(),
    );
    let cfg = ParallelConfig { tp: DOMAIN_SIZE, pp: PER_REPLICA, dp: 16, microbatch: 1 };
    let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
    let table = StrategyTable::build(&sim, &cfg, &rack);
    let topo = Topology::of((JOB_DOMAINS + SPARE_DOMAINS) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    // THE golden trace: fixed seed, fixed rate, fixed horizon. Any
    // change here invalidates the pinned file by design.
    let model = FailureModel::llama3().scaled(40.0);
    let mut rng = Rng::new(0x601D);
    let trace = Trace::generate(&topo, &model, 24.0 * 20.0, &mut rng);
    assert!(!trace.events.is_empty(), "golden trace generated no events");
    let observed = TransitionCosts::model(&sim, &cfg).with_observed_rate(&trace);

    let policies = registry::all();
    let mut entries: Vec<(String, FleetStats)> = Vec::new();
    // Exact event-boundary integration is pinned first (the default
    // semantics every caller now gets); the legacy 2h grid rides along
    // so the clamped-final-interval arithmetic is frozen too.
    for (mode_key, mode) in [("exact", StepMode::Exact), ("grid2h", StepMode::Grid(2.0))] {
        for transition in [None, Some(observed)] {
            for spares in [
                None,
                Some(SparePolicy {
                    spare_domains: SPARE_DOMAINS,
                    cold_domains: 0,
                    min_tp: 28,
                }),
            ] {
                // Cross-check all three integration paths on this config
                // before pinning anything: shared sweep == event-driven
                // per-policy run == per-step replay, bit for bit.
                let msim = MultiPolicySim {
                    topo: &topo,
                    table: &table,
                    domains_per_replica: PER_REPLICA,
                    policies: &policies,
                    spares,
                    packed: true,
                    blast: BlastRadius::Single,
                    transition,
                    detect: None,
                };
                let shared = msim.run(&trace, mode);
                for (i, &policy) in policies.iter().enumerate() {
                    let fs = FleetSim {
                        topo: &topo,
                        table: &table,
                        domains_per_replica: PER_REPLICA,
                        policy,
                        spares,
                        packed: true,
                        blast: BlastRadius::Single,
                        transition,
                        detect: None,
                    };
                    let stats = fs.run(&trace, mode);
                    assert_eq!(
                        stats,
                        fs.run_replay_per_step(&trace, mode),
                        "{} ({mode_key}): event-driven vs per-step drift on the golden trace",
                        policy.name()
                    );
                    assert_eq!(
                        stats,
                        shared[i],
                        "{} ({mode_key}): shared-sweep drift on the golden trace",
                        policy.name()
                    );
                    let key = format!(
                        "{}|mode={mode_key}|spares={}|transitions={}",
                        policy.name(),
                        spares.map(|p| p.spare_domains).unwrap_or(0),
                        transition.is_some()
                    );
                    entries.push((key, stats));
                }
            }
        }
    }

    // Every config pins two keys: the original stats object (unchanged
    // field set — its hex values must not move when the energy channel
    // is off by default) and a sibling `…|energy` key for the power
    // integrals, additive for checkouts pinned before the channel
    // existed.
    let flat: Vec<(String, Value)> = entries
        .iter()
        .flat_map(|(k, s)| {
            [(k.clone(), stats_value(s)), (format!("{k}|energy"), energy_value(s))]
        })
        .collect();
    let got = Value::Obj(flat.iter().cloned().collect());
    let rebless = std::env::var("UPDATE_GOLDEN").is_ok();
    // Verify-only mode (CI sets GOLDEN_VERIFY=1 once the golden file is
    // committed): a missing file is a failure, never a silent bless.
    let verify_only = std::env::var("GOLDEN_VERIFY").map(|v| !v.is_empty()).unwrap_or(false);
    if verify_only {
        assert!(
            !rebless,
            "GOLDEN_VERIFY and UPDATE_GOLDEN are mutually exclusive \
             (re-bless locally, then commit the diff)"
        );
        assert!(
            std::path::Path::new(GOLDEN_PATH).exists(),
            "GOLDEN_VERIFY=1 but {GOLDEN_PATH} is missing — the golden pin must be \
             committed before the verify-only CI mode is enabled"
        );
    }
    match std::fs::read_to_string(GOLDEN_PATH) {
        Ok(text) if !rebless => {
            let want = Value::parse(&text)
                .unwrap_or_else(|e| panic!("golden file is not valid JSON: {e}"));
            let want_map = want.as_obj().expect("golden file must be a JSON object");
            // Stale pinned keys — in the file but no longer produced —
            // mean a policy or grid axis was REMOVED. That is never
            // additive: hard-fail even in verify-only mode.
            let produced: std::collections::HashSet<&str> =
                flat.iter().map(|(k, _)| k.as_str()).collect();
            let stale: Vec<&String> =
                want_map.keys().filter(|k| !produced.contains(k.as_str())).collect();
            assert!(
                stale.is_empty(),
                "golden file pins {} key(s) the test no longer produces (first: \
                 '{}') — a policy or grid axis was removed; re-bless with \
                 UPDATE_GOLDEN=1 if intentional",
                stale.len(),
                stale.first().map(|s| s.as_str()).unwrap_or("")
            );
            // Already-pinned keys verify bit-exactly. Keys the pin has
            // never seen (a freshly registered policy widening the grid)
            // are ADDITIVE: bless them in place — growing the registry
            // must not force a manual re-bless of numbers that did not
            // move, and must not dodge verification of the ones pinned.
            let mut fresh: Vec<&str> = Vec::new();
            for (key, value) in &flat {
                if !want_map.contains_key(key.as_str()) {
                    fresh.push(key);
                    continue;
                }
                assert_eq!(
                    want.get(key),
                    value,
                    "FleetStats drifted from the golden record for '{key}'.\n\
                     If this change is intentional, re-bless with:\n\
                     UPDATE_GOLDEN=1 cargo test --test golden_trace"
                );
            }
            if !fresh.is_empty() {
                std::fs::write(GOLDEN_PATH, got.pretty()).expect("writing golden file");
                eprintln!(
                    "golden_trace: verified {} pinned key(s) bit-exactly and \
                     appended {} new one(s) (first: '{}') to {GOLDEN_PATH} — \
                     commit the diff to pin them",
                    want_map.len(),
                    fresh.len(),
                    fresh[0]
                );
            }
        }
        _ => {
            if let Some(dir) = std::path::Path::new(GOLDEN_PATH).parent() {
                std::fs::create_dir_all(dir).expect("creating tests/golden");
            }
            std::fs::write(GOLDEN_PATH, got.pretty()).expect("writing golden file");
            eprintln!(
                "golden_trace: {} {GOLDEN_PATH} with {} entries — commit it to pin",
                if rebless { "re-blessed" } else { "blessed" },
                flat.len()
            );
        }
    }
}
