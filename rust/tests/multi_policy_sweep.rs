//! Shared-sweep engine equivalence and memoization-soundness suite.
//!
//! * [`MultiPolicySim`] produces **bit-identical** per-policy
//!   [`FleetStats`] to running the per-policy reference
//!   `FleetSim::run` once per policy — property-tested over random
//!   traces, spares on/off, transitions on/off, packed on/off.
//! * Memo soundness: in packed mode (and in fixed-minibatch mode,
//!   whose spare substitution + packing always reorder), every
//!   registered policy's `EvalOut` (throughput, pause, spares used,
//!   donated channel) is a pure function of the damaged-domain
//!   **multiset** — permuting domains never changes the response. The
//!   count-keyed transition memo rides the same bit-identity property.
//! * The counterexample that keeps the memo honest: in *unpacked*
//!   flexible mode the response depends on domain **positions**, so two
//!   snapshots with equal damage multisets can evaluate differently —
//!   which is exactly why `MultiPolicySim` bypasses the memo there.
//! * Sharing one [`ResponseMemo`] across trials and sweep points gives
//!   the same stats as fresh memos.

use ntp::cluster::Topology;
use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::failure::{BlastRadius, FailureModel, Trace};
use ntp::manager::{
    FleetSim, FleetStats, MultiPolicySim, ResponseMemo, SparePolicy, StepMode, StrategyTable,
};
use ntp::parallel::ParallelConfig;
use ntp::policy::{registry, EvalScratch, PolicyCtx, TransitionCosts};
use ntp::power::RackDesign;
use ntp::sim::{IterationModel, SimParams};
use ntp::util::prng::Rng;
use ntp::util::prop::{check, SeedGen};

const DOMAIN_SIZE: usize = 32;
const PER_REPLICA: usize = 4;

fn setup() -> (IterationModel, ParallelConfig, StrategyTable) {
    let sim = IterationModel::new(
        presets::model("gpt-480b").unwrap(),
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 2 * 1024 * 1024,
            dtype: Dtype::BF16,
        },
        presets::cluster("paper-32k-nvl32").unwrap(),
        SimParams::default(),
    );
    let cfg = ParallelConfig { tp: DOMAIN_SIZE, pp: PER_REPLICA, dp: 16, microbatch: 1 };
    let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
    let table = StrategyTable::build(&sim, &cfg, &rack);
    (sim, cfg, table)
}

fn random_healthy(rng: &mut Rng, n: usize) -> Vec<usize> {
    (0..n)
        .map(|_| {
            if rng.chance(0.35) {
                DOMAIN_SIZE - 1 - rng.index(8)
            } else if rng.chance(0.05) {
                0
            } else {
                DOMAIN_SIZE
            }
        })
        .collect()
}

fn shuffle(v: &mut [usize], rng: &mut Rng) {
    for i in (1..v.len()).rev() {
        let j = rng.index(i + 1);
        v.swap(i, j);
    }
}

#[test]
fn shared_sweep_bit_identical_to_per_policy_runs() {
    let (sim, cfg, table) = setup();
    let policies = registry::all();
    let gen = SeedGen;
    check(0x5EE9, 8, &gen, |&seed| {
        let mut rng = Rng::new(seed);
        let spare_domains = [0usize, 4, 6][rng.index(3)];
        let job_domains = PER_REPLICA * (8 + rng.index(12));
        let topo =
            Topology::of((job_domains + spare_domains) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
        let model = FailureModel::llama3().scaled(20.0 + rng.f64() * 60.0);
        let horizon = 24.0 * (8.0 + rng.f64() * 15.0);
        let trace = Trace::generate(&topo, &model, horizon, &mut rng);
        let blast = [BlastRadius::Single, BlastRadius::Node][rng.index(2)];
        let spares = if spare_domains > 0 {
            Some(SparePolicy { spare_domains, cold_domains: 0, min_tp: 28 })
        } else {
            // also exercises flexible mode (and unpacked flexible,
            // where the memo is bypassed entirely)
            None
        };
        // The observed rate makes CKPT-ADAPTIVE genuinely adaptive
        // (Young/Daly interval + steady-state write overhead), so its
        // memoized responses and transition charges are exercised too.
        let observed = TransitionCosts::model(&sim, &cfg).with_observed_rate(&trace);
        // Exact event-boundary integration and the legacy grid must
        // both come out bit-identical to the per-policy reference;
        // alternating per case keeps the property-run cost flat while
        // both modes appear across the seeds.
        let mode = [StepMode::Grid(2.0), StepMode::Exact][rng.index(2)];
        for packed in [true, false] {
            for transition in [None, Some(observed)] {
                let msim = MultiPolicySim {
                    topo: &topo,
                    table: &table,
                    domains_per_replica: PER_REPLICA,
                    policies: &policies,
                    spares,
                    packed,
                    blast,
                    transition,
                    detect: None,
                };
                let shared = msim.run(&trace, mode);
                for (i, &policy) in policies.iter().enumerate() {
                    let fs = FleetSim {
                        topo: &topo,
                        table: &table,
                        domains_per_replica: PER_REPLICA,
                        policy,
                        spares,
                        packed,
                        blast,
                        transition,
                        detect: None,
                    };
                    let reference = fs.run(&trace, mode);
                    if shared[i] != reference {
                        return Err(format!(
                            "policy {} mode {mode:?} packed {packed} spares {spares:?} \
                             transition {:?}: shared {:?} != reference {reference:?}",
                            policy.name(),
                            transition.is_some(),
                            shared[i]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn memo_shared_across_trials_and_sweep_points_is_sound() {
    let (_sim, _cfg, table) = setup();
    let policies = registry::all();
    let job_domains = 24usize;
    let max_spares = 6usize;
    let topo = Topology::of((job_domains + max_spares) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(45.0);
    let mut rng = Rng::new(0xA11);
    let traces: Vec<Trace> = (0..3)
        .map(|i| {
            let mut r = rng.fork(i as u64);
            Trace::generate(&topo, &model, 24.0 * 12.0, &mut r)
        })
        .collect();
    // One memo shared across 3 trials x 3 spare budgets must reproduce
    // what fresh memos produce: sweep points share the topology, and the
    // pool size enters the memo key only through the live-spare count
    // and the job-domain count (fig7-style sweeps rely on this).
    let mut shared_memo = ResponseMemo::new(policies.len());
    let mut with_shared: Vec<Vec<FleetStats>> = Vec::new();
    let mut with_fresh: Vec<Vec<FleetStats>> = Vec::new();
    for &spare_domains in &[0usize, 3, max_spares] {
        let msim = MultiPolicySim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policies: &policies,
            spares: Some(SparePolicy { spare_domains, cold_domains: 0, min_tp: 28 }),
            packed: true,
            blast: BlastRadius::Single,
            transition: Some(TransitionCosts {
                restart_secs: 900.0,
                checkpoint_interval_secs: 3600.0,
                reshard_secs: 2.0,
                spare_load_secs: 300.0,
                cold_spare_load_secs: 1800.0,
                preempt_secs: 5.0,
                rejoin_secs: 45.0,
                ckpt_write_secs: 120.0,
                power_ramp_secs: 60.0,
                // nonzero: CKPT-ADAPTIVE's rate-dependent responses and
                // charges must also memo-share soundly
                failure_rate_per_hour: 0.8,
                validation_sweep_secs: 0.0,
            }),
            detect: None,
        };
        with_shared.extend(msim.run_trials(&traces, StepMode::Exact, &mut shared_memo));
        for trace in &traces {
            with_fresh.push(msim.run(trace, StepMode::Exact));
        }
        // ... and the parallel fan-out (per-thread memos) must be
        // bit-identical to all of the above, for any worker count —
        // including counts above the trace count (5 and 9 over 3
        // traces), where the trailing workers' batches would be empty
        // and are not spawned at all.
        for threads in [1usize, 2, 5, 9] {
            let (par_stats, memo_stats) = msim.run_trials_par(&traces, StepMode::Exact, threads);
            assert_eq!(
                par_stats,
                &with_fresh[with_fresh.len() - traces.len()..],
                "run_trials_par({threads}) diverged at spares={spare_domains}"
            );
            assert!(memo_stats.hits + memo_stats.misses > 0);
        }
    }
    assert_eq!(with_shared, with_fresh);
    assert!(
        shared_memo.hits() > 0,
        "sharing across trials/sweep points should produce memo hits"
    );
    assert!(
        shared_memo.transition_hits() > 0,
        "repeated (changed, degraded) patterns should hit the transition memo"
    );
}

/// The count-keyed transition memo must serve **bit-identical** charges:
/// a warm shared sweep (second pass over the same trace, memo fully
/// primed — every charge a cache hit) against the per-policy
/// `FleetSim::run` reference, which never memoizes. This is the
/// ROADMAP "memoize transition_cost per (policy, changed, degraded,
/// live_spares)" follow-on made safe.
#[test]
fn transition_memo_charges_are_bit_identical() {
    let (sim, cfg, table) = setup();
    let policies = registry::all();
    let job_domains = 24usize;
    let spare_domains = 4usize;
    let topo = Topology::of((job_domains + spare_domains) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(50.0);
    let mut rng = Rng::new(0xC0DE);
    let trace = Trace::generate(&topo, &model, 24.0 * 18.0, &mut rng);
    let transition = Some(TransitionCosts::model(&sim, &cfg).with_observed_rate(&trace));
    for spares in [None, Some(SparePolicy { spare_domains, cold_domains: 0, min_tp: 28 })] {
        let msim = MultiPolicySim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policies: &policies,
            spares,
            packed: true,
            blast: BlastRadius::Single,
            transition,
            detect: None,
        };
        let mut memo = msim.memo();
        let cold = msim.run_with(&trace, StepMode::Exact, &mut memo);
        let cold_hits = memo.transition_hits();
        let warm = msim.run_with(&trace, StepMode::Exact, &mut memo);
        assert_eq!(cold, warm, "a fully warm transition memo changed the stats");
        assert!(
            memo.transition_misses() > 0,
            "transitions never charged — the trace is too quiet for this test"
        );
        assert!(
            memo.transition_hits() > cold_hits,
            "second pass should be served from the transition memo"
        );
        for (i, &policy) in policies.iter().enumerate() {
            let reference = FleetSim {
                topo: &topo,
                table: &table,
                domains_per_replica: PER_REPLICA,
                policy,
                spares,
                packed: true,
                blast: BlastRadius::Single,
                transition,
                detect: None,
            }
            .run(&trace, StepMode::Exact);
            assert_eq!(
                cold[i],
                reference,
                "memoized charges for {} diverge from the unmemoized reference",
                policy.name()
            );
        }
    }
}

#[test]
fn packed_responses_depend_only_on_damage_multiset() {
    let (_sim, _cfg, table) = setup();
    let policies = registry::all();
    let job_domains = 24usize;
    let spare_domains = 5usize;
    let mut rng = Rng::new(0xB0B);
    let mut scratch = EvalScratch::default();
    for trial in 0..250 {
        let job = random_healthy(&mut rng, job_domains);
        let spare_tail = random_healthy(&mut rng, spare_domains);
        // Permute the job domains: equal damage multiset, different
        // positions. (Permuting the spare tail is covered implicitly —
        // only its live count enters the evaluation, and counts are
        // permutation-invariant.)
        let mut job_perm = job.clone();
        shuffle(&mut job_perm, &mut rng);
        // The live pool exactly as the sweep derives it from the tail.
        let live = spare_tail.iter().filter(|&&h| h == DOMAIN_SIZE).count();
        for spares in [None, Some(SparePolicy { spare_domains: live, cold_domains: 0, min_tp: 28 })] {
            let ctx = PolicyCtx {
                table: &table,
                domain_size: DOMAIN_SIZE,
                domains_per_replica: PER_REPLICA,
                packed: true,
                spares,
                n_gpus: (job_domains + spare_domains) * DOMAIN_SIZE,
                transition: None,
            };
            for policy in policies {
                let a = policy.respond_with(&ctx, &job, &mut scratch);
                let b = policy.respond_with(&ctx, &job_perm, &mut scratch);
                assert_eq!(
                    a,
                    b,
                    "trial {trial} {} spares {spares:?}: permuting domains changed \
                     the packed-mode response (job={job:?})",
                    policy.name()
                );
            }
        }
    }
}

/// Why unpacked flexible mode must bypass the memo: without the
/// resource manager's rank reassignment, a replica's TP is the min over
/// its *positional* domain chunk, so the same damage multiset spread
/// across chunks vs concentrated in one chunk gives different
/// throughput. This is the documented counterexample — the memo would
/// return the wrong cached value for the second snapshot.
#[test]
fn unpacked_mode_is_position_dependent_and_must_bypass_memo() {
    let (_sim, _cfg, table) = setup();
    let job_domains = 16usize; // 4 replicas x 4 domains
    let ctx = PolicyCtx {
        table: &table,
        domain_size: DOMAIN_SIZE,
        domains_per_replica: PER_REPLICA,
        packed: false,
        spares: None,
        n_gpus: job_domains * DOMAIN_SIZE,
        transition: None,
    };
    // Same multiset {31, 31, 31, 31, 32 x 12}: spread hits 4 replicas,
    // concentrated hits 1.
    let mut spread = vec![DOMAIN_SIZE; job_domains];
    spread[0] = 31;
    spread[4] = 31;
    spread[8] = 31;
    spread[12] = 31;
    let mut packed_damage = vec![DOMAIN_SIZE; job_domains];
    packed_damage[0] = 31;
    packed_damage[1] = 31;
    packed_damage[2] = 31;
    packed_damage[3] = 31;
    let mut scratch = EvalScratch::default();
    let mut saw_difference = false;
    for policy in registry::all() {
        let a = policy.respond_with(&ctx, &spread, &mut scratch);
        let b = policy.respond_with(&ctx, &packed_damage, &mut scratch);
        // SPARE-MIG — and POWER-SPARES, which delegates its capacity
        // response to it — always restacks (ignores ctx.packed), so
        // they agree; the positional policies must not.
        if matches!(policy.name(), "SPARE-MIG" | "POWER-SPARES") {
            assert_eq!(a, b, "{} restacks regardless of packing", policy.name());
        } else if a != b {
            saw_difference = true;
        }
    }
    assert!(
        saw_difference,
        "expected at least one policy to be position-dependent in unpacked mode"
    );
    // ... and in packed mode the very same snapshots agree for all.
    let packed_ctx = PolicyCtx { packed: true, ..ctx };
    for policy in registry::all() {
        assert_eq!(
            policy.respond_with(&packed_ctx, &spread, &mut scratch),
            policy.respond_with(&packed_ctx, &packed_damage, &mut scratch),
            "{}",
            policy.name()
        );
    }
}
