//! Integration: end-to-end training numerics through the full
//! AOT-artifact + PJRT + Rust-sync stack.
//!
//! The NTP correctness claim: a DP group with a reduced-TP replica
//! trains *identically* (to float tolerance) to a uniform group, because
//! resharding + 1:1 allreduce reconstruct the same global gradient.
//! These tests skip (pass trivially) if artifacts have not been built.

use ntp::runtime::{manifest::default_dir, Runtime};
use ntp::train::{Trainer, TrainerConfig};

fn runtime() -> Option<Runtime> {
    let dir = default_dir();
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).unwrap())
}

fn tiny_cfg(replicas: Vec<(usize, usize)>) -> TrainerConfig {
    TrainerConfig { model: "tiny".into(), replicas, lr: 1e-3, seed: 1234 }
}

#[test]
fn ntp_group_matches_uniform_group() {
    let Some(rt) = runtime() else { return };
    // Uniform DP2 at TP4 vs NTP DP2 at (TP4, TP3): same seeds, same data
    // streams, same batch sizes -> loss curves must coincide.
    let mut uniform = Trainer::new(&rt, &tiny_cfg(vec![(4, 4), (4, 4)])).unwrap();
    let mut ntp_grp = Trainer::new(&rt, &tiny_cfg(vec![(4, 4), (3, 4)])).unwrap();
    for step in 0..12 {
        let a = uniform.step().unwrap();
        let b = ntp_grp.step().unwrap();
        assert!(
            (a.loss - b.loss).abs() < 2e-4,
            "step {step}: uniform {} vs ntp {}",
            a.loss,
            b.loss
        );
    }
    // and training must actually be learning
    let first = uniform.history.first().unwrap().loss;
    let last = uniform.history.last().unwrap().loss;
    assert!(last < first, "loss should drop: {first} -> {last}");
}

#[test]
fn reduced_batch_ntp_weighting_is_consistent() {
    let Some(rt) = runtime() else { return };
    // Plain-NTP mode: the TP3 replica runs batch 3 (of 4). The weighted
    // sync must keep training stable and converging.
    let mut t = Trainer::new(&rt, &tiny_cfg(vec![(4, 4), (3, 3)])).unwrap();
    let mut losses = Vec::new();
    for _ in 0..25 {
        losses.push(t.step().unwrap().loss);
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    let head: f64 = losses[..5].iter().sum::<f64>() / 5.0;
    let tail: f64 = losses[losses.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(tail < head, "no learning: head {head} tail {tail}");
}

#[test]
fn live_reconfiguration_preserves_training() {
    let Some(rt) = runtime() else { return };
    // Reference: uniform (4,4)+(4,4) for 20 steps.
    let mut reference = Trainer::new(&rt, &tiny_cfg(vec![(4, 4), (4, 4)])).unwrap();
    for _ in 0..20 {
        reference.step().unwrap();
    }
    // Failure at step 10: replica 1 drops TP4 -> TP3 (same batch — the
    // power-boost scenario). Parameters and Adam moments are resharded
    // live; the loss trajectory must match the uniform run throughout.
    let mut failed = Trainer::new(&rt, &tiny_cfg(vec![(4, 4), (4, 4)])).unwrap();
    for _ in 0..10 {
        failed.step().unwrap();
    }
    failed.inject_failure(&rt, 1, 3, 4).unwrap();
    assert_eq!(failed.replicas[1].tp(), 3);
    for _ in 10..20 {
        failed.step().unwrap();
    }
    for (a, b) in reference.history.iter().zip(&failed.history) {
        assert!(
            (a.loss - b.loss).abs() < 5e-4,
            "step {}: ref {} vs failover {}",
            a.step,
            a.loss,
            b.loss
        );
    }
}

#[test]
fn single_replica_tp_invariance_over_steps() {
    let Some(rt) = runtime() else { return };
    // DP1 at TP1 vs DP1 at TP4: identical optimization trajectory.
    let mut tp1 = Trainer::new(&rt, &tiny_cfg(vec![(1, 4)])).unwrap();
    let mut tp4 = Trainer::new(&rt, &tiny_cfg(vec![(4, 4)])).unwrap();
    for step in 0..10 {
        let a = tp1.step().unwrap();
        let b = tp4.step().unwrap();
        assert!(
            (a.loss - b.loss).abs() < 2e-4,
            "step {step}: tp1 {} vs tp4 {}",
            a.loss,
            b.loss
        );
    }
}
