//! Integration: fleet-level invariants across failure engine, resource
//! manager, power allocator and strategy evaluation (Figs. 3, 6, 7, 10
//! machinery).

use ntp::cluster::Topology;
use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::failure::{sample_failed_gpus, scenario::scenario_from_failed, BlastRadius, FailureModel, Trace};
use ntp::manager::{pack_domains, FleetSim, SparePolicy, StepMode, StrategyTable};
use ntp::parallel::ParallelConfig;
use ntp::power::RackDesign;
use ntp::sim::{FtStrategy, IterationModel, SimParams};
use ntp::util::prng::Rng;

fn sim_32k() -> (IterationModel, ParallelConfig) {
    let sim = IterationModel::new(
        presets::model("gpt-480b").unwrap(),
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 16 * 1024 * 1024,
            dtype: Dtype::BF16,
        },
        presets::cluster("paper-32k-nvl32").unwrap(),
        SimParams::default(),
    );
    let cfg = ParallelConfig { tp: 32, pp: 8, dp: 128, microbatch: 1 };
    (sim, cfg)
}

#[test]
fn strategy_ordering_holds_across_failure_fractions() {
    // Fig. 6's headline: NTP-PW >= NTP >= DP-DROP at every failed
    // fraction.
    let (sim, cfg) = sim_32k();
    let rack = RackDesign::default();
    let table = StrategyTable::build(&sim, &cfg, &rack);
    let topo = Topology::of(cfg.n_gpus(), 32, 4);
    let mut rng = Rng::new(2026);
    for &fail_frac in &[0.0005, 0.001, 0.002, 0.004] {
        let n_failed = (fail_frac * topo.n_gpus as f64) as usize;
        let failed = sample_failed_gpus(&topo, n_failed, BlastRadius::Single, &mut rng);
        let healthy = scenario_from_failed(&topo, &failed).domain_healthy;
        let assignment = pack_domains(&healthy, 32, cfg.pp, true);
        let drop = table.group_throughput(&assignment.replica_tp, FtStrategy::DpDrop);
        let ntp = table.group_throughput(&assignment.replica_tp, FtStrategy::Ntp);
        let pw = table.group_throughput(&assignment.replica_tp, FtStrategy::NtpPw);
        assert!(
            drop <= ntp + 1e-9 && ntp <= pw + 0.01,
            "f={fail_frac}: drop {drop} ntp {ntp} pw {pw}"
        );
        // NTP loss bounded well below DP-DROP loss
        assert!((1.0 - ntp) <= 0.6 * (1.0 - drop) + 1e-9, "f={fail_frac}");
    }
}

#[test]
fn ntp_pw_single_failures_near_zero_loss() {
    // Paper: NTP-PW <1% loss at up to 4e-3 failed fraction.
    let (sim, cfg) = sim_32k();
    let table = StrategyTable::build(&sim, &cfg, &RackDesign::default());
    let topo = Topology::of(cfg.n_gpus(), 32, 4);
    let mut rng = Rng::new(7);
    let n_failed = (0.002 * topo.n_gpus as f64) as usize;
    let failed = sample_failed_gpus(&topo, n_failed, BlastRadius::Single, &mut rng);
    let healthy = scenario_from_failed(&topo, &failed).domain_healthy;
    let assignment = pack_domains(&healthy, 32, cfg.pp, true);
    let pw = table.group_throughput(&assignment.replica_tp, FtStrategy::NtpPw);
    assert!(pw > 0.97, "NTP-PW throughput {pw}");
}

#[test]
fn packing_never_hurts() {
    let (sim, cfg) = sim_32k();
    let table = StrategyTable::build(&sim, &cfg, &RackDesign::default());
    let topo = Topology::of(cfg.n_gpus(), 32, 4);
    let mut rng = Rng::new(11);
    for trial in 0..20 {
        let n_failed = 1 + rng.index(60);
        let failed = sample_failed_gpus(&topo, n_failed, BlastRadius::Single, &mut rng);
        let healthy = scenario_from_failed(&topo, &failed).domain_healthy;
        for strat in [FtStrategy::DpDrop, FtStrategy::Ntp, FtStrategy::NtpPw] {
            let packed = pack_domains(&healthy, 32, cfg.pp, true);
            let unpacked = pack_domains(&healthy, 32, cfg.pp, false);
            let tp_packed = table.group_throughput(&packed.replica_tp, strat);
            let tp_unpacked = table.group_throughput(&unpacked.replica_tp, strat);
            assert!(
                tp_packed >= tp_unpacked - 1e-9,
                "trial {trial} {strat:?}: packed {tp_packed} < unpacked {tp_unpacked}"
            );
        }
    }
}

#[test]
fn blast_radius_degrades_gracefully() {
    // Fig. 10: larger blast radii cost NTP throughput but it still beats
    // DP-DROP.
    let (sim, cfg) = sim_32k();
    let table = StrategyTable::build(&sim, &cfg, &RackDesign::default());
    let topo = Topology::of(cfg.n_gpus(), 32, 4);
    let n_failed = 33; // ~0.1%
    let mut prev_ntp = 1.1;
    for blast in [BlastRadius::Single, BlastRadius::Gpus(2), BlastRadius::Node] {
        let mut rng = Rng::new(13);
        // average over a few placements
        let mut ntp_acc = 0.0;
        let mut drop_acc = 0.0;
        let trials = 10;
        for _ in 0..trials {
            let failed = sample_failed_gpus(&topo, n_failed, blast, &mut rng);
            let healthy = scenario_from_failed(&topo, &failed).domain_healthy;
            let a = pack_domains(&healthy, 32, cfg.pp, true);
            ntp_acc += table.group_throughput(&a.replica_tp, FtStrategy::Ntp);
            drop_acc += table.group_throughput(&a.replica_tp, FtStrategy::DpDrop);
        }
        let ntp = ntp_acc / trials as f64;
        let drop = drop_acc / trials as f64;
        assert!(ntp > drop, "{blast:?}: ntp {ntp} <= drop {drop}");
        assert!(ntp <= prev_ntp + 0.02, "{blast:?} should not improve: {ntp} vs {prev_ntp}");
        prev_ntp = ntp;
    }
}

#[test]
fn fixed_minibatch_needs_fewer_spares_with_ntp_pw() {
    // Fig. 7's shape: to avoid pausing, DP-DROP needs many spare domains,
    // NTP-PW close to zero.
    let (sim, cfg) = sim_32k();
    let rack = RackDesign::default();
    let table = StrategyTable::build(&sim, &cfg, &rack);
    // small fleet: 16 replicas * 8 domains + spares
    let n_job_domains = 16 * cfg.pp;
    let spares = 8usize;
    let topo = Topology::of((n_job_domains + spares) * 32, 32, 4);
    let model = FailureModel::llama3().scaled(10.0);
    let mut rng = Rng::new(3);
    let trace = Trace::generate(&topo, &model, 24.0 * 10.0, &mut rng);
    let policy = SparePolicy { spare_domains: spares, cold_domains: 0, min_tp: 28 };

    let run = |strategy: FtStrategy| {
        let fs = FleetSim {
            topo: &topo,
            table: &table,
            domains_per_replica: cfg.pp,
            policy: strategy.policy(),
            spares: Some(policy),
            packed: true,
            blast: BlastRadius::Single,
            transition: None,
            detect: None,
        };
        fs.run(&trace, StepMode::Exact)
    };
    let drop = run(FtStrategy::DpDrop);
    let pw = run(FtStrategy::NtpPw);
    assert!(
        pw.paused_frac <= drop.paused_frac,
        "pw paused {} > drop paused {}",
        pw.paused_frac,
        drop.paused_frac
    );
    assert!(pw.mean_throughput >= drop.mean_throughput);
}
