//! Integration: NTP shard-mapping + resharding invariants at paper scale,
//! exercised across modules (shard_map → reshard → sync buffers).

use ntp::ntp::shard_map::ShardMap;
use ntp::ntp::sync::{
    allreduce_mean, comp_to_sync, gather_comp, scatter_comp, sync_to_comp, CopyPlan,
};
use ntp::ntp::{partition, PlanCache, ReshardPlan, SyncPlan};
use ntp::util::prng::Rng;
use ntp::util::prop::{check, ShardInstanceGen};

#[test]
fn paper_scale_tp32_to_tp30_full_roundtrip() {
    // MLP dimension of the 480B model: k = 81920 columns, TP32 -> TP30.
    let map = ShardMap::build(81_920, 32, 30);
    let plan = ReshardPlan::from_map(&map);
    // Offload GPUs 30,31 each hold a balanced comp shard (2560 units)
    // and send all of it.
    assert_eq!(plan.sent_by(30), 2560);
    assert_eq!(plan.sent_by(31), 2560);
    // Each sync GPU receives its block's shortfall.
    let per_sync: usize = (0..30).map(|s| plan.received_by(s)).sum();
    assert_eq!(per_sync, 2 * 2560);
    // Pairwise balance: every (offload, sync) split within 2 units.
    for g in 30..32 {
        let splits = plan.send_splits(g);
        let max = splits.iter().max().unwrap();
        let min = splits.iter().min().unwrap();
        assert!(max - min <= 2, "splits {splits:?}");
    }
}

#[test]
fn buffer_roundtrip_with_data_at_moderate_scale() {
    let k = 4096;
    let unit_len = 16;
    let map = ShardMap::build(k, 16, 13);
    let mut rng = Rng::new(99);
    let full: Vec<f32> = (0..k * unit_len).map(|_| rng.f32()).collect();
    let comp = scatter_comp(&map, unit_len, &full);
    let sync = comp_to_sync(&map, unit_len, &comp);
    // sync layout is the contiguous full tensor, re-chunked
    let cat: Vec<f32> = sync.iter().flatten().copied().collect();
    assert_eq!(cat, full);
    let comp2 = sync_to_comp(&map, unit_len, &sync);
    assert_eq!(gather_comp(&map, unit_len, &comp2), full);
}

#[test]
fn cross_replica_sync_through_explicit_reshard() {
    // Three replicas at TP (8, 7, 6) — gradient averaging through the
    // explicit comp->sync->allreduce->comp path equals the full-tensor
    // average.
    let k = 336; // divisible by lots of things
    let unit_len = 3;
    let tps = [8usize, 7, 6];
    let sync_deg = 6;
    let mut rng = Rng::new(5);
    let fulls: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..k * unit_len).map(|_| rng.f32() - 0.5).collect())
        .collect();
    let maps: Vec<ShardMap> = tps.iter().map(|&tp| ShardMap::build(k, tp, sync_deg)).collect();
    let mut sync_shards: Vec<Vec<Vec<f32>>> = maps
        .iter()
        .zip(&fulls)
        .map(|(m, f)| comp_to_sync(m, unit_len, &scatter_comp(m, unit_len, f)))
        .collect();
    allreduce_mean(&mut sync_shards);
    let want: Vec<f32> = (0..k * unit_len)
        .map(|i| (fulls[0][i] + fulls[1][i] + fulls[2][i]) / 3.0)
        .collect();
    for (m, s) in maps.iter().zip(&sync_shards) {
        let got = gather_comp(m, unit_len, &sync_to_comp(m, unit_len, s));
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6);
        }
    }
}

#[test]
fn sync_plan_volumes_match_paper_ratios() {
    // §6.2: allreduce volume increases proportionally to the TP
    // reduction.
    let plan = SyncPlan::build(81_920, &[32, 32, 30]);
    assert!((plan.allreduce_increase_factor(32) - 32.0 / 30.0).abs() < 1e-12);
    // attention-head dimension of the same model
    let heads = SyncPlan::build(128, &[32, 32, 30]);
    assert_eq!(heads.sync_degree, 30);
    // head imbalance at TP30: 5 vs 4 heads
    let sizes = partition::partition_sizes(128, 30);
    assert_eq!(*sizes.iter().max().unwrap(), 5);
    assert_eq!(*sizes.iter().min().unwrap(), 4);
}

#[test]
fn coalesced_reshard_equals_per_unit_path_exactly() {
    // Property: for random (k, n1, n2) instances, every CopyPlan
    // permutation is exactly (bit-for-bit) the per-unit reference —
    // both are pure copies, so f32 equality must be exact.
    let gen = ShardInstanceGen { max_k: 800, max_n: 24 };
    check(0xC0A1, 120, &gen, |&(k, n1, n2)| {
        // data seed derived from the instance so the property is a pure Fn
        let mut local =
            Rng::new(((k as u64) << 32) ^ ((n1 as u64) << 16) ^ (n2 as u64) ^ 0xD00D);
        let unit_len = 1 + local.index(5);
        let map = ShardMap::build(k, n1, n2);
        let plan = CopyPlan::build(&map);
        let full: Vec<f32> = (0..k * unit_len).map(|_| local.f32() - 0.5).collect();
        let comp = scatter_comp(&map, unit_len, &full);
        if plan.scatter_comp(unit_len, &full) != comp {
            return Err(format!("scatter_comp diverges (k={k} n1={n1} n2={n2})"));
        }
        if plan.gather_comp(unit_len, &comp) != full {
            return Err(format!("gather_comp diverges (k={k} n1={n1} n2={n2})"));
        }
        let sync = comp_to_sync(&map, unit_len, &comp);
        if plan.comp_to_sync(unit_len, &comp) != sync {
            return Err(format!("comp_to_sync diverges (k={k} n1={n1} n2={n2})"));
        }
        if plan.sync_to_comp(unit_len, &sync) != comp {
            return Err(format!("sync_to_comp diverges (k={k} n1={n1} n2={n2})"));
        }
        Ok(())
    });
}

#[test]
fn plan_cache_products_equal_direct_builds_at_paper_scale() {
    let cache = PlanCache::new();
    let info = cache.get(81_920, 32, 30);
    let map = ShardMap::build(81_920, 32, 30);
    assert_eq!(info.map, map);
    let plan = ReshardPlan::from_map(&map);
    for g in 0..32 {
        assert_eq!(info.plan.sent_by(g), plan.sent_by(g));
    }
    let unit_bytes = 2 * 12_288 * 2;
    assert_eq!(
        info.max_units_per_gpu * unit_bytes,
        plan.max_bytes_per_gpu(unit_bytes)
    );
    // CopyPlan covers each unit exactly once
    let covered: usize = info.copy.segments.iter().map(|s| s.len).sum();
    assert_eq!(covered, 81_920);
}

#[test]
fn degenerate_and_extreme_cases() {
    // No reduction.
    let p = SyncPlan::build(100, &[10, 10]);
    assert!(p.is_uniform());
    // Reduction to a single shard.
    let map = ShardMap::build(64, 8, 1);
    let plan = ReshardPlan::from_map(&map);
    assert_eq!(plan.received_by(0), 64 - 8);
    // k == n1 (one unit per GPU).
    let map = ShardMap::build(16, 16, 12);
    let plan = ReshardPlan::from_map(&map);
    let total_moved: usize = (0..16).map(|g| plan.sent_by(g)).sum();
    assert_eq!(total_moved, 4); // the 4 offloaded units
}
