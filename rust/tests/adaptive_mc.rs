//! PR 10 suite: adaptive Monte-Carlo trial allocation.
//!
//! * The stop point, stop reason and every per-policy aggregate of
//!   `run_trials_adaptive` are **bit-identical** at 1/2/5 threads, and
//!   to the sequential shared-memo `run_trials_adaptive_with` — stop
//!   decisions happen only at round boundaries on trial-index-ordered
//!   folds, so the work-stealing schedule can never leak into them.
//! * An adaptive run's aggregates equal the plain sequential
//!   aggregator over exactly its first `trials_run` trials — early
//!   stopping truncates the trial sequence, it never reweights it.
//! * Policies with genuinely different net throughput stop on CI
//!   separation well under budget; a pair of policies that respond
//!   identically (the straggler pair under an Independent scenario,
//!   which emits no Degrade events) never separates and must run its
//!   full budget out.

use ntp::cluster::Topology;
use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::failure::{
    BlastRadius, DetectionModel, FailureModel, ScenarioConfig, ScenarioKind, TrialGen,
};
use ntp::manager::{MultiPolicySim, StepMode, StopReason, StopRule, StrategyTable};
use ntp::parallel::ParallelConfig;
use ntp::policy::{registry, FtPolicy, TransitionCosts};
use ntp::power::RackDesign;
use ntp::sim::{IterationModel, SimParams};

const DOMAIN_SIZE: usize = 32;
const PER_REPLICA: usize = 4;

fn setup() -> (IterationModel, ParallelConfig, StrategyTable) {
    let sim = IterationModel::new(
        presets::model("gpt-480b").unwrap(),
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 2 * 1024 * 1024,
            dtype: Dtype::BF16,
        },
        presets::cluster("paper-32k-nvl32").unwrap(),
        SimParams::default(),
    );
    let cfg = ParallelConfig { tp: DOMAIN_SIZE, pp: PER_REPLICA, dp: 16, microbatch: 1 };
    let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
    let table = StrategyTable::build(&sim, &cfg, &rack);
    (sim, cfg, table)
}

fn parse_all(names: &[&str]) -> Vec<&'static dyn FtPolicy> {
    names.iter().map(|n| registry::parse(n).unwrap()).collect()
}

/// Bit-level equality of two aggregate vectors (counts, plain-sum
/// means, Welford moments and the derived CI).
fn assert_aggs_bit_equal(
    a: &[ntp::manager::PolicyAggregate],
    b: &[ntp::manager::PolicyAggregate],
    what: &str,
) {
    assert_eq!(a.len(), b.len(), "{what}: aggregate count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.trials(), y.trials(), "{what}: trials");
        assert_eq!(x.mean_tput().to_bits(), y.mean_tput().to_bits(), "{what}: mean_tput");
        assert_eq!(
            x.mean_net_tput().to_bits(),
            y.mean_net_tput().to_bits(),
            "{what}: mean_net_tput"
        );
        assert_eq!(x.tput.mean().to_bits(), y.tput.mean().to_bits(), "{what}: Welford mean");
        assert_eq!(
            x.tput.variance().to_bits(),
            y.tput.variance().to_bits(),
            "{what}: Welford variance"
        );
        assert_eq!(x.tput_ci95().to_bits(), y.tput_ci95().to_bits(), "{what}: CI95");
        assert_eq!(
            x.net_tput.mean().to_bits(),
            y.net_tput.mean().to_bits(),
            "{what}: net Welford mean"
        );
    }
}

/// The stop point is a pure function of `(gen, rule)` — the thread
/// count and steal schedule never shift it, and the sequential
/// shared-memo runner lands on the identical outcome. Detection is
/// active, so the delayed-events arm of the dispatch is the one under
/// test too.
#[test]
fn adaptive_stop_is_thread_count_invariant() {
    let (sim, cfg, table) = setup();
    let policies = parse_all(&["ntp", "dp-drop", "ckpt-restart"]);
    let job_domains = 20usize;
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(40.0);
    let gen = TrialGen::new(
        &topo,
        &model,
        &ScenarioConfig::new(ScenarioKind::Independent),
        24.0 * 6.0,
        0xADA,
        48,
    );
    let msim = MultiPolicySim {
        topo: &topo,
        table: &table,
        domains_per_replica: PER_REPLICA,
        policies: &policies,
        spares: None,
        packed: true,
        blast: BlastRadius::Single,
        transition: Some(TransitionCosts::model(&sim, &cfg)),
        detect: Some(DetectionModel {
            fail_latency_hours: 0.4,
            degrade_latency_hours: 1.5,
            false_positives_per_gpu_day: 2e-3,
            jitter_frac: 1.0,
        }),
    };
    let rule = StopRule { round: 8, min_trials: 8, max_trials: 48, rel_ci: 0.0, margin: 0.0 };
    let base = msim.run_trials_adaptive(&gen, StepMode::Exact, &rule, 1);
    // Stops only at whole round boundaries (the budget is a multiple
    // of the round here, so no short final round exists).
    assert_eq!(base.trials_run % rule.round, 0, "stop must land on a round boundary");
    assert!(base.trials_run >= rule.min_trials && base.trials_run <= rule.max_trials);
    for threads in [2usize, 5] {
        let par = msim.run_trials_adaptive(&gen, StepMode::Exact, &rule, threads);
        assert_eq!(par.trials_run, base.trials_run, "stop point drifted at {threads} threads");
        assert_eq!(par.reason, base.reason, "stop reason drifted at {threads} threads");
        assert_aggs_bit_equal(&par.aggs, &base.aggs, &format!("{threads} threads"));
    }
    let mut memo = msim.memo();
    let seq = msim.run_trials_adaptive_with(&gen, StepMode::Exact, &rule, &mut memo);
    assert_eq!(seq.trials_run, base.trials_run);
    assert_eq!(seq.reason, base.reason);
    assert_aggs_bit_equal(&seq.aggs, &base.aggs, "sequential shared-memo runner");

    // Three policies this far apart settle on separation under budget.
    assert_eq!(base.reason, StopReason::Separated);
    assert!(
        base.trials_run < rule.max_trials,
        "distinct policies should separate before the {}-trial budget (ran {})",
        rule.max_trials,
        base.trials_run
    );

    // Early stopping truncates the trial sequence, nothing more: the
    // plain sequential aggregator over exactly the first `trials_run`
    // trials of the same family reproduces the aggregates bit-for-bit.
    let gen_prefix = TrialGen::new(
        &topo,
        &model,
        &ScenarioConfig::new(ScenarioKind::Independent),
        24.0 * 6.0,
        0xADA,
        base.trials_run,
    );
    let mut memo_prefix = msim.memo();
    let prefix = msim.run_trials_stream_agg(&gen_prefix, StepMode::Exact, &mut memo_prefix);
    assert_aggs_bit_equal(&prefix, &base.aggs, "exhaustive prefix");
}

/// Two policies that respond identically on every event never
/// separate: under an Independent scenario no Degrade event fires, so
/// `STRAGGLER-EVICT` and `STRAGGLER-TOLERATE` are both exactly NTP and
/// the net-throughput gap is zero forever. With the precision stop
/// disabled, only the budget can end the run.
#[test]
fn identical_pair_never_stops_early() {
    let (sim, cfg, table) = setup();
    let policies = parse_all(&["straggler-evict", "straggler-tolerate"]);
    let job_domains = 16usize;
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(40.0);
    let gen = TrialGen::new(
        &topo,
        &model,
        &ScenarioConfig::new(ScenarioKind::Independent),
        24.0 * 4.0,
        0xADB,
        12,
    );
    let msim = MultiPolicySim {
        topo: &topo,
        table: &table,
        domains_per_replica: PER_REPLICA,
        policies: &policies,
        spares: None,
        packed: true,
        blast: BlastRadius::Single,
        transition: Some(TransitionCosts::model(&sim, &cfg)),
        detect: None,
    };
    let rule = StopRule { round: 4, min_trials: 4, max_trials: 12, rel_ci: 0.0, margin: 0.0 };
    let out = msim.run_trials_adaptive(&gen, StepMode::Exact, &rule, 2);
    assert_eq!(
        out.reason,
        StopReason::MaxTrials,
        "identical policies must never separate (stopped '{}' after {} trials)",
        out.reason.as_str(),
        out.trials_run
    );
    assert_eq!(out.trials_run, rule.max_trials);
    // The pair really is identical: bit-equal aggregates.
    assert_eq!(
        out.aggs[0].mean_net_tput().to_bits(),
        out.aggs[1].mean_net_tput().to_bits(),
        "straggler pair must respond identically without Degrade events"
    );

    // A loose rel_ci turns the same run into a precision stop instead
    // (the ordering is tied, but the estimates themselves converge).
    let loose = StopRule { rel_ci: 10.0, ..rule };
    let out_loose = msim.run_trials_adaptive(&gen, StepMode::Exact, &loose, 2);
    assert_eq!(out_loose.reason, StopReason::RelCi);
    assert_eq!(out_loose.trials_run, rule.min_trials.max(rule.round));
}

/// A budget that is not a round multiple is cut short at the budget,
/// never overrun — and the short final round still folds.
#[test]
fn budget_cuts_final_round_short() {
    let (sim, cfg, table) = setup();
    let policies = parse_all(&["straggler-evict", "straggler-tolerate"]);
    let topo = Topology::of(16 * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(40.0);
    let gen = TrialGen::new(
        &topo,
        &model,
        &ScenarioConfig::new(ScenarioKind::Independent),
        24.0 * 4.0,
        0xADC,
        10,
    );
    let msim = MultiPolicySim {
        topo: &topo,
        table: &table,
        domains_per_replica: PER_REPLICA,
        policies: &policies,
        spares: None,
        packed: true,
        blast: BlastRadius::Single,
        transition: Some(TransitionCosts::model(&sim, &cfg)),
        detect: None,
    };
    // round 4 does not divide the 10-trial budget: rounds of 4, 4, 2.
    let rule = StopRule { round: 4, min_trials: 10, max_trials: 10, rel_ci: 0.0, margin: 0.0 };
    for threads in [1usize, 3] {
        let out = msim.run_trials_adaptive(&gen, StepMode::Exact, &rule, threads);
        assert_eq!(out.trials_run, 10, "threads={threads}");
        assert_eq!(out.reason, StopReason::MaxTrials, "threads={threads}");
        assert_eq!(out.aggs[0].trials(), 10, "threads={threads}");
    }
}
