//! PR 8 suite: imperfect failure detection, elastic DP, hierarchical
//! spares, preemption budgets, and streaming aggregates.
//!
//! * Zero detection (no model, the instant model, or an all-zero
//!   literal) collapses **bit-exactly** onto the pre-detection path for
//!   every registered policy × all four scenario generators — the knob
//!   at zero is provably free.
//! * With detection *active*, all the engine-equivalence contracts
//!   still hold bit-for-bit: shared sweep == per-policy event-driven
//!   run == per-step replay, refinement invariance, incremental ==
//!   rebuild, stream == materialized, and 1-vs-N-thread identity.
//! * Longer detection latency monotonically degrades
//!   `STRAGGLER-EVICT`'s net throughput (the undetected-stall bill
//!   always costs at least the reconfiguration it hid).
//! * A two-tier spare pool changes only the transition bill: capacity
//!   stats are bit-identical to the flat pool, the cold tier only costs
//!   extra when migrations overflow the warm tier.
//! * False positives charge only policies that evict on a degrade
//!   signal; a latency-free FP-only model leaves every zero-cost
//!   policy bit-identical.
//! * The streaming per-policy aggregates (Welford CIs, no per-trial
//!   storage) reproduce the stored-trials statistics.

use ntp::cluster::Topology;
use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::failure::{
    BlastRadius, DetectionModel, FailureModel, ScenarioConfig, ScenarioKind, TrialGen,
};
use ntp::manager::{FleetSim, FleetStats, MultiPolicySim, SparePolicy, StepMode, StrategyTable};
use ntp::parallel::ParallelConfig;
use ntp::policy::{registry, FtPolicy, TransitionCosts};
use ntp::power::RackDesign;
use ntp::sim::{IterationModel, SimParams};
use ntp::util::stats::Welford;

const DOMAIN_SIZE: usize = 32;
const PER_REPLICA: usize = 4;

const ALL_KINDS: [ScenarioKind; 4] = [
    ScenarioKind::Independent,
    ScenarioKind::Correlated,
    ScenarioKind::Straggler,
    ScenarioKind::Sdc,
];

fn setup() -> (IterationModel, ParallelConfig, StrategyTable) {
    let sim = IterationModel::new(
        presets::model("gpt-480b").unwrap(),
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 2 * 1024 * 1024,
            dtype: Dtype::BF16,
        },
        presets::cluster("paper-32k-nvl32").unwrap(),
        SimParams::default(),
    );
    let cfg = ParallelConfig { tp: DOMAIN_SIZE, pp: PER_REPLICA, dp: 16, microbatch: 1 };
    let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
    let table = StrategyTable::build(&sim, &cfg, &rack);
    (sim, cfg, table)
}

fn hot_scenario(kind: ScenarioKind) -> ScenarioConfig {
    let mut cfg = ScenarioConfig::new(kind);
    cfg.correlated = cfg.correlated.scaled(2_000.0);
    cfg.straggler = cfg.straggler.scaled(200.0);
    cfg.sdc = cfg.sdc.scaled(2_000.0);
    cfg
}

/// A detection model with every knob nonzero, including jitter.
fn lossy_detection() -> DetectionModel {
    DetectionModel {
        fail_latency_hours: 0.4,
        degrade_latency_hours: 1.5,
        false_positives_per_gpu_day: 2e-3,
        jitter_frac: 1.0,
    }
}

/// No detection model, the canonical instant model, and an explicit
/// all-zero literal must all run the IDENTICAL code path.
#[test]
fn zero_detection_collapses_bit_exactly_for_every_policy() {
    let (sim, cfg, table) = setup();
    let policies = registry::all();
    assert_eq!(policies.len(), 12);
    let job_domains = 20usize;
    let spare_domains = 4usize;
    let topo = Topology::of((job_domains + spare_domains) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(40.0);
    let transition = Some(TransitionCosts::model(&sim, &cfg));
    for (k, &kind) in ALL_KINDS.iter().enumerate() {
        let gen =
            TrialGen::new(&topo, &model, &hot_scenario(kind), 24.0 * 10.0, 0xDE7 + k as u64, 3);
        let traces = gen.traces();
        let msim = |detect: Option<DetectionModel>| MultiPolicySim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policies: &policies,
            spares: Some(SparePolicy { spare_domains, cold_domains: 0, min_tp: 28 }),
            packed: true,
            blast: BlastRadius::Single,
            transition,
            detect,
        };
        let none = msim(None);
        let instant = msim(Some(DetectionModel::instant()));
        let zeroed = msim(Some(DetectionModel {
            fail_latency_hours: 0.0,
            degrade_latency_hours: 0.0,
            false_positives_per_gpu_day: 0.0,
            // jitter alone does not make a model active: there is
            // nothing to jitter.
            jitter_frac: 0.7,
        }));
        for mode in [StepMode::Exact, StepMode::Grid(2.0)] {
            let base = none.run_trials(&traces, mode, &mut none.memo());
            assert_eq!(
                base,
                instant.run_trials(&traces, mode, &mut instant.memo()),
                "{kind:?} {mode:?}: Some(instant) must equal None bit-for-bit"
            );
            assert_eq!(
                base,
                zeroed.run_trials(&traces, mode, &mut zeroed.memo()),
                "{kind:?} {mode:?}: the all-zero model must equal None bit-for-bit"
            );
        }
        // FleetSim takes the same normalization path.
        for (detect, label) in
            [(None, "none"), (Some(DetectionModel::instant()), "instant")]
        {
            let fs = FleetSim {
                topo: &topo,
                table: &table,
                domains_per_replica: PER_REPLICA,
                policy: policies[0],
                spares: None,
                packed: true,
                blast: BlastRadius::Single,
                transition,
                detect,
            };
            assert_eq!(
                fs.run(&traces[0], StepMode::Exact),
                FleetSim { detect: None, ..fs }.run(&traces[0], StepMode::Exact),
                "{kind:?} FleetSim({label}): zero detection drifted"
            );
        }
    }
}

/// All engine-equivalence contracts hold with detection ACTIVE: shared
/// sweep == event-driven == per-step replay, refinement invariance,
/// incremental == rebuild, and stream == materialized at any worker
/// count.
#[test]
fn active_detection_preserves_engine_equivalence() {
    let (sim, cfg, table) = setup();
    let policies = registry::all();
    let job_domains = 20usize;
    let spare_domains = 4usize;
    let topo = Topology::of((job_domains + spare_domains) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(40.0);
    let transition = Some(TransitionCosts::model(&sim, &cfg));
    let detect = Some(lossy_detection());
    for (k, &kind) in ALL_KINDS.iter().enumerate() {
        let gen = TrialGen::new(
            &topo,
            &model,
            &hot_scenario(kind),
            24.0 * 10.0,
            0xF0E + k as u64,
            4,
        );
        let traces = gen.traces();
        let msim = MultiPolicySim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policies: &policies,
            spares: Some(SparePolicy { spare_domains, cold_domains: 1, min_tp: 28 }),
            packed: true,
            blast: BlastRadius::Single,
            transition,
            detect,
        };
        for mode in [StepMode::Exact, StepMode::Grid(2.0)] {
            let shared = msim.run(&traces[0], mode);
            for (i, &policy) in policies.iter().enumerate() {
                let fs = FleetSim {
                    topo: &topo,
                    table: &table,
                    domains_per_replica: PER_REPLICA,
                    policy,
                    spares: msim.spares,
                    packed: true,
                    blast: BlastRadius::Single,
                    transition,
                    detect,
                };
                let stats = fs.run(&traces[0], mode);
                assert_eq!(
                    stats,
                    shared[i],
                    "{kind:?} {mode:?} {}: shared sweep drifted under detection",
                    policy.name()
                );
                assert_eq!(
                    stats,
                    fs.run_replay_per_step(&traces[0], mode),
                    "{kind:?} {mode:?} {}: per-step replay drifted under detection",
                    policy.name()
                );
                if mode == StepMode::Exact {
                    // Refinement invariance: extra evaluation points
                    // must not change exact integration.
                    assert_eq!(
                        stats,
                        fs.run_exact_with_refinement(&traces[0], &[13.0, 77.7, 181.1]),
                        "{kind:?} {}: refinement changed exact stats under detection",
                        policy.name()
                    );
                }
            }
            // Stream == materialized, shared memo on each side.
            let mat = msim.run_trials(&traces, mode, &mut msim.memo());
            assert_eq!(
                mat,
                msim.run_trials_stream(&gen, mode, &mut msim.memo()),
                "{kind:?} {mode:?}: streaming diverged under detection"
            );
            // Thread-count bit-identity, workers below/at/above trials.
            for threads in [1usize, 3, 4, 7] {
                let (par_m, _) = msim.run_trials_par(&traces, mode, threads);
                assert_eq!(par_m, mat, "{kind:?} {mode:?} threads={threads}");
                let (par_s, _) = msim.run_trials_stream_par(&gen, mode, threads);
                assert_eq!(par_s, mat, "{kind:?} {mode:?} threads={threads} (stream)");
            }
        }
        // Incremental exact sweep == rebuild oracle under detection.
        for trace in &traces {
            assert_eq!(
                msim.run_with(trace, StepMode::Exact, &mut msim.memo()),
                msim.run_rebuild(trace, &mut msim.memo()),
                "{kind:?}: incremental sweep != rebuild oracle under detection"
            );
        }
    }
}

/// Longer detection latency can only hurt: `STRAGGLER-EVICT`'s net
/// throughput is non-increasing in the latency, strictly lower than
/// the instant-detection baseline once the latency is material.
#[test]
fn detect_latency_degrades_straggler_evict_monotonically() {
    let (sim, cfg, table) = setup();
    let policy = registry::parse("straggler-evict").unwrap();
    let job_domains = 24usize;
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(30.0);
    // Stragglers with real drag (30–70% residual speed) so the hidden
    // window loses meaningful work.
    let mut scen = hot_scenario(ScenarioKind::Straggler);
    scen.straggler.slowdown = (0.3, 0.7);
    let gen = TrialGen::new(&topo, &model, &scen, 24.0 * 12.0, 0x5712A, 1);
    let traces = gen.traces();
    let trace = &traces[0];
    assert!(!trace.events.is_empty());
    let transition = Some(TransitionCosts::model(&sim, &cfg));
    let net_at = |latency_hours: f64| -> f64 {
        let fs = FleetSim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policy,
            spares: None,
            packed: true,
            blast: BlastRadius::Single,
            transition,
            detect: Some(DetectionModel {
                fail_latency_hours: latency_hours,
                degrade_latency_hours: latency_hours,
                false_positives_per_gpu_day: 0.0,
                jitter_frac: 0.0,
            }),
        };
        fs.run(trace, StepMode::Exact).net_throughput()
    };
    let latencies = [0.0, 0.25, 1.0, 3.0, 8.0];
    let nets: Vec<f64> = latencies.iter().map(|&l| net_at(l)).collect();
    for w in nets.windows(2) {
        assert!(
            w[1] <= w[0] + 1e-12,
            "net throughput must be non-increasing in detection latency: {nets:?}"
        );
    }
    assert!(
        nets[nets.len() - 1] < nets[0],
        "hours-scale latency must strictly degrade net throughput: {nets:?}"
    );
}

/// A two-tier pool changes only the transition bill: capacity stats are
/// bit-identical to the flat pool; the cold tier costs extra exactly
/// when migrations overflow the warm tier.
#[test]
fn cold_tier_bills_only_the_overflow() {
    let (sim, cfg, table) = setup();
    let policies: Vec<&'static dyn FtPolicy> =
        vec![registry::parse("spare-mig").unwrap(), registry::parse("elastic-dp").unwrap()];
    let job_domains = 20usize;
    let spare_domains = 4usize;
    let topo = Topology::of((job_domains + spare_domains) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(60.0);
    let gen = TrialGen::new(
        &topo,
        &model,
        &hot_scenario(ScenarioKind::Correlated),
        24.0 * 10.0,
        0xC01D,
        1,
    );
    let traces = gen.traces();
    let trace = &traces[0];
    let transition = Some(TransitionCosts::model(&sim, &cfg));
    let run = |cold_domains: usize| -> Vec<FleetStats> {
        MultiPolicySim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policies: &policies,
            spares: Some(SparePolicy { spare_domains, cold_domains, min_tp: 28 }),
            packed: true,
            blast: BlastRadius::Single,
            transition,
            detect: None,
        }
        .run(trace, StepMode::Exact)
    };
    let flat = run(0);
    let all_cold = run(spare_domains);
    assert!(
        flat[0].mean_spares_used > 0.0,
        "trace too quiet: spares never migrated, the tier split is untested"
    );
    for (f, c) in flat.iter().zip(&all_cold) {
        // Capacity substitution is tier-blind.
        assert_eq!(f.mean_throughput.to_bits(), c.mean_throughput.to_bits());
        assert_eq!(f.mean_spares_used.to_bits(), c.mean_spares_used.to_bits());
        assert_eq!(f.paused_frac.to_bits(), c.paused_frac.to_bits());
        assert_eq!(f.transitions, c.transitions);
        // The bill is not: cold bring-up is never cheaper.
        assert!(c.downtime_frac >= f.downtime_frac);
    }
    // With an all-cold pool every migration overflows the (empty) warm
    // tier, so the cold premium must actually bite.
    assert!(
        all_cold[0].downtime_frac > flat[0].downtime_frac,
        "cold-tier overflow never billed: flat {} vs cold {}",
        flat[0].downtime_frac,
        all_cold[0].downtime_frac
    );
}

/// Latency-free false positives charge only policies that evict on a
/// degrade signal; everyone else stays bit-identical.
#[test]
fn false_positives_charge_only_evicting_policies() {
    let (sim, cfg, table) = setup();
    let policies = registry::all();
    let job_domains = 20usize;
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(40.0);
    let gen = TrialGen::new(
        &topo,
        &model,
        &hot_scenario(ScenarioKind::Straggler),
        24.0 * 10.0,
        0xFA15E,
        1,
    );
    let traces = gen.traces();
    let trace = &traces[0];
    let transition = Some(TransitionCosts::model(&sim, &cfg));
    let run = |fp: f64| -> Vec<FleetStats> {
        MultiPolicySim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policies: &policies,
            spares: None,
            packed: true,
            blast: BlastRadius::Single,
            transition,
            detect: (fp > 0.0).then(|| DetectionModel {
                fail_latency_hours: 0.0,
                degrade_latency_hours: 0.0,
                false_positives_per_gpu_day: fp,
                jitter_frac: 0.0,
            }),
        }
        .run(trace, StepMode::Exact)
    };
    let clean = run(0.0);
    let noisy = run(5e-3);
    let mut charged = Vec::new();
    for ((policy, cl), no) in policies.iter().zip(&clean).zip(&noisy) {
        // A zero-latency model never shifts events: capacity stats are
        // identical, only the expected-eviction bill can differ.
        assert_eq!(cl.mean_throughput.to_bits(), no.mean_throughput.to_bits());
        assert_eq!(cl.transitions, no.transitions);
        if no.downtime_frac > cl.downtime_frac {
            charged.push(policy.name());
        } else {
            assert_eq!(
                cl, no,
                "{}: charged nothing yet stats drifted",
                policy.name()
            );
        }
    }
    assert!(
        charged.contains(&"STRAGGLER-EVICT") && charged.contains(&"ELASTIC-DP"),
        "evicting policies must pay for false positives, got {charged:?}"
    );
    assert!(
        !charged.contains(&"NTP") && !charged.contains(&"DP-DROP"),
        "non-evicting policies must ride out false alarms free, got {charged:?}"
    );
}

/// `LOWPRI-DONATE` pays the preemption-latency budget when reclaiming
/// donated GPUs; the budget changes the bill, never the capacity.
#[test]
fn preemption_budget_bills_lowpri_donate() {
    let (sim, cfg, table) = setup();
    let policy = registry::parse("lowpri-donate").unwrap();
    let job_domains = 20usize;
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(50.0);
    let gen = TrialGen::new(
        &topo,
        &model,
        &hot_scenario(ScenarioKind::Independent),
        24.0 * 15.0,
        0x10321,
        1,
    );
    let traces = gen.traces();
    let trace = &traces[0];
    let base = TransitionCosts::model(&sim, &cfg);
    let run = |preempt_secs: f64| -> FleetStats {
        FleetSim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policy,
            spares: None,
            packed: true,
            blast: BlastRadius::Single,
            transition: Some(TransitionCosts { preempt_secs, ..base }),
            detect: None,
        }
        .run(trace, StepMode::Exact)
    };
    let free = run(0.0);
    let slow = run(120.0);
    assert_eq!(free.mean_throughput.to_bits(), slow.mean_throughput.to_bits());
    assert_eq!(free.mean_donated.to_bits(), slow.mean_donated.to_bits());
    assert_eq!(free.transitions, slow.transitions);
    assert!(
        slow.downtime_frac > free.downtime_frac,
        "recoveries inside the horizon must reclaim donated GPUs and pay \
         the preemption budget: {} vs {}",
        slow.downtime_frac,
        free.downtime_frac
    );
    assert!(slow.net_throughput() < free.net_throughput());
}

/// The streaming per-policy aggregates reproduce the stored-trials
/// statistics: identical means, a CI matching a direct Welford pass,
/// and bit-identical aggregates at every worker count (the stealing
/// coordinator folds in trial-index order, so no merge rounding).
#[test]
fn stream_aggregates_match_stored_trials() {
    let (sim, cfg, table) = setup();
    let policies = registry::all();
    let job_domains = 20usize;
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(40.0);
    let gen = TrialGen::new(
        &topo,
        &model,
        &hot_scenario(ScenarioKind::Correlated),
        24.0 * 8.0,
        0xA66,
        6,
    );
    let msim = MultiPolicySim {
        topo: &topo,
        table: &table,
        domains_per_replica: PER_REPLICA,
        policies: &policies,
        spares: None,
        packed: true,
        blast: BlastRadius::Single,
        transition: Some(TransitionCosts::model(&sim, &cfg)),
        detect: Some(lossy_detection()),
    };
    let (stored, _) = msim.run_trials_stream_par(&gen, StepMode::Exact, 1);
    let (aggs, _) = msim.run_trials_stream_agg_par(&gen, StepMode::Exact, 1);
    assert_eq!(aggs.len(), policies.len());
    let n = stored.len() as f64;
    for (pi, agg) in aggs.iter().enumerate() {
        assert_eq!(agg.trials(), stored.len() as u64);
        let mean = |f: &dyn Fn(&FleetStats) -> f64| -> f64 {
            stored.iter().map(|t| f(&t[pi])).sum::<f64>() / n
        };
        // Single-threaded fold order == stored-path sum order: the
        // plain-sum means must agree bit-for-bit.
        assert_eq!(
            agg.mean_tput().to_bits(),
            mean(&|s| s.mean_throughput).to_bits()
        );
        assert_eq!(
            agg.mean_net_tput().to_bits(),
            mean(&|s| s.net_throughput()).to_bits()
        );
        assert_eq!(
            agg.mean_transitions().to_bits(),
            mean(&|s| s.transitions as f64).to_bits()
        );
        assert_eq!(
            agg.mean_downtime_frac().to_bits(),
            mean(&|s| s.downtime_frac).to_bits()
        );
        let mut w = Welford::default();
        for t in &stored {
            w.push(t[pi].mean_throughput);
        }
        assert_eq!(agg.tput_ci95().to_bits(), w.ci95().to_bits());
    }
    // Multi-worker aggregates are bit-identical: the work-stealing
    // coordinator folds per-trial stats in trial-index order — the
    // exact push sequence of the 1-thread run — never a cross-worker
    // Welford merge (the pre-PR-10 scheduler only promised agreement
    // to rounding here).
    for threads in [2usize, 5] {
        let (par, _) = msim.run_trials_stream_agg_par(&gen, StepMode::Exact, threads);
        for (a, b) in aggs.iter().zip(&par) {
            assert_eq!(a.trials(), b.trials(), "threads={threads}");
            assert_eq!(a.mean_tput().to_bits(), b.mean_tput().to_bits(), "threads={threads}");
            assert_eq!(
                a.mean_net_tput().to_bits(),
                b.mean_net_tput().to_bits(),
                "threads={threads}"
            );
            assert_eq!(a.tput.mean().to_bits(), b.tput.mean().to_bits(), "threads={threads}");
            assert_eq!(
                a.tput.variance().to_bits(),
                b.tput.variance().to_bits(),
                "threads={threads}"
            );
            assert_eq!(a.tput_ci95().to_bits(), b.tput_ci95().to_bits(), "threads={threads}");
        }
    }
}

/// Checkpoint-less live rejoin beats restart-from-checkpoint: under the
/// modeled costs on a failure-heavy trace, `ELASTIC-DP` keeps more net
/// throughput than `CKPT-RESTART`, and with costs disabled it is
/// bit-identical to `DP-DROP` (capacity response is shared).
#[test]
fn elastic_dp_rejoins_cheaper_than_checkpoint_restart() {
    let (sim, cfg, table) = setup();
    let policies: Vec<&'static dyn FtPolicy> = vec![
        registry::parse("elastic-dp").unwrap(),
        registry::parse("ckpt-restart").unwrap(),
        registry::parse("dp-drop").unwrap(),
    ];
    let job_domains = 24usize;
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(40.0);
    let gen = TrialGen::new(
        &topo,
        &model,
        &hot_scenario(ScenarioKind::Independent),
        24.0 * 15.0,
        0xE1A5,
        2,
    );
    let run = |transition: Option<TransitionCosts>| -> Vec<Vec<FleetStats>> {
        let msim = MultiPolicySim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policies: &policies,
            spares: None,
            packed: true,
            blast: BlastRadius::Single,
            transition,
            detect: None,
        };
        msim.run_trials(&gen.traces(), StepMode::Exact, &mut msim.memo())
    };
    let costed = run(Some(TransitionCosts::model(&sim, &cfg)));
    for trial in &costed {
        let (elastic, ckpt) = (&trial[0], &trial[1]);
        assert!(
            elastic.net_throughput() > ckpt.net_throughput(),
            "live rejoin must beat checkpoint rollback: elastic {} vs ckpt {}",
            elastic.net_throughput(),
            ckpt.net_throughput()
        );
    }
    // Costs off: elastic DP == DP-DROP bit-for-bit (pure capacity).
    for trial in &run(None) {
        assert_eq!(trial[0], trial[2], "elastic-dp capacity response must be DP-DROP");
    }
}
