//! Property tests: the event-driven [`FleetReplayer`] sweep is
//! equivalent to the O(steps × events) per-step [`Trace::replay_to`]
//! rebuild — per-GPU health, domain counts, degradation overlays,
//! pending recovery deadlines, failed-GPU series, and the integrated
//! `FleetStats` all agree on randomized traces (every scenario
//! generator included), topologies and blast radii.

use ntp::cluster::{GpuState, Topology};
use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::failure::{
    generate_scenario, BlastRadius, FailureModel, FleetReplayer, ScenarioConfig, ScenarioKind,
    Trace,
};
use ntp::manager::{FleetSim, MultiPolicySim, SparePolicy, StepMode, StrategyTable};
use ntp::parallel::ParallelConfig;
use ntp::policy::{registry, TransitionCosts};
use ntp::power::RackDesign;
use ntp::sim::{IterationModel, SimParams};
use ntp::util::prng::Rng;
use ntp::util::prop::{check, SeedGen};

/// Compare the incremental fleet against a from-scratch replay at `t`:
/// equal health per GPU, equal pending deadline for failed GPUs, equal
/// aggregates. (`at_hours` of an *ongoing* overlapped outage is the one
/// documented difference and is not consumed by anything downstream.)
fn assert_states_match(
    inc: &ntp::cluster::FleetHealth,
    scratch: &ntp::cluster::FleetHealth,
    topo: &Topology,
    t: f64,
) -> Result<(), String> {
    if inc.n_failed() != scratch.n_failed() {
        return Err(format!(
            "n_failed {} != {} at t={t}",
            inc.n_failed(),
            scratch.n_failed()
        ));
    }
    if inc.domain_healthy_counts() != scratch.domain_healthy_counts() {
        return Err(format!("domain counts diverge at t={t}"));
    }
    if inc.domain_degraded_counts() != scratch.domain_degraded_counts() {
        return Err(format!("degraded counts diverge at t={t}"));
    }
    if inc.domain_slowdowns() != scratch.domain_slowdowns() {
        return Err(format!("domain slowdowns diverge at t={t}"));
    }
    for gpu in 0..topo.n_gpus {
        match (inc.state(gpu), scratch.state(gpu)) {
            (GpuState::Healthy, GpuState::Healthy) => {}
            (
                GpuState::Failed { until_hours: u1, .. },
                GpuState::Failed { until_hours: u2, .. },
            ) => {
                if u1 != u2 {
                    return Err(format!("gpu {gpu} until {u1} != {u2} at t={t}"));
                }
            }
            (
                GpuState::Degraded { slowdown: s1, until_hours: u1, .. },
                GpuState::Degraded { slowdown: s2, until_hours: u2, .. },
            ) => {
                if s1 != s2 || u1 != u2 {
                    return Err(format!(
                        "gpu {gpu} degraded ({s1}, {u1}) != ({s2}, {u2}) at t={t}"
                    ));
                }
            }
            (a, b) => return Err(format!("gpu {gpu} state {a:?} != {b:?} at t={t}")),
        }
    }
    inc.check_invariants().map_err(|e| format!("invariants: {e}"))?;
    Ok(())
}

#[test]
fn replayer_equals_replay_to_on_random_traces() {
    let gen = SeedGen;
    check(0xF1EE7, 25, &gen, |&seed| {
        let mut rng = Rng::new(seed);
        // randomized instance
        let domain_size = [8usize, 16, 32][rng.index(3)];
        let n_domains = 4 + rng.index(12);
        let topo = Topology::of(n_domains * domain_size, domain_size, 4.min(domain_size));
        let blast = [
            BlastRadius::Single,
            BlastRadius::Gpus(2),
            BlastRadius::Node,
            BlastRadius::Domain,
        ][rng.index(4)];
        let scale = 20.0 + rng.f64() * 300.0; // dense failures, heavy overlap
        let model = FailureModel::llama3().scaled(scale);
        let horizon = 24.0 * (3.0 + rng.f64() * 12.0);
        let trace = Trace::generate(&topo, &model, horizon, &mut rng);

        // random monotone sample grid, including exact event edges
        let mut times: Vec<f64> = (0..60).map(|_| rng.f64() * horizon * 1.1).collect();
        for ev in trace.events.iter().take(20) {
            times.push(ev.at_hours);
            times.push(ev.recover_at_hours);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());

        let mut rep = FleetReplayer::new(&trace, &topo, blast);
        for &t in &times {
            let inc = rep.advance(t);
            let scratch = trace.replay_to(&topo, blast, t);
            assert_states_match(inc, &scratch, &topo, t)?;
        }
        Ok(())
    });
}

/// Same property over the scenario generators: correlated blasts
/// (expanded in the trace itself), straggler degradation overlays, and
/// SDC detection-boundary failures all replay identically through the
/// incremental and from-scratch paths.
#[test]
fn replayer_equals_replay_to_on_scenario_traces() {
    let gen = SeedGen;
    check(0x5CE2A10, 20, &gen, |&seed| {
        let mut rng = Rng::new(seed);
        let domain_size = [8usize, 16, 32][rng.index(3)];
        let n_domains = 4 + rng.index(12);
        let topo = Topology::of(n_domains * domain_size, domain_size, 4.min(domain_size));
        let kind = [
            ScenarioKind::Independent,
            ScenarioKind::Correlated,
            ScenarioKind::Straggler,
            ScenarioKind::Sdc,
        ][rng.index(4)];
        // Hot enough that small clusters still see dense overlap.
        let mut scen = ScenarioConfig::new(kind);
        scen.correlated = scen.correlated.scaled(500.0 + rng.f64() * 2000.0);
        scen.straggler = scen.straggler.scaled(100.0 + rng.f64() * 400.0);
        scen.sdc = scen.sdc.scaled(500.0 + rng.f64() * 2000.0);
        let model = FailureModel::llama3().scaled(10.0 + rng.f64() * 100.0);
        let horizon = 24.0 * (3.0 + rng.f64() * 9.0);
        let trace = generate_scenario(&topo, &model, &scen, horizon, &mut rng);

        let mut times: Vec<f64> = (0..60).map(|_| rng.f64() * horizon * 1.1).collect();
        for ev in trace.events.iter().take(20) {
            times.push(ev.at_hours);
            times.push(ev.recover_at_hours);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());

        // Scenario traces carry their own blast expansion, so they are
        // replayed with the per-GPU radius.
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        for &t in &times {
            let inc = rep.advance(t);
            let scratch = trace.replay_to(&topo, BlastRadius::Single, t);
            assert_states_match(inc, &scratch, &topo, t)
                .map_err(|e| format!("{}: {e}", kind.name()))?;
        }
        Ok(())
    });
}

#[test]
fn replayer_handles_spiky_traces() {
    let topo = Topology::of(512, 16, 4);
    let model = FailureModel::llama3().scaled(60.0);
    let mut rng = Rng::new(99);
    let trace =
        Trace::generate_with_spikes(&topo, &model, 24.0 * 20.0, 7.0, 1.5, 10.0, &mut rng);
    let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Node);
    for step in 0..400 {
        let t = step as f64 * 1.3;
        let inc = rep.advance(t);
        let scratch = trace.replay_to(&topo, BlastRadius::Node, t);
        assert_states_match(inc, &scratch, &topo, t).unwrap();
    }
}

#[test]
fn failed_series_matches_replay_to_counts() {
    let topo = Topology::of(1024, 8, 4);
    let model = FailureModel::llama3().scaled(80.0);
    let mut rng = Rng::new(17);
    let trace = Trace::generate(&topo, &model, 24.0 * 12.0, &mut rng);
    for blast in [BlastRadius::Single, BlastRadius::Node] {
        let series = trace.failed_series(&topo, blast, 2.5);
        assert_eq!(series.len(), (trace.horizon_hours / 2.5).ceil() as usize + 1);
        for &(t, failed) in &series {
            assert_eq!(
                failed,
                trace.replay_to(&topo, blast, t).n_failed(),
                "blast {blast:?} t={t}"
            );
        }
    }
}

/// Scenario traces (correlated / straggler / SDC) flow through three
/// independent execution paths — the event-driven `FleetSim::run`, the
/// per-boundary `run_replay_per_step` reference that rebuilds the fleet
/// from scratch at every boundary, and the shared `MultiPolicySim`
/// sweep — and all three must produce bit-identical `FleetStats` for
/// every registered policy, degradation drag, SDC rollback charges and
/// transition accounting included.
#[test]
fn fleet_stats_bit_identical_on_scenario_traces() {
    let sim = IterationModel::new(
        presets::model("gpt-480b").unwrap(),
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 2 * 1024 * 1024,
            dtype: Dtype::BF16,
        },
        presets::cluster("paper-32k-nvl32").unwrap(),
        SimParams::default(),
    );
    let cfg = ParallelConfig { tp: 32, pp: 4, dp: 16, microbatch: 1 };
    let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
    let table = StrategyTable::build(&sim, &cfg, &rack);
    let topo = Topology::of(cfg.n_gpus(), 32, 4);
    let model = FailureModel::llama3().scaled(35.0);
    let policies = registry::all();
    let transition = Some(TransitionCosts::model(&sim, &cfg));

    let mut scenarios = Vec::new();
    let mut corr = ScenarioConfig::new(ScenarioKind::Correlated);
    corr.correlated = corr.correlated.scaled(500.0);
    scenarios.push(corr);
    let mut strag = ScenarioConfig::new(ScenarioKind::Straggler);
    strag.straggler = strag.straggler.scaled(200.0);
    scenarios.push(strag);
    let mut sdc = ScenarioConfig::new(ScenarioKind::Sdc);
    sdc.sdc = sdc.sdc.scaled(500.0);
    scenarios.push(sdc);

    let mut rng = Rng::new(0x5D);
    for scen in &scenarios {
        // Short horizon: the per-step reference is quadratic in the
        // event count, and hot scenario traces are dense.
        let trace = generate_scenario(&topo, &model, scen, 24.0 * 7.0, &mut rng);
        assert!(!trace.events.is_empty(), "{} trace is empty", scen.kind.name());
        let msim = MultiPolicySim {
            topo: &topo,
            table: &table,
            domains_per_replica: cfg.pp,
            policies: &policies,
            spares: None,
            packed: true,
            blast: BlastRadius::Single,
            transition,
            detect: None,
        };
        let swept = msim.run(&trace, StepMode::Exact);
        for (pi, &policy) in policies.iter().enumerate() {
            let fs = FleetSim {
                topo: &topo,
                table: &table,
                domains_per_replica: cfg.pp,
                policy,
                spares: None,
                packed: true,
                blast: BlastRadius::Single,
                transition,
                detect: None,
            };
            let fast = fs.run(&trace, StepMode::Exact);
            let slow = fs.run_replay_per_step(&trace, StepMode::Exact);
            assert_eq!(fast, slow, "{} policy {}", scen.kind.name(), policy.name());
            assert_eq!(
                fast,
                swept[pi],
                "{} policy {}: shared sweep diverged",
                scen.kind.name(),
                policy.name()
            );
        }
    }
}

/// The exact step-function series: one breakpoint per actual change in
/// the concurrently-failed count, each agreeing with a from-scratch
/// `replay_to` at the breakpoint AND holding constant until the next
/// one — so integrals over the series are exact, which the grid-sampled
/// series converges to from below as the step shrinks.
#[test]
fn failed_series_exact_matches_replay_to_everywhere() {
    let topo = Topology::of(1024, 8, 4);
    let model = FailureModel::llama3().scaled(80.0);
    let mut rng = Rng::new(23);
    let trace = Trace::generate(&topo, &model, 24.0 * 12.0, &mut rng);
    for blast in [BlastRadius::Single, BlastRadius::Node] {
        let series = trace.failed_series_exact(&topo, blast);
        assert!(series.len() > 2, "trace too quiet for this test");
        assert_eq!(series[0].0, 0.0);
        for (i, &(t, failed)) in series.iter().enumerate() {
            assert!(t < trace.horizon_hours, "breakpoint past the horizon");
            assert_eq!(
                failed,
                trace.replay_to(&topo, blast, t).n_failed(),
                "blast {blast:?} breakpoint t={t}"
            );
            if i > 0 {
                let (prev_t, prev_failed) = series[i - 1];
                assert!(prev_t < t, "breakpoints must be strictly increasing");
                assert_ne!(prev_failed, failed, "breakpoint without a count change at t={t}");
                // piecewise-constant between breakpoints
                let mid = 0.5 * (prev_t + t);
                assert_eq!(
                    prev_failed,
                    trace.replay_to(&topo, blast, mid).n_failed(),
                    "blast {blast:?} midpoint t={mid}"
                );
            }
        }
        // The exact time-above integral agrees with integrating the
        // series by hand, and the grid-sampled estimate approaches it.
        let thresh = 0.002;
        let exact = trace.time_above_fraction_exact(&topo, blast, thresh);
        let mut by_hand = 0.0;
        for (i, &(t0, failed)) in series.iter().enumerate() {
            let t1 = series.get(i + 1).map(|&(t, _)| t).unwrap_or(trace.horizon_hours);
            if failed as f64 / topo.n_gpus as f64 > thresh {
                by_hand += t1 - t0;
            }
        }
        assert!((exact - by_hand / trace.horizon_hours).abs() < 1e-12);
        let sampled = trace.time_above_fraction(&topo, blast, 0.05, thresh);
        assert!(
            (sampled - exact).abs() < 0.05,
            "fine-grid estimate {sampled} should approach the exact integral {exact}"
        );
    }
}

#[test]
fn fleet_stats_bit_identical_for_every_policy_and_spares() {
    let sim = IterationModel::new(
        presets::model("gpt-480b").unwrap(),
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 2 * 1024 * 1024,
            dtype: Dtype::BF16,
        },
        presets::cluster("paper-32k-nvl32").unwrap(),
        SimParams::default(),
    );
    let cfg = ParallelConfig { tp: 32, pp: 4, dp: 16, microbatch: 1 };
    let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
    let table = StrategyTable::build(&sim, &cfg, &rack);
    let topo = Topology::of(cfg.n_gpus(), 32, 4);
    let model = FailureModel::llama3().scaled(35.0);
    let mut rng = Rng::new(4);
    let trace = Trace::generate(&topo, &model, 24.0 * 25.0, &mut rng);
    // The exact-mode per-step reference is O(boundaries × events) —
    // quadratic in the event count — so its leg runs a shorter trace
    // to keep the 72-combination sweep debug-friendly.
    let trace_short = Trace::generate(&topo, &model, 24.0 * 7.0, &mut rng);

    // Every registered policy (legacy ports and the new ones), with and
    // without modeled transition costs, in BOTH step modes: the
    // event-driven sweep and the per-step replay must produce
    // bit-identical FleetStats, downtime accounting included. In exact
    // mode the per-step reference walks the trace's sorted
    // arrival/recovery boundaries and rebuilds the fleet from scratch
    // at each, so the event cursor + lazy recovery heap is checked
    // against straight-line replay_to on the exact timeline too.
    for (mode, trace) in [(StepMode::Grid(1.5), &trace), (StepMode::Exact, &trace_short)] {
        for policy in registry::all() {
            for spares in [None, Some(SparePolicy { spare_domains: 6, cold_domains: 0, min_tp: 28 })] {
                for blast in [BlastRadius::Single, BlastRadius::Gpus(2)] {
                    for transition in [None, Some(TransitionCosts::model(&sim, &cfg))] {
                        let fs = FleetSim {
                            topo: &topo,
                            table: &table,
                            domains_per_replica: cfg.pp,
                            policy,
                            spares,
                            packed: true,
                            blast,
                            transition,
                            detect: None,
                        };
                        let fast = fs.run(trace, mode);
                        let slow = fs.run_replay_per_step(trace, mode);
                        assert_eq!(
                            fast,
                            slow,
                            "mode {mode:?} policy {} spares {spares:?} blast {blast:?} \
                             transition {transition:?}",
                            policy.name()
                        );
                        if transition.is_none() {
                            assert_eq!(fast.downtime_frac, 0.0, "{}", policy.name());
                        }
                    }
                }
            }
        }
    }
}
