//! Exact event-boundary integration suite (the PR 5 tentpole's
//! contract):
//!
//! * **Refinement invariance** — [`StepMode::Exact`] stats are a pure
//!   function of the trace: merging ANY extra sample times into the
//!   boundary stream (`FleetSim::run_exact_with_refinement`) leaves
//!   every [`FleetStats`] field bit-identical, for every registered
//!   policy. (A per-sample-mean integrator would fail this instantly —
//!   added samples would reweight the average.)
//! * **Grid convergence** — the legacy fixed grid converges to the
//!   exact stats as `step_hours → 0`, for every registered policy, and
//!   never observes more transitions than actually happened.
//! * **Partial-last-step regression** — the former
//!   `n_steps = ceil(horizon/step)` loop integrated a full step past
//!   `trace.horizon_hours`; the clamped grid weights the final partial
//!   interval by exactly its duration (hand-computed oracle).
//! * **Per-event charges** — exact mode charges each health-change
//!   boundary individually, where the grid collapses the events
//!   between two samples into one net charge.
//! * **Scenario generators** — every [`generate_scenario`] kind emits
//!   the timestamped-event contract (time-sorted, in-horizon,
//!   `recover_at_hours > at_hours`), and exact-mode stats over
//!   correlated / straggler / SDC traces stay refinement-invariant for
//!   every registered policy.

use ntp::cluster::Topology;
use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::failure::{
    generate_scenario, BlastRadius, EventKind, FailureEvent, FailureModel, ScenarioConfig,
    ScenarioKind, Trace,
};
use ntp::manager::{FleetSim, MultiPolicySim, SparePolicy, StepMode, StrategyTable};
use ntp::parallel::ParallelConfig;
use ntp::policy::{registry, TransitionCosts};
use ntp::power::RackDesign;
use ntp::sim::{FtStrategy, IterationModel, SimParams};
use ntp::util::prng::Rng;

const DOMAIN_SIZE: usize = 32;
const PER_REPLICA: usize = 4;

fn setup() -> (IterationModel, ParallelConfig, StrategyTable) {
    let sim = IterationModel::new(
        presets::model("gpt-480b").unwrap(),
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 2 * 1024 * 1024,
            dtype: Dtype::BF16,
        },
        presets::cluster("paper-32k-nvl32").unwrap(),
        SimParams::default(),
    );
    let cfg = ParallelConfig { tp: DOMAIN_SIZE, pp: PER_REPLICA, dp: 16, microbatch: 1 };
    let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
    let table = StrategyTable::build(&sim, &cfg, &rack);
    (sim, cfg, table)
}

#[test]
fn exact_mode_is_invariant_to_any_refinement() {
    let (sim, cfg, table) = setup();
    let job_domains = 16usize;
    let spare_domains = 4usize;
    let topo = Topology::of((job_domains + spare_domains) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(30.0);
    let mut rng = Rng::new(0xE7AC7);
    let trace = Trace::generate(&topo, &model, 24.0 * 12.0, &mut rng);
    assert!(!trace.events.is_empty());
    let horizon = trace.horizon_hours;
    let transition = Some(TransitionCosts::model(&sim, &cfg).with_observed_rate(&trace));

    // Three refinement families: a dense uniform grid, the event
    // edges themselves plus off-boundary midpoints, and random times.
    let uniform: Vec<f64> = (1..2000).map(|i| i as f64 * (horizon / 2000.0)).collect();
    let mut edges: Vec<f64> = trace
        .events
        .iter()
        .flat_map(|e| [e.at_hours, e.recover_at_hours, e.at_hours + 0.1237])
        .filter(|&t| t > 0.0 && t < horizon)
        .collect();
    edges.sort_by(f64::total_cmp);
    let mut random: Vec<f64> = (0..500).map(|_| rng.f64() * horizon).collect();
    random.sort_by(f64::total_cmp);

    for policy in registry::all() {
        for spares in [None, Some(SparePolicy { spare_domains, cold_domains: 0, min_tp: 28 })] {
            let fs = FleetSim {
                topo: &topo,
                table: &table,
                domains_per_replica: PER_REPLICA,
                policy,
                spares,
                packed: true,
                blast: BlastRadius::Single,
                transition,
                detect: None,
            };
            let base = fs.run(&trace, StepMode::Exact);
            assert_eq!(base, fs.run_exact_with_refinement(&trace, &[]), "{}", policy.name());
            for (label, extra) in
                [("uniform", &uniform), ("edges", &edges), ("random", &random)]
            {
                assert_eq!(
                    base,
                    fs.run_exact_with_refinement(&trace, extra),
                    "{} spares {spares:?}: {label} refinement changed the exact stats",
                    policy.name()
                );
            }
        }
    }
}

#[test]
fn grid_converges_to_exact_for_every_policy() {
    let (sim, cfg, table) = setup();
    let job_domains = 24usize;
    let spare_domains = 4usize;
    let topo = Topology::of((job_domains + spare_domains) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    // Moderate rate so downtime stays far from the 1.0 cap and the
    // quantization error has real dynamic range.
    let model = FailureModel::llama3().scaled(5.0);
    let mut rng = Rng::new(0xC0471);
    let trace = Trace::generate(&topo, &model, 24.0 * 12.0, &mut rng);
    assert!(trace.events.len() > 10, "trace too quiet: {}", trace.events.len());
    let transition = Some(TransitionCosts::model(&sim, &cfg).with_observed_rate(&trace));
    let policies = registry::all();
    let msim = MultiPolicySim {
        topo: &topo,
        table: &table,
        domains_per_replica: PER_REPLICA,
        policies: &policies,
        spares: Some(SparePolicy { spare_domains, cold_domains: 0, min_tp: 28 }),
        packed: true,
        blast: BlastRadius::Single,
        transition,
        detect: None,
    };
    let exact = msim.run(&trace, StepMode::Exact);
    let coarse = msim.run(&trace, StepMode::Grid(6.0));
    let fine = msim.run(&trace, StepMode::Grid(0.25));
    for (pi, &policy) in policies.iter().enumerate() {
        let name = policy.name();
        let err = |g: &ntp::manager::FleetStats| {
            (g.mean_throughput - exact[pi].mean_throughput).abs()
        };
        let (e_coarse, e_fine) = (err(&coarse[pi]), err(&fine[pi]));
        // Absolute convergence at the fine step, and no blow-up at the
        // coarse one (quantization error is statistical, so the fine
        // grid gets a small slack floor rather than strict ordering).
        assert!(e_fine < 0.02, "{name}: fine-grid tput error {e_fine}");
        assert!(e_coarse < 0.2, "{name}: coarse-grid tput error {e_coarse}");
        assert!(
            e_fine <= e_coarse + 0.01,
            "{name}: refining the grid made the error worse ({e_coarse} -> {e_fine})"
        );
        let d_fine = (fine[pi].downtime_frac - exact[pi].downtime_frac).abs();
        assert!(d_fine < 0.02, "{name}: fine-grid downtime error {d_fine}");
        let p_fine = (fine[pi].paused_frac - exact[pi].paused_frac).abs();
        assert!(p_fine < 0.05, "{name}: fine-grid paused error {p_fine}");
        // Collapsing events between samples can only *lose* observed
        // transitions, never invent them.
        assert!(coarse[pi].transitions <= exact[pi].transitions, "{name}");
        assert!(fine[pi].transitions <= exact[pi].transitions, "{name}");
        assert!(exact[pi].transitions > 0, "{name}");
    }
}

/// Satellite regression: `n_steps = ceil(horizon/step)` used to
/// integrate a full step past `trace.horizon_hours`, overweighting
/// whatever the last sample saw (1/n of the mean instead of the true
/// `(horizon - t_last)/horizon`). The clamped grid weights every state
/// by exactly the time it was sampled for — checked against a
/// hand-computed oracle on a non-divisible horizon.
#[test]
fn grid_clamps_the_partial_final_step() {
    let (_sim, _cfg, table) = setup();
    let job_domains = 16usize;
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    // Horizon 10h, step 4h: samples at 0, 4, 8 with weights 4, 4, 2.
    // One failure at t = 7.5 (seen by the t = 8 sample), never
    // recovering within the horizon.
    let trace = Trace {
        horizon_hours: 10.0,
        events: vec![FailureEvent {
            at_hours: 7.5,
            gpu: 0,
            is_hw: true,
            recover_at_hours: 100.0,
            kind: EventKind::Fail,
        }],
    };
    let fs = FleetSim {
        topo: &topo,
        table: &table,
        domains_per_replica: PER_REPLICA,
        policy: FtStrategy::Ntp.policy(),
        spares: None,
        packed: true,
        blast: BlastRadius::Single,
        transition: None,
        detect: None,
    };
    let mut degraded = vec![DOMAIN_SIZE; job_domains];
    degraded[0] = DOMAIN_SIZE - 1;
    let x = fs.evaluate(&degraded).tput;
    assert!(x < 1.0);

    let grid = fs.run(&trace, StepMode::Grid(4.0));
    // Same accumulation order as the sweep: healthy 4h + healthy 4h +
    // degraded 2h, normalized by the 10h of integrated time.
    let expected_grid = (1.0 * 4.0 + 1.0 * 4.0 + x * 2.0) / 10.0;
    assert_eq!(grid.mean_throughput, expected_grid);
    // The old ceil loop would have charged the degraded state 1/3 of
    // the mean (a full 4h step); the clamp weights it 2h/10h.
    let old_bias = (1.0 + 1.0 + x) / 3.0;
    assert!(grid.mean_throughput > old_bias);

    // Exact mode: the failure is weighted from 7.5h, not from the 8h
    // sample that first saw it.
    let exact = fs.run(&trace, StepMode::Exact);
    let expected_exact = (1.0 * 7.5 + x * 2.5) / 10.0;
    assert_eq!(exact.mean_throughput, expected_exact);
    assert!(exact.mean_throughput < grid.mean_throughput);

    // All-healthy fleet on a non-divisible horizon: exactly 1.0 in
    // both modes (constant integrands survive any partition bit-for-bit).
    let quiet = Trace { horizon_hours: 10.0, events: vec![] };
    assert_eq!(fs.run(&quiet, StepMode::Grid(3.0)).mean_throughput, 1.0);
    assert_eq!(fs.run(&quiet, StepMode::Exact).mean_throughput, 1.0);
    // ... and the per-step reference clamps identically.
    assert_eq!(grid, fs.run_replay_per_step(&trace, StepMode::Grid(4.0)));
    assert_eq!(exact, fs.run_replay_per_step(&trace, StepMode::Exact));
}

#[test]
fn exact_mode_charges_each_event_at_its_boundary() {
    let (_sim, _cfg, table) = setup();
    let job_domains = 16usize;
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    // Two failures in distinct domains, both inside the first 6h grid
    // step, neither recovering within the horizon.
    let trace = Trace {
        horizon_hours: 12.0,
        events: vec![
            FailureEvent {
                at_hours: 1.0,
                gpu: 0,
                is_hw: true,
                recover_at_hours: 50.0,
                kind: EventKind::Fail,
            },
            FailureEvent {
                at_hours: 2.0,
                gpu: DOMAIN_SIZE, // first GPU of domain 1
                is_hw: true,
                recover_at_hours: 50.0,
                kind: EventKind::Fail,
            },
        ],
    };
    let costs = TransitionCosts {
        restart_secs: 900.0,
        checkpoint_interval_secs: 3600.0,
        reshard_secs: 2.0,
        spare_load_secs: 300.0,
        cold_spare_load_secs: 1800.0,
        preempt_secs: 5.0,
        rejoin_secs: 45.0,
        ckpt_write_secs: 120.0,
        power_ramp_secs: 60.0,
        failure_rate_per_hour: 0.0,
        validation_sweep_secs: 0.0,
    };
    let run = |strategy: FtStrategy, mode: StepMode| {
        FleetSim {
            topo: &topo,
            table: &table,
            domains_per_replica: PER_REPLICA,
            policy: strategy.policy(),
            spares: None,
            packed: true,
            blast: BlastRadius::Single,
            transition: Some(costs),
            detect: None,
        }
        .run(&trace, mode)
    };
    // Grid(6h): both events collapse into the t = 6 sample — ONE net
    // change. Exact: two boundaries, two charges.
    let grid = run(FtStrategy::DpDrop, StepMode::Grid(6.0));
    let exact = run(FtStrategy::DpDrop, StepMode::Exact);
    assert_eq!(grid.transitions, 1);
    assert_eq!(exact.transitions, 2);
    // DP-DROP pays a full-job restart per charge, so the exact bill is
    // twice the collapsed one.
    assert!(grid.downtime_frac > 0.0);
    assert!(
        (exact.downtime_frac - 2.0 * grid.downtime_frac).abs() < 1e-12,
        "exact {} vs 2x grid {}",
        exact.downtime_frac,
        grid.downtime_frac
    );
    // NTP's bill scales linearly with the changed-domain count, so
    // one collapsed charge of 2 domains equals two charges of 1 —
    // same total, different transition counts.
    let grid_ntp = run(FtStrategy::Ntp, StepMode::Grid(6.0));
    let exact_ntp = run(FtStrategy::Ntp, StepMode::Exact);
    assert_eq!(grid_ntp.transitions, 1);
    assert_eq!(exact_ntp.transitions, 2);
    assert!((exact_ntp.downtime_frac - grid_ntp.downtime_frac).abs() < 1e-15);
}

/// Satellite: `TransitionCosts::validation_sweep_secs` bills an
/// amortized periodic validation stall — `secs/GPU/hour × horizon ×
/// n_gpus` GPU-seconds through the rollback channel. With everything
/// else free that lands as exactly `secs/3600` of downtime fraction;
/// the default `0.0` leaves every stat bitwise unchanged; and the
/// FleetSim, per-step reference, and shared-sweep paths all charge the
/// identical `f64`.
#[test]
fn validation_sweep_bill_is_exact_and_zero_by_default() {
    let (sim, cfg, table) = setup();
    let job_domains = 16usize;
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(20.0);
    let mut rng = Rng::new(0x7A1);
    let trace = Trace::generate(&topo, &model, 24.0 * 8.0, &mut rng);
    assert!(!trace.events.is_empty());
    let base_costs = TransitionCosts::model(&sim, &cfg);
    assert_eq!(base_costs.validation_sweep_secs, 0.0, "default must stay free");
    let secs_per_hour = 7.2;
    let mut sweep_costs = base_costs;
    sweep_costs.validation_sweep_secs = secs_per_hour;

    let policies = registry::all();
    let swept = MultiPolicySim {
        topo: &topo,
        table: &table,
        domains_per_replica: PER_REPLICA,
        policies: &policies,
        spares: None,
        packed: true,
        blast: BlastRadius::Single,
        transition: Some(sweep_costs),
        detect: None,
    }
    .run(&trace, StepMode::Exact);
    for (pi, &policy) in policies.iter().enumerate() {
        let run = |costs: TransitionCosts| {
            FleetSim {
                topo: &topo,
                table: &table,
                domains_per_replica: PER_REPLICA,
                policy,
                spares: None,
                packed: true,
                blast: BlastRadius::Single,
                transition: Some(costs),
                detect: None,
            }
            .run(&trace, StepMode::Exact)
        };
        let base = run(base_costs);
        let billed = run(sweep_costs);
        // Only the downtime pool moves, by the amortized stall: the
        // bill normalizes to secs/GPU/hour / 3600 s/h of fleet time.
        assert_eq!(billed.mean_throughput, base.mean_throughput, "{}", policy.name());
        assert_eq!(billed.paused_frac, base.paused_frac, "{}", policy.name());
        assert_eq!(billed.transitions, base.transitions, "{}", policy.name());
        assert_eq!(billed.mean_spares_used, base.mean_spares_used, "{}", policy.name());
        let expected = secs_per_hour / 3600.0;
        assert!(
            (billed.downtime_frac - base.downtime_frac - expected).abs() < 1e-12,
            "{}: downtime moved by {} instead of {expected}",
            policy.name(),
            billed.downtime_frac - base.downtime_frac
        );
        // All three sweep paths charge the identical f64.
        assert_eq!(
            billed,
            FleetSim {
                topo: &topo,
                table: &table,
                domains_per_replica: PER_REPLICA,
                policy,
                spares: None,
                packed: true,
                blast: BlastRadius::Single,
                transition: Some(sweep_costs),
                detect: None,
            }
            .run_replay_per_step(&trace, StepMode::Exact),
            "{}: per-step reference diverged",
            policy.name()
        );
        assert_eq!(swept[pi], billed, "{}: shared sweep diverged", policy.name());
    }
}

/// One config per generator kind, each scaled hot enough that a 6-day
/// trace on a small cluster carries all its event types.
fn hot_scenarios() -> Vec<ScenarioConfig> {
    let mut corr = ScenarioConfig::new(ScenarioKind::Correlated);
    corr.correlated = corr.correlated.scaled(500.0);
    let mut strag = ScenarioConfig::new(ScenarioKind::Straggler);
    strag.straggler = strag.straggler.scaled(200.0);
    let mut sdc = ScenarioConfig::new(ScenarioKind::Sdc);
    sdc.sdc = sdc.sdc.scaled(500.0);
    vec![ScenarioConfig::new(ScenarioKind::Independent), corr, strag, sdc]
}

/// Every generator kind emits the contract the exact integrator and the
/// incremental replayer rely on: time-sorted events, onsets inside the
/// horizon, strictly later recoveries, valid GPU ids, and kind-specific
/// payloads (slowdowns in `(0, 1]`, corruption strictly before its
/// detection boundary).
#[test]
fn scenario_generators_satisfy_the_event_contract() {
    let topo = Topology::of(18 * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(10.0);
    let horizon = 24.0 * 6.0;
    for scen in hot_scenarios() {
        let name = scen.kind.name();
        let mut rng = Rng::new(0x5CE4);
        let trace = generate_scenario(&topo, &model, &scen, horizon, &mut rng);
        assert!(!trace.events.is_empty(), "{name}: empty trace");
        assert_eq!(trace.horizon_hours, horizon);
        for pair in trace.events.windows(2) {
            assert!(pair[0].at_hours <= pair[1].at_hours, "{name}: events out of order");
        }
        let mut extra = 0usize;
        for e in &trace.events {
            assert!(e.at_hours >= 0.0 && e.at_hours < horizon, "{name}: onset {}", e.at_hours);
            assert!(
                e.recover_at_hours > e.at_hours,
                "{name}: recovery {} not after onset {}",
                e.recover_at_hours,
                e.at_hours
            );
            assert!(e.gpu < topo.n_gpus, "{name}: gpu {} out of range", e.gpu);
            match e.kind {
                EventKind::Fail => {}
                EventKind::Degrade { slowdown } => {
                    extra += 1;
                    assert_eq!(scen.kind, ScenarioKind::Straggler, "{name}");
                    assert!(slowdown > 0.0 && slowdown <= 1.0, "{name}: slowdown {slowdown}");
                }
                EventKind::Sdc { corrupt_at_hours } => {
                    extra += 1;
                    assert_eq!(scen.kind, ScenarioKind::Sdc, "{name}");
                    assert!(
                        corrupt_at_hours >= 0.0 && corrupt_at_hours < e.at_hours,
                        "{name}: corruption {corrupt_at_hours} not before detection {}",
                        e.at_hours
                    );
                }
            }
        }
        match scen.kind {
            ScenarioKind::Straggler | ScenarioKind::Sdc => {
                assert!(extra > 0, "{name}: no scenario-specific events");
            }
            _ => assert_eq!(extra, 0, "{name}: unexpected non-Fail events"),
        }
    }
    // The correlated superposition strictly adds (Fail) events over the
    // same-seed independent base process.
    let scens = hot_scenarios();
    let base = generate_scenario(&topo, &model, &scens[0], horizon, &mut Rng::new(7));
    let corr = generate_scenario(&topo, &model, &scens[1], horizon, &mut Rng::new(7));
    assert!(corr.events.len() > base.events.len());
}

/// Refinement invariance extends to every scenario generator: merging
/// arbitrary extra sample times into a correlated / straggler / SDC
/// boundary stream leaves the exact stats bit-identical for every
/// registered policy — including slowdown-only boundaries (which change
/// drag but not counts) and SDC rollback charges.
#[test]
fn exact_mode_is_refinement_invariant_on_scenario_traces() {
    let (sim, cfg, table) = setup();
    let job_domains = 16usize;
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(10.0);
    let transition = Some(TransitionCosts::model(&sim, &cfg));
    for scen in hot_scenarios() {
        let mut rng = Rng::new(0x5EED);
        let trace = generate_scenario(&topo, &model, &scen, 24.0 * 6.0, &mut rng);
        let horizon = trace.horizon_hours;
        let uniform: Vec<f64> = (1..400).map(|i| i as f64 * (horizon / 400.0)).collect();
        let mut edges: Vec<f64> = trace
            .events
            .iter()
            .flat_map(|e| [e.at_hours, e.recover_at_hours, e.at_hours + 0.1237])
            .filter(|&t| t > 0.0 && t < horizon)
            .collect();
        edges.sort_by(f64::total_cmp);
        let mut random: Vec<f64> = (0..200).map(|_| rng.f64() * horizon).collect();
        random.sort_by(f64::total_cmp);
        for policy in registry::all() {
            let fs = FleetSim {
                topo: &topo,
                table: &table,
                domains_per_replica: PER_REPLICA,
                policy,
                spares: None,
                packed: true,
                blast: BlastRadius::Single,
                transition,
                detect: None,
            };
            let base = fs.run(&trace, StepMode::Exact);
            for (label, extra) in
                [("uniform", &uniform), ("edges", &edges), ("random", &random)]
            {
                assert_eq!(
                    base,
                    fs.run_exact_with_refinement(&trace, extra),
                    "{} on {}: {label} refinement changed the exact stats",
                    policy.name(),
                    scen.kind.name()
                );
            }
        }
    }
}

/// Hand-oracle for the energy integral: 2048 GPUs, one failure at
/// exactly half the horizon, a non-boosting policy. Power is 1.0 for
/// the first half and `2047/2048` for the second, so the
/// duration-weighted mean is `1 − (1/2048)/2 = 4095/4096` — every
/// division is by a power of two, so the integrator must land on it
/// **to the bit**, in exact mode, on the clamped grid, and through the
/// per-step replay reference.
///
/// (Refinement invariance of the energy integral needs no test of its
/// own: `mean_power_frac` and `peak_rack_power_frac` are `FleetStats`
/// fields, so every `assert_eq!`-on-stats refinement test above —
/// 12 policies × 4 scenario generators × Exact/Grid — now pins the
/// energy channel too.)
#[test]
fn energy_integral_matches_hand_oracle_to_the_bit() {
    let (_sim, _cfg, table) = setup();
    let job_domains = 64usize; // 64 × 32 = 2048 GPUs
    let topo = Topology::of(job_domains * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let trace = Trace {
        horizon_hours: 2.0,
        events: vec![FailureEvent {
            at_hours: 1.0,
            gpu: 0,
            is_hw: true,
            recover_at_hours: 100.0,
            kind: EventKind::Fail,
        }],
    };
    let fs = FleetSim {
        topo: &topo,
        table: &table,
        domains_per_replica: PER_REPLICA,
        policy: FtStrategy::Ntp.policy(),
        spares: None,
        packed: true,
        blast: BlastRadius::Single,
        transition: None,
        detect: None,
    };
    let expected = 1.0 - (1.0 / 2048.0) / 2.0; // = 4095/4096, exact
    let exact = fs.run(&trace, StepMode::Exact);
    assert_eq!(exact.mean_power_frac, expected);
    // Other domains stay full, so the hottest domain never leaves 1.0.
    assert_eq!(exact.peak_rack_power_frac, 1.0);
    // energy_per_token is the derived ratio of the two integrals.
    assert_eq!(
        exact.energy_per_token(),
        exact.mean_power_frac / exact.net_throughput()
    );
    // A 0.5 h grid samples the boundary exactly; the replay reference
    // must agree bit-for-bit on all three paths.
    assert_eq!(fs.run(&trace, StepMode::Grid(0.5)).mean_power_frac, expected);
    assert_eq!(fs.run_replay_per_step(&trace, StepMode::Exact), exact);
    let healthy = Trace { horizon_hours: 2.0, events: vec![] };
    assert_eq!(fs.run(&healthy, StepMode::Exact).mean_power_frac, 1.0);
    assert_eq!(fs.run(&healthy, StepMode::Exact).peak_rack_power_frac, 1.0);
}

/// The energy channel is strictly an *observer*: varying the rack's
/// power-accounting knobs (`idle_frac`, `standby_frac`,
/// `degraded_derate`) moves only the power stats — every throughput,
/// pause, downtime, spare and donation stat stays bit-identical, for
/// every registered policy. (The shaping knobs — boost cap, thermal,
/// row caps — legitimately move throughput; they are exercised in
/// `power::rack` and the allocator tests.)
#[test]
fn power_accounting_knobs_never_move_throughput() {
    let (sim, cfg, _table) = setup();
    let job_domains = 16usize;
    let spare_domains = 4usize;
    let topo = Topology::of((job_domains + spare_domains) * DOMAIN_SIZE, DOMAIN_SIZE, 4);
    let model = FailureModel::llama3().scaled(25.0);
    let mut rng = Rng::new(0x9E7);
    let trace = Trace::generate(&topo, &model, 24.0 * 8.0, &mut rng);
    assert!(!trace.events.is_empty());
    let transition = Some(TransitionCosts::model(&sim, &cfg));

    let base_rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
    let tweaked_rack = RackDesign {
        idle_frac: 0.08,
        standby_frac: 0.05,
        degraded_derate: 0.5,
        ..base_rack
    };
    let base_table = StrategyTable::build(&sim, &cfg, &base_rack);
    let tweaked_table = StrategyTable::build(&sim, &cfg, &tweaked_rack);
    // Accounting knobs must not leak into the batch/boost tables.
    assert_eq!(base_table.batch, tweaked_table.batch);
    assert_eq!(base_table.batch_pw, tweaked_table.batch_pw);

    let spares = Some(SparePolicy { spare_domains, cold_domains: 0, min_tp: 28 });
    for policy in registry::all() {
        let run = |table: &StrategyTable| {
            FleetSim {
                topo: &topo,
                table,
                domains_per_replica: PER_REPLICA,
                policy,
                spares,
                packed: true,
                blast: BlastRadius::Single,
                transition,
                detect: None,
            }
            .run(&trace, StepMode::Exact)
        };
        let a = run(&base_table);
        let b = run(&tweaked_table);
        let name = policy.name();
        assert_eq!(a.mean_throughput, b.mean_throughput, "{name}");
        assert_eq!(a.paused_frac, b.paused_frac, "{name}");
        assert_eq!(a.mean_spares_used, b.mean_spares_used, "{name}");
        assert_eq!(a.throughput_per_gpu, b.throughput_per_gpu, "{name}");
        assert_eq!(a.downtime_frac, b.downtime_frac, "{name}");
        assert_eq!(a.transitions, b.transitions, "{name}");
        assert_eq!(a.mean_donated, b.mean_donated, "{name}");
        // Sanity that the knobs are live: the dark pool's saving reads
        // the fleet-wide standby fraction, so POWER-SPARES must draw
        // *less* under the deeper standby cap.
        if name == "POWER-SPARES" {
            assert!(
                b.mean_power_frac < a.mean_power_frac,
                "{name}: standby knob dead ({} vs {})",
                b.mean_power_frac,
                a.mean_power_frac
            );
        }
    }
}
