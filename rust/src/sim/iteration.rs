//! End-to-end iteration-time model: assembles compute, TP collectives,
//! the 1F1B pipeline and the (partially overlapped) DP gradient allreduce
//! into one iteration's timing with a full breakdown — the quantity every
//! large-scale figure is computed from.

use super::comm::{self, Link};
use super::compute;
use super::pipeline::PipelineTiming;
use crate::config::{ClusterConfig, ModelConfig, WorkloadConfig};
use crate::ntp::PlanCache;
use crate::parallel::ParallelConfig;
use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};

/// Tunable simulator constants (fit once in [`super::calibrate`]).
#[derive(Clone, Copy, Debug)]
pub struct SimParams {
    /// Peak fraction achievable by large GEMMs.
    pub base_eff: f64,
    /// Interleaved virtual stages per GPU (Megatron-style).
    pub virtual_stages: usize,
    /// Fraction of the TP allreduce that overlaps with computation
    /// (async TP / comm-overlap techniques; 0 = fully exposed).
    pub tp_overlap: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        // tp_overlap 0.75: Megatron-style sequence-parallel + async TP
        // collectives hide most of the allreduce behind the GEMMs (the
        // paper reports 87% per-GPU utilization at NVL32/32K, which
        // requires most TP comm to be hidden).
        SimParams { base_eff: 0.85, virtual_stages: 4, tp_overlap: 0.75 }
    }
}

/// Iteration-time breakdown (seconds). `compute` is pure math; the comm
/// terms are *exposed* (non-overlapped) times.
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    pub compute: f64,
    pub tp_comm: f64,
    pub pp_bubble: f64,
    pub pp_p2p: f64,
    pub dp_exposed: f64,
    /// NTP overheads: exposed reshard + allreduce-volume increase.
    pub ntp_overhead: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.compute + self.tp_comm + self.pp_bubble + self.pp_p2p + self.dp_exposed
            + self.ntp_overhead
    }

    /// Model-FLOPs utilization proxy: compute / total.
    pub fn utilization(&self) -> f64 {
        if self.total() <= 0.0 {
            return 0.0;
        }
        self.compute / self.total()
    }
}

/// Fig. 8 exposure law, shared by the reduced-replica overhead model
/// ([`IterationModel::ntp_iteration`]) and the healthy-replica reshard
/// factor ([`super::engine::healthy_reshard_factor`]): the pre-sync
/// reshard overlaps the backward pass, and the exposed fraction grows
/// linearly in the reshard:backward ratio. Keeping it in one place
/// keeps the two overhead models consistent when the law is
/// recalibrated.
pub(crate) fn exposed_reshard_secs(t_reshard: f64, t_bwd: f64) -> f64 {
    let ratio = (t_reshard / t_bwd.max(1e-12)).min(1.0);
    t_reshard * (0.05 + 0.5 * ratio).min(1.0)
}

/// Memo of healthy-iteration breakdowns keyed on the parallel config
/// (the only variable input once the model/workload/cluster triple is
/// fixed). `evaluate_group`, `StrategyTable::build` and the planner all
/// re-derive the same healthy baseline in loops; this makes repeats a
/// hash lookup.
#[derive(Default)]
struct HealthyMemo {
    inner: Mutex<HashMap<(usize, usize, usize, usize), Breakdown>>,
}

impl fmt::Debug for HealthyMemo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "HealthyMemo(len={})", self.inner.lock().unwrap().len())
    }
}

/// The iteration model for one (model, workload, cluster) triple.
///
/// Holds two process-lifetime caches: NTP shard-map/reshard plans keyed
/// on `(k, n1, n2)` and healthy iteration breakdowns keyed on the
/// parallel config. The healthy memo assumes the public config fields
/// are not mutated after construction; `Clone` therefore hands the
/// clone a *fresh* healthy memo, so the clone-then-tweak sweep pattern
/// stays correct. The plan cache is shared across clones — its key
/// fully determines the value regardless of any config field.
#[derive(Debug)]
pub struct IterationModel {
    pub model: ModelConfig,
    pub work: WorkloadConfig,
    pub cluster: ClusterConfig,
    pub params: SimParams,
    plans: Arc<PlanCache>,
    healthy_memo: Arc<HealthyMemo>,
}

impl Clone for IterationModel {
    fn clone(&self) -> IterationModel {
        IterationModel {
            model: self.model.clone(),
            work: self.work.clone(),
            cluster: self.cluster.clone(),
            params: self.params,
            // Safe to share: keyed on (k, n1, n2) alone.
            plans: Arc::clone(&self.plans),
            // NOT safe to share: keyed on ParallelConfig only, so a
            // clone whose model/work/cluster fields get tweaked must
            // not see the original's memoized breakdowns.
            healthy_memo: Arc::new(HealthyMemo::default()),
        }
    }
}

impl IterationModel {
    pub fn new(
        model: ModelConfig,
        work: WorkloadConfig,
        cluster: ClusterConfig,
        params: SimParams,
    ) -> IterationModel {
        IterationModel {
            model,
            work,
            cluster,
            params,
            plans: Arc::new(PlanCache::new()),
            healthy_memo: Arc::new(HealthyMemo::default()),
        }
    }

    /// The NTP plan cache backing [`IterationModel::ntp_iteration`]
    /// (exposed for the perf benches and for sharing with a training
    /// driver).
    pub fn plan_cache(&self) -> &PlanCache {
        &self.plans
    }

    fn nvlink(&self) -> Link {
        Link::nvlink(self.cluster.gpu.nvlink_gbs)
    }

    fn ib(&self) -> Link {
        Link::infiniband(self.cluster.gpu.ib_gbs)
    }

    /// Evaluate one DP replica's iteration time at TP degree `cfg.tp`
    /// (which may be a reduced NTP degree), local batch `local_batch`
    /// samples, with GPUs running at `perf_factor` × nominal speed.
    ///
    /// Returns the breakdown. `cfg.dp` only affects the DP-allreduce
    /// term; the pipeline/compute terms are per-replica.
    pub fn replica_iteration(
        &self,
        cfg: &ParallelConfig,
        local_batch: usize,
        perf_factor: f64,
    ) -> Breakdown {
        let m = (local_batch / cfg.microbatch).max(1);
        let seq = self.work.seq_len;
        let mb = cfg.microbatch;
        let dtype = self.work.dtype;
        let layers_per_stage = cfg.layers_per_stage(&self.model);

        // --- per-microbatch, per-stage compute ---
        let t_layer_f = compute::layer_fwd_time(
            &self.model,
            &self.cluster.gpu,
            dtype,
            seq,
            mb,
            cfg.tp,
            self.params.base_eff,
            perf_factor,
        );
        let t_fwd_comp = t_layer_f * layers_per_stage as f64;
        let t_bwd_comp = 2.0 * t_fwd_comp;

        // --- TP collectives: 2 allreduces fwd + 2 bwd per layer over the
        // activation tensor [mb, seq, hidden] on the scale-up fabric ---
        let act_bytes = (mb * seq * self.model.hidden * dtype.bytes()) as f64;
        let t_ar = comm::allreduce(&self.nvlink(), cfg.tp, act_bytes);
        let tp_exposed_per_layer = 4.0 * t_ar * (1.0 - self.params.tp_overlap);
        let t_tp_stage = tp_exposed_per_layer * layers_per_stage as f64;
        // fwd carries 2 of the 4 allreduces
        let t_fwd = t_fwd_comp + 0.5 * t_tp_stage;
        let t_bwd = t_bwd_comp + 0.5 * t_tp_stage;

        // --- PP p2p: activation [mb, seq, hidden] split over tp NICs ---
        let p2p_bytes = act_bytes / cfg.tp as f64;
        let t_p2p = comm::p2p(&self.ib(), p2p_bytes);

        let v = self.params.virtual_stages.min(layers_per_stage).max(1);
        let pipe = PipelineTiming { t_fwd, t_bwd, t_p2p, pp: cfg.pp, m, v };

        // --- DP gradient allreduce (bf16 grads) over IB, overlapped with
        // the pipeline cooldown ---
        let grad_bytes =
            self.model.params() as f64 / (cfg.tp * cfg.pp) as f64 * 2.0;
        let t_dp = comm::allreduce(&self.ib(), cfg.dp, grad_bytes);
        let dp_exposed = (t_dp - pipe.dp_overlap_window()).max(0.0);

        let compute_total = m as f64 * (t_fwd_comp + t_bwd_comp);
        Breakdown {
            compute: compute_total,
            tp_comm: m as f64 * t_tp_stage,
            pp_bubble: pipe.bubble_time(),
            pp_p2p: pipe.p2p_time(),
            dp_exposed,
            ntp_overhead: 0.0,
        }
    }

    /// Healthy-replica iteration for a full config (local batch from the
    /// workload's global batch). Memoized per parallel config — repeat
    /// calls (the `evaluate_group` / `StrategyTable` hot path) are a
    /// hash-map hit returning the identical `Breakdown`.
    pub fn healthy_iteration(&self, cfg: &ParallelConfig) -> Breakdown {
        let key = (cfg.tp, cfg.pp, cfg.dp, cfg.microbatch);
        if let Some(b) = self.healthy_memo.inner.lock().unwrap().get(&key) {
            return *b;
        }
        let local_batch = self.work.global_batch() / cfg.dp.max(1);
        let b = self.replica_iteration(cfg, local_batch.max(1), 1.0);
        self.healthy_memo.inner.lock().unwrap().insert(key, b);
        b
    }

    /// Fraction of the healthy iteration that scales with single-GPU
    /// speed — the lever a straggler pulls. Probes
    /// [`Self::replica_iteration`] at perf 1.0 and 0.5: compute-bound
    /// terms double at half speed while exposed-communication terms stay
    /// fixed, so `phi = (t(0.5) - t(1.0)) / t(1.0)` recovers the
    /// perf-sensitive share. A TP group paced by a member delivering
    /// slowdown-fraction `s` of nominal speed then runs at
    /// `1 / ((1 - phi) + phi / s)` of healthy throughput
    /// (exactly 1 at `s = 1`).
    pub fn perf_sensitive_fraction(&self, cfg: &ParallelConfig, local_batch: usize) -> f64 {
        let t1 = self.replica_iteration(cfg, local_batch, 1.0).total();
        if t1 <= 0.0 {
            return 0.0;
        }
        let t_half = self.replica_iteration(cfg, local_batch, 0.5).total();
        ((t_half - t1) / t1).clamp(0.0, 1.0)
    }

    /// Iteration of an NTP-reduced replica: TP degree `tp_reduced`,
    /// local batch `local_batch`, optional power boost, including the
    /// NTP synchronization overheads (§6.2):
    /// * pre-sync reshard — overlapped with backward, exposed fraction
    ///   grows with the reshard:compute ratio (Fig. 8's linear law);
    /// * allreduce volume increase — gradients sync over `tp_reduced`
    ///   instead of `tp_full` GPUs;
    /// * post-sync reshard — fully overlapped with the allreduce.
    pub fn ntp_iteration(
        &self,
        cfg_full: &ParallelConfig,
        tp_reduced: usize,
        local_batch: usize,
        perf_factor: f64,
    ) -> Breakdown {
        let cfg_red = ParallelConfig { tp: tp_reduced, ..*cfg_full };
        let mut b = self.replica_iteration(&cfg_red, local_batch, perf_factor);

        // NTP overheads only exist when the group is nonuniform.
        if tp_reduced < cfg_full.tp {
            // Algorithm-1 products are memoized per (k, n1, n2): this is
            // called in loops by `max_batch_within` / `StrategyTable`,
            // and the map is identical every time.
            let info = self.plans.get(self.model.ffn, cfg_full.tp, tp_reduced);
            // one unit = one (A column + B row) pair per layer, bf16
            let unit_bytes = 2 * self.model.hidden * 2;
            let reshard_bytes =
                (info.max_units_per_gpu * unit_bytes) as f64 * self.model.layers as f64
                    / cfg_full.pp as f64;
            let t_reshard = reshard_bytes / (self.cluster.gpu.nvlink_gbs * 1e9);
            let exposed_reshard = exposed_reshard_secs(t_reshard, 2.0 / 3.0 * b.compute);

            // allreduce volume increase on sync GPUs: n_full / n_reduced
            let grad_bytes = self.model.params() as f64
                / (cfg_full.tp * cfg_full.pp) as f64
                * 2.0
                * (cfg_full.tp as f64 / tp_reduced as f64 - 1.0);
            let extra_ar = comm::allreduce(&self.ib(), cfg_full.dp, grad_bytes);
            // mostly overlapped with the tail backward; expose 30%
            b.ntp_overhead = exposed_reshard + 0.3 * extra_ar;
        }
        b
    }

    /// Tokens/second/GPU for a healthy config — the y-axis of Fig. 2.
    pub fn tokens_per_sec_per_gpu(&self, cfg: &ParallelConfig) -> f64 {
        let b = self.healthy_iteration(cfg);
        let tokens = self.work.minibatch_tokens as f64;
        tokens / b.total() / cfg.n_gpus() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Dtype};

    fn setup() -> IterationModel {
        IterationModel::new(
            presets::model("gpt-480b").unwrap(),
            WorkloadConfig {
                seq_len: 8192,
                minibatch_tokens: 16 * 1024 * 1024,
                dtype: Dtype::BF16,
            },
            presets::cluster("paper-32k-nvl32").unwrap(),
            SimParams::default(),
        )
    }

    fn cfg32k() -> ParallelConfig {
        ParallelConfig { tp: 32, pp: 8, dp: 128, microbatch: 1 }
    }

    #[test]
    fn breakdown_is_positive_and_decomposes() {
        let m = setup();
        let b = m.healthy_iteration(&cfg32k());
        assert!(b.compute > 0.0);
        assert!(b.pp_bubble > 0.0);
        assert!(b.total() >= b.compute);
        assert!(b.utilization() > 0.3 && b.utilization() < 1.0, "util {}", b.utilization());
    }

    #[test]
    fn higher_tp_cuts_bubble_at_scale() {
        // The Fig. 2b mechanism: at 32K GPUs, capping TP forces more
        // PP/DP and a bigger bubble share.
        let m = setup();
        let tp32 = m.healthy_iteration(&cfg32k());
        let tp8 = m.healthy_iteration(&ParallelConfig { tp: 8, pp: 16, dp: 256, microbatch: 1 });
        let share32 = tp32.pp_bubble / tp32.total();
        let share8 = tp8.pp_bubble / tp8.total();
        assert!(share8 > share32, "bubble share tp8 {share8} vs tp32 {share32}");
    }

    #[test]
    fn reduced_tp_replica_is_slower_at_same_batch() {
        let m = setup();
        let full = m.healthy_iteration(&cfg32k());
        let red = m.ntp_iteration(&cfg32k(), 30, 16, 1.0);
        assert!(red.total() > full.total());
        assert!(red.ntp_overhead > 0.0);
    }

    #[test]
    fn reduced_batch_compensates() {
        // Paper Table 1: TP30 with local bs 7 (of 8) keeps the reduced
        // replica's iteration time within the healthy replicas'.
        let m = setup();
        let full_local = m.work.global_batch() / cfg32k().dp; // 16M tok / 16K seq / 128 dp... = 8? (global 2048 at 8K; here seq 8192 -> 2048/128 = 16)
        let full = m.healthy_iteration(&cfg32k());
        // bs scaled by ~ (30/32) / (1 + imbalance) -> ceil at 7/8 of full
        let reduced_bs = full_local * 7 / 8;
        let red = m.ntp_iteration(&cfg32k(), 30, reduced_bs, 1.0);
        assert!(
            red.total() <= full.total() * 1.02,
            "red {} vs full {}",
            red.total(),
            full.total()
        );
    }

    #[test]
    fn power_boost_compensates_full_batch() {
        // Paper Table 1: TP28-PW at 1.3x power sustains full local batch.
        let m = setup();
        let full = m.healthy_iteration(&cfg32k());
        let boost = m.cluster.gpu.perf_at_power(1.3);
        let red = m.ntp_iteration(&cfg32k(), 28, 16, boost);
        assert!(
            red.total() <= full.total() * 1.05,
            "red {} vs full {}",
            red.total(),
            full.total()
        );
    }

    #[test]
    fn uniform_ntp_iteration_has_no_overhead() {
        let m = setup();
        let b = m.ntp_iteration(&cfg32k(), 32, 16, 1.0);
        assert_eq!(b.ntp_overhead, 0.0);
    }

    #[test]
    fn plan_cache_populates_and_results_are_stable() {
        let m = setup();
        assert!(m.plan_cache().is_empty());
        let a = m.ntp_iteration(&cfg32k(), 30, 14, 1.0);
        assert_eq!(m.plan_cache().len(), 1);
        // repeat calls hit the cache and reproduce bit-identical totals
        let b = m.ntp_iteration(&cfg32k(), 30, 14, 1.0);
        assert_eq!(a.total(), b.total());
        assert_eq!(a.ntp_overhead, b.ntp_overhead);
        m.ntp_iteration(&cfg32k(), 28, 14, 1.0);
        assert_eq!(m.plan_cache().len(), 2);
        // clones share the cache
        let clone = m.clone();
        clone.ntp_iteration(&cfg32k(), 30, 7, 1.0);
        assert_eq!(m.plan_cache().len(), 2);
    }

    #[test]
    fn cloned_model_with_tweaked_config_is_not_served_stale_memos() {
        let m = setup();
        let cfg = cfg32k();
        let base = m.healthy_iteration(&cfg).total();
        let mut heavier = m.clone();
        heavier.work.minibatch_tokens *= 2;
        let doubled = heavier.healthy_iteration(&cfg).total();
        assert!(
            doubled > base * 1.5,
            "clone must recompute, not reuse the original's memo ({doubled} vs {base})"
        );
    }

    #[test]
    fn healthy_iteration_memo_is_transparent() {
        let m = setup();
        let cfg = cfg32k();
        let first = m.healthy_iteration(&cfg);
        let second = m.healthy_iteration(&cfg);
        assert_eq!(first.total(), second.total());
        assert_eq!(first.compute, second.compute);
        // distinct configs get distinct entries
        let other = ParallelConfig { tp: 8, pp: 16, dp: 256, microbatch: 1 };
        let b = m.healthy_iteration(&other);
        assert!(b.total() != first.total());
    }

    #[test]
    fn tokens_per_sec_sane_range() {
        let m = setup();
        let tps = m.tokens_per_sec_per_gpu(&cfg32k());
        // B200 ~2.2 PFLOP/s bf16; 480B model needs ~2.9 TFLOPs/token.
        // Perfect world ≈ 700 tok/s/GPU; expect 30–90% of that.
        assert!(tps > 200.0 && tps < 700.0, "tokens/s/gpu {tps}");
    }
}
