//! Failure-response evaluation: given a failure scenario (healthy GPUs
//! per domain) and a fault-tolerance strategy, compute the DP group's
//! relative throughput — the quantity behind Figs. 6, 7 and 10.
//!
//! Job mapping: TP = scale-up domain size; each pipeline stage occupies
//! one domain, so a DP replica owns `pp` consecutive domains (rank order;
//! the resource manager may permute domains first to pack failures).

use super::iteration::{exposed_reshard_secs, IterationModel};
use crate::parallel::ParallelConfig;
use crate::power::{min_boost_for, BoostDecision, RackDesign};

/// Fault-tolerance strategy under comparison.
///
/// This enum is the *compat shim* over the pluggable policy layer: the
/// three variants are ported to [`crate::policy::FtPolicy`]
/// implementations (reach them via [`FtStrategy::policy`], defined in
/// `policy::legacy`), and new strategies are added as policies rather
/// than variants. `parse`/`name` remain the CLI/bench surface for the
/// legacy trio.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FtStrategy {
    /// Drop any DP replica containing a failed GPU (baseline).
    DpDrop,
    /// Nonuniform TP: reduced replicas continue at reduced local batch.
    Ntp,
    /// NTP + power boosting: reduced replicas keep full batch.
    NtpPw,
}

impl FtStrategy {
    pub fn name(self) -> &'static str {
        match self {
            FtStrategy::DpDrop => "DP-DROP",
            FtStrategy::Ntp => "NTP",
            FtStrategy::NtpPw => "NTP-PW",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<FtStrategy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dp-drop" | "dpdrop" | "drop" => FtStrategy::DpDrop,
            "ntp" => FtStrategy::Ntp,
            "ntp-pw" | "ntppw" | "pw" => FtStrategy::NtpPw,
            other => anyhow::bail!("unknown strategy '{other}'"),
        })
    }
}

/// Outcome for one DP group under one scenario.
#[derive(Clone, Debug)]
pub struct GroupOutcome {
    /// Relative throughput vs a fully healthy group (0..=1).
    pub throughput_frac: f64,
    /// Relative minibatch actually processed (0..=1).
    pub minibatch_frac: f64,
    /// Per-replica effective TP degrees.
    pub replica_tp: Vec<usize>,
    /// Per-replica local batch (samples).
    pub replica_batch: Vec<usize>,
    /// Per-replica power fraction (1.0 = nominal).
    pub replica_power: Vec<f64>,
    /// Replicas dropped entirely.
    pub dropped: usize,
}

impl GroupOutcome {
    /// Fraction of the group's GPU capacity doing no useful work.
    pub fn gpus_lost_frac(&self) -> f64 {
        1.0 - self.throughput_frac
    }
}

/// The lowest TP degree NTP reconfigures down to before giving the
/// replica up (the paper evaluates reductions of ≤ 12.5%: TP32→TP28;
/// deeper reductions hit attention-head imbalance and memory limits).
pub fn min_supported_tp(full_tp: usize) -> usize {
    (full_tp * 7).div_ceil(8)
}

/// Evaluate one DP group.
///
/// * `replica_tp_raw[r]` — lowest healthy-GPU count among replica `r`'s
///   domains (from the resource manager's assignment); `full_tp` when
///   the replica is untouched.
/// * `sim`/`cfg_full` — the iteration model and the healthy config.
pub fn evaluate_group(
    sim: &IterationModel,
    cfg_full: &ParallelConfig,
    replica_tp_raw: &[usize],
    strategy: FtStrategy,
    rack: &RackDesign,
) -> GroupOutcome {
    let full_tp = cfg_full.tp;
    let n_rep = replica_tp_raw.len();
    let full_local = (sim.work.global_batch() / cfg_full.dp.max(1)).max(1);
    // Hoisted out of the per-replica loop and memoized inside the model,
    // so scenario sweeps calling `evaluate_group` thousands of times pay
    // for the healthy baseline once.
    let healthy_time = sim.healthy_iteration(cfg_full).total();

    let mut replica_tp = Vec::with_capacity(n_rep);
    let mut replica_batch = Vec::with_capacity(n_rep);
    let mut replica_power = Vec::with_capacity(n_rep);
    let mut dropped = 0;

    for &tp_raw in replica_tp_raw {
        if tp_raw >= full_tp {
            replica_tp.push(full_tp);
            replica_batch.push(full_local);
            replica_power.push(1.0);
            continue;
        }
        let drop = |replica_tp: &mut Vec<usize>,
                    replica_batch: &mut Vec<usize>,
                    replica_power: &mut Vec<f64>,
                    dropped: &mut usize| {
            replica_tp.push(0);
            replica_batch.push(0);
            replica_power.push(0.0);
            *dropped += 1;
        };
        match strategy {
            FtStrategy::DpDrop => {
                drop(&mut replica_tp, &mut replica_batch, &mut replica_power, &mut dropped)
            }
            FtStrategy::Ntp | FtStrategy::NtpPw => {
                if tp_raw < min_supported_tp(full_tp) || tp_raw == 0 {
                    drop(
                        &mut replica_tp,
                        &mut replica_batch,
                        &mut replica_power,
                        &mut dropped,
                    );
                    continue;
                }
                if strategy == FtStrategy::NtpPw {
                    match min_boost_for(
                        sim,
                        cfg_full,
                        tp_raw,
                        full_local,
                        healthy_time,
                        rack,
                        &sim.cluster.gpu,
                    ) {
                        BoostDecision::NotNeeded => {
                            replica_tp.push(tp_raw);
                            replica_batch.push(full_local);
                            replica_power.push(1.0);
                            continue;
                        }
                        BoostDecision::Boost { power_frac } => {
                            replica_tp.push(tp_raw);
                            replica_batch.push(full_local);
                            replica_power.push(power_frac);
                            continue;
                        }
                        BoostDecision::Infeasible { max_power_frac } => {
                            // fall back to batch reduction at max boost
                            let perf = sim.cluster.gpu.perf_at_power(max_power_frac);
                            let bs = max_batch_within(
                                sim, cfg_full, tp_raw, full_local, healthy_time, perf,
                            );
                            replica_tp.push(tp_raw);
                            replica_batch.push(bs);
                            replica_power.push(max_power_frac);
                            continue;
                        }
                    }
                }
                // plain NTP: shrink local batch until it keeps up
                let bs =
                    max_batch_within(sim, cfg_full, tp_raw, full_local, healthy_time, 1.0);
                if bs == 0 {
                    drop(
                        &mut replica_tp,
                        &mut replica_batch,
                        &mut replica_power,
                        &mut dropped,
                    );
                } else {
                    replica_tp.push(tp_raw);
                    replica_batch.push(bs);
                    replica_power.push(1.0);
                }
            }
        }
    }

    // Healthy replicas in a nonuniform group pay the (<1%) reshard
    // overhead (§6.2); apply it to the whole group's rate. Modeled from
    // the CopyPlan traffic over the scale-up link (the former hard-coded
    // 0.995 is pinned as an upper bound on this in the policy tests).
    let nonuniform = replica_tp.iter().any(|&t| t != 0 && t != full_tp);
    let overhead = if nonuniform { healthy_reshard_factor(sim, cfg_full) } else { 1.0 };

    let processed: usize = replica_batch.iter().sum();
    let capacity = full_local * n_rep;
    let minibatch_frac = processed as f64 / capacity as f64;
    let throughput_frac = minibatch_frac * overhead;

    GroupOutcome {
        throughput_frac,
        minibatch_frac,
        replica_tp,
        replica_batch,
        replica_power,
        dropped,
    }
}

/// Relative-throughput factor healthy replicas keep in a *nonuniform*
/// group: every iteration they reshard gradients to the reduced sync
/// layout and back, so a sliver of iteration time goes to data movement
/// instead of training. Derived from the coalesced
/// [`crate::ntp::CopyPlan`] traffic — busiest-GPU moved bytes for the
/// deepest supported reduction (`full_tp` → `min_supported_tp`), pre- +
/// post-sync, per pipeline stage — over the scale-up link, with the same
/// Fig. 8 exposure law as [`IterationModel::ntp_iteration`] (the reshard
/// overlaps the backward pass). Replaces the former hard-coded `0.995`;
/// the policy-conformance tests pin the old constant as an approximation
/// bound (modeled overhead ≤ 0.5%, factor in `[0.995, 1)` for the paper
/// config).
pub fn healthy_reshard_factor(sim: &IterationModel, cfg_full: &ParallelConfig) -> f64 {
    let full_tp = cfg_full.tp;
    let n2 = min_supported_tp(full_tp);
    if n2 >= full_tp {
        return 1.0;
    }
    let info = sim.plan_cache().get(sim.model.ffn, full_tp, n2);
    let unit_bytes = 2 * sim.model.hidden * 2;
    let bytes = 2.0
        * (info.copy.max_moved_units_per_shard() * unit_bytes) as f64
        * sim.model.layers as f64
        / cfg_full.pp as f64;
    let t_reshard = bytes / (sim.cluster.gpu.nvlink_gbs * 1e9);
    let healthy = sim.healthy_iteration(cfg_full);
    let total = healthy.total();
    if total <= 0.0 {
        return 1.0;
    }
    let exposed = exposed_reshard_secs(t_reshard, 2.0 / 3.0 * healthy.compute);
    (total / (total + exposed)).min(1.0)
}

/// Largest local batch (≤ `full_local`) the reduced replica can process
/// within `target_secs`.
///
/// A 0.5% tolerance is applied: the paper's own Table 1 accepts reduced
/// replicas at relative iteration times of 1.002–1.003 (bulk-synchronous
/// jitter absorbs sub-percent skew).
///
/// Iteration time is monotone nondecreasing in the batch size (compute,
/// TP volume and pipeline depth all scale with the microbatch count), so
/// the feasible set is a prefix `1..=b*` and binary search finds the
/// same answer as the previous descending linear scan in O(log
/// full_local) model evaluations instead of O(full_local).
pub fn max_batch_within(
    sim: &IterationModel,
    cfg_full: &ParallelConfig,
    tp_reduced: usize,
    full_local: usize,
    target_secs: f64,
    perf: f64,
) -> usize {
    let budget = target_secs * 1.005;
    let fits =
        |bs: usize| sim.ntp_iteration(cfg_full, tp_reduced, bs, perf).total() <= budget;
    if full_local == 0 || !fits(1) {
        return 0;
    }
    if fits(full_local) {
        return full_local;
    }
    // Invariant: fits(lo) && !fits(hi).
    let mut lo = 1usize;
    let mut hi = full_local;
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Dtype, WorkloadConfig};
    use crate::sim::SimParams;

    fn sim() -> IterationModel {
        IterationModel::new(
            presets::model("gpt-480b").unwrap(),
            WorkloadConfig {
                seq_len: 16_384,
                minibatch_tokens: 16 * 1024 * 1024,
                dtype: Dtype::BF16,
            },
            presets::cluster("paper-32k-nvl32").unwrap(),
            SimParams::default(),
        )
    }

    fn cfg() -> ParallelConfig {
        ParallelConfig { tp: 32, pp: 8, dp: 128, microbatch: 1 }
    }

    #[test]
    fn healthy_group_is_lossless() {
        let s = sim();
        let tps = vec![32; 8];
        for strat in [FtStrategy::DpDrop, FtStrategy::Ntp, FtStrategy::NtpPw] {
            let o = evaluate_group(&s, &cfg(), &tps, strat, &RackDesign::default());
            assert!((o.throughput_frac - 1.0).abs() < 1e-12, "{strat:?}");
            assert_eq!(o.dropped, 0);
        }
    }

    #[test]
    fn dp_drop_loses_whole_replica() {
        let s = sim();
        let tps = vec![32, 32, 31, 32]; // one failed GPU in replica 2
        let o = evaluate_group(&s, &cfg(), &tps, FtStrategy::DpDrop, &RackDesign::default());
        assert_eq!(o.dropped, 1);
        assert!((o.throughput_frac - 0.75).abs() < 1e-9);
    }

    #[test]
    fn ntp_beats_dp_drop() {
        let s = sim();
        let tps = vec![32, 30, 32, 31];
        let c = cfg();
        let rack = RackDesign::default();
        let drop = evaluate_group(&s, &c, &tps, FtStrategy::DpDrop, &rack);
        let ntp = evaluate_group(&s, &c, &tps, FtStrategy::Ntp, &rack);
        assert!(ntp.throughput_frac > drop.throughput_frac + 0.2);
        // NTP loss should be near the failed-GPU fraction (3/128 here)
        assert!(ntp.gpus_lost_frac() < 0.10, "lost {}", ntp.gpus_lost_frac());
        assert_eq!(ntp.dropped, 0);
    }

    #[test]
    fn ntp_pw_nearly_eliminates_loss() {
        let s = sim();
        let tps = vec![32, 30, 32, 32, 31, 32, 32, 32];
        let c = cfg();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let pw = evaluate_group(&s, &c, &tps, FtStrategy::NtpPw, &rack);
        assert!(pw.gpus_lost_frac() < 0.01, "lost {}", pw.gpus_lost_frac());
        // boosted replicas run above nominal power
        assert!(pw.replica_power.iter().any(|&p| p > 1.0));
        // full minibatch maintained
        assert!((pw.minibatch_frac - 1.0).abs() < 1e-12);
    }

    #[test]
    fn deep_reduction_falls_back_to_drop() {
        let s = sim();
        let tps = vec![32, 16]; // half the domain dead: below min TP
        let o = evaluate_group(&s, &cfg(), &tps, FtStrategy::Ntp, &RackDesign::default());
        assert_eq!(o.dropped, 1);
    }

    /// Reference descending scan the binary search replaced.
    fn max_batch_linear(
        sim: &IterationModel,
        cfg_full: &ParallelConfig,
        tp_reduced: usize,
        full_local: usize,
        target_secs: f64,
        perf: f64,
    ) -> usize {
        let budget = target_secs * 1.005;
        for bs in (1..=full_local).rev() {
            if sim.ntp_iteration(cfg_full, tp_reduced, bs, perf).total() <= budget {
                return bs;
            }
        }
        0
    }

    #[test]
    fn binary_search_batch_matches_linear_scan() {
        let s = sim();
        let c = cfg();
        let full_local = (s.work.global_batch() / c.dp).max(1);
        let healthy = s.healthy_iteration(&c).total();
        for tp in [28usize, 29, 30, 31] {
            for perf in [0.9, 1.0, 1.1] {
                let fast = max_batch_within(&s, &c, tp, full_local, healthy, perf);
                let slow = max_batch_linear(&s, &c, tp, full_local, healthy, perf);
                assert_eq!(fast, slow, "tp={tp} perf={perf}");
            }
        }
        // degenerate budgets
        assert_eq!(max_batch_within(&s, &c, 28, full_local, 0.0, 1.0), 0);
        assert_eq!(max_batch_within(&s, &c, 28, 0, healthy, 1.0), 0);
    }

    #[test]
    fn iteration_time_monotone_in_batch() {
        // The monotonicity assumption behind the binary search.
        let s = sim();
        let c = cfg();
        let full_local = (s.work.global_batch() / c.dp).max(1);
        for tp in [28usize, 30] {
            let mut prev = 0.0;
            for bs in 1..=full_local {
                let t = s.ntp_iteration(&c, tp, bs, 1.0).total();
                assert!(t >= prev, "tp={tp} bs={bs}: {t} < {prev}");
                prev = t;
            }
        }
    }

    #[test]
    fn healthy_reshard_factor_pins_old_constant() {
        let s = sim();
        let c = cfg();
        let f = healthy_reshard_factor(&s, &c);
        // The retired hard-coded 0.995 is an approximation bound for the
        // modeled factor: overhead stays below 0.5% for the paper config.
        assert!((0.995..1.0).contains(&f), "factor {f}");
        // trivial TP (nothing to reduce) pays nothing
        let c1 = ParallelConfig { tp: 1, pp: 8, dp: 128, microbatch: 1 };
        assert_eq!(healthy_reshard_factor(&s, &c1), 1.0);
    }

    #[test]
    fn min_supported_tp_is_7_8ths() {
        assert_eq!(min_supported_tp(32), 28);
        assert_eq!(min_supported_tp(8), 7);
        assert_eq!(min_supported_tp(64), 56);
        assert_eq!(min_supported_tp(72), 63);
    }

    #[test]
    fn ntp_reduced_batch_proportionality() {
        // Paper Table 1: TP30 -> local bs 7 (of 8); TP28 -> 6.
        let s = sim();
        let c = cfg();
        let o = evaluate_group(
            &s,
            &c,
            &[32, 30, 28],
            FtStrategy::Ntp,
            &RackDesign::default(),
        );
        let full = s.work.global_batch() / c.dp; // 8
        assert_eq!(o.replica_batch[0], full);
        assert!(o.replica_batch[1] < full && o.replica_batch[1] >= full * 30 / 32 - 1);
        assert!(o.replica_batch[2] <= o.replica_batch[1]);
        assert!(o.replica_batch[2] >= full * 28 / 32 - 1);
    }
}
