//! Performance simulator (paper §4.2): an analytical model of large-scale
//! LLM training — per-GPU compute, collective communication, the 1F1B
//! pipeline schedule, compute/comm overlap, and power — detailed enough
//! to reproduce the *shape* of every large-scale result in the paper
//! (Figs. 2, 6, 7, 10, 14; Table 1). Fidelity against real execution is
//! checked in Fig. 11 ([`calibrate`] fits the CPU-host GpuSpec to
//! measured PJRT runs, then predicted-vs-measured correlation is
//! reported).

pub mod calibrate;
pub mod comm;
pub mod compute;
pub mod engine;
pub mod iteration;
pub mod pipeline;

pub use engine::{evaluate_group, FtStrategy, GroupOutcome};
pub use iteration::{Breakdown, IterationModel, SimParams};
