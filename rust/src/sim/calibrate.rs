//! Simulator calibration against real execution (paper §6.3 / Fig. 11).
//!
//! The paper validates its simulator by correlating projected throughput
//! with measurements on DGX-H100s across workloads and power caps. Our
//! testbed is the CPU PJRT backend, so we do the same methodology at CPU
//! scale: run real training steps through `runtime`, fit the `cpu-host`
//! GpuSpec's effective FLOP/s (and the power curve is exercised
//! analytically), then report predicted-vs-measured correlation.

use crate::util::stats;

/// A calibration data point: work in FLOPs, measured wall time.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub flops: f64,
    pub secs: f64,
    /// Label for reports (model/seq/tp).
    pub id: usize,
}

/// Result of fitting `secs ≈ flops / eff_flops + overhead`.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Effective FLOP/s of the backend.
    pub eff_flops: f64,
    /// Fixed per-step overhead (dispatch, host work), seconds.
    pub overhead_secs: f64,
    /// Pearson r between measured and fitted times.
    pub r: f64,
}

/// Least-squares fit of time vs flops.
pub fn fit(measurements: &[Measurement]) -> Calibration {
    assert!(measurements.len() >= 2, "need >= 2 calibration points");
    let xs: Vec<f64> = measurements.iter().map(|m| m.flops).collect();
    let ys: Vec<f64> = measurements.iter().map(|m| m.secs).collect();
    let (intercept, slope) = stats::linear_fit(&xs, &ys);
    let r = stats::pearson_r(&xs, &ys);
    Calibration {
        eff_flops: if slope > 0.0 { 1.0 / slope } else { f64::INFINITY },
        overhead_secs: intercept.max(0.0),
        r,
    }
}

/// Predict a step time under a calibration.
pub fn predict(cal: &Calibration, flops: f64) -> f64 {
    flops / cal.eff_flops + cal.overhead_secs
}

/// Predicted-vs-measured correlation for held-out points.
pub fn validation_r(cal: &Calibration, held_out: &[Measurement]) -> f64 {
    let predicted: Vec<f64> = held_out.iter().map(|m| predict(cal, m.flops)).collect();
    let measured: Vec<f64> = held_out.iter().map(|m| m.secs).collect();
    stats::pearson_r(&predicted, &measured)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn synth(n: usize, eff: f64, overhead: f64, noise: f64, seed: u64) -> Vec<Measurement> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|id| {
                let flops = 1e9 * (1.0 + rng.f64() * 50.0);
                let secs = flops / eff + overhead + rng.normal() * noise;
                Measurement { flops, secs: secs.max(1e-6), id }
            })
            .collect()
    }

    #[test]
    fn recovers_known_parameters() {
        let ms = synth(50, 5e10, 0.01, 0.0, 1);
        let cal = fit(&ms);
        assert!((cal.eff_flops / 5e10 - 1.0).abs() < 1e-6);
        assert!((cal.overhead_secs - 0.01).abs() < 1e-6);
        assert!(cal.r > 0.9999);
    }

    #[test]
    fn noisy_fit_still_correlates() {
        let ms = synth(100, 5e10, 0.01, 0.02, 2);
        let cal = fit(&ms);
        assert!(cal.r > 0.95, "r = {}", cal.r);
        let held = synth(30, 5e10, 0.01, 0.02, 3);
        assert!(validation_r(&cal, &held) > 0.95);
    }

    #[test]
    fn predict_is_linear() {
        let cal = Calibration { eff_flops: 1e9, overhead_secs: 0.5, r: 1.0 };
        assert!((predict(&cal, 1e9) - 1.5).abs() < 1e-12);
        assert!((predict(&cal, 2e9) - 2.5).abs() < 1e-12);
    }
}
