//! 1F1B pipeline schedule timing (with Megatron-style interleaved
//! virtual stages): steady-state cost, bubble ratio, and the exposure
//! window available for overlapping the DP gradient allreduce.

/// Timing of one pipeline iteration.
#[derive(Clone, Copy, Debug)]
pub struct PipelineTiming {
    /// Per-microbatch forward time of one stage (seconds).
    pub t_fwd: f64,
    /// Per-microbatch backward time of one stage.
    pub t_bwd: f64,
    /// Per-boundary p2p activation transfer time.
    pub t_p2p: f64,
    pub pp: usize,
    /// Microbatches per iteration.
    pub m: usize,
    /// Interleaving factor (virtual pipeline stages per GPU); 1 = plain
    /// 1F1B. Interleaving divides the bubble by `v` at the cost of `v×`
    /// more p2p boundaries.
    pub v: usize,
}

impl PipelineTiming {
    /// Pipeline bubble ratio: fraction of the iteration the average GPU
    /// is idle waiting for the pipeline: `(pp-1) / (v·m + pp - 1)`.
    pub fn bubble_ratio(&self) -> f64 {
        if self.pp <= 1 {
            return 0.0;
        }
        let ppf = self.pp as f64;
        (ppf - 1.0) / (self.v as f64 * self.m as f64 + ppf - 1.0)
    }

    /// Active (non-bubble) time: every stage processes `m` microbatches.
    pub fn active_time(&self) -> f64 {
        self.m as f64 * (self.t_fwd + self.t_bwd)
    }

    /// Bubble time implied by the ratio.
    pub fn bubble_time(&self) -> f64 {
        let r = self.bubble_ratio();
        self.active_time() * r / (1.0 - r)
    }

    /// Total p2p transfer time on the critical path: the pipeline fill
    /// traverses `pp-1` boundaries (×`v` interleave rounds); steady-state
    /// p2p overlaps with compute.
    pub fn p2p_time(&self) -> f64 {
        if self.pp <= 1 {
            return 0.0;
        }
        (self.pp - 1) as f64 * self.v as f64 * 2.0 * self.t_p2p
    }

    /// End-to-end pipeline time (before DP sync).
    pub fn total_time(&self) -> f64 {
        self.active_time() + self.bubble_time() + self.p2p_time()
    }

    /// Window at the tail of the iteration during which DP allreduce can
    /// overlap with remaining backward work: roughly the cooldown phase,
    /// `(pp-1)/v + 1` microbatches of backward plus the final stage's
    /// backward stream.
    pub fn dp_overlap_window(&self) -> f64 {
        let tail_ubatches = ((self.pp - 1) as f64 / self.v as f64 + 1.0)
            .min(self.m as f64);
        tail_ubatches * self.t_bwd
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> PipelineTiming {
        PipelineTiming { t_fwd: 0.01, t_bwd: 0.02, t_p2p: 1e-4, pp: 8, m: 32, v: 1 }
    }

    #[test]
    fn bubble_formula() {
        let p = base();
        assert!((p.bubble_ratio() - 7.0 / 39.0).abs() < 1e-12);
        // no pipeline, no bubble
        let p1 = PipelineTiming { pp: 1, ..base() };
        assert_eq!(p1.bubble_ratio(), 0.0);
    }

    #[test]
    fn interleaving_shrinks_bubble() {
        let p1 = base();
        let p4 = PipelineTiming { v: 4, ..base() };
        assert!(p4.bubble_ratio() < p1.bubble_ratio() / 2.0);
        // ... but adds p2p
        assert!(p4.p2p_time() > p1.p2p_time());
    }

    #[test]
    fn more_microbatches_shrink_bubble() {
        let few = PipelineTiming { m: 8, ..base() };
        let many = PipelineTiming { m: 128, ..base() };
        assert!(many.bubble_ratio() < few.bubble_ratio());
    }

    #[test]
    fn total_decomposes() {
        let p = base();
        let total = p.total_time();
        assert!(
            (total - (p.active_time() + p.bubble_time() + p.p2p_time())).abs() < 1e-12
        );
        assert!(total > p.active_time());
    }

    #[test]
    fn bubble_time_consistent_with_ratio() {
        let p = base();
        let ratio = p.bubble_time() / (p.bubble_time() + p.active_time());
        assert!((ratio - p.bubble_ratio()).abs() < 1e-12);
    }

    #[test]
    fn overlap_window_bounded_by_iteration() {
        let p = base();
        assert!(p.dp_overlap_window() <= p.m as f64 * p.t_bwd);
        assert!(p.dp_overlap_window() > 0.0);
    }
}
