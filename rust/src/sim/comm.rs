//! Collective communication cost models (α–β style): ring allreduce,
//! reduce-scatter/all-gather, all-to-all and point-to-point, over either
//! the scale-up (NVLink-class) or scale-out (IB/Ethernet) fabric.

/// A link model: per-GPU unidirectional bandwidth and per-message latency.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// GB/s per GPU, unidirectional.
    pub gbs: f64,
    /// Per-hop latency, seconds.
    pub latency: f64,
}

impl Link {
    pub fn nvlink(gbs: f64) -> Link {
        Link { gbs, latency: 2.0e-6 }
    }

    pub fn infiniband(gbs: f64) -> Link {
        Link { gbs, latency: 6.0e-6 }
    }

    #[inline]
    fn bytes_time(&self, bytes: f64) -> f64 {
        bytes / (self.gbs * 1e9)
    }
}

/// Ring allreduce over `n` ranks of `bytes` per rank:
/// `2 (n-1)/n · bytes / bw + 2 (n-1) · α`.
pub fn allreduce(link: &Link, n: usize, bytes: f64) -> f64 {
    if n <= 1 || bytes <= 0.0 {
        return 0.0;
    }
    let nf = n as f64;
    2.0 * (nf - 1.0) / nf * link.bytes_time(bytes) + 2.0 * (nf - 1.0) * link.latency
}

/// Reduce-scatter (or all-gather): half an allreduce.
pub fn reduce_scatter(link: &Link, n: usize, bytes: f64) -> f64 {
    if n <= 1 || bytes <= 0.0 {
        return 0.0;
    }
    let nf = n as f64;
    (nf - 1.0) / nf * link.bytes_time(bytes) + (nf - 1.0) * link.latency
}

/// All-to-all where the busiest rank moves `max_bytes_per_gpu`:
/// bandwidth-bound on that rank plus fan-out latency.
pub fn all_to_all(link: &Link, n: usize, max_bytes_per_gpu: f64) -> f64 {
    if n <= 1 || max_bytes_per_gpu <= 0.0 {
        return 0.0;
    }
    link.bytes_time(max_bytes_per_gpu) + (n as f64 - 1.0) * link.latency
}

/// Point-to-point transfer.
pub fn p2p(link: &Link, bytes: f64) -> f64 {
    if bytes <= 0.0 {
        return 0.0;
    }
    link.bytes_time(bytes) + link.latency
}

/// Broadcast within a scale-up domain (tree): `log2(n)` hops.
pub fn broadcast(link: &Link, n: usize, bytes: f64) -> f64 {
    if n <= 1 || bytes <= 0.0 {
        return 0.0;
    }
    let hops = (n as f64).log2().ceil();
    link.bytes_time(bytes) + hops * link.latency
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_asymptotics() {
        let l = Link::nvlink(900.0);
        // Large message: approaches 2*bytes/bw.
        let bytes = 1e9;
        let t = allreduce(&l, 32, bytes);
        let ideal = 2.0 * bytes / (900.0 * 1e9);
        assert!(t > ideal && t < ideal * 1.2, "t={t} ideal={ideal}");
        // n=1 or empty is free.
        assert_eq!(allreduce(&l, 1, bytes), 0.0);
        assert_eq!(allreduce(&l, 8, 0.0), 0.0);
    }

    #[test]
    fn latency_dominates_small_messages() {
        let l = Link::nvlink(900.0);
        let t = allreduce(&l, 32, 1024.0);
        // 62 hops × 2µs ≈ 124µs >> bandwidth term (~2ns)
        assert!(t > 1.0e-4);
    }

    #[test]
    fn reduce_scatter_is_half_allreduce() {
        let l = Link::infiniband(100.0);
        let bytes = 1e8;
        let ar = allreduce(&l, 16, bytes);
        let rs = reduce_scatter(&l, 16, bytes);
        assert!((ar / rs - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bigger_rings_cost_more_latency() {
        let l = Link::nvlink(900.0);
        assert!(allreduce(&l, 64, 1e6) > allreduce(&l, 8, 1e6));
    }

    #[test]
    fn p2p_and_broadcast() {
        let l = Link::infiniband(50.0);
        assert!(p2p(&l, 1e9) > 0.019);
        let b = broadcast(&Link::nvlink(900.0), 32, 1e6);
        assert!(b > 0.0);
    }
}
