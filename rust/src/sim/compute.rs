//! Per-GPU compute-time model for transformer layers under TP sharding,
//! including the efficiency penalty of small local matmuls (high TP
//! shrinks the per-GPU GEMM shapes) and the imbalance penalty of
//! nonuniform shard widths (§3.1 "Attention blocks").

use crate::config::{Dtype, GpuSpec, ModelConfig};
use crate::ntp::partition;

/// Forward FLOPs for one token of one transformer layer, unsharded.
pub fn layer_fwd_flops(model: &ModelConfig, seq_len: usize) -> f64 {
    let h = model.hidden as f64;
    let ad = (model.heads * model.head_dim) as f64;
    let f = model.ffn as f64;
    // qkv + out-proj matmuls: 2*(3·h·ad) + 2*(ad·h)
    let attn_linear = 8.0 * h * ad;
    // attention scores + context: 2 matmuls of [s, ad] — 4·s·ad per token
    let attn_quad = 4.0 * seq_len as f64 * ad;
    // MLP: two matmuls h×f
    let mlp = 4.0 * h * f;
    attn_linear + attn_quad + mlp
}

/// GEMM efficiency model: fraction of peak achieved as a function of the
/// smallest local matmul dimension `d` (columns of the sharded weight).
/// Saturates at `base_eff` for large tiles, decays when TP slicing makes
/// the local GEMM skinny — the classic reason TP doesn't scale forever.
pub fn gemm_efficiency(base_eff: f64, local_dim: usize) -> f64 {
    let d = local_dim as f64;
    base_eff * d / (d + 96.0)
}

/// Compute time (seconds) for one microbatch of `mb_samples` through one
/// transformer layer's **forward**, sharded `tp`-ways on `gpu`.
///
/// `shard_units_max / shard_units_mean` captures nonuniform-TP imbalance:
/// the slowest (largest) shard gates the TP group.
pub fn layer_fwd_time(
    model: &ModelConfig,
    gpu: &GpuSpec,
    dtype: Dtype,
    seq_len: usize,
    mb_samples: usize,
    tp: usize,
    base_eff: f64,
    perf_factor: f64,
) -> f64 {
    let tokens = (seq_len * mb_samples) as f64;
    let total = layer_fwd_flops(model, seq_len);
    // Imbalance penalties are per sharded dimension, weighted by that
    // block's compute share: attention shards by head (coarse, O(10–100)
    // units — the §3.1 imbalance concern), MLP by ffn column (fine).
    let h = model.hidden as f64;
    let ad = (model.heads * model.head_dim) as f64;
    let attn_share = (8.0 * h * ad + 4.0 * seq_len as f64 * ad) / total;
    let mlp_share = 1.0 - attn_share;
    let head_imb = if model.heads >= tp {
        partition::imbalance(model.heads, tp)
    } else {
        0.0
    };
    let ffn_imb = if model.ffn >= tp { partition::imbalance(model.ffn, tp) } else { 0.0 };
    let imb = 1.0 + attn_share * head_imb + mlp_share * ffn_imb;
    let flops = total * tokens / tp as f64;
    let local_ffn_cols = model.ffn / tp;
    let eff = gemm_efficiency(base_eff, local_ffn_cols);
    flops * imb / (gpu.tflops(dtype) * 1e12 * eff * perf_factor)
}

/// Backward ≈ 2× forward.
pub fn layer_bwd_time(
    model: &ModelConfig,
    gpu: &GpuSpec,
    dtype: Dtype,
    seq_len: usize,
    mb_samples: usize,
    tp: usize,
    base_eff: f64,
    perf_factor: f64,
) -> f64 {
    2.0 * layer_fwd_time(model, gpu, dtype, seq_len, mb_samples, tp, base_eff, perf_factor)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn flops_scale_with_model() {
        let big = presets::model("gpt-480b").unwrap();
        let small = presets::model("gpt-8b").unwrap();
        assert!(layer_fwd_flops(&big, 8192) > 10.0 * layer_fwd_flops(&small, 8192));
    }

    #[test]
    fn time_inversely_proportional_to_tp() {
        let m = presets::model("gpt-480b").unwrap();
        let g = presets::gpu("b200").unwrap();
        let t8 = layer_fwd_time(&m, &g, Dtype::BF16, 8192, 1, 8, 0.85, 1.0);
        let t32 = layer_fwd_time(&m, &g, Dtype::BF16, 8192, 1, 32, 0.85, 1.0);
        // 4x more GPUs, but lower efficiency: speedup between 3x and 4x.
        let speedup = t8 / t32;
        assert!(speedup > 3.0 && speedup < 4.0, "speedup {speedup}");
    }

    #[test]
    fn perf_factor_scales_linearly() {
        let m = presets::model("gpt-175b").unwrap();
        let g = presets::gpu("h100").unwrap();
        let t1 = layer_fwd_time(&m, &g, Dtype::BF16, 4096, 2, 8, 0.85, 1.0);
        let t2 = layer_fwd_time(&m, &g, Dtype::BF16, 4096, 2, 8, 0.85, 1.1);
        assert!((t1 / t2 - 1.1).abs() < 1e-9);
    }

    #[test]
    fn imbalance_hurts_odd_tp() {
        let m = presets::model("gpt-480b").unwrap(); // 128 heads
        let g = presets::gpu("b200").unwrap();
        // TP30: heads split 5/4 -> ~17% imbalance; TP32 is exact.
        let t30 = layer_fwd_time(&m, &g, Dtype::BF16, 8192, 1, 30, 0.85, 1.0);
        let t32 = layer_fwd_time(&m, &g, Dtype::BF16, 8192, 1, 32, 0.85, 1.0);
        // per-GPU work at TP30 > (32/30)·TP32 work because of imbalance
        let ratio = t30 / t32;
        assert!(ratio > 32.0 / 30.0, "ratio {ratio}");
    }

    #[test]
    fn bwd_is_twice_fwd() {
        let m = presets::model("gpt-15b").unwrap();
        let g = presets::gpu("h100").unwrap();
        let f = layer_fwd_time(&m, &g, Dtype::FP8, 2048, 4, 8, 0.85, 1.0);
        let b = layer_bwd_time(&m, &g, Dtype::FP8, 2048, 4, 8, 0.85, 1.0);
        assert!((b / f - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fp8_faster_than_bf16() {
        let m = presets::model("gpt-15b").unwrap();
        let g = presets::gpu("h100").unwrap();
        let t_bf16 = layer_fwd_time(&m, &g, Dtype::BF16, 2048, 1, 8, 0.85, 1.0);
        let t_fp8 = layer_fwd_time(&m, &g, Dtype::FP8, 2048, 1, 8, 0.85, 1.0);
        assert!(t_fp8 < t_bf16);
    }

    #[test]
    fn gemm_efficiency_monotone() {
        assert!(gemm_efficiency(0.85, 4096) > gemm_efficiency(0.85, 256));
        assert!(gemm_efficiency(0.85, 256) > gemm_efficiency(0.85, 32));
        assert!(gemm_efficiency(0.85, 100_000) <= 0.85);
    }
}
