//! Training driver: real data-parallel training over the AOT-compiled
//! replica programs, with nonuniform TP — DP replicas at different TP
//! degrees, gradient resharding + weighted allreduce in Rust memory,
//! AdamW, and live failure-driven TP reconfiguration.

pub mod checkpoint;
pub mod data;
pub mod optimizer;
pub mod params;
pub mod replica;
pub mod sync;
pub mod trainer;

pub use optimizer::AdamW;
pub use replica::Replica;
pub use trainer::{Trainer, TrainerConfig};
