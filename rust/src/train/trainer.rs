//! The training loop: DP replicas (possibly at nonuniform TP degrees),
//! per-step gradient sync, AdamW, loss/throughput accounting, and live
//! failure injection with TP reconfiguration.

use super::data::Corpus;
use super::replica::Replica;
use super::sync::{sync_grads, SyncTiming};
use crate::runtime::Runtime;
use anyhow::Result;

/// Trainer configuration.
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub model: String,
    /// (tp, batch) per DP replica — `[(4,4),(3,4)]` is an NTP-PW-style
    /// group (reduced TP, full batch), `[(4,4),(3,3)]` plain NTP.
    pub replicas: Vec<(usize, usize)>,
    pub lr: f32,
    pub seed: u64,
}

/// Per-step record.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: u64,
    /// Batch-weighted mean loss across replicas.
    pub loss: f64,
    /// Wall time of the whole step, seconds.
    pub wall_secs: f64,
    /// PJRT execute time summed over replicas.
    pub execute_secs: f64,
    pub sync: SyncTiming,
    /// Tokens processed this step (all replicas).
    pub tokens: usize,
}

/// The DP training group.
pub struct Trainer {
    pub replicas: Vec<Replica>,
    corpora: Vec<Corpus>,
    pub history: Vec<StepRecord>,
    seq_len: usize,
    step: u64,
}

impl Trainer {
    pub fn new(rt: &Runtime, cfg: &TrainerConfig) -> Result<Trainer> {
        anyhow::ensure!(!cfg.replicas.is_empty(), "no replicas");
        let mut replicas = Vec::new();
        for &(tp, batch) in &cfg.replicas {
            replicas.push(Replica::new(rt, &cfg.model, tp, batch, cfg.lr, cfg.seed)?);
        }
        let seq_len = replicas[0].program.meta.seq_len;
        let vocab = replicas[0].program.meta.model.vocab;
        // independent data stream per replica (data parallelism)
        let corpora = (0..replicas.len())
            .map(|r| Corpus::new(vocab, cfg.seed ^ (0xD0 + r as u64)))
            .collect();
        Ok(Trainer { replicas, corpora, history: Vec::new(), seq_len, step: 0 })
    }

    /// Run one synchronized training step.
    pub fn step(&mut self) -> Result<StepRecord> {
        let t0 = std::time::Instant::now();
        let n_rep = self.replicas.len();
        let mut grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n_rep);
        let mut weights: Vec<f32> = Vec::with_capacity(n_rep);
        let mut loss_acc = 0.0f64;
        let mut execute_secs = 0.0;
        let mut tokens = 0usize;
        for r in 0..n_rep {
            let b = self.replicas[r].batch();
            let (toks, targs) = self.corpora[r].next_batch(b, self.seq_len);
            let out = self.replicas[r].step(&toks, &targs)?;
            loss_acc += out.loss as f64 * b as f64;
            weights.push(b as f32);
            tokens += b * self.seq_len;
            execute_secs += out.execute_secs;
            grads.push(out.grads);
        }
        let metas: Vec<_> = self.replicas.iter().map(|r| &r.program.meta).collect();
        let sync = sync_grads(&metas, &mut grads, &weights)?;
        for (r, g) in grads.iter().enumerate() {
            self.replicas[r].apply(g);
        }
        self.step += 1;
        let rec = StepRecord {
            step: self.step,
            loss: loss_acc / weights.iter().sum::<f32>() as f64,
            wall_secs: t0.elapsed().as_secs_f64(),
            execute_secs,
            sync,
            tokens,
        };
        self.history.push(rec);
        Ok(rec)
    }

    /// Run `n` steps; returns the last record.
    pub fn run(&mut self, n: usize) -> Result<StepRecord> {
        let mut last = None;
        for _ in 0..n {
            last = Some(self.step()?);
        }
        last.ok_or_else(|| anyhow::anyhow!("run(0)"))
    }

    /// Inject a failure into replica `r`: reconfigure it to `new_tp`
    /// (and `new_batch`), carrying parameters and optimizer state over —
    /// the live NTP response.
    pub fn inject_failure(
        &mut self,
        rt: &Runtime,
        r: usize,
        new_tp: usize,
        new_batch: usize,
    ) -> Result<()> {
        self.replicas[r].reconfigure(rt, new_tp, new_batch)
    }

    /// Loss curve as (step, loss) pairs.
    pub fn loss_curve(&self) -> Vec<(f64, f64)> {
        self.history.iter().map(|r| (r.step as f64, r.loss)).collect()
    }

    /// Tokens/second over the last `n` steps.
    pub fn tokens_per_sec(&self, n: usize) -> f64 {
        let tail = &self.history[self.history.len().saturating_sub(n)..];
        let tokens: usize = tail.iter().map(|r| r.tokens).sum();
        let secs: f64 = tail.iter().map(|r| r.wall_secs).sum();
        if secs > 0.0 {
            tokens as f64 / secs
        } else {
            0.0
        }
    }
}
