//! AdamW on flat f32 buffers (one moment pair per parameter tensor).
//!
//! The optimizer state is sharded exactly like the parameters, so a TP
//! reconfiguration gathers and re-slices `m`/`v` the same way it does
//! the weights (see `trainer::Trainer::reconfigure`).

use crate::util::par::{self, PAR_MIN_ELEMS};

/// The scalar AdamW recurrence over one tensor's slices.
#[allow(clippy::too_many_arguments)]
fn adamw_tensor(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    decay: f32,
    lr: f32,
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
) {
    assert_eq!(p.len(), g.len());
    for j in 0..p.len() {
        let gj = g[j];
        m[j] = b1 * m[j] + (1.0 - b1) * gj;
        v[j] = b2 * v[j] + (1.0 - b2) * gj * gj;
        let mhat = m[j] / bc1;
        let vhat = v[j] / bc2;
        p[j] -= lr * (mhat / (vhat.sqrt() + eps) + decay * p[j]);
    }
}

/// Runs the recurrence over a slice of per-tensor work items (the unit
/// handed to one worker thread).
#[allow(clippy::too_many_arguments, clippy::type_complexity)]
fn run_items(
    items: &mut [(&mut [f32], &[f32], &mut [f32], &mut [f32], f32)],
    lr: f32,
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
) {
    for w in items.iter_mut() {
        let decay = w.4;
        adamw_tensor(w.0, w.1, w.2, w.3, decay, lr, b1, b2, bc1, bc2, eps);
    }
}

/// AdamW hyperparameters + state.
#[derive(Clone, Debug)]
pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub step: u64,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl AdamW {
    pub fn new(lr: f32, params: &[Vec<f32>]) -> AdamW {
        AdamW {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            step: 0,
            m: params.iter().map(|p| vec![0.0; p.len()]).collect(),
            v: params.iter().map(|p| vec![0.0; p.len()]).collect(),
        }
    }

    /// One update step in place. `decay_mask[i] = false` exempts a tensor
    /// (norm scales/biases) from weight decay.
    ///
    /// Large updates fan out over scoped threads, one disjoint slice of
    /// tensors per worker. Tensors are updated independently, so the
    /// parallel result is bit-identical to the sequential one.
    pub fn update(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>], decay_mask: &[bool]) {
        let threads = par::num_threads();
        self.update_with_threads(params, grads, decay_mask, threads);
    }

    /// [`AdamW::update`] with an explicit worker count (1 = sequential;
    /// the perf benches compare the two).
    pub fn update_with_threads(
        &mut self,
        params: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        decay_mask: &[bool],
        threads: usize,
    ) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.step += 1;
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.step as i32);
        let bc2 = 1.0 - b2.powi(self.step as i32);
        let lr = self.lr;
        let eps = self.eps;
        let wd = self.weight_decay;
        let total: usize = params.iter().map(|p| p.len()).sum();

        // Per-tensor work items: (param, grad, m, v, decay).
        let mut work: Vec<(&mut [f32], &[f32], &mut [f32], &mut [f32], f32)> = params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut())
            .zip(self.v.iter_mut())
            .enumerate()
            .map(|(i, (((p, g), m), v))| {
                let decay = if decay_mask[i] { wd } else { 0.0 };
                (p.as_mut_slice(), g.as_slice(), m.as_mut_slice(), v.as_mut_slice(), decay)
            })
            .collect();

        if threads > 1 && work.len() > 1 && total >= PAR_MIN_ELEMS {
            // Balance chunks by element count, not tensor count — one
            // oversized tensor must not gate the whole fan-out.
            let weights: Vec<usize> = work.iter().map(|w| w.1.len()).collect();
            par::par_chunks_weighted_mut(&mut work, &weights, threads, |_off, chunk| {
                run_items(chunk, lr, b1, b2, bc1, bc2, eps)
            });
        } else {
            run_items(&mut work, lr, b1, b2, bc1, bc2, eps);
        }
    }

    /// Plain SGD fallback (used in a couple of tests for closed-form
    /// verification).
    pub fn sgd(params: &mut [Vec<f32>], grads: &[Vec<f32>], lr: f32) {
        for (p, g) in params.iter_mut().zip(grads) {
            for (pj, gj) in p.iter_mut().zip(g) {
                *pj -= lr * gj;
            }
        }
    }
}

/// Default decay mask from parameter names: no decay for norms/biases.
pub fn decay_mask_from_names<'a>(names: impl Iterator<Item = &'a str>) -> Vec<bool> {
    names
        .map(|n| !(n.ends_with(".scale") || n.ends_with(".bias")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_first_step_is_signed_lr() {
        // With zero init moments, step 1 moves each param by ~lr*sign(g).
        let mut params = vec![vec![1.0f32, -1.0]];
        let grads = vec![vec![0.5f32, -2.0]];
        let mut opt = AdamW::new(0.1, &params);
        opt.weight_decay = 0.0;
        opt.update(&mut params, &grads, &[true]);
        assert!((params[0][0] - (1.0 - 0.1)).abs() < 1e-4);
        assert!((params[0][1] - (-1.0 + 0.1)).abs() < 1e-4);
    }

    #[test]
    fn weight_decay_respects_mask() {
        let mut params = vec![vec![1.0f32], vec![1.0f32]];
        let grads = vec![vec![0.0f32], vec![0.0f32]];
        let mut opt = AdamW::new(0.1, &params);
        opt.update(&mut params, &grads, &[true, false]);
        assert!(params[0][0] < 1.0); // decayed
        assert_eq!(params[1][0], 1.0); // exempt
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize f(x) = (x-3)^2 with grad 2(x-3)
        let mut params = vec![vec![0.0f32]];
        let mut opt = AdamW::new(0.05, &params);
        opt.weight_decay = 0.0;
        for _ in 0..800 {
            let g = vec![vec![2.0 * (params[0][0] - 3.0)]];
            opt.update(&mut params, &g, &[true]);
        }
        assert!((params[0][0] - 3.0).abs() < 0.05, "x={}", params[0][0]);
    }

    #[test]
    fn parallel_update_is_bit_identical_to_sequential() {
        // Enough elements to clear PAR_MIN_ELEMS so the fan-out actually
        // runs.
        let n_tensors = 8;
        let len = (super::PAR_MIN_ELEMS / n_tensors) + 7;
        let mut rng = crate::util::prng::Rng::new(3);
        let params: Vec<Vec<f32>> = (0..n_tensors).map(|_| rng.normal_vec_f32(len, 0.1)).collect();
        let grads: Vec<Vec<f32>> =
            params.iter().map(|p| p.iter().map(|x| x * 0.3 + 0.01).collect()).collect();
        let mask: Vec<bool> = (0..n_tensors).map(|i| i % 2 == 0).collect();

        let mut p_seq = params.clone();
        let mut p_par = params;
        let mut opt_seq = AdamW::new(1e-3, &p_seq);
        let mut opt_par = AdamW::new(1e-3, &p_par);
        for _ in 0..3 {
            opt_seq.update_with_threads(&mut p_seq, &grads, &mask, 1);
            opt_par.update_with_threads(&mut p_par, &grads, &mask, 4);
        }
        assert_eq!(p_seq, p_par);
        assert_eq!(opt_seq.m, opt_par.m);
        assert_eq!(opt_seq.v, opt_par.v);
        assert_eq!(opt_seq.step, opt_par.step);
    }

    #[test]
    fn decay_mask_from_names_rules() {
        let mask = decay_mask_from_names(
            ["l0.ln1.scale", "l0.ln1.bias", "l0.mlp.wa.s0", "embed"].into_iter(),
        );
        assert_eq!(mask, vec![false, false, true, true]);
    }

    #[test]
    fn sgd_basic() {
        let mut p = vec![vec![1.0f32, 2.0]];
        AdamW::sgd(&mut p, &[vec![1.0, -1.0]], 0.5);
        assert_eq!(p[0], vec![0.5, 2.5]);
    }
}
