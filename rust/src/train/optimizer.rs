//! AdamW on flat f32 buffers (one moment pair per parameter tensor).
//!
//! The optimizer state is sharded exactly like the parameters, so a TP
//! reconfiguration gathers and re-slices `m`/`v` the same way it does
//! the weights (see `trainer::Trainer::reconfigure`).

/// AdamW hyperparameters + state.
#[derive(Clone, Debug)]
pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub step: u64,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl AdamW {
    pub fn new(lr: f32, params: &[Vec<f32>]) -> AdamW {
        AdamW {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            step: 0,
            m: params.iter().map(|p| vec![0.0; p.len()]).collect(),
            v: params.iter().map(|p| vec![0.0; p.len()]).collect(),
        }
    }

    /// One update step in place. `decay_mask[i] = false` exempts a tensor
    /// (norm scales/biases) from weight decay.
    pub fn update(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>], decay_mask: &[bool]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.step += 1;
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.step as i32);
        let bc2 = 1.0 - b2.powi(self.step as i32);
        let lr = self.lr;
        for i in 0..params.len() {
            let decay = if decay_mask[i] { self.weight_decay } else { 0.0 };
            let (p, g, m, v) = (
                &mut params[i][..],
                &grads[i][..],
                &mut self.m[i][..],
                &mut self.v[i][..],
            );
            assert_eq!(p.len(), g.len());
            for j in 0..p.len() {
                let gj = g[j];
                m[j] = b1 * m[j] + (1.0 - b1) * gj;
                v[j] = b2 * v[j] + (1.0 - b2) * gj * gj;
                let mhat = m[j] / bc1;
                let vhat = v[j] / bc2;
                p[j] -= lr * (mhat / (vhat.sqrt() + self.eps) + decay * p[j]);
            }
        }
    }

    /// Plain SGD fallback (used in a couple of tests for closed-form
    /// verification).
    pub fn sgd(params: &mut [Vec<f32>], grads: &[Vec<f32>], lr: f32) {
        for (p, g) in params.iter_mut().zip(grads) {
            for (pj, gj) in p.iter_mut().zip(g) {
                *pj -= lr * gj;
            }
        }
    }
}

/// Default decay mask from parameter names: no decay for norms/biases.
pub fn decay_mask_from_names<'a>(names: impl Iterator<Item = &'a str>) -> Vec<bool> {
    names
        .map(|n| !(n.ends_with(".scale") || n.ends_with(".bias")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_first_step_is_signed_lr() {
        // With zero init moments, step 1 moves each param by ~lr*sign(g).
        let mut params = vec![vec![1.0f32, -1.0]];
        let grads = vec![vec![0.5f32, -2.0]];
        let mut opt = AdamW::new(0.1, &params);
        opt.weight_decay = 0.0;
        opt.update(&mut params, &grads, &[true]);
        assert!((params[0][0] - (1.0 - 0.1)).abs() < 1e-4);
        assert!((params[0][1] - (-1.0 + 0.1)).abs() < 1e-4);
    }

    #[test]
    fn weight_decay_respects_mask() {
        let mut params = vec![vec![1.0f32], vec![1.0f32]];
        let grads = vec![vec![0.0f32], vec![0.0f32]];
        let mut opt = AdamW::new(0.1, &params);
        opt.update(&mut params, &grads, &[true, false]);
        assert!(params[0][0] < 1.0); // decayed
        assert_eq!(params[1][0], 1.0); // exempt
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize f(x) = (x-3)^2 with grad 2(x-3)
        let mut params = vec![vec![0.0f32]];
        let mut opt = AdamW::new(0.05, &params);
        opt.weight_decay = 0.0;
        for _ in 0..800 {
            let g = vec![vec![2.0 * (params[0][0] - 3.0)]];
            opt.update(&mut params, &g, &[true]);
        }
        assert!((params[0][0] - 3.0).abs() < 0.05, "x={}", params[0][0]);
    }

    #[test]
    fn decay_mask_from_names_rules() {
        let mask = decay_mask_from_names(
            ["l0.ln1.scale", "l0.ln1.bias", "l0.mlp.wa.s0", "embed"].into_iter(),
        );
        assert_eq!(mask, vec![false, false, true, true]);
    }

    #[test]
    fn sgd_basic() {
        let mut p = vec![vec![1.0f32, 2.0]];
        AdamW::sgd(&mut p, &[vec![1.0, -1.0]], 0.5);
        assert_eq!(p[0], vec![0.5, 2.5]);
    }
}
