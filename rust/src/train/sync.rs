//! Cross-replica gradient synchronization with nonuniform TP.
//!
//! Numerically this is: per parameter *group*, reshard every replica's
//! shards to the common sync sharding (contiguous over the minimum TP
//! degree), perform the 1:1 weighted allreduce, and reshard back
//! (paper Fig. 5). Because the sync sharding of a group is just a
//! different contiguous slicing of the same full tensor, the fused
//! implementation accumulates each replica's shards into one full-tensor
//! buffer (gather ≙ pre-sync reshard), averages (≙ allreduce), and
//! slices back out (≙ post-sync reshard) — bit-identical to the
//! explicit three-phase dance while touching each element once.
//!
//! Weights handle replicas running *different local batch sizes* (plain
//! NTP shrinks the reduced replica's batch): the correct global gradient
//! is the batch-size-weighted mean of per-replica mean-gradients.

use crate::runtime::ProgramMeta;
use crate::util::par::{self, PAR_MIN_ELEMS};
use anyhow::Result;
use std::time::Instant;

/// `dst[i] += w * src[i]`, fanned out over disjoint contiguous chunks
/// when the buffers are large. Element-independent, so the parallel
/// result is bit-identical to the sequential loop. Public so the perf
/// benches can compare explicit worker counts.
pub fn weighted_accumulate(dst: &mut [f32], src: &[f32], w: f32, threads: usize) {
    assert_eq!(dst.len(), src.len());
    if threads > 1 && dst.len() >= PAR_MIN_ELEMS {
        par::par_chunks_mut(dst, threads, |off, chunk| {
            let src = &src[off..off + chunk.len()];
            for (d, s) in chunk.iter_mut().zip(src) {
                *d += w * *s;
            }
        });
    } else {
        for (d, s) in dst.iter_mut().zip(src) {
            *d += w * *s;
        }
    }
}

/// Timing breakdown of one synchronization (for the Fig. 8/9 benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct SyncTiming {
    /// Gather (pre-sync reshard analog), seconds.
    pub gather_secs: f64,
    /// Accumulate + scale (allreduce analog), seconds.
    pub reduce_secs: f64,
    /// Scatter (post-sync reshard analog), seconds.
    pub scatter_secs: f64,
}

impl SyncTiming {
    pub fn total(&self) -> f64 {
        self.gather_secs + self.reduce_secs + self.scatter_secs
    }
}

/// Index of one parameter group across a replica's flat param list.
#[derive(Clone, Debug)]
struct Group {
    /// (param index, element length) per shard, in shard order; one entry
    /// with the full length for replicated tensors.
    members: Vec<(usize, usize)>,
    total_len: usize,
}

/// Build the group table for one program variant (same group list and
/// total lengths across all variants of a model).
fn groups_of(meta: &ProgramMeta) -> Vec<Group> {
    let mut out: Vec<Group> = Vec::new();
    let mut by_name: std::collections::BTreeMap<String, usize> = Default::default();
    for (i, p) in meta.params.iter().enumerate() {
        let group = p.group_name().to_string();
        let len = p.n_elements();
        match by_name.get(&group) {
            None => {
                by_name.insert(group, out.len());
                out.push(Group { members: vec![(i, len)], total_len: len });
            }
            Some(&gi) => {
                out[gi].members.push((i, len));
                out[gi].total_len += len;
            }
        }
    }
    out
}

/// Synchronize gradients across replicas in place.
///
/// `metas[r]` / `grads[r]` describe replica `r` (possibly different TP
/// degrees and batch sizes); `weights[r]` is its local batch size. After
/// the call every replica holds the weighted-mean gradient in its own
/// sharding.
pub fn sync_grads(
    metas: &[&ProgramMeta],
    grads: &mut [Vec<Vec<f32>>],
    weights: &[f32],
) -> Result<SyncTiming> {
    let n_rep = metas.len();
    anyhow::ensure!(n_rep == grads.len() && n_rep == weights.len(), "length mismatch");
    anyhow::ensure!(n_rep >= 1, "no replicas");
    let wsum: f32 = weights.iter().sum();
    anyhow::ensure!(wsum > 0.0, "zero total weight");

    let group_tables: Vec<Vec<Group>> = metas.iter().map(|m| groups_of(m)).collect();
    let n_groups = group_tables[0].len();
    for (r, t) in group_tables.iter().enumerate() {
        anyhow::ensure!(
            t.len() == n_groups,
            "replica {r} has {} groups, expected {n_groups}",
            t.len()
        );
    }

    let mut timing = SyncTiming::default();
    let mut full: Vec<f32> = Vec::new();
    let threads = par::num_threads();
    for g in 0..n_groups {
        let total = group_tables[0][g].total_len;
        for (r, t) in group_tables.iter().enumerate() {
            anyhow::ensure!(
                t[g].total_len == total,
                "group {g} length differs on replica {r}"
            );
        }
        full.clear();
        full.resize(total, 0.0);

        // gather (pre-sync reshard analog: replica 0's shards laid out
        // into the sync buffer) ...
        let t0 = Instant::now();
        {
            let w = weights[0] / wsum;
            let mut off = 0usize;
            for &(pi, len) in &group_tables[0][g].members {
                let src = &grads[0][pi];
                debug_assert_eq!(src.len(), len);
                weighted_accumulate(&mut full[off..off + len], src, w, threads);
                off += len;
            }
        }
        timing.gather_secs += t0.elapsed().as_secs_f64();
        // ... + weighted accumulate of the peers (the allreduce analog)
        let t0 = Instant::now();
        for r in 1..n_rep {
            let w = weights[r] / wsum;
            let mut off = 0usize;
            for &(pi, len) in &group_tables[r][g].members {
                let src = &grads[r][pi];
                debug_assert_eq!(src.len(), len);
                weighted_accumulate(&mut full[off..off + len], src, w, threads);
                off += len;
            }
        }
        timing.reduce_secs += t0.elapsed().as_secs_f64();

        // scatter back (post-sync reshard)
        let t1 = Instant::now();
        for r in 0..n_rep {
            let mut off = 0usize;
            for &(pi, len) in &group_tables[r][g].members {
                grads[r][pi].copy_from_slice(&full[off..off + len]);
                off += len;
            }
        }
        timing.scatter_secs += t1.elapsed().as_secs_f64();
    }
    Ok(timing)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::runtime::ParamMeta;

    fn meta_with_tp(tp: usize) -> ProgramMeta {
        let heads = crate::ntp::partition::partition_sizes(4, tp);
        let ffns = crate::ntp::partition::partition_sizes(12, tp);
        let mut params = vec![ParamMeta { name: "ln.scale".into(), shape: vec![6], shard: None }];
        for (s, &f) in ffns.iter().enumerate() {
            params.push(ParamMeta {
                name: format!("mlp.wa.s{s}"),
                shape: vec![f, 6],
                shard: Some("ffn".into()),
            });
        }
        ProgramMeta {
            name: format!("m_tp{tp}"),
            file: String::new(),
            model: ModelConfig {
                name: "m".into(),
                hidden: 6,
                ffn: 12,
                heads: 4,
                head_dim: 2,
                layers: 1,
                vocab: 8,
            },
            tp,
            batch: 1,
            seq_len: 4,
            head_shards: heads,
            ffn_shards: ffns,
            params,
        }
    }

    fn grads_for(meta: &ProgramMeta, fill: impl Fn(usize) -> f32) -> Vec<Vec<f32>> {
        // deterministic values by *global* element index within each group
        let mut out = Vec::new();
        let mut group_off: std::collections::BTreeMap<String, usize> = Default::default();
        for p in &meta.params {
            let off = *group_off.get(p.group_name()).unwrap_or(&0);
            let len = p.n_elements();
            out.push((0..len).map(|j| fill(off + j)).collect());
            *group_off.entry(p.group_name().to_string()).or_insert(0) += len;
        }
        out
    }

    #[test]
    fn uniform_replicas_average() {
        let m = meta_with_tp(2);
        let mut grads = vec![
            grads_for(&m, |i| i as f32),
            grads_for(&m, |i| 3.0 * i as f32),
        ];
        let metas = vec![&m, &m];
        sync_grads(&metas, &mut grads, &[1.0, 1.0]).unwrap();
        let expect = grads_for(&m, |i| 2.0 * i as f32);
        assert_eq!(grads[0], expect);
        assert_eq!(grads[1], expect);
    }

    #[test]
    fn nonuniform_tp_sync_matches_full_average() {
        // TP4 and TP3 replicas: same full-gradient semantics.
        let m4 = meta_with_tp(4);
        let m3 = meta_with_tp(3);
        let mut grads = vec![
            grads_for(&m4, |i| i as f32),
            grads_for(&m3, |i| 10.0 + i as f32),
        ];
        let metas: Vec<&ProgramMeta> = vec![&m4, &m3];
        sync_grads(&metas, &mut grads, &[1.0, 1.0]).unwrap();
        let expect4 = grads_for(&m4, |i| (i as f32 + 10.0 + i as f32) / 2.0);
        let expect3 = grads_for(&m3, |i| (i as f32 + 10.0 + i as f32) / 2.0);
        assert_eq!(grads[0], expect4);
        assert_eq!(grads[1], expect3);
    }

    #[test]
    fn weighted_mean_for_mixed_batches() {
        // Replica 0 ran batch 3, replica 1 batch 1: weights 3:1.
        let m = meta_with_tp(1);
        let mut grads =
            vec![grads_for(&m, |_| 4.0), grads_for(&m, |_| 0.0)];
        let metas = vec![&m, &m];
        sync_grads(&metas, &mut grads, &[3.0, 1.0]).unwrap();
        for buf in &grads[0] {
            for &x in buf {
                assert!((x - 3.0).abs() < 1e-6); // (3*4 + 1*0)/4
            }
        }
    }

    #[test]
    fn single_replica_is_identity() {
        let m = meta_with_tp(2);
        let orig = grads_for(&m, |i| i as f32 * 0.5);
        let mut grads = vec![orig.clone()];
        let metas = vec![&m];
        sync_grads(&metas, &mut grads, &[1.0]).unwrap();
        assert_eq!(grads[0], orig);
    }

    #[test]
    fn weighted_accumulate_parallel_matches_sequential() {
        let mut rng = crate::util::prng::Rng::new(8);
        let n = super::PAR_MIN_ELEMS + 11; // force the parallel branch
        let src = rng.normal_vec_f32(n, 1.0);
        let base = rng.normal_vec_f32(n, 1.0);
        let mut seq = base.clone();
        let mut par_buf = base;
        weighted_accumulate(&mut seq, &src, 0.37, 1);
        weighted_accumulate(&mut par_buf, &src, 0.37, 4);
        assert_eq!(seq, par_buf);
    }

    #[test]
    fn three_way_mixed_degrees() {
        let m4 = meta_with_tp(4);
        let m3 = meta_with_tp(3);
        let m2 = meta_with_tp(2);
        let mut grads = vec![
            grads_for(&m4, |i| i as f32),
            grads_for(&m3, |i| 2.0 * i as f32),
            grads_for(&m2, |i| 3.0 * i as f32),
        ];
        let metas: Vec<&ProgramMeta> = vec![&m4, &m3, &m2];
        sync_grads(&metas, &mut grads, &[1.0, 1.0, 1.0]).unwrap();
        let expect = |i: usize| (1.0 + 2.0 + 3.0) * i as f32 / 3.0;
        assert_eq!(grads[0], grads_for(&m4, expect));
        assert_eq!(grads[1], grads_for(&m3, expect));
        assert_eq!(grads[2], grads_for(&m2, expect));
    }
}
