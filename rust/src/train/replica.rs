//! One DP replica: a compiled program + its sharded parameters and
//! optimizer state.

use super::optimizer::{decay_mask_from_names, AdamW};
use super::params;
use crate::runtime::{Program, Runtime, StepOutput};
use anyhow::Result;

/// A live DP replica.
pub struct Replica {
    pub program: Program,
    pub params: Vec<Vec<f32>>,
    pub opt: AdamW,
    pub decay_mask: Vec<bool>,
    /// Cumulative PJRT execute time, seconds.
    pub execute_secs: f64,
    pub steps: u64,
}

impl Replica {
    /// Create with deterministic full-tensor init (seed shared across
    /// replicas so all start from identical full parameters).
    pub fn new(rt: &Runtime, model: &str, tp: usize, batch: usize, lr: f32, seed: u64) -> Result<Replica> {
        let program = rt.load_spec(model, tp, batch)?;
        let params = params::init_full_then_shard(&program.meta, seed);
        let opt = AdamW::new(lr, &params);
        let decay_mask =
            decay_mask_from_names(program.meta.params.iter().map(|p| p.name.as_str()));
        Ok(Replica { program, params, opt, decay_mask, execute_secs: 0.0, steps: 0 })
    }

    pub fn tp(&self) -> usize {
        self.program.meta.tp
    }

    pub fn batch(&self) -> usize {
        self.program.meta.batch
    }

    /// Forward+backward over one local batch.
    pub fn step(&mut self, tokens: &[i32], targets: &[i32]) -> Result<StepOutput> {
        let out = self.program.train_step(tokens, targets, &self.params)?;
        self.execute_secs += out.execute_secs;
        self.steps += 1;
        Ok(out)
    }

    /// Apply (already synchronized) gradients.
    pub fn apply(&mut self, grads: &[Vec<f32>]) {
        self.opt.update(&mut self.params, grads, &self.decay_mask);
    }

    /// Reconfigure to a new TP degree / batch (NTP failure response):
    /// gather params and optimizer moments to full tensors, re-slice for
    /// the new program variant. The optimizer step count carries over.
    pub fn reconfigure(&mut self, rt: &Runtime, new_tp: usize, new_batch: usize) -> Result<()> {
        let model = self.program.meta.model.name.clone();
        let new_program = rt.load_spec(&model, new_tp, new_batch)?;

        let full_p = params::gather_full(&self.program.meta, &self.params);
        let full_m = params::gather_full(&self.program.meta, &self.opt.m);
        let full_v = params::gather_full(&self.program.meta, &self.opt.v);

        self.params = params::reshard_full(&new_program.meta, &full_p)?;
        self.opt.m = params::reshard_full(&new_program.meta, &full_m)?;
        self.opt.v = params::reshard_full(&new_program.meta, &full_v)?;
        self.decay_mask =
            decay_mask_from_names(new_program.meta.params.iter().map(|p| p.name.as_str()));
        self.program = new_program;
        Ok(())
    }
}
