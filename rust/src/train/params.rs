//! Parameter initialization and (re)sharding.
//!
//! Full tensors are initialized deterministically per *group name* (the
//! parameter name without its `.sN` shard suffix), so every TP variant
//! of the same model slices the exact same full tensors — the property
//! the NTP numerics tests rely on, and what makes live TP
//! reconfiguration (gather at TP `n1`, re-slice at TP `n2`) exact.

use crate::ntp::partition::partition_ranges;
use crate::runtime::{ParamMeta, ProgramMeta};
use crate::util::prng::Rng;

/// FNV-1a hash for stable per-group PRNG streams.
fn name_hash(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn init_group(group: &str, n: usize, seed: u64) -> Vec<f32> {
    if group.ends_with(".scale") {
        return vec![1.0; n];
    }
    if group.ends_with(".bias") {
        return vec![0.0; n];
    }
    let mut rng = Rng::new(seed ^ name_hash(group));
    rng.normal_vec_f32(n, 0.02)
}

/// Shard sizes along axis 0 for a sharded param group.
fn group_shard_sizes(meta: &ProgramMeta, p: &ParamMeta) -> Vec<usize> {
    match p.shard.as_deref() {
        Some("heads") => meta.head_shards.clone(),
        Some("ffn") => meta.ffn_shards.clone(),
        _ => vec![],
    }
}

/// Initialize all params for `meta`, slicing sharded groups from
/// deterministic full tensors. Returns buffers in manifest order.
pub fn init_full_then_shard(meta: &ProgramMeta, seed: u64) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(meta.params.len());
    for p in &meta.params {
        match p.shard_index() {
            None => out.push(init_group(&p.name, p.n_elements(), seed)),
            Some(sidx) => {
                let sizes = group_shard_sizes(meta, p);
                let k: usize = sizes.iter().sum();
                let unit = p.unit_len();
                let full = init_group(p.group_name(), k * unit, seed);
                let start: usize = sizes[..sidx].iter().sum();
                let len = sizes[sidx];
                out.push(full[start * unit..(start + len) * unit].to_vec());
            }
        }
    }
    out
}

/// Gather a TP-`n` parameter list back into full tensors keyed by group
/// name, in first-appearance order. Used for TP reconfiguration and
/// checkpointing.
pub fn gather_full(meta: &ProgramMeta, params: &[Vec<f32>]) -> Vec<(String, Vec<f32>)> {
    let mut out: Vec<(String, Vec<f32>)> = Vec::new();
    let mut index: std::collections::BTreeMap<String, usize> = Default::default();
    for (p, buf) in meta.params.iter().zip(params) {
        let group = p.group_name().to_string();
        match index.get(&group) {
            None => {
                index.insert(group.clone(), out.len());
                out.push((group, buf.clone()));
            }
            Some(&i) => {
                out[i].1.extend_from_slice(buf);
            }
        }
    }
    out
}

/// Re-shard full tensors (from [`gather_full`]) into the layout another
/// program variant expects.
pub fn reshard_full(
    target: &ProgramMeta,
    full: &[(String, Vec<f32>)],
) -> anyhow::Result<Vec<Vec<f32>>> {
    let by_name: std::collections::BTreeMap<&str, &Vec<f32>> =
        full.iter().map(|(n, v)| (n.as_str(), v)).collect();
    let mut out = Vec::with_capacity(target.params.len());
    for p in &target.params {
        let group = p.group_name();
        let src = by_name
            .get(group)
            .ok_or_else(|| anyhow::anyhow!("missing group '{group}' in checkpoint"))?;
        match p.shard_index() {
            None => {
                anyhow::ensure!(src.len() == p.n_elements(), "size mismatch for {group}");
                out.push((*src).clone());
            }
            Some(sidx) => {
                let sizes = group_shard_sizes(target, p);
                let unit = p.unit_len();
                let k: usize = sizes.iter().sum();
                anyhow::ensure!(
                    src.len() == k * unit,
                    "full tensor '{group}' has {} elements, expected {}",
                    src.len(),
                    k * unit
                );
                let start: usize = sizes[..sidx].iter().sum();
                out.push(src[start * unit..(start + sizes[sidx]) * unit].to_vec());
            }
        }
    }
    Ok(out)
}

/// Contiguous ranges of units per shard for a sharded dimension.
pub fn shard_ranges(sizes: &[usize]) -> Vec<std::ops::Range<usize>> {
    let k: usize = sizes.iter().sum();
    // partition_ranges re-derives balanced ranges; shard sizes from the
    // manifest are always the balanced partition, assert equivalence.
    let ranges = partition_ranges(k, sizes.len());
    debug_assert_eq!(
        ranges.iter().map(|r| r.len()).collect::<Vec<_>>(),
        sizes.to_vec()
    );
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    /// Hand-built tiny ProgramMeta (no artifacts needed).
    fn fake_meta(tp: usize) -> ProgramMeta {
        let model = ModelConfig {
            name: "fake".into(),
            hidden: 8,
            ffn: 16,
            heads: 4,
            head_dim: 2,
            layers: 1,
            vocab: 10,
        };
        let heads = crate::ntp::partition::partition_sizes(4, tp);
        let ffns = crate::ntp::partition::partition_sizes(16, tp);
        let mut params = vec![ParamMeta {
            name: "l0.ln1.scale".into(),
            shape: vec![8],
            shard: None,
        }];
        for (s, &nh) in heads.iter().enumerate() {
            params.push(ParamMeta {
                name: format!("l0.attn.wqkv.s{s}"),
                shape: vec![nh, 3, 2, 8],
                shard: Some("heads".into()),
            });
        }
        for (s, &f) in ffns.iter().enumerate() {
            params.push(ParamMeta {
                name: format!("l0.mlp.wa.s{s}"),
                shape: vec![f, 8],
                shard: Some("ffn".into()),
            });
        }
        ProgramMeta {
            name: format!("fake_tp{tp}"),
            file: String::new(),
            model,
            tp,
            batch: 1,
            seq_len: 4,
            head_shards: heads,
            ffn_shards: ffns,
            params,
        }
    }

    #[test]
    fn same_seed_same_full_tensors_across_tp() {
        let m1 = fake_meta(1);
        let m3 = fake_meta(3);
        let p1 = init_full_then_shard(&m1, 5);
        let p3 = init_full_then_shard(&m3, 5);
        let f1 = gather_full(&m1, &p1);
        let f3 = gather_full(&m3, &p3);
        assert_eq!(f1, f3);
    }

    #[test]
    fn reshard_roundtrip() {
        let m4 = fake_meta(4);
        let m2 = fake_meta(2);
        let p4 = init_full_then_shard(&m4, 9);
        let full = gather_full(&m4, &p4);
        let p2 = reshard_full(&m2, &full).unwrap();
        // gathering the resharded params gives the same full tensors
        assert_eq!(gather_full(&m2, &p2), full);
        // and resharding back to tp4 reproduces the original buffers
        let p4b = reshard_full(&m4, &gather_full(&m2, &p2)).unwrap();
        assert_eq!(p4, p4b);
    }

    #[test]
    fn scale_bias_init_special_cased() {
        let m = fake_meta(1);
        let p = init_full_then_shard(&m, 1);
        assert!(p[0].iter().all(|&x| x == 1.0)); // ln scale
    }

    #[test]
    fn different_groups_get_different_values() {
        let m = fake_meta(1);
        let p = init_full_then_shard(&m, 1);
        // wqkv vs wa must differ (independent streams)
        assert_ne!(p[1][..8], p[2][..8]);
    }

    #[test]
    fn missing_group_errors() {
        let m = fake_meta(2);
        let full = vec![("nope".to_string(), vec![0.0; 4])];
        assert!(reshard_full(&m, &full).is_err());
    }
}
