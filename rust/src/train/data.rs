//! Synthetic training corpus: a deterministic order-2 Markov token
//! stream with Zipf-distributed unigram fallback. It is learnable (a
//! transformer's loss drops well below the unigram entropy) but not
//! trivially memorizable — good enough to exercise real optimization
//! dynamics for the e2e loss-curve experiments.

use crate::util::prng::Rng;

/// Deterministic synthetic corpus generator.
pub struct Corpus {
    vocab: usize,
    rng: Rng,
    /// sparse order-2 transition table: state -> preferred next tokens
    table_a: Vec<u32>,
    table_b: Vec<u32>,
    prev: u32,
    prev2: u32,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        assert!(vocab >= 8);
        let mut rng = Rng::new(seed);
        // Two deterministic "successor" maps; mixing them by state parity
        // creates structure a 2-layer transformer can pick up.
        let table_a: Vec<u32> = (0..vocab).map(|_| rng.below(vocab as u64) as u32).collect();
        let table_b: Vec<u32> = (0..vocab).map(|_| rng.below(vocab as u64) as u32).collect();
        Corpus { vocab, rng, table_a, table_b, prev: 0, prev2: 0 }
    }

    /// Zipf-ish unigram sample (heavier mass on low token ids).
    fn unigram(&mut self) -> u32 {
        let u = self.rng.f64();
        let v = self.vocab as f64;
        // inverse-CDF of p(i) ∝ 1/(i+2)
        let x = ((v + 2.0).powf(u) - 2.0).clamp(0.0, v - 1.0);
        x as u32
    }

    pub fn next_token(&mut self) -> u32 {
        let t = if self.rng.chance(0.75) {
            // Markov continuation: each token has at most two successors,
            // selected by the parity of the token before it — structure a
            // 2-layer transformer learns quickly.
            if self.prev2 & 1 == 0 {
                self.table_a[self.prev as usize]
            } else {
                self.table_b[self.prev as usize]
            }
        } else {
            self.unigram()
        };
        self.prev2 = self.prev;
        self.prev = t;
        t
    }

    /// Next (tokens, targets) batch, each `batch*seq` row-major; targets
    /// are tokens shifted by one (next-token prediction).
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut prev = self.next_token();
            for _ in 0..seq {
                let t = self.next_token();
                tokens.push(prev as i32);
                targets.push(t as i32);
                prev = t;
            }
        }
        (tokens, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Corpus::new(256, 3);
        let mut b = Corpus::new(256, 3);
        let (ta, _) = a.next_batch(2, 16);
        let (tb, _) = b.next_batch(2, 16);
        assert_eq!(ta, tb);
    }

    #[test]
    fn tokens_in_range_and_targets_shifted() {
        let mut c = Corpus::new(64, 1);
        let (tokens, targets) = c.next_batch(4, 32);
        assert_eq!(tokens.len(), 128);
        assert!(tokens.iter().all(|&t| (0..64).contains(&t)));
        assert!(targets.iter().all(|&t| (0..64).contains(&t)));
        // within a row, targets[i] == tokens[i+1]
        for row in 0..4 {
            for i in 0..31 {
                assert_eq!(targets[row * 32 + i], tokens[row * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn stream_is_learnable_not_uniform() {
        // The Markov structure should make the bigram distribution far
        // from uniform: count distinct successors of a frequent token.
        let mut c = Corpus::new(64, 2);
        let mut successors = vec![std::collections::BTreeSet::new(); 64];
        let mut prev = c.next_token();
        for _ in 0..20_000 {
            let t = c.next_token();
            successors[prev as usize].insert(t);
            prev = t;
        }
        let avg: f64 = successors.iter().map(|s| s.len() as f64).sum::<f64>() / 64.0;
        // uniform would approach 64 successors each; structure keeps it low
        assert!(avg < 48.0, "avg successors {avg}");
    }

    #[test]
    fn different_seeds_differ() {
        let (a, _) = Corpus::new(128, 1).next_batch(1, 32);
        let (b, _) = Corpus::new(128, 2).next_batch(1, 32);
        assert_ne!(a, b);
    }
}
