//! Checkpointing — the traditional resilience baseline the paper
//! contrasts NTP against (§7: "DNN training has relied on checkpointing
//! for resilience"). Full-tensor checkpoints are TP-layout-agnostic
//! (saved via `params::gather_full`), so a job can checkpoint at TP32
//! and restore at TP30 — which is also exactly what a cold NTP restart
//! does.
//!
//! Format: a little-endian binary blob per tensor group plus a JSON
//! index — no external serialization deps (offline build).

use super::params;
use super::replica::Replica;
use crate::util::json::Value;
use anyhow::{Context, Result};
use std::io::{Read, Write};

/// Magic + version for the binary blob.
const MAGIC: &[u8; 8] = b"NTPCKPT1";

/// Young/Daly optimal checkpoint interval, seconds: `τ* = sqrt(2 δ M)`
/// for checkpoint-write cost `δ` and mean time between failures `M` —
/// the closed-form minimizer of [`checkpoint_overhead_frac`]. Edge
/// cases: an infinite MTBF (no failures observed) returns `∞` (never
/// checkpoint), a zero MTBF or zero write cost returns `0`
/// (checkpoint continuously / for free).
pub fn young_daly_interval_secs(write_secs: f64, mtbf_secs: f64) -> f64 {
    assert!(write_secs >= 0.0 && mtbf_secs >= 0.0, "negative checkpoint inputs");
    if mtbf_secs.is_infinite() {
        return f64::INFINITY;
    }
    (2.0 * write_secs * mtbf_secs).sqrt()
}

/// First-order expected overhead fraction of checkpointing every
/// `interval_secs` (Young's model): the write cost amortized per
/// interval, plus the expected rollback of half an interval once per
/// MTBF. Minimized exactly at [`young_daly_interval_secs`].
pub fn checkpoint_overhead_frac(interval_secs: f64, write_secs: f64, mtbf_secs: f64) -> f64 {
    assert!(interval_secs > 0.0, "interval must be positive");
    write_secs / interval_secs + interval_secs / (2.0 * mtbf_secs)
}

/// A checkpoint: named full tensors + optimizer state + step counter.
pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<(String, Vec<f32>)>,
    pub opt_m: Vec<(String, Vec<f32>)>,
    pub opt_v: Vec<(String, Vec<f32>)>,
}

impl Checkpoint {
    /// Capture a replica's state (any TP degree).
    pub fn capture(replica: &Replica) -> Checkpoint {
        let meta = &replica.program.meta;
        Checkpoint {
            step: replica.opt.step,
            params: params::gather_full(meta, &replica.params),
            opt_m: params::gather_full(meta, &replica.opt.m),
            opt_v: params::gather_full(meta, &replica.opt.v),
        }
    }

    /// Restore into a replica (possibly at a different TP degree).
    pub fn restore(&self, replica: &mut Replica) -> Result<()> {
        let meta = replica.program.meta.clone();
        replica.params = params::reshard_full(&meta, &self.params)?;
        replica.opt.m = params::reshard_full(&meta, &self.opt_m)?;
        replica.opt.v = params::reshard_full(&meta, &self.opt_v)?;
        replica.opt.step = self.step;
        Ok(())
    }

    fn write_sections(out: &mut impl Write, sections: &[(String, Vec<f32>)]) -> Result<()> {
        for (_, data) in sections {
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            out.write_all(bytes)?;
        }
        Ok(())
    }

    /// Save to `<path>.json` (index) + `<path>.bin` (tensor data).
    pub fn save(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let index_of = |sections: &[(String, Vec<f32>)]| -> Value {
            Value::Arr(
                sections
                    .iter()
                    .map(|(name, data)| {
                        Value::obj(vec![
                            ("name", name.as_str().into()),
                            ("len", data.len().into()),
                        ])
                    })
                    .collect(),
            )
        };
        let index = Value::obj(vec![
            ("step", (self.step as usize).into()),
            ("params", index_of(&self.params)),
            ("opt_m", index_of(&self.opt_m)),
            ("opt_v", index_of(&self.opt_v)),
        ]);
        std::fs::write(format!("{path}.json"), index.pretty())?;

        let mut bin = std::io::BufWriter::new(std::fs::File::create(format!("{path}.bin"))?);
        bin.write_all(MAGIC)?;
        Self::write_sections(&mut bin, &self.params)?;
        Self::write_sections(&mut bin, &self.opt_m)?;
        Self::write_sections(&mut bin, &self.opt_v)?;
        Ok(())
    }

    /// Load from `<path>.{json,bin}`.
    pub fn load(path: &str) -> Result<Checkpoint> {
        let index_text = std::fs::read_to_string(format!("{path}.json"))
            .with_context(|| format!("reading {path}.json"))?;
        let index = Value::parse(&index_text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut bin = std::io::BufReader::new(
            std::fs::File::open(format!("{path}.bin"))
                .with_context(|| format!("opening {path}.bin"))?,
        );
        let mut magic = [0u8; 8];
        bin.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "bad checkpoint magic");

        let read_sections = |bin: &mut dyn Read, key: &str| -> Result<Vec<(String, Vec<f32>)>> {
            let mut out = Vec::new();
            for e in index.get(key).as_arr().unwrap_or(&[]) {
                let name = e.req_str("name")?.to_string();
                let len = e.req_usize("len")?;
                let mut data = vec![0f32; len];
                let bytes: &mut [u8] = unsafe {
                    std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, len * 4)
                };
                bin.read_exact(bytes)?;
                out.push((name, data));
            }
            Ok(out)
        };
        let params = read_sections(&mut bin, "params")?;
        let opt_m = read_sections(&mut bin, "opt_m")?;
        let opt_v = read_sections(&mut bin, "opt_v")?;
        Ok(Checkpoint {
            step: index.req_usize("step")? as u64,
            params,
            opt_m,
            opt_v,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_ckpt() -> Checkpoint {
        Checkpoint {
            step: 42,
            params: vec![
                ("embed".into(), vec![1.0, 2.0, 3.5]),
                ("l0.mlp.wa".into(), vec![-0.25; 64]),
            ],
            opt_m: vec![
                ("embed".into(), vec![0.1, 0.2, 0.3]),
                ("l0.mlp.wa".into(), vec![0.0; 64]),
            ],
            opt_v: vec![
                ("embed".into(), vec![0.4, 0.5, 0.6]),
                ("l0.mlp.wa".into(), vec![1e-8; 64]),
            ],
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ntp_ckpt_test");
        let path = dir.join("ck").to_str().unwrap().to_string();
        let ck = fake_ckpt();
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 42);
        assert_eq!(loaded.params, ck.params);
        assert_eq!(loaded.opt_m, ck.opt_m);
        assert_eq!(loaded.opt_v, ck.opt_v);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(Checkpoint::load("/nonexistent/ck").is_err());
    }

    #[test]
    fn young_daly_matches_brute_force_minimization() {
        // The closed form must land on (or beat, up to grid resolution)
        // a brute-force numeric minimization of the overhead model over
        // a fine interval grid, across disparate (δ, M) regimes.
        for &(write, mtbf) in &[
            (120.0, 50_000.0), // hourly-ish optimum
            (120.0, 500.0),    // brutal failure rate: τ* < 10 min
            (10.0, 3.0e6),     // cheap writes, rare failures
            (600.0, 86_400.0), // slow writes, daily failures
        ] {
            let tau = young_daly_interval_secs(write, mtbf);
            assert!((tau - (2.0 * write * mtbf).sqrt()).abs() < 1e-9);
            let f = |t: f64| checkpoint_overhead_frac(t, write, mtbf);
            // Grid search over [tau/50, tau*50] at 0.1% resolution.
            let (mut best_t, mut best_f) = (tau / 50.0, f(tau / 50.0));
            let mut t = tau / 50.0;
            while t < tau * 50.0 {
                let v = f(t);
                if v < best_f {
                    best_f = v;
                    best_t = t;
                }
                t *= 1.001;
            }
            assert!(
                f(tau) <= best_f + 1e-12,
                "closed form τ={tau} (overhead {}) beaten by grid t={best_t} ({best_f}) \
                 for δ={write} M={mtbf}",
                f(tau)
            );
            assert!(
                (best_t / tau - 1.0).abs() < 0.01,
                "grid argmin {best_t} far from closed form {tau} (δ={write} M={mtbf})"
            );
        }
    }

    #[test]
    fn young_daly_edge_cases() {
        // zero failure rate (infinite MTBF): never checkpoint
        assert_eq!(young_daly_interval_secs(120.0, f64::INFINITY), f64::INFINITY);
        // rate -> ∞ (MTBF -> 0): checkpoint continuously
        assert_eq!(young_daly_interval_secs(120.0, 0.0), 0.0);
        let tiny = young_daly_interval_secs(120.0, 1e-9);
        assert!(tiny > 0.0 && tiny < 1e-3);
        // free checkpoints: τ* = 0 regardless of MTBF
        assert_eq!(young_daly_interval_secs(0.0, 50_000.0), 0.0);
        // interval monotone in both δ and M
        assert!(
            young_daly_interval_secs(120.0, 1000.0) < young_daly_interval_secs(120.0, 4000.0)
        );
        assert!(
            young_daly_interval_secs(30.0, 1000.0) < young_daly_interval_secs(120.0, 1000.0)
        );
    }

    #[test]
    fn corrupt_magic_rejected() {
        let dir = std::env::temp_dir().join("ntp_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck").to_str().unwrap().to_string();
        fake_ckpt().save(&path).unwrap();
        // stomp the magic
        let bin = format!("{path}.bin");
        let mut data = std::fs::read(&bin).unwrap();
        data[0] = b'X';
        std::fs::write(&bin, data).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
