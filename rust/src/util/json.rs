//! Minimal JSON parser / writer.
//!
//! The offline vendor set has no `serde`, so configs, the artifact
//! manifest and metric dumps go through this module. It supports the full
//! JSON grammar (objects, arrays, strings with escapes, numbers, bools,
//! null) plus two conveniences for hand-written configs: `//` line
//! comments and trailing commas.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

/// Parse error with byte offset and 1-based line number.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
    pub line: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    pub fn parse(text: &str) -> Result<Value, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after top-level value"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `Value::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required typed lookups — return descriptive errors for config code.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Value {
        Value::Arr(xs.iter().map(|s| Value::Str(s.to_string())).collect())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out, Some(2), 0);
        out
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Value {
        Value::Num(x)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Value {
        Value::Num(x as f64)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Value {
        Value::Bool(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Value {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Value {
        Value::Str(x)
    }
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(x) => write_num(*x, out),
        Value::Str(s) => write_str(s, out),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Value::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, item)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_str(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no Inf/NaN; clamp to null to keep output parseable.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        let line = 1 + self.bytes[..self.pos.min(self.bytes.len())]
            .iter()
            .filter(|&&b| b == b'\n')
            .count();
        ParseError { msg: msg.to_string(), offset: self.pos, line }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        loop {
            while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
                self.pos += 1;
            }
            // `//` line comments (config convenience)
            if self.bytes[self.pos..].starts_with(b"//") {
                while let Some(b) = self.peek() {
                    self.pos += 1;
                    if b == b'\n' {
                        break;
                    }
                }
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal(b"true", Value::Bool(true)),
            Some(b'f') => self.literal(b"false", Value::Bool(false)),
            Some(b'n') => self.literal(b"null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &[u8], v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            if self.peek() == Some(b'}') {
                // trailing comma convenience
                self.pos += 1;
                return Ok(Value::Obj(map));
            }
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            if self.peek() == Some(b']') {
                self.pos += 1;
                return Ok(Value::Arr(items));
            }
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in configs; map lone
                            // surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(v.get("d").as_bool(), Some(false));
    }

    #[test]
    fn comments_and_trailing_commas() {
        let v = Value::parse(
            "// config\n{\n  \"x\": 1, // inline\n  \"y\": [1, 2,],\n}",
        )
        .unwrap();
        assert_eq!(v.get("x").as_f64(), Some(1.0));
        assert_eq!(v.get("y").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"s"],"b":{"c":null,"d":true},"e":-3}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Value::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\te\u{1}".into());
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse("\"héllo ∀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∀"));
        assert_eq!(Value::parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
    }

    #[test]
    fn errors_have_lines() {
        let e = Value::parse("{\n\"a\": }").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(Value::parse("[1, 2").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
        assert!(Value::parse("12 34").is_err());
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(Value::Num(32768.0).to_string(), "32768");
        assert_eq!(Value::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn typed_accessors() {
        let v = Value::parse(r#"{"n": 7, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 7);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_f64("missing").is_err());
        assert!(v.get("s").as_f64().is_none());
        assert_eq!(Value::Num(1.5).as_u64(), None);
    }
}
