//! Descriptive statistics and fitting helpers used by the simulator,
//! the failure engine and the bench harness (Pearson r for Fig. 11,
//! linear fit for Fig. 8, percentiles for trace reports).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Maximum; NaN-free inputs assumed. 0.0 for empty.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max).max(0.0)
}

/// Minimum; 0.0 for empty.
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Ordinary least squares `y = a + b x`. Returns `(intercept, slope)`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linear_fit needs >= 2 points");
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    let slope = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    (my - slope * mx, slope)
}

/// Pearson correlation coefficient.
pub fn pearson_r(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Coefficient of determination of the OLS fit.
pub fn r_squared(xs: &[f64], ys: &[f64]) -> f64 {
    let r = pearson_r(xs, ys);
    r * r
}

/// Geometric mean of strictly positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Two-tailed 95% Student-t critical value for `df` degrees of
/// freedom. Small Monte-Carlo batches (adaptive early stopping checks
/// CIs after as few as 8 trials) are anti-conservative under the
/// normal 1.96 constant — at df = 7 the exact value is 2.365, 21%
/// wider. Table-driven for df < 30, converging to the normal 1.96
/// beyond (the df = 29 entry is 2.045; the residual error from
/// switching to 1.96 at df ≥ 30 is < 2.5% and shrinks with n).
/// `df = 0` (one observation) has no finite interval and is clamped to
/// the df = 1 value; [`Welford::ci95`] never calls it below `n = 2`.
pub fn t_critical_95(df: u64) -> f64 {
    /// `t.ppf(0.975, df)` for df = 1..=29.
    const T95: [f64; 29] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045,
    ];
    match df {
        0 => T95[0],
        1..=29 => T95[df as usize - 1],
        _ => 1.96,
    }
}

/// Welford's online mean/variance accumulator: numerically stable,
/// O(1) state — confidence intervals over Monte-Carlo trial batches
/// without storing per-trial values. Mergeable across parallel workers
/// via Chan's pairwise formula ([`Welford::merge`]); note that both
/// `push` order and merge grouping reassociate floating-point sums, so
/// two different batchings agree only to rounding, not bit-for-bit —
/// which is why the trial scheduler (`manager::sweep`) folds per-trial
/// stats in trial-index order on one accumulator instead of merging
/// per-worker partials.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Fold another accumulator in (Chan et al.'s parallel update).
    /// Merging an empty accumulator is the exact identity.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let n = n1 + n2;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.mean += d * n2 / n;
        self.n += other.n;
    }

    /// Observations folded in.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean; 0.0 when empty.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample (Bessel-corrected) variance; 0.0 below two observations.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        self.m2 / (self.n - 1) as f64
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Half-width of the 95% confidence interval on the mean:
    /// `t·σ/√n` with the Student-t critical value for `n − 1` degrees
    /// of freedom ([`t_critical_95`] — 1.96 for n ≥ 31, wider below so
    /// small-trial CIs aren't anti-conservative). 0.0 below two
    /// observations.
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        t_critical_95(self.n - 1) * (self.variance() / self.n as f64).sqrt()
    }
}

/// Summary bundle used by the bench harness.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: min(xs),
            p50: median(xs),
            p95: percentile(xs, 95.0),
            max: max(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 2.0, 9.0]), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0]; // y = 1 + 2x
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0];
        assert!((pearson_r(&xs, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson_r(&xs, &[6.0, 4.0, 2.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate_is_zero() {
        assert_eq!(pearson_r(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn variance_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_two_pass() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), xs.len() as u64);
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        // stats::variance is population; Welford reports sample.
        let n = xs.len() as f64;
        let sample = variance(&xs) * n / (n - 1.0);
        assert!((w.variance() - sample).abs() < 1e-12);
        // n = 8 ⇒ df = 7 ⇒ Student-t 2.365, not the normal 1.96.
        let ci = t_critical_95(7) * (sample / n).sqrt();
        assert!((w.ci95() - ci).abs() < 1e-12);
    }

    #[test]
    fn t_critical_converges_to_normal() {
        assert_eq!(t_critical_95(0), t_critical_95(1));
        assert_eq!(t_critical_95(1), 12.706);
        assert_eq!(t_critical_95(7), 2.365);
        assert_eq!(t_critical_95(29), 2.045);
        assert_eq!(t_critical_95(30), 1.96);
        assert_eq!(t_critical_95(u64::MAX), 1.96);
        // Monotone non-increasing toward the normal limit.
        for df in 1..40 {
            assert!(t_critical_95(df + 1) <= t_critical_95(df), "df {df}");
            assert!(t_critical_95(df) >= 1.96, "df {df}");
        }
    }

    #[test]
    fn welford_merge_vs_push_oracle() {
        // Merge-of-parts must agree with a single push stream, and both
        // with the two-pass `stats::{mean,variance}` oracle, for an
        // uneven three-way split (the shape a work-stealing worker set
        // actually produces).
        let xs: Vec<f64> = (0..53).map(|i| ((i as f64) * 1.137).cos() * 3.0 + 7.5).collect();
        let mut pushed = Welford::default();
        for &x in &xs {
            pushed.push(x);
        }
        let mut merged = Welford::default();
        for part in [&xs[..5], &xs[5..31], &xs[31..]] {
            let mut w = Welford::default();
            for &x in part {
                w.push(x);
            }
            merged.merge(&w);
        }
        assert_eq!(merged.count(), pushed.count());
        assert!((merged.mean() - pushed.mean()).abs() < 1e-12);
        assert!((merged.variance() - pushed.variance()).abs() < 1e-10);
        assert!((merged.ci95() - pushed.ci95()).abs() < 1e-10);
        let n = xs.len() as f64;
        assert!((pushed.mean() - mean(&xs)).abs() < 1e-12);
        assert!((pushed.variance() - variance(&xs) * n / (n - 1.0)).abs() < 1e-10);
    }

    #[test]
    fn welford_merge_matches_whole() {
        let xs: Vec<f64> = (0..37).map(|i| (i as f64 * 0.731).sin() * 5.0 + 10.0).collect();
        let mut whole = Welford::default();
        for &x in &xs {
            whole.push(x);
        }
        for split in [1, 7, 18, 36] {
            let (mut a, mut b) = (Welford::default(), Welford::default());
            for &x in &xs[..split] {
                a.push(x);
            }
            for &x in &xs[split..] {
                b.push(x);
            }
            a.merge(&b);
            assert_eq!(a.count(), whole.count());
            assert!((a.mean() - whole.mean()).abs() < 1e-12, "split {split}");
            assert!((a.variance() - whole.variance()).abs() < 1e-10, "split {split}");
        }
        // Identity merges, both ways.
        let mut w = whole;
        w.merge(&Welford::default());
        assert_eq!(w.mean().to_bits(), whole.mean().to_bits());
        assert_eq!(w.m2.to_bits(), whole.m2.to_bits());
        let mut e = Welford::default();
        e.merge(&whole);
        assert_eq!(e.mean().to_bits(), whole.mean().to_bits());
        assert_eq!(e.count(), whole.count());
    }

    #[test]
    fn welford_degenerate_cases() {
        let mut w = Welford::default();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.variance(), 0.0);
        assert_eq!(w.ci95(), 0.0);
        w.push(3.5);
        assert_eq!(w.mean(), 3.5);
        assert_eq!(w.variance(), 0.0);
        assert_eq!(w.ci95(), 0.0);
    }

    #[test]
    fn summary_consistent() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }
}
