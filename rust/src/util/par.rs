//! Scoped-thread fan-out on `std::thread::scope` — no external deps (the
//! offline vendor set has no rayon). Used by the Monte-Carlo benches
//! (one PRNG stream per trial), the chunked AdamW update and the
//! weighted gradient reduce.
//!
//! Everything here preserves result order and (for the mutable-chunk
//! helper) partitions the buffer disjointly, so parallel execution is
//! bit-identical to sequential execution for element-independent work.

/// Below this many total elements a parallel numeric kernel is not
/// worth the thread spawns (shared by AdamW and the gradient reduce).
pub const PAR_MIN_ELEMS: usize = 1 << 20;

/// Worker count: `NTP_THREADS` env override, else the machine's
/// available parallelism, else 1. Resolved once per process (callers
/// sit in hot loops; re-reading the env would take the process-wide
/// env lock every call) — set `NTP_THREADS` before first use.
pub fn num_threads() -> usize {
    static THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("NTP_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Map `f` over `0..n_items` on up to `threads` scoped threads,
/// returning results in index order. Items are split into contiguous
/// index ranges (one per worker); with `threads <= 1` this is a plain
/// sequential map with no thread spawned.
pub fn par_map<U, F>(n_items: usize, threads: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let t = threads.max(1).min(n_items.max(1));
    if t <= 1 {
        return (0..n_items).map(f).collect();
    }
    let chunk = n_items.div_ceil(t);
    let fref = &f;
    let mut parts: Vec<Vec<U>> = Vec::with_capacity(t);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..t)
            .map(|ti| {
                let lo = (ti * chunk).min(n_items);
                let hi = ((ti + 1) * chunk).min(n_items);
                s.spawn(move || (lo..hi).map(fref).collect::<Vec<U>>())
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("par_map worker panicked"));
        }
    });
    let mut out = Vec::with_capacity(n_items);
    for p in parts {
        out.extend(p);
    }
    out
}

/// Split `buf` into up to `threads` contiguous chunks and run
/// `f(chunk_offset, chunk)` on each concurrently. Chunks are disjoint,
/// so any element-independent `f` produces the same buffer contents as
/// one sequential pass. With `threads <= 1` runs inline.
pub fn par_chunks_mut<T, F>(buf: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let t = threads.max(1).min(buf.len().max(1));
    if t <= 1 {
        f(0, buf);
        return;
    }
    let chunk = buf.len().div_ceil(t);
    let fref = &f;
    std::thread::scope(|s| {
        let mut rest: &mut [T] = buf;
        let mut off = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            let o = off;
            s.spawn(move || fref(o, head));
            off += take;
            rest = tail;
        }
    });
}

/// Work-stealing fan-out over `0..n_items` with per-worker state: one
/// worker per element of `states`, each repeatedly claiming the next
/// unclaimed index from a shared atomic cursor until the range drains.
/// Static contiguous chunking ([`par_map`]) lets the slowest batch
/// gate wall-clock when per-item cost is heterogeneous (a correlated
/// failure-blast trial replays thousands of events while a quiet trial
/// replays a handful); stealing keeps every worker busy to the end.
///
/// Results come back in **index order**, so which worker computed what
/// never leaks into the output — for index-independent `f` the result
/// vector is bit-identical for any worker count and any scheduling.
/// Only the mutations `f` makes to its worker state (e.g. per-worker
/// memo hit counters) remain scheduling-dependent. With a single state
/// (or fewer than two items) the map runs inline on `states[0]` with
/// no thread spawned or cursor touched.
pub fn par_steal_with_states<S, U, F>(n_items: usize, states: &mut [S], f: F) -> Vec<U>
where
    S: Send,
    U: Send,
    F: Fn(&mut S, usize) -> U + Sync,
{
    assert!(!states.is_empty(), "par_steal_with_states needs at least one worker state");
    if states.len() == 1 || n_items <= 1 {
        let st = &mut states[0];
        return (0..n_items).map(|i| f(st, i)).collect();
    }
    let cursor = std::sync::atomic::AtomicUsize::new(0);
    let (fref, cref) = (&f, &cursor);
    let mut parts: Vec<Vec<(usize, U)>> = Vec::with_capacity(states.len());
    std::thread::scope(|s| {
        let handles: Vec<_> = states
            .iter_mut()
            .map(|st| {
                s.spawn(move || {
                    let mut got: Vec<(usize, U)> = Vec::new();
                    loop {
                        let i = cref.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= n_items {
                            break;
                        }
                        got.push((i, fref(st, i)));
                    }
                    got
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("par_steal_with_states worker panicked"));
        }
    });
    let mut all: Vec<(usize, U)> = Vec::with_capacity(n_items);
    for p in parts {
        all.extend(p);
    }
    all.sort_unstable_by_key(|&(i, _)| i);
    all.into_iter().map(|(_, v)| v).collect()
}

/// Like [`par_chunks_mut`], but chunk boundaries are chosen so each
/// chunk carries a near-equal share of `weights[i]` (e.g. element
/// counts of per-tensor work items) instead of a near-equal item
/// count — one oversized item cannot gate the whole fan-out. Chunks
/// stay contiguous and disjoint, so element-independent `f` remains
/// bit-identical to a sequential pass.
pub fn par_chunks_weighted_mut<T, F>(buf: &mut [T], weights: &[usize], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(buf.len(), weights.len());
    let t = threads.max(1).min(buf.len().max(1));
    if t <= 1 {
        f(0, buf);
        return;
    }
    let total: usize = weights.iter().sum();
    let target = (total.div_ceil(t)).max(1);
    let fref = &f;
    std::thread::scope(|s| {
        let mut rest: &mut [T] = buf;
        let mut idx = 0usize; // global index of rest[0]
        while !rest.is_empty() {
            let mut take = 1usize;
            let mut w = weights[idx];
            while take < rest.len() && w < target {
                w += weights[idx + take];
                take += 1;
            }
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            let off = idx;
            s.spawn(move || fref(off, head));
            idx += take;
            rest = tail;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        for threads in [1usize, 2, 3, 8] {
            let got = par_map(17, threads, |i| i * i);
            let want: Vec<usize> = (0..17).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
        assert!(par_map(0, 4, |i| i).is_empty());
        assert_eq!(par_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn par_chunks_mut_covers_every_element_once() {
        for threads in [1usize, 2, 5] {
            let mut buf: Vec<u64> = vec![0; 103];
            par_chunks_mut(&mut buf, threads, |off, chunk| {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x += (off + i) as u64 + 1;
                }
            });
            let want: Vec<u64> = (0..103).map(|i| i + 1).collect();
            assert_eq!(buf, want, "threads={threads}");
        }
        // empty buffer is a no-op
        let mut empty: Vec<u64> = Vec::new();
        par_chunks_mut(&mut empty, 4, |_, _| {});
    }

    #[test]
    fn weighted_chunks_cover_every_item_once_and_balance() {
        for threads in [1usize, 2, 4] {
            // one huge item among many small ones
            let weights: Vec<usize> = (0..40).map(|i| if i == 7 { 10_000 } else { 10 }).collect();
            let mut buf: Vec<u64> = vec![0; 40];
            par_chunks_weighted_mut(&mut buf, &weights, threads, |off, chunk| {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x += (off + i) as u64 + 1;
                }
            });
            let want: Vec<u64> = (0..40).map(|i| i + 1).collect();
            assert_eq!(buf, want, "threads={threads}");
        }
        // degenerate: single item, empty
        let mut one = vec![0u64];
        par_chunks_weighted_mut(&mut one, &[5], 4, |_, c| c[0] = 9);
        assert_eq!(one, vec![9]);
        let mut empty: Vec<u64> = Vec::new();
        par_chunks_weighted_mut(&mut empty, &[], 4, |_, _| {});
    }

    #[test]
    fn steal_matches_sequential_any_worker_count() {
        let want: Vec<usize> = (0..53).map(|i| i * 3 + 1).collect();
        for workers in [1usize, 2, 3, 8] {
            let mut states: Vec<u64> = vec![0; workers];
            let got = par_steal_with_states(53, &mut states, |st, i| {
                *st += 1; // per-worker claim count
                i * 3 + 1
            });
            assert_eq!(got, want, "workers={workers}");
            // Every index was claimed exactly once, across all workers.
            assert_eq!(states.iter().sum::<u64>(), 53, "workers={workers}");
        }
    }

    #[test]
    fn steal_degenerate_cases() {
        let mut one = [0u8];
        assert!(par_steal_with_states(0, &mut one, |_, i| i).is_empty());
        assert_eq!(par_steal_with_states(1, &mut one, |_, i| i + 7), vec![7]);
        // More workers than items still covers each index once.
        let mut many = [0u8; 9];
        assert_eq!(par_steal_with_states(2, &mut many, |_, i| i), vec![0, 1]);
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }
}
