//! Tiny command-line parser (no `clap` in the offline vendor set).
//!
//! Grammar: `ntp <subcommand> [--flag] [--key value] [--key=value] [pos...]`.
//! Typed accessors with defaults; `finish()` rejects unknown options so
//! typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut args = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(item) = it.next() {
            if let Some(stripped) = item.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(item);
            } else {
                args.positional.push(item);
            }
        }
        args
    }

    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_str(&mut self, name: &str) -> Option<String> {
        self.consumed.push(name.to_string());
        self.options.get(name).cloned()
    }

    pub fn str_or(&mut self, name: &str, default: &str) -> String {
        self.opt_str(name).unwrap_or_else(|| default.to_string())
    }

    /// `Some(parsed)` when the option is present, `None` when absent —
    /// for flags whose *presence* changes behavior (e.g. `fleet
    /// --spares N` opting into fixed-minibatch mode).
    pub fn opt_usize(&mut self, name: &str) -> Option<usize> {
        self.opt_str(name).map(|v| {
            v.parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
        })
    }

    pub fn usize_or(&mut self, name: &str, default: usize) -> usize {
        self.opt_usize(name).unwrap_or(default)
    }

    pub fn u64_or(&mut self, name: &str, default: u64) -> u64 {
        self.opt_str(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    /// `Some(parsed)` when the option is present, `None` when absent —
    /// for numeric overrides whose default is computed elsewhere (e.g.
    /// the modeled `fleet --reshard-secs`).
    pub fn opt_f64(&mut self, name: &str) -> Option<f64> {
        self.opt_str(name).map(|v| {
            v.parse()
                .unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'"))
        })
    }

    pub fn f64_or(&mut self, name: &str, default: f64) -> f64 {
        self.opt_f64(name).unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--tp 8,16,32`.
    pub fn usize_list_or(&mut self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.opt_str(name) {
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer '{s}'"))
                })
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated f64 list, e.g. `--rate-x 1,2,5,10`.
    pub fn f64_list_or(&mut self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.opt_str(name) {
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad number '{s}'"))
                })
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Error if any unconsumed `--option` remains (catches typos).
    pub fn finish(&self) -> anyhow::Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !self.consumed.iter().any(|c| c == k) {
                anyhow::bail!("unknown option --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let mut a = parse("train --steps 100 --model small --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("steps", 1), 100);
        assert_eq!(a.str_or("model", "tiny"), "small");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax_and_lists() {
        let mut a = parse("sim --tp=8,16,32 --scale=2.5 --rate-x=1,2.5,10");
        assert_eq!(a.usize_list_or("tp", &[]), vec![8, 16, 32]);
        assert_eq!(a.f64_or("scale", 1.0), 2.5);
        assert_eq!(a.f64_list_or("rate-x", &[]), vec![1.0, 2.5, 10.0]);
        assert_eq!(a.f64_list_or("absent", &[0.5]), vec![0.5]);
        a.finish().unwrap();
    }

    #[test]
    fn positional_args() {
        let a = parse("run file1 file2");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn unknown_option_rejected() {
        let mut a = parse("x --good 1 --bad 2");
        let _ = a.usize_or("good", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn defaults_apply() {
        let mut a = parse("x");
        assert_eq!(a.usize_or("steps", 7), 7);
        assert_eq!(a.str_or("name", "d"), "d");
        assert_eq!(a.usize_list_or("l", &[1, 2]), vec![1, 2]);
        assert_eq!(a.opt_usize("spares"), None);
        let mut b = parse("x --spares 0");
        assert_eq!(b.opt_usize("spares"), Some(0));
    }

    #[test]
    fn trailing_flag_without_value() {
        let mut a = parse("x --dry-run");
        assert!(a.flag("dry-run"));
        a.finish().unwrap();
    }
}
