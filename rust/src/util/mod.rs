//! Infrastructure substrates built in-repo (the offline vendor set has no
//! serde / clap / criterion / proptest / rand).

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod par;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;
