//! Aligned text tables for bench output — every figure/table bench prints
//! a paper-vs-measured table through this.

/// A simple column-aligned table with a header row.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Table {
        self.title = Some(title.to_string());
        self
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: row of display-ables.
    pub fn rowd(&mut self, cells: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&v);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("## {t}\n"));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                // Right-align numeric-looking cells, left-align text.
                let numeric = cell
                    .chars()
                    .next()
                    .map(|c| c.is_ascii_digit() || c == '-' || c == '+' || c == '.')
                    .unwrap_or(false);
                if numeric && i > 0 {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                } else {
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV rendering for results/ dumps.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format helpers shared by benches.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}
/// Seconds, auto-scaled (ns/µs/ms/s).
pub fn dur(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["alpha".into(), "1.00".into()]);
        t.row(&["b".into(), "123.45".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        // numeric column right-aligned
        assert!(lines[3].ends_with("123.45"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn duration_scaling() {
        assert_eq!(dur(2.5), "2.500s");
        assert_eq!(dur(0.0025), "2.50ms");
        assert_eq!(dur(2.5e-6), "2.5µs");
        assert_eq!(dur(2.5e-9), "2.5ns");
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.1234), "12.34%");
    }
}
