//! Mini property-testing helper (no `proptest` offline).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs; on failure it performs greedy shrinking via the generator's
//! `shrink` hook and panics with the minimal counterexample. Coordinator
//! invariants (Alg. 1 assignments, reshard roundtrips, packing, planner
//! monotonicity) are tested through this.

use crate::util::prng::Rng;
use std::fmt::Debug;

/// A generator of random test inputs with optional shrinking.
pub trait Gen {
    type Item: Clone + Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Item;
    /// Candidate smaller inputs; default: no shrinking.
    fn shrink(&self, _item: &Self::Item) -> Vec<Self::Item> {
        Vec::new()
    }
}

/// Run `prop` on `cases` random inputs. Panics with a (shrunk)
/// counterexample on the first failure.
pub fn check<G: Gen, P: Fn(&G::Item) -> Result<(), String>>(
    seed: u64,
    cases: usize,
    gen: &G,
    prop: P,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first failing shrink.
            let mut current = input;
            let mut current_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                budget -= 1;
                for cand in gen.shrink(&current) {
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        current_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: {current:?}\n  error: {current_msg}"
            );
        }
    }
}

/// Generator for `(k, n1, n2)` NTP shard-mapping instances with
/// `1 <= n2 <= n1 <= k`.
pub struct ShardInstanceGen {
    pub max_k: usize,
    pub max_n: usize,
}

impl Gen for ShardInstanceGen {
    type Item = (usize, usize, usize);

    fn generate(&self, rng: &mut Rng) -> (usize, usize, usize) {
        let n1 = 1 + rng.index(self.max_n);
        let n2 = 1 + rng.index(n1);
        // k >= n1 so every shard holds at least one column.
        let k = n1 + rng.index(self.max_k.saturating_sub(n1) + 1);
        (k, n1, n2)
    }

    fn shrink(&self, &(k, n1, n2): &(usize, usize, usize)) -> Vec<(usize, usize, usize)> {
        let mut out = Vec::new();
        if k > n1 {
            out.push((k - 1, n1, n2));
            out.push((n1, n1, n2)); // jump to minimum k
        }
        if n1 > n2 {
            out.push((k, n1 - 1, n2.min(n1 - 1)));
        }
        if n2 > 1 {
            out.push((k, n1, n2 - 1));
        }
        out
    }
}

/// Generator for u64 seeds (for randomized sub-experiments).
pub struct SeedGen;
impl Gen for SeedGen {
    type Item = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        rng.next_u64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let gen = ShardInstanceGen { max_k: 100, max_n: 16 };
        check(1, 200, &gen, |&(k, n1, n2)| {
            if n2 <= n1 && n1 <= k {
                Ok(())
            } else {
                Err(format!("bad instance {k} {n1} {n2}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        let gen = ShardInstanceGen { max_k: 50, max_n: 8 };
        check(2, 500, &gen, |&(k, _, _)| {
            if k < 10 {
                Ok(())
            } else {
                Err("k too big".into())
            }
        });
    }

    #[test]
    fn shrinking_reaches_small_instance() {
        // Verify the shrinker produces strictly "smaller" candidates.
        let gen = ShardInstanceGen { max_k: 100, max_n: 16 };
        let shrinks = gen.shrink(&(50, 8, 4));
        assert!(!shrinks.is_empty());
        for (k, n1, n2) in shrinks {
            assert!(n2 <= n1 && n1 <= k);
            assert!(k < 50 || n1 < 8 || n2 < 4);
        }
    }
}
