//! Criterion-lite: a small measurement harness for `cargo bench` targets
//! (the offline vendor set has no criterion). Warms up, runs timed
//! iterations until a time or count budget is reached, and reports a
//! `stats::Summary`. Used both by the per-figure benches and by the §Perf
//! optimization loop in EXPERIMENTS.md.
//!
//! [`JsonReport`] additionally collects results and named scalars
//! (speedup ratios, problem sizes) into a machine-readable JSON file —
//! `benches/perf_hotpath.rs` writes `BENCH_perf_hotpath.json` so the
//! perf trajectory is trackable across PRs.

use crate::util::json::Value;
use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Whether `--name` appears in the bench binary's argv (the
/// `harness = false` mains parse their own flags; cargo forwards
/// everything after `--`).
pub fn arg_flag(name: &str) -> bool {
    std::env::args().any(|a| a == name)
}

/// Value of `--name value` / `--name=value` from the bench binary's
/// argv, if present.
pub fn arg_value(name: &str) -> Option<String> {
    let eq = format!("{name}=");
    let mut it = std::env::args();
    while let Some(a) = it.next() {
        if a == name {
            return it.next();
        }
        if let Some(rest) = a.strip_prefix(&eq) {
            return Some(rest.to_string());
        }
    }
    None
}

/// The shared `--exact` / `--grid-hours H` bench-argv convention
/// (fig6/fig7 and kin): exact event-boundary integration by default,
/// `--grid-hours H` opts back into the legacy fixed grid, passing both
/// is an error — mirroring the `fleet` CLI's flags. Lives here (not on
/// [`crate::manager::StepMode`] itself) because it reads process-global
/// argv and panics on malformed flags — bench-main behavior, not
/// simulation-core behavior.
pub fn step_mode_from_args() -> crate::manager::StepMode {
    use crate::manager::StepMode;
    let grid = arg_value("--grid-hours");
    assert!(
        !(arg_flag("--exact") && grid.is_some()),
        "--exact (the default) conflicts with --grid-hours"
    );
    match grid {
        Some(v) => {
            let h: f64 = v.parse().expect("--grid-hours expects hours");
            assert!(h > 0.0, "--grid-hours must be positive");
            StepMode::Grid(h)
        }
        None => StepMode::Exact,
    }
}

#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            max_time: Duration::from_secs(3),
        }
    }
}

impl BenchConfig {
    /// Budget for expensive end-to-end cases (PJRT training steps).
    pub fn slow() -> BenchConfig {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 30,
            max_time: Duration::from_secs(10),
        }
    }

    /// Budget for microbenchmarks.
    pub fn fast() -> BenchConfig {
        BenchConfig {
            warmup_iters: 10,
            min_iters: 50,
            max_iters: 10_000,
            max_time: Duration::from_secs(1),
        }
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs: Summary,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.secs.mean
    }

    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>10} mean  {:>10} p50  {:>10} p95  (n={})",
            self.name,
            crate::util::table::dur(self.secs.mean),
            crate::util::table::dur(self.secs.p50),
            crate::util::table::dur(self.secs.p95),
            self.iters
        )
    }
}

/// Time `f` under `cfg`, returning per-iteration summaries.
pub fn bench_with<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(cfg.min_iters);
    let budget_start = Instant::now();
    while samples.len() < cfg.max_iters
        && (samples.len() < cfg.min_iters || budget_start.elapsed() < cfg.max_time)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        secs: Summary::of(&samples),
    }
}

/// Time `f` with the default config and print one line.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench_with(name, BenchConfig::default(), f);
    println!("{}", r.line());
    r
}

/// Measure a one-shot operation (no repetition), e.g. a whole simulated
/// 15-day trace.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Machine-readable collection of bench results + named scalars.
#[derive(Debug, Default)]
pub struct JsonReport {
    name: String,
    entries: Vec<Value>,
    scalars: Vec<(String, f64)>,
    labels: Vec<(String, String)>,
    rows: Vec<Value>,
}

impl JsonReport {
    pub fn new(name: &str) -> JsonReport {
        JsonReport { name: name.to_string(), ..Default::default() }
    }

    /// Record one timed case.
    pub fn result(&mut self, r: &BenchResult) {
        self.entries.push(Value::obj(vec![
            ("name", Value::Str(r.name.clone())),
            ("iters", Value::Num(r.iters as f64)),
            ("mean_secs", Value::Num(r.secs.mean)),
            ("p50_secs", Value::Num(r.secs.p50)),
            ("p95_secs", Value::Num(r.secs.p95)),
            ("min_secs", Value::Num(r.secs.min)),
            ("max_secs", Value::Num(r.secs.max)),
        ]));
    }

    /// Record a named scalar (speedup ratio, problem size, ...).
    pub fn scalar(&mut self, key: &str, value: f64) {
        self.scalars.push((key.to_string(), value));
    }

    /// Record a named string (scenario kind, policy list, ...) — the
    /// provenance a reproducibility record needs but a scalar can't
    /// carry. Emitted as a separate `labels` object.
    pub fn label(&mut self, key: &str, value: &str) {
        self.labels.push((key.to_string(), value.to_string()));
    }

    /// Append one data row (an arbitrary JSON object) to the report's
    /// `rows` array — the shape of a parameter-grid result cube, where
    /// every grid point contributes one row of coordinates + outputs.
    pub fn row(&mut self, row: Value) {
        self.rows.push(row);
    }

    /// Rows appended so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("bench", Value::Str(self.name.clone())),
            ("entries", Value::Arr(self.entries.clone())),
            ("rows", Value::Arr(self.rows.clone())),
            (
                "scalars",
                Value::Obj(
                    self.scalars.iter().map(|(k, v)| (k.clone(), Value::Num(*v))).collect(),
                ),
            ),
            (
                "labels",
                Value::Obj(
                    self.labels
                        .iter()
                        .map(|(k, v)| (k.clone(), Value::Str(v.clone())))
                        .collect(),
                ),
            ),
        ])
    }

    /// Write the report as pretty JSON to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().pretty() + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 5,
            max_time: Duration::from_millis(100),
        };
        let r = bench_with("spin", cfg, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(r.iters, 5);
        assert!(r.secs.mean > 0.0);
        assert!(r.secs.min <= r.secs.p50 && r.secs.p50 <= r.secs.max);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, t) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }

    #[test]
    fn json_report_roundtrips() {
        let mut rep = JsonReport::new("unit");
        let r = BenchResult {
            name: "case_a".into(),
            iters: 4,
            secs: Summary::of(&[0.5, 0.5, 0.5, 0.5]),
        };
        rep.result(&r);
        rep.scalar("speedup", 12.5);
        rep.label("scenario", "correlated");
        rep.row(Value::obj(vec![
            ("rate_x", Value::Num(2.0)),
            ("goodput", Value::Num(0.97)),
        ]));
        assert_eq!(rep.n_rows(), 1);
        let v = rep.to_json();
        let parsed = Value::parse(&v.pretty()).unwrap();
        assert_eq!(parsed.get("bench").as_str(), Some("unit"));
        let entries = parsed.get("entries").as_arr().unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("name").as_str(), Some("case_a"));
        assert_eq!(entries[0].get("mean_secs").as_f64(), Some(0.5));
        assert_eq!(parsed.get("scalars").get("speedup").as_f64(), Some(12.5));
        assert_eq!(parsed.get("labels").get("scenario").as_str(), Some("correlated"));
        let rows = parsed.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("rate_x").as_f64(), Some(2.0));
    }

    #[test]
    fn respects_max_iters() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 3,
            max_time: Duration::from_secs(60),
        };
        let r = bench_with("noop", cfg, || {});
        assert!(r.iters <= 3);
    }
}
