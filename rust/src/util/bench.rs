//! Criterion-lite: a small measurement harness for `cargo bench` targets
//! (the offline vendor set has no criterion). Warms up, runs timed
//! iterations until a time or count budget is reached, and reports a
//! `stats::Summary`. Used both by the per-figure benches and by the §Perf
//! optimization loop in EXPERIMENTS.md.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            max_time: Duration::from_secs(3),
        }
    }
}

impl BenchConfig {
    /// Budget for expensive end-to-end cases (PJRT training steps).
    pub fn slow() -> BenchConfig {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 30,
            max_time: Duration::from_secs(10),
        }
    }

    /// Budget for microbenchmarks.
    pub fn fast() -> BenchConfig {
        BenchConfig {
            warmup_iters: 10,
            min_iters: 50,
            max_iters: 10_000,
            max_time: Duration::from_secs(1),
        }
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs: Summary,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.secs.mean
    }

    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>10} mean  {:>10} p50  {:>10} p95  (n={})",
            self.name,
            crate::util::table::dur(self.secs.mean),
            crate::util::table::dur(self.secs.p50),
            crate::util::table::dur(self.secs.p95),
            self.iters
        )
    }
}

/// Time `f` under `cfg`, returning per-iteration summaries.
pub fn bench_with<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(cfg.min_iters);
    let budget_start = Instant::now();
    while samples.len() < cfg.max_iters
        && (samples.len() < cfg.min_iters || budget_start.elapsed() < cfg.max_time)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        secs: Summary::of(&samples),
    }
}

/// Time `f` with the default config and print one line.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench_with(name, BenchConfig::default(), f);
    println!("{}", r.line());
    r
}

/// Measure a one-shot operation (no repetition), e.g. a whole simulated
/// 15-day trace.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 5,
            max_time: Duration::from_millis(100),
        };
        let r = bench_with("spin", cfg, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(r.iters, 5);
        assert!(r.secs.mean > 0.0);
        assert!(r.secs.min <= r.secs.p50 && r.secs.p50 <= r.secs.max);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, t) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }

    #[test]
    fn respects_max_iters() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 3,
            max_time: Duration::from_secs(60),
        };
        let r = bench_with("noop", cfg, || {});
        assert!(r.iters <= 3);
    }
}
