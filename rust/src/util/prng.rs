//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding, Xoshiro256++ as the workhorse generator.
//! Everything in the simulator and the failure engine draws from these so
//! every experiment is reproducible from a single `--seed`.

/// SplitMix64 step: used to expand a single `u64` seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ PRNG (Blackman & Vigna). Deterministic, fast, and good
/// enough statistically for Monte-Carlo failure sampling.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Build a generator from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // Avoid the all-zero state (cannot happen via splitmix64, but be safe).
        if s == [0, 0, 0, 0] {
            s[0] = 0xDEAD_BEEF;
        }
        Rng { s }
    }

    /// Derive an independent child stream (e.g. one per Monte-Carlo trial).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's rejection method to avoid
    /// modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (cached second value is skipped for
    /// simplicity; this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`). Used for failure
    /// inter-arrival and recovery times.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from `[0, n)` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "sample_indices: m={m} > n={n}");
        // For small m relative to n, use a hash-set-free Floyd's algorithm
        // over a sorted vec; for large m just shuffle.
        if m * 4 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(m);
            all
        } else {
            let mut chosen: Vec<usize> = Vec::with_capacity(m);
            for j in (n - m)..n {
                let t = self.index(j + 1);
                if let Err(pos) = chosen.binary_search(&t) {
                    chosen.insert(pos, t);
                } else {
                    let pos = chosen.binary_search(&j).unwrap_err();
                    chosen.insert(pos, j);
                }
            }
            chosen
        }
    }

    /// Standard normal f32 vector, for parameter initialization.
    pub fn normal_vec_f32(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * std).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            // each bucket ~10k; allow 5% slack
            assert!((9_000..11_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(5);
        for &(n, m) in &[(100usize, 10usize), (100, 80), (1, 1), (50, 0), (10, 10)] {
            let s = r.sample_indices(n, m);
            assert_eq!(s.len(), m);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), m, "duplicates for n={n} m={m}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
