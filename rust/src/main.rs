//! `ntp` CLI — the leader entrypoint.
//!
//! Subcommands map to the paper's workflows:
//!
//! * `train`       — real NTP training over the AOT artifacts (PJRT).
//! * `plan`        — hybrid-parallel config search (Fig. 2b machinery).
//! * `simulate`    — iteration-time breakdown for one config.
//! * `availability`— failure-amplification scan (Fig. 3).
//! * `trace`       — synthetic failure trace stats (Fig. 4).
//! * `reshard-plan`— Algorithm-1 shard mapping + all-to-all splits.
//! * `power`       — power-boost solve for reduced-TP replicas (Table 1).
//! * `fleet`       — trace-driven fleet simulation (Figs. 6/7 semantics).
//! * `sweep`       — memo-shared parameter-grid sweep (rate × spares ×
//!   scenario scale × cluster) in one process, one JSON cube.

use anyhow::Result;
use ntp::cluster::Topology;
use ntp::config::{presets, Dtype, WorkloadConfig};
use ntp::failure::{
    generate_scenario, sample_failed_gpus, scenario::scenario_from_failed, BlastRadius,
    DetectionModel, EventKind, FailureModel, ScenarioConfig, ScenarioKind, Trace, TrialGen,
};
use ntp::manager::{
    FleetStats, MemoStats, MultiPolicySim, ResponseMemo, SparePolicy, StepMode, StopRule,
    StrategyTable,
};
use ntp::util::stats::Welford;
use ntp::ntp::{ReshardPlan, ShardMap};
use ntp::parallel::{best_config, ParallelConfig};
use ntp::policy::{registry, reshard_transition_secs_over, PolicyCtx, TransitionCosts};
use ntp::power::{min_boost_for, BoostDecision, RackDesign};
use ntp::sim::engine::min_supported_tp;
use ntp::sim::{IterationModel, SimParams};
use ntp::util::bench::JsonReport;
use ntp::util::cli::Args;
use ntp::util::json::Value;
use ntp::util::prng::Rng;
use ntp::util::table::{f2, f3, f4, pct, Table};

fn main() {
    let mut args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&mut args),
        Some("plan") => cmd_plan(&mut args),
        Some("simulate") => cmd_simulate(&mut args),
        Some("availability") => cmd_availability(&mut args),
        Some("trace") => cmd_trace(&mut args),
        Some("reshard-plan") => cmd_reshard_plan(&mut args),
        Some("power") => cmd_power(&mut args),
        Some("fleet") => cmd_fleet(&mut args),
        Some("sweep") => cmd_sweep(&mut args),
        Some(other) => Err(anyhow::anyhow!("unknown subcommand '{other}'\n{USAGE}")),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "\
ntp — Nonuniform Tensor Parallelism (paper reproduction)

USAGE: ntp <subcommand> [options]

  train         --model tiny|e2e-20m|e2e-100m --replicas 4,3 --batches 4,4
                --steps N --lr 1e-3 --seed S [--fail-at STEP --fail-tp TP]
  plan          --model gpt-480b --cluster paper-32k-nvl32 --seq 8192
                [--tp-cap 32]
  simulate      --model gpt-480b --cluster paper-32k-nvl32 --tp 32 --pp 8
                --dp 128 [--seq 16384]
  availability  --cluster paper-32k-nvl32 --tp 8,16,32,64 [--samples 200]
                [--policy ntp] (adds a throughput column under that policy)
                [--model gpt-480b] (model for the policy column)
  trace         --cluster llama3-16k-nvl8 --days 15 [--rate-x 1.0]
                [--scenario independent|correlated|straggler|sdc]
                (scenario generator knobs, shared with `fleet`:)
                [--corr-x 1.0] (scale the correlated rack/switch rates)
                [--straggler-x 1.0] (scale the straggler onset rate)
                [--slowdown-lo 0.3] [--slowdown-hi 0.9] (straggler speed
                as a fraction of healthy, uniform in [lo, hi])
                [--sdc-x 1.0] (scale the silent-corruption rate)
                [--validation-hours 6] (SDC validation-sweep period)
  reshard-plan  --k 12288 --n1 32 --n2 30
  power         --model gpt-480b --cluster paper-32k-nvl32 --tp 32 --pp 8
                --dp 128
  fleet         --strategy dp-drop,ntp,ntp-pw,ckpt-restart,spare-mig,
                lowpri-donate,partial-restart,power-spares,ckpt-adaptive,
                straggler-evict,straggler-tolerate,elastic-dp
                (comma-separated list, evaluated in ONE shared trace sweep;
                LOWPRI-DONATE/POWER-SPARES report the secondary channel in
                the 'donated' column; STRAGGLER-* differ only on degraded
                snapshots, i.e. under --scenario straggler; ELASTIC-DP
                shrinks/grows the DP world at event boundaries and bills
                live rejoins as peer-to-peer state transfer)
                --days 15 [--spares N] (fixed minibatch with N spare domains)
                [--cold-spares C] (the last C of the pool are fleet-wide
                cold spares billed at --cold-load-secs; requires a pool
                via --spares, C <= total) | [--warm-spares W] (alternative
                pool spelling: total = W + C warm/cold tiers; conflicts
                with --spares)
                [--replicas 16] [--rate-x 10] [--json] [--no-transitions]
                [--scenario independent|correlated|straggler|sdc] plus the
                generator knobs listed under `trace` (--corr-x,
                --straggler-x, --slowdown-lo/-hi, --sdc-x,
                --validation-hours); --json records seed, scenario kind
                and generator parameters for reproducibility
                [--cluster paper-32k-nvl32|paper-100k-nvl72|...] [--pp 8]
                [--exact] (default: exact event-boundary integration —
                stats are exact for the trace, transitions charged per
                event) | [--grid-hours H] (legacy fixed-grid sampling)
                [--trials N] (Monte-Carlo traces, per-trial forked PRNG
                streams; table/JSON report per-policy means over trials)
                [--threads T] (parallel trial batches over scoped
                threads, bit-identical to 1 thread; default: all cores)
                [--stream] (streaming Monte-Carlo: trial events are
                generated lazily and consumed as they are replayed, so
                no trace is ever materialized — O(1) memory per trial at
                any --trials. Deterministic in --seed and independent of
                --threads, but trials are drawn from the random-access
                per-trial PRNG family, so stats differ from the default
                path's sequential fork chain for trials >= 1)
                [--adaptive] (adaptive Monte-Carlo: trials run in
                --round-sized rounds and stop early once every pairwise
                policy ordering is separated by non-overlapping 95% CIs
                on net throughput, or every CI half-width falls below
                --rel-ci of its mean; implies the --stream trial
                family, reports trials_run + stop_reason, and the stop
                point is independent of --threads)
                [--rel-ci 0.01] (relative CI target; 0 disables the
                precision stop) [--round 16] (trials per round)
                [--min-trials 16] (no early stop before this many)
                [--max-trials N] (trial budget; default --trials)
                transition-cost calibration (defaults are the modeled
                TransitionCosts with the trace's observed failure rate,
                see EXPERIMENTS.md §Policies):
                [--restart-secs 900] [--ckpt-interval 3600]
                [--spare-load-secs 300] [--reshard-secs <modeled>]
                [--reshard-gbs <NVLink GB/s for the reshard model>]
                [--ckpt-write-secs 120] [--power-ramp-secs 60]
                [--cold-load-secs 1800] (cold-tier spare bring-up)
                [--preempt-secs 0] (low-priority preemption latency each
                donated GPU pays when LOWPRI-DONATE reclaims it)
                [--rejoin-secs <modeled>] (ELASTIC-DP live-rejoin bill
                per recovered domain; default is the modeled
                peer-to-peer state-transfer time over the CopyPlan)
                [--failure-rate <events/hour, overrides the observed rate
                CKPT-ADAPTIVE optimizes its Young/Daly interval against>]
                [--validation-sweep-secs S] (periodic SDC validation
                stall: S seconds per GPU per sweep, amortized over the
                --validation-hours cadence and billed over the whole
                horizon; default 0 = validation is free)
                imperfect failure detection (default: detection is
                instant and perfect — bit-identical to earlier builds):
                [--detect-latency S] (mean seconds from a failure to the
                manager seeing it; an undetected hard failure wedges
                the whole job for the window — billed as rollback
                stall — and an undetected straggler gates it at the
                straggler's speed)
                [--degrade-detect-latency S] (same for Degrade events —
                stragglers hide longer; defaults to --detect-latency)
                [--false-positive-rate R] (false alarms per GPU-day;
                each charges the policy's false-positive bill, e.g.
                STRAGGLER-EVICT evicts + re-admits a healthy domain)
                rack power/energy design (power is integrated exactly on
                the event timeline; the table/JSON report mean_power_frac,
                energy_per_token and peak_rack_power_frac per policy):
                [--traditional-rack] (no boost budget at all: NTP-PW's
                boost credit collapses to plain NTP)
                [--thermal-headroom-secs S] (boost sustainable for S
                seconds before recovering at nominal; default infinite —
                bit-exact no-op) [--thermal-recover-frac R] (cooling
                rate relative to heating; 1.0 = 50% duty cycle)
                [--row-domains D] (domains per rack row; enables the
                row-level power cap) [--row-budget-frac B] (row budget
                over nominal; bounds concurrently-boosted domains)
  sweep         --clusters paper-32k-nvl32[,paper-100k-nvl72,...]
                --rate-x 1,2,5,10,20 --spares 0,2,4,6,8
                --scen-x 0.5,1,2,4 (scenario-generator rate multipliers)
                [--scenario correlated] [--strategy dp-drop,ntp,
                ckpt-restart] [--days 15] [--trials 2] [--replicas 16]
                [--pp 8] [--seed 5] [--out PATH]
                [--adaptive] [--rel-ci 0.01] [--round 16]
                [--min-trials 16] [--max-trials N (default --trials)]
                (per-point CI-driven early stop, same semantics as
                `fleet --adaptive`; rows gain trials_run/stop_reason
                and the cube reports total trials run vs budget)
                Runs the whole (rate x spares x scenario-scale x
                cluster) grid in ONE process: every grid point streams
                its trials through the shared response/transition memo
                (ResponseMemo::begin_point marks point boundaries), so
                repeated damage signatures pay one evaluation across the
                WHOLE grid — the cube reports cross_point_hit_rate > 0.
                Emits one JSON cube (stdout by default, --out writes a
                file): one row per grid point with per-policy means over
                trials, plus grid-wide memo scalars.
";

fn cmd_train(args: &mut Args) -> Result<()> {
    use ntp::runtime::Runtime;
    use ntp::train::{Trainer, TrainerConfig};
    let model = args.str_or("model", "tiny");
    let tps = args.usize_list_or("replicas", &[4, 3]);
    let batches = args.usize_list_or("batches", &vec![4; tps.len()]);
    let steps = args.usize_or("steps", 20);
    let lr = args.f64_or("lr", 1e-3) as f32;
    let seed = args.u64_or("seed", 42);
    let fail_at = args.usize_or("fail-at", 0);
    let fail_tp = args.usize_or("fail-tp", 3);
    args.finish()?;
    anyhow::ensure!(tps.len() == batches.len(), "--replicas and --batches lengths differ");

    let rt = Runtime::with_default_dir()?;
    let replicas: Vec<(usize, usize)> = tps.into_iter().zip(batches).collect();
    println!("# training {model} with replicas {replicas:?}");
    let mut trainer = Trainer::new(&rt, &TrainerConfig {
        model: model.clone(),
        replicas,
        lr,
        seed,
    })?;
    for step in 0..steps {
        if fail_at > 0 && step == fail_at {
            println!("! injecting failure: replica 1 -> TP{fail_tp}");
            trainer.inject_failure(&rt, 1, fail_tp, trainer.replicas[1].batch())?;
        }
        let rec = trainer.step()?;
        if step < 3 || (step + 1) % 10 == 0 {
            println!(
                "step {:>4}  loss {:.4}  wall {:.2}s  exec {:.2}s  sync {:.1}ms",
                rec.step,
                rec.loss,
                rec.wall_secs,
                rec.execute_secs,
                rec.sync.total() * 1e3
            );
        }
    }
    println!("tokens/sec (last 10 steps): {:.1}", trainer.tokens_per_sec(10));
    Ok(())
}

fn cmd_plan(args: &mut Args) -> Result<()> {
    let model = presets::model(&args.str_or("model", "gpt-480b"))?;
    let cluster = presets::cluster(&args.str_or("cluster", "paper-32k-nvl32"))?;
    let seq = args.usize_or("seq", 8192);
    let tp_cap = args.usize_or("tp-cap", cluster.domain_size);
    args.finish()?;
    let w = WorkloadConfig { seq_len: seq, minibatch_tokens: 16 << 20, dtype: Dtype::BF16 };
    let best = best_config(&model, &w, &cluster, tp_cap, SimParams::default())
        .ok_or_else(|| anyhow::anyhow!("no legal config"))?;
    println!("best config: {}", best.cfg.label());
    println!("tokens/s/GPU: {:.1}", best.tokens_per_sec_per_gpu);
    let b = best.breakdown;
    let mut t = Table::new(&["component", "seconds", "share"]);
    for (name, v) in [
        ("compute", b.compute),
        ("tp_comm", b.tp_comm),
        ("pp_bubble", b.pp_bubble),
        ("pp_p2p", b.pp_p2p),
        ("dp_exposed", b.dp_exposed),
    ] {
        t.row(&[name.into(), f3(v), pct(v / b.total())]);
    }
    t.print();
    Ok(())
}

fn cmd_simulate(args: &mut Args) -> Result<()> {
    let model = presets::model(&args.str_or("model", "gpt-480b"))?;
    let cluster = presets::cluster(&args.str_or("cluster", "paper-32k-nvl32"))?;
    let seq = args.usize_or("seq", 16_384);
    let cfg = ParallelConfig {
        tp: args.usize_or("tp", 32),
        pp: args.usize_or("pp", 8),
        dp: args.usize_or("dp", 128),
        microbatch: args.usize_or("microbatch", 1),
    };
    args.finish()?;
    let w = WorkloadConfig { seq_len: seq, minibatch_tokens: 16 << 20, dtype: Dtype::BF16 };
    let sim = IterationModel::new(model, w, cluster, SimParams::default());
    let b = sim.healthy_iteration(&cfg);
    println!("config {}: iteration {:.3}s, util {}", cfg.label(), b.total(), pct(b.utilization()));
    Ok(())
}

fn cmd_availability(args: &mut Args) -> Result<()> {
    let cluster = presets::cluster(&args.str_or("cluster", "paper-32k-nvl32"))?;
    let tps = args.usize_list_or("tp", &[8, 16, 32, 64]);
    let samples = args.usize_or("samples", 200);
    let model_name = args.str_or("model", "gpt-480b");
    let policy = args.opt_str("policy").map(|n| registry::parse(&n)).transpose()?;
    args.finish()?;
    let headers: &[&str] = if policy.is_some() {
        &["failed%", "TP", "avail(median)", "avail(min)", "tput(policy)"]
    } else {
        &["failed%", "TP", "avail(median)", "avail(min)"]
    };
    let mut t = Table::new(headers);
    let mut rng = Rng::new(1);
    for &tp in &tps {
        let topo = Topology::of(cluster.n_gpus / tp * tp, tp, tp.min(4));
        // Policy throughput needs a strategy table for this TP degree:
        // one pipeline stage per 4 domains, DP over the rest.
        let per_replica = 4.min(topo.n_domains());
        let table = policy
            .map(|_| -> Result<(StrategyTable, ParallelConfig)> {
                let cfg = ParallelConfig {
                    tp,
                    pp: per_replica,
                    dp: topo.n_domains() / per_replica,
                    microbatch: 1,
                };
                let w = WorkloadConfig {
                    seq_len: 16_384,
                    minibatch_tokens: 16 << 20,
                    dtype: Dtype::BF16,
                };
                let sim = IterationModel::new(
                    presets::model(&model_name)?,
                    w,
                    cluster.clone(),
                    SimParams::default(),
                );
                Ok((StrategyTable::build(&sim, &cfg, &RackDesign::default()), cfg))
            })
            .transpose()?;
        for &frac in &[0.0005, 0.001, 0.002, 0.004] {
            let n_failed = (frac * topo.n_gpus as f64) as usize;
            let mut avails = Vec::with_capacity(samples);
            let mut tput_sum = 0.0;
            for _ in 0..samples {
                let failed =
                    sample_failed_gpus(&topo, n_failed, BlastRadius::Single, &mut rng);
                let scenario = scenario_from_failed(&topo, &failed);
                if let (Some(p), Some((table, cfg))) = (policy, table.as_ref()) {
                    let ctx = PolicyCtx {
                        table,
                        domain_size: topo.domain_size,
                        domains_per_replica: cfg.pp,
                        packed: true,
                        spares: None,
                        n_gpus: topo.n_gpus,
                        transition: None,
                    };
                    let resp = p.respond(&ctx, &scenario.domain_healthy);
                    tput_sum += resp.throughput(table.full_local_batch);
                }
                avails.push(scenario.availability_domain_drop());
            }
            avails.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut row = vec![
                pct(frac),
                format!("{tp}"),
                f4(avails[samples / 2]),
                f4(avails[0]),
            ];
            if policy.is_some() {
                row.push(f4(tput_sum / samples as f64));
            }
            t.row(&row);
        }
    }
    t.print();
    Ok(())
}

/// Parse the scenario-generator flags shared by `trace` and `fleet`:
/// `--scenario` picks the generator, the rest scale or override the
/// calibrated [`ScenarioConfig`] defaults.
fn scenario_from_args(args: &mut Args) -> Result<ScenarioConfig> {
    let kind = ScenarioKind::parse(&args.str_or("scenario", "independent"))?;
    let mut cfg = ScenarioConfig::new(kind);
    cfg.correlated = cfg.correlated.scaled(args.f64_or("corr-x", 1.0));
    cfg.straggler = cfg.straggler.scaled(args.f64_or("straggler-x", 1.0));
    cfg.sdc = cfg.sdc.scaled(args.f64_or("sdc-x", 1.0));
    if let Some(lo) = args.opt_f64("slowdown-lo") {
        cfg.straggler.slowdown.0 = lo;
    }
    if let Some(hi) = args.opt_f64("slowdown-hi") {
        cfg.straggler.slowdown.1 = hi;
    }
    if let Some(v) = args.opt_f64("validation-hours") {
        cfg.sdc.validation_interval_hours = v;
    }
    let (lo, hi) = cfg.straggler.slowdown;
    anyhow::ensure!(
        lo > 0.0 && lo <= hi && hi <= 1.0,
        "straggler slowdown range must satisfy 0 < --slowdown-lo <= --slowdown-hi <= 1 \
         (got {lo}..{hi})"
    );
    anyhow::ensure!(
        cfg.sdc.validation_interval_hours > 0.0,
        "--validation-hours must be positive"
    );
    Ok(cfg)
}

/// Record a scenario's kind and generator parameters into a
/// [`JsonReport`] (the reproducibility block `fleet --json` and the
/// fig12 bench both carry).
fn scenario_report(rep: &mut JsonReport, scen: &ScenarioConfig) {
    rep.label("scenario", scen.kind.name());
    match scen.kind {
        ScenarioKind::Independent => {}
        ScenarioKind::Correlated => {
            rep.scalar("corr_node_events_per_node_day", scen.correlated.node_events_per_node_day);
            rep.scalar(
                "corr_domain_events_per_domain_day",
                scen.correlated.domain_events_per_domain_day,
            );
            rep.scalar("corr_recovery_hours_lo", scen.correlated.recovery_hours.0);
            rep.scalar("corr_recovery_hours_hi", scen.correlated.recovery_hours.1);
        }
        ScenarioKind::Straggler => {
            rep.scalar("straggler_events_per_gpu_day", scen.straggler.events_per_gpu_day);
            rep.scalar("straggler_slowdown_lo", scen.straggler.slowdown.0);
            rep.scalar("straggler_slowdown_hi", scen.straggler.slowdown.1);
            rep.scalar("straggler_mean_duration_hours", scen.straggler.mean_duration_hours);
        }
        ScenarioKind::Sdc => {
            rep.scalar("sdc_events_per_gpu_day", scen.sdc.events_per_gpu_day);
            rep.scalar("sdc_validation_interval_hours", scen.sdc.validation_interval_hours);
        }
    }
}

fn cmd_trace(args: &mut Args) -> Result<()> {
    let cluster = presets::cluster(&args.str_or("cluster", "llama3-16k-nvl8"))?;
    let days = args.f64_or("days", 15.0);
    let rate_x = args.f64_or("rate-x", 1.0);
    let seed = args.u64_or("seed", 7);
    let scen = scenario_from_args(args)?;
    args.finish()?;
    let topo = Topology::new(&cluster);
    let model = FailureModel::llama3().scaled(rate_x);
    let mut rng = Rng::new(seed);
    let trace = generate_scenario(&topo, &model, &scen, days * 24.0, &mut rng);
    let series = trace.failed_series(&topo, BlastRadius::Single, 1.0);
    let fracs: Vec<f64> =
        series.iter().map(|&(_, f)| f as f64 / topo.n_gpus as f64).collect();
    let (mut fails, mut degrades, mut sdcs) = (0usize, 0usize, 0usize);
    for ev in &trace.events {
        match ev.kind {
            EventKind::Fail => fails += 1,
            EventKind::Degrade { .. } => degrades += 1,
            EventKind::Sdc { .. } => sdcs += 1,
        }
    }
    println!("scenario: {}", scen.kind.name());
    println!(
        "events: {} (fail {fails}, degrade {degrades}, sdc {sdcs})",
        trace.events.len()
    );
    println!("peak failed fraction: {}", pct(fracs.iter().cloned().fold(0.0, f64::max)));
    println!(
        "time above 0.1% failed: {}",
        pct(trace.time_above_fraction(&topo, BlastRadius::Single, 1.0, 0.001))
    );
    Ok(())
}

fn cmd_reshard_plan(args: &mut Args) -> Result<()> {
    let k = args.usize_or("k", 12_288);
    let n1 = args.usize_or("n1", 32);
    let n2 = args.usize_or("n2", 30);
    args.finish()?;
    let map = ShardMap::build(k, n1, n2);
    let plan = ReshardPlan::from_map(&map);
    println!("k={k} n1={n1} n2={n2}");
    let mut t = Table::new(&["gpu", "role", "units", "sent", "received"]);
    for g in 0..n1 {
        let role = if g < n2 { "sync" } else { "offload" };
        let recv = if g < n2 { plan.received_by(g) } else { 0 };
        t.row(&[
            format!("{g}"),
            role.into(),
            format!("{}", map.comp_size(g)),
            format!("{}", plan.sent_by(g)),
            format!("{recv}"),
        ]);
    }
    t.print();
    let unit_bytes = 2 * 2 * args.usize_or("hidden", 12_288);
    println!(
        "max bytes/GPU: {:.2} MB; total moved: {:.2} MB",
        plan.max_bytes_per_gpu(unit_bytes) as f64 / 1e6,
        plan.total_bytes(unit_bytes) as f64 / 1e6
    );
    Ok(())
}

fn cmd_power(args: &mut Args) -> Result<()> {
    let model = presets::model(&args.str_or("model", "gpt-480b"))?;
    let cluster = presets::cluster(&args.str_or("cluster", "paper-32k-nvl32"))?;
    let cfg = ParallelConfig {
        tp: args.usize_or("tp", 32),
        pp: args.usize_or("pp", 8),
        dp: args.usize_or("dp", 128),
        microbatch: 1,
    };
    args.finish()?;
    let w = WorkloadConfig { seq_len: 16_384, minibatch_tokens: 16 << 20, dtype: Dtype::BF16 };
    let sim = IterationModel::new(model, w, cluster, SimParams::default());
    let full_local = sim.work.global_batch() / cfg.dp;
    let target = sim.healthy_iteration(&cfg).total();
    let rack = RackDesign::default();
    let mut t = Table::new(&["TP", "power", "rel iter time"]);
    t.row(&["32 (healthy)".into(), "1.00x".into(), f3(1.0)]);
    for tp in [31, 30, 29, 28] {
        match min_boost_for(&sim, &cfg, tp, full_local, target, &rack, &sim.cluster.gpu) {
            BoostDecision::Boost { power_frac } => {
                let perf = sim.cluster.gpu.perf_at_power(power_frac);
                let rel = sim.ntp_iteration(&cfg, tp, full_local, perf).total() / target;
                t.row(&[format!("{tp}-PW"), format!("{:.2}x", power_frac), f3(rel)]);
            }
            BoostDecision::NotNeeded => t.row(&[format!("{tp}-PW"), "1.00x".into(), f3(1.0)]),
            BoostDecision::Infeasible { max_power_frac } => {
                t.row(&[format!("{tp}-PW"), format!(">{:.2}x", max_power_frac), "inf".into()])
            }
        }
    }
    t.print();
    Ok(())
}

fn cmd_fleet(args: &mut Args) -> Result<()> {
    let policies = registry::parse_list(&args.str_or("strategy", "ntp"))?;
    let days = args.f64_or("days", 15.0);
    // `--spares N` switches to fixed-minibatch mode with N spare
    // domains; omitting it runs the flexible-minibatch semantics.
    // `--warm-spares W` [+ `--cold-spares C`] is the two-tier spelling
    // (total = W + C); `--cold-spares` alone carves the cold tier out
    // of an explicit `--spares` total.
    let spares_flag = args.opt_usize("spares");
    let warm_spares = args.opt_usize("warm-spares");
    let cold_spares = args.opt_usize("cold-spares");
    let n_replicas = args.usize_or("replicas", 16);
    let rate_x = args.f64_or("rate-x", 10.0);
    let seed = args.u64_or("seed", 5);
    let json = args.flag("json");
    let no_transitions = args.flag("no-transitions");
    let cluster_name = args.str_or("cluster", "paper-32k-nvl32");
    let pp = args.usize_or("pp", 8);
    // Time stepping: exact event-boundary integration is the default;
    // --grid-hours opts back into the legacy fixed-grid sampling.
    let exact_flag = args.flag("exact");
    let grid_hours = args.opt_f64("grid-hours");
    // Monte-Carlo: N independent traces (per-trial forked PRNG
    // streams), batched over scoped threads.
    let trials = args.usize_or("trials", 1).max(1);
    let threads = match args.opt_usize("threads") {
        Some(t) => t.max(1),
        None => ntp::util::par::num_threads(),
    };
    // Streaming Monte-Carlo: generate trial events lazily and consume
    // them as they replay — no materialized trace, O(1) memory per
    // trial at any --trials.
    let stream = args.flag("stream");
    // Adaptive Monte-Carlo (manager::adaptive): CI-driven early
    // stopping at round boundaries over the streaming trial family;
    // --trials doubles as the default budget.
    let adaptive = args.flag("adaptive");
    let rel_ci = args.opt_f64("rel-ci");
    let round = args.opt_usize("round");
    let min_trials = args.opt_usize("min-trials");
    let max_trials = args.opt_usize("max-trials");
    // Transition-cost calibration knobs (defaults: the modeled
    // TransitionCosts — see EXPERIMENTS.md §Policies for the published
    // latencies the defaults are calibrated against).
    let restart_secs = args.opt_f64("restart-secs");
    let ckpt_interval = args.opt_f64("ckpt-interval");
    let spare_load_secs = args.opt_f64("spare-load-secs");
    let reshard_secs = args.opt_f64("reshard-secs");
    let reshard_gbs = args.opt_f64("reshard-gbs");
    let ckpt_write_secs = args.opt_f64("ckpt-write-secs");
    let power_ramp_secs = args.opt_f64("power-ramp-secs");
    let cold_load_secs = args.opt_f64("cold-load-secs");
    let preempt_secs = args.opt_f64("preempt-secs");
    let rejoin_secs = args.opt_f64("rejoin-secs");
    let failure_rate = args.opt_f64("failure-rate");
    let validation_sweep_secs = args.opt_f64("validation-sweep-secs");
    // Imperfect detection knobs (seconds / per-GPU-day). Deliberately
    // NOT in the --no-transitions conflict list: delaying when the
    // replayer sees events changes the observed stats even with cost
    // billing disabled (the stall/false-positive *bills* ride the
    // transition channel and vanish with it).
    let detect_latency = args.opt_f64("detect-latency");
    let degrade_detect_latency = args.opt_f64("degrade-detect-latency");
    let false_positive_rate = args.opt_f64("false-positive-rate");
    // Rack power/thermal design knobs (energy co-simulation). Defaults
    // reproduce RackDesign::default() bit-for-bit, so runs without
    // these flags match the pre-energy goldens on every existing key.
    let traditional_rack = args.flag("traditional-rack");
    let thermal_headroom_secs = args.opt_f64("thermal-headroom-secs");
    let thermal_recover_frac = args.opt_f64("thermal-recover-frac");
    let row_domains = args.opt_usize("row-domains");
    let row_budget_frac = args.opt_f64("row-budget-frac");
    // Scenario diversity: which failure process the trace generator
    // draws from (independent per-GPU Poisson by default).
    let scen = scenario_from_args(args)?;
    args.finish()?;
    anyhow::ensure!(
        !(no_transitions
            && [
                restart_secs,
                ckpt_interval,
                spare_load_secs,
                reshard_secs,
                reshard_gbs,
                ckpt_write_secs,
                power_ramp_secs,
                cold_load_secs,
                preempt_secs,
                rejoin_secs,
                failure_rate,
                validation_sweep_secs,
            ]
            .iter()
            .any(|o| o.is_some())),
        "--no-transitions conflicts with transition-cost flags \
         (--restart-secs/--ckpt-interval/--spare-load-secs/--reshard-secs/--reshard-gbs/\
          --ckpt-write-secs/--power-ramp-secs/--cold-load-secs/--preempt-secs/\
          --rejoin-secs/--failure-rate/--validation-sweep-secs)"
    );
    anyhow::ensure!(
        adaptive
            || (rel_ci.is_none()
                && round.is_none()
                && min_trials.is_none()
                && max_trials.is_none()),
        "--rel-ci/--round/--min-trials/--max-trials require --adaptive"
    );
    anyhow::ensure!(
        rel_ci.map(|r| r >= 0.0).unwrap_or(true),
        "--rel-ci must be non-negative (0 disables the precision stop)"
    );
    let rule = StopRule {
        round: round.unwrap_or(16),
        min_trials: min_trials.unwrap_or(16),
        max_trials: max_trials.unwrap_or(trials),
        rel_ci: rel_ci.unwrap_or(0.01),
        margin: 0.0,
    }
    .normalized();
    anyhow::ensure!(
        !(spares_flag.is_some() && warm_spares.is_some()),
        "--spares (total pool) and --warm-spares (tiered spelling) conflict; \
         pass one or the other"
    );
    anyhow::ensure!(
        !(cold_spares.is_some() && spares_flag.is_none() && warm_spares.is_none()),
        "--cold-spares needs a pool: pass --spares TOTAL (cold carved from it) \
         or --warm-spares W (total = W + C)"
    );
    let spares: Option<usize> = match (spares_flag, warm_spares) {
        (Some(total), None) => Some(total),
        (None, Some(w)) => Some(w + cold_spares.unwrap_or(0)),
        (None, None) => None,
        (Some(_), Some(_)) => unreachable!("rejected above"),
    };
    let cold_domains = cold_spares.unwrap_or(0);
    if let Some(total) = spares {
        anyhow::ensure!(
            cold_domains <= total,
            "--cold-spares ({cold_domains}) exceeds the spare pool total ({total})"
        );
    }
    anyhow::ensure!(
        [detect_latency, degrade_detect_latency, false_positive_rate]
            .iter()
            .flatten()
            .all(|&v| v >= 0.0),
        "detection knobs (--detect-latency/--degrade-detect-latency/--false-positive-rate) \
         must be non-negative"
    );
    // None (no flag) and an all-zero model are both instant-perfect
    // detection; DetectionModel::active treats them identically, so
    // either spelling reproduces the pre-detection results bit-for-bit.
    let detect = if detect_latency.is_some()
        || degrade_detect_latency.is_some()
        || false_positive_rate.is_some()
    {
        let fail_h = detect_latency.unwrap_or(0.0) / 3600.0;
        Some(DetectionModel {
            fail_latency_hours: fail_h,
            degrade_latency_hours: degrade_detect_latency
                .map(|s| s / 3600.0)
                .unwrap_or(fail_h),
            false_positives_per_gpu_day: false_positive_rate.unwrap_or(0.0),
            jitter_frac: 0.0,
        })
    } else {
        None
    };
    anyhow::ensure!(
        validation_sweep_secs.map(|s| s >= 0.0).unwrap_or(true),
        "--validation-sweep-secs must be non-negative"
    );
    anyhow::ensure!(
        !(reshard_secs.is_some() && reshard_gbs.is_some()),
        "--reshard-secs and --reshard-gbs both set the reshard cost; pass one or the other"
    );
    anyhow::ensure!(
        !(exact_flag && grid_hours.is_some()),
        "--exact (the default) conflicts with --grid-hours; pass one or the other"
    );
    let mode = match grid_hours {
        Some(h) => {
            anyhow::ensure!(h > 0.0, "--grid-hours must be positive");
            StepMode::Grid(h)
        }
        None => StepMode::Exact,
    };

    let model = presets::model("gpt-480b")?;
    let cluster = presets::cluster(&cluster_name)?;
    let tp = cluster.domain_size;
    let w = WorkloadConfig { seq_len: 16_384, minibatch_tokens: 16 << 20, dtype: Dtype::BF16 };
    let cfg = ParallelConfig { tp, pp, dp: n_replicas, microbatch: 1 };
    let gpus_per_node = cluster.gpus_per_node;
    let sim = IterationModel::new(model, w, cluster, SimParams::default());
    anyhow::ensure!(
        thermal_recover_frac.map(|r| r > 0.0).unwrap_or(true),
        "--thermal-recover-frac must be positive"
    );
    anyhow::ensure!(
        !(traditional_rack
            && (thermal_headroom_secs.is_some()
                || thermal_recover_frac.is_some()
                || row_domains.is_some()
                || row_budget_frac.is_some())),
        "--traditional-rack (no boost at all) conflicts with the boost-shaping flags \
         (--thermal-headroom-secs/--thermal-recover-frac/--row-domains/--row-budget-frac)"
    );
    let mut rack =
        if traditional_rack { RackDesign::traditional() } else { RackDesign::default() };
    if let Some(s) = thermal_headroom_secs {
        rack.thermal.headroom_secs = s;
    }
    if let Some(r) = thermal_recover_frac {
        rack.thermal.recover_frac = r;
    }
    if let Some(d) = row_domains {
        rack.row_domains = d;
    }
    if let Some(b) = row_budget_frac {
        rack.row_budget_frac = b;
    }
    let table = StrategyTable::build(&sim, &cfg, &rack);
    let n_domains = n_replicas * cfg.pp + spares.unwrap_or(0);
    let topo = Topology::of(n_domains * tp, tp, gpus_per_node);
    let fmodel = FailureModel::llama3().scaled(rate_x);
    // Default path: one forked PRNG stream per Monte-Carlo trial —
    // trace i is the same for any --trials >= i+1 and any --threads.
    // --stream and --adaptive paths: nothing materialized; trials come
    // from the random-access TrialGen family instead (adaptive sizes
    // the family by its trial budget, not --trials).
    let stream_like = stream || adaptive;
    let gen_trials = if adaptive { rule.max_trials } else { trials };
    let gen = TrialGen::new(&topo, &fmodel, &scen, days * 24.0, seed, gen_trials);
    let traces: Vec<Trace> = if stream_like {
        Vec::new()
    } else {
        let mut rng = Rng::new(seed);
        (0..trials)
            .map(|i| {
                let mut r = rng.fork(i as u64);
                generate_scenario(&topo, &fmodel, &scen, days * 24.0, &mut r)
            })
            .collect()
    };
    let transition = if no_transitions {
        None
    } else {
        // The observed event rate of the generated trace batch feeds
        // CKPT-ADAPTIVE's Young/Daly interval (override with
        // --failure-rate). One pooled rate: the whole batch must share
        // one cost model to share one response memo. The streaming path
        // counts events by draining throwaway streams (O(1) memory,
        // same totals its trials will replay).
        let mut t = if stream_like {
            let mut events = 0usize;
            for i in 0..gen.trials {
                let mut s = gen.stream_for(i);
                while s.next_event().is_some() {
                    events += 1;
                }
            }
            // Adaptive runs pool the rate over the whole budget family
            // (the rate must be fixed before any trial runs — the cost
            // model is part of the shared memo fingerprint).
            let total_hours = days * 24.0 * gen.trials as f64;
            let mut t = TransitionCosts::model(&sim, &cfg);
            if total_hours > 0.0 {
                t.failure_rate_per_hour = events as f64 / total_hours;
            }
            t
        } else {
            TransitionCosts::model(&sim, &cfg).with_observed_rate_over(&traces)
        };
        if let Some(gbs) = reshard_gbs {
            t.reshard_secs = reshard_transition_secs_over(&sim, &cfg, gbs);
        }
        if let Some(s) = reshard_secs {
            t.reshard_secs = s;
        }
        if let Some(s) = restart_secs {
            t.restart_secs = s;
        }
        if let Some(s) = ckpt_interval {
            t.checkpoint_interval_secs = s;
        }
        if let Some(s) = spare_load_secs {
            t.spare_load_secs = s;
        }
        if let Some(s) = ckpt_write_secs {
            t.ckpt_write_secs = s;
        }
        if let Some(s) = power_ramp_secs {
            t.power_ramp_secs = s;
        }
        if let Some(s) = cold_load_secs {
            t.cold_spare_load_secs = s;
        }
        if let Some(s) = preempt_secs {
            t.preempt_secs = s;
        }
        if let Some(s) = rejoin_secs {
            t.rejoin_secs = s;
        }
        if let Some(r) = failure_rate {
            t.failure_rate_per_hour = r;
        }
        if let Some(s) = validation_sweep_secs {
            // CLI takes seconds of stall per sweep; the model field is
            // the amortized stall per simulated hour at the validation
            // cadence (--validation-hours).
            t.validation_sweep_secs = s / scen.sdc.validation_interval_hours;
        }
        Some(t)
    };

    // One shared-sweep pass per trace evaluates every requested policy
    // (the trace is replayed once and repeated damage signatures are
    // memoized); trial batches fan out over scoped threads with
    // per-thread memos, bit-identical to a single-thread run.
    let min_tp = min_supported_tp(tp);
    let msim = MultiPolicySim {
        topo: &topo,
        table: &table,
        domains_per_replica: cfg.pp,
        policies: &policies,
        spares: spares.map(|s| SparePolicy { spare_domains: s, cold_domains, min_tp }),
        packed: true,
        blast: BlastRadius::Single,
        transition,
        detect,
    };
    // Streaming keeps O(1) memory per trial, so per-trial stats are
    // never stored: fold them into per-policy aggregates (plain sums
    // for means + Welford moments for the CI). The stored path keeps
    // per-trial stats and derives the same numbers from them.
    let (per_trial, stream_agg, memo, adaptive_run) = if adaptive {
        let out = msim.run_trials_adaptive(&gen, mode, &rule, threads);
        (Vec::new(), Some(out.aggs), out.memo, Some((out.trials_run, out.reason)))
    } else if stream {
        let (agg, memo) = msim.run_trials_stream_agg_par(&gen, mode, threads);
        (Vec::new(), Some(agg), memo, None)
    } else {
        let (per_trial, memo) = msim.run_trials_par(&traces, mode, threads);
        (per_trial, None, memo, None)
    };

    let mut out = Table::new(&[
        "policy", "mean tput", "±95%", "net tput", "tput/GPU", "paused", "downtime",
        "donated", "spares used", "transitions", "power", "J/tok", "peak rack",
    ]);
    let mut rep = JsonReport::new("fleet");
    rep.scalar("days", days);
    rep.scalar("rate_x", rate_x);
    // Reproducibility block: the PRNG seed, the scenario kind and the
    // generator parameters that produced the trace batch.
    rep.scalar("seed", seed as f64);
    scenario_report(&mut rep, &scen);
    rep.scalar("replicas", n_replicas as f64);
    rep.scalar("spares", spares.unwrap_or(0) as f64);
    rep.scalar("cold_spares", cold_domains as f64);
    if let Some(d) = &detect {
        rep.scalar("detect_latency_secs", d.fail_latency_hours * 3600.0);
        rep.scalar("degrade_detect_latency_secs", d.degrade_latency_hours * 3600.0);
        rep.scalar("false_positive_rate_per_gpu_day", d.false_positives_per_gpu_day);
    }
    rep.scalar("n_gpus", topo.n_gpus as f64);
    rep.scalar("trials", trials as f64);
    rep.scalar("threads", threads as f64);
    rep.scalar("stream", if stream { 1.0 } else { 0.0 });
    // Adaptive keys appear only under --adaptive, so runs without the
    // flag stay bit-identical to earlier builds.
    if let Some((trials_run, reason)) = adaptive_run {
        rep.scalar("adaptive", 1.0);
        rep.scalar("round", rule.round as f64);
        rep.scalar("min_trials", rule.min_trials as f64);
        rep.scalar("max_trials", rule.max_trials as f64);
        rep.scalar("rel_ci", rule.rel_ci);
        rep.scalar("trials_run", trials_run as f64);
        rep.label("stop_reason", reason.as_str());
    }
    rep.scalar("exact", if grid_hours.is_none() { 1.0 } else { 0.0 });
    if let Some(h) = grid_hours {
        rep.scalar("grid_hours", h);
    }
    // Merged across per-thread memos (MemoStats::merge).
    rep.scalar("memo_hit_rate", memo.hit_rate());
    rep.scalar("memo_entries", memo.unique_entries as f64);
    rep.scalar("transition_memo_hit_rate", memo.transition_hit_rate());
    if let Some(t) = &transition {
        rep.scalar("observed_failure_rate_per_hour", t.failure_rate_per_hour);
        rep.scalar("validation_sweep_secs_per_hour", t.validation_sweep_secs);
    }
    // Per-policy Monte-Carlo means over the trial batch (for
    // --trials 1 these are exactly the single trace's stats). The
    // stream path never stored per-trial stats, so it reads the same
    // numbers off the fold-as-you-go aggregates; both paths report a
    // Welford 95% CI on mean throughput without re-walking trials.
    let n = per_trial.len() as f64;
    let mean_over = |f: &dyn Fn(&FleetStats) -> f64, pi: usize| -> f64 {
        per_trial.iter().map(|trial| f(&trial[pi])).sum::<f64>() / n
    };
    for (pi, policy) in policies.iter().enumerate() {
        let (
            mean_tput,
            net_tput,
            tput_per_gpu,
            paused,
            downtime,
            donated,
            spares_used,
            transitions,
            tput_ci95,
            mean_power,
            energy_per_token,
            peak_rack_power,
        ) = match &stream_agg {
            Some(agg) => {
                let a = &agg[pi];
                (
                    a.mean_tput(),
                    a.mean_net_tput(),
                    a.mean_tput_per_gpu(),
                    a.mean_paused_frac(),
                    a.mean_downtime_frac(),
                    a.mean_donated(),
                    a.mean_spares_used(),
                    a.mean_transitions(),
                    a.tput_ci95(),
                    a.mean_power_frac(),
                    a.mean_energy_per_token(),
                    a.peak_rack_power_frac(),
                )
            }
            None => {
                let mut w = Welford::default();
                for trial in &per_trial {
                    w.push(trial[pi].mean_throughput);
                }
                (
                    mean_over(&|s| s.mean_throughput, pi),
                    mean_over(&|s| s.net_throughput(), pi),
                    mean_over(&|s| s.throughput_per_gpu, pi),
                    mean_over(&|s| s.paused_frac, pi),
                    mean_over(&|s| s.downtime_frac, pi),
                    mean_over(&|s| s.mean_donated, pi),
                    mean_over(&|s| s.mean_spares_used, pi),
                    mean_over(&|s| s.transitions as f64, pi),
                    w.ci95(),
                    mean_over(&|s| s.mean_power_frac, pi),
                    mean_over(&|s| s.energy_per_token(), pi),
                    per_trial
                        .iter()
                        .map(|trial| trial[pi].peak_rack_power_frac)
                        .fold(0.0f64, f64::max),
                )
            }
        };
        out.row(&[
            policy.name().into(),
            f4(mean_tput),
            f4(tput_ci95),
            f4(net_tput),
            f4(tput_per_gpu),
            pct(paused),
            pct(downtime),
            f4(donated),
            f2(spares_used),
            if trials == 1 {
                format!("{}", transitions as usize)
            } else {
                f2(transitions)
            },
            f4(mean_power),
            f4(energy_per_token),
            f4(peak_rack_power),
        ]);
        let key = policy.name().to_ascii_lowercase().replace('-', "_");
        rep.scalar(&format!("{key}_mean_tput"), mean_tput);
        rep.scalar(&format!("{key}_tput_ci95"), tput_ci95);
        rep.scalar(&format!("{key}_net_tput"), net_tput);
        rep.scalar(&format!("{key}_tput_per_gpu"), tput_per_gpu);
        rep.scalar(&format!("{key}_paused_frac"), paused);
        rep.scalar(&format!("{key}_downtime_frac"), downtime);
        rep.scalar(&format!("{key}_donated"), donated);
        rep.scalar(&format!("{key}_transitions"), transitions);
        rep.scalar(&format!("{key}_mean_power_frac"), mean_power);
        rep.scalar(&format!("{key}_energy_per_token"), energy_per_token);
        rep.scalar(&format!("{key}_peak_rack_power_frac"), peak_rack_power);
    }
    if json {
        println!("{}", rep.to_json().pretty());
    } else {
        out.print();
        if let Some((trials_run, reason)) = adaptive_run {
            println!(
                "adaptive: stopped after {trials_run}/{} trials ({})",
                rule.max_trials,
                reason.as_str()
            );
        }
    }
    Ok(())
}

/// Memo-shared parameter-grid sweep: the whole
/// (rate × spares × scenario-scale × cluster) grid in one process, one
/// JSON cube. Every grid point streams its Monte-Carlo trials
/// ([`MultiPolicySim::run_trials_stream`], nothing materialized)
/// through ONE [`ResponseMemo`] per cluster, with
/// [`ResponseMemo::begin_point`] marking point boundaries so the cube
/// can report how much evaluation work later points inherited from
/// earlier ones (`cross_point_hit_rate`). The cost model is pinned per
/// cluster (no per-point observed rate — a shared memo requires one
/// transition fingerprint), so points differ only in their trace
/// process and spare pool.
fn cmd_sweep(args: &mut Args) -> Result<()> {
    let cluster_names: Vec<String> = args
        .str_or("clusters", "paper-32k-nvl32")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let rate_xs = args.f64_list_or("rate-x", &[1.0, 2.0, 5.0, 10.0, 20.0]);
    let spares_list = args.usize_list_or("spares", &[0, 2, 4, 6, 8]);
    let scen_xs = args.f64_list_or("scen-x", &[0.5, 1.0, 2.0, 4.0]);
    let kind = ScenarioKind::parse(&args.str_or("scenario", "correlated"))?;
    let policies = registry::parse_list(&args.str_or("strategy", "dp-drop,ntp,ckpt-restart"))?;
    let days = args.f64_or("days", 15.0);
    let trials = args.usize_or("trials", 2).max(1);
    let n_replicas = args.usize_or("replicas", 16);
    let pp = args.usize_or("pp", 8);
    let seed = args.u64_or("seed", 5);
    let out_path = args.opt_str("out");
    // Per-point adaptive early stop (same rule semantics as `fleet
    // --adaptive`); trials stream through the shared memo, so
    // cross-point reuse keeps accruing.
    let adaptive = args.flag("adaptive");
    let rel_ci = args.opt_f64("rel-ci");
    let round = args.opt_usize("round");
    let min_trials = args.opt_usize("min-trials");
    let max_trials = args.opt_usize("max-trials");
    args.finish()?;
    anyhow::ensure!(
        adaptive
            || (rel_ci.is_none()
                && round.is_none()
                && min_trials.is_none()
                && max_trials.is_none()),
        "--rel-ci/--round/--min-trials/--max-trials require --adaptive"
    );
    anyhow::ensure!(
        rel_ci.map(|r| r >= 0.0).unwrap_or(true),
        "--rel-ci must be non-negative (0 disables the precision stop)"
    );
    let rule = StopRule {
        round: round.unwrap_or(16),
        min_trials: min_trials.unwrap_or(16),
        max_trials: max_trials.unwrap_or(trials),
        rel_ci: rel_ci.unwrap_or(0.01),
        margin: 0.0,
    }
    .normalized();
    anyhow::ensure!(!cluster_names.is_empty(), "--clusters must name at least one cluster");
    anyhow::ensure!(
        !(rate_xs.is_empty() || spares_list.is_empty() || scen_xs.is_empty()),
        "--rate-x/--spares/--scen-x lists must be non-empty"
    );
    anyhow::ensure!(
        rate_xs.iter().chain(&scen_xs).all(|&x| x > 0.0),
        "--rate-x and --scen-x multipliers must be positive"
    );

    let model = presets::model("gpt-480b")?;
    let w = WorkloadConfig { seq_len: 16_384, minibatch_tokens: 16 << 20, dtype: Dtype::BF16 };
    let grid_points =
        cluster_names.len() * rate_xs.len() * spares_list.len() * scen_xs.len();
    let mut rep = JsonReport::new("sweep");
    rep.scalar("grid_points", grid_points as f64);
    rep.scalar("days", days);
    rep.scalar("trials", trials as f64);
    rep.scalar("replicas", n_replicas as f64);
    rep.scalar("seed", seed as f64);
    rep.label("scenario", kind.name());
    rep.label("clusters", &cluster_names.join(","));
    rep.label(
        "policies",
        &policies.iter().map(|p| p.name()).collect::<Vec<_>>().join(","),
    );
    let mut merged = MemoStats::default();
    let mut trials_run_total = 0usize;

    for cluster_name in &cluster_names {
        let cluster = presets::cluster(cluster_name)?;
        let tp = cluster.domain_size;
        let gpus_per_node = cluster.gpus_per_node;
        let cfg = ParallelConfig { tp, pp, dp: n_replicas, microbatch: 1 };
        let sim = IterationModel::new(model.clone(), w.clone(), cluster, SimParams::default());
        let table = StrategyTable::build(&sim, &cfg, &RackDesign::default());
        // One topology per cluster, sized for the LARGEST spare budget:
        // sweep points vary only SparePolicy::spare_domains, so every
        // point shares the fleet shape — and therefore the memo (its
        // context fingerprints n_gpus).
        let max_spares = spares_list.iter().copied().max().unwrap_or(0);
        let n_domains = n_replicas * cfg.pp + max_spares;
        let topo = Topology::of(n_domains * tp, tp, gpus_per_node);
        // Pinned cost model: the default modeled costs with NO observed
        // rate (CKPT-ADAPTIVE falls back to its fixed interval). A
        // per-point observed rate would change the transition
        // fingerprint and panic the shared memo's bind check.
        let costs = TransitionCosts::model(&sim, &cfg);
        let min_tp = min_supported_tp(tp);
        let mut memo = ResponseMemo::new(policies.len());
        for &rate_x in &rate_xs {
            let fmodel = FailureModel::llama3().scaled(rate_x);
            for &scen_x in &scen_xs {
                let mut scen = ScenarioConfig::new(kind);
                scen.correlated = scen.correlated.scaled(scen_x);
                scen.straggler = scen.straggler.scaled(scen_x);
                scen.sdc = scen.sdc.scaled(scen_x);
                // Same seed at every point: points differing only in
                // spare budget replay IDENTICAL streams (the topology
                // is shared), which is both a paired-comparison win and
                // the strongest cross-point memo reuse. Adaptive sizes
                // the family by its per-point budget instead of
                // --trials.
                let gen_trials = if adaptive { rule.max_trials } else { trials };
                let gen =
                    TrialGen::new(&topo, &fmodel, &scen, days * 24.0, seed, gen_trials);
                for &spare_domains in &spares_list {
                    memo.begin_point();
                    let msim = MultiPolicySim {
                        topo: &topo,
                        table: &table,
                        domains_per_replica: cfg.pp,
                        policies: &policies,
                        spares: Some(SparePolicy { spare_domains, cold_domains: 0, min_tp }),
                        packed: true,
                        blast: BlastRadius::Single,
                        transition: Some(costs),
                        detect: None,
                    };
                    let mut row: Vec<(String, Value)> = vec![
                        ("cluster".into(), Value::Str(cluster_name.clone())),
                        ("rate_x".into(), Value::Num(rate_x)),
                        ("scen_x".into(), Value::Num(scen_x)),
                        ("spares".into(), Value::Num(spare_domains as f64)),
                        ("n_gpus".into(), Value::Num(topo.n_gpus as f64)),
                    ];
                    if adaptive {
                        // Sequential adaptive runner on the SHARED memo:
                        // the stop point is bit-identical to the
                        // parallel runner at any thread count, and
                        // cross-point hits keep accruing.
                        let res = msim.run_trials_adaptive_with(
                            &gen,
                            StepMode::Exact,
                            &rule,
                            &mut memo,
                        );
                        for (pi, policy) in policies.iter().enumerate() {
                            let key =
                                policy.name().to_ascii_lowercase().replace('-', "_");
                            let a = &res.aggs[pi];
                            row.push((
                                format!("{key}_net_tput"),
                                Value::Num(a.mean_net_tput()),
                            ));
                            row.push((
                                format!("{key}_mean_tput"),
                                Value::Num(a.mean_tput()),
                            ));
                            row.push((
                                format!("{key}_downtime_frac"),
                                Value::Num(a.mean_downtime_frac()),
                            ));
                        }
                        row.push(("trials_run".into(), Value::Num(res.trials_run as f64)));
                        row.push((
                            "stop_reason".into(),
                            Value::Str(res.reason.as_str().to_string()),
                        ));
                        trials_run_total += res.trials_run;
                    } else {
                        let per_trial =
                            msim.run_trials_stream(&gen, StepMode::Exact, &mut memo);
                        let n = per_trial.len() as f64;
                        for (pi, policy) in policies.iter().enumerate() {
                            let key =
                                policy.name().to_ascii_lowercase().replace('-', "_");
                            let mean = |f: &dyn Fn(&FleetStats) -> f64| -> f64 {
                                per_trial.iter().map(|t| f(&t[pi])).sum::<f64>() / n
                            };
                            row.push((
                                format!("{key}_net_tput"),
                                Value::Num(mean(&|s| s.net_throughput())),
                            ));
                            row.push((
                                format!("{key}_mean_tput"),
                                Value::Num(mean(&|s| s.mean_throughput)),
                            ));
                            row.push((
                                format!("{key}_downtime_frac"),
                                Value::Num(mean(&|s| s.downtime_frac)),
                            ));
                        }
                    }
                    rep.row(Value::Obj(row));
                }
            }
        }
        merged.merge(&memo.stats());
    }

    rep.scalar("memo_hit_rate", merged.hit_rate());
    rep.scalar("transition_memo_hit_rate", merged.transition_hit_rate());
    rep.scalar("cross_point_hits", merged.cross_hits as f64);
    rep.scalar("cross_point_transition_hits", merged.cross_transition_hits as f64);
    rep.scalar("cross_point_hit_rate", merged.cross_hit_rate());
    rep.scalar("memo_entries", merged.unique_entries as f64);
    // Saved-trial accounting, only under --adaptive so default cubes
    // stay bit-identical to earlier builds.
    if adaptive {
        let budget = grid_points * rule.max_trials;
        rep.scalar("adaptive", 1.0);
        rep.scalar("round", rule.round as f64);
        rep.scalar("min_trials", rule.min_trials as f64);
        rep.scalar("max_trials_per_point", rule.max_trials as f64);
        rep.scalar("rel_ci", rule.rel_ci);
        rep.scalar("trials_run_total", trials_run_total as f64);
        rep.scalar("trials_budget_total", budget as f64);
        rep.scalar("trials_saved", (budget - trials_run_total) as f64);
        if budget > 0 {
            rep.scalar(
                "trials_saved_frac",
                (budget - trials_run_total) as f64 / budget as f64,
            );
        }
    }
    match out_path {
        Some(path) => {
            rep.write(&path)?;
            println!(
                "sweep: {grid_points} grid points x {trials} trials -> {path} \
                 (cross-point memo hit rate {:.3})",
                merged.cross_hit_rate()
            );
        }
        None => println!("{}", rep.to_json().pretty()),
    }
    Ok(())
}
