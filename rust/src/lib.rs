//! `ntp` — Nonuniform Tensor Parallelism: failure-resilient LLM training.
//!
//! Reproduction of "Nonuniform-Tensor-Parallelism: Mitigating GPU failure
//! impact for Scaled-up LLM Training" (cs.DC 2025). See DESIGN.md for the
//! system inventory and EXPERIMENTS.md for paper-vs-measured results.
//!
//! The crate is organized as:
//!
//! * [`util`] / [`config`] / [`metrics`] — infrastructure substrates
//!   (JSON, PRNG, stats, CLI, bench harness, scoped-thread fan-out)
//!   built in-repo because the offline vendor set has no
//!   serde/clap/criterion/rayon.
//! * [`cluster`] / [`failure`] — cluster topology and the failure engine
//!   (Llama-3-calibrated rates, blast radius, Monte-Carlo scenarios,
//!   and the event-driven incremental trace replayer behind every
//!   trace-integrated figure).
//! * [`ntp`] — the paper's contribution: nonuniform partitioning,
//!   Algorithm 1 shard mapping, all-to-all reshard plans, and the
//!   bucketed gradient-sync orchestration.
//! * [`parallel`] / [`sim`] / [`power`] / [`manager`] — hybrid-parallel
//!   planner, the performance simulator behind every large-scale figure,
//!   the power-boost allocator (NTP-PW), and the fleet resource manager.
//! * [`policy`] — the pluggable fault-tolerance policy layer: the
//!   paper's DP-drop/NTP/NTP-PW trio as ports, plus checkpoint /
//!   partial / rate-adaptive (Young/Daly) restarts, spare migration,
//!   dark power-capped spares and low-priority donation — each with
//!   modeled reconfiguration downtime and a secondary (donated)
//!   capacity channel integrated by the fleet sweep.
//! * [`runtime`] / [`train`] — PJRT execution of the AOT-compiled JAX
//!   model and the real-numerics training driver (DP replicas at
//!   nonuniform TP, reshard + allreduce in Rust memory).

pub mod util;
pub mod config;
pub mod metrics;
pub mod cluster;
pub mod failure;
pub mod ntp;
pub mod parallel;
pub mod sim;
pub mod power;
pub mod manager;
pub mod policy;
pub mod runtime;
pub mod train;
