//! Checkpoint–restart baseline (fleet-scale ByteDance-style operation).
//!
//! The classical response to a GPU failure: stop the whole job, roll
//! back to the last checkpoint, and restart on the surviving hardware
//! (spares substituted wholesale when available, otherwise the damaged
//! replicas sit out — DP-drop capacity). Steady-state throughput is
//! therefore the DP-drop response; what distinguishes the policy is the
//! *transition* bill: every fleet-health change (failure **or**
//! recovery rejoin) costs a full-job restart, and unplanned failures
//! additionally lose half a checkpoint interval of work on average.
//!
//! The capacity response is shared by the whole restart family
//! ([`restart_capacity_respond`] / [`restart_capacity_respond_with`]):
//! `CKPT-RESTART`, [`super::partial_restart::PartialRestart`] and
//! [`super::adaptive_checkpoint::AdaptiveCheckpoint`] differ only in
//! what a reconfiguration costs (and, for the adaptive policy, the
//! checkpoint-write overhead charged against steady state).

use super::{degraded_domains, legacy, EvalOut, EvalScratch, FtPolicy, PolicyCtx, PolicyResponse};
use crate::manager::packing::{packed_replica_tp, packed_replica_tp_into};
use crate::manager::spares::{apply_spares, apply_spares_into};
use crate::sim::engine::FtStrategy;

/// Unit policy: all cost parameters come from
/// [`super::TransitionCosts`] in the context.
#[derive(Clone, Copy, Debug, Default)]
pub struct CheckpointRestart;

pub static CKPT_RESTART: CheckpointRestart = CheckpointRestart;

/// Post-restart capacity: uniform TP only — replicas containing failed
/// GPUs sit out (DP-drop), spares substituted wholesale first; fixed
/// minibatch pauses unless every replica came back at full TP.
pub(crate) fn restart_capacity_respond(
    ctx: &PolicyCtx,
    job_healthy: &[usize],
) -> PolicyResponse {
    let (replica_tp, spares_used) = match ctx.spares {
        Some(pool) => {
            let o = apply_spares(
                job_healthy,
                ctx.domain_size,
                ctx.domains_per_replica,
                &pool,
            );
            (o.assignment.replica_tp, o.spares_used)
        }
        None => (
            packed_replica_tp(
                job_healthy,
                ctx.domain_size,
                ctx.domains_per_replica,
                ctx.packed,
            ),
            0,
        ),
    };
    let paused = ctx.spares.is_some() && replica_tp.iter().any(|&tp| tp < ctx.domain_size);
    // The restart family never boosts: healthy GPUs draw nominal power,
    // paused fleets idle at the rack's idle floor.
    let (power, rack_power) = super::snapshot_power(ctx, job_healthy, paused, 1.0);
    PolicyResponse {
        replicas: legacy::decisions(ctx.table, &replica_tp, FtStrategy::DpDrop),
        paused,
        spares_used,
        overhead: 1.0,
        donated: 0.0,
        power,
        rack_power,
    }
}

/// Allocation-free [`restart_capacity_respond`].
pub(crate) fn restart_capacity_respond_with(
    ctx: &PolicyCtx,
    job_healthy: &[usize],
    s: &mut EvalScratch,
) -> EvalOut {
    let spares_used = match ctx.spares {
        Some(pool) => {
            let used = apply_spares_into(
                job_healthy,
                ctx.domain_size,
                &pool,
                &mut s.effective,
                &mut s.order,
            );
            packed_replica_tp_into(
                &s.effective,
                ctx.domain_size,
                ctx.domains_per_replica,
                true,
                &mut s.pack,
                &mut s.replica_tp,
            );
            used
        }
        None => {
            packed_replica_tp_into(
                job_healthy,
                ctx.domain_size,
                ctx.domains_per_replica,
                ctx.packed,
                &mut s.pack,
                &mut s.replica_tp,
            );
            0
        }
    };
    let paused = ctx.spares.is_some() && s.replica_tp.iter().any(|&tp| tp < ctx.domain_size);
    let (power, rack_power) = super::snapshot_power(ctx, job_healthy, paused, 1.0);
    if paused {
        return EvalOut { tput: 0.0, paused: true, spares_used, donated: 0.0, power, rack_power };
    }
    let processed: usize = s
        .replica_tp
        .iter()
        .map(|&tp| ctx.table.replica_batch(tp, FtStrategy::DpDrop))
        .sum();
    let capacity = ctx.table.full_local_batch * s.replica_tp.len();
    // overhead is exactly 1.0 (uniform TP after restart): multiplying
    // by it is a bitwise no-op, so it is omitted here.
    EvalOut {
        tput: processed as f64 / capacity as f64,
        paused: false,
        spares_used,
        donated: 0.0,
        power,
        rack_power,
    }
}

impl FtPolicy for CheckpointRestart {
    fn name(&self) -> &'static str {
        "CKPT-RESTART"
    }

    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse {
        restart_capacity_respond(ctx, job_healthy)
    }

    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        s: &mut EvalScratch,
    ) -> EvalOut {
        restart_capacity_respond_with(ctx, job_healthy, s)
    }

    fn transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        let Some(t) = ctx.transition else { return 0.0 };
        // Any health change restarts the whole job; an unplanned
        // failure also rolls back to the last checkpoint.
        let rollback = if degraded_domains(prev, next) > 0 {
            0.5 * t.checkpoint_interval_secs
        } else {
            0.0
        };
        ctx.n_gpus as f64 * (t.restart_secs + rollback)
    }

    fn transition_cost_is_count_pure(&self) -> bool {
        true
    }
}
