//! Ports of the paper's three strategies onto the policy layer.
//!
//! Each port replays the exact decision sequence of the pre-policy
//! `FleetSim::evaluate` / `StrategyTable` code (same calls, same
//! floating-point operation order), so with transition costs disabled
//! the integrated `FleetStats` are bit-identical to the legacy paths —
//! asserted by `rust/tests/policy_conformance.rs`.

use super::{
    affected_gpus, changed_domains, EvalOut, EvalScratch, FtPolicy, PolicyCtx, PolicyResponse,
    ReplicaDecision,
};
use crate::manager::packing::{packed_replica_tp, packed_replica_tp_into};
use crate::manager::spares::{apply_spares, apply_spares_into, meets_minibatch, meets_minibatch_tp};
use crate::sim::engine::FtStrategy;

/// One legacy strategy as a policy.
#[derive(Clone, Copy, Debug)]
pub struct LegacyPolicy {
    pub strategy: FtStrategy,
}

pub static DP_DROP: LegacyPolicy = LegacyPolicy { strategy: FtStrategy::DpDrop };
pub static NTP: LegacyPolicy = LegacyPolicy { strategy: FtStrategy::Ntp };
pub static NTP_PW: LegacyPolicy = LegacyPolicy { strategy: FtStrategy::NtpPw };

impl FtStrategy {
    /// The policy-layer port of this strategy (zero transition cost
    /// unless the sim supplies a `TransitionCosts` model).
    pub fn policy(self) -> &'static dyn FtPolicy {
        match self {
            FtStrategy::DpDrop => &DP_DROP,
            FtStrategy::Ntp => &NTP,
            FtStrategy::NtpPw => &NTP_PW,
        }
    }
}

/// Per-replica decisions for a TP-degree vector under a legacy
/// strategy, batches exactly as `StrategyTable::replica_batch`.
pub fn decisions(
    table: &crate::manager::StrategyTable,
    replica_tp: &[usize],
    strategy: FtStrategy,
) -> Vec<ReplicaDecision> {
    replica_tp
        .iter()
        .map(|&tp| {
            let batch = table.replica_batch(tp, strategy);
            let power = if batch == 0 {
                0.0
            } else if strategy == FtStrategy::NtpPw && tp < table.full_tp {
                table.power[tp - table.min_tp].unwrap_or(1.0)
            } else {
                1.0
            };
            ReplicaDecision { tp, batch, power }
        })
        .collect()
}

/// Group overhead factor exactly as `StrategyTable::group_throughput`
/// applies it: the modeled healthy-replica reshard factor when the
/// group is nonuniform, else exactly `1.0`.
pub fn overhead_for(
    table: &crate::manager::StrategyTable,
    replica_tp: &[usize],
    strategy: FtStrategy,
) -> f64 {
    let nonuniform = strategy != FtStrategy::DpDrop
        && replica_tp.iter().any(|&t| t < table.full_tp && t >= table.min_tp);
    if nonuniform {
        table.reshard_overhead
    } else {
        1.0
    }
}

/// Consume `dpr` boosted domains from a row allowance. `None` means the
/// rack imposes no row cap (every grant succeeds — the default, which
/// keeps the no-cap path bit-identical to the pre-cap walk).
fn grant(allowance: &mut Option<usize>, dpr: usize) -> bool {
    match allowance {
        None => true,
        Some(a) => {
            if *a < dpr {
                false
            } else {
                *a -= dpr;
                true
            }
        }
    }
}

/// One NTP-PW replica's `(batch, power)` under a running row-boost
/// allowance. With the allowance off (`None`) this reproduces the
/// original per-replica logic of [`decisions`] operation-for-operation;
/// a replica denied a boost grant falls back to the *plain-NTP* batch
/// at nominal power (the rack refuses the watts, so the replica runs
/// the unboosted reduced-TP configuration instead).
fn pw_replica(
    table: &crate::manager::StrategyTable,
    allowance: &mut Option<usize>,
    dpr: usize,
    tp: usize,
) -> (usize, f64) {
    if tp >= table.full_tp {
        return (table.full_local_batch, 1.0);
    }
    if tp < table.min_tp {
        return (0, 0.0);
    }
    let i = tp - table.min_tp;
    let boost = table.power[i];
    if let Some(b) = boost {
        if b > 1.0 && !grant(allowance, dpr) {
            let batch = table.batch[i];
            return (batch, if batch == 0 { 0.0 } else { 1.0 });
        }
    }
    let batch = table.batch_pw[i];
    (batch, if batch == 0 { 0.0 } else { boost.unwrap_or(1.0) })
}

/// Walk a TP-degree vector under NTP-PW with the rack's row-boost
/// allowance, returning `(processed, extra_gpu_draw, peak_domain_frac)`:
/// total batch processed, the *extra* GPU-equivalents of draw beyond
/// nominal from boosted survivors, and the hottest single-domain power
/// fraction the boosts produce. Replicas are visited in the same packed
/// order as [`decisions`], so grants are deterministic for a given
/// damage multiset.
fn pw_walk(
    table: &crate::manager::StrategyTable,
    domains_per_replica: usize,
    replica_tp: &[usize],
) -> (usize, f64, f64) {
    let mut allowance =
        table.rack.row_boost_allowance(replica_tp.len() * domains_per_replica);
    let mut processed = 0usize;
    let mut extra = 0.0f64;
    let mut peak = 0.0f64;
    for &tp in replica_tp {
        let (batch, power) = pw_replica(table, &mut allowance, domains_per_replica, tp);
        processed += batch;
        if power > 1.0 {
            extra += (power - 1.0) * (tp * domains_per_replica) as f64;
            let frac = power * tp as f64 / table.full_tp as f64;
            if frac > peak {
                peak = frac;
            }
        }
    }
    (processed, extra, peak)
}

/// Per-replica decisions for NTP-PW under the rack's row-boost
/// allowance — the same walk as [`pw_walk`], materialized. With the row
/// cap off this is bit-identical to `decisions(table, replica_tp,
/// FtStrategy::NtpPw)`.
fn pw_decisions(
    table: &crate::manager::StrategyTable,
    domains_per_replica: usize,
    replica_tp: &[usize],
) -> Vec<ReplicaDecision> {
    let mut allowance =
        table.rack.row_boost_allowance(replica_tp.len() * domains_per_replica);
    replica_tp
        .iter()
        .map(|&tp| {
            let (batch, power) = pw_replica(table, &mut allowance, domains_per_replica, tp);
            ReplicaDecision { tp, batch, power }
        })
        .collect()
}

/// Fleet power fraction + hottest-domain draw for a legacy-strategy
/// snapshot: the base healthy/idle draw from
/// [`super::snapshot_power`], plus — for NTP-PW only — the boosted
/// survivors' extra draw from the same allowance walk that sets the
/// replica decisions.
fn legacy_power(
    ctx: &PolicyCtx,
    job_healthy: &[usize],
    replica_tp: &[usize],
    strategy: FtStrategy,
    paused: bool,
) -> (f64, f64) {
    let (mut power, mut rack_power) = super::snapshot_power(ctx, job_healthy, paused, 1.0);
    if !paused && strategy == FtStrategy::NtpPw {
        let (_, extra, peak) = pw_walk(ctx.table, ctx.domains_per_replica, replica_tp);
        power += extra / ctx.n_gpus as f64;
        if peak > rack_power {
            rack_power = peak;
        }
    }
    (power, rack_power)
}

impl FtPolicy for LegacyPolicy {
    fn name(&self) -> &'static str {
        self.strategy.name()
    }

    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse {
        match ctx.spares {
            None => {
                // Flexible minibatch (Fig. 6 semantics).
                let replica_tp = packed_replica_tp(
                    job_healthy,
                    ctx.domain_size,
                    ctx.domains_per_replica,
                    ctx.packed,
                );
                let overhead = overhead_for(ctx.table, &replica_tp, self.strategy);
                let replicas = if self.strategy == FtStrategy::NtpPw {
                    pw_decisions(ctx.table, ctx.domains_per_replica, &replica_tp)
                } else {
                    decisions(ctx.table, &replica_tp, self.strategy)
                };
                let (power, rack_power) =
                    legacy_power(ctx, job_healthy, &replica_tp, self.strategy, false);
                PolicyResponse {
                    replicas,
                    paused: false,
                    spares_used: 0,
                    overhead,
                    donated: 0.0,
                    power,
                    rack_power,
                }
            }
            Some(policy) => {
                // Fixed minibatch with spares + pausing (Fig. 7
                // semantics) — the pre-policy `FleetSim::evaluate` arm.
                let o = apply_spares(
                    job_healthy,
                    ctx.domain_size,
                    ctx.domains_per_replica,
                    &policy,
                );
                let boosted = self.strategy == FtStrategy::NtpPw;
                let ok = match self.strategy {
                    FtStrategy::DpDrop => {
                        meets_minibatch(&o.assignment, ctx.domain_size, false)
                    }
                    FtStrategy::Ntp => {
                        // Fig. 7 NTP curve: the minibatch counts as met
                        // while the shortfall from reduced replicas stays
                        // below one replica's worth.
                        let frac = ctx
                            .table
                            .group_minibatch_frac(&o.assignment.replica_tp, self.strategy);
                        let shortfall = (1.0 - frac) * o.assignment.replica_tp.len() as f64;
                        shortfall < 1.0
                    }
                    FtStrategy::NtpPw => meets_minibatch(&o.assignment, policy.min_tp, boosted),
                };
                let overhead =
                    overhead_for(ctx.table, &o.assignment.replica_tp, self.strategy);
                let replicas = if self.strategy == FtStrategy::NtpPw {
                    pw_decisions(ctx.table, ctx.domains_per_replica, &o.assignment.replica_tp)
                } else {
                    decisions(ctx.table, &o.assignment.replica_tp, self.strategy)
                };
                let (power, rack_power) =
                    legacy_power(ctx, job_healthy, &o.assignment.replica_tp, self.strategy, !ok);
                PolicyResponse {
                    replicas,
                    paused: !ok,
                    spares_used: o.spares_used,
                    overhead,
                    donated: 0.0,
                    power,
                    rack_power,
                }
            }
        }
    }

    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        s: &mut EvalScratch,
    ) -> EvalOut {
        match ctx.spares {
            None => {
                packed_replica_tp_into(
                    job_healthy,
                    ctx.domain_size,
                    ctx.domains_per_replica,
                    ctx.packed,
                    &mut s.pack,
                    &mut s.replica_tp,
                );
                let processed: usize = if self.strategy == FtStrategy::NtpPw {
                    pw_walk(ctx.table, ctx.domains_per_replica, &s.replica_tp).0
                } else {
                    s.replica_tp
                        .iter()
                        .map(|&tp| ctx.table.replica_batch(tp, self.strategy))
                        .sum()
                };
                let capacity = ctx.table.full_local_batch * s.replica_tp.len();
                let overhead = overhead_for(ctx.table, &s.replica_tp, self.strategy);
                let (power, rack_power) =
                    legacy_power(ctx, job_healthy, &s.replica_tp, self.strategy, false);
                EvalOut {
                    tput: processed as f64 / capacity as f64 * overhead,
                    paused: false,
                    spares_used: 0,
                    donated: 0.0,
                    power,
                    rack_power,
                }
            }
            Some(policy) => {
                let spares_used = apply_spares_into(
                    job_healthy,
                    ctx.domain_size,
                    &policy,
                    &mut s.effective,
                    &mut s.order,
                );
                // apply_spares packs with `packed = true` internally.
                packed_replica_tp_into(
                    &s.effective,
                    ctx.domain_size,
                    ctx.domains_per_replica,
                    true,
                    &mut s.pack,
                    &mut s.replica_tp,
                );
                let ok = match self.strategy {
                    FtStrategy::DpDrop => {
                        meets_minibatch_tp(&s.replica_tp, ctx.domain_size, ctx.domain_size, false)
                    }
                    FtStrategy::Ntp => {
                        let frac =
                            ctx.table.group_minibatch_frac(&s.replica_tp, self.strategy);
                        let shortfall = (1.0 - frac) * s.replica_tp.len() as f64;
                        shortfall < 1.0
                    }
                    FtStrategy::NtpPw => {
                        meets_minibatch_tp(&s.replica_tp, ctx.domain_size, policy.min_tp, true)
                    }
                };
                if !ok {
                    let (power, rack_power) =
                        legacy_power(ctx, job_healthy, &s.replica_tp, self.strategy, true);
                    return EvalOut {
                        tput: 0.0,
                        paused: true,
                        spares_used,
                        donated: 0.0,
                        power,
                        rack_power,
                    };
                }
                let processed: usize = if self.strategy == FtStrategy::NtpPw {
                    pw_walk(ctx.table, ctx.domains_per_replica, &s.replica_tp).0
                } else {
                    s.replica_tp
                        .iter()
                        .map(|&tp| ctx.table.replica_batch(tp, self.strategy))
                        .sum()
                };
                let capacity = ctx.table.full_local_batch * s.replica_tp.len();
                let overhead = overhead_for(ctx.table, &s.replica_tp, self.strategy);
                let (power, rack_power) =
                    legacy_power(ctx, job_healthy, &s.replica_tp, self.strategy, false);
                EvalOut {
                    tput: processed as f64 / capacity as f64 * overhead,
                    paused: false,
                    spares_used,
                    donated: 0.0,
                    power,
                    rack_power,
                }
            }
        }
    }

    fn transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        let Some(t) = ctx.transition else { return 0.0 };
        match self.strategy {
            // Dropping / re-adding a DP replica repacks process-group
            // ranks: a full-job restart.
            FtStrategy::DpDrop => ctx.n_gpus as f64 * t.restart_secs,
            // NTP reconfigures live: only replicas containing changed
            // domains reshard their TP layout.
            FtStrategy::Ntp | FtStrategy::NtpPw => {
                affected_gpus(ctx, changed_domains(prev, next)) as f64 * t.reshard_secs
            }
        }
    }

    fn transition_cost_is_count_pure(&self) -> bool {
        true
    }
}
