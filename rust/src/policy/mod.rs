//! Pluggable fault-tolerance policy layer.
//!
//! The paper's frozen three-way comparison (DP-drop vs NTP vs NTP-PW)
//! generalizes here into a first-class abstraction: an [`FtPolicy`]
//! decides, per fleet-health snapshot, what every DP replica does
//! (TP degree, local batch, power) and what a *reconfiguration costs*
//! (GPU-seconds of transition downtime) whenever the fleet's health
//! changes. [`crate::manager::FleetSim`] drives any policy through the
//! event-driven trace sweep and integrates both steady-state throughput
//! and transition downtime into [`crate::manager::FleetStats`].
//!
//! Ports and new policies:
//!
//! * [`legacy`] — the paper's trio as zero-refactor-cost ports; with no
//!   [`TransitionCosts`] in the context they are bit-identical to the
//!   pre-policy-layer `FtStrategy` code paths
//!   (`rust/tests/policy_conformance.rs`).
//! * [`checkpoint`] — checkpoint–restart baseline (ByteDance-style
//!   fleet operation): every health change stops the whole job, rolls
//!   back to the last checkpoint and restarts on the surviving
//!   hardware.
//! * [`spare_migration`] — SPARe-inspired migrate-then-shrink: spare
//!   domains are migrated into damaged slots and damage is stacked
//!   (reordered) into the fewest replicas *before* any TP shrink;
//!   residual shortfall is redistributed over survivors instead of
//!   pausing.
//!
//! [`registry`] maps CLI names to policy instances; every registered
//! policy is exercised by the conformance suite.

pub mod checkpoint;
pub mod legacy;
pub mod registry;
pub mod spare_migration;

pub use checkpoint::CheckpointRestart;
pub use spare_migration::SpareMigration;

use crate::manager::packing::PackScratch;
use crate::manager::{SparePolicy, StrategyTable};
use crate::parallel::ParallelConfig;
use crate::sim::engine::min_supported_tp;
use crate::sim::IterationModel;

/// Everything a policy may consult when responding to a snapshot.
/// Cheap to build per evaluation (all borrows / `Copy` data).
#[derive(Clone, Copy, Debug)]
pub struct PolicyCtx<'a> {
    /// Precomputed per-TP-degree batch/power responses.
    pub table: &'a StrategyTable,
    /// Scale-up domain size (full TP degree).
    pub domain_size: usize,
    /// Domains per DP replica (= pipeline stages).
    pub domains_per_replica: usize,
    /// Whether the resource manager repacks damaged domains together.
    pub packed: bool,
    /// `Some` ⇒ fixed-minibatch mode with this (live-spare-adjusted)
    /// pool; `None` ⇒ flexible minibatch.
    pub spares: Option<SparePolicy>,
    /// Total provisioned GPUs (job + spares) — the denominator for
    /// transition-cost accounting.
    pub n_gpus: usize,
    /// `None` ⇒ reconfigurations are free (the pre-policy-layer model).
    pub transition: Option<TransitionCosts>,
}

/// What one replica does under the policy's response.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicaDecision {
    /// Effective TP degree (0 = replica dropped).
    pub tp: usize,
    /// Local batch contributed per iteration (samples).
    pub batch: usize,
    /// Power fraction (1.0 = nominal, 0.0 = dropped).
    pub power: f64,
}

/// A policy's full response to one fleet-health snapshot.
#[derive(Clone, Debug)]
pub struct PolicyResponse {
    pub replicas: Vec<ReplicaDecision>,
    /// Fixed-minibatch pause: the group cannot make progress.
    pub paused: bool,
    pub spares_used: usize,
    /// Multiplicative group-rate factor (healthy-replica reshard
    /// overhead and kin); exactly `1.0` when nothing is nonuniform.
    pub overhead: f64,
}

impl PolicyResponse {
    /// Group relative throughput in `[0, 1]` (0 when paused).
    pub fn throughput(&self, full_local_batch: usize) -> f64 {
        if self.paused {
            return 0.0;
        }
        let processed: usize = self.replicas.iter().map(|r| r.batch).sum();
        let capacity = full_local_batch * self.replicas.len();
        processed as f64 / capacity as f64 * self.overhead
    }
}

/// Reusable buffers threaded through [`FtPolicy::respond_with`] so the
/// steady-state fleet sweep ([`crate::manager::MultiPolicySim`])
/// allocates nothing: every vector grows to the instance size once and
/// is then reused across snapshots, policies, trials and sweep points.
#[derive(Debug, Default)]
pub struct EvalScratch {
    /// Per-replica TP degrees of the current snapshot.
    pub replica_tp: Vec<usize>,
    /// Spare-substituted per-domain healthy counts (fixed-minibatch mode).
    pub effective: Vec<usize>,
    /// Domain permutation used by the spare substitution.
    pub order: Vec<usize>,
    /// Counting-sort histogram for the packing fast path.
    pub pack: PackScratch,
}

/// A fault-tolerance policy: per-snapshot replica decisions plus the
/// modeled cost of reconfiguring when the fleet's health changes.
///
/// Object-safe; [`crate::manager::FleetSim`] holds `&dyn FtPolicy`.
pub trait FtPolicy: Send + Sync {
    /// Display / CLI name.
    fn name(&self) -> &'static str;

    /// Respond to one snapshot. `job_healthy` is the per-domain healthy
    /// count of the *job* domains (spare-pool tail already split off by
    /// the caller; the live pool size is in `ctx.spares`).
    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse;

    /// Allocation-free evaluation of one snapshot, returning only the
    /// integrated quantities `(throughput, paused, spares_used)` —
    /// exactly `respond(..)` collapsed through
    /// [`PolicyResponse::throughput`], without materializing the
    /// per-replica decision vector. The fleet-sweep hot path
    /// ([`crate::manager::MultiPolicySim`]) calls this behind its
    /// snapshot-signature memo; the default implementation delegates to
    /// [`FtPolicy::respond`], and every in-tree policy overrides it with
    /// a scratch-buffer version (equivalence asserted in
    /// `rust/tests/policy_conformance.rs`).
    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        _scratch: &mut EvalScratch,
    ) -> (f64, bool, usize) {
        let resp = self.respond(ctx, job_healthy);
        (resp.throughput(ctx.table.full_local_batch), resp.paused, resp.spares_used)
    }

    /// GPU-seconds of downtime charged when the fleet's per-domain
    /// health changes from `prev` to `next` (full fleet, spares
    /// included). Must return `0.0` when `ctx.transition` is `None` —
    /// that is what makes the legacy ports bit-identical to the
    /// pre-policy-layer paths.
    fn transition_cost(&self, _ctx: &PolicyCtx, _prev: &[usize], _next: &[usize]) -> f64 {
        0.0
    }
}

/// Modeled reconfiguration-cost inputs shared by all policies.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransitionCosts {
    /// Full-job restart latency (scheduler, process groups, checkpoint
    /// load), seconds.
    pub restart_secs: f64,
    /// Checkpoint interval, seconds; an unplanned failure rolls back
    /// half of it on average.
    pub checkpoint_interval_secs: f64,
    /// One NTP reshard reconfiguration of an affected replica, seconds
    /// (CopyPlan traffic over the scale-up link, see
    /// [`reshard_transition_secs`]).
    pub reshard_secs: f64,
    /// Streaming a replica shard's weights onto a migrated-in spare
    /// domain, seconds.
    pub spare_load_secs: f64,
}

impl TransitionCosts {
    /// Defaults with the reshard term derived from the iteration
    /// model's `CopyPlan` for the deepest supported reduction.
    pub fn model(sim: &IterationModel, cfg: &ParallelConfig) -> TransitionCosts {
        TransitionCosts {
            restart_secs: 900.0,
            checkpoint_interval_secs: 3600.0,
            reshard_secs: reshard_transition_secs(sim, cfg),
            spare_load_secs: 300.0,
        }
    }
}

/// Wall-clock seconds one replica needs to reconfigure its TP layout:
/// the optimizer state behind every offloaded unit (weights, fp32
/// master copy, two AdamW moments ≈ 6× the bf16 weight bytes) moves
/// over the scale-up link, bounded by the busiest GPU of the
/// [`crate::ntp::CopyPlan`] for the deepest supported reduction.
pub fn reshard_transition_secs(sim: &IterationModel, cfg: &ParallelConfig) -> f64 {
    reshard_transition_secs_over(sim, cfg, sim.cluster.gpu.nvlink_gbs)
}

/// [`reshard_transition_secs`] over an explicit scale-up link bandwidth
/// (GB/s) instead of the cluster's NVLink spec — the `fleet
/// --reshard-gbs` calibration knob.
pub fn reshard_transition_secs_over(
    sim: &IterationModel,
    cfg: &ParallelConfig,
    link_gbs: f64,
) -> f64 {
    let n2 = min_supported_tp(cfg.tp);
    if n2 >= cfg.tp {
        return 0.0;
    }
    let info = sim.plan_cache().get(sim.model.ffn, cfg.tp, n2);
    let weight_unit_bytes = 2 * sim.model.hidden * 2;
    let state_bytes_per_unit = 6 * weight_unit_bytes;
    let bytes = (info.copy.max_moved_units_per_shard() * state_bytes_per_unit) as f64
        * sim.model.layers as f64
        / cfg.pp as f64;
    bytes / (link_gbs * 1e9)
}

/// GPUs touched when `changed_domains` domains change health: every
/// replica containing a changed domain re-plans, so charge whole
/// replicas, capped at the fleet.
pub(crate) fn affected_gpus(ctx: &PolicyCtx, changed_domains: usize) -> usize {
    (changed_domains * ctx.domains_per_replica * ctx.domain_size).min(ctx.n_gpus)
}

/// Count of domains whose health differs between two snapshots.
pub(crate) fn changed_domains(prev: &[usize], next: &[usize]) -> usize {
    prev.iter().zip(next).filter(|(a, b)| a != b).count()
}

/// Count of domains that got *worse* (a new failure landed).
pub(crate) fn degraded_domains(prev: &[usize], next: &[usize]) -> usize {
    prev.iter().zip(next).filter(|(a, b)| b < a).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Dtype, WorkloadConfig};
    use crate::sim::SimParams;

    #[test]
    fn reshard_transition_secs_is_small_but_positive() {
        let sim = IterationModel::new(
            presets::model("gpt-480b").unwrap(),
            WorkloadConfig {
                seq_len: 16_384,
                minibatch_tokens: 16 << 20,
                dtype: Dtype::BF16,
            },
            presets::cluster("paper-32k-nvl32").unwrap(),
            SimParams::default(),
        );
        let cfg = ParallelConfig { tp: 32, pp: 8, dp: 128, microbatch: 1 };
        let t = reshard_transition_secs(&sim, &cfg);
        // moving ~GBs of optimizer state over NVLink: sub-second, not zero
        assert!(t > 0.0 && t < 60.0, "reshard transition {t}s");
        // nothing to reshard at TP1
        let cfg1 = ParallelConfig { tp: 1, pp: 8, dp: 128, microbatch: 1 };
        assert_eq!(reshard_transition_secs(&sim, &cfg1), 0.0);
    }

    #[test]
    fn snapshot_helpers_count_changes() {
        let prev = [32usize, 31, 32, 30];
        let next = [32usize, 32, 31, 30];
        assert_eq!(changed_domains(&prev, &next), 2);
        assert_eq!(degraded_domains(&prev, &next), 1);
        assert_eq!(changed_domains(&prev, &prev), 0);
    }
}
