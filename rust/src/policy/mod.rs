//! Pluggable fault-tolerance policy layer.
//!
//! The paper's frozen three-way comparison (DP-drop vs NTP vs NTP-PW)
//! generalizes here into a first-class abstraction: an [`FtPolicy`]
//! decides, per fleet-health snapshot, what every DP replica does
//! (TP degree, local batch, power) and what a *reconfiguration costs*
//! (GPU-seconds of transition downtime) whenever the fleet's health
//! changes. [`crate::manager::FleetSim`] drives any policy through the
//! event-driven trace sweep and integrates both steady-state throughput
//! and transition downtime into [`crate::manager::FleetStats`].
//!
//! Ports and new policies:
//!
//! * [`legacy`] — the paper's trio as zero-refactor-cost ports; with no
//!   [`TransitionCosts`] in the context they are bit-identical to the
//!   pre-policy-layer `FtStrategy` code paths
//!   (`rust/tests/policy_conformance.rs`).
//! * [`checkpoint`] — checkpoint–restart baseline (ByteDance-style
//!   fleet operation): every health change stops the whole job, rolls
//!   back to the last checkpoint and restarts on the surviving
//!   hardware.
//! * [`spare_migration`] — SPARe-inspired migrate-then-shrink: spare
//!   domains are migrated into damaged slots and damage is stacked
//!   (reordered) into the fewest replicas *before* any TP shrink;
//!   residual shortfall is redistributed over survivors instead of
//!   pausing.
//! * [`lowpri_donation`] — NTP capacity response with idle healthy GPUs
//!   donated to low-priority jobs (paper §3.3, lifted from
//!   [`crate::manager::lowpri`]); the donated capacity flows through the
//!   secondary accounting channel ([`PolicyResponse::donated`]).
//! * [`partial_restart`] — ByteDance-style partial recovery: only the
//!   DP replicas containing changed domains restart (with per-replica
//!   rollback), the rest of the fleet keeps running — between NTP's
//!   live reshard and `ckpt-restart`'s global stop.
//! * [`power_spares`] — spare domains kept dark (power-capped via
//!   [`crate::power::RackDesign`]) until migrated in; transitions pay a
//!   ramp-up on top of the weight load, steady state credits the saved
//!   rack power through the secondary channel.
//! * [`adaptive_checkpoint`] — `ckpt-restart` with the checkpoint
//!   interval set by the Young/Daly optimum for the trace's *observed*
//!   failure rate instead of the fixed 3600 s (and the checkpoint-write
//!   overhead it implies charged against steady-state throughput).
//! * [`straggler`] — detection-aware responses to degraded-but-alive
//!   GPUs: `straggler-evict` reshards stragglers away like failures
//!   (NTP on degradation-adjusted counts, paying reshard transitions),
//!   `straggler-tolerate` keeps them and eats the TP-group drag.
//! * [`elastic`] — TorchFT-style elastic data parallelism: the DP world
//!   shrinks when replicas fail (survivors keep training, the elastic
//!   minibatch rescales) and recovered domains rejoin *live* via
//!   peer-to-peer state transfer ([`TransitionCosts::rejoin_secs`],
//!   derived from the `CopyPlan` traffic model) — no checkpoint
//!   rollback term anywhere.
//!
//! [`registry`] maps CLI names to policy instances; every registered
//! policy is exercised by the registry-driven conformance suite
//! (`rust/tests/policy_conformance.rs`) with zero per-policy test code.

pub mod adaptive_checkpoint;
pub mod checkpoint;
pub mod elastic;
pub mod legacy;
pub mod lowpri_donation;
pub mod partial_restart;
pub mod power_spares;
pub mod registry;
pub mod spare_migration;
pub mod straggler;

pub use adaptive_checkpoint::AdaptiveCheckpoint;
pub use checkpoint::CheckpointRestart;
pub use elastic::ElasticDp;
pub use lowpri_donation::LowpriDonate;
pub use partial_restart::PartialRestart;
pub use power_spares::PowerSpares;
pub use spare_migration::SpareMigration;
pub use straggler::{StragglerEvict, StragglerTolerate};

use crate::manager::packing::PackScratch;
use crate::manager::{SparePolicy, StrategyTable};
use crate::parallel::ParallelConfig;
use crate::sim::engine::min_supported_tp;
use crate::sim::IterationModel;

/// Everything a policy may consult when responding to a snapshot.
/// Cheap to build per evaluation (all borrows / `Copy` data).
#[derive(Clone, Copy, Debug)]
pub struct PolicyCtx<'a> {
    /// Precomputed per-TP-degree batch/power responses.
    pub table: &'a StrategyTable,
    /// Scale-up domain size (full TP degree).
    pub domain_size: usize,
    /// Domains per DP replica (= pipeline stages).
    pub domains_per_replica: usize,
    /// Whether the resource manager repacks damaged domains together.
    pub packed: bool,
    /// `Some` ⇒ fixed-minibatch mode with this (live-spare-adjusted)
    /// pool; `None` ⇒ flexible minibatch.
    pub spares: Option<SparePolicy>,
    /// Total provisioned GPUs (job + spares) — the denominator for
    /// transition-cost accounting.
    pub n_gpus: usize,
    /// `None` ⇒ reconfigurations are free (the pre-policy-layer model).
    pub transition: Option<TransitionCosts>,
}

/// What one replica does under the policy's response.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicaDecision {
    /// Effective TP degree (0 = replica dropped).
    pub tp: usize,
    /// Local batch contributed per iteration (samples).
    pub batch: usize,
    /// Power fraction (1.0 = nominal, 0.0 = dropped).
    pub power: f64,
}

/// A policy's full response to one fleet-health snapshot.
#[derive(Clone, Debug)]
pub struct PolicyResponse {
    pub replicas: Vec<ReplicaDecision>,
    /// Fixed-minibatch pause: the group cannot make progress.
    pub paused: bool,
    pub spares_used: usize,
    /// Multiplicative group-rate factor (healthy-replica reshard
    /// overhead and kin); exactly `1.0` when nothing is nonuniform.
    pub overhead: f64,
    /// Secondary accounting channel, as a fraction of provisioned GPUs:
    /// capacity the policy recovers *outside* the primary job — idle
    /// healthy GPUs hosting low-priority work (`LOWPRI-DONATE`) or
    /// dark-spare rack power saved (`POWER-SPARES`). Exactly `0.0` for
    /// policies with no secondary channel.
    pub donated: f64,
    /// Fleet power draw of this snapshot as a fraction of `n_gpus ×
    /// TDP`: healthy GPUs at their boost level, failed GPUs at 0, dark
    /// spares at standby, a paused job at the idle floor
    /// ([`snapshot_power`]). Piecewise-constant between health changes,
    /// so the exact event-boundary sweep integrates it with zero
    /// quantization — exactly like throughput.
    pub power: f64,
    /// Draw of the hottest scale-up domain, as a fraction of
    /// `domain_size × TDP` — the peak-rack headroom a datacenter
    /// operator provisions for ([`crate::manager::FleetStats::peak_rack_power_frac`]).
    pub rack_power: f64,
}

impl PolicyResponse {
    /// Group relative throughput in `[0, 1]` (0 when paused).
    pub fn throughput(&self, full_local_batch: usize) -> f64 {
        if self.paused {
            return 0.0;
        }
        let processed: usize = self.replicas.iter().map(|r| r.batch).sum();
        let capacity = full_local_batch * self.replicas.len();
        processed as f64 / capacity as f64 * self.overhead
    }
}

/// The integrated quantities of one snapshot evaluation — what the
/// fleet sweeps accumulate per sample. [`FtPolicy::respond_with`]
/// returns this directly; [`EvalOut::of`] collapses a full
/// [`PolicyResponse`] to it.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvalOut {
    /// Group relative throughput in `[0, 1]` (0 when paused).
    pub tput: f64,
    /// Fixed-minibatch pause: the group cannot make progress.
    pub paused: bool,
    /// Spares consumed by this snapshot's response.
    pub spares_used: usize,
    /// Secondary-channel capacity fraction ([`PolicyResponse::donated`]).
    pub donated: f64,
    /// Fleet power fraction ([`PolicyResponse::power`]).
    pub power: f64,
    /// Hottest-domain draw fraction ([`PolicyResponse::rack_power`]).
    pub rack_power: f64,
}

impl EvalOut {
    /// Collapse a full response to its integrated quantities.
    pub fn of(resp: &PolicyResponse, full_local_batch: usize) -> EvalOut {
        EvalOut {
            tput: resp.throughput(full_local_batch),
            paused: resp.paused,
            spares_used: resp.spares_used,
            donated: resp.donated,
            power: resp.power,
            rack_power: resp.rack_power,
        }
    }
}

/// Fleet power fraction + hottest-domain draw of one snapshot, shared
/// by every policy's `respond` / `respond_with` pair (identical call,
/// identical operations — the conformance suite pins the two paths
/// bit-for-bit through [`EvalOut`]'s `PartialEq`).
///
/// The base model, before any policy-specific surcharge (NTP-PW boost)
/// or credit (dark spares):
///
/// * every healthy GPU (job domains *and* the live spare pool) draws
///   nominal TDP; failed GPUs draw 0 — on a zero-failure snapshot with
///   a consistent context (`n_gpus` = job + spare GPUs) the fleet
///   fraction is **exactly 1.0** (`n/n`, an exact division);
/// * `spare_frac` scales the live spare pool's draw (1.0 = warm
///   standby; `POWER-SPARES` subtracts its dark-pool saving on top);
/// * a paused job idles everything at [`crate::power::RackDesign::idle_frac`]
///   (clocks floored, HBM refreshed) — the "paused ⇒ idle-power floor"
///   conformance invariant;
/// * the hottest-domain draw is the fullest job domain's healthy
///   fraction (boost surcharges raise it above 1.0 where granted).
///
/// Both outputs are pure functions of the damage *multiset* (a sum and
/// a max over domains) plus the context — the invariant that makes the
/// cached [`EvalOut`]s of the shared sweep's snapshot-signature memo
/// safe to reuse across permutations.
pub(crate) fn snapshot_power(
    ctx: &PolicyCtx,
    job_healthy: &[usize],
    paused: bool,
    spare_frac: f64,
) -> (f64, f64) {
    let rack = &ctx.table.rack;
    let healthy: usize = job_healthy.iter().sum();
    let spare_gpus = ctx.spares.map(|p| p.spare_domains * ctx.domain_size).unwrap_or(0);
    let n = ctx.n_gpus as f64;
    if paused {
        let draw = (healthy + spare_gpus) as f64 * rack.idle_frac;
        let peak = if healthy + spare_gpus > 0 { rack.idle_frac } else { 0.0 };
        return (draw / n, peak);
    }
    let draw = healthy as f64 + spare_gpus as f64 * spare_frac;
    let mut peak = 0.0f64;
    for &h in job_healthy {
        let frac = h as f64 / ctx.domain_size as f64;
        if frac > peak {
            peak = frac;
        }
    }
    if spare_gpus > 0 && spare_frac > peak {
        peak = spare_frac;
    }
    (draw / n, peak)
}

/// Reusable buffers threaded through [`FtPolicy::respond_with`] so the
/// steady-state fleet sweep ([`crate::manager::MultiPolicySim`])
/// allocates nothing: every vector grows to the instance size once and
/// is then reused across snapshots, policies, trials and sweep points.
#[derive(Debug, Default)]
pub struct EvalScratch {
    /// Per-replica TP degrees of the current snapshot.
    pub replica_tp: Vec<usize>,
    /// Spare-substituted per-domain healthy counts (fixed-minibatch mode).
    pub effective: Vec<usize>,
    /// Domain permutation used by the spare substitution.
    pub order: Vec<usize>,
    /// Counting-sort histogram for the packing fast path.
    pub pack: PackScratch,
    /// Degradation-adjusted healthy counts (`STRAGGLER-EVICT` treats
    /// degraded GPUs as failed before delegating to the NTP response).
    pub degrade_eff: Vec<usize>,
}

/// A fault-tolerance policy: per-snapshot replica decisions plus the
/// modeled cost of reconfiguring when the fleet's health changes.
///
/// Object-safe; [`crate::manager::FleetSim`] holds `&dyn FtPolicy`.
pub trait FtPolicy: Send + Sync {
    /// Display / CLI name.
    fn name(&self) -> &'static str;

    /// Respond to one snapshot. `job_healthy` is the per-domain healthy
    /// count of the *job* domains (spare-pool tail already split off by
    /// the caller; the live pool size is in `ctx.spares`).
    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse;

    /// Allocation-free evaluation of one snapshot, returning only the
    /// integrated [`EvalOut`] quantities — exactly `respond(..)`
    /// collapsed through [`EvalOut::of`], without materializing the
    /// per-replica decision vector. The fleet-sweep hot path
    /// ([`crate::manager::MultiPolicySim`]) calls this behind its
    /// snapshot-signature memo; the default implementation delegates to
    /// [`FtPolicy::respond`], and every in-tree policy overrides it with
    /// a scratch-buffer version (equivalence asserted in
    /// `rust/tests/policy_conformance.rs`).
    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        _scratch: &mut EvalScratch,
    ) -> EvalOut {
        EvalOut::of(&self.respond(ctx, job_healthy), ctx.table.full_local_batch)
    }

    /// GPU-seconds of downtime charged when the fleet's per-domain
    /// health changes from `prev` to `next` (full fleet, spares
    /// included). Under exact event-boundary integration
    /// ([`crate::manager::StepMode::Exact`], the default) this is
    /// charged once per actual change boundary; grid sweeps collapse
    /// the events between two samples into one net change. Must return
    /// `0.0` when `ctx.transition` is `None` — that is what makes the
    /// legacy ports bit-identical to the pre-policy-layer paths.
    fn transition_cost(&self, _ctx: &PolicyCtx, _prev: &[usize], _next: &[usize]) -> f64 {
        0.0
    }

    /// Evaluate one snapshot that carries *degradation* information:
    /// `job_degraded[d]` GPUs of job domain `d` are alive but slow, the
    /// slowest delivering fraction `job_slowdowns[d]` of nominal speed
    /// (exactly `1.0` where none are degraded). The default keeps the
    /// degraded GPUs in place: it responds to the plain healthy counts
    /// and multiplies throughput by the capacity-weighted TP-group drag
    /// ([`StrategyTable::group_drag`] — the slowest member paces its
    /// group). With no degraded domain the drag factor is exactly `1.0`
    /// and this collapses bit-exactly to the plain respond path.
    /// `STRAGGLER-EVICT` overrides it to treat degraded GPUs as failed
    /// instead (reshard away the straggler, keep full group pace).
    ///
    /// Power: a degraded GPU runs slow because it runs capped
    /// (thermal throttle, flaky link retraining), so each one is
    /// derated from nominal draw to
    /// [`crate::power::RackDesign::degraded_derate`]. The guard keeps
    /// the zero-degradation collapse bit-exact (no subtraction at all),
    /// and the hottest-domain draw is left conservative (the hottest
    /// domain need not be the degraded one).
    fn eval_degraded(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        job_degraded: &[usize],
        job_slowdowns: &[f64],
    ) -> EvalOut {
        let mut out = EvalOut::of(&self.respond(ctx, job_healthy), ctx.table.full_local_batch);
        out.tput *= ctx.table.group_drag(job_healthy, job_slowdowns);
        let degraded: usize = job_degraded.iter().sum();
        if !out.paused && degraded > 0 {
            out.power -=
                degraded as f64 * (1.0 - ctx.table.rack.degraded_derate) / ctx.n_gpus as f64;
        }
        out
    }

    /// Allocation-free [`FtPolicy::eval_degraded`] — the shared-sweep
    /// hot path ([`crate::manager::MultiPolicySim`]); must agree
    /// bit-for-bit with it, exactly as [`FtPolicy::respond_with`] must
    /// agree with [`FtPolicy::respond`] (both pinned by the conformance
    /// suite).
    fn eval_degraded_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        job_degraded: &[usize],
        job_slowdowns: &[f64],
        scratch: &mut EvalScratch,
    ) -> EvalOut {
        let mut out = self.respond_with(ctx, job_healthy, scratch);
        out.tput *= ctx.table.group_drag(job_healthy, job_slowdowns);
        let degraded: usize = job_degraded.iter().sum();
        if !out.paused && degraded > 0 {
            out.power -=
                degraded as f64 * (1.0 - ctx.table.rack.degraded_derate) / ctx.n_gpus as f64;
        }
        out
    }

    /// GPU-seconds of downtime charged when the per-domain *degraded*
    /// counts change (straggler onset or remediation) — the
    /// degradation-layer counterpart of [`FtPolicy::transition_cost`],
    /// charged by the sweeps only when the degraded counts actually
    /// differ. Defaults to `0.0`: policies that keep stragglers in
    /// place reconfigure nothing when one appears. Must return `0.0`
    /// when `ctx.transition` is `None`.
    fn degrade_transition_cost(&self, _ctx: &PolicyCtx, _prev: &[usize], _next: &[usize]) -> f64 {
        0.0
    }

    /// GPU-seconds of downtime one *spurious* failure/straggler
    /// detection costs this policy (the detector fired, the policy
    /// reconfigured, the "fault" turned out to be noise, and the policy
    /// reconfigured back). Billed in expectation by the sims as
    /// `DetectionModel::false_positive_events × this`, through the same
    /// rollback channel as SDC detection lag — the trace and every
    /// response memo stay untouched. Defaults to `0.0`: a policy that
    /// does not react to a degrade signal (or reacts for free) loses
    /// nothing to a false alarm. Must return `0.0` when
    /// `ctx.transition` is `None`.
    fn false_positive_cost(&self, _ctx: &PolicyCtx) -> f64 {
        0.0
    }

    /// Whether [`FtPolicy::transition_cost`] is a pure function of the
    /// *counts* `(changed domains, degraded domains)` plus the context
    /// (live spare pool, total GPUs, cost model) — i.e. independent of
    /// *which* domains changed and by how much. The shared sweep
    /// ([`crate::manager::MultiPolicySim`]) memoizes transition charges
    /// per count tuple only when this returns `true`. Every in-tree
    /// policy is count-pure (asserted by the conformance suite); the
    /// conservative default is `false` so out-of-tree policies must opt
    /// in explicitly.
    fn transition_cost_is_count_pure(&self) -> bool {
        false
    }
}

/// Modeled reconfiguration-cost inputs shared by all policies.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransitionCosts {
    /// Full-job restart latency (scheduler, process groups, checkpoint
    /// load), seconds.
    pub restart_secs: f64,
    /// Checkpoint interval, seconds; an unplanned failure rolls back
    /// half of it on average.
    pub checkpoint_interval_secs: f64,
    /// One NTP reshard reconfiguration of an affected replica, seconds
    /// (CopyPlan traffic over the scale-up link, see
    /// [`reshard_transition_secs`]).
    pub reshard_secs: f64,
    /// Streaming a replica shard's weights onto a migrated-in spare
    /// domain, seconds.
    pub spare_load_secs: f64,
    /// Writing one checkpoint, seconds (the Young/Daly δ that
    /// `CKPT-ADAPTIVE` optimizes its interval against).
    pub ckpt_write_secs: f64,
    /// Ramping a dark (power-capped) spare domain back to full power
    /// and stable clocks, seconds per domain (`POWER-SPARES`).
    pub power_ramp_secs: f64,
    /// Observed job-stopping failure rate, events per hour. `0.0` means
    /// "not observed": `CKPT-ADAPTIVE` then falls back to the fixed
    /// [`TransitionCosts::checkpoint_interval_secs`] and behaves exactly
    /// like `CKPT-RESTART`. Set from a trace via
    /// [`TransitionCosts::with_observed_rate`].
    pub failure_rate_per_hour: f64,
    /// Amortized periodic validation-sweep stall, GPU-seconds per GPU
    /// per simulated hour (the recurring cost of the SDC validation
    /// cadence, distinct from the per-detection rollback). Billed
    /// trace- and policy-independently over the whole horizon via the
    /// rollback channel. Default `0.0` ⇒ validation is free and every
    /// golden output is bitwise unchanged.
    pub validation_sweep_secs: f64,
    /// Reclaiming donated low-priority capacity when the primary job
    /// grows back (preempt the guest, drain its kernels, restore the
    /// partition), seconds per reclaimed GPU (`LOWPRI-DONATE`). Default
    /// `0.0` ⇒ preemption is free and every pre-existing output is
    /// bitwise unchanged.
    pub preempt_secs: f64,
    /// Streaming a replica shard's weights onto a migrated-in
    /// **cold-tier** spare (fleet-wide pool: scale-out fabric, image
    /// boot, no warm weights), seconds — the slow counterpart of
    /// [`TransitionCosts::spare_load_secs`]. Only read when a
    /// [`crate::manager::SparePolicy`] configures `cold_domains > 0`.
    pub cold_spare_load_secs: f64,
    /// Live peer-to-peer state transfer when a recovered domain rejoins
    /// an elastic DP world ([`elastic::ElasticDp`]), seconds per rejoin
    /// — one full replica shard (weights + fp32 master + AdamW moments)
    /// streamed from peers over the scale-up link, modeled by
    /// [`rejoin_transfer_secs`]. No checkpoint rollback term: healthy
    /// replicas never stopped.
    pub rejoin_secs: f64,
}

impl TransitionCosts {
    /// Defaults with the reshard term derived from the iteration
    /// model's `CopyPlan` for the deepest supported reduction.
    pub fn model(sim: &IterationModel, cfg: &ParallelConfig) -> TransitionCosts {
        TransitionCosts {
            restart_secs: 900.0,
            checkpoint_interval_secs: 3600.0,
            reshard_secs: reshard_transition_secs(sim, cfg),
            spare_load_secs: 300.0,
            ckpt_write_secs: 120.0,
            power_ramp_secs: 60.0,
            failure_rate_per_hour: 0.0,
            validation_sweep_secs: 0.0,
            preempt_secs: 0.0,
            cold_spare_load_secs: 1800.0,
            rejoin_secs: rejoin_transfer_secs(sim, cfg),
        }
    }

    /// The same costs with [`TransitionCosts::failure_rate_per_hour`]
    /// set to the trace's *observed* event rate — what `CKPT-ADAPTIVE`
    /// feeds the Young/Daly optimum instead of assuming an interval.
    pub fn with_observed_rate(self, trace: &crate::failure::Trace) -> TransitionCosts {
        self.with_observed_rate_over(std::slice::from_ref(trace))
    }

    /// [`TransitionCosts::with_observed_rate`] pooled over a
    /// Monte-Carlo batch: total events over total horizon hours. A
    /// shared sweep over many trials needs ONE cost model (the
    /// response memo fingerprints it), so the rate is estimated from
    /// the whole batch instead of any single trace; for a one-trace
    /// batch this is exactly `with_observed_rate`.
    pub fn with_observed_rate_over(self, traces: &[crate::failure::Trace]) -> TransitionCosts {
        let total_hours: f64 = traces.iter().map(|t| t.horizon_hours).sum();
        let rate = if total_hours > 0.0 {
            traces.iter().map(|t| t.events.len()).sum::<usize>() as f64 / total_hours
        } else {
            0.0
        };
        TransitionCosts { failure_rate_per_hour: rate, ..self }
    }
}

/// Wall-clock seconds one replica needs to reconfigure its TP layout:
/// the optimizer state behind every offloaded unit (weights, fp32
/// master copy, two AdamW moments ≈ 6× the bf16 weight bytes) moves
/// over the scale-up link, bounded by the busiest GPU of the
/// [`crate::ntp::CopyPlan`] for the deepest supported reduction.
pub fn reshard_transition_secs(sim: &IterationModel, cfg: &ParallelConfig) -> f64 {
    reshard_transition_secs_over(sim, cfg, sim.cluster.gpu.nvlink_gbs)
}

/// [`reshard_transition_secs`] over an explicit scale-up link bandwidth
/// (GB/s) instead of the cluster's NVLink spec — the `fleet
/// --reshard-gbs` calibration knob.
pub fn reshard_transition_secs_over(
    sim: &IterationModel,
    cfg: &ParallelConfig,
    link_gbs: f64,
) -> f64 {
    let n2 = min_supported_tp(cfg.tp);
    if n2 >= cfg.tp {
        return 0.0;
    }
    let info = sim.plan_cache().get(sim.model.ffn, cfg.tp, n2);
    let weight_unit_bytes = 2 * sim.model.hidden * 2;
    let state_bytes_per_unit = 6 * weight_unit_bytes;
    let bytes = (info.copy.max_moved_units_per_shard() * state_bytes_per_unit) as f64
        * sim.model.layers as f64
        / cfg.pp as f64;
    bytes / (link_gbs * 1e9)
}

/// Wall-clock seconds a recovered domain needs to rejoin an elastic DP
/// world *live*: the returning replica pulls a full stage shard of
/// optimizer state (bf16 weights + fp32 master copy + two AdamW
/// moments ≈ 8× the bf16 weight bytes per unit) peer-to-peer from a
/// healthy replica over the scale-up link — TorchFT-style
/// checkpoint-less recovery, so there is no rollback term and the
/// donors keep training while they stream.
pub fn rejoin_transfer_secs(sim: &IterationModel, cfg: &ParallelConfig) -> f64 {
    rejoin_transfer_secs_over(sim, cfg, sim.cluster.gpu.nvlink_gbs)
}

/// [`rejoin_transfer_secs`] over an explicit link bandwidth (GB/s) —
/// the `fleet --rejoin-secs` knob overrides the result directly, this
/// keeps the model testable against the reshard model it parallels.
pub fn rejoin_transfer_secs_over(
    sim: &IterationModel,
    cfg: &ParallelConfig,
    link_gbs: f64,
) -> f64 {
    let n2 = min_supported_tp(cfg.tp);
    if n2 >= cfg.tp {
        return 0.0;
    }
    // The FULL per-GPU comp shard moves (a rejoining domain holds
    // nothing), unlike a reshard which moves only the displaced units —
    // so the bound is the largest comp shard of the healthy CopyPlan,
    // not `max_moved_units_per_shard`. State per unit: bf16 weights +
    // fp32 master copy + two fp32 AdamW moments ≈ 8× the bf16 weight
    // bytes (2 bytes × hidden per weight unit).
    let info = sim.plan_cache().get(sim.model.ffn, cfg.tp, n2);
    let max_shard_units = info.copy.comp_units.iter().copied().max().unwrap_or(0);
    let weight_unit_bytes = 2 * sim.model.hidden * 2;
    let state_bytes_per_unit = 8 * weight_unit_bytes;
    let bytes = (max_shard_units * state_bytes_per_unit) as f64 * sim.model.layers as f64
        / cfg.pp as f64;
    bytes / (link_gbs * 1e9)
}

/// GPUs touched when `changed_domains` domains change health: every
/// replica containing a changed domain re-plans, so charge whole
/// replicas, capped at the fleet.
pub(crate) fn affected_gpus(ctx: &PolicyCtx, changed_domains: usize) -> usize {
    (changed_domains * ctx.domains_per_replica * ctx.domain_size).min(ctx.n_gpus)
}

/// Count of domains whose health differs between two snapshots.
pub(crate) fn changed_domains(prev: &[usize], next: &[usize]) -> usize {
    prev.iter().zip(next).filter(|(a, b)| a != b).count()
}

/// Count of domains that got *worse* (a new failure landed).
pub(crate) fn degraded_domains(prev: &[usize], next: &[usize]) -> usize {
    prev.iter().zip(next).filter(|(a, b)| b < a).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Dtype, WorkloadConfig};
    use crate::sim::SimParams;

    #[test]
    fn reshard_transition_secs_is_small_but_positive() {
        let sim = IterationModel::new(
            presets::model("gpt-480b").unwrap(),
            WorkloadConfig {
                seq_len: 16_384,
                minibatch_tokens: 16 << 20,
                dtype: Dtype::BF16,
            },
            presets::cluster("paper-32k-nvl32").unwrap(),
            SimParams::default(),
        );
        let cfg = ParallelConfig { tp: 32, pp: 8, dp: 128, microbatch: 1 };
        let t = reshard_transition_secs(&sim, &cfg);
        // moving ~GBs of optimizer state over NVLink: sub-second, not zero
        assert!(t > 0.0 && t < 60.0, "reshard transition {t}s");
        // nothing to reshard at TP1
        let cfg1 = ParallelConfig { tp: 1, pp: 8, dp: 128, microbatch: 1 };
        assert_eq!(reshard_transition_secs(&sim, &cfg1), 0.0);
        // A live rejoin streams the FULL shard (with heavier per-unit
        // state), so it costs strictly more than a reshard — but it is
        // still peer-to-peer over the scale-up link, nowhere near a
        // checkpoint rollback.
        let rejoin = rejoin_transfer_secs(&sim, &cfg);
        assert!(rejoin > t, "rejoin {rejoin}s should exceed reshard {t}s");
        assert!(rejoin < 1800.0, "rejoin {rejoin}s should beat a half-interval rollback");
        assert_eq!(rejoin_transfer_secs(&sim, &cfg1), 0.0);
    }

    #[test]
    fn observed_rate_is_events_per_hour() {
        use crate::failure::{EventKind, FailureEvent, Trace};
        let mk = |gpu| FailureEvent {
            at_hours: 1.0,
            gpu,
            is_hw: false,
            recover_at_hours: 2.0,
            kind: EventKind::Fail,
        };
        let trace = Trace { horizon_hours: 48.0, events: vec![mk(0), mk(1), mk(2)] };
        let base = TransitionCosts {
            restart_secs: 900.0,
            checkpoint_interval_secs: 3600.0,
            reshard_secs: 1.0,
            spare_load_secs: 300.0,
            ckpt_write_secs: 120.0,
            power_ramp_secs: 60.0,
            failure_rate_per_hour: 0.0,
            validation_sweep_secs: 0.0,
            preempt_secs: 0.0,
            cold_spare_load_secs: 1800.0,
            rejoin_secs: 2.0,
        };
        let t = base.with_observed_rate(&trace);
        assert!((t.failure_rate_per_hour - 3.0 / 48.0).abs() < 1e-15);
        // everything else untouched
        assert_eq!(t.restart_secs, base.restart_secs);
        assert_eq!(t.ckpt_write_secs, base.ckpt_write_secs);
        let empty = Trace { horizon_hours: 0.0, events: vec![] };
        assert_eq!(base.with_observed_rate(&empty).failure_rate_per_hour, 0.0);
        // pooled over a batch: total events / total hours
        let other = Trace { horizon_hours: 12.0, events: vec![mk(5), mk(6), mk(7)] };
        let pooled = base.with_observed_rate_over(&[
            Trace { horizon_hours: 48.0, events: vec![mk(0), mk(1), mk(2)] },
            other,
        ]);
        assert!((pooled.failure_rate_per_hour - 6.0 / 60.0).abs() < 1e-15);
        assert_eq!(base.with_observed_rate_over(&[]).failure_rate_per_hour, 0.0);
    }

    #[test]
    fn snapshot_helpers_count_changes() {
        let prev = [32usize, 31, 32, 30];
        let next = [32usize, 32, 31, 30];
        assert_eq!(changed_domains(&prev, &next), 2);
        assert_eq!(degraded_domains(&prev, &next), 1);
        assert_eq!(changed_domains(&prev, &prev), 0);
    }
}
