//! Rate-adaptive checkpoint–restart (Young/Daly).
//!
//! `CKPT-RESTART` rolls back half of a *fixed* 3600 s interval per
//! failure. This policy instead sets the interval to the Young/Daly
//! optimum `τ* = sqrt(2 δ M)` for the trace's **observed** failure rate
//! ([`super::TransitionCosts::failure_rate_per_hour`], set via
//! [`super::TransitionCosts::with_observed_rate`]) and checkpoint-write
//! cost `δ` ([`super::TransitionCosts::ckpt_write_secs`]). Two effects,
//! both modeled:
//!
//! * failures roll back `τ*/2` instead of half the fixed interval —
//!   cheaper whenever failures are frequent enough that `τ* < 3600 s`;
//! * writing checkpoints every `τ*` costs `δ/τ*` of steady-state
//!   throughput, charged through [`PolicyResponse::overhead`] — the
//!   honest price the fixed-interval baseline silently ignores.
//!
//! With no observed rate (`failure_rate_per_hour == 0`, the default of
//! [`super::TransitionCosts::model`]) there is nothing to adapt to and
//! the policy is **bit-identical** to `CKPT-RESTART` — asserted by the
//! fig6 bench. The interval math lives in
//! [`crate::train::checkpoint::young_daly_interval_secs`], unit-tested
//! against a brute-force minimization.

use super::checkpoint::{restart_capacity_respond, restart_capacity_respond_with};
use super::{
    degraded_domains, EvalOut, EvalScratch, FtPolicy, PolicyCtx, PolicyResponse, TransitionCosts,
};
use crate::train::checkpoint::young_daly_interval_secs;

/// Unit policy: all cost parameters come from
/// [`super::TransitionCosts`] in the context.
#[derive(Clone, Copy, Debug, Default)]
pub struct AdaptiveCheckpoint;

pub static CKPT_ADAPTIVE: AdaptiveCheckpoint = AdaptiveCheckpoint;

impl AdaptiveCheckpoint {
    /// The checkpoint interval in force: the Young/Daly optimum for the
    /// observed failure rate, or the fixed interval when no rate was
    /// observed (`failure_rate_per_hour == 0`).
    pub fn interval_secs(costs: &TransitionCosts) -> f64 {
        if costs.failure_rate_per_hour > 0.0 {
            young_daly_interval_secs(
                costs.ckpt_write_secs,
                3600.0 / costs.failure_rate_per_hour,
            )
        } else {
            costs.checkpoint_interval_secs
        }
    }

    /// Steady-state rate factor for writing a checkpoint every `τ*`
    /// seconds: `1 − δ/τ*`, exactly `1.0` when there is no observed
    /// rate to adapt to (the `CKPT-RESTART`-identical regime) or when
    /// checkpoints are free.
    fn write_overhead_factor(ctx: &PolicyCtx) -> f64 {
        match ctx.transition {
            Some(t) if t.failure_rate_per_hour > 0.0 => {
                let tau = Self::interval_secs(&t);
                if tau.is_finite() && tau > 0.0 {
                    (1.0 - t.ckpt_write_secs / tau).max(0.0)
                } else {
                    1.0
                }
            }
            _ => 1.0,
        }
    }
}

impl FtPolicy for AdaptiveCheckpoint {
    fn name(&self) -> &'static str {
        "CKPT-ADAPTIVE"
    }

    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse {
        let mut resp = restart_capacity_respond(ctx, job_healthy);
        resp.overhead = Self::write_overhead_factor(ctx);
        resp
    }

    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        s: &mut EvalScratch,
    ) -> EvalOut {
        let mut out = restart_capacity_respond_with(ctx, job_healthy, s);
        // `x * 1.0` is a bitwise no-op, so the no-rate regime stays
        // bit-identical to CKPT-RESTART (and a paused 0.0 stays 0.0).
        out.tput *= Self::write_overhead_factor(ctx);
        out
    }

    fn transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        let Some(t) = ctx.transition else { return 0.0 };
        // Full-job restart on any change (same fleet operation as
        // CKPT-RESTART); failures roll back half the *optimized*
        // interval.
        let rollback = if degraded_domains(prev, next) > 0 {
            0.5 * Self::interval_secs(&t)
        } else {
            0.0
        };
        ctx.n_gpus as f64 * (t.restart_secs + rollback)
    }

    fn transition_cost_is_count_pure(&self) -> bool {
        true
    }
}
