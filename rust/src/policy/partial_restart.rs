//! Partial (replica-scoped) restart — ByteDance-style partial recovery.
//!
//! Sits between NTP's live reshard and `ckpt-restart`'s global stop:
//! when a domain's health changes, only the DP replicas *containing*
//! that domain stop, restart their process groups on the surviving
//! hardware and roll back to their last checkpoint shard; the rest of
//! the fleet keeps training. Steady-state capacity is therefore the
//! same post-restart uniform-TP response as `ckpt-restart`
//! ([`super::checkpoint::restart_capacity_respond`]) — what changes is
//! the transition bill, which scales with the *affected* GPUs instead
//! of the whole fleet.
//!
//! First-order model: the unaffected replicas are assumed to keep
//! making progress through the replica restart (gradient contributions
//! of the restarting replica are skipped, as in partial-recovery
//! systems), so only the restarting replicas' GPU-seconds are charged.

use super::checkpoint::{restart_capacity_respond, restart_capacity_respond_with};
use super::{
    affected_gpus, changed_domains, degraded_domains, EvalOut, EvalScratch, FtPolicy, PolicyCtx,
    PolicyResponse,
};

/// Unit policy: all cost parameters come from
/// [`super::TransitionCosts`] in the context.
#[derive(Clone, Copy, Debug, Default)]
pub struct PartialRestart;

pub static PARTIAL_RESTART: PartialRestart = PartialRestart;

impl FtPolicy for PartialRestart {
    fn name(&self) -> &'static str {
        "PARTIAL-RESTART"
    }

    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse {
        restart_capacity_respond(ctx, job_healthy)
    }

    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        s: &mut EvalScratch,
    ) -> EvalOut {
        restart_capacity_respond_with(ctx, job_healthy, s)
    }

    fn transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        let Some(t) = ctx.transition else { return 0.0 };
        // Every replica containing a changed domain restarts; replicas
        // containing a freshly *degraded* domain additionally roll back
        // to their last checkpoint shard (half an interval on average).
        let restart = affected_gpus(ctx, changed_domains(prev, next)) as f64 * t.restart_secs;
        let rollback = affected_gpus(ctx, degraded_domains(prev, next)) as f64
            * 0.5
            * t.checkpoint_interval_secs;
        restart + rollback
    }

    fn transition_cost_is_count_pure(&self) -> bool {
        true
    }
}
