//! Detection-aware straggler policies: what to do with a GPU that is
//! alive but slow (thermal throttling, a flaky NVLink lane, ECC
//! retirement storms — the degraded-but-alive events the straggler
//! scenario generator emits).
//!
//! Both policies are exactly NTP on plain health counts — a straggler
//! is invisible to liveness checks, so a policy that only sees healthy
//! counts cannot react to it (and the registry-driven conformance
//! suite drives plain counts through every policy). They differ only
//! in the degradation-aware evaluation path:
//!
//! * [`STRAGGLER_EVICT`] — treat a degraded GPU as failed: reshard the
//!   affected replicas down one TP degree (the NTP response to the
//!   degradation-adjusted counts) and keep full group pace. Pays an
//!   NTP-style reshard transition every time the degraded counts
//!   change, wins when the slowdown is deep.
//! * [`STRAGGLER_TOLERATE`] — keep the straggler and eat the TP-group
//!   drag (the [`FtPolicy::eval_degraded`] default: the slowest member
//!   paces its group). Reconfigures nothing, wins when the slowdown is
//!   mild. The crossover slowdown between the two is the quantity the
//!   `fig12_scenarios` bench pins.

use super::legacy::NTP;
use super::{
    affected_gpus, changed_domains, EvalOut, EvalScratch, FtPolicy, PolicyCtx, PolicyResponse,
};

/// Evict stragglers: degraded GPUs are resharded away like failures.
#[derive(Clone, Copy, Debug)]
pub struct StragglerEvict;

/// Tolerate stragglers: degraded GPUs stay and drag their TP group.
#[derive(Clone, Copy, Debug)]
pub struct StragglerTolerate;

pub static STRAGGLER_EVICT: StragglerEvict = StragglerEvict;
pub static STRAGGLER_TOLERATE: StragglerTolerate = StragglerTolerate;

impl FtPolicy for StragglerEvict {
    fn name(&self) -> &'static str {
        "STRAGGLER-EVICT"
    }

    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse {
        NTP.respond(ctx, job_healthy)
    }

    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        scratch: &mut EvalScratch,
    ) -> EvalOut {
        NTP.respond_with(ctx, job_healthy, scratch)
    }

    fn eval_degraded(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        job_degraded: &[usize],
        job_slowdowns: &[f64],
    ) -> EvalOut {
        // Degraded GPUs count as failed; the evicted group runs at full
        // pace, so the slowdown factors are irrelevant here. Power falls
        // out the same way: the evicted straggler is powered down, so
        // the NTP snapshot on the adjusted counts already excludes its
        // draw (no derate term — the default derate path applies only to
        // *tolerated* stragglers).
        let _ = job_slowdowns;
        let effective: Vec<usize> = job_healthy
            .iter()
            .zip(job_degraded)
            .map(|(&h, &d)| h.saturating_sub(d))
            .collect();
        EvalOut::of(&NTP.respond(ctx, &effective), ctx.table.full_local_batch)
    }

    fn eval_degraded_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        job_degraded: &[usize],
        job_slowdowns: &[f64],
        scratch: &mut EvalScratch,
    ) -> EvalOut {
        let _ = job_slowdowns;
        // Take the buffer out so the NTP delegate may use the rest of
        // the scratch; element-wise identical to `eval_degraded`'s
        // `effective`, so both paths stay bit-identical.
        let mut eff = std::mem::take(&mut scratch.degrade_eff);
        eff.clear();
        eff.extend(job_healthy.iter().zip(job_degraded).map(|(&h, &d)| h.saturating_sub(d)));
        let out = NTP.respond_with(ctx, &eff, scratch);
        scratch.degrade_eff = eff;
        out
    }

    fn transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        NTP.transition_cost(ctx, prev, next)
    }

    fn degrade_transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        let Some(t) = ctx.transition else { return 0.0 };
        // Evicting (or readmitting) a straggler reshards the replicas
        // containing its domain — the same live TP reconfiguration an
        // NTP health transition pays.
        affected_gpus(ctx, changed_domains(prev, next)) as f64 * t.reshard_secs
    }

    fn false_positive_cost(&self, ctx: &PolicyCtx) -> f64 {
        let Some(t) = ctx.transition else { return 0.0 };
        // A falsely flagged straggler is evicted (one reshard) and
        // readmitted once the detector clears it (a second reshard) —
        // the round trip of `degrade_transition_cost` for one domain.
        2.0 * affected_gpus(ctx, 1) as f64 * t.reshard_secs
    }

    fn transition_cost_is_count_pure(&self) -> bool {
        true
    }
}

impl FtPolicy for StragglerTolerate {
    fn name(&self) -> &'static str {
        "STRAGGLER-TOLERATE"
    }

    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse {
        NTP.respond(ctx, job_healthy)
    }

    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        scratch: &mut EvalScratch,
    ) -> EvalOut {
        NTP.respond_with(ctx, job_healthy, scratch)
    }

    // eval_degraded / eval_degraded_with: the trait defaults — respond
    // to plain counts, multiply by the TP-group drag. That IS the
    // tolerate policy; degrade_transition_cost stays the default 0.0
    // (nothing reconfigures when a straggler appears or heals).

    fn transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        NTP.transition_cost(ctx, prev, next)
    }

    fn transition_cost_is_count_pure(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Dtype, WorkloadConfig};
    use crate::manager::StrategyTable;
    use crate::parallel::ParallelConfig;
    use crate::policy::TransitionCosts;
    use crate::power::RackDesign;
    use crate::sim::{IterationModel, SimParams};

    fn setup() -> (IterationModel, ParallelConfig, StrategyTable) {
        let sim = IterationModel::new(
            presets::model("gpt-480b").unwrap(),
            WorkloadConfig {
                seq_len: 16_384,
                minibatch_tokens: 2 * 1024 * 1024,
                dtype: Dtype::BF16,
            },
            presets::cluster("paper-32k-nvl32").unwrap(),
            SimParams::default(),
        );
        let cfg = ParallelConfig { tp: 32, pp: 4, dp: 16, microbatch: 1 };
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let table = StrategyTable::build(&sim, &cfg, &rack);
        (sim, cfg, table)
    }

    fn ctx<'a>(
        table: &'a StrategyTable,
        transition: Option<TransitionCosts>,
    ) -> PolicyCtx<'a> {
        PolicyCtx {
            table,
            domain_size: 32,
            domains_per_replica: 4,
            packed: true,
            spares: None,
            n_gpus: 2048,
            transition,
        }
    }

    #[test]
    fn plain_counts_are_exactly_ntp() {
        let (_sim, _cfg, table) = setup();
        let c = ctx(&table, None);
        let mut healthy = vec![32usize; 64];
        healthy[3] = 31;
        healthy[17] = 30;
        for policy in [&STRAGGLER_EVICT as &dyn FtPolicy, &STRAGGLER_TOLERATE] {
            let ours = policy.respond(&c, &healthy);
            let ntp = NTP.respond(&c, &healthy);
            assert_eq!(ours.replicas, ntp.replicas, "{}", policy.name());
            assert_eq!(ours.paused, ntp.paused);
            assert_eq!(ours.overhead, ntp.overhead);
            let mut s = EvalScratch::default();
            assert_eq!(
                policy.respond_with(&c, &healthy, &mut s),
                EvalOut::of(&ours, table.full_local_batch),
            );
        }
    }

    #[test]
    fn evict_reshards_tolerate_drags() {
        let (_sim, _cfg, table) = setup();
        let c = ctx(&table, None);
        let healthy = vec![32usize; 64];
        let mut degraded = vec![0usize; 64];
        degraded[5] = 1;
        let mut slow = vec![1.0f64; 64];
        slow[5] = 0.4;

        // Evict responds as if domain 5 lost a GPU: same as NTP on the
        // adjusted counts, full pace, slowdown ignored.
        let mut eff = healthy.clone();
        eff[5] = 31;
        let evict = STRAGGLER_EVICT.eval_degraded(&c, &healthy, &degraded, &slow);
        assert_eq!(evict, STRAGGLER_EVICT.evaluate_reference(&c, &eff));
        // Tolerate keeps full counts but eats the group drag.
        let tol = STRAGGLER_TOLERATE.eval_degraded(&c, &healthy, &degraded, &slow);
        let drag = table.group_drag(&healthy, &slow);
        assert!(drag < 1.0);
        assert!((tol.tput - drag).abs() < 1e-12, "tol {} drag {drag}", tol.tput);

        // Deep slowdown: evicting wins. Mild slowdown: tolerating wins.
        slow[5] = 0.3;
        let tol_deep = STRAGGLER_TOLERATE.eval_degraded(&c, &healthy, &degraded, &slow);
        assert!(evict.tput > tol_deep.tput, "evict {} tol {}", evict.tput, tol_deep.tput);
        slow[5] = 0.98;
        let tol_mild = STRAGGLER_TOLERATE.eval_degraded(&c, &healthy, &degraded, &slow);
        assert!(tol_mild.tput > evict.tput, "evict {} tol {}", evict.tput, tol_mild.tput);

        // Scratch variants agree bit-for-bit with the allocating ones.
        let mut s = EvalScratch::default();
        assert_eq!(
            STRAGGLER_EVICT.eval_degraded_with(&c, &healthy, &degraded, &slow, &mut s),
            STRAGGLER_EVICT.eval_degraded(&c, &healthy, &degraded, &slow),
        );
        assert_eq!(
            STRAGGLER_TOLERATE.eval_degraded_with(&c, &healthy, &degraded, &slow, &mut s),
            STRAGGLER_TOLERATE.eval_degraded(&c, &healthy, &degraded, &slow),
        );
    }

    #[test]
    fn degrade_transitions_charge_evict_only() {
        let (sim, cfg, table) = setup();
        let costs = TransitionCosts::model(&sim, &cfg);
        let c = ctx(&table, Some(costs));
        let prev = vec![0usize; 64];
        let mut next = prev.clone();
        next[2] = 1;
        let evict = STRAGGLER_EVICT.degrade_transition_cost(&c, &prev, &next);
        let expect = affected_gpus(&c, 1) as f64 * costs.reshard_secs;
        assert!(evict > 0.0 && (evict - expect).abs() < 1e-9, "evict {evict}");
        assert_eq!(STRAGGLER_TOLERATE.degrade_transition_cost(&c, &prev, &next), 0.0);
        // zero-cost contract without a transition model
        let free = ctx(&table, None);
        assert_eq!(STRAGGLER_EVICT.degrade_transition_cost(&free, &prev, &next), 0.0);
        // no change, no charge
        assert_eq!(STRAGGLER_EVICT.degrade_transition_cost(&c, &prev, &prev), 0.0);
    }
}

#[cfg(test)]
impl StragglerEvict {
    /// Test helper: the plain-counts evaluation `eval_degraded` must
    /// reduce to when eviction is applied by hand.
    fn evaluate_reference(&self, ctx: &PolicyCtx, counts: &[usize]) -> EvalOut {
        EvalOut::of(&NTP.respond(ctx, counts), ctx.table.full_local_batch)
    }
}
