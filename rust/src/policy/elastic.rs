//! TorchFT-style elastic data parallelism with checkpoint-less live
//! rejoin.
//!
//! The classical DP-drop response treats a damaged replica as lost
//! capacity *and* bills every fleet-health change as a full-job restart
//! (process groups are static, the world size is baked in). Elastic DP
//! — the TorchFT/TorchTitan shape described in SNIPPETS.md Snippet 1 —
//! makes the DP world size itself dynamic:
//!
//! * **shrink**: when a replica loses a domain, the surviving replicas
//!   re-form their process groups *live* and keep training on the
//!   elastic (rescaled) minibatch — nobody pauses, nothing rolls back,
//!   and the bill is the affected replicas' group re-formation
//!   ([`TransitionCosts::reshard_secs`]), not a restart;
//! * **grow**: when a domain recovers, its replica *rejoins live*,
//!   pulling its full stage shard (weights + fp32 master + AdamW
//!   moments) peer-to-peer from a healthy donor over the scale-up link
//!   ([`TransitionCosts::rejoin_secs`], derived from the `CopyPlan`
//!   machinery by [`super::rejoin_transfer_secs`]). There is **no
//!   checkpoint rollback term anywhere** — the healthy world never
//!   stopped, so there is nothing to roll back to.
//!
//! The *capacity* response is uniform-TP DP-drop (damaged replicas sit
//! out — elastic DP scales the world, it does not reshard TP within a
//! replica), so with transition costs disabled elastic-DP is
//! bit-identical to `DP-DROP` on flexible minibatch; everything that
//! distinguishes it is in what a health change *costs* and in never
//! pausing: a fixed-minibatch caller still gets `paused = false`
//! because the elastic world redefines the effective minibatch at each
//! world-size change (the throughput fraction already reflects the
//! missing replicas' samples).

use super::{
    affected_gpus, changed_domains, degraded_domains, legacy, EvalOut, EvalScratch, FtPolicy,
    PolicyCtx, PolicyResponse,
};
use crate::manager::packing::{packed_replica_tp, packed_replica_tp_into};
use crate::manager::spares::{apply_spares, apply_spares_into};
use crate::sim::engine::FtStrategy;

/// Unit policy: all cost parameters come from
/// [`super::TransitionCosts`] in the context.
#[derive(Clone, Copy, Debug, Default)]
pub struct ElasticDp;

pub static ELASTIC_DP: ElasticDp = ElasticDp;

impl FtPolicy for ElasticDp {
    fn name(&self) -> &'static str {
        "ELASTIC-DP"
    }

    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse {
        // Spares substitute wholesale first (a two-tier pool changes
        // the transition bill, not the capacity response), then damaged
        // replicas leave the elastic world (DP-drop capacity).
        let (replica_tp, spares_used) = match ctx.spares {
            Some(pool) => {
                let o = apply_spares(
                    job_healthy,
                    ctx.domain_size,
                    ctx.domains_per_replica,
                    &pool,
                );
                (o.assignment.replica_tp, o.spares_used)
            }
            None => (
                packed_replica_tp(
                    job_healthy,
                    ctx.domain_size,
                    ctx.domains_per_replica,
                    ctx.packed,
                ),
                0,
            ),
        };
        // Never boosts; dropped replicas' healthy GPUs stay warm (they
        // are live peers waiting to rejoin, not powered-down hardware),
        // so the fleet draw is the plain healthy-GPU snapshot.
        let (power, rack_power) = super::snapshot_power(ctx, job_healthy, false, 1.0);
        PolicyResponse {
            replicas: legacy::decisions(ctx.table, &replica_tp, FtStrategy::DpDrop),
            // Never pauses: the elastic world rescales its minibatch.
            paused: false,
            spares_used,
            overhead: 1.0,
            donated: 0.0,
            power,
            rack_power,
        }
    }

    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        s: &mut EvalScratch,
    ) -> EvalOut {
        let spares_used = match ctx.spares {
            Some(pool) => {
                let used = apply_spares_into(
                    job_healthy,
                    ctx.domain_size,
                    &pool,
                    &mut s.effective,
                    &mut s.order,
                );
                packed_replica_tp_into(
                    &s.effective,
                    ctx.domain_size,
                    ctx.domains_per_replica,
                    true,
                    &mut s.pack,
                    &mut s.replica_tp,
                );
                used
            }
            None => {
                packed_replica_tp_into(
                    job_healthy,
                    ctx.domain_size,
                    ctx.domains_per_replica,
                    ctx.packed,
                    &mut s.pack,
                    &mut s.replica_tp,
                );
                0
            }
        };
        let processed: usize = s
            .replica_tp
            .iter()
            .map(|&tp| ctx.table.replica_batch(tp, FtStrategy::DpDrop))
            .sum();
        let capacity = ctx.table.full_local_batch * s.replica_tp.len();
        let (power, rack_power) = super::snapshot_power(ctx, job_healthy, false, 1.0);
        // overhead is exactly 1.0 (uniform TP, no reshard within a
        // replica): multiplying by it is a bitwise no-op, omitted.
        EvalOut {
            tput: processed as f64 / capacity as f64,
            paused: false,
            spares_used,
            donated: 0.0,
            power,
            rack_power,
        }
    }

    fn transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        let Some(t) = ctx.transition else { return 0.0 };
        let changed = changed_domains(prev, next);
        let degraded = degraded_domains(prev, next);
        // Shrink: affected replicas' survivors re-form process groups
        // live (reshard-scale, not restart-scale). Grow: each improved
        // domain's replica streams its full shard back in peer-to-peer.
        // No rollback term — healthy replicas never stopped.
        let shrink = affected_gpus(ctx, degraded) as f64 * t.reshard_secs;
        let grow = affected_gpus(ctx, changed - degraded) as f64 * t.rejoin_secs;
        shrink + grow
    }

    fn false_positive_cost(&self, ctx: &PolicyCtx) -> f64 {
        let Some(t) = ctx.transition else { return 0.0 };
        // A spurious failure detection ejects one replica from the
        // elastic world (its survivors re-form groups) and then
        // readmits it via a live rejoin once the false alarm clears.
        affected_gpus(ctx, 1) as f64 * (t.reshard_secs + t.rejoin_secs)
    }

    fn transition_cost_is_count_pure(&self) -> bool {
        true
    }
}
