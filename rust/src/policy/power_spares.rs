//! Power-aware dark spares.
//!
//! Provisioning spare scale-up domains costs rack power even while they
//! idle. This policy keeps the spare pool **dark** — power-capped to a
//! standby fraction of TDP via the [`crate::power::RackDesign`] budget
//! model — until a failure migrates one in. The capacity response is
//! exactly [`super::spare_migration::SpareMigration`]'s
//! migrate-then-stack-then-shrink (delegated, so the primary job's
//! throughput is bit-identical to `SPARE-MIG`); what changes:
//!
//! * steady state credits the rack budget freed by the *unused* dark
//!   domains — provisioned at [`RackDesign::rack_budget_frac`] × TDP
//!   per GPU, drawing only `standby_power_frac` while dark — through
//!   the secondary accounting channel ([`PolicyResponse::donated`]),
//!   per provisioned GPU;
//! * each migrated-in domain pays a power **ramp-up**
//!   ([`super::TransitionCosts::power_ramp_secs`]) on top of the weight
//!   load before it can serve traffic.

use super::spare_migration::{migrated_domains, SPARE_MIGRATION};
use super::{EvalOut, EvalScratch, FtPolicy, PolicyCtx, PolicyResponse};
use crate::power::{RackDesign, ThermalModel};

#[derive(Clone, Debug)]
pub struct PowerSpares {
    /// Rack budget model the dark pool is capped under.
    pub rack: RackDesign,
    /// Standby power of a dark spare domain as a fraction of TDP
    /// (VR/HBM retention + fabric keep-alive).
    pub standby_power_frac: f64,
}

pub static POWER_SPARES: PowerSpares = PowerSpares {
    rack: RackDesign {
        gpu_boost_cap: 1.3,
        rack_budget_frac: 1.3,
        thermal: ThermalModel::UNLIMITED,
        standby_frac: 0.15,
        idle_frac: 0.15,
        degraded_derate: 0.7,
        row_domains: 0,
        row_budget_frac: 1.0,
    },
    standby_power_frac: 0.15,
};

impl PowerSpares {
    /// Saved-rack-power credit of the dark (unused) spare domains, in
    /// units of nominal (TDP) GPU power per provisioned GPU. A spare
    /// domain is provisioned for `rack_budget_frac × TDP` per GPU (the
    /// flexible rack's oversubscribed budget, §3.2) but draws only the
    /// standby fraction while dark — the difference is budget the row
    /// can redistribute (boost headroom for NTP-PW neighbors), which is
    /// what makes the rack design, not just the standby cap, shape the
    /// credit: a traditional rack (`rack_budget_frac = 1.0`) frees
    /// strictly less than the paper's 1.3× flexible rack.
    fn dark_credit(&self, ctx: &PolicyCtx, spares_used: usize) -> f64 {
        let Some(pool) = ctx.spares else { return 0.0 };
        let dark_gpus = pool.spare_domains.saturating_sub(spares_used) * ctx.domain_size;
        let freed_budget = (self.rack.rack_budget_frac - self.standby_power_frac).max(0.0);
        dark_gpus as f64 * freed_budget / ctx.n_gpus as f64
    }

    /// Real power *saved* by the dark pool versus the delegated warm
    /// pool: `SPARE-MIG`'s snapshot counts every spare GPU at nominal
    /// draw, but an unused dark domain sips only the fleet-wide
    /// [`RackDesign::standby_frac`] (the table's rack, so the CLI's
    /// rack knobs govern it — unlike the frozen `donated` credit, which
    /// keeps this policy's own provisioning constants). Pure in the
    /// damage multiset (depends only on the configured pool and
    /// `spares_used`), so the memoized response stays valid.
    fn dark_power_saving(&self, ctx: &PolicyCtx, spares_used: usize) -> f64 {
        let Some(pool) = ctx.spares else { return 0.0 };
        let dark_gpus = pool.spare_domains.saturating_sub(spares_used) * ctx.domain_size;
        dark_gpus as f64 * (1.0 - ctx.table.rack.standby_frac) / ctx.n_gpus as f64
    }
}

impl FtPolicy for PowerSpares {
    fn name(&self) -> &'static str {
        "POWER-SPARES"
    }

    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse {
        let mut resp = SPARE_MIGRATION.respond(ctx, job_healthy);
        resp.donated = self.dark_credit(ctx, resp.spares_used);
        if !resp.paused {
            resp.power -= self.dark_power_saving(ctx, resp.spares_used);
        }
        resp
    }

    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        s: &mut EvalScratch,
    ) -> EvalOut {
        let mut out = SPARE_MIGRATION.respond_with(ctx, job_healthy, s);
        out.donated = self.dark_credit(ctx, out.spares_used);
        if !out.paused {
            out.power -= self.dark_power_saving(ctx, out.spares_used);
        }
        out
    }

    fn transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        let Some(t) = ctx.transition else { return 0.0 };
        // Exactly SPARE-MIG's bill (affected replicas reshard,
        // migrated-in domains stream weights — delegated, so the two
        // policies cannot drift apart) plus the power ramp of waking
        // each migrated domain from standby.
        SPARE_MIGRATION.transition_cost(ctx, prev, next)
            + (migrated_domains(ctx, prev, next) * ctx.domain_size) as f64 * t.power_ramp_secs
    }

    fn transition_cost_is_count_pure(&self) -> bool {
        true
    }
}
