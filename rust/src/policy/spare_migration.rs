//! SPARe-inspired spare-migration / stacked policy.
//!
//! Ordering matters: the policy first *migrates* spare domains into the
//! most-damaged slots and *reorders* (stacks) the remaining damage into
//! the fewest replicas, and only then shrinks TP on the replicas still
//! carrying failures. Residual batch shortfall is redistributed over
//! the surviving replicas via gradient accumulation, so a
//! fixed-minibatch job keeps its global batch (at stretched iteration
//! time) instead of pausing — it only pauses when surviving capacity
//! falls below [`SpareMigration::min_capacity_frac`].

use super::{
    affected_gpus, changed_domains, degraded_domains, legacy, FtPolicy, PolicyCtx,
    PolicyResponse,
};
use crate::manager::packing::packed_replica_tp;
use crate::manager::spares::apply_spares;
use crate::sim::engine::FtStrategy;

#[derive(Clone, Copy, Debug)]
pub struct SpareMigration {
    /// Below this surviving-capacity fraction the redistribution stops
    /// making progress and the job pauses (fixed-minibatch mode).
    pub min_capacity_frac: f64,
}

pub static SPARE_MIGRATION: SpareMigration = SpareMigration { min_capacity_frac: 0.5 };

impl FtPolicy for SpareMigration {
    fn name(&self) -> &'static str {
        "SPARE-MIG"
    }

    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse {
        // 1) Migrate spares into the worst domains first.
        let (healthy, spares_used) = match ctx.spares {
            Some(pool) => {
                let o = apply_spares(
                    job_healthy,
                    ctx.domain_size,
                    ctx.domains_per_replica,
                    &pool,
                );
                (o.effective_healthy, o.spares_used)
            }
            None => (job_healthy.to_vec(), 0),
        };
        // 2) Stack residual damage into the fewest replicas (always
        //    reordered, regardless of ctx.packed), then NTP-shrink them.
        let replica_tp =
            packed_replica_tp(&healthy, ctx.domain_size, ctx.domains_per_replica, true);
        let replicas = legacy::decisions(ctx.table, &replica_tp, FtStrategy::Ntp);
        let overhead = legacy::overhead_for(ctx.table, &replica_tp, FtStrategy::Ntp);
        // 3) Redistribute the shortfall: survivors absorb the missing
        //    samples by gradient accumulation, so the fixed minibatch
        //    stays met while enough capacity survives.
        let processed: usize = replicas.iter().map(|r| r.batch).sum();
        let capacity = ctx.table.full_local_batch * replicas.len().max(1);
        let frac = processed as f64 / capacity as f64;
        let paused = ctx.spares.is_some() && frac < self.min_capacity_frac;
        PolicyResponse { replicas, paused, spares_used, overhead }
    }

    fn transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        let Some(t) = ctx.transition else { return 0.0 };
        // Affected replicas reshard their TP layout; each freshly
        // damaged domain additionally pulls a weight copy onto the
        // spare domain migrated into its place.
        let reshard = affected_gpus(ctx, changed_domains(prev, next)) as f64 * t.reshard_secs;
        let migrations = degraded_domains(prev, next) * ctx.domain_size;
        reshard + migrations as f64 * t.spare_load_secs
    }
}
