//! SPARe-inspired spare-migration / stacked policy.
//!
//! Ordering matters: the policy first *migrates* spare domains into the
//! most-damaged slots and *reorders* (stacks) the remaining damage into
//! the fewest replicas, and only then shrinks TP on the replicas still
//! carrying failures. Residual batch shortfall is redistributed over
//! the surviving replicas via gradient accumulation, so a
//! fixed-minibatch job keeps its global batch (at stretched iteration
//! time) instead of pausing — it only pauses when surviving capacity
//! falls below [`SpareMigration::min_capacity_frac`].

use super::{
    affected_gpus, changed_domains, degraded_domains, legacy, EvalOut, EvalScratch, FtPolicy,
    PolicyCtx, PolicyResponse,
};
use crate::manager::packing::{packed_replica_tp, packed_replica_tp_into};
use crate::manager::spares::{apply_spares, apply_spares_into};
use crate::sim::engine::FtStrategy;

#[derive(Clone, Copy, Debug)]
pub struct SpareMigration {
    /// Below this surviving-capacity fraction the redistribution stops
    /// making progress and the job pauses (fixed-minibatch mode).
    pub min_capacity_frac: f64,
}

pub static SPARE_MIGRATION: SpareMigration = SpareMigration { min_capacity_frac: 0.5 };

/// Spare domains migrated in by one health change: one per freshly
/// degraded domain, bounded by the *live* pool (failed spare domains
/// cannot be migrated in — `ctx.spares` carries the live-adjusted pool,
/// see `FleetSim::live_spares_in`); with no pool configured the count
/// models pulling in warm standbys, one per fresh failure. Shared by
/// `SPARE-MIG` and the dark-pool `POWER-SPARES` bill.
pub(crate) fn migrated_domains(ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> usize {
    let degraded = degraded_domains(prev, next);
    match ctx.spares {
        Some(pool) => degraded.min(pool.spare_domains),
        None => degraded,
    }
}

impl FtPolicy for SpareMigration {
    fn name(&self) -> &'static str {
        "SPARE-MIG"
    }

    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse {
        // 1) Migrate spares into the worst domains first.
        let (healthy, spares_used) = match ctx.spares {
            Some(pool) => {
                let o = apply_spares(
                    job_healthy,
                    ctx.domain_size,
                    ctx.domains_per_replica,
                    &pool,
                );
                (o.effective_healthy, o.spares_used)
            }
            None => (job_healthy.to_vec(), 0),
        };
        // 2) Stack residual damage into the fewest replicas (always
        //    reordered, regardless of ctx.packed), then NTP-shrink them.
        let replica_tp =
            packed_replica_tp(&healthy, ctx.domain_size, ctx.domains_per_replica, true);
        let replicas = legacy::decisions(ctx.table, &replica_tp, FtStrategy::Ntp);
        let overhead = legacy::overhead_for(ctx.table, &replica_tp, FtStrategy::Ntp);
        // 3) Redistribute the shortfall: survivors absorb the missing
        //    samples by gradient accumulation, so the fixed minibatch
        //    stays met while enough capacity survives.
        let processed: usize = replicas.iter().map(|r| r.batch).sum();
        let capacity = ctx.table.full_local_batch * replicas.len().max(1);
        let frac = processed as f64 / capacity as f64;
        let paused = ctx.spares.is_some() && frac < self.min_capacity_frac;
        // Plain-NTP shrink — no boost, so migrated-in spares draw full
        // nominal power (spare_frac = 1.0: the pool is kept warm here;
        // the dark-standby variant is `POWER-SPARES`).
        let (power, rack_power) = super::snapshot_power(ctx, job_healthy, paused, 1.0);
        PolicyResponse { replicas, paused, spares_used, overhead, donated: 0.0, power, rack_power }
    }

    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        s: &mut EvalScratch,
    ) -> EvalOut {
        // 1) Migrate spares into the worst domains first.
        let (spares_used, packed_from_effective) = match ctx.spares {
            Some(pool) => (
                apply_spares_into(
                    job_healthy,
                    ctx.domain_size,
                    &pool,
                    &mut s.effective,
                    &mut s.order,
                ),
                true,
            ),
            None => (0, false),
        };
        // 2) Stack residual damage into the fewest replicas (always
        //    reordered, regardless of ctx.packed), then NTP-shrink them.
        let healthy: &[usize] =
            if packed_from_effective { &s.effective } else { job_healthy };
        packed_replica_tp_into(
            healthy,
            ctx.domain_size,
            ctx.domains_per_replica,
            true,
            &mut s.pack,
            &mut s.replica_tp,
        );
        let overhead = legacy::overhead_for(ctx.table, &s.replica_tp, FtStrategy::Ntp);
        // 3) Redistribute the shortfall (gradient accumulation) — pause
        //    only below the minimum surviving-capacity fraction.
        let processed: usize = s
            .replica_tp
            .iter()
            .map(|&tp| ctx.table.replica_batch(tp, FtStrategy::Ntp))
            .sum();
        let capacity = ctx.table.full_local_batch * s.replica_tp.len().max(1);
        let frac = processed as f64 / capacity as f64;
        let paused = ctx.spares.is_some() && frac < self.min_capacity_frac;
        let (power, rack_power) = super::snapshot_power(ctx, job_healthy, paused, 1.0);
        if paused {
            return EvalOut { tput: 0.0, paused: true, spares_used, donated: 0.0, power, rack_power };
        }
        let throughput_capacity = ctx.table.full_local_batch * s.replica_tp.len();
        EvalOut {
            tput: processed as f64 / throughput_capacity as f64 * overhead,
            paused: false,
            spares_used,
            donated: 0.0,
            power,
            rack_power,
        }
    }

    fn transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        let Some(t) = ctx.transition else { return 0.0 };
        // Affected replicas reshard their TP layout; each freshly
        // damaged domain additionally pulls a weight copy onto the
        // spare domain migrated into its place ([`migrated_domains`]).
        // With a hierarchical pool, warm (per-row) spares are consumed
        // first at `spare_load_secs`; only migrations that overflow
        // into the cold tier pay `cold_spare_load_secs`. A flat pool
        // (`cold_domains == 0`) never enters the cold branch, keeping
        // the bill bitwise identical to the single-tier formula.
        let reshard = affected_gpus(ctx, changed_domains(prev, next)) as f64 * t.reshard_secs;
        let migrated = migrated_domains(ctx, prev, next);
        let (warm_used, cold_used) = match ctx.spares {
            Some(pool) => {
                let warm_live = pool.spare_domains - pool.cold_domains;
                let warm_used = migrated.min(warm_live);
                (warm_used, migrated - warm_used)
            }
            None => (migrated, 0),
        };
        let mut bill = reshard + (warm_used * ctx.domain_size) as f64 * t.spare_load_secs;
        if cold_used > 0 {
            bill += (cold_used * ctx.domain_size) as f64 * t.cold_spare_load_secs;
        }
        bill
    }

    fn transition_cost_is_count_pure(&self) -> bool {
        true
    }
}
