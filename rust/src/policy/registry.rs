//! Name → policy registry: the single place new fault-tolerance
//! policies are plugged in. CLI subcommands, benches and the
//! registry-driven conformance suite all enumerate or parse through
//! here — adding an entry to [`all`] is what buys a new policy its
//! automatic property coverage (`rust/tests/policy_conformance.rs`),
//! shared-sweep bit-identity (`rust/tests/multi_policy_sweep.rs`) and
//! golden-trace pin (`rust/tests/golden_trace.rs`).

use super::adaptive_checkpoint::CKPT_ADAPTIVE;
use super::checkpoint::CKPT_RESTART;
use super::elastic::ELASTIC_DP;
use super::legacy::{DP_DROP, NTP, NTP_PW};
use super::lowpri_donation::LOWPRI_DONATE;
use super::partial_restart::PARTIAL_RESTART;
use super::power_spares::POWER_SPARES;
use super::spare_migration::SPARE_MIGRATION;
use super::straggler::{STRAGGLER_EVICT, STRAGGLER_TOLERATE};
use super::FtPolicy;

/// Every registered policy with its default parameters (the
/// conformance suite runs against exactly this list).
pub fn all() -> [&'static dyn FtPolicy; 12] {
    [
        &DP_DROP,
        &NTP,
        &NTP_PW,
        &CKPT_RESTART,
        &SPARE_MIGRATION,
        &LOWPRI_DONATE,
        &PARTIAL_RESTART,
        &POWER_SPARES,
        &CKPT_ADAPTIVE,
        &STRAGGLER_EVICT,
        &STRAGGLER_TOLERATE,
        &ELASTIC_DP,
    ]
}

/// Registered CLI names (canonical spellings).
pub fn names() -> Vec<&'static str> {
    all().iter().map(|p| p.name()).collect()
}

/// Parse a CLI name (accepts the legacy `FtStrategy` spellings plus
/// the new policies' aliases).
pub fn parse(name: &str) -> anyhow::Result<&'static dyn FtPolicy> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "dp-drop" | "dpdrop" | "drop" => &DP_DROP,
        "ntp" => &NTP,
        "ntp-pw" | "ntppw" | "pw" => &NTP_PW,
        "ckpt-restart" | "ckpt" | "checkpoint" | "checkpoint-restart" => &CKPT_RESTART,
        "spare-mig" | "spare-migration" | "stacked" => &SPARE_MIGRATION,
        "lowpri-donate" | "lowpri" | "donate" => &LOWPRI_DONATE,
        "partial-restart" | "partial" => &PARTIAL_RESTART,
        "power-spares" | "dark-spares" => &POWER_SPARES,
        "ckpt-adaptive" | "adaptive" | "young-daly" => &CKPT_ADAPTIVE,
        "straggler-evict" | "evict" => &STRAGGLER_EVICT,
        "straggler-tolerate" | "tolerate" => &STRAGGLER_TOLERATE,
        "elastic-dp" | "elastic" | "torchft" => &ELASTIC_DP,
        other => anyhow::bail!(
            "unknown policy '{other}' (known: dp-drop, ntp, ntp-pw, ckpt-restart, \
             spare-mig, lowpri-donate, partial-restart, power-spares, ckpt-adaptive, \
             straggler-evict, straggler-tolerate, elastic-dp)"
        ),
    })
}

/// Parse a comma-separated policy list (the `fleet --strategy` syntax).
pub fn parse_list(list: &str) -> anyhow::Result<Vec<&'static dyn FtPolicy>> {
    list.split(',').map(|s| parse(s.trim())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_parse_back() {
        for p in all() {
            let again = parse(p.name()).unwrap();
            assert_eq!(again.name(), p.name());
        }
    }

    #[test]
    fn aliases_and_lists() {
        assert_eq!(parse("drop").unwrap().name(), "DP-DROP");
        assert_eq!(parse("checkpoint").unwrap().name(), "CKPT-RESTART");
        assert_eq!(parse("stacked").unwrap().name(), "SPARE-MIG");
        assert_eq!(parse("lowpri").unwrap().name(), "LOWPRI-DONATE");
        assert_eq!(parse("partial").unwrap().name(), "PARTIAL-RESTART");
        assert_eq!(parse("dark-spares").unwrap().name(), "POWER-SPARES");
        assert_eq!(parse("young-daly").unwrap().name(), "CKPT-ADAPTIVE");
        assert_eq!(parse("evict").unwrap().name(), "STRAGGLER-EVICT");
        assert_eq!(parse("tolerate").unwrap().name(), "STRAGGLER-TOLERATE");
        assert_eq!(parse("elastic").unwrap().name(), "ELASTIC-DP");
        assert_eq!(parse("torchft").unwrap().name(), "ELASTIC-DP");
        let l = parse_list("ntp, ntp-pw,ckpt-adaptive").unwrap();
        assert_eq!(
            l.iter().map(|p| p.name()).collect::<Vec<_>>(),
            vec!["NTP", "NTP-PW", "CKPT-ADAPTIVE"]
        );
        assert!(parse("nope").is_err());
        assert!(parse_list("ntp,nope").is_err());
    }

    #[test]
    fn registry_is_twelve_distinct_policies() {
        let names = names();
        assert_eq!(names.len(), 12);
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), 12);
    }
}
