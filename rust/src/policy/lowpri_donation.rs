//! Low-priority donation policy (paper §3.3).
//!
//! The NTP capacity response leaves healthy GPUs idle wherever a
//! replica runs below its domain's healthy count (and leaves *every*
//! healthy GPU of a dropped or paused replica idle). The paper notes
//! those GPUs "can be made available to run other workloads rather than
//! remain idle" — [`crate::manager::lowpri`] models that inventory and
//! scheduler, and this policy lifts it into the [`FtPolicy`] layer: the
//! primary job's throughput is **bit-identical** to plain NTP, and the
//! capacity recovered by hosting best-effort low-priority work flows
//! through the secondary accounting channel
//! ([`PolicyResponse::donated`] → `FleetStats::mean_donated`, the
//! `donated` column of `fleet --json`).
//!
//! The reference [`FtPolicy::respond`] path builds the donatable
//! inventory and drives it through the real best-fit scheduler
//! ([`crate::manager::lowpri::schedule`], saturating best-effort
//! demand: one job per idle block); the allocation-free
//! [`FtPolicy::respond_with`] computes the same donation in closed form
//! — every idle block places exactly, so both are the same integer sum
//! (equivalence asserted by the conformance suite).

use super::legacy::NTP;
use super::{
    affected_gpus, changed_domains, degraded_domains, EvalOut, EvalScratch, FtPolicy, PolicyCtx,
    PolicyResponse,
};
use crate::manager::lowpri::{self, LowPriJob};
use crate::manager::packing::pack_domains;
use crate::manager::spares::apply_spares;
use crate::sim::engine::FtStrategy;

/// Unit policy: NTP capacity + saturating low-priority donation.
#[derive(Clone, Copy, Debug, Default)]
pub struct LowpriDonate;

pub static LOWPRI_DONATE: LowpriDonate = LowpriDonate;

impl FtPolicy for LowpriDonate {
    fn name(&self) -> &'static str {
        "LOWPRI-DONATE"
    }

    fn respond(&self, ctx: &PolicyCtx, job_healthy: &[usize]) -> PolicyResponse {
        let mut resp = NTP.respond(ctx, job_healthy);
        // Rebuild the exact assignment the NTP response derived from
        // (same calls, deterministic), to know which domain backs which
        // replica.
        let (healthy, assignment) = match ctx.spares {
            Some(pool) => {
                let o = apply_spares(
                    job_healthy,
                    ctx.domain_size,
                    ctx.domains_per_replica,
                    &pool,
                );
                (o.effective_healthy, o.assignment)
            }
            None => (
                job_healthy.to_vec(),
                pack_domains(job_healthy, ctx.domain_size, ctx.domains_per_replica, ctx.packed),
            ),
        };
        // Donatable inventory: idle healthy GPUs of running replicas
        // (healthy − TP per domain), every healthy GPU of dropped
        // replicas, and — when the whole job is paused — everything.
        let mut in_replica = vec![false; healthy.len()];
        let mut inventory: Vec<(usize, usize)> = Vec::new();
        for (r, doms) in assignment.replicas.iter().enumerate() {
            let running = !resp.paused && resp.replicas[r].batch > 0;
            let tp = if running { assignment.replica_tp[r] } else { 0 };
            for &d in doms {
                in_replica[d] = true;
                // tp <= min healthy of the chunk for every in-tree
                // assignment; saturate (as lowpri::idle_inventory does)
                // so an exotic future assignment degrades to "no idle"
                // instead of panicking.
                let idle = healthy[d].saturating_sub(tp);
                if idle > 0 {
                    inventory.push((d, idle));
                }
            }
        }
        // Domains backing no replica (possible only when the domain
        // count is not a replica multiple) are fully idle.
        for (d, &h) in healthy.iter().enumerate() {
            if !in_replica[d] && h > 0 {
                inventory.push((d, h));
            }
        }
        inventory.sort_unstable();
        // Saturating best-effort demand: one job per idle block. Every
        // job exact-fits some block, so the best-fit-decreasing
        // scheduler places all of them.
        let jobs: Vec<LowPriJob> = inventory
            .iter()
            .enumerate()
            .map(|(id, &(_, idle))| LowPriJob { id, gpus: idle })
            .collect();
        let (placements, unplaced) = lowpri::schedule(&inventory, &jobs);
        debug_assert!(unplaced.is_empty(), "exact-fit low-pri jobs must all place");
        resp.donated = lowpri::recovered_fraction(&placements, ctx.n_gpus);
        resp
    }

    fn respond_with(
        &self,
        ctx: &PolicyCtx,
        job_healthy: &[usize],
        s: &mut EvalScratch,
    ) -> EvalOut {
        let mut out = NTP.respond_with(ctx, job_healthy, s);
        // `s.replica_tp` (and, in fixed-minibatch mode, `s.effective`)
        // still hold this evaluation's state. Closed form of the
        // scheduler above: total healthy minus the GPUs actively
        // computing (running replicas only; a paused job computes on
        // nothing).
        let healthy_sum: usize = if ctx.spares.is_some() {
            s.effective.iter().sum()
        } else {
            job_healthy.iter().sum()
        };
        let used: usize = if out.paused {
            0
        } else {
            s.replica_tp
                .iter()
                .filter(|&&tp| ctx.table.replica_batch(tp, FtStrategy::Ntp) > 0)
                .map(|&tp| tp * ctx.domains_per_replica)
                .sum()
        };
        out.donated = healthy_sum.saturating_sub(used) as f64 / ctx.n_gpus as f64;
        out
    }

    fn transition_cost(&self, ctx: &PolicyCtx, prev: &[usize], next: &[usize]) -> f64 {
        // The primary job reconfigures exactly as NTP does. On top of
        // that, every *recovering* domain reclaims GPUs currently
        // hosting donated low-pri work, and the primary job waits out
        // the preemption grace window before it can reshard back up
        // ([`super::TransitionCosts::preempt_secs`], default `0.0`).
        // Degrading transitions only free capacity — nothing is
        // preempted — so on those this stays bit-identical to NTP.
        let base = NTP.transition_cost(ctx, prev, next);
        let Some(t) = ctx.transition else { return base };
        let improved = changed_domains(prev, next) - degraded_domains(prev, next);
        base + affected_gpus(ctx, improved) as f64 * t.preempt_secs
    }

    fn transition_cost_is_count_pure(&self) -> bool {
        true
    }
}
