//! Typed configuration: model shapes, GPU specs, cluster topologies and
//! training workloads, with JSON load/save (via [`crate::util::json`])
//! and the presets used throughout the paper's experiments.

pub mod presets;

use crate::util::json::Value;
use anyhow::Result;

/// Transformer model shape (decoder-only, Megatron-style).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub hidden: usize,
    /// FFN inner width (paper: 4x hidden).
    pub ffn: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub layers: usize,
    pub vocab: usize,
}

impl ModelConfig {
    /// Parameter count (embeddings + per-layer attn/MLP + final norm),
    /// untied input/output embeddings.
    pub fn params(&self) -> u64 {
        let h = self.hidden as u64;
        let f = self.ffn as u64;
        let attn_dim = (self.heads * self.head_dim) as u64;
        // qkv: h -> 3*attn_dim, out proj: attn_dim -> h, 2 norms (2h)
        let attn = 3 * h * attn_dim + attn_dim * h;
        let mlp = h * f + f * h;
        let per_layer = attn + mlp + 4 * h; // norms + biases approx
        2 * (self.vocab as u64) * h + (self.layers as u64) * per_layer + 2 * h
    }

    /// Training FLOPs per token (fwd+bwd ≈ 3x fwd; fwd ≈ 2·params + attention
    /// quadratic term).
    pub fn flops_per_token(&self, seq_len: usize) -> f64 {
        let dense = 2.0 * self.params() as f64;
        // attention scores+context: 2 matmuls of [seq, d] x [d, seq] per layer
        let attn_quad = 4.0 * (self.layers as f64)
            * (seq_len as f64)
            * (self.heads * self.head_dim) as f64;
        3.0 * (dense + attn_quad)
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", self.name.as_str().into()),
            ("hidden", self.hidden.into()),
            ("ffn", self.ffn.into()),
            ("heads", self.heads.into()),
            ("head_dim", self.head_dim.into()),
            ("layers", self.layers.into()),
            ("vocab", self.vocab.into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: v.req_str("name")?.to_string(),
            hidden: v.req_usize("hidden")?,
            ffn: v.req_usize("ffn")?,
            heads: v.req_usize("heads")?,
            head_dim: v.req_usize("head_dim")?,
            layers: v.req_usize("layers")?,
            vocab: v.req_usize("vocab")?,
        })
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.hidden > 0 && self.layers > 0, "empty model");
        anyhow::ensure!(
            self.heads * self.head_dim == self.hidden || self.head_dim > 0,
            "head geometry"
        );
        Ok(())
    }
}

/// Numeric format used for compute (affects flops and bytes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    BF16,
    FP8,
    FP32,
}

impl Dtype {
    pub fn bytes(self) -> usize {
        match self {
            Dtype::FP8 => 1,
            Dtype::BF16 => 2,
            Dtype::FP32 => 4,
        }
    }

    pub fn parse(s: &str) -> Result<Dtype> {
        match s.to_ascii_lowercase().as_str() {
            "bf16" => Ok(Dtype::BF16),
            "fp8" => Ok(Dtype::FP8),
            "fp32" | "f32" => Ok(Dtype::FP32),
            other => anyhow::bail!("unknown dtype '{other}'"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::BF16 => "bf16",
            Dtype::FP8 => "fp8",
            Dtype::FP32 => "fp32",
        }
    }
}

/// GPU ("AI accelerator") specification used by the performance simulator.
#[derive(Clone, Debug, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Peak dense TFLOP/s at BF16.
    pub tflops_bf16: f64,
    /// Peak dense TFLOP/s at FP8 (0 if unsupported).
    pub tflops_fp8: f64,
    /// HBM capacity, GiB.
    pub hbm_gib: f64,
    /// HBM bandwidth, GB/s.
    pub hbm_gbs: f64,
    /// Per-GPU scale-up (NVLink-class) bandwidth, GB/s unidirectional.
    pub nvlink_gbs: f64,
    /// Per-GPU scale-out (InfiniBand/Ethernet) bandwidth, GB/s.
    pub ib_gbs: f64,
    /// Nominal TDP, watts.
    pub tdp_w: f64,
    /// Max sustained boost as a fraction of TDP (paper rack design: 1.3).
    pub max_boost: f64,
    /// Exponent of the power-frequency curve: power ∝ freq^alpha
    /// (alpha ≈ 2.4 for recent datacenter GPUs; perf ∝ freq in the
    /// compute-bound regime).
    pub power_alpha: f64,
}

impl GpuSpec {
    /// Effective peak TFLOP/s for a dtype.
    pub fn tflops(&self, dtype: Dtype) -> f64 {
        match dtype {
            Dtype::BF16 => self.tflops_bf16,
            Dtype::FP8 => {
                if self.tflops_fp8 > 0.0 {
                    self.tflops_fp8
                } else {
                    self.tflops_bf16
                }
            }
            Dtype::FP32 => self.tflops_bf16 / 2.0,
        }
    }

    /// Relative performance at `power` (fraction of TDP): perf ∝ f,
    /// power ∝ f^alpha  ⇒  perf = power^(1/alpha). Clamped to
    /// `[idle floor, max_boost^(1/alpha)]`.
    pub fn perf_at_power(&self, power_frac: f64) -> f64 {
        let p = power_frac.clamp(0.2, self.max_boost);
        p.powf(1.0 / self.power_alpha)
    }

    /// Power fraction needed to reach `perf` (relative to TDP-perf).
    pub fn power_for_perf(&self, perf: f64) -> f64 {
        perf.max(0.0).powf(self.power_alpha)
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", self.name.as_str().into()),
            ("tflops_bf16", self.tflops_bf16.into()),
            ("tflops_fp8", self.tflops_fp8.into()),
            ("hbm_gib", self.hbm_gib.into()),
            ("hbm_gbs", self.hbm_gbs.into()),
            ("nvlink_gbs", self.nvlink_gbs.into()),
            ("ib_gbs", self.ib_gbs.into()),
            ("tdp_w", self.tdp_w.into()),
            ("max_boost", self.max_boost.into()),
            ("power_alpha", self.power_alpha.into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<GpuSpec> {
        Ok(GpuSpec {
            name: v.req_str("name")?.to_string(),
            tflops_bf16: v.req_f64("tflops_bf16")?,
            tflops_fp8: v.req_f64("tflops_fp8")?,
            hbm_gib: v.req_f64("hbm_gib")?,
            hbm_gbs: v.req_f64("hbm_gbs")?,
            nvlink_gbs: v.req_f64("nvlink_gbs")?,
            ib_gbs: v.req_f64("ib_gbs")?,
            tdp_w: v.req_f64("tdp_w")?,
            max_boost: v.req_f64("max_boost")?,
            power_alpha: v.req_f64("power_alpha")?,
        })
    }
}

/// Cluster topology: `n_gpus` split into scale-up (NVL) domains of
/// `domain_size`, grouped into racks (1 domain = 1 rack for GB200-class).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    pub name: String,
    pub n_gpus: usize,
    /// Scale-up domain size (NVL8 / NVL32 / NVL72 ...).
    pub domain_size: usize,
    /// GPUs that share a host board (failure blast radius option "node").
    pub gpus_per_node: usize,
    pub gpu: GpuSpec,
}

impl ClusterConfig {
    pub fn n_domains(&self) -> usize {
        self.n_gpus / self.domain_size
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.domain_size > 0, "domain_size = 0");
        anyhow::ensure!(
            self.n_gpus % self.domain_size == 0,
            "n_gpus {} not divisible by domain_size {}",
            self.n_gpus,
            self.domain_size
        );
        anyhow::ensure!(
            self.domain_size % self.gpus_per_node == 0,
            "domain_size {} not divisible by gpus_per_node {}",
            self.domain_size,
            self.gpus_per_node
        );
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", self.name.as_str().into()),
            ("n_gpus", self.n_gpus.into()),
            ("domain_size", self.domain_size.into()),
            ("gpus_per_node", self.gpus_per_node.into()),
            ("gpu", self.gpu.to_json()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<ClusterConfig> {
        Ok(ClusterConfig {
            name: v.req_str("name")?.to_string(),
            n_gpus: v.req_usize("n_gpus")?,
            domain_size: v.req_usize("domain_size")?,
            gpus_per_node: v.req_usize("gpus_per_node")?,
            gpu: GpuSpec::from_json(v.get("gpu"))?,
        })
    }
}

/// Training workload: sequence length and global batch in tokens.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    pub seq_len: usize,
    /// Global minibatch size in tokens (paper: 16M tokens).
    pub minibatch_tokens: usize,
    pub dtype: Dtype,
}

impl WorkloadConfig {
    pub fn global_batch(&self) -> usize {
        self.minibatch_tokens / self.seq_len
    }
}

/// Load a JSON config file into a `Value` (with `//` comments allowed).
pub fn load_json(path: &str) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    Value::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
}

/// Save a `Value` pretty-printed.
pub fn save_json(path: &str, v: &Value) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, v.pretty() + "\n")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_matches_expected_scale() {
        let m = presets::model("gpt-480b").unwrap();
        let p = m.params() as f64;
        // 480B nominal, allow 15% for accounting differences.
        assert!((p / 480e9 - 1.0).abs() < 0.15, "params {p:.3e}");
    }

    #[test]
    fn params_100m_scale() {
        let m = presets::model("e2e-100m").unwrap();
        let p = m.params() as f64;
        assert!((0.8e8..1.3e8).contains(&p), "params {p:.3e}");
    }

    #[test]
    fn model_json_roundtrip() {
        let m = presets::model("tiny").unwrap();
        let m2 = ModelConfig::from_json(&m.to_json()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn gpu_json_roundtrip() {
        let g = presets::gpu("b200").unwrap();
        let g2 = GpuSpec::from_json(&g.to_json()).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn cluster_validation() {
        let mut c = presets::cluster("paper-32k-nvl32").unwrap();
        c.validate().unwrap();
        assert_eq!(c.n_domains(), 1024);
        c.n_gpus = 100; // not divisible by 32
        assert!(c.validate().is_err());
    }

    #[test]
    fn power_curve_monotone_and_inverse() {
        let g = presets::gpu("b200").unwrap();
        let p1 = g.perf_at_power(1.0);
        let p13 = g.perf_at_power(1.3);
        assert!((p1 - 1.0).abs() < 1e-12);
        assert!(p13 > 1.0 && p13 < 1.3, "sublinear boost {p13}");
        // inverse consistency
        let need = g.power_for_perf(p13);
        assert!((need - 1.3).abs() < 1e-9);
    }

    #[test]
    fn perf_at_power_clamps() {
        let g = presets::gpu("h100").unwrap();
        assert_eq!(g.perf_at_power(5.0), g.perf_at_power(g.max_boost));
        assert_eq!(g.perf_at_power(0.0), g.perf_at_power(0.2));
    }

    #[test]
    fn dtype_bytes_and_parse() {
        assert_eq!(Dtype::BF16.bytes(), 2);
        assert_eq!(Dtype::parse("FP8").unwrap(), Dtype::FP8);
        assert!(Dtype::parse("int4").is_err());
    }

    #[test]
    fn flops_per_token_dominated_by_params() {
        let m = presets::model("gpt-175b").unwrap();
        let f = m.flops_per_token(2048);
        // classic 6·params lower bound
        assert!(f >= 6.0 * m.params() as f64);
        assert!(f < 8.0 * m.params() as f64);
    }

    #[test]
    fn workload_global_batch() {
        let w = WorkloadConfig {
            seq_len: 16384,
            minibatch_tokens: 16 * 1024 * 1024,
            dtype: Dtype::BF16,
        };
        assert_eq!(w.global_batch(), 1024);
    }
}
