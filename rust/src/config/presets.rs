//! Named presets for the models, GPUs and clusters used in the paper's
//! experiments, plus the CPU-scale models used by the real-execution
//! prototype (examples/ and the Fig. 8/9/11 benches).

use super::{ClusterConfig, GpuSpec, ModelConfig};
use anyhow::Result;

/// Model presets.
///
/// Paper-scale shapes (used analytically by the simulator):
/// * `gpt-480b` — §5.3: hidden 20480, 128 heads, FFN 4x, 100 layers.
/// * `gpt-340b` / `gpt-15b` — Fig. 11a validation workloads.
/// * `gpt-175b`, `gpt-70b`, `gpt-8b` — Fig. 11b sweep.
/// * `proto-12k` / `proto-6k` — §5.1 prototype shapes (hidden 12288/6144).
///
/// CPU-scale shapes (actually executed through PJRT):
/// * `tiny` — unit tests and the quickstart.
/// * `e2e-20m` — e2e loss-curve runs (hundreds of steps on 1 CPU core).
/// * `e2e-100m` — the ~100M-parameter end-to-end model.
pub fn model(name: &str) -> Result<ModelConfig> {
    let m = |name: &str, hidden, ffn, heads, head_dim, layers, vocab| ModelConfig {
        name: name.to_string(),
        hidden,
        ffn,
        heads,
        head_dim,
        layers,
        vocab,
    };
    Ok(match name {
        "gpt-480b" => m("gpt-480b", 20480, 81920, 128, 160, 100, 128_000),
        "gpt-340b" => m("gpt-340b", 18432, 73728, 96, 192, 96, 128_000),
        "gpt-175b" => m("gpt-175b", 12288, 49152, 96, 128, 96, 50_257),
        "gpt-70b" => m("gpt-70b", 8192, 28672, 64, 128, 80, 128_000),
        "gpt-15b" => m("gpt-15b", 5120, 20480, 40, 128, 48, 50_257),
        "gpt-8b" => m("gpt-8b", 4096, 14336, 32, 128, 32, 128_000),
        "proto-12k" => m("proto-12k", 12288, 49152, 96, 128, 3, 50_257),
        "proto-6k" => m("proto-6k", 6144, 24576, 48, 128, 3, 50_257),
        // CPU-scale (runnable) models. head_dim * heads == hidden.
        "tiny" => m("tiny", 64, 256, 4, 16, 2, 256),
        "e2e-20m" => m("e2e-20m", 320, 1280, 8, 40, 8, 8192),
        "e2e-100m" => m("e2e-100m", 640, 2560, 8, 80, 12, 32_768),
        other => anyhow::bail!("unknown model preset '{other}'"),
    })
}

/// GPU presets. Numbers are public spec-sheet values; `power_alpha` is
/// the effective power∝perf^α exponent. α = 1.5 reproduces the paper's
/// §6.4 perf/watt sensitivities (at 1.1× power, perf/watt drops ~2.8–3%;
/// at 1.2×, ~6%) and Table 1 (TP30-PW at ~1.15× power, TP28-PW at ~1.3×
/// with full batch). The effective α is below the core-voltage α≈2.4
/// because part of the package power (HBM, interconnect) doesn't scale
/// with core frequency.
pub fn gpu(name: &str) -> Result<GpuSpec> {
    let g = |name: &str,
             tflops_bf16,
             tflops_fp8,
             hbm_gib,
             hbm_gbs,
             nvlink_gbs,
             ib_gbs,
             tdp_w| GpuSpec {
        name: name.to_string(),
        tflops_bf16,
        tflops_fp8,
        hbm_gib,
        hbm_gbs,
        nvlink_gbs,
        ib_gbs,
        tdp_w,
        max_boost: 1.3,
        power_alpha: 1.5,
    };
    Ok(match name {
        "a100" => g("a100", 312.0, 0.0, 80.0, 2039.0, 300.0, 25.0, 400.0),
        "h100" => g("h100", 989.0, 1979.0, 80.0, 3350.0, 450.0, 50.0, 700.0),
        // Paper §5.3: B200, 189 GB, NVL 1.8 TB/s per GPU, 800 Gbps IB.
        "b200" => g("b200", 2250.0, 4500.0, 189.0, 8000.0, 900.0, 100.0, 1000.0),
        // Calibrated single-core CPU host used to validate the simulator
        // against real PJRT runs (Fig. 11). tflops here is *measured*
        // effective f32 throughput, see sim::calibrate.
        "cpu-host" => GpuSpec {
            name: "cpu-host".to_string(),
            tflops_bf16: 0.05,
            tflops_fp8: 0.0,
            hbm_gib: 32.0,
            hbm_gbs: 20.0,
            nvlink_gbs: 10.0,
            ib_gbs: 1.0,
            tdp_w: 65.0,
            max_boost: 1.3,
            power_alpha: 1.5,
        },
        other => anyhow::bail!("unknown gpu preset '{other}'"),
    })
}

/// Cluster presets.
///
/// * `paper-32k-nvl32` — §5.3 main simulation target: 32K B200, NVL32.
/// * `paper-32k-nvl{8,16,72}` — Fig. 2a NVL-domain sweep.
/// * `paper-100k-nvl72` — SPARe-scale fleet (100,800 B200 = 1400 NVL72
///   domains) for the shared multi-policy sweep engine.
/// * `llama3-16k-nvl8` — Fig. 4 failure-trace cluster (16K H100, DGX).
/// * `dgx-a100-2` — §5.1 prototype: 2 DGX-A100 (16 GPUs).
pub fn cluster(name: &str) -> Result<ClusterConfig> {
    Ok(match name {
        "paper-32k-nvl32" => ClusterConfig {
            name: name.to_string(),
            n_gpus: 32_768,
            domain_size: 32,
            gpus_per_node: 4, // GB200-class: 4 GPUs per compute tray
            gpu: gpu("b200")?,
        },
        "paper-32k-nvl8" => ClusterConfig {
            name: name.to_string(),
            n_gpus: 32_768,
            domain_size: 8,
            gpus_per_node: 4,
            gpu: gpu("b200")?,
        },
        "paper-32k-nvl16" => ClusterConfig {
            name: name.to_string(),
            n_gpus: 32_768,
            domain_size: 16,
            gpus_per_node: 4,
            gpu: gpu("b200")?,
        },
        "paper-32k-nvl72" => ClusterConfig {
            name: name.to_string(),
            n_gpus: 32_256, // 448 NVL72 domains
            domain_size: 72,
            gpus_per_node: 4,
            gpu: gpu("b200")?,
        },
        // SPARe-scale fleet (arXiv 2603.00357 argues 100K+ GPUs is the
        // regime where sweep cost explodes): 1400 NVL72 domains.
        "paper-100k-nvl72" => ClusterConfig {
            name: name.to_string(),
            n_gpus: 100_800,
            domain_size: 72,
            gpus_per_node: 4,
            gpu: gpu("b200")?,
        },
        "llama3-16k-nvl8" => ClusterConfig {
            name: name.to_string(),
            n_gpus: 16_384,
            domain_size: 8,
            gpus_per_node: 8,
            gpu: gpu("h100")?,
        },
        "dgx-a100-2" => ClusterConfig {
            name: name.to_string(),
            n_gpus: 16,
            domain_size: 8,
            gpus_per_node: 8,
            gpu: gpu("a100")?,
        },
        other => anyhow::bail!("unknown cluster preset '{other}'"),
    })
}

/// All model preset names (for `ntp plan --list`).
pub fn model_names() -> &'static [&'static str] {
    &[
        "gpt-480b", "gpt-340b", "gpt-175b", "gpt-70b", "gpt-15b", "gpt-8b",
        "proto-12k", "proto-6k", "tiny", "e2e-20m", "e2e-100m",
    ]
}

pub fn cluster_names() -> &'static [&'static str] {
    &[
        "paper-32k-nvl32",
        "paper-32k-nvl8",
        "paper-32k-nvl16",
        "paper-32k-nvl72",
        "paper-100k-nvl72",
        "llama3-16k-nvl8",
        "dgx-a100-2",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_model_presets_resolve_and_validate() {
        for name in model_names() {
            let m = model(name).unwrap();
            m.validate().unwrap();
            assert_eq!(&m.name, name);
        }
    }

    #[test]
    fn all_cluster_presets_resolve_and_validate() {
        for name in cluster_names() {
            let c = cluster(name).unwrap();
            c.validate().unwrap();
        }
    }

    #[test]
    fn unknown_presets_error() {
        assert!(model("nope").is_err());
        assert!(gpu("nope").is_err());
        assert!(cluster("nope").is_err());
    }

    #[test]
    fn runnable_models_have_consistent_heads() {
        for name in ["tiny", "e2e-20m", "e2e-100m"] {
            let m = model(name).unwrap();
            assert_eq!(m.heads * m.head_dim, m.hidden, "{name}");
            assert_eq!(m.ffn, 4 * m.hidden, "{name}");
        }
    }

    #[test]
    fn spare_scale_cluster_is_100k_nvl72() {
        let c = cluster("paper-100k-nvl72").unwrap();
        assert_eq!(c.n_gpus, 100_800);
        assert_eq!(c.domain_size, 72);
        assert_eq!(c.n_gpus / c.domain_size, 1400);
        assert_eq!(c.gpu.name, "b200");
    }

    #[test]
    fn paper_cluster_is_32k_b200_nvl32() {
        let c = cluster("paper-32k-nvl32").unwrap();
        assert_eq!(c.n_gpus, 32_768);
        assert_eq!(c.domain_size, 32);
        assert_eq!(c.gpu.name, "b200");
        assert!((c.gpu.hbm_gib - 189.0).abs() < 1e-9);
    }
}
