//! Exhaustive hybrid-parallel configuration search (the paper's §2.2
//! methodology: "we exhaustively search the space of hybrid-parallel
//! configurations"), under a TP-degree cap — reproduces Fig. 2b/14.

use super::config::ParallelConfig;
use super::memory::MemoryModel;
use crate::config::{ClusterConfig, ModelConfig, WorkloadConfig};
use crate::sim::{IterationModel, SimParams};

/// Result of a planner run.
#[derive(Clone, Debug)]
pub struct PlanChoice {
    pub cfg: ParallelConfig,
    pub tokens_per_sec_per_gpu: f64,
    pub breakdown: crate::sim::Breakdown,
}

/// All legal configs: TP ∈ powers-of-two ≤ min(cap, domain), PP divides
/// layers reasonably, DP fills the cluster, memory fits, batch divides.
pub fn enumerate_legal(
    model: &ModelConfig,
    work: &WorkloadConfig,
    cluster: &ClusterConfig,
    tp_cap: usize,
) -> Vec<ParallelConfig> {
    let mm = MemoryModel::default();
    let n = cluster.n_gpus;
    let global_batch = work.global_batch();
    let mut out = Vec::new();
    let mut tp = 1;
    while tp <= tp_cap.min(cluster.domain_size) {
        let mut pp = 1;
        while pp <= 64 && tp * pp <= n {
            if n % (tp * pp) == 0 && pp <= model.layers {
                let dp = n / (tp * pp);
                if dp <= global_batch && global_batch % dp == 0 {
                    for mb in [1usize, 2, 4] {
                        let cfg = ParallelConfig { tp, pp, dp, microbatch: mb };
                        if cfg.divides_batch(global_batch)
                            && mm.fits(model, &cfg, work, cluster.gpu.hbm_gib)
                        {
                            out.push(cfg);
                        }
                    }
                }
            }
            pp *= 2;
        }
        tp *= 2;
    }
    out
}

/// Best config by simulated tokens/s/GPU.
pub fn best_config(
    model: &ModelConfig,
    work: &WorkloadConfig,
    cluster: &ClusterConfig,
    tp_cap: usize,
    params: SimParams,
) -> Option<PlanChoice> {
    let sim = IterationModel::new(model.clone(), work.clone(), cluster.clone(), params);
    enumerate_legal(model, work, cluster, tp_cap)
        .into_iter()
        .map(|cfg| {
            let tput = sim.tokens_per_sec_per_gpu(&cfg);
            let breakdown = sim.healthy_iteration(&cfg);
            PlanChoice { cfg, tokens_per_sec_per_gpu: tput, breakdown }
        })
        .max_by(|a, b| {
            a.tokens_per_sec_per_gpu
                .partial_cmp(&b.tokens_per_sec_per_gpu)
                .unwrap()
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Dtype};

    fn work(seq: usize) -> WorkloadConfig {
        WorkloadConfig {
            seq_len: seq,
            minibatch_tokens: 16 * 1024 * 1024,
            dtype: Dtype::BF16,
        }
    }

    #[test]
    fn legal_configs_fill_cluster_exactly() {
        let m = presets::model("gpt-480b").unwrap();
        let c = presets::cluster("paper-32k-nvl32").unwrap();
        let w = work(8192);
        let configs = enumerate_legal(&m, &w, &c, 32);
        assert!(!configs.is_empty());
        for cfg in &configs {
            assert_eq!(cfg.n_gpus(), c.n_gpus);
            assert!(cfg.tp <= 32);
            assert!(cfg.divides_batch(w.global_batch()));
        }
    }

    #[test]
    fn relaxing_tp_cap_never_hurts() {
        // DESIGN.md invariant: the best config under a looser cap is at
        // least as good.
        let m = presets::model("gpt-480b").unwrap();
        let c = presets::cluster("paper-32k-nvl32").unwrap();
        let w = work(8192);
        let p = SimParams::default();
        let best8 = best_config(&m, &w, &c, 8, p).unwrap();
        let best16 = best_config(&m, &w, &c, 16, p).unwrap();
        let best32 = best_config(&m, &w, &c, 32, p).unwrap();
        assert!(best16.tokens_per_sec_per_gpu >= best8.tokens_per_sec_per_gpu);
        assert!(best32.tokens_per_sec_per_gpu >= best16.tokens_per_sec_per_gpu);
    }

    #[test]
    fn high_scale_wants_high_tp() {
        // Fig. 2b: at 32K GPUs the unrestricted best uses TP > 8.
        let m = presets::model("gpt-480b").unwrap();
        let c = presets::cluster("paper-32k-nvl32").unwrap();
        let w = work(8192);
        let best = best_config(&m, &w, &c, 32, SimParams::default()).unwrap();
        assert!(best.cfg.tp > 8, "chose {:?}", best.cfg);
    }

    #[test]
    fn chosen_config_fits_memory() {
        let m = presets::model("gpt-480b").unwrap();
        let c = presets::cluster("paper-32k-nvl32").unwrap();
        let w = work(8192);
        let best = best_config(&m, &w, &c, 32, SimParams::default()).unwrap();
        assert!(MemoryModel::default().fits(&m, &best.cfg, &w, c.gpu.hbm_gib));
    }
}
