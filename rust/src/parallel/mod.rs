//! Hybrid parallelism: configuration space (TP × PP × DP), the per-GPU
//! memory-footprint model that constrains it, and the exhaustive planner
//! behind Fig. 2b / Fig. 14 ("best config under a TP cap").

pub mod config;
pub mod memory;
pub mod planner;

pub use config::ParallelConfig;
pub use memory::MemoryModel;
pub use planner::{best_config, enumerate_legal, PlanChoice};
