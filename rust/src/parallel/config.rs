//! Hybrid-parallel configuration: TP within the scale-up domain, PP
//! across domains, DP across replicas. (Context parallelism is folded
//! into the TP degree, as in the paper's appendix; expert parallelism is
//! out of scope for the dense models evaluated.)

use crate::config::ModelConfig;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Tensor-parallel degree (GPUs per TP group; must fit in a scale-up
    /// domain).
    pub tp: usize,
    /// Pipeline-parallel degree (stages).
    pub pp: usize,
    /// Data-parallel degree (replicas).
    pub dp: usize,
    /// Local batch size per DP replica per microbatch (samples).
    pub microbatch: usize,
}

impl ParallelConfig {
    pub fn n_gpus(&self) -> usize {
        self.tp * self.pp * self.dp
    }

    /// GPUs per DP replica.
    pub fn gpus_per_replica(&self) -> usize {
        self.tp * self.pp
    }

    /// Number of microbatches per replica per iteration for a global
    /// batch of `global_batch` samples.
    pub fn n_microbatches(&self, global_batch: usize) -> usize {
        let local = global_batch / self.dp;
        (local / self.microbatch).max(1)
    }

    /// Layers per pipeline stage (balanced; asserts divisibility handled
    /// by ceiling — trailing stage may be lighter).
    pub fn layers_per_stage(&self, model: &ModelConfig) -> usize {
        model.layers.div_ceil(self.pp)
    }

    /// Does this config evenly consume `global_batch` samples?
    pub fn divides_batch(&self, global_batch: usize) -> bool {
        global_batch % self.dp == 0 && (global_batch / self.dp) % self.microbatch == 0
    }

    pub fn label(&self) -> String {
        format!("TP{}/PP{}/DP{}/mb{}", self.tp, self.pp, self.dp, self.microbatch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn gpu_accounting() {
        let c = ParallelConfig { tp: 32, pp: 8, dp: 128, microbatch: 1 };
        assert_eq!(c.n_gpus(), 32_768);
        assert_eq!(c.gpus_per_replica(), 256);
    }

    #[test]
    fn microbatch_count() {
        let c = ParallelConfig { tp: 8, pp: 4, dp: 16, microbatch: 2 };
        // global batch 1024 -> local 64 -> 32 microbatches
        assert_eq!(c.n_microbatches(1024), 32);
        assert!(c.divides_batch(1024));
        assert!(!c.divides_batch(1000));
    }

    #[test]
    fn layers_per_stage_ceil() {
        let m = presets::model("gpt-480b").unwrap(); // 100 layers
        let c = ParallelConfig { tp: 32, pp: 8, dp: 128, microbatch: 1 };
        assert_eq!(c.layers_per_stage(&m), 13);
        let c2 = ParallelConfig { tp: 32, pp: 4, dp: 256, microbatch: 1 };
        assert_eq!(c2.layers_per_stage(&m), 25);
    }
}
