//! Per-GPU HBM footprint model (Megatron-style accounting with a
//! ZeRO-1/distributed optimizer over DP and selective activation
//! recompute), used to reject hybrid-parallel configs that do not fit.
//!
//! References: Korthikanti et al. "Reducing Activation Recomputation in
//! Large Transformer Models" for the activation term; the paper's §2.1
//! for why PP degree is "set to the minimum required to fit".

use super::config::ParallelConfig;
use crate::config::{Dtype, ModelConfig, WorkloadConfig};

#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    /// Fraction of HBM usable for model state (rest: framework, NCCL
    /// buffers, fragmentation).
    pub usable_fraction: f64,
    /// Shard the optimizer state over DP (ZeRO-1 / Megatron distributed
    /// optimizer). The paper's Megatron baseline keeps full Adam state
    /// per rank, so this defaults to `false` — which is what forces
    /// low-TP configs into deep PP (Fig. 2's mechanism).
    pub zero1: bool,
}

impl Default for MemoryModel {
    fn default() -> Self {
        MemoryModel { usable_fraction: 0.9, zero1: false }
    }
}

impl MemoryModel {
    /// Parameter-state bytes per GPU: bf16 params + fp32 grads held for
    /// accumulation + fp32 Adam (m, v, master), optionally sharded over
    /// DP (ZeRO-1).
    pub fn param_state_bytes(
        &self,
        model: &ModelConfig,
        cfg: &ParallelConfig,
        dtype: Dtype,
    ) -> f64 {
        let p_local = model.params() as f64 / (cfg.tp * cfg.pp) as f64;
        let weight = dtype.bytes().max(2) as f64; // fp8 still keeps bf16 weights
        let grad = 4.0;
        let optim = if self.zero1 { 12.0 / cfg.dp as f64 } else { 12.0 };
        p_local * (weight + grad + optim)
    }

    /// Activation bytes per GPU with selective recompute **and sequence
    /// parallelism** (standard for Megatron at these scales): per layer &
    /// microbatch ≈ `s·b·h·34 / tp` bytes (softmax/score tensors
    /// recomputed; the rest sharded over the TP group along sequence or
    /// hidden). 1F1B keeps up to `pp` microbatches in flight on the
    /// first stage.
    pub fn activation_bytes(
        &self,
        model: &ModelConfig,
        cfg: &ParallelConfig,
        work: &WorkloadConfig,
    ) -> f64 {
        let s = work.seq_len as f64;
        let b = cfg.microbatch as f64;
        let h = model.hidden as f64;
        let per_layer = s * b * h * 34.0 / cfg.tp as f64;
        let layers = cfg.layers_per_stage(model) as f64;
        // 1F1B first stage holds min(pp, m) microbatches in flight.
        let m = cfg.n_microbatches(work.global_batch()) as f64;
        let in_flight = (cfg.pp as f64).min(m);
        per_layer * layers * in_flight
    }

    /// Total per-GPU bytes.
    pub fn total_bytes(
        &self,
        model: &ModelConfig,
        cfg: &ParallelConfig,
        work: &WorkloadConfig,
    ) -> f64 {
        self.param_state_bytes(model, cfg, work.dtype)
            + self.activation_bytes(model, cfg, work)
    }

    /// Does the config fit in `hbm_gib` GiB?
    pub fn fits(
        &self,
        model: &ModelConfig,
        cfg: &ParallelConfig,
        work: &WorkloadConfig,
        hbm_gib: f64,
    ) -> bool {
        self.total_bytes(model, cfg, work) <= hbm_gib * self.usable_fraction * (1u64 << 30) as f64
    }

    /// Minimum PP degree that fits (with TP and DP fixed), or None.
    pub fn min_pp(
        &self,
        model: &ModelConfig,
        tp: usize,
        dp: usize,
        microbatch: usize,
        work: &WorkloadConfig,
        hbm_gib: f64,
        max_pp: usize,
    ) -> Option<usize> {
        (1..=max_pp).find(|&pp| {
            let cfg = ParallelConfig { tp, pp, dp, microbatch };
            self.fits(model, &cfg, work, hbm_gib)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn work() -> WorkloadConfig {
        WorkloadConfig {
            seq_len: 16_384,
            minibatch_tokens: 16 * 1024 * 1024,
            dtype: Dtype::BF16,
        }
    }

    #[test]
    fn paper_config_fits_on_b200() {
        // 480B on 32K B200 (189 GiB) at TP32: needs PP to fit.
        let m = presets::model("gpt-480b").unwrap();
        let mm = MemoryModel::default();
        let cfg = ParallelConfig { tp: 32, pp: 8, dp: 128, microbatch: 1 };
        assert!(mm.fits(&m, &cfg, &work(), 189.0));
    }

    #[test]
    fn low_tp_no_pp_does_not_fit_480b() {
        // Without PP at TP8, the param state (~6 B/param / 8 ≈ 360 GB)
        // overflows even B200's 189 GB — PP is mandatory (§2.1).
        let m = presets::model("gpt-480b").unwrap();
        let mm = MemoryModel::default();
        let cfg = ParallelConfig { tp: 8, pp: 1, dp: 4096, microbatch: 1 };
        assert!(!mm.fits(&m, &cfg, &work(), 189.0));
    }

    #[test]
    fn memory_decreases_with_tp_and_pp() {
        let m = presets::model("gpt-175b").unwrap();
        let mm = MemoryModel::default();
        let w = work();
        let base = ParallelConfig { tp: 8, pp: 4, dp: 8, microbatch: 1 };
        let more_tp = ParallelConfig { tp: 16, pp: 4, dp: 8, microbatch: 1 };
        let more_pp = ParallelConfig { tp: 8, pp: 8, dp: 8, microbatch: 1 };
        let t0 = mm.total_bytes(&m, &base, &w);
        assert!(mm.total_bytes(&m, &more_tp, &w) < t0);
        // more PP shrinks param state but raises in-flight activations;
        // param state dominates at these shapes
        assert!(mm.param_state_bytes(&m, &more_pp, w.dtype) < mm.param_state_bytes(&m, &base, w.dtype));
    }

    #[test]
    fn min_pp_monotone_in_hbm() {
        let m = presets::model("gpt-175b").unwrap();
        let mm = MemoryModel::default();
        let w = WorkloadConfig {
            seq_len: 4096,
            minibatch_tokens: 16 * 1024 * 1024,
            dtype: Dtype::BF16,
        };
        let pp_small = mm.min_pp(&m, 8, 64, 1, &w, 80.0, 64);
        let pp_big = mm.min_pp(&m, 8, 64, 1, &w, 189.0, 64);
        let (a, b) = (pp_small.unwrap(), pp_big.unwrap());
        assert!(b <= a, "more HBM should not need more PP ({a} vs {b})");
        assert!(a > 1, "175B at TP8 on 80 GB needs PP");
    }

    #[test]
    fn zero1_optimizer_shards_over_dp() {
        let m = presets::model("gpt-8b").unwrap();
        let mm = MemoryModel { zero1: true, ..MemoryModel::default() };
        let small_dp = ParallelConfig { tp: 8, pp: 1, dp: 2, microbatch: 1 };
        let big_dp = ParallelConfig { tp: 8, pp: 1, dp: 64, microbatch: 1 };
        assert!(
            mm.param_state_bytes(&m, &big_dp, Dtype::BF16)
                < mm.param_state_bytes(&m, &small_dp, Dtype::BF16)
        );
        // default (Megatron baseline) is DP-independent
        let base = MemoryModel::default();
        assert_eq!(
            base.param_state_bytes(&m, &big_dp, Dtype::BF16),
            base.param_state_bytes(&m, &small_dp, Dtype::BF16)
        );
    }
}
