//! Experiment metric recording: named series of (x, y) points plus
//! scalar results, dumped as JSON/CSV under `results/` so EXPERIMENTS.md
//! numbers are regenerable.

use crate::util::json::Value;
use anyhow::Result;
use std::collections::BTreeMap;

/// One experiment's recorded output.
#[derive(Debug, Default)]
pub struct Recorder {
    pub name: String,
    scalars: BTreeMap<String, f64>,
    series: BTreeMap<String, Vec<(f64, f64)>>,
    notes: Vec<String>,
}

impl Recorder {
    pub fn new(name: &str) -> Recorder {
        Recorder { name: name.to_string(), ..Default::default() }
    }

    pub fn scalar(&mut self, key: &str, value: f64) {
        self.scalars.insert(key.to_string(), value);
    }

    pub fn point(&mut self, series: &str, x: f64, y: f64) {
        self.series.entry(series.to_string()).or_default().push((x, y));
    }

    pub fn note(&mut self, msg: impl Into<String>) {
        self.notes.push(msg.into());
    }

    pub fn get_scalar(&self, key: &str) -> Option<f64> {
        self.scalars.get(key).copied()
    }

    pub fn get_series(&self, name: &str) -> Option<&[(f64, f64)]> {
        self.series.get(name).map(|v| v.as_slice())
    }

    pub fn to_json(&self) -> Value {
        let scalars = Value::Obj(
            self.scalars.iter().map(|(k, v)| (k.clone(), Value::Num(*v))).collect(),
        );
        let series = Value::Obj(
            self.series
                .iter()
                .map(|(k, pts)| {
                    let arr = Value::Arr(
                        pts.iter()
                            .map(|&(x, y)| Value::Arr(vec![Value::Num(x), Value::Num(y)]))
                            .collect(),
                    );
                    (k.clone(), arr)
                })
                .collect(),
        );
        Value::obj(vec![
            ("name", self.name.as_str().into()),
            ("scalars", scalars),
            ("series", series),
            (
                "notes",
                Value::Arr(self.notes.iter().map(|n| Value::Str(n.clone())).collect()),
            ),
        ])
    }

    /// Write `results/<name>.json`. Creates the directory as needed.
    pub fn save(&self, dir: &str) -> Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{}.json", self.name);
        std::fs::write(&path, self.to_json().pretty() + "\n")?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_serialize() {
        let mut r = Recorder::new("test_exp");
        r.scalar("throughput", 0.97);
        r.point("loss", 0.0, 5.0);
        r.point("loss", 1.0, 4.2);
        r.note("first run");
        let v = r.to_json();
        assert_eq!(v.get("name").as_str(), Some("test_exp"));
        assert_eq!(v.get("scalars").get("throughput").as_f64(), Some(0.97));
        assert_eq!(v.get("series").get("loss").as_arr().unwrap().len(), 2);
        // roundtrip through the parser
        let v2 = Value::parse(&v.pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn save_creates_file() {
        let dir = std::env::temp_dir().join("ntp_metrics_test");
        let dir = dir.to_str().unwrap();
        let mut r = Recorder::new("unit");
        r.scalar("x", 1.0);
        let path = r.save(dir).unwrap();
        assert!(std::path::Path::new(&path).exists());
        std::fs::remove_file(path).ok();
    }
}
