//! Dynamic power allocator: solve for the *minimum* boost that lets a
//! reduced-TP replica keep the healthy replicas' iteration time at full
//! local batch (§5.3: "minimum operating power (for power-boosted) for
//! the iteration time ... to be less than or equal to the iteration time
//! of the healthy replicas").

use super::rack::RackDesign;
use crate::config::GpuSpec;
use crate::parallel::ParallelConfig;
use crate::sim::IterationModel;

/// Outcome of a boost solve for one reduced-TP replica.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BoostDecision {
    /// No boost needed (replica keeps up at nominal power).
    NotNeeded,
    /// Boost to `power_frac` × TDP keeps full batch at healthy iteration
    /// time.
    Boost { power_frac: f64 },
    /// Even the max available boost cannot keep up; caller must fall back
    /// to batch reduction (plain NTP) at `max_power_frac`.
    Infeasible { max_power_frac: f64 },
}

/// Binary-search the minimum power fraction in `[1, max_boost]` such that
/// the reduced replica at full `local_batch` matches `target_secs`.
pub fn min_boost_for(
    sim: &IterationModel,
    cfg_full: &ParallelConfig,
    tp_reduced: usize,
    local_batch: usize,
    target_secs: f64,
    rack: &RackDesign,
    gpu: &GpuSpec,
) -> BoostDecision {
    let domain_size = cfg_full.tp;
    let max_power = rack
        .max_boost(domain_size, tp_reduced)
        .min(gpu.max_boost);

    let time_at = |power: f64| -> f64 {
        let perf = gpu.perf_at_power(power);
        sim.ntp_iteration(cfg_full, tp_reduced, local_batch, perf).total()
    };

    if time_at(1.0) <= target_secs {
        return BoostDecision::NotNeeded;
    }
    if time_at(max_power) > target_secs {
        return BoostDecision::Infeasible { max_power_frac: max_power };
    }
    // Bisect on power.
    let (mut lo, mut hi) = (1.0f64, max_power);
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        if time_at(mid) <= target_secs {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    BoostDecision::Boost { power_frac: hi }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Dtype, WorkloadConfig};
    use crate::sim::SimParams;

    fn sim() -> IterationModel {
        IterationModel::new(
            presets::model("gpt-480b").unwrap(),
            WorkloadConfig {
                seq_len: 16_384,
                minibatch_tokens: 16 * 1024 * 1024,
                dtype: Dtype::BF16,
            },
            presets::cluster("paper-32k-nvl32").unwrap(),
            SimParams::default(),
        )
    }

    fn cfg() -> ParallelConfig {
        ParallelConfig { tp: 32, pp: 8, dp: 128, microbatch: 1 }
    }

    #[test]
    fn table1_tp30_needs_moderate_boost() {
        // Paper Table 1: TP30-PW runs at 1.15× power with full batch.
        let s = sim();
        let cfg = cfg();
        let local = s.work.global_batch() / cfg.dp;
        let target = s.healthy_iteration(&cfg).total();
        let rack = RackDesign::default();
        // Allow rack budget beyond repurposed power (provisioned rack).
        let rack = RackDesign { rack_budget_frac: 1.3, ..rack };
        match min_boost_for(&s, &cfg, 30, local, target, &rack, &s.cluster.gpu) {
            BoostDecision::Boost { power_frac } => {
                assert!(
                    (1.02..1.30).contains(&power_frac),
                    "TP30 boost {power_frac}"
                );
            }
            other => panic!("expected Boost, got {other:?}"),
        }
    }

    #[test]
    fn tp28_needs_more_boost_than_tp30() {
        let s = sim();
        let cfg = cfg();
        let local = s.work.global_batch() / cfg.dp;
        let target = s.healthy_iteration(&cfg).total();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let b30 = min_boost_for(&s, &cfg, 30, local, target, &rack, &s.cluster.gpu);
        let b28 = min_boost_for(&s, &cfg, 28, local, target, &rack, &s.cluster.gpu);
        match (b30, b28) {
            (BoostDecision::Boost { power_frac: p30 }, BoostDecision::Boost { power_frac: p28 }) => {
                assert!(p28 > p30, "p28 {p28} should exceed p30 {p30}");
            }
            other => panic!("expected two Boosts, got {other:?}"),
        }
    }

    #[test]
    fn extreme_reduction_is_infeasible() {
        let s = sim();
        let cfg = cfg();
        let local = s.work.global_batch() / cfg.dp;
        let target = s.healthy_iteration(&cfg).total();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        // Halving the TP group cannot be fixed by 1.3x power.
        match min_boost_for(&s, &cfg, 16, local, target, &rack, &s.cluster.gpu) {
            BoostDecision::Infeasible { max_power_frac } => {
                assert!(max_power_frac <= 1.3 + 1e-12);
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
    }

    #[test]
    fn no_reduction_needs_no_boost() {
        let s = sim();
        let cfg = cfg();
        let local = s.work.global_batch() / cfg.dp;
        let target = s.healthy_iteration(&cfg).total();
        let rack = RackDesign::default();
        assert_eq!(
            min_boost_for(&s, &cfg, 32, local, target, &rack, &s.cluster.gpu),
            BoostDecision::NotNeeded
        );
    }
}
