//! Rack electrical/thermal budget model (§3.2).
//!
//! The flexible rack provisions whips, breakers, PDUs, PSUs, VRs and
//! cooling for up to `gpu_boost_cap` × TDP per GPU (1.3 in the paper,
//! matching GH200's 700 W → 900 W dynamic balancing), with the row-level
//! budget oversubscribed: the *expected* draw stays near nominal because
//! boosting only happens in domains that have failed (power-free) GPUs.

use crate::config::GpuSpec;

#[derive(Clone, Debug)]
pub struct RackDesign {
    /// Max sustained per-GPU power as a fraction of TDP.
    pub gpu_boost_cap: f64,
    /// Rack-level budget as a fraction of `domain_size × TDP` (1.0 =
    /// traditional rack; the flexible design keeps 1.0 nominal but allows
    /// per-GPU boost inside it).
    pub rack_budget_frac: f64,
}

impl Default for RackDesign {
    fn default() -> Self {
        RackDesign { gpu_boost_cap: 1.3, rack_budget_frac: 1.3 }
    }
}

/// A traditional rack: no boosting at all.
impl RackDesign {
    pub fn traditional() -> RackDesign {
        RackDesign { gpu_boost_cap: 1.0, rack_budget_frac: 1.0 }
    }

    /// Maximum uniform boost (fraction of TDP) available to the `healthy`
    /// survivors of a domain of `domain_size` GPUs: limited by the GPU
    /// cap and by the rack budget with failed GPUs' power repurposed.
    pub fn max_boost(&self, domain_size: usize, healthy: usize) -> f64 {
        if healthy == 0 {
            return 0.0;
        }
        let rack_limit =
            self.rack_budget_frac * domain_size as f64 / healthy as f64;
        self.gpu_boost_cap.min(rack_limit.max(1.0))
    }

    /// Net domain power draw (fraction of nominal `domain_size × TDP`)
    /// when `healthy` GPUs run at `boost` × TDP.
    pub fn domain_power_frac(&self, domain_size: usize, healthy: usize, boost: f64) -> f64 {
        healthy as f64 * boost / domain_size as f64
    }

    /// Perf-per-watt penalty of running at `boost` × TDP (relative to
    /// TDP operation): perf ∝ P^(1/α) ⇒ perf/W ∝ P^(1/α - 1).
    pub fn perf_per_watt_penalty(&self, gpu: &GpuSpec, boost: f64) -> f64 {
        1.0 - boost.powf(1.0 / gpu.power_alpha - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn traditional_rack_never_boosts() {
        let r = RackDesign::traditional();
        assert_eq!(r.max_boost(32, 30), 1.0);
    }

    #[test]
    fn flexible_rack_boosts_up_to_cap() {
        let r = RackDesign::default();
        // provisioned rack: per-GPU cap binds
        assert_eq!(r.max_boost(32, 30), 1.3);
        assert_eq!(r.max_boost(32, 16), 1.3);
        // no failures: the flexible rack could still boost, but the
        // allocator never asks for it (no repurposed power); budget math
        // still caps at the GPU limit
        assert_eq!(r.max_boost(32, 32), 1.3);
        // dead domain
        assert_eq!(r.max_boost(32, 0), 0.0);

        // A rack with only nominal budget: boost limited to the
        // repurposed power of the failed GPUs.
        let nominal = RackDesign { gpu_boost_cap: 1.3, rack_budget_frac: 1.0 };
        assert!((nominal.max_boost(32, 30) - 32.0 / 30.0).abs() < 1e-12);
        assert_eq!(nominal.max_boost(32, 32), 1.0);
    }

    #[test]
    fn boosted_domain_stays_within_provisioned_budget() {
        let r = RackDesign::default();
        let healthy = 30;
        let boost = r.max_boost(32, healthy);
        assert!(r.domain_power_frac(32, healthy, boost) <= r.rack_budget_frac + 1e-12);
    }

    #[test]
    fn perf_per_watt_matches_paper_sensitivity() {
        // §6.4: at 1.1× power perf/watt drops ~2.8%; at 1.2× ~6.5%.
        let gpu = presets::gpu("b200").unwrap();
        let r = RackDesign::default();
        let p11 = r.perf_per_watt_penalty(&gpu, 1.1);
        let p12 = r.perf_per_watt_penalty(&gpu, 1.2);
        assert!((p11 - 0.028).abs() < 0.03, "1.1x penalty {p11}");
        assert!((p12 - 0.065).abs() < 0.045, "1.2x penalty {p12}");
        assert!(p12 > p11);
    }
}
