//! Rack electrical/thermal budget model (§3.2).
//!
//! The flexible rack provisions whips, breakers, PDUs, PSUs, VRs and
//! cooling for up to `gpu_boost_cap` × TDP per GPU (1.3 in the paper,
//! matching GH200's 700 W → 900 W dynamic balancing), with the row-level
//! budget oversubscribed: the *expected* draw stays near nominal because
//! boosting only happens in domains that have failed (power-free) GPUs.
//!
//! Beyond the per-domain boost budget, the model carries everything the
//! fleet-wide power integrand needs (the `power` channel of
//! [`crate::policy::EvalOut`], integrated duration-weighted by
//! `manager::Accum`): the standby draw of dark spare domains
//! (`POWER-SPARES`), the idle floor of a paused job, the derate of a
//! degraded (throttling) GPU, a boost-sustainability model
//! ([`ThermalModel`] — boost only while thermal headroom lasts), and a
//! row-level power cap bounding how many boosted domains may coexist
//! ([`RackDesign::row_boost_allowance`]). Every addition defaults to
//! the pre-power behavior bit-exactly: infinite thermal headroom
//! returns the untouched boost, and `row_domains == 0` disables the
//! row cap.

use crate::config::GpuSpec;

/// Boost sustainability: a domain can hold boosted clocks only while
/// its thermal headroom (cold-plate / cooling-loop margin) lasts, then
/// must fall back to nominal power to recover. The model caps the
/// *sustained* boost as the duty-cycled average of the boost/recover
/// cycle; [`ThermalModel::UNLIMITED`] (infinite headroom, the default)
/// collapses bit-exactly to the unthrottled behavior.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ThermalModel {
    /// Seconds a domain can hold boost before exhausting its thermal
    /// headroom. `f64::INFINITY` (the default) disables the model;
    /// `0.0` forbids sustained boost entirely.
    pub headroom_secs: f64,
    /// Cooling rate relative to heating: after `headroom_secs` of
    /// boost the domain recovers at nominal power for
    /// `headroom_secs / recover_frac` before it can boost again
    /// (`1.0` = cools as fast as it heats, a 50% duty cycle).
    pub recover_frac: f64,
}

impl ThermalModel {
    /// Infinite headroom: boost is sustainable forever —
    /// [`ThermalModel::sustained`] is the bit-exact identity.
    pub const UNLIMITED: ThermalModel =
        ThermalModel { headroom_secs: f64::INFINITY, recover_frac: 1.0 };

    /// The boost level a domain can *sustain* given its thermal
    /// headroom: the duty-cycled average of `headroom_secs` at `boost`
    /// followed by `headroom_secs / recover_frac` at nominal.
    ///
    /// Bit-exactness contract: with infinite headroom — or when the
    /// input does not boost at all (`boost <= 1.0`, including the
    /// `0.0` of a dead domain) — the input is returned untouched, so
    /// the default model cannot perturb any existing result.
    pub fn sustained(&self, boost: f64) -> f64 {
        if !(boost > 1.0) || self.headroom_secs.is_infinite() {
            return boost;
        }
        if self.headroom_secs <= 0.0 {
            return 1.0;
        }
        let on = self.headroom_secs;
        let off = on / self.recover_frac.max(1e-9);
        1.0 + (boost - 1.0) * on / (on + off)
    }
}

impl Default for ThermalModel {
    fn default() -> Self {
        ThermalModel::UNLIMITED
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RackDesign {
    /// Max sustained per-GPU power as a fraction of TDP.
    pub gpu_boost_cap: f64,
    /// Rack-level budget as a fraction of `domain_size × TDP` (1.0 =
    /// traditional rack; the flexible design keeps 1.0 nominal but allows
    /// per-GPU boost inside it).
    pub rack_budget_frac: f64,
    /// Boost sustainability ([`ThermalModel::sustained`] caps
    /// [`RackDesign::max_boost`]); [`ThermalModel::UNLIMITED`] by
    /// default, which is a bit-exact no-op.
    pub thermal: ThermalModel,
    /// Standby draw of a dark (power-capped) spare domain as a
    /// fraction of TDP (VR/HBM retention + fabric keep-alive) —
    /// `POWER-SPARES` keeps its unused pool here.
    pub standby_frac: f64,
    /// Draw of a healthy-but-idle GPU while the job is paused, as a
    /// fraction of TDP (clocks floored, HBM refreshed, links up).
    pub idle_frac: f64,
    /// Draw of a degraded (thermally throttling / flaky) GPU as a
    /// fraction of TDP — stragglers run slow because they run capped.
    pub degraded_derate: f64,
    /// Scale-up domains per rack row for the row-level power cap; `0`
    /// (the default) disables the cap.
    pub row_domains: usize,
    /// Row power budget as a fraction of `row_domains × domain_size ×
    /// TDP` — bounds how many boosted domains may coexist per row
    /// ([`RackDesign::row_boost_allowance`]).
    pub row_budget_frac: f64,
}

impl Default for RackDesign {
    fn default() -> Self {
        RackDesign {
            gpu_boost_cap: 1.3,
            rack_budget_frac: 1.3,
            thermal: ThermalModel::UNLIMITED,
            standby_frac: 0.15,
            idle_frac: 0.15,
            degraded_derate: 0.7,
            row_domains: 0,
            row_budget_frac: 1.0,
        }
    }
}

/// A traditional rack: no boosting at all.
impl RackDesign {
    pub fn traditional() -> RackDesign {
        RackDesign { gpu_boost_cap: 1.0, rack_budget_frac: 1.0, ..RackDesign::default() }
    }

    /// Maximum uniform boost (fraction of TDP) available to the `healthy`
    /// survivors of a domain of `domain_size` GPUs: limited by the GPU
    /// cap, by the rack budget with failed GPUs' power repurposed, and
    /// by the sustained-boost thermal model (a bit-exact pass-through
    /// with the default infinite headroom).
    pub fn max_boost(&self, domain_size: usize, healthy: usize) -> f64 {
        if healthy == 0 {
            return 0.0;
        }
        let rack_limit =
            self.rack_budget_frac * domain_size as f64 / healthy as f64;
        self.thermal.sustained(self.gpu_boost_cap.min(rack_limit.max(1.0)))
    }

    /// Net domain power draw (fraction of nominal `domain_size × TDP`)
    /// when `healthy` GPUs run at `boost` × TDP.
    pub fn domain_power_frac(&self, domain_size: usize, healthy: usize, boost: f64) -> f64 {
        healthy as f64 * boost / domain_size as f64
    }

    /// Fleet-wide count of domains allowed to run boosted under the
    /// row-level power cap, or `None` when the cap is off
    /// (`row_domains == 0`) or the rack cannot boost at all. Each row
    /// of `row_domains` domains carries `(row_budget_frac − 1) ×
    /// row_domains` domains' worth of budget above nominal; a boosted
    /// domain draws up to `gpu_boost_cap − 1` above nominal, so a row
    /// sustains `floor(row_domains × (row_budget_frac − 1) /
    /// (gpu_boost_cap − 1))` boosted domains. The allowance is pooled
    /// over the fleet's rows (placement within rows is the resource
    /// manager's concern, not this electrical model's).
    pub fn row_boost_allowance(&self, n_domains: usize) -> Option<usize> {
        if self.row_domains == 0 || self.gpu_boost_cap <= 1.0 {
            return None;
        }
        let per_row = (self.row_domains as f64 * (self.row_budget_frac - 1.0).max(0.0)
            / (self.gpu_boost_cap - 1.0))
            .floor() as usize;
        Some(per_row * n_domains.div_ceil(self.row_domains))
    }

    /// Perf-per-watt penalty of running at `boost` × TDP (relative to
    /// TDP operation): perf ∝ P^(1/α) ⇒ perf/W ∝ P^(1/α - 1).
    pub fn perf_per_watt_penalty(&self, gpu: &GpuSpec, boost: f64) -> f64 {
        1.0 - boost.powf(1.0 / gpu.power_alpha - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn traditional_rack_never_boosts() {
        let r = RackDesign::traditional();
        assert_eq!(r.max_boost(32, 30), 1.0);
        // the whole healthy range, not just one point
        for healthy in 1..=32 {
            assert_eq!(r.max_boost(32, healthy), 1.0, "healthy {healthy}");
        }
    }

    #[test]
    fn flexible_rack_boosts_up_to_cap() {
        let r = RackDesign::default();
        // provisioned rack: per-GPU cap binds
        assert_eq!(r.max_boost(32, 30), 1.3);
        assert_eq!(r.max_boost(32, 16), 1.3);
        // no failures: the flexible rack could still boost, but the
        // allocator never asks for it (no repurposed power); budget math
        // still caps at the GPU limit
        assert_eq!(r.max_boost(32, 32), 1.3);
        // dead domain
        assert_eq!(r.max_boost(32, 0), 0.0);

        // A rack with only nominal budget: boost limited to the
        // repurposed power of the failed GPUs.
        let nominal =
            RackDesign { gpu_boost_cap: 1.3, rack_budget_frac: 1.0, ..RackDesign::default() };
        assert!((nominal.max_boost(32, 30) - 32.0 / 30.0).abs() < 1e-12);
        assert_eq!(nominal.max_boost(32, 32), 1.0);
    }

    #[test]
    fn max_boost_edge_cases() {
        let r = RackDesign::default();
        // healthy == 0: a dead domain draws (and boosts) nothing.
        assert_eq!(r.max_boost(32, 0), 0.0);
        assert_eq!(RackDesign::traditional().max_boost(32, 0), 0.0);
        // healthy == domain_size: cap-bound on the flexible rack,
        // exactly nominal on the traditional one.
        assert_eq!(r.max_boost(32, 32), 1.3);
        assert_eq!(RackDesign::traditional().max_boost(32, 32), 1.0);
        // rack_budget_frac < 1.0 (a derated/brownout row): the
        // `max(1.0)` floor guarantees survivors still get nominal
        // power — the model never starves a healthy GPU below TDP.
        let derated =
            RackDesign { gpu_boost_cap: 1.3, rack_budget_frac: 0.8, ..RackDesign::default() };
        assert_eq!(derated.max_boost(32, 32), 1.0);
        assert_eq!(derated.max_boost(32, 30), 1.0);
        // a single survivor of a derated rack still gets the GPU cap
        // (budget floor × repurposed power dominates)
        assert_eq!(derated.max_boost(32, 1), 1.3);
    }

    #[test]
    fn thermal_unlimited_collapses_bit_exactly() {
        // Satellite contract: headroom=∞ must reproduce the
        // no-thermal path to the bit, for every (domain, healthy)
        // shape and every budget that exercises cap-, budget- and
        // floor-bound boosts.
        let unthrottled = |rack: &RackDesign, ds: usize, h: usize| -> f64 {
            // the pre-thermal formula, verbatim
            if h == 0 {
                return 0.0;
            }
            let rack_limit = rack.rack_budget_frac * ds as f64 / h as f64;
            rack.gpu_boost_cap.min(rack_limit.max(1.0))
        };
        for budget in [0.8, 1.0, 1.15, 1.3] {
            let r = RackDesign {
                gpu_boost_cap: 1.3,
                rack_budget_frac: budget,
                thermal: ThermalModel { headroom_secs: f64::INFINITY, recover_frac: 0.25 },
                ..RackDesign::default()
            };
            for ds in [8usize, 32, 72] {
                for h in 0..=ds {
                    assert_eq!(
                        r.max_boost(ds, h).to_bits(),
                        unthrottled(&r, ds, h).to_bits(),
                        "budget {budget} ds {ds} h {h}"
                    );
                }
            }
        }
        // the identity also holds through `sustained` directly,
        // including the non-boosting inputs 0.0 and 1.0
        for b in [0.0, 0.5, 1.0, 1.2, 1.3] {
            assert_eq!(ThermalModel::UNLIMITED.sustained(b).to_bits(), b.to_bits());
        }
    }

    #[test]
    fn thermal_headroom_caps_sustained_boost() {
        // zero headroom: no boost can be sustained at all
        let none = ThermalModel { headroom_secs: 0.0, recover_frac: 1.0 };
        assert_eq!(none.sustained(1.3), 1.0);
        // finite headroom: strictly between nominal and the ask;
        // symmetric heat/cool (recover_frac = 1) is a 50% duty cycle
        let even = ThermalModel { headroom_secs: 600.0, recover_frac: 1.0 };
        let s = even.sustained(1.3);
        assert!((s - 1.15).abs() < 1e-12, "50% duty of 1.3 is 1.15, got {s}");
        // slower cooling sustains less
        let slow = ThermalModel { headroom_secs: 600.0, recover_frac: 0.5 };
        assert!(slow.sustained(1.3) < s);
        // a thermally-limited rack's max_boost shrinks but never
        // below nominal for a live domain
        let r = RackDesign { thermal: even, ..RackDesign::default() };
        assert!((r.max_boost(32, 30) - 1.15).abs() < 1e-12);
        assert!(r.max_boost(32, 31) >= 1.0);
        assert_eq!(r.max_boost(32, 0), 0.0);
    }

    #[test]
    fn row_cap_bounds_boosted_domains() {
        // cap off by default
        assert_eq!(RackDesign::default().row_boost_allowance(96), None);
        // a traditional rack cannot boost, so the cap is moot
        let trad = RackDesign { row_domains: 8, ..RackDesign::traditional() };
        assert_eq!(trad.row_boost_allowance(96), None);
        // 8 domains per row, 10% row headroom, 30% boost per domain:
        // floor(8 × 0.1 / 0.3) = 2 boosted domains per row
        let r = RackDesign { row_domains: 8, row_budget_frac: 1.1, ..RackDesign::default() };
        assert_eq!(r.row_boost_allowance(96), Some(2 * 12));
        // partial rows round up to a whole row's allowance
        assert_eq!(r.row_boost_allowance(9), Some(2 * 2));
        // a row with no headroom allows no boosted domains
        let tight = RackDesign { row_domains: 8, row_budget_frac: 1.0, ..RackDesign::default() };
        assert_eq!(tight.row_boost_allowance(96), Some(0));
    }

    #[test]
    fn boosted_domain_stays_within_provisioned_budget() {
        let r = RackDesign::default();
        let healthy = 30;
        let boost = r.max_boost(32, healthy);
        assert!(r.domain_power_frac(32, healthy, boost) <= r.rack_budget_frac + 1e-12);
    }

    #[test]
    fn perf_per_watt_matches_paper_sensitivity() {
        // §6.4: at 1.1× power perf/watt drops ~2.8%; at 1.2× ~6.5%.
        let gpu = presets::gpu("b200").unwrap();
        let r = RackDesign::default();
        let p11 = r.perf_per_watt_penalty(&gpu, 1.1);
        let p12 = r.perf_per_watt_penalty(&gpu, 1.2);
        assert!((p11 - 0.028).abs() < 0.03, "1.1x penalty {p11}");
        assert!((p12 - 0.065).abs() < 0.045, "1.2x penalty {p12}");
        assert!(p12 > p11);
    }
}
