//! Dynamic power allocation (paper §3.2): the flexible rack design that
//! redistributes the power budget of failed GPUs to the survivors in the
//! same scale-up domain, letting a reduced-TP replica keep full local
//! batch size (NTP-PW).

pub mod allocator;
pub mod rack;

pub use allocator::{min_boost_for, BoostDecision};
pub use rack::{RackDesign, ThermalModel};
