//! Artifact manifest: what `python/compile/aot.py` emitted — program
//! names, model shapes, TP shardings and the ordered parameter list the
//! XLA programs expect.

use crate::config::ModelConfig;
use crate::util::json::Value;
use anyhow::{Context, Result};

/// One parameter tensor in program order.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    /// `None` = replicated; `Some("heads" | "ffn")` = sharded along
    /// axis 0 by that dimension's partition.
    pub shard: Option<String>,
}

impl ParamMeta {
    pub fn n_elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// For sharded params: bytes (f32) of one unit (one row of axis 0).
    pub fn unit_len(&self) -> usize {
        self.shape[1..].iter().product()
    }

    /// Which shard index this tensor is (parsed from the trailing `.sN`),
    /// if sharded.
    pub fn shard_index(&self) -> Option<usize> {
        self.shard.as_ref()?;
        let (_, idx) = self.name.rsplit_once(".s")?;
        idx.parse().ok()
    }

    /// Group key: parameter name without the `.sN` suffix.
    pub fn group_name(&self) -> &str {
        if self.shard.is_some() {
            self.name.rsplit_once(".s").map(|(b, _)| b).unwrap_or(&self.name)
        } else {
            &self.name
        }
    }
}

/// One compiled program.
#[derive(Clone, Debug)]
pub struct ProgramMeta {
    pub name: String,
    pub file: String,
    pub model: ModelConfig,
    pub tp: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub head_shards: Vec<usize>,
    pub ffn_shards: Vec<usize>,
    pub params: Vec<ParamMeta>,
}

impl ProgramMeta {
    /// Total parameter element count (all shards).
    pub fn n_param_elements(&self) -> usize {
        self.params.iter().map(|p| p.n_elements()).sum()
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: String,
    pub programs: Vec<ProgramMeta>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} — run `make artifacts` first"))?;
        let v = Value::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let mut programs = Vec::new();
        for p in v.get("programs").as_arr().unwrap_or(&[]) {
            let model_v = p.get("model");
            let model = ModelConfig {
                name: model_v.req_str("name")?.to_string(),
                hidden: model_v.req_usize("hidden")?,
                ffn: model_v.req_usize("ffn")?,
                heads: model_v.req_usize("heads")?,
                head_dim: model_v.req_usize("head_dim")?,
                layers: model_v.req_usize("layers")?,
                vocab: model_v.req_usize("vocab")?,
            };
            let usize_arr = |key: &str| -> Result<Vec<usize>> {
                p.get(key)
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("missing array '{key}'"))?
                    .iter()
                    .map(|x| {
                        x.as_usize().ok_or_else(|| anyhow::anyhow!("bad int in '{key}'"))
                    })
                    .collect()
            };
            let mut params = Vec::new();
            for e in p.get("params").as_arr().unwrap_or(&[]) {
                params.push(ParamMeta {
                    name: e.req_str("name")?.to_string(),
                    shape: e
                        .get("shape")
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("param missing shape"))?
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect(),
                    shard: e.get("shard").as_str().map(|s| s.to_string()),
                });
            }
            programs.push(ProgramMeta {
                name: p.req_str("name")?.to_string(),
                file: p.req_str("file")?.to_string(),
                model,
                tp: p.req_usize("tp")?,
                batch: p.req_usize("batch")?,
                seq_len: p.req_usize("seq_len")?,
                head_shards: usize_arr("head_shards")?,
                ffn_shards: usize_arr("ffn_shards")?,
                params,
            });
        }
        Ok(Manifest { dir: dir.to_string(), programs })
    }

    pub fn find(&self, name: &str) -> Result<&ProgramMeta> {
        self.programs
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow::anyhow!("program '{name}' not in manifest"))
    }

    /// Find by (model, tp, batch).
    pub fn find_spec(&self, model: &str, tp: usize, batch: usize) -> Result<&ProgramMeta> {
        self.programs
            .iter()
            .find(|p| p.model.name == model && p.tp == tp && p.batch == batch)
            .ok_or_else(|| {
                anyhow::anyhow!("no program for model={model} tp={tp} batch={batch}")
            })
    }

    pub fn hlo_path(&self, p: &ProgramMeta) -> String {
        format!("{}/{}", self.dir, p.file)
    }
}

/// Default artifacts directory (relative to the repo root).
pub fn default_dir() -> String {
    std::env::var("NTP_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        std::path::Path::new(&format!("{}/manifest.json", default_dir())).exists()
    }

    #[test]
    fn loads_manifest_when_present() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&default_dir()).unwrap();
        assert!(!m.programs.is_empty());
        let tiny = m.find_spec("tiny", 3, 4).unwrap();
        assert_eq!(tiny.tp, 3);
        assert_eq!(tiny.head_shards, vec![2, 1, 1]);
        assert_eq!(tiny.ffn_shards, vec![86, 85, 85]);
        // parameter order sanity: first four entries are layer-0 norms +
        // attn shards
        assert_eq!(tiny.params[0].name, "l0.ln1.scale");
        assert!(tiny.params[2].name.starts_with("l0.attn.wqkv.s0"));
        // sharded params expose group + index
        let p = &tiny.params[2];
        assert_eq!(p.group_name(), "l0.attn.wqkv");
        assert_eq!(p.shard_index(), Some(0));
        assert_eq!(p.unit_len(), 3 * 16 * 64);
        // last param is the lm head
        assert_eq!(tiny.params.last().unwrap().name, "lm_head");
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
