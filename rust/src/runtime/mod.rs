//! Runtime: load AOT-compiled HLO-text artifacts and execute them on the
//! PJRT CPU client. Python never runs here — the Rust binary is
//! self-contained once `make artifacts` has produced
//! `artifacts/manifest.json` + `*.hlo.txt`.

pub mod client;
pub mod manifest;

pub use client::{Program, Runtime, StepOutput};
pub use manifest::{Manifest, ParamMeta, ProgramMeta};
