//! PJRT execution: compile HLO text once per program, then run training
//! steps from the Rust hot path (adapting /opt/xla-example/load_hlo).

use super::manifest::{Manifest, ProgramMeta};
use anyhow::{Context, Result};
use std::time::Instant;

/// Output of one replica training step.
#[derive(Debug)]
pub struct StepOutput {
    pub loss: f32,
    /// Gradients in manifest parameter order.
    pub grads: Vec<Vec<f32>>,
    /// Pure PJRT execute time (seconds).
    pub execute_secs: f64,
}

/// A compiled, ready-to-run replica program. Cheap to clone: the
/// compiled executable is shared through the runtime's cache, so two
/// uniform replicas of the same variant compile once.
pub struct Program {
    pub meta: ProgramMeta,
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
}

/// The PJRT client plus compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: std::cell::RefCell<
        std::collections::HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
    >,
}

impl Runtime {
    /// CPU PJRT client over the artifacts in `dir`.
    pub fn new(dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: Default::default() })
    }

    pub fn with_default_dir() -> Result<Runtime> {
        Runtime::new(&super::manifest::default_dir())
    }

    /// Load + compile one program. Compilation happens once per variant
    /// per runtime; subsequent loads share the cached executable.
    pub fn load(&self, name: &str) -> Result<Program> {
        let meta = self.manifest.find(name)?.clone();
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Program { meta, exe: exe.clone() });
        }
        let path = self.manifest.hlo_path(&meta);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(Program { meta, exe })
    }

    /// Load by (model, tp, batch).
    pub fn load_spec(&self, model: &str, tp: usize, batch: usize) -> Result<Program> {
        let name = self.manifest.find_spec(model, tp, batch)?.name.clone();
        self.load(&name)
    }
}

impl Program {
    /// Run one training step: tokens/targets are `[batch, seq]` row-major
    /// i32; `params` in manifest order. Returns loss + grads.
    pub fn train_step(
        &self,
        tokens: &[i32],
        targets: &[i32],
        params: &[Vec<f32>],
    ) -> Result<StepOutput> {
        let b = self.meta.batch as i64;
        let s = self.meta.seq_len as i64;
        anyhow::ensure!(
            tokens.len() == (b * s) as usize && targets.len() == tokens.len(),
            "batch shape mismatch: got {} tokens, program wants {}x{}",
            tokens.len(),
            b,
            s
        );
        anyhow::ensure!(
            params.len() == self.meta.params.len(),
            "param count mismatch: {} vs manifest {}",
            params.len(),
            self.meta.params.len()
        );

        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(2 + params.len());
        inputs.push(xla::Literal::vec1(tokens).reshape(&[b, s])?);
        inputs.push(xla::Literal::vec1(targets).reshape(&[b, s])?);
        for (p, meta) in params.iter().zip(&self.meta.params) {
            anyhow::ensure!(
                p.len() == meta.n_elements(),
                "param '{}' length {} != shape {:?}",
                meta.name,
                p.len(),
                meta.shape
            );
            let dims: Vec<i64> = meta.shape.iter().map(|&d| d as i64).collect();
            inputs.push(xla::Literal::vec1(p).reshape(&dims)?);
        }

        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&inputs)?;
        let out = result[0][0].to_literal_sync()?;
        let execute_secs = t0.elapsed().as_secs_f64();

        let mut parts = out.to_tuple()?;
        anyhow::ensure!(
            parts.len() == 1 + params.len(),
            "program returned {} outputs, expected {}",
            parts.len(),
            1 + params.len()
        );
        let loss = parts.remove(0).get_first_element::<f32>()?;
        let mut grads = Vec::with_capacity(parts.len());
        for part in parts {
            grads.push(part.to_vec::<f32>()?);
        }
        Ok(StepOutput { loss, grads, execute_secs })
    }

    /// FLOPs of one step (fwd+bwd) for calibration / utilization reports.
    pub fn step_flops(&self) -> f64 {
        let tokens = (self.meta.batch * self.meta.seq_len) as f64;
        self.meta.model.flops_per_token(self.meta.seq_len) * tokens
            // + the LM-head matmul fwd+bwd (not in flops_per_token's dense
            // term because params() counts it once; close enough for
            // calibration: include 6*V*H per token)
            + 6.0 * (self.meta.model.vocab * self.meta.model.hidden) as f64 * tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::params::init_full_then_shard;

    fn runtime() -> Option<Runtime> {
        let dir = super::super::manifest::default_dir();
        if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new(&dir).unwrap())
    }

    #[test]
    fn tiny_step_runs_and_loss_is_sane() {
        let Some(rt) = runtime() else { return };
        let prog = rt.load_spec("tiny", 2, 4).unwrap();
        let n = prog.meta.batch * prog.meta.seq_len;
        let tokens: Vec<i32> = (0..n).map(|i| (i % 250) as i32).collect();
        let targets: Vec<i32> = (0..n).map(|i| ((i + 1) % 250) as i32).collect();
        let params = init_full_then_shard(&prog.meta, 42);
        let out = prog.train_step(&tokens, &targets, &params).unwrap();
        // vocab 256 -> random-init loss ~ ln(256) = 5.55
        assert!(out.loss.is_finite());
        assert!((3.0..8.0).contains(&out.loss), "loss {}", out.loss);
        assert_eq!(out.grads.len(), params.len());
        for (g, p) in out.grads.iter().zip(&params) {
            assert_eq!(g.len(), p.len());
        }
        // some gradient must be nonzero
        assert!(out.grads.iter().any(|g| g.iter().any(|&x| x != 0.0)));
    }

    #[test]
    fn tp_degrees_agree_on_loss() {
        // The NTP numerics claim, now through the full AOT+PJRT path:
        // identical full params sharded at TP1/2/3/4 give the same loss.
        let Some(rt) = runtime() else { return };
        let mut losses = Vec::new();
        for tp in [1usize, 2, 3, 4] {
            let prog = rt.load_spec("tiny", tp, 4).unwrap();
            let n = prog.meta.batch * prog.meta.seq_len;
            let tokens: Vec<i32> = (0..n).map(|i| ((i * 7) % 256) as i32).collect();
            let targets: Vec<i32> = (0..n).map(|i| ((i * 7 + 1) % 256) as i32).collect();
            let params = init_full_then_shard(&prog.meta, 7);
            let out = prog.train_step(&tokens, &targets, &params).unwrap();
            losses.push(out.loss);
        }
        for w in losses.windows(2) {
            assert!(
                (w[0] - w[1]).abs() < 1e-4,
                "losses diverge across TP: {losses:?}"
            );
        }
    }
}
