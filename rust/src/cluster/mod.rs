//! Cluster topology and health: GPUs grouped into scale-up (NVL) domains
//! and host nodes, with a per-GPU health state machine driven by the
//! failure engine.

pub mod health;
pub mod topology;

pub use health::{FleetHealth, GpuState};
pub use topology::Topology;
