//! Static cluster topology derived from a [`ClusterConfig`]: which GPUs
//! share a scale-up domain (NVLink-class fabric) and a host node.

use crate::config::ClusterConfig;

/// Immutable topology view. GPUs are numbered `0..n_gpus`; domain `d`
/// owns the contiguous range `[d*domain_size, (d+1)*domain_size)`, and
/// nodes subdivide domains.
#[derive(Clone, Debug)]
pub struct Topology {
    pub n_gpus: usize,
    pub domain_size: usize,
    pub gpus_per_node: usize,
}

impl Topology {
    pub fn new(cfg: &ClusterConfig) -> Topology {
        cfg.validate().expect("invalid cluster config");
        Topology {
            n_gpus: cfg.n_gpus,
            domain_size: cfg.domain_size,
            gpus_per_node: cfg.gpus_per_node,
        }
    }

    /// Build directly from sizes (tests / ad-hoc experiments).
    pub fn of(n_gpus: usize, domain_size: usize, gpus_per_node: usize) -> Topology {
        assert!(domain_size > 0 && n_gpus % domain_size == 0);
        assert!(gpus_per_node > 0 && domain_size % gpus_per_node == 0);
        Topology { n_gpus, domain_size, gpus_per_node }
    }

    pub fn n_domains(&self) -> usize {
        self.n_gpus / self.domain_size
    }

    pub fn n_nodes(&self) -> usize {
        self.n_gpus / self.gpus_per_node
    }

    #[inline]
    pub fn domain_of(&self, gpu: usize) -> usize {
        debug_assert!(gpu < self.n_gpus);
        gpu / self.domain_size
    }

    #[inline]
    pub fn node_of(&self, gpu: usize) -> usize {
        gpu / self.gpus_per_node
    }

    /// GPUs in domain `d` as a range.
    pub fn domain_gpus(&self, d: usize) -> std::ops::Range<usize> {
        let start = d * self.domain_size;
        start..start + self.domain_size
    }

    /// GPUs on node `n` as a range.
    pub fn node_gpus(&self, n: usize) -> std::ops::Range<usize> {
        let start = n * self.gpus_per_node;
        start..start + self.gpus_per_node
    }

    /// Nodes making up domain `d`.
    pub fn domain_nodes(&self, d: usize) -> std::ops::Range<usize> {
        let per = self.domain_size / self.gpus_per_node;
        d * per..(d + 1) * per
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let t = Topology::of(64, 16, 4);
        assert_eq!(t.n_domains(), 4);
        assert_eq!(t.n_nodes(), 16);
        for gpu in 0..t.n_gpus {
            let d = t.domain_of(gpu);
            assert!(t.domain_gpus(d).contains(&gpu));
            let n = t.node_of(gpu);
            assert!(t.node_gpus(n).contains(&gpu));
            // node nested in domain
            assert!(t.domain_nodes(d).contains(&n));
        }
    }

    #[test]
    fn domain_ranges_partition_cluster() {
        let t = Topology::of(96, 8, 4);
        let mut seen = vec![false; 96];
        for d in 0..t.n_domains() {
            for g in t.domain_gpus(d) {
                assert!(!seen[g]);
                seen[g] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    #[should_panic]
    fn indivisible_sizes_panic() {
        Topology::of(100, 32, 4);
    }
}
