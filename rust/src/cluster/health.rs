//! Fleet health: per-GPU state plus per-domain aggregates that the NTP
//! planner and the resource manager consume ("how many GPUs are still
//! usable in each scale-up domain?").

use super::topology::Topology;

/// Health of one GPU.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GpuState {
    Healthy,
    /// Degraded-but-alive at `slowdown` × healthy speed since `at_hours`,
    /// expected back to full speed at `until_hours` (sim time).
    Degraded { slowdown: f64, at_hours: f64, until_hours: f64 },
    /// Failed at `at_hours`, expected back at `until_hours` (sim time).
    Failed { at_hours: f64, until_hours: f64 },
}

impl GpuState {
    pub fn is_healthy(&self) -> bool {
        matches!(self, GpuState::Healthy)
    }

    /// Healthy or degraded — i.e. still participating in training.
    pub fn is_alive(&self) -> bool {
        !matches!(self, GpuState::Failed { .. })
    }
}

/// Mutable fleet health snapshot.
///
/// Health is tracked as two independent per-GPU layers — the hard-fail
/// layer (`states`) and the degrade overlay (`degrades`). The effective
/// state reported by [`FleetHealth::state`] is `Failed` if the fail
/// layer is active, else `Degraded` if the overlay is, else `Healthy`.
/// Each layer merges overlapping events order-independently, so replay
/// order never matters.
#[derive(Clone, Debug)]
pub struct FleetHealth {
    pub topo: Topology,
    states: Vec<GpuState>,
    /// Degrade overlay: the `(slowdown, at_hours, until_hours)` entries
    /// currently active on each GPU. A list (not a merged scalar) so
    /// that expiring the shorter of two overlapping degradations
    /// restores the survivor's slowdown exactly — the effective values
    /// are order-independent set functions (min slowdown, max deadline)
    /// of the active entries, which keeps incremental replay
    /// bit-identical to a from-scratch rebuild. Independent of the fail
    /// layer — a GPU can be degraded *and* failed (fail wins in the
    /// effective state).
    degrades: Vec<Vec<(f64, f64, f64)>>,
    /// healthy-GPU count per domain (maintained incrementally; a
    /// degraded-but-alive GPU still counts as healthy here).
    domain_healthy: Vec<usize>,
    /// per-domain count of GPUs that are degraded *and alive*.
    domain_degraded: Vec<usize>,
    /// worst (minimum) slowdown among degraded-and-alive GPUs per
    /// domain; `1.0` when none.
    domain_slowdown: Vec<f64>,
    n_failed: usize,
    /// Total degrade-overlay entries (active or shadowed by a failure).
    n_degrades: usize,
    /// Bumped on every health *transition* (fail/recover/degrade/reset).
    /// Two snapshots of the same `FleetHealth` with equal versions have
    /// identical `domain_healthy_counts`, so consumers evaluating a
    /// function of the counts (e.g. `FleetSim`) can skip recomputation.
    version: u64,
}

impl FleetHealth {
    pub fn new(topo: Topology) -> FleetHealth {
        let n = topo.n_gpus;
        let d = topo.n_domains();
        let ds = topo.domain_size;
        FleetHealth {
            topo,
            states: vec![GpuState::Healthy; n],
            degrades: vec![Vec::new(); n],
            domain_healthy: vec![ds; d],
            domain_degraded: vec![0; d],
            domain_slowdown: vec![1.0; d],
            n_failed: 0,
            n_degrades: 0,
            version: 0,
        }
    }

    /// Monotone counter of health transitions (see field docs).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Effective state of one GPU: fail layer wins over the degrade
    /// overlay, which wins over healthy. A degraded GPU reports the
    /// worst slowdown, earliest onset and latest deadline among its
    /// active overlay entries.
    pub fn state(&self, gpu: usize) -> GpuState {
        match self.states[gpu] {
            GpuState::Healthy => {
                let entries = &self.degrades[gpu];
                if entries.is_empty() {
                    GpuState::Healthy
                } else {
                    let mut slowdown = f64::INFINITY;
                    let mut at_hours = f64::INFINITY;
                    let mut until_hours = f64::NEG_INFINITY;
                    for &(s, at, until) in entries {
                        slowdown = slowdown.min(s);
                        at_hours = at_hours.min(at);
                        until_hours = until_hours.max(until);
                    }
                    GpuState::Degraded { slowdown, at_hours, until_hours }
                }
            }
            failed => failed,
        }
    }

    /// The degrade overlay's latest pending recovery deadline, if any —
    /// independent of whether a failure currently shadows it.
    pub fn degrade_until(&self, gpu: usize) -> Option<f64> {
        self.degrades[gpu]
            .iter()
            .map(|&(_, _, until)| until)
            .fold(None, |acc: Option<f64>, u| Some(acc.map_or(u, |a| a.max(u))))
    }

    pub fn n_failed(&self) -> usize {
        self.n_failed
    }

    pub fn failed_fraction(&self) -> f64 {
        self.n_failed as f64 / self.topo.n_gpus as f64
    }

    /// Healthy GPUs remaining in domain `d`.
    pub fn domain_healthy(&self, d: usize) -> usize {
        self.domain_healthy[d]
    }

    /// Per-domain healthy counts (for the packing manager). Degraded
    /// GPUs are alive and still counted here.
    pub fn domain_healthy_counts(&self) -> &[usize] {
        &self.domain_healthy
    }

    /// Per-domain count of degraded-and-alive GPUs.
    pub fn domain_degraded_counts(&self) -> &[usize] {
        &self.domain_degraded
    }

    /// Per-domain worst (minimum) slowdown among degraded-and-alive
    /// GPUs; `1.0` for domains with none. Because the TP group syncs at
    /// every layer, the slowest member sets the group's pace.
    pub fn domain_slowdowns(&self) -> &[f64] {
        &self.domain_slowdown
    }

    /// Total degraded-and-alive GPUs.
    pub fn n_degraded(&self) -> usize {
        self.domain_degraded.iter().sum()
    }

    /// Number of domains with at least one failure but not fully dead.
    pub fn n_partial_domains(&self) -> usize {
        self.domain_healthy
            .iter()
            .filter(|&&h| h > 0 && h < self.topo.domain_size)
            .count()
    }

    /// Number of fully healthy domains.
    pub fn n_full_domains(&self) -> usize {
        self.domain_healthy.iter().filter(|&&h| h == self.topo.domain_size).count()
    }

    /// Recompute domain `d`'s degraded-and-alive count and worst
    /// slowdown from the layers. O(domain_size), called only when a
    /// mutation could change the domain's degrade view.
    fn rescan_degraded(&mut self, d: usize) {
        let mut count = 0;
        let mut worst = 1.0f64;
        for g in self.topo.domain_gpus(d) {
            if !self.degrades[g].is_empty() && self.states[g].is_healthy() {
                count += 1;
                for &(s, _, _) in &self.degrades[g] {
                    worst = worst.min(s);
                }
            }
        }
        self.domain_degraded[d] = count;
        self.domain_slowdown[d] = worst;
    }

    /// Mark a GPU failed. Idempotent (re-failing a failed GPU extends its
    /// recovery time).
    pub fn fail(&mut self, gpu: usize, at_hours: f64, until_hours: f64) {
        let d = self.topo.domain_of(gpu);
        match self.states[gpu] {
            GpuState::Healthy => {
                self.states[gpu] = GpuState::Failed { at_hours, until_hours };
                self.domain_healthy[d] -= 1;
                self.n_failed += 1;
                self.version += 1;
                if self.n_degrades > 0 && !self.degrades[gpu].is_empty() {
                    // a failure shadows this GPU's degradation
                    self.rescan_degraded(d);
                }
            }
            GpuState::Failed { at_hours: prev_at, until_hours: prev_until } => {
                self.states[gpu] = GpuState::Failed {
                    at_hours: prev_at,
                    until_hours: prev_until.max(until_hours),
                };
            }
            GpuState::Degraded { .. } => unreachable!("fail layer never holds Degraded"),
        }
    }

    /// Mark a GPU recovered (fail layer only; any degrade overlay with a
    /// later deadline resurfaces).
    pub fn recover(&mut self, gpu: usize) {
        if let GpuState::Failed { .. } = self.states[gpu] {
            let d = self.topo.domain_of(gpu);
            self.states[gpu] = GpuState::Healthy;
            self.domain_healthy[d] += 1;
            self.n_failed -= 1;
            self.version += 1;
            if self.n_degrades > 0 && !self.degrades[gpu].is_empty() {
                self.rescan_degraded(d);
            }
        }
    }

    /// Mark a GPU degraded-but-alive at `slowdown` × healthy speed.
    /// Overlapping degradations stack: each keeps its own deadline, and
    /// the effective slowdown is the worst among the active entries.
    pub fn degrade(&mut self, gpu: usize, slowdown: f64, at_hours: f64, until_hours: f64) {
        debug_assert!(
            slowdown > 0.0 && slowdown <= 1.0,
            "slowdown {slowdown} outside (0, 1]"
        );
        let d = self.topo.domain_of(gpu);
        if self.degrades[gpu].is_empty() {
            self.n_degrades += 1;
        }
        self.degrades[gpu].push((slowdown, at_hours, until_hours));
        self.version += 1;
        if self.states[gpu].is_healthy() {
            self.rescan_degraded(d);
        }
    }

    /// Clear a GPU's degrade overlay entirely.
    pub fn recover_degrade(&mut self, gpu: usize) {
        if !self.degrades[gpu].is_empty() {
            let was_alive = self.states[gpu].is_healthy();
            self.degrades[gpu].clear();
            self.n_degrades -= 1;
            self.version += 1;
            if was_alive {
                self.rescan_degraded(self.topo.domain_of(gpu));
            }
        }
    }

    /// Expire the degrade-overlay entries on `gpu` whose deadline is
    /// `<= now_hours`. A surviving overlapping entry keeps the GPU
    /// degraded at its own slowdown.
    pub fn recover_degrade_due(&mut self, gpu: usize, now_hours: f64) {
        if self.degrades[gpu].is_empty() {
            return;
        }
        let before = self.degrades[gpu].len();
        self.degrades[gpu].retain(|&(_, _, until)| until > now_hours);
        if self.degrades[gpu].len() == before {
            return;
        }
        if self.degrades[gpu].is_empty() {
            self.n_degrades -= 1;
        }
        self.version += 1;
        if self.states[gpu].is_healthy() {
            self.rescan_degraded(self.topo.domain_of(gpu));
        }
    }

    /// Recover everything due by `now_hours` — both layers; returns how
    /// many *failures* recovered (degrade expiries are not counted).
    pub fn recover_due(&mut self, now_hours: f64) -> usize {
        let mut n = 0;
        for gpu in 0..self.states.len() {
            if let GpuState::Failed { until_hours, .. } = self.states[gpu] {
                if until_hours <= now_hours {
                    self.recover(gpu);
                    n += 1;
                }
            }
            self.recover_degrade_due(gpu, now_hours);
        }
        n
    }

    /// Reset to all-healthy.
    pub fn reset(&mut self) {
        for s in &mut self.states {
            *s = GpuState::Healthy;
        }
        for dg in &mut self.degrades {
            dg.clear();
        }
        for h in &mut self.domain_healthy {
            *h = self.topo.domain_size;
        }
        for c in &mut self.domain_degraded {
            *c = 0;
        }
        for s in &mut self.domain_slowdown {
            *s = 1.0;
        }
        self.n_failed = 0;
        self.n_degrades = 0;
        self.version += 1;
    }

    /// Internal consistency check (used by tests and debug assertions).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut failed = 0;
        let mut degrades = 0;
        for d in 0..self.topo.n_domains() {
            let healthy = self
                .topo
                .domain_gpus(d)
                .filter(|&g| self.states[g].is_healthy())
                .count();
            if healthy != self.domain_healthy[d] {
                return Err(format!(
                    "domain {d}: cached healthy {} != actual {healthy}",
                    self.domain_healthy[d]
                ));
            }
            failed += self.topo.domain_size - healthy;
            let mut degraded = 0;
            let mut worst = 1.0f64;
            for g in self.topo.domain_gpus(d) {
                if !self.degrades[g].is_empty() {
                    degrades += 1;
                    if self.states[g].is_healthy() {
                        degraded += 1;
                        for &(s, _, _) in &self.degrades[g] {
                            worst = worst.min(s);
                        }
                    }
                }
            }
            if degraded != self.domain_degraded[d] {
                return Err(format!(
                    "domain {d}: cached degraded {} != actual {degraded}",
                    self.domain_degraded[d]
                ));
            }
            if worst != self.domain_slowdown[d] {
                return Err(format!(
                    "domain {d}: cached slowdown {} != actual {worst}",
                    self.domain_slowdown[d]
                ));
            }
        }
        if failed != self.n_failed {
            return Err(format!("cached n_failed {} != actual {failed}", self.n_failed));
        }
        if degrades != self.n_degrades {
            return Err(format!(
                "cached n_degrades {} != actual {degrades}",
                self.n_degrades
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet() -> FleetHealth {
        FleetHealth::new(Topology::of(32, 8, 4))
    }

    #[test]
    fn fail_and_recover_maintain_counts() {
        let mut f = fleet();
        f.fail(0, 0.0, 10.0);
        f.fail(1, 0.0, 5.0);
        f.fail(9, 1.0, 3.0);
        assert_eq!(f.n_failed(), 3);
        assert_eq!(f.domain_healthy(0), 6);
        assert_eq!(f.domain_healthy(1), 7);
        assert_eq!(f.n_partial_domains(), 2);
        assert_eq!(f.n_full_domains(), 2);
        f.check_invariants().unwrap();

        let recovered = f.recover_due(6.0);
        assert_eq!(recovered, 2); // gpu1 (until 5) and gpu9 (until 3)
        assert_eq!(f.n_failed(), 1);
        f.check_invariants().unwrap();
    }

    #[test]
    fn refail_extends_recovery() {
        let mut f = fleet();
        f.fail(3, 0.0, 5.0);
        f.fail(3, 2.0, 20.0); // extension, not double-count
        assert_eq!(f.n_failed(), 1);
        assert_eq!(f.recover_due(10.0), 0);
        assert_eq!(f.recover_due(21.0), 1);
        f.check_invariants().unwrap();
    }

    #[test]
    fn recover_healthy_is_noop() {
        let mut f = fleet();
        f.recover(5);
        assert_eq!(f.n_failed(), 0);
        f.check_invariants().unwrap();
    }

    #[test]
    fn failed_fraction() {
        let mut f = fleet();
        for g in 0..8 {
            f.fail(g, 0.0, 1.0);
        }
        assert!((f.failed_fraction() - 0.25).abs() < 1e-12);
        assert_eq!(f.domain_healthy(0), 0);
        assert_eq!(f.n_partial_domains(), 0); // fully dead, not partial
    }

    #[test]
    fn reset_restores_all() {
        let mut f = fleet();
        f.fail(0, 0.0, 1.0);
        f.fail(31, 0.0, 1.0);
        f.degrade(5, 0.5, 0.0, 1.0);
        f.reset();
        assert_eq!(f.n_failed(), 0);
        assert_eq!(f.n_degraded(), 0);
        assert_eq!(f.n_full_domains(), 4);
        assert!(f.domain_slowdowns().iter().all(|&s| s == 1.0));
        f.check_invariants().unwrap();
    }

    #[test]
    fn degrade_layer_tracks_worst_slowdown() {
        let mut f = fleet();
        f.degrade(0, 0.8, 0.0, 10.0);
        f.degrade(1, 0.5, 1.0, 5.0);
        assert_eq!(f.n_degraded(), 2);
        assert_eq!(f.domain_degraded_counts()[0], 2);
        assert_eq!(f.domain_slowdowns()[0], 0.5);
        assert_eq!(f.n_failed(), 0); // degraded GPUs are alive
        assert_eq!(f.domain_healthy(0), 8);
        // overlapping degrades stack; domain worst is still gpu1's 0.5
        f.degrade(0, 0.6, 2.0, 4.0);
        assert_eq!(f.n_degraded(), 2);
        assert_eq!(f.domain_slowdowns()[0], 0.5);
        assert!(matches!(f.state(0), GpuState::Degraded { slowdown, .. } if slowdown == 0.6));
        f.check_invariants().unwrap();
        // at t=6, gpu1 (until 5) and gpu0's stacked 0.6 entry (until 4)
        // expire; gpu0's original 0.8 degrade (until 10) survives
        f.recover_due(6.0);
        assert_eq!(f.n_degraded(), 1);
        assert_eq!(f.domain_slowdowns()[0], 0.8);
        f.recover_due(11.0);
        assert_eq!(f.n_degraded(), 0);
        assert_eq!(f.domain_slowdowns()[0], 1.0);
        f.check_invariants().unwrap();
    }

    #[test]
    fn failure_shadows_degradation() {
        let mut f = fleet();
        f.degrade(3, 0.4, 0.0, 20.0);
        assert!(matches!(f.state(3), GpuState::Degraded { slowdown, .. } if slowdown == 0.4));
        // a hard failure wins over the overlay...
        f.fail(3, 1.0, 5.0);
        assert!(matches!(f.state(3), GpuState::Failed { .. }));
        assert_eq!(f.n_degraded(), 0);
        assert_eq!(f.domain_slowdowns()[0], 1.0);
        assert_eq!(f.degrade_until(3), Some(20.0));
        f.check_invariants().unwrap();
        // ...and the overlay resurfaces when the failure recovers
        f.recover(3);
        assert!(matches!(f.state(3), GpuState::Degraded { slowdown, .. } if slowdown == 0.4));
        assert_eq!(f.n_degraded(), 1);
        assert_eq!(f.domain_slowdowns()[0], 0.4);
        f.recover_degrade(3);
        assert!(f.state(3).is_healthy());
        assert!(f.state(3).is_alive());
        f.check_invariants().unwrap();
    }
}
