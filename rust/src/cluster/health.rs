//! Fleet health: per-GPU state plus per-domain aggregates that the NTP
//! planner and the resource manager consume ("how many GPUs are still
//! usable in each scale-up domain?").

use super::topology::Topology;

/// Health of one GPU.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GpuState {
    Healthy,
    /// Failed at `at_hours`, expected back at `until_hours` (sim time).
    Failed { at_hours: f64, until_hours: f64 },
}

impl GpuState {
    pub fn is_healthy(&self) -> bool {
        matches!(self, GpuState::Healthy)
    }
}

/// Mutable fleet health snapshot.
#[derive(Clone, Debug)]
pub struct FleetHealth {
    pub topo: Topology,
    states: Vec<GpuState>,
    /// healthy-GPU count per domain (maintained incrementally).
    domain_healthy: Vec<usize>,
    n_failed: usize,
    /// Bumped on every health *transition* (fail/recover/reset). Two
    /// snapshots of the same `FleetHealth` with equal versions have
    /// identical `domain_healthy_counts`, so consumers evaluating a
    /// function of the counts (e.g. `FleetSim`) can skip recomputation.
    version: u64,
}

impl FleetHealth {
    pub fn new(topo: Topology) -> FleetHealth {
        let n = topo.n_gpus;
        let d = topo.n_domains();
        let ds = topo.domain_size;
        FleetHealth {
            topo,
            states: vec![GpuState::Healthy; n],
            domain_healthy: vec![ds; d],
            n_failed: 0,
            version: 0,
        }
    }

    /// Monotone counter of health transitions (see field docs).
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn state(&self, gpu: usize) -> GpuState {
        self.states[gpu]
    }

    pub fn n_failed(&self) -> usize {
        self.n_failed
    }

    pub fn failed_fraction(&self) -> f64 {
        self.n_failed as f64 / self.topo.n_gpus as f64
    }

    /// Healthy GPUs remaining in domain `d`.
    pub fn domain_healthy(&self, d: usize) -> usize {
        self.domain_healthy[d]
    }

    /// Per-domain healthy counts (for the packing manager).
    pub fn domain_healthy_counts(&self) -> &[usize] {
        &self.domain_healthy
    }

    /// Number of domains with at least one failure but not fully dead.
    pub fn n_partial_domains(&self) -> usize {
        self.domain_healthy
            .iter()
            .filter(|&&h| h > 0 && h < self.topo.domain_size)
            .count()
    }

    /// Number of fully healthy domains.
    pub fn n_full_domains(&self) -> usize {
        self.domain_healthy.iter().filter(|&&h| h == self.topo.domain_size).count()
    }

    /// Mark a GPU failed. Idempotent (re-failing a failed GPU extends its
    /// recovery time).
    pub fn fail(&mut self, gpu: usize, at_hours: f64, until_hours: f64) {
        let d = self.topo.domain_of(gpu);
        match self.states[gpu] {
            GpuState::Healthy => {
                self.states[gpu] = GpuState::Failed { at_hours, until_hours };
                self.domain_healthy[d] -= 1;
                self.n_failed += 1;
                self.version += 1;
            }
            GpuState::Failed { at_hours: prev_at, until_hours: prev_until } => {
                self.states[gpu] = GpuState::Failed {
                    at_hours: prev_at,
                    until_hours: prev_until.max(until_hours),
                };
            }
        }
    }

    /// Mark a GPU recovered.
    pub fn recover(&mut self, gpu: usize) {
        if let GpuState::Failed { .. } = self.states[gpu] {
            self.states[gpu] = GpuState::Healthy;
            self.domain_healthy[self.topo.domain_of(gpu)] += 1;
            self.n_failed -= 1;
            self.version += 1;
        }
    }

    /// Recover everything due by `now_hours`; returns how many recovered.
    pub fn recover_due(&mut self, now_hours: f64) -> usize {
        let mut n = 0;
        for gpu in 0..self.states.len() {
            if let GpuState::Failed { until_hours, .. } = self.states[gpu] {
                if until_hours <= now_hours {
                    self.recover(gpu);
                    n += 1;
                }
            }
        }
        n
    }

    /// Reset to all-healthy.
    pub fn reset(&mut self) {
        for s in &mut self.states {
            *s = GpuState::Healthy;
        }
        for h in &mut self.domain_healthy {
            *h = self.topo.domain_size;
        }
        self.n_failed = 0;
        self.version += 1;
    }

    /// Internal consistency check (used by tests and debug assertions).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut failed = 0;
        for d in 0..self.topo.n_domains() {
            let healthy = self
                .topo
                .domain_gpus(d)
                .filter(|&g| self.states[g].is_healthy())
                .count();
            if healthy != self.domain_healthy[d] {
                return Err(format!(
                    "domain {d}: cached healthy {} != actual {healthy}",
                    self.domain_healthy[d]
                ));
            }
            failed += self.topo.domain_size - healthy;
        }
        if failed != self.n_failed {
            return Err(format!("cached n_failed {} != actual {failed}", self.n_failed));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet() -> FleetHealth {
        FleetHealth::new(Topology::of(32, 8, 4))
    }

    #[test]
    fn fail_and_recover_maintain_counts() {
        let mut f = fleet();
        f.fail(0, 0.0, 10.0);
        f.fail(1, 0.0, 5.0);
        f.fail(9, 1.0, 3.0);
        assert_eq!(f.n_failed(), 3);
        assert_eq!(f.domain_healthy(0), 6);
        assert_eq!(f.domain_healthy(1), 7);
        assert_eq!(f.n_partial_domains(), 2);
        assert_eq!(f.n_full_domains(), 2);
        f.check_invariants().unwrap();

        let recovered = f.recover_due(6.0);
        assert_eq!(recovered, 2); // gpu1 (until 5) and gpu9 (until 3)
        assert_eq!(f.n_failed(), 1);
        f.check_invariants().unwrap();
    }

    #[test]
    fn refail_extends_recovery() {
        let mut f = fleet();
        f.fail(3, 0.0, 5.0);
        f.fail(3, 2.0, 20.0); // extension, not double-count
        assert_eq!(f.n_failed(), 1);
        assert_eq!(f.recover_due(10.0), 0);
        assert_eq!(f.recover_due(21.0), 1);
        f.check_invariants().unwrap();
    }

    #[test]
    fn recover_healthy_is_noop() {
        let mut f = fleet();
        f.recover(5);
        assert_eq!(f.n_failed(), 0);
        f.check_invariants().unwrap();
    }

    #[test]
    fn failed_fraction() {
        let mut f = fleet();
        for g in 0..8 {
            f.fail(g, 0.0, 1.0);
        }
        assert!((f.failed_fraction() - 0.25).abs() < 1e-12);
        assert_eq!(f.domain_healthy(0), 0);
        assert_eq!(f.n_partial_domains(), 0); // fully dead, not partial
    }

    #[test]
    fn reset_restores_all() {
        let mut f = fleet();
        f.fail(0, 0.0, 1.0);
        f.fail(31, 0.0, 1.0);
        f.reset();
        assert_eq!(f.n_failed(), 0);
        assert_eq!(f.n_full_domains(), 4);
        f.check_invariants().unwrap();
    }
}
