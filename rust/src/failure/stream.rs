//! Streaming trace generation: draw failure events lazily, in time
//! order, instead of materializing a full `Trace` vector per trial.
//!
//! A [`TraceStream`] wraps the same Poisson processes as
//! [`Trace::generate`] and every [`ScenarioKind`] arm of
//! [`generate_scenario`](super::scenario::generate_scenario) behind one
//! pull contract ([`EventSource`]): each call to
//! [`TraceStream::next_event`] returns the next event by `at_hours`,
//! holding only O(blast) buffered events — so a Monte-Carlo trial fused
//! with the incremental replayer runs in O(1) memory regardless of
//! horizon (the million-trial regime of ROADMAP item 5).
//!
//! ## Determinism and draw order
//!
//! The materialized generators draw each superposed process *to
//! completion* against one shared PRNG and then time-sort, which a lazy
//! merge cannot reproduce draw-for-draw. The stream therefore defines
//! its own canonical order: every process gets a sub-PRNG forked from
//! the trial PRNG under a fixed tag, each process draws
//! `arrival → payload → next arrival` exactly as its materialized
//! counterpart does, and emissions are merged by time (ties broken by
//! process index: base, then node, then domain). Two consequences the
//! tests pin down:
//!
//! * `ScenarioKind::Independent` uses the trial PRNG *directly* (no
//!   fork), so [`TraceStream::collect_trace`] is event-for-event
//!   identical to [`Trace::generate`] on the same PRNG state.
//! * For every kind, replaying the live stream is bit-identical to
//!   materializing it first via `collect_trace` and replaying that
//!   trace — the property the stream-vs-materialized `FleetStats`
//!   identity suites build on.

use super::blast::BlastRadius;
use super::rates::FailureModel;
use super::replayer::EventSource;
use super::scenario::{ScenarioConfig, ScenarioKind};
use super::trace::{EventKind, FailureEvent, Trace};
use crate::cluster::Topology;
use crate::util::prng::Rng;
use std::collections::VecDeque;

/// Fixed fork tags for the per-process sub-PRNGs (non-Independent
/// kinds). Part of the stream's determinism contract: changing a tag
/// changes every scenario stream.
const FORK_BASE: u64 = 0x5743_BA5E;
const FORK_NODE: u64 = 0x5743_140D;
const FORK_DOMAIN: u64 = 0x5743_D011;
const FORK_EXTRA: u64 = 0x5743_E77A;

/// Which Poisson process a [`Process`] draws from.
#[derive(Clone, Copy, Debug)]
enum ProcKind {
    /// Independent per-GPU failures (the `Trace::generate` base).
    Base,
    /// Whole-node correlated blasts.
    Node,
    /// Whole-domain correlated blasts.
    Domain,
    /// Degraded-but-alive straggler onsets.
    Straggler,
    /// Silent corruptions surfacing at the next validation sweep.
    Sdc,
}

/// One lazy Poisson arrival process with its own PRNG.
#[derive(Clone, Debug)]
struct Process {
    kind: ProcKind,
    rng: Rng,
    /// Arrivals per hour.
    rate: f64,
    /// Most recent arrival time (the corruption time for SDC).
    arrival_t: f64,
    /// Time of the next emission; `f64::INFINITY` once exhausted. For
    /// SDC this is the *detection* boundary, which is monotone in the
    /// arrival time, so per-process emissions stay time-sorted.
    emit_t: f64,
}

impl Process {
    fn new(kind: ProcKind, rate: f64, rng: Rng) -> Process {
        Process { kind, rng, rate, arrival_t: 0.0, emit_t: f64::INFINITY }
    }

    /// Draw the next arrival and derive the next emission time. For SDC
    /// a detection boundary at/after the horizon ends the process: the
    /// boundary is monotone in the arrival time, so every later arrival
    /// would be discarded too (the materialized generator keeps drawing
    /// and skipping; with a private sub-PRNG the extra draws are
    /// unobservable and skipped).
    fn advance_arrival(&mut self, horizon_hours: f64, validation_interval_hours: f64) {
        if self.rate <= 0.0 {
            self.emit_t = f64::INFINITY;
            return;
        }
        self.arrival_t += self.rng.exponential(self.rate);
        if self.arrival_t >= horizon_hours {
            self.emit_t = f64::INFINITY;
            return;
        }
        self.emit_t = match self.kind {
            ProcKind::Sdc => {
                let v = validation_interval_hours;
                let detected = ((self.arrival_t / v).floor() + 1.0) * v;
                if detected >= horizon_hours {
                    f64::INFINITY
                } else {
                    detected
                }
            }
            _ => self.arrival_t,
        };
    }
}

/// Lazily generated, time-sorted failure-event stream for one trial.
#[derive(Clone, Debug)]
pub struct TraceStream {
    topo: Topology,
    model: FailureModel,
    cfg: ScenarioConfig,
    horizon_hours: f64,
    procs: Vec<Process>,
    /// Events already drawn but not yet handed out — at most one blast
    /// group (≤ `domain_size` events), never a whole trace.
    buf: VecDeque<FailureEvent>,
    max_buffered: usize,
    emitted: usize,
}

impl TraceStream {
    /// Stream equivalent of
    /// [`generate_scenario`](super::scenario::generate_scenario):
    /// `cfg.kind` selects which processes are superposed on the
    /// independent base process. The PRNG is taken by value — it is the
    /// trial's entire entropy source (fork one per trial).
    pub fn new(
        topo: &Topology,
        model: &FailureModel,
        cfg: &ScenarioConfig,
        horizon_hours: f64,
        mut rng: Rng,
    ) -> TraceStream {
        let base_rate = model.cluster_rate_per_hour(topo.n_gpus);
        let procs = match cfg.kind {
            // The base process consumes the trial PRNG directly, in
            // Trace::generate's exact draw order.
            ScenarioKind::Independent => vec![Process::new(ProcKind::Base, base_rate, rng)],
            ScenarioKind::Correlated => {
                let r = &cfg.correlated;
                let node_rate = r.node_events_per_node_day * topo.n_nodes() as f64 / 24.0;
                let domain_rate = r.domain_events_per_domain_day * topo.n_domains() as f64 / 24.0;
                vec![
                    Process::new(ProcKind::Base, base_rate, rng.fork(FORK_BASE)),
                    Process::new(ProcKind::Node, node_rate, rng.fork(FORK_NODE)),
                    Process::new(ProcKind::Domain, domain_rate, rng.fork(FORK_DOMAIN)),
                ]
            }
            ScenarioKind::Straggler => {
                let rate = cfg.straggler.events_per_gpu_day * topo.n_gpus as f64 / 24.0;
                vec![
                    Process::new(ProcKind::Base, base_rate, rng.fork(FORK_BASE)),
                    Process::new(ProcKind::Straggler, rate, rng.fork(FORK_EXTRA)),
                ]
            }
            ScenarioKind::Sdc => {
                let rate = cfg.sdc.events_per_gpu_day * topo.n_gpus as f64 / 24.0;
                vec![
                    Process::new(ProcKind::Base, base_rate, rng.fork(FORK_BASE)),
                    Process::new(ProcKind::Sdc, rate, rng.fork(FORK_EXTRA)),
                ]
            }
        };
        let mut stream = TraceStream {
            topo: topo.clone(),
            model: model.clone(),
            cfg: cfg.clone(),
            horizon_hours,
            procs,
            buf: VecDeque::new(),
            max_buffered: 0,
            emitted: 0,
        };
        let v = stream.cfg.sdc.validation_interval_hours;
        for p in &mut stream.procs {
            p.advance_arrival(horizon_hours, v);
        }
        stream
    }

    /// Independent-kind stream (the bare `Trace::generate` process).
    pub fn independent(
        topo: &Topology,
        model: &FailureModel,
        horizon_hours: f64,
        rng: Rng,
    ) -> TraceStream {
        TraceStream::new(topo, model, &ScenarioConfig::new(ScenarioKind::Independent), horizon_hours, rng)
    }

    pub fn horizon_hours(&self) -> f64 {
        self.horizon_hours
    }

    /// Events handed out so far.
    pub fn emitted(&self) -> usize {
        self.emitted
    }

    /// High-water mark of the internal event buffer — bounded by the
    /// largest blast group (≤ `domain_size`), the O(1)-memory evidence
    /// the perf gate asserts on.
    pub fn max_buffered(&self) -> usize {
        self.max_buffered
    }

    /// Draw all events of the earliest-emitting process's current
    /// arrival into the buffer, then schedule that process's next one.
    fn refill(&mut self) -> bool {
        let mut best: Option<(f64, usize)> = None;
        for (i, p) in self.procs.iter().enumerate() {
            if p.emit_t.is_finite() && best.map_or(true, |(t, _)| p.emit_t < t) {
                best = Some((p.emit_t, i));
            }
        }
        let Some((t, pi)) = best else { return false };
        let p = &mut self.procs[pi];
        match p.kind {
            ProcKind::Base => {
                let gpu = p.rng.index(self.topo.n_gpus);
                let (is_hw, rec) = self.model.draw_recovery_hours(&mut p.rng);
                self.buf.push_back(FailureEvent {
                    at_hours: t,
                    gpu,
                    is_hw,
                    recover_at_hours: t + rec,
                    kind: EventKind::Fail,
                });
            }
            ProcKind::Node | ProcKind::Domain => {
                // Correlated events expand into per-GPU failures sharing
                // one arrival and one recovery, exactly like the
                // materialized generator — the blast lives in the trace.
                let (lo, hi) = self.cfg.correlated.recovery_hours;
                let (anchor, blast) = match p.kind {
                    ProcKind::Node => {
                        (p.rng.index(self.topo.n_nodes()) * self.topo.gpus_per_node, BlastRadius::Node)
                    }
                    _ => {
                        (p.rng.index(self.topo.n_domains()) * self.topo.domain_size, BlastRadius::Domain)
                    }
                };
                let rec = p.rng.range_f64(lo, hi);
                for g in blast.affected_range(&self.topo, anchor) {
                    self.buf.push_back(FailureEvent {
                        at_hours: t,
                        gpu: g,
                        is_hw: true,
                        recover_at_hours: t + rec,
                        kind: EventKind::Fail,
                    });
                }
            }
            ProcKind::Straggler => {
                let r = &self.cfg.straggler;
                let (lo, hi) = r.slowdown;
                let gpu = p.rng.index(self.topo.n_gpus);
                let slowdown = p.rng.range_f64(lo, hi);
                let duration = p.rng.exponential(1.0 / r.mean_duration_hours);
                self.buf.push_back(FailureEvent {
                    at_hours: t,
                    gpu,
                    is_hw: false,
                    recover_at_hours: t + duration,
                    kind: EventKind::Degrade { slowdown },
                });
            }
            ProcKind::Sdc => {
                let gpu = p.rng.index(self.topo.n_gpus);
                let (is_hw, rec) = self.model.draw_recovery_hours(&mut p.rng);
                self.buf.push_back(FailureEvent {
                    at_hours: t,
                    gpu,
                    is_hw,
                    recover_at_hours: t + rec,
                    kind: EventKind::Sdc { corrupt_at_hours: p.arrival_t },
                });
            }
        }
        let v = self.cfg.sdc.validation_interval_hours;
        self.procs[pi].advance_arrival(self.horizon_hours, v);
        self.max_buffered = self.max_buffered.max(self.buf.len());
        true
    }

    /// The next event by `at_hours`, or `None` once every process has
    /// run past the horizon. Emission times are non-decreasing.
    pub fn next_event(&mut self) -> Option<FailureEvent> {
        if self.buf.is_empty() && !self.refill() {
            return None;
        }
        self.emitted += 1;
        self.buf.pop_front()
    }

    /// Materialize the remaining stream as a `Trace` (time-sorted by
    /// construction). The bridge between the streaming and materialized
    /// paths: replaying `collect_trace()` is bit-identical to replaying
    /// the live stream.
    pub fn collect_trace(mut self) -> Trace {
        let horizon_hours = self.horizon_hours;
        let mut events = Vec::new();
        while let Some(ev) = self.next_event() {
            events.push(ev);
        }
        Trace { horizon_hours, events }
    }
}

impl EventSource for TraceStream {
    fn horizon_hours(&self) -> f64 {
        self.horizon_hours
    }

    fn next_event(&mut self) -> Option<FailureEvent> {
        TraceStream::next_event(self)
    }
}

/// Deterministic per-trial stream factory: one seed fans out to
/// independent trial PRNGs by fork tag, so trial `i`'s stream (and its
/// materialized twin) can be rebuilt in O(1) from any worker thread —
/// the random-access property `run_trials_stream_par` batches on.
#[derive(Clone, Debug)]
pub struct TrialGen {
    pub topo: Topology,
    pub model: FailureModel,
    pub cfg: ScenarioConfig,
    pub horizon_hours: f64,
    pub seed: u64,
    pub trials: usize,
}

impl TrialGen {
    pub fn new(
        topo: &Topology,
        model: &FailureModel,
        cfg: &ScenarioConfig,
        horizon_hours: f64,
        seed: u64,
        trials: usize,
    ) -> TrialGen {
        TrialGen {
            topo: topo.clone(),
            model: model.clone(),
            cfg: cfg.clone(),
            horizon_hours,
            seed,
            trials,
        }
    }

    /// Trial `i`'s PRNG. A fresh root is re-seeded per call so the fork
    /// is O(1) per trial (no order-dependent draw chain), giving every
    /// trial an independent stream addressable from any thread.
    pub fn rng_for(&self, trial: usize) -> Rng {
        let mut root = Rng::new(self.seed);
        root.fork(trial as u64)
    }

    pub fn stream_for(&self, trial: usize) -> TraceStream {
        TraceStream::new(&self.topo, &self.model, &self.cfg, self.horizon_hours, self.rng_for(trial))
    }

    /// Materialized twin of [`TrialGen::stream_for`] — same events, same
    /// order (the bit-identity baseline and A/B memory comparand).
    pub fn trace_for(&self, trial: usize) -> Trace {
        self.stream_for(trial).collect_trace()
    }

    pub fn traces(&self) -> Vec<Trace> {
        (0..self.trials).map(|i| self.trace_for(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::of(512, 16, 4)
    }

    fn hot_config(kind: ScenarioKind) -> ScenarioConfig {
        let mut cfg = ScenarioConfig::new(kind);
        cfg.correlated = cfg.correlated.scaled(2_000.0);
        cfg.straggler = cfg.straggler.scaled(200.0);
        cfg.sdc = cfg.sdc.scaled(2_000.0);
        cfg
    }

    fn all_kinds() -> [ScenarioKind; 4] {
        [
            ScenarioKind::Independent,
            ScenarioKind::Correlated,
            ScenarioKind::Straggler,
            ScenarioKind::Sdc,
        ]
    }

    #[test]
    fn independent_stream_matches_trace_generate_exactly() {
        let topo = topo();
        let model = FailureModel::llama3().scaled(40.0);
        let horizon = 24.0 * 12.0;
        let stream = TraceStream::independent(&topo, &model, horizon, Rng::new(99));
        let collected = stream.collect_trace();
        let mut rng = Rng::new(99);
        let reference = Trace::generate(&topo, &model, horizon, &mut rng);
        assert_eq!(collected.horizon_hours, reference.horizon_hours);
        assert_eq!(collected.events, reference.events);
        assert!(!collected.events.is_empty());
    }

    #[test]
    fn every_kind_streams_the_event_contract() {
        let topo = topo();
        let model = FailureModel::llama3().scaled(30.0);
        let horizon = 24.0 * 10.0;
        for kind in all_kinds() {
            let mut stream =
                TraceStream::new(&topo, &model, &hot_config(kind), horizon, Rng::new(0xC0FFEE));
            let mut prev = 0.0f64;
            let mut n = 0usize;
            while let Some(ev) = stream.next_event() {
                assert!(ev.at_hours >= prev, "{kind:?} went backwards");
                prev = ev.at_hours;
                assert!(ev.at_hours >= 0.0 && ev.at_hours < horizon, "{kind:?} out of horizon");
                assert!(ev.recover_at_hours > ev.at_hours, "{kind:?} non-positive outage");
                assert!(ev.gpu < topo.n_gpus);
                n += 1;
            }
            assert!(n > 0, "{kind:?} produced no events");
            assert_eq!(stream.emitted(), n);
            // O(1) buffering: never more than one blast group in flight.
            assert!(
                stream.max_buffered() <= topo.domain_size,
                "{kind:?} buffered {}",
                stream.max_buffered()
            );
        }
    }

    #[test]
    fn stream_event_mix_matches_materialized_generator() {
        // Same processes, different draw interleavings: event *counts*
        // per kind should agree within Monte-Carlo noise.
        let topo = topo();
        let model = FailureModel::llama3().scaled(30.0);
        let horizon = 24.0 * 30.0;
        for kind in all_kinds() {
            let cfg = hot_config(kind);
            let count = |tr: &Trace, pick: fn(&EventKind) -> bool| {
                tr.events.iter().filter(|e| pick(&e.kind)).count()
            };
            let mut streamed = (0usize, 0usize, 0usize); // fail/degrade/sdc
            let mut materialized = (0usize, 0usize, 0usize);
            for trial in 0..8u64 {
                let s = TraceStream::new(&topo, &model, &cfg, horizon, Rng::new(1000 + trial));
                let t = s.collect_trace();
                streamed.0 += count(&t, |k| matches!(k, EventKind::Fail));
                streamed.1 += count(&t, |k| matches!(k, EventKind::Degrade { .. }));
                streamed.2 += count(&t, |k| matches!(k, EventKind::Sdc { .. }));
                let mut rng = Rng::new(5000 + trial);
                let t = crate::failure::generate_scenario(&topo, &model, &cfg, horizon, &mut rng);
                materialized.0 += count(&t, |k| matches!(k, EventKind::Fail));
                materialized.1 += count(&t, |k| matches!(k, EventKind::Degrade { .. }));
                materialized.2 += count(&t, |k| matches!(k, EventKind::Sdc { .. }));
            }
            for (s, m) in [
                (streamed.0, materialized.0),
                (streamed.1, materialized.1),
                (streamed.2, materialized.2),
            ] {
                if s + m < 40 {
                    continue; // too few arrivals to compare rates
                }
                let ratio = s as f64 / m.max(1) as f64;
                assert!((0.6..1.7).contains(&ratio), "{kind:?}: stream {s} vs materialized {m}");
            }
        }
    }

    #[test]
    fn correlated_stream_emits_whole_blast_groups() {
        let topo = topo();
        // Silence the base process so only correlated groups remain.
        let model = FailureModel::llama3().scaled(1e-9);
        let mut cfg = ScenarioConfig::new(ScenarioKind::Correlated);
        cfg.correlated = cfg.correlated.scaled(3_000.0);
        let trace =
            TraceStream::new(&topo, &model, &cfg, 24.0 * 10.0, Rng::new(8)).collect_trace();
        assert!(!trace.events.is_empty());
        let mut i = 0;
        let mut saw_domain = false;
        while i < trace.events.len() {
            let t = trace.events[i].at_hours;
            let mut j = i;
            while j < trace.events.len() && trace.events[j].at_hours == t {
                j += 1;
            }
            let group = j - i;
            assert!(
                group == topo.gpus_per_node || group == topo.domain_size,
                "blast group of {group} at t={t}"
            );
            saw_domain |= group == topo.domain_size;
            i = j;
        }
        assert!(saw_domain, "no domain-level blast streamed");
    }

    #[test]
    fn trial_gen_streams_are_independent_and_reproducible() {
        let topo = topo();
        let model = FailureModel::llama3().scaled(30.0);
        let gen = TrialGen::new(
            &topo,
            &model,
            &hot_config(ScenarioKind::Sdc),
            24.0 * 10.0,
            42,
            4,
        );
        let a0 = gen.trace_for(0);
        let a0_again = gen.trace_for(0);
        assert_eq!(a0.events, a0_again.events, "trial 0 not reproducible");
        let a1 = gen.trace_for(1);
        assert_ne!(a0.events, a1.events, "trials 0 and 1 identical");
        assert_eq!(gen.traces().len(), 4);
    }
}
