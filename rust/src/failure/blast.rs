//! Failure blast radius (§6.4 / Fig. 10): how many GPUs a single failure
//! event takes out. Per [Cui et al. 2025], 91% of GPU failures are
//! uncontained memory / MMU errors confined to one GPU, ~5% are NVLink
//! errors that can propagate; and on GB200-class racks operators may
//! prefer discarding a whole compute tray (node) or domain.

use crate::cluster::topology::Topology;

/// Blast-radius policy for a single failure event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlastRadius {
    /// Only the failing GPU.
    Single,
    /// The failing GPU plus `k - 1` neighbours within its node/domain.
    Gpus(usize),
    /// The failing GPU's host node (compute tray).
    Node,
    /// The entire scale-up domain.
    Domain,
}

impl BlastRadius {
    pub fn parse(s: &str) -> anyhow::Result<BlastRadius> {
        Ok(match s {
            "single" | "1" => BlastRadius::Single,
            "node" => BlastRadius::Node,
            "domain" => BlastRadius::Domain,
            other => BlastRadius::Gpus(
                other
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad blast radius '{other}'"))?,
            ),
        })
    }

    /// Number of GPUs affected under topology `t`.
    pub fn size(&self, t: &Topology) -> usize {
        match self {
            BlastRadius::Single => 1,
            BlastRadius::Gpus(k) => (*k).min(t.domain_size),
            BlastRadius::Node => t.gpus_per_node,
            BlastRadius::Domain => t.domain_size,
        }
    }

    /// GPUs taken out when `gpu` fails. The affected set is contained
    /// within the GPU's scale-up domain (failures never propagate over
    /// the scale-out network) and aligned to blocks of `size` so whole
    /// trays/domains are discarded cleanly.
    pub fn affected(&self, t: &Topology, gpu: usize) -> Vec<usize> {
        self.affected_range(t, gpu).collect()
    }

    /// Allocation-free [`BlastRadius::affected`]: the affected set is
    /// always one contiguous aligned block, so the replay and streaming
    /// hot paths iterate the range directly instead of materializing a
    /// `Vec` per event.
    pub fn affected_range(&self, t: &Topology, gpu: usize) -> std::ops::Range<usize> {
        let k = self.size(t);
        let domain_start = t.domain_of(gpu) * t.domain_size;
        // Align to k-sized blocks within the domain.
        let offset = (gpu - domain_start) / k * k;
        let start = domain_start + offset;
        start..start + k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_is_self() {
        let t = Topology::of(64, 16, 4);
        assert_eq!(BlastRadius::Single.affected(&t, 37), vec![37]);
    }

    #[test]
    fn node_takes_out_tray() {
        let t = Topology::of(64, 16, 4);
        // gpu 37 is on node 9 (gpus 36..40)
        assert_eq!(BlastRadius::Node.affected(&t, 37), vec![36, 37, 38, 39]);
    }

    #[test]
    fn domain_takes_out_whole_domain() {
        let t = Topology::of(64, 16, 4);
        let a = BlastRadius::Domain.affected(&t, 37);
        assert_eq!(a, (32..48).collect::<Vec<_>>());
    }

    #[test]
    fn pair_blocks_stay_in_domain() {
        let t = Topology::of(64, 16, 4);
        for gpu in 0..64 {
            let a = BlastRadius::Gpus(2).affected(&t, gpu);
            assert_eq!(a.len(), 2);
            assert!(a.contains(&gpu));
            assert!(a.iter().all(|&g| t.domain_of(g) == t.domain_of(gpu)));
        }
    }

    #[test]
    fn oversized_radius_clamps_to_domain() {
        let t = Topology::of(64, 16, 4);
        let a = BlastRadius::Gpus(100).affected(&t, 5);
        assert_eq!(a.len(), 16);
    }

    #[test]
    fn parse_variants() {
        assert_eq!(BlastRadius::parse("single").unwrap(), BlastRadius::Single);
        assert_eq!(BlastRadius::parse("4").unwrap(), BlastRadius::Gpus(4));
        assert_eq!(BlastRadius::parse("node").unwrap(), BlastRadius::Node);
        assert_eq!(BlastRadius::parse("domain").unwrap(), BlastRadius::Domain);
        assert!(BlastRadius::parse("huge").is_err());
    }
}
