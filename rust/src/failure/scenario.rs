//! Monte-Carlo failure-placement scenarios (Figs. 3, 6, 10): sample F
//! failed GPUs uniformly at random (with blast-radius expansion) and
//! summarize the per-domain damage — the input to the availability and
//! throughput-loss computations.

use super::blast::BlastRadius;
use crate::cluster::Topology;
use crate::util::prng::Rng;

/// One sampled failure placement.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Healthy GPUs remaining per domain.
    pub domain_healthy: Vec<usize>,
    pub domain_size: usize,
    pub n_failed: usize,
}

impl Scenario {
    pub fn n_domains(&self) -> usize {
        self.domain_healthy.len()
    }

    /// Domains with zero failures.
    pub fn full_domains(&self) -> usize {
        self.domain_healthy.iter().filter(|&&h| h == self.domain_size).count()
    }

    /// Domains with at least one failure.
    pub fn impacted_domains(&self) -> usize {
        self.n_domains() - self.full_domains()
    }

    /// Fleet availability if any impacted domain is entirely unusable
    /// (the uniform-TP / pre-NTP model behind Fig. 3).
    pub fn availability_domain_drop(&self) -> f64 {
        self.full_domains() as f64 / self.n_domains() as f64
    }

    /// Fleet availability if impacted domains still contribute their
    /// healthy GPUs (the NTP model: throughput ∝ functional GPUs).
    pub fn availability_ntp(&self) -> f64 {
        let healthy: usize = self.domain_healthy.iter().sum();
        healthy as f64 / (self.n_domains() * self.domain_size) as f64
    }
}

/// Sample `n_failed` distinct failed GPUs uniformly; when `blast`
/// expands an event, sampling proceeds event-by-event until at least
/// `n_failed` GPUs are down (matching the paper's x-axis of "fraction of
/// GPUs failed").
pub fn sample_failed_gpus(
    topo: &Topology,
    n_failed: usize,
    blast: BlastRadius,
    rng: &mut Rng,
) -> Vec<usize> {
    if blast == BlastRadius::Single {
        return rng.sample_indices(topo.n_gpus, n_failed);
    }
    let mut failed = vec![false; topo.n_gpus];
    let mut count = 0;
    while count < n_failed {
        let gpu = rng.index(topo.n_gpus);
        for g in blast.affected(topo, gpu) {
            if !failed[g] {
                failed[g] = true;
                count += 1;
            }
        }
    }
    failed
        .iter()
        .enumerate()
        .filter_map(|(g, &f)| if f { Some(g) } else { None })
        .collect()
}

/// Build a [`Scenario`] from an explicit failed-GPU set.
pub fn scenario_from_failed(topo: &Topology, failed: &[usize]) -> Scenario {
    let mut domain_healthy = vec![topo.domain_size; topo.n_domains()];
    for &g in failed {
        domain_healthy[topo.domain_of(g)] -= 1;
    }
    Scenario {
        domain_healthy,
        domain_size: topo.domain_size,
        n_failed: failed.len(),
    }
}

/// Sample a scenario directly.
pub fn sample_scenario(
    topo: &Topology,
    n_failed: usize,
    blast: BlastRadius,
    rng: &mut Rng,
) -> Scenario {
    let failed = sample_failed_gpus(topo, n_failed, blast, rng);
    scenario_from_failed(topo, &failed)
}

/// Closed-form expected domain-drop availability under uniform single-GPU
/// failures: P(domain untouched) = prod_{i=0..D-1} (N - F - i) / (N - i).
/// Used to validate the Monte-Carlo sampler.
pub fn expected_availability_domain_drop(n_gpus: usize, domain_size: usize, n_failed: usize) -> f64 {
    let mut p = 1.0;
    for i in 0..domain_size {
        if n_failed + i >= n_gpus {
            return 0.0; // more failures than remaining slots
        }
        p *= (n_gpus - n_failed - i) as f64 / (n_gpus - i) as f64;
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampled_failures_are_distinct_and_counted() {
        let topo = Topology::of(1024, 32, 4);
        let mut rng = Rng::new(5);
        let failed = sample_failed_gpus(&topo, 50, BlastRadius::Single, &mut rng);
        assert_eq!(failed.len(), 50);
        let s = scenario_from_failed(&topo, &failed);
        assert_eq!(s.n_failed, 50);
        assert_eq!(
            s.domain_healthy.iter().sum::<usize>(),
            1024 - 50
        );
    }

    #[test]
    fn monte_carlo_matches_closed_form() {
        let topo = Topology::of(4096, 16, 4);
        let n_failed = 8;
        let mut rng = Rng::new(9);
        let trials = 4000;
        let mean_avail: f64 = (0..trials)
            .map(|_| {
                sample_scenario(&topo, n_failed, BlastRadius::Single, &mut rng)
                    .availability_domain_drop()
            })
            .sum::<f64>()
            / trials as f64;
        let expected = expected_availability_domain_drop(4096, 16, n_failed);
        assert!(
            (mean_avail - expected).abs() < 0.005,
            "mc {mean_avail} vs exact {expected}"
        );
    }

    #[test]
    fn paper_fig3_tp64_at_0_1pct() {
        // Paper: TP64, 0.1% failed → ~94% availability.
        let a = expected_availability_domain_drop(32_768, 64, 33);
        assert!((a - 0.94).abs() < 0.01, "availability {a}");
    }

    #[test]
    fn ntp_availability_dominates_domain_drop() {
        let topo = Topology::of(2048, 32, 4);
        let mut rng = Rng::new(2);
        for &f in &[1usize, 10, 50, 200] {
            let s = sample_scenario(&topo, f, BlastRadius::Single, &mut rng);
            assert!(s.availability_ntp() >= s.availability_domain_drop());
            // NTP availability is exactly 1 - failed fraction.
            let exact = 1.0 - f as f64 / 2048.0;
            assert!((s.availability_ntp() - exact).abs() < 1e-12);
        }
    }

    #[test]
    fn blast_expansion_reaches_target() {
        let topo = Topology::of(512, 16, 4);
        let mut rng = Rng::new(3);
        let failed = sample_failed_gpus(&topo, 30, BlastRadius::Node, &mut rng);
        assert!(failed.len() >= 30);
        // all-or-nothing per node
        for n in 0..topo.n_nodes() {
            let in_node = topo.node_gpus(n).filter(|g| failed.contains(g)).count();
            assert!(in_node == 0 || in_node == topo.gpus_per_node);
        }
    }
}
