//! Monte-Carlo failure-placement scenarios (Figs. 3, 6, 10): sample F
//! failed GPUs uniformly at random (with blast-radius expansion) and
//! summarize the per-domain damage — the input to the availability and
//! throughput-loss computations.
//!
//! Also home to the scenario-diversity trace generators: correlated
//! rack/switch blasts, degraded-but-alive stragglers, and silent data
//! corruption detected by periodic validation sweeps. All emit the same
//! timestamped-event contract as [`Trace::generate`], so the exact
//! event-boundary integrator and the incremental replayer work on them
//! unchanged.

use super::blast::BlastRadius;
use super::rates::{CorrelatedRates, FailureModel, SdcRates, StragglerRates};
use super::trace::{EventKind, FailureEvent, Trace};
use crate::cluster::Topology;
use crate::util::prng::Rng;

/// One sampled failure placement.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Healthy GPUs remaining per domain.
    pub domain_healthy: Vec<usize>,
    pub domain_size: usize,
    pub n_failed: usize,
}

impl Scenario {
    pub fn n_domains(&self) -> usize {
        self.domain_healthy.len()
    }

    /// Domains with zero failures.
    pub fn full_domains(&self) -> usize {
        self.domain_healthy.iter().filter(|&&h| h == self.domain_size).count()
    }

    /// Domains with at least one failure.
    pub fn impacted_domains(&self) -> usize {
        self.n_domains() - self.full_domains()
    }

    /// Fleet availability if any impacted domain is entirely unusable
    /// (the uniform-TP / pre-NTP model behind Fig. 3).
    pub fn availability_domain_drop(&self) -> f64 {
        self.full_domains() as f64 / self.n_domains() as f64
    }

    /// Fleet availability if impacted domains still contribute their
    /// healthy GPUs (the NTP model: throughput ∝ functional GPUs).
    pub fn availability_ntp(&self) -> f64 {
        let healthy: usize = self.domain_healthy.iter().sum();
        healthy as f64 / (self.n_domains() * self.domain_size) as f64
    }
}

/// Sample `n_failed` distinct failed GPUs uniformly; when `blast`
/// expands an event, sampling proceeds event-by-event until at least
/// `n_failed` GPUs are down (matching the paper's x-axis of "fraction of
/// GPUs failed").
pub fn sample_failed_gpus(
    topo: &Topology,
    n_failed: usize,
    blast: BlastRadius,
    rng: &mut Rng,
) -> Vec<usize> {
    if blast == BlastRadius::Single {
        return rng.sample_indices(topo.n_gpus, n_failed);
    }
    let mut failed = vec![false; topo.n_gpus];
    let mut count = 0;
    while count < n_failed {
        let gpu = rng.index(topo.n_gpus);
        for g in blast.affected(topo, gpu) {
            if !failed[g] {
                failed[g] = true;
                count += 1;
            }
        }
    }
    failed
        .iter()
        .enumerate()
        .filter_map(|(g, &f)| if f { Some(g) } else { None })
        .collect()
}

/// Build a [`Scenario`] from an explicit failed-GPU set.
pub fn scenario_from_failed(topo: &Topology, failed: &[usize]) -> Scenario {
    let mut domain_healthy = vec![topo.domain_size; topo.n_domains()];
    for &g in failed {
        domain_healthy[topo.domain_of(g)] -= 1;
    }
    Scenario {
        domain_healthy,
        domain_size: topo.domain_size,
        n_failed: failed.len(),
    }
}

/// Sample a scenario directly.
pub fn sample_scenario(
    topo: &Topology,
    n_failed: usize,
    blast: BlastRadius,
    rng: &mut Rng,
) -> Scenario {
    let failed = sample_failed_gpus(topo, n_failed, blast, rng);
    scenario_from_failed(topo, &failed)
}

/// Closed-form expected domain-drop availability under uniform single-GPU
/// failures: P(domain untouched) = prod_{i=0..D-1} (N - F - i) / (N - i).
/// Used to validate the Monte-Carlo sampler.
pub fn expected_availability_domain_drop(n_gpus: usize, domain_size: usize, n_failed: usize) -> f64 {
    let mut p = 1.0;
    for i in 0..domain_size {
        if n_failed + i >= n_gpus {
            return 0.0; // more failures than remaining slots
        }
        p *= (n_gpus - n_failed - i) as f64 / (n_gpus - i) as f64;
    }
    p
}

/// Which failure process a trace generator draws from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Independent per-GPU Poisson failures (the paper's Fig-4 base case).
    Independent,
    /// Base process plus rack- and scale-up-switch-level events that
    /// fail a whole node / domain at once — the blast radius becomes
    /// endogenous to the trace instead of a replay-time parameter.
    Correlated,
    /// Base process plus degraded-but-alive straggler onsets.
    Straggler,
    /// Base process plus silent corruptions that surface only at the
    /// next periodic validation sweep.
    Sdc,
}

impl ScenarioKind {
    pub fn parse(s: &str) -> anyhow::Result<ScenarioKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "independent" | "iid" => ScenarioKind::Independent,
            "correlated" | "blast" => ScenarioKind::Correlated,
            "straggler" | "stragglers" => ScenarioKind::Straggler,
            "sdc" => ScenarioKind::Sdc,
            other => anyhow::bail!(
                "unknown scenario '{other}' (expected independent, correlated, straggler or sdc)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScenarioKind::Independent => "independent",
            ScenarioKind::Correlated => "correlated",
            ScenarioKind::Straggler => "straggler",
            ScenarioKind::Sdc => "sdc",
        }
    }
}

/// Full parameterization of one scenario generator. Only the section
/// matching `kind` is consumed; the others ride along so one config can
/// be threaded through CLI / bench plumbing unconditionally.
#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    pub kind: ScenarioKind,
    pub correlated: CorrelatedRates,
    pub straggler: StragglerRates,
    pub sdc: SdcRates,
}

impl ScenarioConfig {
    /// Calibrated (ByteDance-report) defaults for every process.
    pub fn new(kind: ScenarioKind) -> ScenarioConfig {
        ScenarioConfig {
            kind,
            correlated: CorrelatedRates::bytedance(),
            straggler: StragglerRates::bytedance(),
            sdc: SdcRates::bytedance(),
        }
    }
}

/// Homogeneous Poisson arrival stream at `rate` events/hour over
/// `[0, horizon_hours)`.
fn poisson_arrivals(
    rate: f64,
    horizon_hours: f64,
    rng: &mut Rng,
    mut emit: impl FnMut(&mut Rng, f64),
) {
    if rate <= 0.0 {
        return;
    }
    let mut t = 0.0;
    loop {
        t += rng.exponential(rate);
        if t >= horizon_hours {
            break;
        }
        emit(rng, t);
    }
}

/// Generate a scenario trace: the independent per-GPU base process from
/// `model`, superposed with the extra process selected by `cfg.kind`.
/// The result satisfies the generator contract every consumer relies
/// on: events time-sorted by `at_hours`, all within the horizon, and
/// `recover_at_hours > at_hours` for every event.
pub fn generate_scenario(
    topo: &Topology,
    model: &FailureModel,
    cfg: &ScenarioConfig,
    horizon_hours: f64,
    rng: &mut Rng,
) -> Trace {
    let mut trace = Trace::generate(topo, model, horizon_hours, rng);
    match cfg.kind {
        ScenarioKind::Independent => {}
        ScenarioKind::Correlated => {
            // Correlated events are expanded into per-GPU failures at
            // generation time (sharing one arrival and one recovery), so
            // a replay with `BlastRadius::Single` still sees whole-node /
            // whole-domain outages — the blast radius is in the trace.
            let r = &cfg.correlated;
            let (lo, hi) = r.recovery_hours;
            let node_rate = r.node_events_per_node_day * topo.n_nodes() as f64 / 24.0;
            poisson_arrivals(node_rate, horizon_hours, rng, |rng, t| {
                let anchor = rng.index(topo.n_nodes()) * topo.gpus_per_node;
                let rec = rng.range_f64(lo, hi);
                for g in BlastRadius::Node.affected(topo, anchor) {
                    trace.events.push(FailureEvent {
                        at_hours: t,
                        gpu: g,
                        is_hw: true,
                        recover_at_hours: t + rec,
                        kind: EventKind::Fail,
                    });
                }
            });
            let domain_rate = r.domain_events_per_domain_day * topo.n_domains() as f64 / 24.0;
            poisson_arrivals(domain_rate, horizon_hours, rng, |rng, t| {
                let anchor = rng.index(topo.n_domains()) * topo.domain_size;
                let rec = rng.range_f64(lo, hi);
                for g in BlastRadius::Domain.affected(topo, anchor) {
                    trace.events.push(FailureEvent {
                        at_hours: t,
                        gpu: g,
                        is_hw: true,
                        recover_at_hours: t + rec,
                        kind: EventKind::Fail,
                    });
                }
            });
        }
        ScenarioKind::Straggler => {
            let r = &cfg.straggler;
            let rate = r.events_per_gpu_day * topo.n_gpus as f64 / 24.0;
            let (lo, hi) = r.slowdown;
            poisson_arrivals(rate, horizon_hours, rng, |rng, t| {
                let gpu = rng.index(topo.n_gpus);
                let slowdown = rng.range_f64(lo, hi);
                let duration = rng.exponential(1.0 / r.mean_duration_hours);
                trace.events.push(FailureEvent {
                    at_hours: t,
                    gpu,
                    is_hw: false,
                    recover_at_hours: t + duration,
                    kind: EventKind::Degrade { slowdown },
                });
            });
        }
        ScenarioKind::Sdc => {
            let r = &cfg.sdc;
            let rate = r.events_per_gpu_day * topo.n_gpus as f64 / 24.0;
            let v = r.validation_interval_hours;
            poisson_arrivals(rate, horizon_hours, rng, |rng, t| {
                // Corrupted at t, invisible until the next validation
                // sweep: the trace event lives at the detection boundary
                // and carries the corruption time so the integrator can
                // charge the detection-lag rollback.
                let detected = ((t / v).floor() + 1.0) * v;
                if detected >= horizon_hours {
                    return;
                }
                let gpu = rng.index(topo.n_gpus);
                let (is_hw, rec) = model.draw_recovery_hours(rng);
                trace.events.push(FailureEvent {
                    at_hours: detected,
                    gpu,
                    is_hw,
                    recover_at_hours: detected + rec,
                    kind: EventKind::Sdc { corrupt_at_hours: t },
                });
            });
        }
    }
    trace.events.sort_by(|a, b| a.at_hours.total_cmp(&b.at_hours));
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampled_failures_are_distinct_and_counted() {
        let topo = Topology::of(1024, 32, 4);
        let mut rng = Rng::new(5);
        let failed = sample_failed_gpus(&topo, 50, BlastRadius::Single, &mut rng);
        assert_eq!(failed.len(), 50);
        let s = scenario_from_failed(&topo, &failed);
        assert_eq!(s.n_failed, 50);
        assert_eq!(
            s.domain_healthy.iter().sum::<usize>(),
            1024 - 50
        );
    }

    #[test]
    fn monte_carlo_matches_closed_form() {
        let topo = Topology::of(4096, 16, 4);
        let n_failed = 8;
        let mut rng = Rng::new(9);
        let trials = 4000;
        let mean_avail: f64 = (0..trials)
            .map(|_| {
                sample_scenario(&topo, n_failed, BlastRadius::Single, &mut rng)
                    .availability_domain_drop()
            })
            .sum::<f64>()
            / trials as f64;
        let expected = expected_availability_domain_drop(4096, 16, n_failed);
        assert!(
            (mean_avail - expected).abs() < 0.005,
            "mc {mean_avail} vs exact {expected}"
        );
    }

    #[test]
    fn paper_fig3_tp64_at_0_1pct() {
        // Paper: TP64, 0.1% failed → ~94% availability.
        let a = expected_availability_domain_drop(32_768, 64, 33);
        assert!((a - 0.94).abs() < 0.01, "availability {a}");
    }

    #[test]
    fn ntp_availability_dominates_domain_drop() {
        let topo = Topology::of(2048, 32, 4);
        let mut rng = Rng::new(2);
        for &f in &[1usize, 10, 50, 200] {
            let s = sample_scenario(&topo, f, BlastRadius::Single, &mut rng);
            assert!(s.availability_ntp() >= s.availability_domain_drop());
            // NTP availability is exactly 1 - failed fraction.
            let exact = 1.0 - f as f64 / 2048.0;
            assert!((s.availability_ntp() - exact).abs() < 1e-12);
        }
    }

    #[test]
    fn blast_expansion_reaches_target() {
        let topo = Topology::of(512, 16, 4);
        let mut rng = Rng::new(3);
        let failed = sample_failed_gpus(&topo, 30, BlastRadius::Node, &mut rng);
        assert!(failed.len() >= 30);
        // all-or-nothing per node
        for n in 0..topo.n_nodes() {
            let in_node = topo.node_gpus(n).filter(|g| failed.contains(g)).count();
            assert!(in_node == 0 || in_node == topo.gpus_per_node);
        }
    }

    fn all_kinds() -> [ScenarioKind; 4] {
        [
            ScenarioKind::Independent,
            ScenarioKind::Correlated,
            ScenarioKind::Straggler,
            ScenarioKind::Sdc,
        ]
    }

    /// Amplified config so short test horizons see plenty of each
    /// event kind.
    fn hot_config(kind: ScenarioKind) -> ScenarioConfig {
        let mut cfg = ScenarioConfig::new(kind);
        cfg.correlated = cfg.correlated.scaled(2_000.0);
        cfg.straggler = cfg.straggler.scaled(200.0);
        cfg.sdc = cfg.sdc.scaled(2_000.0);
        cfg
    }

    #[test]
    fn every_generator_satisfies_the_event_contract() {
        let topo = Topology::of(512, 16, 4);
        let model = FailureModel::llama3().scaled(30.0);
        let horizon = 24.0 * 10.0;
        for kind in all_kinds() {
            let mut rng = Rng::new(0xC0FFEE);
            let trace = generate_scenario(&topo, &model, &hot_config(kind), horizon, &mut rng);
            assert!(!trace.events.is_empty(), "{kind:?} produced no events");
            for w in trace.events.windows(2) {
                assert!(w[0].at_hours <= w[1].at_hours, "{kind:?} unsorted");
            }
            for ev in &trace.events {
                assert!(ev.at_hours >= 0.0 && ev.at_hours < horizon, "{kind:?} out of horizon");
                assert!(ev.recover_at_hours > ev.at_hours, "{kind:?} non-positive outage");
                assert!(ev.gpu < topo.n_gpus);
            }
        }
    }

    #[test]
    fn correlated_traces_contain_whole_domain_blasts() {
        let topo = Topology::of(512, 16, 4);
        // silence the base process so only correlated events remain
        let model = FailureModel::llama3().scaled(1e-9);
        let mut cfg = ScenarioConfig::new(ScenarioKind::Correlated);
        cfg.correlated = cfg.correlated.scaled(3_000.0);
        let mut rng = Rng::new(8);
        let trace = generate_scenario(&topo, &model, &cfg, 24.0 * 10.0, &mut rng);
        assert!(!trace.events.is_empty());
        // every correlated event group fails a whole node or domain at
        // one shared instant — visible under a Single-GPU replay
        let mut saw_domain_blast = false;
        let mut i = 0;
        while i < trace.events.len() {
            let t = trace.events[i].at_hours;
            let mut j = i;
            while j < trace.events.len() && trace.events[j].at_hours == t {
                j += 1;
            }
            let group = j - i;
            assert!(
                group == topo.gpus_per_node || group == topo.domain_size,
                "correlated group of {group} GPUs at t={t}"
            );
            if group == topo.domain_size {
                saw_domain_blast = true;
                let fleet = trace.replay_to(&topo, BlastRadius::Single, t);
                let d = topo.domain_of(trace.events[i].gpu);
                assert_eq!(fleet.domain_healthy(d), 0, "domain {d} not fully down at t={t}");
            }
            i = j;
        }
        assert!(saw_domain_blast, "no domain-level blast in the trace");
    }

    #[test]
    fn straggler_generator_degrades_but_does_not_kill() {
        let topo = Topology::of(512, 16, 4);
        let model = FailureModel::llama3().scaled(1e-9);
        let cfg = hot_config(ScenarioKind::Straggler);
        let mut rng = Rng::new(5);
        let horizon = 24.0 * 10.0;
        let trace = generate_scenario(&topo, &model, &cfg, horizon, &mut rng);
        assert!(!trace.events.is_empty());
        let (lo, hi) = cfg.straggler.slowdown;
        for ev in &trace.events {
            match ev.kind {
                EventKind::Degrade { slowdown } => {
                    assert!((lo..hi).contains(&slowdown), "slowdown {slowdown}");
                }
                other => panic!("unexpected event kind {other:?} under a silent base process"),
            }
        }
        // degraded GPUs stay alive: replay shows degradation, no deaths
        let mut degraded_seen = 0;
        for step in 0..100 {
            let fleet = trace.replay_to(&topo, BlastRadius::Single, horizon * step as f64 / 100.0);
            assert_eq!(fleet.n_failed(), 0);
            degraded_seen += fleet.n_degraded();
            fleet.check_invariants().unwrap();
        }
        assert!(degraded_seen > 0, "no degradation ever observed");
    }

    #[test]
    fn sdc_detection_aligns_with_validation_sweeps() {
        let topo = Topology::of(512, 16, 4);
        let model = FailureModel::llama3().scaled(1e-9);
        let cfg = hot_config(ScenarioKind::Sdc);
        let v = cfg.sdc.validation_interval_hours;
        let mut rng = Rng::new(13);
        let trace = generate_scenario(&topo, &model, &cfg, 24.0 * 10.0, &mut rng);
        assert!(!trace.events.is_empty());
        for ev in &trace.events {
            let EventKind::Sdc { corrupt_at_hours } = ev.kind else {
                panic!("unexpected event kind {:?} under a silent base process", ev.kind);
            };
            // detected at the first sweep strictly after the corruption
            assert!(ev.at_hours > corrupt_at_hours);
            assert!(ev.at_hours - corrupt_at_hours <= v + 1e-9);
            let sweeps = ev.at_hours / v;
            assert!((sweeps - sweeps.round()).abs() < 1e-9, "off-sweep detection at {}", ev.at_hours);
        }
    }
}
