//! Failure rate model.
//!
//! Calibrated from the Llama-3 training report (§2.3 / Fig. 4 of the
//! paper): ~466 job interruptions over 54 days on a 16,384-GPU cluster,
//! 78% attributed to hardware. Hardware failures need a part swap
//! (3–5 days, the paper notes this may be optimistic); software failures
//! recover in ~3 hours. The paper's 3× sensitivity case models observed
//! rate spikes ([15]: 7× variation in a 16K-A100 fleet).

use crate::util::prng::Rng;

/// Per-GPU failure process parameters.
#[derive(Clone, Debug)]
pub struct FailureModel {
    /// Failures per GPU-day (both kinds combined).
    pub failures_per_gpu_day: f64,
    /// Fraction of failures that are hardware (paper: 0.78).
    pub hw_fraction: f64,
    /// Hardware recovery time range, hours (paper: 3–5 days).
    pub hw_recovery_hours: (f64, f64),
    /// Software recovery time, hours (paper: 3 h).
    pub sw_recovery_hours: f64,
}

impl FailureModel {
    /// Llama-3-report calibration: 466 interruptions / 54 days / 16,384
    /// GPUs ≈ 5.3e-4 failures per GPU-day.
    pub fn llama3() -> FailureModel {
        FailureModel {
            failures_per_gpu_day: 466.0 / (54.0 * 16_384.0),
            hw_fraction: 0.78,
            hw_recovery_hours: (3.0 * 24.0, 5.0 * 24.0),
            sw_recovery_hours: 3.0,
        }
    }

    /// The paper's "3× the Llama-3 rate" sensitivity case.
    pub fn llama3_3x() -> FailureModel {
        let mut m = FailureModel::llama3();
        m.failures_per_gpu_day *= 3.0;
        m
    }

    /// Scale the base rate (for sweeps).
    pub fn scaled(&self, factor: f64) -> FailureModel {
        let mut m = self.clone();
        m.failures_per_gpu_day *= factor;
        m
    }

    /// Expected failures per hour across `n_gpus`.
    pub fn cluster_rate_per_hour(&self, n_gpus: usize) -> f64 {
        self.failures_per_gpu_day * n_gpus as f64 / 24.0
    }

    /// Draw a recovery duration (hours) for one failure event.
    pub fn draw_recovery_hours(&self, rng: &mut Rng) -> (bool, f64) {
        if rng.chance(self.hw_fraction) {
            let (lo, hi) = self.hw_recovery_hours;
            (true, rng.range_f64(lo, hi))
        } else {
            (false, self.sw_recovery_hours)
        }
    }

    /// Steady-state expected fraction of GPUs concurrently failed
    /// (Little's law: rate × mean repair time).
    pub fn steady_state_failed_fraction(&self) -> f64 {
        let (lo, hi) = self.hw_recovery_hours;
        let mean_hours =
            self.hw_fraction * 0.5 * (lo + hi) + (1.0 - self.hw_fraction) * self.sw_recovery_hours;
        (self.failures_per_gpu_day / 24.0) * mean_hours
    }
}

/// Correlated-failure process parameters: Poisson superposition of
/// node-level (rack power / host) and domain-level (scale-up switch)
/// events layered over the per-GPU base process. Calibrated to the
/// ByteDance 100K-scale infrastructure report: correlated events are
/// one to two orders of magnitude rarer than single-GPU failures, but
/// each takes out 8–72 GPUs at once.
#[derive(Clone, Debug)]
pub struct CorrelatedRates {
    /// Whole-node (rack) events per node-day.
    pub node_events_per_node_day: f64,
    /// Whole-domain (scale-up switch) events per domain-day.
    pub domain_events_per_domain_day: f64,
    /// Recovery time range for correlated events, hours — a switch
    /// reboot or rack power cycle, not a multi-day part swap.
    pub recovery_hours: (f64, f64),
}

impl CorrelatedRates {
    /// ByteDance-report order of magnitude: a given rack sees an outage
    /// about every ~14 node-years, a scale-up switch about every
    /// ~55 domain-years; both recover in 0.5–4 hours.
    pub fn bytedance() -> CorrelatedRates {
        CorrelatedRates {
            node_events_per_node_day: 2.0e-4,
            domain_events_per_domain_day: 5.0e-5,
            recovery_hours: (0.5, 4.0),
        }
    }

    /// Scale both correlated rates (for sweeps).
    pub fn scaled(&self, factor: f64) -> CorrelatedRates {
        let mut r = self.clone();
        r.node_events_per_node_day *= factor;
        r.domain_events_per_domain_day *= factor;
        r
    }
}

/// Straggler (degraded-but-alive) process parameters: GPUs that keep
/// running but drag their TP group — thermal throttling, a flaky
/// NVLink lane, ECC retirement storms. The FailSafe paper motivates
/// these as the hard resilience case: they are invisible to liveness
/// checks yet slow the slowest-member-paced group.
#[derive(Clone, Debug)]
pub struct StragglerRates {
    /// Degradation onsets per GPU-day.
    pub events_per_gpu_day: f64,
    /// Uniform slowdown-factor range, each in `(0, 1]` (fraction of
    /// healthy speed the degraded GPU still delivers).
    pub slowdown: (f64, f64),
    /// Mean degradation duration, hours (exponential).
    pub mean_duration_hours: f64,
}

impl StragglerRates {
    /// ByteDance-report order of magnitude: straggler onsets are
    /// roughly half as frequent as hard failures, run at 30–90% of
    /// healthy speed, and persist ~6 hours until remediation.
    pub fn bytedance() -> StragglerRates {
        StragglerRates {
            events_per_gpu_day: 2.5e-4,
            slowdown: (0.3, 0.9),
            mean_duration_hours: 6.0,
        }
    }

    /// Scale the onset rate (for sweeps).
    pub fn scaled(&self, factor: f64) -> StragglerRates {
        let mut r = self.clone();
        r.events_per_gpu_day *= factor;
        r
    }
}

/// Silent-data-corruption process parameters: corruptions are invisible
/// until the next periodic validation sweep fires, so every detection
/// carries `detection lag + rollback to the last checkpoint` of wasted
/// work.
#[derive(Clone, Debug)]
pub struct SdcRates {
    /// Silent corruptions per GPU-day.
    pub events_per_gpu_day: f64,
    /// Period of the validation sweep that detects them, hours.
    pub validation_interval_hours: f64,
}

impl SdcRates {
    /// Fleet-scale SDC studies (Meta / Google: "one in a few thousand
    /// machines") put silent corruptions one to two orders below hard
    /// failures; validation sweeps every 6 hours.
    pub fn bytedance() -> SdcRates {
        SdcRates {
            events_per_gpu_day: 1.5e-5,
            validation_interval_hours: 6.0,
        }
    }

    /// Scale the corruption rate (for sweeps).
    pub fn scaled(&self, factor: f64) -> SdcRates {
        let mut r = self.clone();
        r.events_per_gpu_day *= factor;
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama3_rate_magnitude() {
        let m = FailureModel::llama3();
        assert!((5.0e-4..6.0e-4).contains(&m.failures_per_gpu_day));
        assert!((m.hw_fraction - 0.78).abs() < 1e-12);
    }

    #[test]
    fn steady_state_fraction_matches_paper_regime() {
        // Paper Fig. 4: with 3/5-day hw recovery the 16K cluster spends most
        // of its time above 0.1% failed; steady state should be ~0.1–0.4%.
        let f = FailureModel::llama3().steady_state_failed_fraction();
        assert!((0.001..0.004).contains(&f), "steady-state {f}");
        // 3x case roughly triples it.
        let f3 = FailureModel::llama3_3x().steady_state_failed_fraction();
        assert!((f3 / f - 3.0).abs() < 1e-9);
    }

    #[test]
    fn recovery_draws_in_range() {
        let m = FailureModel::llama3();
        let mut rng = Rng::new(1);
        let mut hw_seen = 0;
        for _ in 0..2000 {
            let (is_hw, hours) = m.draw_recovery_hours(&mut rng);
            if is_hw {
                hw_seen += 1;
                assert!((72.0..=120.0).contains(&hours));
            } else {
                assert_eq!(hours, 3.0);
            }
        }
        // ~78% hardware
        assert!((1450..1700).contains(&hw_seen), "hw {hw_seen}");
    }

    #[test]
    fn cluster_rate_scales_linearly() {
        let m = FailureModel::llama3();
        let r1 = m.cluster_rate_per_hour(16_384);
        let r2 = m.cluster_rate_per_hour(32_768);
        assert!((r2 / r1 - 2.0).abs() < 1e-12);
        // ~8.6 failures/day on the Llama-3 cluster.
        assert!((r1 * 24.0 - 8.63).abs() < 0.1);
    }

    #[test]
    fn scenario_rates_are_calibrated_sanely() {
        let c = CorrelatedRates::bytedance();
        // correlated events are rarer per blast anchor than per-GPU
        // failures, but never zero
        let per_gpu = FailureModel::llama3().failures_per_gpu_day;
        assert!(c.node_events_per_node_day > 0.0);
        assert!(c.node_events_per_node_day < per_gpu);
        assert!(c.domain_events_per_domain_day < c.node_events_per_node_day);
        assert!(c.recovery_hours.0 > 0.0 && c.recovery_hours.1 > c.recovery_hours.0);
        let c2 = c.scaled(2.0);
        assert!((c2.node_events_per_node_day / c.node_events_per_node_day - 2.0).abs() < 1e-12);

        let s = StragglerRates::bytedance();
        assert!(s.events_per_gpu_day > 0.0 && s.events_per_gpu_day < per_gpu);
        assert!(s.slowdown.0 > 0.0 && s.slowdown.1 <= 1.0 && s.slowdown.0 < s.slowdown.1);
        assert!(s.mean_duration_hours > 0.0);

        let d = SdcRates::bytedance();
        assert!(d.events_per_gpu_day > 0.0 && d.events_per_gpu_day < per_gpu / 10.0);
        assert!(d.validation_interval_hours > 0.0);
        assert!((d.scaled(3.0).events_per_gpu_day / d.events_per_gpu_day - 3.0).abs() < 1e-12);
    }
}
