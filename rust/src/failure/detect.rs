//! Imperfect failure detection: latency between a fault occurring and
//! the fleet manager *noticing* it, plus a false-positive rate for
//! straggler detectors.
//!
//! The scenario engine's traces record when faults physically happen;
//! every policy so far reacted at that instant (oracle detection). A
//! real fleet manager sees a `Fail` only after health checks time out
//! and a `Degrade` only after a profiling window flags the straggler —
//! ByteDance and FailSafe both report minutes-scale diagnosis lags that
//! govern delivered throughput as much as the fault rate itself.
//!
//! [`DelayedEvents`] is an [`EventSource`] adapter that shifts each
//! `Fail`/`Degrade` event's *reveal* time forward by the per-kind
//! detection latency (optionally jittered, deterministically, per
//! event), re-sorts the shifted stream with a reorder buffer, and
//! accounts the **undetected-stall bill**: while a fault is live but
//! undetected the job makes no useful progress — a dead rank wedges
//! every collective it participates in (and the DP allreduce then
//! gates the whole job), while a silent straggler drags every rank to
//! its speed — yet the policy layer still integrates the fleet as
//! healthy. The adapter therefore charges `stall_gpus ×
//! undetected-window` GPU-hours through the rollback/downtime channel,
//! weighted `1.0` for a `Fail` (the job is fully wedged) and
//! `1 − slowdown` for a `Degrade` (the job runs, gated at the
//! straggler's speed). Events that heal before detection are never
//! revealed at all (the policy never reconfigures) but still pay their
//! full outage as stall. This is what makes slower detection strictly
//! worse: the stall always costs at least as much work as the
//! reconfiguration the policy would have made had it known.
//!
//! `Sdc` events pass through unshifted: their detection lag is already
//! modeled explicitly by the validation-sweep machinery
//! ([`EventKind::Sdc`] carries `corrupt_at_hours`).
//!
//! False positives are billed in expectation, not sampled: a detector
//! with false-positive rate `r` per GPU-day fires `r × n_gpus ×
//! horizon/24` spurious evictions over the horizon, and each policy
//! prices one spurious eviction via
//! [`crate::policy::FtPolicy::false_positive_cost`] (evict-and-readmit
//! reshard for `straggler-evict` / `elastic-dp`, free for policies that
//! never evict on a degrade signal). Expected-value billing keeps the
//! trace — and therefore every response memo and bit-identity contract
//! — untouched by the false-positive knob.

use std::cmp::Ordering;
use std::collections::hash_map::DefaultHasher;
use std::collections::BinaryHeap;
use std::hash::{Hash, Hasher};

use super::replayer::EventSource;
use super::trace::{EventKind, FailureEvent, Trace};
use super::TraceCursor;

/// Detection-quality model: per-kind mean latencies, deterministic
/// per-event jitter, and the straggler detector's false-positive rate.
///
/// The all-zero model is **instant detection** — sims normalize it away
/// ([`DetectionModel::active`]) so the zero configuration runs today's
/// exact code path bit-for-bit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DetectionModel {
    /// Mean latency from a hard failure to its detection, hours
    /// (health-check timeout + diagnosis).
    pub fail_latency_hours: f64,
    /// Mean latency from straggler onset to its detection, hours
    /// (profiling-window flagging).
    pub degrade_latency_hours: f64,
    /// Spurious straggler detections per GPU per day, billed in
    /// expectation via [`crate::policy::FtPolicy::false_positive_cost`].
    pub false_positives_per_gpu_day: f64,
    /// Relative spread of the per-event latency around its mean: each
    /// event's latency is `mean × (1 + jitter_frac × (u − 0.5))` with
    /// `u ∈ [0, 1)` hashed deterministically from `(gpu, at_hours)`.
    /// `0` = every event at the mean; values in `[0, 2]` keep latencies
    /// non-negative (clamped regardless).
    pub jitter_frac: f64,
}

impl DetectionModel {
    /// Instant, perfect detection — the pre-detection semantics.
    pub fn instant() -> DetectionModel {
        DetectionModel {
            fail_latency_hours: 0.0,
            degrade_latency_hours: 0.0,
            false_positives_per_gpu_day: 0.0,
            jitter_frac: 0.0,
        }
    }

    /// True when the model is indistinguishable from no model at all:
    /// zero latency for every kind and zero false positives.
    pub fn is_instant(&self) -> bool {
        self.fail_latency_hours == 0.0
            && self.degrade_latency_hours == 0.0
            && self.false_positives_per_gpu_day == 0.0
    }

    /// Normalize an optional model: `Some(instant)` behaves — and must
    /// stay, bit-for-bit — identical to `None`, so every sim entry
    /// point filters through this before branching onto the adapter
    /// path.
    pub fn active(model: &Option<DetectionModel>) -> Option<&DetectionModel> {
        model.as_ref().filter(|d| !d.is_instant())
    }

    /// Memo-key fingerprint: nonzero for any active model, `0` reserved
    /// for instant/no detection (mirrors the transition-cost
    /// fingerprint convention in `manager::sweep`).
    pub fn fingerprint(model: &Option<DetectionModel>) -> u64 {
        match Self::active(model) {
            None => 0,
            Some(d) => {
                let mut h = DefaultHasher::new();
                for v in [
                    d.fail_latency_hours,
                    d.degrade_latency_hours,
                    d.false_positives_per_gpu_day,
                    d.jitter_frac,
                ] {
                    v.to_bits().hash(&mut h);
                }
                h.finish().max(1)
            }
        }
    }

    /// Detection latency of one event, hours. `Sdc` is always `0` (its
    /// lag is the validation sweep's job); `Fail`/`Degrade` take their
    /// kind's mean, jittered deterministically per `(gpu, at_hours)`.
    pub fn latency_hours(&self, ev: &FailureEvent) -> f64 {
        let base = match ev.kind {
            EventKind::Fail => self.fail_latency_hours,
            EventKind::Degrade { .. } => self.degrade_latency_hours,
            EventKind::Sdc { .. } => return 0.0,
        };
        if base <= 0.0 {
            return 0.0;
        }
        if self.jitter_frac == 0.0 {
            return base;
        }
        let u = hash_unit(ev.gpu, ev.at_hours);
        (base * (1.0 + self.jitter_frac * (u - 0.5))).max(0.0)
    }

    /// Expected spurious straggler detections over the horizon.
    pub fn false_positive_events(&self, n_gpus: usize, horizon_hours: f64) -> f64 {
        self.false_positives_per_gpu_day * n_gpus as f64 * horizon_hours / 24.0
    }

    /// Materialize the detection-shifted view of a trace: the events a
    /// manager with this model actually *sees* (reveal-time-sorted,
    /// healed-before-detected events elided), plus the undetected-stall
    /// bill in GPU-hours (`stall_gpus` is the job size the wedge
    /// gates, see [`DelayedEvents`]). Defined as — and bit-identical
    /// to — draining a [`DelayedEvents`] over the trace's cursor, so
    /// the materialized and streaming detection paths cannot drift
    /// apart.
    pub fn delay_trace(&self, trace: &Trace, stall_gpus: usize) -> (Trace, f64) {
        let mut delayed = DelayedEvents::new(TraceCursor::new(trace), *self, stall_gpus);
        let mut events = Vec::new();
        while let Some(ev) = delayed.next_event() {
            events.push(ev);
        }
        let trace = Trace { horizon_hours: trace.horizon_hours, events };
        (trace, delayed.stall_gpu_hours())
    }
}

/// Deterministic `[0, 1)` hash of an event's identity (splitmix64 over
/// `(gpu, at_hours)`) — per-event latency jitter without any PRNG
/// state, so replays, resets and thread fan-outs all see identical
/// latencies.
fn hash_unit(gpu: usize, at_hours: f64) -> f64 {
    let mut z = (gpu as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ at_hours.to_bits();
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

/// Heap entry: an already-shifted event keyed by its reveal time, with
/// an intake sequence number so equal reveal times keep source order
/// (BinaryHeap is not stable on its own).
struct Delayed {
    reveal: f64,
    seq: u64,
    ev: FailureEvent,
}

impl PartialEq for Delayed {
    fn eq(&self, other: &Delayed) -> bool {
        self.reveal.total_cmp(&other.reveal) == Ordering::Equal && self.seq == other.seq
    }
}
impl Eq for Delayed {}
impl PartialOrd for Delayed {
    fn partial_cmp(&self, other: &Delayed) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Delayed {
    fn cmp(&self, other: &Delayed) -> Ordering {
        // Reversed: BinaryHeap is a max-heap and we pop earliest first.
        other
            .reveal
            .total_cmp(&self.reveal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// [`EventSource`] adapter that reveals its inner source's events only
/// after the [`DetectionModel`]'s per-kind latency has elapsed.
///
/// Invariants:
/// * output is non-decreasing in `at_hours` (reorder buffer: a shifted
///   event is emitted only once no unconsumed source event could still
///   produce an earlier reveal);
/// * an event whose reveal would land at/after its own recovery or the
///   horizon is dropped — the manager never saw it — but its full
///   outage is charged as undetected stall;
/// * `Sdc` events pass through untouched;
/// * [`DelayedEvents::stall_gpu_hours`] is complete once `next_event`
///   has returned `None` (i.e. after `ReplayCore::drain_source`).
pub struct DelayedEvents<S: EventSource> {
    source: S,
    model: DetectionModel,
    /// GPUs an undetected fault gates — the whole job for a hard
    /// failure (a dead rank hangs every collective and the DP
    /// allreduce propagates the wedge), attenuated by the straggler's
    /// residual speed for a `Degrade`. Callers pass the fleet's GPU
    /// count.
    stall_gpus: usize,
    /// One-event lookahead into the source (its `at_hours` lower-bounds
    /// every future reveal, which is what licenses emitting the heap
    /// front).
    pending_src: Option<FailureEvent>,
    source_done: bool,
    heap: BinaryHeap<Delayed>,
    seq: u64,
    stall_gpu_hours: f64,
}

impl<S: EventSource> DelayedEvents<S> {
    pub fn new(source: S, model: DetectionModel, stall_gpus: usize) -> DelayedEvents<S> {
        DelayedEvents {
            source,
            model,
            stall_gpus,
            pending_src: None,
            source_done: false,
            heap: BinaryHeap::new(),
            seq: 0,
            stall_gpu_hours: 0.0,
        }
    }

    /// Undetected-stall bill accumulated so far, GPU-hours. Complete
    /// only after the source is exhausted.
    pub fn stall_gpu_hours(&self) -> f64 {
        self.stall_gpu_hours
    }

    /// Shift one source event, account its stall, and (unless it healed
    /// or fell past the horizon before detection) buffer it for
    /// reveal-ordered emission.
    fn intake(&mut self, ev: FailureEvent) {
        let latency = self.model.latency_hours(&ev);
        let reveal = ev.at_hours + latency;
        if latency > 0.0 {
            // Fully wedged for a hard failure; gated at the straggler's
            // residual speed for a degrade. (`Sdc` never reaches here:
            // its latency is always 0.)
            let weight = match ev.kind {
                EventKind::Fail => 1.0,
                EventKind::Degrade { slowdown } => 1.0 - slowdown,
                EventKind::Sdc { .. } => 0.0,
            };
            let stall_end = reveal.min(ev.recover_at_hours).min(self.source.horizon_hours());
            if stall_end > ev.at_hours && weight > 0.0 {
                self.stall_gpu_hours +=
                    weight * self.stall_gpus as f64 * (stall_end - ev.at_hours);
            }
            if reveal >= ev.recover_at_hours || reveal >= self.source.horizon_hours() {
                return; // healed (or horizon passed) before anyone noticed
            }
        }
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Delayed {
            reveal,
            seq,
            ev: FailureEvent { at_hours: reveal, ..ev },
        });
    }
}

impl<S: EventSource> EventSource for DelayedEvents<S> {
    fn horizon_hours(&self) -> f64 {
        self.source.horizon_hours()
    }

    fn next_event(&mut self) -> Option<FailureEvent> {
        loop {
            if self.pending_src.is_none() && !self.source_done {
                self.pending_src = self.source.next_event();
                self.source_done = self.pending_src.is_none();
            }
            let front_reveal = self.heap.peek().map(|d| d.reveal);
            match (front_reveal, &self.pending_src) {
                // The buffered front cannot be preempted: every source
                // event still unseen arrives at ≥ the lookahead's
                // `at_hours`, and reveals never precede arrivals.
                (Some(reveal), Some(src)) if reveal <= src.at_hours => {
                    return self.heap.pop().map(|d| d.ev);
                }
                (_, Some(_)) => {
                    let ev = self.pending_src.take().expect("lookahead present");
                    self.intake(ev);
                }
                (Some(_), None) => return self.heap.pop().map(|d| d.ev),
                (None, None) => return None,
            }
        }
    }

    fn detect_stall_gpu_hours(&self) -> f64 {
        self.stall_gpu_hours
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: f64, gpu: usize, recover: f64, kind: EventKind) -> FailureEvent {
        FailureEvent { at_hours: at, gpu, is_hw: true, recover_at_hours: recover, kind }
    }

    fn drain<S: EventSource>(mut s: DelayedEvents<S>) -> (Vec<FailureEvent>, f64) {
        let mut out = Vec::new();
        while let Some(e) = s.next_event() {
            out.push(e);
        }
        let stall = s.stall_gpu_hours();
        (out, stall)
    }

    #[test]
    fn instant_model_is_a_bitwise_passthrough() {
        let trace = Trace {
            horizon_hours: 100.0,
            events: vec![
                ev(1.0, 3, 10.0, EventKind::Fail),
                ev(2.0, 7, 4.0, EventKind::Degrade { slowdown: 0.5 }),
                ev(5.0, 1, 9.0, EventKind::Sdc { corrupt_at_hours: 3.0 }),
            ],
        };
        let model = DetectionModel::instant();
        assert!(model.is_instant());
        assert_eq!(DetectionModel::fingerprint(&Some(model)), 0);
        assert_eq!(DetectionModel::fingerprint(&None), 0);
        let (out, stall) =
            drain(DelayedEvents::new(TraceCursor::new(&trace), model, 32));
        assert_eq!(stall, 0.0);
        assert_eq!(out.len(), trace.events.len());
        for (a, b) in out.iter().zip(&trace.events) {
            assert_eq!(a.at_hours.to_bits(), b.at_hours.to_bits());
            assert_eq!(a.gpu, b.gpu);
            assert_eq!(a.recover_at_hours.to_bits(), b.recover_at_hours.to_bits());
        }
    }

    #[test]
    fn latency_shifts_and_reorders_against_sdc() {
        // A fail at t=1 with 2h latency reveals at t=3; an SDC at t=2
        // passes through unshifted and must be emitted FIRST.
        let trace = Trace {
            horizon_hours: 100.0,
            events: vec![
                ev(1.0, 0, 50.0, EventKind::Fail),
                ev(2.0, 1, 50.0, EventKind::Sdc { corrupt_at_hours: 1.5 }),
            ],
        };
        let model = DetectionModel {
            fail_latency_hours: 2.0,
            ..DetectionModel::instant()
        };
        assert!(!model.is_instant());
        assert_ne!(DetectionModel::fingerprint(&Some(model)), 0);
        let (out, stall) =
            drain(DelayedEvents::new(TraceCursor::new(&trace), model, 4));
        assert_eq!(out.len(), 2);
        assert!(matches!(out[0].kind, EventKind::Sdc { .. }));
        assert_eq!(out[0].at_hours, 2.0);
        assert!(matches!(out[1].kind, EventKind::Fail));
        assert_eq!(out[1].at_hours, 3.0);
        assert_eq!(out[1].recover_at_hours, 50.0);
        // Undetected window: [1, 3) × 4 wedged GPUs (Fail gates the
        // whole job at weight 1) = 8 GPU-hours.
        assert_eq!(stall, 8.0);
        // Output stays sorted.
        assert!(out.windows(2).all(|w| w[0].at_hours <= w[1].at_hours));
    }

    #[test]
    fn healed_before_detection_is_dropped_but_billed() {
        // Degrade heals at t=2, detection would land at t=4: the
        // manager never sees it; the whole outage is stall.
        let trace = Trace {
            horizon_hours: 100.0,
            events: vec![ev(1.0, 5, 2.0, EventKind::Degrade { slowdown: 0.7 })],
        };
        let model = DetectionModel {
            degrade_latency_hours: 3.0,
            ..DetectionModel::instant()
        };
        let (out, stall) =
            drain(DelayedEvents::new(TraceCursor::new(&trace), model, 8));
        assert!(out.is_empty());
        // [1, 2) × 8 GPUs × the straggler's (1 − 0.7) drag.
        assert_eq!(stall, (1.0 - 0.7) * 8.0);
    }

    #[test]
    fn delay_trace_matches_streaming_adapter() {
        let trace = Trace {
            horizon_hours: 48.0,
            events: vec![
                ev(0.5, 2, 30.0, EventKind::Fail),
                ev(1.0, 9, 1.2, EventKind::Fail),
                ev(6.0, 4, 20.0, EventKind::Degrade { slowdown: 0.4 }),
                ev(40.0, 7, 80.0, EventKind::Fail),
            ],
        };
        let model = DetectionModel {
            fail_latency_hours: 0.5,
            degrade_latency_hours: 1.5,
            false_positives_per_gpu_day: 0.01,
            jitter_frac: 1.0,
        };
        let (materialized, stall_m) = model.delay_trace(&trace, 16);
        let (streamed, stall_s) =
            drain(DelayedEvents::new(TraceCursor::new(&trace), model, 16));
        assert_eq!(stall_m.to_bits(), stall_s.to_bits());
        assert_eq!(materialized.events.len(), streamed.len());
        for (a, b) in materialized.events.iter().zip(&streamed) {
            assert_eq!(a.at_hours.to_bits(), b.at_hours.to_bits());
            assert_eq!(a.gpu, b.gpu);
        }
        // The second fail (heals at 1.2, reveal ≥ 1.2 only if its
        // jittered latency ≥ 0.2 — either way the survivors are sorted
        // and in-horizon).
        assert!(materialized
            .events
            .windows(2)
            .all(|w| w[0].at_hours <= w[1].at_hours));
        assert!(materialized
            .events
            .iter()
            .all(|e| e.at_hours < trace.horizon_hours
                && e.at_hours < e.recover_at_hours));
        // Jitter is deterministic: a second pass is bit-identical.
        let (again, stall_again) = model.delay_trace(&trace, 16);
        assert_eq!(stall_again.to_bits(), stall_m.to_bits());
        assert_eq!(again.events.len(), materialized.events.len());
    }

    #[test]
    fn false_positive_expectation_scales_with_fleet_and_horizon() {
        let model = DetectionModel {
            false_positives_per_gpu_day: 0.5,
            ..DetectionModel::instant()
        };
        assert_eq!(model.false_positive_events(100, 48.0), 100.0);
        assert!(!model.is_instant());
        assert_eq!(DetectionModel::instant().false_positive_events(100, 48.0), 0.0);
    }
}
