//! Failure engine: rate models calibrated to the Llama-3 training report,
//! blast-radius expansion, synthetic failure traces (Fig. 4),
//! Monte-Carlo failure-placement scenarios (Figs. 3, 6, 10), and the
//! scenario-diversity trace generators (correlated rack/switch blasts,
//! degraded-but-alive stragglers, silent data corruption).

pub mod blast;
pub mod detect;
pub mod rates;
pub mod replayer;
pub mod scenario;
pub mod stream;
pub mod trace;

pub use blast::BlastRadius;
pub use detect::{DelayedEvents, DetectionModel};
pub use rates::{CorrelatedRates, FailureModel, SdcRates, StragglerRates};
pub use replayer::{EventSource, FleetReplayer, ReplayCore, TraceCursor};
pub use scenario::{generate_scenario, sample_failed_gpus, Scenario, ScenarioConfig, ScenarioKind};
pub use stream::{TraceStream, TrialGen};
pub use trace::{EventKind, FailureEvent, Trace};
