//! Failure engine: rate models calibrated to the Llama-3 training report,
//! blast-radius expansion, synthetic failure traces (Fig. 4) and
//! Monte-Carlo failure-placement scenarios (Figs. 3, 6, 10).

pub mod blast;
pub mod rates;
pub mod replayer;
pub mod scenario;
pub mod trace;

pub use blast::BlastRadius;
pub use rates::FailureModel;
pub use replayer::FleetReplayer;
pub use scenario::{sample_failed_gpus, Scenario};
pub use trace::{FailureEvent, Trace};
