//! Synthetic failure traces (paper Fig. 4): Poisson failure arrivals at
//! the calibrated per-GPU rate, hw/sw recovery mix, replayed against a
//! [`FleetHealth`] to produce the concurrently-failed time series.

use super::blast::BlastRadius;
use super::rates::FailureModel;
use super::replayer::FleetReplayer;
use crate::cluster::{FleetHealth, Topology};
use crate::util::prng::Rng;

/// What a trace event does to the GPUs in its blast radius.
///
/// All kinds share the same timestamped contract the exact integrator
/// relies on: the effect starts at `at_hours` and ends at
/// `recover_at_hours`, both event boundaries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    /// Hard failure: the GPU is gone until `recover_at_hours`.
    Fail,
    /// Degraded-but-alive (straggler): the GPU keeps running at
    /// `slowdown` × healthy speed (in `(0, 1]`) until it recovers.
    Degrade { slowdown: f64 },
    /// Silent data corruption: the GPU corrupted state at
    /// `corrupt_at_hours` but the event is invisible until a validation
    /// sweep fires at `at_hours` — from then on it behaves like a hard
    /// failure, and the detection lag (`at_hours - corrupt_at_hours`)
    /// is charged as rollback through the transition-cost machinery.
    Sdc { corrupt_at_hours: f64 },
}

/// One failure event in a trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureEvent {
    pub at_hours: f64,
    pub gpu: usize,
    pub is_hw: bool,
    pub recover_at_hours: f64,
    pub kind: EventKind,
}

/// A generated failure trace over a time horizon.
#[derive(Clone, Debug)]
pub struct Trace {
    pub horizon_hours: f64,
    pub events: Vec<FailureEvent>,
}

impl Trace {
    /// Generate a trace: cluster-wide Poisson process with per-event
    /// uniform GPU choice (paper assumption: failures i.i.d. across GPUs).
    pub fn generate(
        topo: &Topology,
        model: &FailureModel,
        horizon_hours: f64,
        rng: &mut Rng,
    ) -> Trace {
        let rate = model.cluster_rate_per_hour(topo.n_gpus);
        let mut events = Vec::new();
        let mut t = 0.0;
        loop {
            t += rng.exponential(rate);
            if t >= horizon_hours {
                break;
            }
            let gpu = rng.index(topo.n_gpus);
            let (is_hw, rec) = model.draw_recovery_hours(rng);
            events.push(FailureEvent {
                at_hours: t,
                gpu,
                is_hw,
                recover_at_hours: t + rec,
                kind: EventKind::Fail,
            });
        }
        Trace { horizon_hours, events }
    }

    /// Sample the number of concurrently-failed GPUs at `step_hours`
    /// granularity, applying `blast` expansion. Returns `(t, failed)`
    /// pairs. A GPU hit by overlapping events stays failed until the
    /// latest recovery.
    ///
    /// Implemented as one incremental [`FleetReplayer`] sweep —
    /// O(events × blast × log events) total instead of re-deriving the
    /// fleet state per sample.
    pub fn failed_series(
        &self,
        topo: &Topology,
        blast: BlastRadius,
        step_hours: f64,
    ) -> Vec<(f64, usize)> {
        let mut rep = FleetReplayer::new(self, topo, blast);
        let n_steps = (self.horizon_hours / step_hours).ceil() as usize;
        let mut out = Vec::with_capacity(n_steps + 1);
        for step in 0..=n_steps {
            let t = step as f64 * step_hours;
            out.push((t, rep.advance(t).n_failed()));
        }
        out
    }

    /// Exact step-function variant of [`Trace::failed_series`]: the
    /// concurrently-failed count is piecewise constant, so instead of
    /// sampling on a grid this returns its breakpoints — `(t, failed)`
    /// at `t = 0` and at every event boundary (< horizon) where the
    /// count actually changes. The count holds from each breakpoint
    /// until the next (or the horizon), which makes time integrals over
    /// the series exact rather than grid-quantized (the Fig. 4 bench's
    /// exact mode).
    pub fn failed_series_exact(&self, topo: &Topology, blast: BlastRadius) -> Vec<(f64, usize)> {
        let mut rep = FleetReplayer::new(self, topo, blast);
        let mut out = vec![(0.0, rep.advance(0.0).n_failed())];
        while let Some(t) = rep.next_change_hours() {
            if t >= self.horizon_hours {
                break; // boundaries are non-decreasing; the rest is out of range
            }
            let failed = rep.advance(t).n_failed();
            if failed != out.last().unwrap().1 {
                out.push((t, failed));
            }
        }
        out
    }

    /// Exact (breakpoint-integrated) counterpart of
    /// [`Trace::time_above_fraction`]: fraction of `[0, horizon]` with
    /// failed fraction strictly above `thresh`, free of step-size bias.
    pub fn time_above_fraction_exact(
        &self,
        topo: &Topology,
        blast: BlastRadius,
        thresh: f64,
    ) -> f64 {
        let series = self.failed_series_exact(topo, blast);
        let mut above = 0.0;
        for (i, &(t, failed)) in series.iter().enumerate() {
            let end = series.get(i + 1).map_or(self.horizon_hours, |&(t2, _)| t2);
            if failed as f64 / topo.n_gpus as f64 > thresh {
                above += end - t;
            }
        }
        above / self.horizon_hours
    }

    /// Replay the trace into a fresh `FleetHealth` up to `now_hours`.
    ///
    /// O(events) *per call* — use [`FleetReplayer`] when sampling a trace
    /// over a time grid. Kept as the straight-line reference
    /// implementation the replayer's equivalence tests check against.
    pub fn replay_to(
        &self,
        topo: &Topology,
        blast: BlastRadius,
        now_hours: f64,
    ) -> FleetHealth {
        let mut fleet = FleetHealth::new(topo.clone());
        for ev in &self.events {
            if ev.at_hours > now_hours {
                break;
            }
            if ev.recover_at_hours > now_hours {
                match ev.kind {
                    EventKind::Degrade { slowdown } => {
                        for g in blast.affected(topo, ev.gpu) {
                            fleet.degrade(g, slowdown, ev.at_hours, ev.recover_at_hours);
                        }
                    }
                    // An SDC behaves like a hard failure from its
                    // detection boundary on (which is `at_hours`).
                    EventKind::Fail | EventKind::Sdc { .. } => {
                        for g in blast.affected(topo, ev.gpu) {
                            fleet.fail(g, ev.at_hours, ev.recover_at_hours);
                        }
                    }
                }
            }
        }
        fleet
    }

    /// Generate a trace with *time-varying* rate spikes ([Kokolis et al.]
    /// observed 7x rate variation in a 16K-A100 fleet). Implemented by
    /// thinning a Poisson process at `peak = spike_factor x base`:
    /// during spike windows (each `spike_hours` long, starting at rate
    /// `spikes_per_week`) all arrivals are kept, otherwise only
    /// `1/spike_factor` of them.
    pub fn generate_with_spikes(
        topo: &Topology,
        model: &FailureModel,
        horizon_hours: f64,
        spike_factor: f64,
        spikes_per_week: f64,
        spike_hours: f64,
        rng: &mut Rng,
    ) -> Trace {
        assert!(spike_factor >= 1.0);
        // sample spike windows
        let mut windows: Vec<(f64, f64)> = Vec::new();
        let spike_rate = spikes_per_week / (7.0 * 24.0);
        let mut t = 0.0;
        loop {
            t += rng.exponential(spike_rate.max(1e-12));
            if t >= horizon_hours {
                break;
            }
            windows.push((t, t + spike_hours));
        }
        let in_spike = |t: f64| windows.iter().any(|&(a, b)| t >= a && t < b);

        let peak = model.cluster_rate_per_hour(topo.n_gpus) * spike_factor;
        let mut events = Vec::new();
        let mut t = 0.0;
        loop {
            t += rng.exponential(peak);
            if t >= horizon_hours {
                break;
            }
            if !in_spike(t) && !rng.chance(1.0 / spike_factor) {
                continue; // thinned to the base rate
            }
            let gpu = rng.index(topo.n_gpus);
            let (is_hw, rec) = model.draw_recovery_hours(rng);
            events.push(FailureEvent {
                at_hours: t,
                gpu,
                is_hw,
                recover_at_hours: t + rec,
                kind: EventKind::Fail,
            });
        }
        Trace { horizon_hours, events }
    }

    /// Fraction of sampled time with failed fraction strictly above
    /// `thresh`. Rides the same single-sweep replayer as
    /// [`Trace::failed_series`].
    pub fn time_above_fraction(
        &self,
        topo: &Topology,
        blast: BlastRadius,
        step_hours: f64,
        thresh: f64,
    ) -> f64 {
        let series = self.failed_series(topo, blast, step_hours);
        let above = series
            .iter()
            .filter(|&&(_, failed)| failed as f64 / topo.n_gpus as f64 > thresh)
            .count();
        above as f64 / series.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_topo() -> Topology {
        Topology::of(1024, 8, 4)
    }

    #[test]
    fn event_count_matches_rate() {
        let topo = small_topo();
        let model = FailureModel {
            failures_per_gpu_day: 0.01,
            hw_fraction: 0.5,
            hw_recovery_hours: (10.0, 20.0),
            sw_recovery_hours: 1.0,
        };
        let mut rng = Rng::new(7);
        let horizon = 24.0 * 100.0;
        let trace = Trace::generate(&topo, &model, horizon, &mut rng);
        let expected = model.cluster_rate_per_hour(topo.n_gpus) * horizon;
        let got = trace.events.len() as f64;
        assert!((got / expected - 1.0).abs() < 0.1, "got {got} expected {expected}");
        // events sorted in time, within horizon
        for w in trace.events.windows(2) {
            assert!(w[0].at_hours <= w[1].at_hours);
        }
        assert!(trace.events.iter().all(|e| e.at_hours < horizon));
    }

    #[test]
    fn series_counts_match_replay() {
        let topo = small_topo();
        let model = FailureModel::llama3().scaled(50.0);
        let mut rng = Rng::new(3);
        let trace = Trace::generate(&topo, &model, 24.0 * 15.0, &mut rng);
        let series = trace.failed_series(&topo, BlastRadius::Single, 6.0);
        for &(t, failed) in series.iter().step_by(10) {
            let fleet = trace.replay_to(&topo, BlastRadius::Single, t);
            assert_eq!(fleet.n_failed(), failed, "mismatch at t={t}");
            fleet.check_invariants().unwrap();
        }
    }

    #[test]
    fn exact_series_matches_replay_at_and_between_breakpoints() {
        let topo = small_topo();
        let model = FailureModel::llama3().scaled(50.0);
        let mut rng = Rng::new(13);
        let trace = Trace::generate(&topo, &model, 24.0 * 10.0, &mut rng);
        let series = trace.failed_series_exact(&topo, BlastRadius::Node);
        assert!(series.len() > 2);
        // strictly increasing times, count changes at every breakpoint
        for w in series.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert_ne!(w[0].1, w[1].1);
        }
        for (i, &(t, failed)) in series.iter().enumerate() {
            assert_eq!(trace.replay_to(&topo, BlastRadius::Node, t).n_failed(), failed);
            // the count holds on the whole segment
            let end = series.get(i + 1).map_or(trace.horizon_hours, |&(t2, _)| t2);
            let mid = 0.5 * (t + end);
            assert_eq!(
                trace.replay_to(&topo, BlastRadius::Node, mid).n_failed(),
                failed,
                "segment [{t}, {end}) not constant"
            );
        }
    }

    #[test]
    fn exact_fraction_is_the_fine_grid_limit() {
        let topo = small_topo();
        let model = FailureModel::llama3().scaled(50.0);
        let mut rng = Rng::new(29);
        let trace = Trace::generate(&topo, &model, 24.0 * 10.0, &mut rng);
        let exact = trace.time_above_fraction_exact(&topo, BlastRadius::Single, 0.001);
        let coarse = trace.time_above_fraction(&topo, BlastRadius::Single, 4.0, 0.001);
        let fine = trace.time_above_fraction(&topo, BlastRadius::Single, 0.01, 0.001);
        assert!((exact - fine).abs() < 0.01, "exact {exact} vs fine grid {fine}");
        assert!(
            (exact - fine).abs() <= (exact - coarse).abs() + 1e-9,
            "finer grid should not move away from exact ({exact} / {fine} / {coarse})"
        );
        assert!(exact > 0.0 && exact < 1.0);
    }

    #[test]
    fn blast_radius_scales_failed_counts() {
        let topo = small_topo();
        let model = FailureModel::llama3().scaled(20.0);
        let mut rng = Rng::new(11);
        let trace = Trace::generate(&topo, &model, 24.0 * 15.0, &mut rng);
        let single: usize =
            trace.failed_series(&topo, BlastRadius::Single, 12.0).iter().map(|x| x.1).sum();
        let node: usize =
            trace.failed_series(&topo, BlastRadius::Node, 12.0).iter().map(|x| x.1).sum();
        assert!(node > 2 * single, "node {node} vs single {single}");
    }

    #[test]
    fn spiky_traces_have_heavier_tails() {
        let topo = small_topo();
        let model = FailureModel::llama3().scaled(30.0);
        let horizon = 24.0 * 30.0;
        let mut r1 = Rng::new(21);
        let flat = Trace::generate(&topo, &model, horizon, &mut r1);
        let mut r2 = Rng::new(21);
        let spiky = Trace::generate_with_spikes(&topo, &model, horizon, 7.0, 1.0, 12.0, &mut r2);
        let peak = |t: &Trace| {
            t.failed_series(&topo, BlastRadius::Single, 2.0)
                .iter()
                .map(|x| x.1)
                .max()
                .unwrap_or(0)
        };
        // spiky trace mean rate ~ base rate, but peaks higher
        let ratio = flat.events.len() as f64 / spiky.events.len().max(1) as f64;
        assert!((0.4..2.5).contains(&ratio), "mean rates should be comparable ({ratio})");
        assert!(peak(&spiky) > peak(&flat), "spikes should raise the peak");
    }

    #[test]
    fn paper_fig4_regime_time_above_threshold() {
        // Llama-3 rates on the 16K cluster: most of a 15-day trace should
        // sit above 0.1% failed (paper reports 81%).
        let topo = Topology::of(16_384, 8, 8);
        let model = FailureModel::llama3();
        let mut rng = Rng::new(42);
        let trace = Trace::generate(&topo, &model, 24.0 * 15.0, &mut rng);
        let frac = trace.time_above_fraction(&topo, BlastRadius::Single, 1.0, 0.001);
        assert!(frac > 0.5, "time above 0.1% = {frac}");
    }
}
