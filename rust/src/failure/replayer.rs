//! Event-driven incremental trace replay.
//!
//! [`Trace::replay_to`] rebuilds a fresh [`FleetHealth`] (one topology
//! clone + a full event rescan) for *every* queried time — O(steps ×
//! events) when a simulation samples a trace on a time grid. The
//! [`FleetReplayer`] instead sweeps the trace once: an event cursor
//! walks the (time-sorted) failure events, and a lazy-deletion min-heap
//! schedules recoveries, both applied to one persistent `FleetHealth`.
//! Advancing the replayer over a whole trace is O(events × blast ×
//! log events) total, independent of how many times it is sampled.
//!
//! ## Equivalence with `replay_to`
//!
//! At every queried time `t`, the replayer's fleet agrees with
//! `trace.replay_to(topo, blast, t)` on the health of every GPU, on
//! `n_failed`, on `domain_healthy_counts`, and on the pending
//! `until_hours` of every failed GPU (`rust/tests/replay_equivalence.rs`
//! asserts this on randomized traces). The one intentional difference:
//! for a GPU hit by *overlapping* events, `replay_to` re-derives
//! `at_hours` from whichever events are still active at `t`, while the
//! incremental sweep keeps the start of the uninterrupted outage —
//! the physically meaningful value. Nothing downstream consumes
//! `at_hours` of an ongoing failure, so every derived statistic
//! (`FleetStats`, failed-GPU series, availability fractions) is
//! bit-identical between the two paths.
//!
//! Tie-breaking matches `replay_to` exactly: a failure is active on
//! `[at_hours, recover_at_hours)` — an event starting at exactly `t`
//! counts as failed at `t`, a recovery due at exactly `t` has already
//! happened at `t`.

use super::blast::BlastRadius;
use super::trace::{EventKind, Trace};
use crate::cluster::{FleetHealth, GpuState, Topology};
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// Total-order key over (finite) f64 times so they can live in a heap.
#[derive(Clone, Copy, Debug)]
struct TimeKey(f64);

impl PartialEq for TimeKey {
    fn eq(&self, other: &TimeKey) -> bool {
        self.0.total_cmp(&other.0) == Ordering::Equal
    }
}
impl Eq for TimeKey {}
impl PartialOrd for TimeKey {
    fn partial_cmp(&self, other: &TimeKey) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimeKey {
    fn cmp(&self, other: &TimeKey) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Incremental, forward-only replay of one trace against one topology.
pub struct FleetReplayer<'a> {
    trace: &'a Trace,
    blast: BlastRadius,
    fleet: FleetHealth,
    /// Index of the first not-yet-applied event.
    next_event: usize,
    /// Min-heap of scheduled recoveries `(recover_at, gpu, is_degrade)`.
    /// Entries are lazily deleted: a popped entry only triggers a
    /// recovery if the GPU's *actual* deadline in the tagged layer has
    /// not been extended past it by an overlapping later event.
    recoveries: BinaryHeap<Reverse<(TimeKey, usize, bool)>>,
    now: f64,
}

impl<'a> FleetReplayer<'a> {
    /// Start a sweep at `t = 0` with an all-healthy fleet. `trace.events`
    /// must be sorted by `at_hours` (all generators produce sorted
    /// traces; `Trace::replay_to` silently assumes the same). Checked
    /// loudly here — one O(events) scan per replayer — because an
    /// out-of-order cursor would return wrong counts without it.
    pub fn new(trace: &'a Trace, topo: &Topology, blast: BlastRadius) -> FleetReplayer<'a> {
        assert!(
            trace.events.windows(2).all(|w| w[0].at_hours <= w[1].at_hours),
            "FleetReplayer requires time-sorted events"
        );
        FleetReplayer {
            trace,
            blast,
            fleet: FleetHealth::new(topo.clone()),
            next_event: 0,
            recoveries: BinaryHeap::new(),
            now: 0.0,
        }
    }

    /// Current sweep time.
    pub fn now_hours(&self) -> f64 {
        self.now
    }

    /// Horizon of the trace under replay (hours).
    pub fn horizon_hours(&self) -> f64 {
        self.trace.horizon_hours
    }

    /// The trace under replay — the shared multi-policy sweep charges
    /// trace-global costs (SDC detection-lag rollback) from it.
    pub fn trace(&self) -> &'a Trace {
        self.trace
    }

    /// Rewind to `t = 0` on a (possibly different) trace, reusing the
    /// fleet-health allocation — at 100K-GPU scale the per-GPU state
    /// vector dominates replayer construction, so Monte-Carlo trial
    /// loops ([`crate::manager::MultiPolicySim::run_trials`]) reset one
    /// replayer instead of building one per trace. The topology and
    /// blast radius are unchanged; the same sortedness requirement as
    /// [`FleetReplayer::new`] applies.
    pub fn reset(&mut self, trace: &'a Trace) {
        assert!(
            trace.events.windows(2).all(|w| w[0].at_hours <= w[1].at_hours),
            "FleetReplayer requires time-sorted events"
        );
        self.trace = trace;
        self.fleet.reset();
        self.next_event = 0;
        self.recoveries.clear();
        self.now = 0.0;
    }

    /// The fleet state as of the last `advance`.
    pub fn fleet(&self) -> &FleetHealth {
        &self.fleet
    }

    /// The next instant (strictly after the current sweep time) at
    /// which the fleet state *may* change: the earlier of the next
    /// failure arrival and the earliest scheduled recovery. `None`
    /// once the trace is exhausted and every outage has resolved.
    ///
    /// This is the cursor exact event-boundary integration
    /// ([`crate::manager::StepMode::Exact`]) steps on. Lazily-deleted
    /// (stale) recovery entries can surface as candidates; at such a
    /// time the fleet provably does NOT change (the extending event
    /// queued its own, later entry), so sweeps that close integration
    /// intervals only on an *observed* health change stay exact — a
    /// stale boundary is just a no-op advance.
    pub fn next_change_hours(&self) -> Option<f64> {
        let ev = self.trace.events.get(self.next_event).map(|e| e.at_hours);
        let rec = self.recoveries.peek().map(|&Reverse((TimeKey(u), _, _))| u);
        match (ev, rec) {
            (None, None) => None,
            (Some(a), None) => Some(a),
            (None, Some(b)) => Some(b),
            (Some(a), Some(b)) => Some(a.min(b)),
        }
    }

    /// Advance the sweep to `now_hours` (must be >= the current time) and
    /// return the fleet state at that instant. Failure events and
    /// recoveries are interleaved in time order; on a tie the recovery is
    /// applied first (matching `replay_to`, where an event whose
    /// `recover_at_hours == t` is already gone at `t`).
    pub fn advance(&mut self, now_hours: f64) -> &FleetHealth {
        assert!(
            now_hours >= self.now,
            "FleetReplayer::advance must move forward in time ({} -> {now_hours})",
            self.now
        );
        loop {
            let next_rec = self.recoveries.peek().map(|&Reverse((TimeKey(u), _, _))| u);
            let next_ev = self.trace.events.get(self.next_event).map(|e| e.at_hours);
            let rec_due = matches!(next_rec, Some(u) if u <= now_hours);
            let ev_due = matches!(next_ev, Some(a) if a <= now_hours);
            if rec_due && (!ev_due || next_rec.unwrap() <= next_ev.unwrap()) {
                let Reverse((TimeKey(due), gpu, is_degrade)) = self.recoveries.pop().unwrap();
                if is_degrade {
                    // Degrade entries stack per GPU: expire the ones due
                    // by this boundary, surviving overlaps stay active.
                    self.fleet.recover_degrade_due(gpu, due);
                } else if let GpuState::Failed { until_hours, .. } = self.fleet.state(gpu) {
                    // Stale entry if an overlapping failure pushed the
                    // actual deadline past this one; the extending event
                    // queued its own (later) entry.
                    if until_hours <= due {
                        self.fleet.recover(gpu);
                    }
                }
            } else if ev_due {
                let ev = self.trace.events[self.next_event];
                self.next_event += 1;
                match ev.kind {
                    EventKind::Degrade { slowdown } => {
                        for g in self.blast.affected(&self.fleet.topo, ev.gpu) {
                            self.fleet.degrade(g, slowdown, ev.at_hours, ev.recover_at_hours);
                            self.recoveries.push(Reverse((
                                TimeKey(ev.recover_at_hours),
                                g,
                                true,
                            )));
                        }
                    }
                    EventKind::Fail | EventKind::Sdc { .. } => {
                        for g in self.blast.affected(&self.fleet.topo, ev.gpu) {
                            self.fleet.fail(g, ev.at_hours, ev.recover_at_hours);
                            self.recoveries.push(Reverse((
                                TimeKey(ev.recover_at_hours),
                                g,
                                false,
                            )));
                        }
                    }
                }
            } else {
                break;
            }
        }
        self.now = now_hours;
        &self.fleet
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::rates::FailureModel;
    use crate::util::prng::Rng;

    fn assert_matches_replay_to(trace: &Trace, topo: &Topology, blast: BlastRadius, times: &[f64]) {
        let mut rep = FleetReplayer::new(trace, topo, blast);
        for &t in times {
            let inc = rep.advance(t);
            let scratch = trace.replay_to(topo, blast, t);
            assert_eq!(inc.n_failed(), scratch.n_failed(), "n_failed at t={t}");
            assert_eq!(
                inc.domain_healthy_counts(),
                scratch.domain_healthy_counts(),
                "domain counts at t={t}"
            );
            inc.check_invariants().unwrap();
        }
    }

    #[test]
    fn matches_replay_to_on_dense_trace() {
        let topo = Topology::of(256, 8, 4);
        let model = FailureModel::llama3().scaled(200.0);
        let mut rng = Rng::new(12);
        let trace = Trace::generate(&topo, &model, 24.0 * 10.0, &mut rng);
        let times: Vec<f64> = (0..200).map(|i| i as f64 * 1.2).collect();
        assert_matches_replay_to(&trace, &topo, BlastRadius::Single, &times);
    }

    #[test]
    fn matches_replay_to_with_blast_overlap() {
        // Node blast makes overlapping multi-GPU outages common, which
        // exercises the lazy-deletion / extension path.
        let topo = Topology::of(128, 16, 4);
        let model = FailureModel::llama3().scaled(400.0);
        let mut rng = Rng::new(77);
        let trace = Trace::generate(&topo, &model, 24.0 * 8.0, &mut rng);
        let times: Vec<f64> = (0..300).map(|i| i as f64 * 0.7).collect();
        assert_matches_replay_to(&trace, &topo, BlastRadius::Node, &times);
    }

    #[test]
    fn sampling_exactly_on_event_edges() {
        // Hand-built trace probing the inclusive/exclusive boundaries.
        let topo = Topology::of(16, 8, 4);
        let trace = Trace {
            horizon_hours: 20.0,
            events: vec![
                crate::failure::FailureEvent {
                    at_hours: 1.0,
                    gpu: 3,
                    is_hw: true,
                    recover_at_hours: 5.0,
                    kind: EventKind::Fail,
                },
                crate::failure::FailureEvent {
                    at_hours: 5.0,
                    gpu: 3,
                    is_hw: false,
                    recover_at_hours: 7.0,
                    kind: EventKind::Fail,
                },
            ],
        };
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        assert_eq!(rep.advance(0.5).n_failed(), 0);
        assert_eq!(rep.advance(1.0).n_failed(), 1); // failure at exactly t
        assert_eq!(rep.advance(4.9).n_failed(), 1);
        // at t=5: first outage recovers, second begins — still failed,
        // same as replay_to
        assert_eq!(rep.advance(5.0).n_failed(), 1);
        assert_eq!(trace.replay_to(&topo, BlastRadius::Single, 5.0).n_failed(), 1);
        assert_eq!(rep.advance(6.9).n_failed(), 1);
        assert_eq!(rep.advance(7.0).n_failed(), 0); // recovery at exactly t
    }

    #[test]
    fn reset_replays_a_new_trace_from_scratch() {
        let topo = Topology::of(256, 8, 4);
        let model = FailureModel::llama3().scaled(150.0);
        let mut rng = Rng::new(41);
        let trace_a = Trace::generate(&topo, &model, 24.0 * 6.0, &mut rng);
        let trace_b = Trace::generate(&topo, &model, 24.0 * 9.0, &mut rng);
        let times: Vec<f64> = (0..120).map(|i| i as f64 * 1.1).collect();
        let mut rep = FleetReplayer::new(&trace_a, &topo, BlastRadius::Node);
        for &t in &times {
            rep.advance(t);
        }
        // Reset onto trace B mid-flight: must match a fresh sweep of B.
        rep.reset(&trace_b);
        assert_eq!(rep.now_hours(), 0.0);
        assert_eq!(rep.horizon_hours(), trace_b.horizon_hours);
        assert_matches_replay_to(&trace_b, &topo, BlastRadius::Node, &times);
        for &t in &times {
            let inc = rep.advance(t);
            let scratch = trace_b.replay_to(&topo, BlastRadius::Node, t);
            assert_eq!(inc.n_failed(), scratch.n_failed(), "after reset, t={t}");
            assert_eq!(
                inc.domain_healthy_counts(),
                scratch.domain_healthy_counts(),
                "after reset, t={t}"
            );
        }
    }

    #[test]
    fn next_change_hours_walks_every_boundary() {
        let topo = Topology::of(16, 8, 4);
        let trace = Trace {
            horizon_hours: 20.0,
            events: vec![
                crate::failure::FailureEvent {
                    at_hours: 1.0,
                    gpu: 3,
                    is_hw: true,
                    recover_at_hours: 5.0,
                    kind: EventKind::Fail,
                },
                crate::failure::FailureEvent {
                    at_hours: 2.0,
                    gpu: 9,
                    is_hw: false,
                    recover_at_hours: 4.0,
                    kind: EventKind::Fail,
                },
                // overlapping re-failure of gpu 3: extends to 7.0, the
                // 5.0 recovery entry goes stale (a no-op boundary)
                crate::failure::FailureEvent {
                    at_hours: 3.0,
                    gpu: 3,
                    is_hw: false,
                    recover_at_hours: 7.0,
                    kind: EventKind::Fail,
                },
            ],
        };
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        rep.advance(0.0);
        let mut boundaries = Vec::new();
        let mut failed = Vec::new();
        while let Some(t) = rep.next_change_hours() {
            boundaries.push(t);
            failed.push(rep.advance(t).n_failed());
        }
        // arrivals 1,2,3; recoveries 4 (gpu 9), 5 (stale), 7 (gpu 3)
        assert_eq!(boundaries, vec![1.0, 2.0, 3.0, 4.0, 5.0, 7.0]);
        assert_eq!(failed, vec![1, 2, 2, 1, 1, 0]);
        // every boundary matches the from-scratch replay
        for (&t, &f) in boundaries.iter().zip(&failed) {
            assert_eq!(trace.replay_to(&topo, BlastRadius::Single, t).n_failed(), f);
        }
        assert_eq!(rep.next_change_hours(), None);
        // empty trace: no boundaries at all
        let quiet = Trace { horizon_hours: 5.0, events: vec![] };
        let rep = FleetReplayer::new(&quiet, &topo, BlastRadius::Single);
        assert_eq!(rep.next_change_hours(), None);
    }

    #[test]
    fn degrade_and_fail_layers_replay_independently() {
        let topo = Topology::of(16, 8, 4);
        let trace = Trace {
            horizon_hours: 20.0,
            events: vec![
                // degrade gpu 3 on [1, 9)
                crate::failure::FailureEvent {
                    at_hours: 1.0,
                    gpu: 3,
                    is_hw: false,
                    recover_at_hours: 9.0,
                    kind: EventKind::Degrade { slowdown: 0.5 },
                },
                // hard-fail the same gpu on [2, 6): shadows the degrade
                crate::failure::FailureEvent {
                    at_hours: 2.0,
                    gpu: 3,
                    is_hw: true,
                    recover_at_hours: 6.0,
                    kind: EventKind::Fail,
                },
                // deeper overlapping degrade, ends before the first
                crate::failure::FailureEvent {
                    at_hours: 3.0,
                    gpu: 3,
                    is_hw: false,
                    recover_at_hours: 5.0,
                    kind: EventKind::Degrade { slowdown: 0.25 },
                },
            ],
        };
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        let expect = |t: f64, failed: usize, degraded: usize, slow: f64| {
            let scratch = trace.replay_to(&topo, BlastRadius::Single, t);
            assert_eq!(scratch.n_failed(), failed, "replay_to failed at t={t}");
            assert_eq!(scratch.n_degraded(), degraded, "replay_to degraded at t={t}");
            assert_eq!(scratch.domain_slowdowns()[0], slow, "replay_to slow at t={t}");
            scratch.check_invariants().unwrap();
        };
        assert_eq!(rep.advance(1.5).n_degraded(), 1);
        expect(1.5, 0, 1, 0.5);
        // failure shadows the degrade
        assert_eq!(rep.advance(2.5).n_failed(), 1);
        assert_eq!(rep.fleet().n_degraded(), 0);
        expect(2.5, 1, 0, 1.0);
        // at 4 the deeper 0.25 degrade is active but shadowed
        expect(4.0, 1, 0, 1.0);
        // at 6 the failure recovers; the 0.25 entry expired at 5, so the
        // surviving 0.5 degrade resurfaces at its own slowdown
        assert_eq!(rep.advance(6.0).n_failed(), 0);
        assert_eq!(rep.fleet().n_degraded(), 1);
        assert_eq!(rep.fleet().domain_slowdowns()[0], 0.5);
        expect(6.0, 0, 1, 0.5);
        // last degrade entry expires at 9
        assert_eq!(rep.advance(9.0).n_degraded(), 0);
        assert_eq!(rep.fleet().domain_slowdowns()[0], 1.0);
        expect(9.0, 0, 0, 1.0);
        rep.fleet().check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "forward in time")]
    fn rewinding_panics() {
        let topo = Topology::of(16, 8, 4);
        let trace = Trace { horizon_hours: 1.0, events: vec![] };
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        rep.advance(1.0);
        rep.advance(0.5);
    }
}
