//! Event-driven incremental trace replay.
//!
//! [`Trace::replay_to`] rebuilds a fresh [`FleetHealth`] (one topology
//! clone + a full event rescan) for *every* queried time — O(steps ×
//! events) when a simulation samples a trace on a time grid. The
//! [`FleetReplayer`] instead sweeps the trace once: an event cursor
//! walks the (time-sorted) failure events, and a lazy-deletion min-heap
//! schedules recoveries, both applied to one persistent `FleetHealth`.
//! Advancing the replayer over a whole trace is O(events × blast ×
//! log events) total, independent of how many times it is sampled.
//!
//! ## Event sources
//!
//! The replay core ([`ReplayCore`]) is generic over where events come
//! from ([`EventSource`]): a [`TraceCursor`] walking a materialized
//! `&Trace` (the classic [`FleetReplayer`], now a type alias), or a
//! lazily drawn [`TraceStream`](super::stream::TraceStream) — the
//! streaming Monte-Carlo path that never materializes a trace. One
//! event of lookahead is held so `next_change_hours` stays `&self`.
//!
//! While events apply, the core maintains three incremental aggregates
//! the shared multi-policy sweep used to recompute per boundary:
//!
//! * the damaged-domain **deficit histogram** over the job-domain
//!   prefix (the `SnapshotSig` multiset, updated by delta instead of
//!   re-sorting `domain_healthy_counts`),
//! * the **live-spare count** (tail domains at full health) and the
//!   count of job domains with an active degrade, and
//! * a **dirty-domain list** — exactly the domains whose
//!   `(healthy, degraded, slowdown)` view changed since the last
//!   [`ReplayCore::clear_dirty`], so change detection is O(touched)
//!   instead of O(domains).
//!
//! SDC detection-lag billing is accumulated here too: `(at, corrupt)`
//! pairs are recorded in pull order as events stream past, which makes
//! the rollback bill identical bit-for-bit to the trace-order scan of
//! `sdc_rollback_gpu_secs` without requiring a materialized trace.
//!
//! ## Equivalence with `replay_to`
//!
//! At every queried time `t`, the replayer's fleet agrees with
//! `trace.replay_to(topo, blast, t)` on the health of every GPU, on
//! `n_failed`, on `domain_healthy_counts`, and on the pending
//! `until_hours` of every failed GPU (`rust/tests/replay_equivalence.rs`
//! asserts this on randomized traces). The one intentional difference:
//! for a GPU hit by *overlapping* events, `replay_to` re-derives
//! `at_hours` from whichever events are still active at `t`, while the
//! incremental sweep keeps the start of the uninterrupted outage —
//! the physically meaningful value. Nothing downstream consumes
//! `at_hours` of an ongoing failure, so every derived statistic
//! (`FleetStats`, failed-GPU series, availability fractions) is
//! bit-identical between the two paths.
//!
//! Tie-breaking matches `replay_to` exactly: a failure is active on
//! `[at_hours, recover_at_hours)` — an event starting at exactly `t`
//! counts as failed at `t`, a recovery due at exactly `t` has already
//! happened at `t`.

use super::blast::BlastRadius;
use super::trace::{EventKind, FailureEvent, Trace};
use crate::cluster::{FleetHealth, GpuState, Topology};
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// Total-order key over (finite) f64 times so they can live in a heap.
#[derive(Clone, Copy, Debug)]
struct TimeKey(f64);

impl PartialEq for TimeKey {
    fn eq(&self, other: &TimeKey) -> bool {
        self.0.total_cmp(&other.0) == Ordering::Equal
    }
}
impl Eq for TimeKey {}
impl PartialOrd for TimeKey {
    fn partial_cmp(&self, other: &TimeKey) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimeKey {
    fn cmp(&self, other: &TimeKey) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Where a replay's failure events come from: a materialized trace
/// cursor or a live generator stream. Events must be handed out in
/// non-decreasing `at_hours` order (checked incrementally as they are
/// pulled).
pub trait EventSource {
    /// Horizon of the event source (hours).
    fn horizon_hours(&self) -> f64;
    /// The next event in time order, `None` once exhausted.
    fn next_event(&mut self) -> Option<FailureEvent>;
    /// Undetected-stall bill accumulated by a detection-latency adapter
    /// ([`super::detect::DelayedEvents`]), GPU-hours; `0` for raw
    /// sources (instant detection). Complete only once the source is
    /// exhausted — drain before reading.
    fn detect_stall_gpu_hours(&self) -> f64 {
        0.0
    }
}

/// [`EventSource`] over a materialized `&Trace`.
pub struct TraceCursor<'a> {
    trace: &'a Trace,
    next: usize,
}

impl<'a> TraceCursor<'a> {
    /// `trace.events` must be sorted by `at_hours` (all generators
    /// produce sorted traces; `Trace::replay_to` silently assumes the
    /// same). Checked loudly here — one O(events) scan per cursor —
    /// because an out-of-order cursor would return wrong counts.
    pub fn new(trace: &'a Trace) -> TraceCursor<'a> {
        assert!(
            trace.events.windows(2).all(|w| w[0].at_hours <= w[1].at_hours),
            "FleetReplayer requires time-sorted events"
        );
        TraceCursor { trace, next: 0 }
    }
}

impl<'a> EventSource for TraceCursor<'a> {
    fn horizon_hours(&self) -> f64 {
        self.trace.horizon_hours
    }

    fn next_event(&mut self) -> Option<FailureEvent> {
        let ev = self.trace.events.get(self.next).copied();
        if ev.is_some() {
            self.next += 1;
        }
        ev
    }
}

/// Incremental, forward-only replay of one event source against one
/// topology. See the module docs for the aggregates maintained.
pub struct ReplayCore<S> {
    source: S,
    /// One-event lookahead so `next_change_hours` can peek without
    /// pulling from the (mutable) source.
    pending: Option<FailureEvent>,
    /// Monotonicity watermark over pulled events.
    last_pulled_at: f64,
    horizon: f64,
    blast: BlastRadius,
    fleet: FleetHealth,
    /// Min-heap of scheduled recoveries `(recover_at, gpu, is_degrade)`.
    /// Entries are lazily deleted: a popped entry only triggers a
    /// recovery if the GPU's *actual* deadline in the tagged layer has
    /// not been extended past it by an overlapping later event.
    recoveries: BinaryHeap<Reverse<(TimeKey, usize, bool)>>,
    now: f64,
    /// In-horizon SDC `(at_hours, corrupt_at_hours)` pairs in pull
    /// order — the streaming replacement for scanning the whole trace
    /// when billing detection-lag rollback.
    sdc_pairs: Vec<(f64, f64)>,
    /// Job-domain prefix length for the damage aggregates (domains
    /// `>= n_job` are the spare tail). Defaults to every domain.
    n_job: usize,
    /// `deficit_hist[k]` = number of job domains missing exactly `k`
    /// GPUs (`k in 1..=domain_size`; index 0 unused). An ascending scan
    /// reproduces the sorted `(deficit, count)` RLE of `SnapshotSig`.
    deficit_hist: Vec<u32>,
    /// Spare-tail domains currently at full health (= live spares).
    tail_full: usize,
    /// Job domains with at least one degraded-and-alive GPU.
    job_degraded: usize,
    /// Domains whose `(healthy, degraded, slowdown)` view changed since
    /// the last `clear_dirty`, each listed once.
    dirty: Vec<u32>,
    dirty_epoch: Vec<u64>,
    epoch: u64,
}

/// The classic materialized-trace replayer.
pub type FleetReplayer<'a> = ReplayCore<TraceCursor<'a>>;

impl<'a> ReplayCore<TraceCursor<'a>> {
    /// Start a sweep at `t = 0` with an all-healthy fleet over a
    /// materialized trace.
    pub fn new(trace: &'a Trace, topo: &Topology, blast: BlastRadius) -> FleetReplayer<'a> {
        ReplayCore::from_source(TraceCursor::new(trace), topo, blast)
    }

    /// Rewind to `t = 0` on a (possibly different) trace, reusing the
    /// fleet-health allocation — at 100K-GPU scale the per-GPU state
    /// vector dominates replayer construction, so Monte-Carlo trial
    /// loops ([`crate::manager::MultiPolicySim::run_trials`]) reset one
    /// replayer instead of building one per trace. The topology and
    /// blast radius are unchanged; the same sortedness requirement as
    /// [`FleetReplayer::new`] applies.
    pub fn reset(&mut self, trace: &'a Trace) {
        self.reset_source(TraceCursor::new(trace));
    }

    /// The trace under replay — reference paths charge trace-global
    /// costs (SDC detection-lag rollback) from it; the streaming path
    /// uses [`ReplayCore::sdc_pairs`] instead.
    pub fn trace(&self) -> &'a Trace {
        self.source.trace
    }
}

impl<S: EventSource> ReplayCore<S> {
    /// Start a sweep at `t = 0` with an all-healthy fleet over any
    /// event source (e.g. a live
    /// [`TraceStream`](super::stream::TraceStream)).
    pub fn from_source(source: S, topo: &Topology, blast: BlastRadius) -> ReplayCore<S> {
        let n_domains = topo.n_domains();
        let mut core = ReplayCore {
            source,
            pending: None,
            last_pulled_at: f64::NEG_INFINITY,
            horizon: 0.0,
            blast,
            fleet: FleetHealth::new(topo.clone()),
            recoveries: BinaryHeap::new(),
            now: 0.0,
            sdc_pairs: Vec::new(),
            n_job: n_domains,
            deficit_hist: vec![0; topo.domain_size + 1],
            tail_full: 0,
            job_degraded: 0,
            dirty: Vec::new(),
            dirty_epoch: vec![0; n_domains],
            epoch: 1,
        };
        core.horizon = core.source.horizon_hours();
        core.pull();
        core
    }

    /// Rewind to `t = 0` on a new event source, reusing every
    /// allocation (fleet state, recovery heap, damage aggregates) — the
    /// streaming trial loop's O(1)-memory reset.
    pub fn reset_source(&mut self, source: S) {
        self.source = source;
        self.fleet.reset();
        self.recoveries.clear();
        self.now = 0.0;
        self.pending = None;
        self.last_pulled_at = f64::NEG_INFINITY;
        self.sdc_pairs.clear();
        self.n_job = self.fleet.topo.n_domains();
        for v in &mut self.deficit_hist {
            *v = 0;
        }
        self.tail_full = 0;
        self.job_degraded = 0;
        self.dirty.clear();
        self.epoch += 1;
        self.horizon = self.source.horizon_hours();
        self.pull();
    }

    /// Current sweep time.
    pub fn now_hours(&self) -> f64 {
        self.now
    }

    /// Horizon of the source under replay (hours).
    pub fn horizon_hours(&self) -> f64 {
        self.horizon
    }

    /// The fleet state as of the last `advance`.
    pub fn fleet(&self) -> &FleetHealth {
        &self.fleet
    }

    /// Refill the one-event lookahead, checking time order and
    /// recording in-horizon SDC detections for rollback billing.
    fn pull(&mut self) {
        self.pending = self.source.next_event();
        if let Some(ev) = self.pending {
            assert!(
                ev.at_hours >= self.last_pulled_at,
                "FleetReplayer requires time-sorted events"
            );
            self.last_pulled_at = ev.at_hours;
            if let EventKind::Sdc { corrupt_at_hours } = ev.kind {
                if ev.at_hours > 0.0 && ev.at_hours < self.horizon {
                    self.sdc_pairs.push((ev.at_hours, corrupt_at_hours));
                }
            }
        }
    }

    /// The next instant (strictly after the current sweep time) at
    /// which the fleet state *may* change: the earlier of the next
    /// failure arrival and the earliest scheduled recovery. `None`
    /// once the source is exhausted and every outage has resolved.
    ///
    /// This is the cursor exact event-boundary integration
    /// ([`crate::manager::StepMode::Exact`]) steps on. Lazily-deleted
    /// (stale) recovery entries can surface as candidates; at such a
    /// time the fleet provably does NOT change (the extending event
    /// queued its own, later entry), so sweeps that close integration
    /// intervals only on an *observed* health change stay exact — a
    /// stale boundary is just a no-op advance.
    pub fn next_change_hours(&self) -> Option<f64> {
        let ev = self.pending.map(|e| e.at_hours);
        let rec = self.recoveries.peek().map(|&Reverse((TimeKey(u), _, _))| u);
        match (ev, rec) {
            (None, None) => None,
            (Some(a), None) => Some(a),
            (None, Some(b)) => Some(b),
            (Some(a), Some(b)) => Some(a.min(b)),
        }
    }

    /// `(healthy, degraded, slowdown)` view of one domain.
    #[inline]
    fn domain_view(&self, d: usize) -> (usize, usize, f64) {
        (
            self.fleet.domain_healthy(d),
            self.fleet.domain_degraded_counts()[d],
            self.fleet.domain_slowdowns()[d],
        )
    }

    /// Fold one domain's post-mutation view into the incremental
    /// aggregates. Blast sets never cross a domain boundary, so each
    /// event (and each recovery pop) touches exactly one domain.
    fn domain_delta(&mut self, d: usize, pre: (usize, usize, f64)) {
        let (h0, dg0, sl0) = pre;
        let (h1, dg1, sl1) = self.domain_view(d);
        if h1 == h0 && dg1 == dg0 && sl1 == sl0 {
            return;
        }
        if self.dirty_epoch[d] != self.epoch {
            self.dirty_epoch[d] = self.epoch;
            self.dirty.push(d as u32);
        }
        if h1 != h0 {
            let ds = self.fleet.topo.domain_size;
            if d < self.n_job {
                let (def0, def1) = (ds - h0, ds - h1);
                if def0 > 0 {
                    self.deficit_hist[def0] -= 1;
                }
                if def1 > 0 {
                    self.deficit_hist[def1] += 1;
                }
            } else {
                if h0 == ds {
                    self.tail_full -= 1;
                }
                if h1 == ds {
                    self.tail_full += 1;
                }
            }
        }
        if d < self.n_job && (dg0 > 0) != (dg1 > 0) {
            if dg1 > 0 {
                self.job_degraded += 1;
            } else {
                self.job_degraded -= 1;
            }
        }
    }

    /// Advance the sweep to `now_hours` (must be >= the current time) and
    /// return the fleet state at that instant. Failure events and
    /// recoveries are interleaved in time order; on a tie the recovery is
    /// applied first (matching `replay_to`, where an event whose
    /// `recover_at_hours == t` is already gone at `t`).
    pub fn advance(&mut self, now_hours: f64) -> &FleetHealth {
        assert!(
            now_hours >= self.now,
            "FleetReplayer::advance must move forward in time ({} -> {now_hours})",
            self.now
        );
        loop {
            let next_rec = self.recoveries.peek().map(|&Reverse((TimeKey(u), _, _))| u);
            let next_ev = self.pending.map(|e| e.at_hours);
            let rec_due = matches!(next_rec, Some(u) if u <= now_hours);
            let ev_due = matches!(next_ev, Some(a) if a <= now_hours);
            if rec_due && (!ev_due || next_rec.unwrap() <= next_ev.unwrap()) {
                let Reverse((TimeKey(due), gpu, is_degrade)) = self.recoveries.pop().unwrap();
                let d = self.fleet.topo.domain_of(gpu);
                let pre = self.domain_view(d);
                if is_degrade {
                    // Degrade entries stack per GPU: expire the ones due
                    // by this boundary, surviving overlaps stay active.
                    self.fleet.recover_degrade_due(gpu, due);
                } else if let GpuState::Failed { until_hours, .. } = self.fleet.state(gpu) {
                    // Stale entry if an overlapping failure pushed the
                    // actual deadline past this one; the extending event
                    // queued its own (later) entry.
                    if until_hours <= due {
                        self.fleet.recover(gpu);
                    }
                }
                self.domain_delta(d, pre);
            } else if ev_due {
                let ev = self.pending.take().unwrap();
                self.pull();
                let d = self.fleet.topo.domain_of(ev.gpu);
                let pre = self.domain_view(d);
                match ev.kind {
                    EventKind::Degrade { slowdown } => {
                        for g in self.blast.affected_range(&self.fleet.topo, ev.gpu) {
                            self.fleet.degrade(g, slowdown, ev.at_hours, ev.recover_at_hours);
                            self.recoveries.push(Reverse((
                                TimeKey(ev.recover_at_hours),
                                g,
                                true,
                            )));
                        }
                    }
                    EventKind::Fail | EventKind::Sdc { .. } => {
                        for g in self.blast.affected_range(&self.fleet.topo, ev.gpu) {
                            self.fleet.fail(g, ev.at_hours, ev.recover_at_hours);
                            self.recoveries.push(Reverse((
                                TimeKey(ev.recover_at_hours),
                                g,
                                false,
                            )));
                        }
                    }
                }
                self.domain_delta(d, pre);
            } else {
                break;
            }
        }
        self.now = now_hours;
        &self.fleet
    }

    /// Declare the job/spare split: domains `< n_job` feed the deficit
    /// histogram, the tail feeds the live-spare count. Recomputes the
    /// aggregates from the current fleet state (O(domains), called once
    /// per trial by the shared sweep).
    pub fn set_job_domains(&mut self, n_job: usize) {
        let n_domains = self.fleet.topo.n_domains();
        assert!(n_job <= n_domains, "job prefix {n_job} > {n_domains} domains");
        self.n_job = n_job;
        for v in &mut self.deficit_hist {
            *v = 0;
        }
        self.tail_full = 0;
        self.job_degraded = 0;
        let ds = self.fleet.topo.domain_size;
        for d in 0..n_domains {
            let h = self.fleet.domain_healthy(d);
            if d < n_job {
                let def = ds - h;
                if def > 0 {
                    self.deficit_hist[def] += 1;
                }
                if self.fleet.domain_degraded_counts()[d] > 0 {
                    self.job_degraded += 1;
                }
            } else if h == ds {
                self.tail_full += 1;
            }
        }
    }

    /// Job-domain prefix length set by [`ReplayCore::set_job_domains`].
    pub fn job_domains(&self) -> usize {
        self.n_job
    }

    /// Damaged-domain deficit histogram over the job prefix (index =
    /// missing GPUs, `deficit_hist()[0]` unused). An ascending scan is
    /// exactly the sorted `(deficit, count)` multiset `SnapshotSig`
    /// encodes.
    pub fn deficit_histogram(&self) -> &[u32] {
        &self.deficit_hist
    }

    /// Spare-tail domains currently at full health — the same count
    /// `split_job_spares` derives by scanning the tail slice.
    pub fn live_spare_domains(&self) -> usize {
        self.tail_full
    }

    /// Live spares among the LAST `cold_domains` domains (the
    /// fleet-wide cold tier of a hierarchical pool) — the same count
    /// `split_job_spares` derives from the tail's cold suffix. O(cold)
    /// per call; cold pools are small, so the incremental sweep scans
    /// rather than maintaining another aggregate.
    pub fn live_cold_spare_domains(&self, cold_domains: usize) -> usize {
        let n_domains = self.fleet.topo.n_domains();
        debug_assert!(cold_domains <= n_domains - self.n_job);
        let ds = self.fleet.topo.domain_size;
        (n_domains - cold_domains..n_domains)
            .filter(|&d| self.fleet.domain_healthy(d) == ds)
            .count()
    }

    /// Undetected-stall bill of a detection-latency source adapter
    /// (GPU-hours; `0` for raw sources). Complete only after
    /// [`ReplayCore::drain_source`].
    pub fn detect_stall_gpu_hours(&self) -> f64 {
        self.source.detect_stall_gpu_hours()
    }

    /// Job domains with at least one degraded-and-alive GPU.
    pub fn job_degraded_domains(&self) -> usize {
        self.job_degraded
    }

    /// Domains whose `(healthy, degraded, slowdown)` view changed since
    /// the last [`ReplayCore::clear_dirty`] (each listed once, in
    /// first-touched order). A domain in this list may have net-zero
    /// change (e.g. a recovery and a failure at one boundary cancel);
    /// compare against tracked previous values to confirm.
    pub fn dirty_domains(&self) -> &[u32] {
        &self.dirty
    }

    pub fn clear_dirty(&mut self) {
        self.dirty.clear();
        self.epoch += 1;
    }

    /// In-horizon SDC `(at_hours, corrupt_at_hours)` pairs pulled so
    /// far, in event order. Complete once the source is exhausted —
    /// call [`ReplayCore::drain_source`] first if the sweep stopped
    /// before the horizon.
    pub fn sdc_pairs(&self) -> &[(f64, f64)] {
        &self.sdc_pairs
    }

    /// Consume the rest of the source *without* applying it to the
    /// fleet, so `sdc_pairs` covers every in-horizon detection. Grid
    /// sweeps stop advancing at the last grid point; the trailing
    /// events still owe rollback. After draining, `advance` only
    /// resolves already-scheduled recoveries.
    pub fn drain_source(&mut self) {
        while self.pending.is_some() {
            self.pull();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::rates::FailureModel;
    use crate::failure::scenario::{generate_scenario, ScenarioConfig, ScenarioKind};
    use crate::failure::stream::TraceStream;
    use crate::util::prng::Rng;

    fn assert_matches_replay_to(trace: &Trace, topo: &Topology, blast: BlastRadius, times: &[f64]) {
        let mut rep = FleetReplayer::new(trace, topo, blast);
        for &t in times {
            let inc = rep.advance(t);
            let scratch = trace.replay_to(topo, blast, t);
            assert_eq!(inc.n_failed(), scratch.n_failed(), "n_failed at t={t}");
            assert_eq!(
                inc.domain_healthy_counts(),
                scratch.domain_healthy_counts(),
                "domain counts at t={t}"
            );
            inc.check_invariants().unwrap();
        }
    }

    #[test]
    fn matches_replay_to_on_dense_trace() {
        let topo = Topology::of(256, 8, 4);
        let model = FailureModel::llama3().scaled(200.0);
        let mut rng = Rng::new(12);
        let trace = Trace::generate(&topo, &model, 24.0 * 10.0, &mut rng);
        let times: Vec<f64> = (0..200).map(|i| i as f64 * 1.2).collect();
        assert_matches_replay_to(&trace, &topo, BlastRadius::Single, &times);
    }

    #[test]
    fn matches_replay_to_with_blast_overlap() {
        // Node blast makes overlapping multi-GPU outages common, which
        // exercises the lazy-deletion / extension path.
        let topo = Topology::of(128, 16, 4);
        let model = FailureModel::llama3().scaled(400.0);
        let mut rng = Rng::new(77);
        let trace = Trace::generate(&topo, &model, 24.0 * 8.0, &mut rng);
        let times: Vec<f64> = (0..300).map(|i| i as f64 * 0.7).collect();
        assert_matches_replay_to(&trace, &topo, BlastRadius::Node, &times);
    }

    #[test]
    fn sampling_exactly_on_event_edges() {
        // Hand-built trace probing the inclusive/exclusive boundaries.
        let topo = Topology::of(16, 8, 4);
        let trace = Trace {
            horizon_hours: 20.0,
            events: vec![
                crate::failure::FailureEvent {
                    at_hours: 1.0,
                    gpu: 3,
                    is_hw: true,
                    recover_at_hours: 5.0,
                    kind: EventKind::Fail,
                },
                crate::failure::FailureEvent {
                    at_hours: 5.0,
                    gpu: 3,
                    is_hw: false,
                    recover_at_hours: 7.0,
                    kind: EventKind::Fail,
                },
            ],
        };
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        assert_eq!(rep.advance(0.5).n_failed(), 0);
        assert_eq!(rep.advance(1.0).n_failed(), 1); // failure at exactly t
        assert_eq!(rep.advance(4.9).n_failed(), 1);
        // at t=5: first outage recovers, second begins — still failed,
        // same as replay_to
        assert_eq!(rep.advance(5.0).n_failed(), 1);
        assert_eq!(trace.replay_to(&topo, BlastRadius::Single, 5.0).n_failed(), 1);
        assert_eq!(rep.advance(6.9).n_failed(), 1);
        assert_eq!(rep.advance(7.0).n_failed(), 0); // recovery at exactly t
    }

    #[test]
    fn reset_replays_a_new_trace_from_scratch() {
        let topo = Topology::of(256, 8, 4);
        let model = FailureModel::llama3().scaled(150.0);
        let mut rng = Rng::new(41);
        let trace_a = Trace::generate(&topo, &model, 24.0 * 6.0, &mut rng);
        let trace_b = Trace::generate(&topo, &model, 24.0 * 9.0, &mut rng);
        let times: Vec<f64> = (0..120).map(|i| i as f64 * 1.1).collect();
        let mut rep = FleetReplayer::new(&trace_a, &topo, BlastRadius::Node);
        for &t in &times {
            rep.advance(t);
        }
        // Reset onto trace B mid-flight: must match a fresh sweep of B.
        rep.reset(&trace_b);
        assert_eq!(rep.now_hours(), 0.0);
        assert_eq!(rep.horizon_hours(), trace_b.horizon_hours);
        assert_matches_replay_to(&trace_b, &topo, BlastRadius::Node, &times);
        for &t in &times {
            let inc = rep.advance(t);
            let scratch = trace_b.replay_to(&topo, BlastRadius::Node, t);
            assert_eq!(inc.n_failed(), scratch.n_failed(), "after reset, t={t}");
            assert_eq!(
                inc.domain_healthy_counts(),
                scratch.domain_healthy_counts(),
                "after reset, t={t}"
            );
        }
    }

    #[test]
    fn next_change_hours_walks_every_boundary() {
        let topo = Topology::of(16, 8, 4);
        let trace = Trace {
            horizon_hours: 20.0,
            events: vec![
                crate::failure::FailureEvent {
                    at_hours: 1.0,
                    gpu: 3,
                    is_hw: true,
                    recover_at_hours: 5.0,
                    kind: EventKind::Fail,
                },
                crate::failure::FailureEvent {
                    at_hours: 2.0,
                    gpu: 9,
                    is_hw: false,
                    recover_at_hours: 4.0,
                    kind: EventKind::Fail,
                },
                // overlapping re-failure of gpu 3: extends to 7.0, the
                // 5.0 recovery entry goes stale (a no-op boundary)
                crate::failure::FailureEvent {
                    at_hours: 3.0,
                    gpu: 3,
                    is_hw: false,
                    recover_at_hours: 7.0,
                    kind: EventKind::Fail,
                },
            ],
        };
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        rep.advance(0.0);
        let mut boundaries = Vec::new();
        let mut failed = Vec::new();
        while let Some(t) = rep.next_change_hours() {
            boundaries.push(t);
            failed.push(rep.advance(t).n_failed());
        }
        // arrivals 1,2,3; recoveries 4 (gpu 9), 5 (stale), 7 (gpu 3)
        assert_eq!(boundaries, vec![1.0, 2.0, 3.0, 4.0, 5.0, 7.0]);
        assert_eq!(failed, vec![1, 2, 2, 1, 1, 0]);
        // every boundary matches the from-scratch replay
        for (&t, &f) in boundaries.iter().zip(&failed) {
            assert_eq!(trace.replay_to(&topo, BlastRadius::Single, t).n_failed(), f);
        }
        assert_eq!(rep.next_change_hours(), None);
        // empty trace: no boundaries at all
        let quiet = Trace { horizon_hours: 5.0, events: vec![] };
        let rep = FleetReplayer::new(&quiet, &topo, BlastRadius::Single);
        assert_eq!(rep.next_change_hours(), None);
    }

    #[test]
    fn degrade_and_fail_layers_replay_independently() {
        let topo = Topology::of(16, 8, 4);
        let trace = Trace {
            horizon_hours: 20.0,
            events: vec![
                // degrade gpu 3 on [1, 9)
                crate::failure::FailureEvent {
                    at_hours: 1.0,
                    gpu: 3,
                    is_hw: false,
                    recover_at_hours: 9.0,
                    kind: EventKind::Degrade { slowdown: 0.5 },
                },
                // hard-fail the same gpu on [2, 6): shadows the degrade
                crate::failure::FailureEvent {
                    at_hours: 2.0,
                    gpu: 3,
                    is_hw: true,
                    recover_at_hours: 6.0,
                    kind: EventKind::Fail,
                },
                // deeper overlapping degrade, ends before the first
                crate::failure::FailureEvent {
                    at_hours: 3.0,
                    gpu: 3,
                    is_hw: false,
                    recover_at_hours: 5.0,
                    kind: EventKind::Degrade { slowdown: 0.25 },
                },
            ],
        };
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        let expect = |t: f64, failed: usize, degraded: usize, slow: f64| {
            let scratch = trace.replay_to(&topo, BlastRadius::Single, t);
            assert_eq!(scratch.n_failed(), failed, "replay_to failed at t={t}");
            assert_eq!(scratch.n_degraded(), degraded, "replay_to degraded at t={t}");
            assert_eq!(scratch.domain_slowdowns()[0], slow, "replay_to slow at t={t}");
            scratch.check_invariants().unwrap();
        };
        assert_eq!(rep.advance(1.5).n_degraded(), 1);
        expect(1.5, 0, 1, 0.5);
        // failure shadows the degrade
        assert_eq!(rep.advance(2.5).n_failed(), 1);
        assert_eq!(rep.fleet().n_degraded(), 0);
        expect(2.5, 1, 0, 1.0);
        // at 4 the deeper 0.25 degrade is active but shadowed
        expect(4.0, 1, 0, 1.0);
        // at 6 the failure recovers; the 0.25 entry expired at 5, so the
        // surviving 0.5 degrade resurfaces at its own slowdown
        assert_eq!(rep.advance(6.0).n_failed(), 0);
        assert_eq!(rep.fleet().n_degraded(), 1);
        assert_eq!(rep.fleet().domain_slowdowns()[0], 0.5);
        expect(6.0, 0, 1, 0.5);
        // last degrade entry expires at 9
        assert_eq!(rep.advance(9.0).n_degraded(), 0);
        assert_eq!(rep.fleet().domain_slowdowns()[0], 1.0);
        expect(9.0, 0, 0, 1.0);
        rep.fleet().check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "forward in time")]
    fn rewinding_panics() {
        let topo = Topology::of(16, 8, 4);
        let trace = Trace { horizon_hours: 1.0, events: vec![] };
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        rep.advance(1.0);
        rep.advance(0.5);
    }

    fn hot_config(kind: ScenarioKind) -> ScenarioConfig {
        let mut cfg = ScenarioConfig::new(kind);
        cfg.correlated = cfg.correlated.scaled(2_000.0);
        cfg.straggler = cfg.straggler.scaled(300.0);
        cfg.sdc = cfg.sdc.scaled(2_000.0);
        cfg
    }

    fn all_kinds() -> [ScenarioKind; 4] {
        [
            ScenarioKind::Independent,
            ScenarioKind::Correlated,
            ScenarioKind::Straggler,
            ScenarioKind::Sdc,
        ]
    }

    /// Rebuild the deficit histogram / live spares / degraded-domain
    /// count from the fleet slices — the from-scratch oracle for the
    /// incremental aggregates.
    fn aggregates_from_scratch(
        fleet: &FleetHealth,
        n_job: usize,
    ) -> (Vec<u32>, usize, usize) {
        let ds = fleet.topo.domain_size;
        let mut hist = vec![0u32; ds + 1];
        let mut tail_full = 0;
        let mut job_degraded = 0;
        for d in 0..fleet.topo.n_domains() {
            let h = fleet.domain_healthy(d);
            if d < n_job {
                if ds - h > 0 {
                    hist[ds - h] += 1;
                }
                if fleet.domain_degraded_counts()[d] > 0 {
                    job_degraded += 1;
                }
            } else if h == ds {
                tail_full += 1;
            }
        }
        (hist, tail_full, job_degraded)
    }

    #[test]
    fn incremental_aggregates_match_from_scratch_on_every_boundary() {
        let topo = Topology::of(256, 16, 4);
        let model = FailureModel::llama3().scaled(250.0);
        let horizon = 24.0 * 8.0;
        for kind in all_kinds() {
            for (seed, n_job) in [(1u64, 16), (2, 12), (3, 10)] {
                let mut rng = Rng::new(seed);
                let trace =
                    generate_scenario(&topo, &model, &hot_config(kind), horizon, &mut rng);
                let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
                rep.set_job_domains(n_job);
                rep.advance(0.0);
                let mut boundaries = 0;
                while let Some(t) = rep.next_change_hours() {
                    rep.advance(t);
                    let (hist, tail_full, job_degraded) =
                        aggregates_from_scratch(rep.fleet(), n_job);
                    assert_eq!(
                        rep.deficit_histogram(),
                        &hist[..],
                        "{kind:?} seed {seed} n_job {n_job} hist at t={t}"
                    );
                    assert_eq!(rep.live_spare_domains(), tail_full, "{kind:?} spares at t={t}");
                    assert_eq!(
                        rep.job_degraded_domains(),
                        job_degraded,
                        "{kind:?} degraded at t={t}"
                    );
                    boundaries += 1;
                }
                assert!(boundaries > 0, "{kind:?} had no boundaries");
            }
        }
    }

    #[test]
    fn dirty_domains_are_exactly_the_changed_domains() {
        let topo = Topology::of(256, 16, 4);
        let model = FailureModel::llama3().scaled(250.0);
        let mut rng = Rng::new(9);
        let trace = generate_scenario(
            &topo,
            &model,
            &hot_config(ScenarioKind::Straggler),
            24.0 * 8.0,
            &mut rng,
        );
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        rep.advance(0.0);
        rep.clear_dirty();
        let mut prev: Vec<(usize, usize, f64)> = (0..topo.n_domains())
            .map(|d| {
                (
                    rep.fleet().domain_healthy(d),
                    rep.fleet().domain_degraded_counts()[d],
                    rep.fleet().domain_slowdowns()[d],
                )
            })
            .collect();
        while let Some(t) = rep.next_change_hours() {
            rep.advance(t);
            let actually_changed: Vec<u32> = (0..topo.n_domains())
                .filter(|&d| {
                    let now = (
                        rep.fleet().domain_healthy(d),
                        rep.fleet().domain_degraded_counts()[d],
                        rep.fleet().domain_slowdowns()[d],
                    );
                    now != prev[d]
                })
                .map(|d| d as u32)
                .collect();
            let mut dirty: Vec<u32> = rep.dirty_domains().to_vec();
            dirty.sort_unstable();
            // Dirty is a superset (net-zero touches may linger), but
            // every actual change must be flagged.
            for d in &actually_changed {
                assert!(dirty.contains(d), "domain {d} changed at t={t} but not dirty");
            }
            for &d in &dirty {
                prev[d as usize] = (
                    rep.fleet().domain_healthy(d as usize),
                    rep.fleet().domain_degraded_counts()[d as usize],
                    rep.fleet().domain_slowdowns()[d as usize],
                );
            }
            rep.clear_dirty();
        }
        assert!(rep.dirty_domains().is_empty());
    }

    #[test]
    fn streamed_replay_is_bit_identical_to_materialized_replay() {
        let topo = Topology::of(256, 16, 4);
        let model = FailureModel::llama3().scaled(100.0);
        let horizon = 24.0 * 10.0;
        for kind in all_kinds() {
            let cfg = hot_config(kind);
            let stream = TraceStream::new(&topo, &model, &cfg, horizon, Rng::new(1234));
            let trace = stream.clone().collect_trace();
            let mut live = ReplayCore::from_source(stream, &topo, BlastRadius::Single);
            let mut mat = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
            live.advance(0.0);
            mat.advance(0.0);
            loop {
                let (a, b) = (live.next_change_hours(), mat.next_change_hours());
                assert_eq!(a, b, "{kind:?} boundary mismatch");
                let Some(t) = a else { break };
                live.advance(t);
                mat.advance(t);
                assert_eq!(
                    live.fleet().domain_healthy_counts(),
                    mat.fleet().domain_healthy_counts(),
                    "{kind:?} counts at t={t}"
                );
                assert_eq!(
                    live.fleet().domain_slowdowns(),
                    mat.fleet().domain_slowdowns(),
                    "{kind:?} slowdowns at t={t}"
                );
            }
            live.drain_source();
            mat.drain_source();
            assert_eq!(live.sdc_pairs(), mat.sdc_pairs(), "{kind:?} sdc pairs");
        }
    }

    #[test]
    fn drained_sdc_pairs_match_the_trace_scan() {
        let topo = Topology::of(256, 16, 4);
        let model = FailureModel::llama3().scaled(50.0);
        let mut rng = Rng::new(77);
        let trace = generate_scenario(
            &topo,
            &model,
            &hot_config(ScenarioKind::Sdc),
            24.0 * 10.0,
            &mut rng,
        );
        let expected: Vec<(f64, f64)> = trace
            .events
            .iter()
            .filter_map(|ev| match ev.kind {
                EventKind::Sdc { corrupt_at_hours }
                    if ev.at_hours > 0.0 && ev.at_hours < trace.horizon_hours =>
                {
                    Some((ev.at_hours, corrupt_at_hours))
                }
                _ => None,
            })
            .collect();
        assert!(!expected.is_empty());
        // Grid-style early stop: advance partway, then drain.
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        rep.advance(trace.horizon_hours * 0.3);
        rep.drain_source();
        assert_eq!(rep.sdc_pairs(), &expected[..]);
        // Exact-style full walk collects them without draining.
        let mut rep = FleetReplayer::new(&trace, &topo, BlastRadius::Single);
        while let Some(t) = rep.next_change_hours() {
            rep.advance(t);
        }
        rep.drain_source();
        assert_eq!(rep.sdc_pairs(), &expected[..]);
    }
}
