//! Fleet simulation: evolve a failure trace against a training job and
//! integrate throughput over time (Figs. 4, 6, 7 and the fleet_sim
//! example). A precomputed [`StrategyTable`] makes per-event evaluation
//! O(#replicas) instead of re-running the iteration model.

use super::spares::SparePolicy;
use crate::cluster::Topology;
use crate::failure::{BlastRadius, FleetReplayer, Trace};
use crate::parallel::ParallelConfig;
use crate::policy::{EvalOut, FtPolicy, PolicyCtx, TransitionCosts};
use crate::power::{min_boost_for, BoostDecision, RackDesign};
use crate::sim::engine::{
    healthy_reshard_factor, max_batch_within, min_supported_tp, FtStrategy,
};
use crate::sim::IterationModel;

/// Precomputed per-TP-degree responses for one (sim, cfg, strategy).
#[derive(Clone, Debug)]
pub struct StrategyTable {
    pub full_tp: usize,
    pub full_local_batch: usize,
    pub min_tp: usize,
    /// `batch[t]` — local batch the replica can run at TP degree
    /// `min_tp + t` (plain NTP); `power[t]` — boost under NTP-PW
    /// (`None` ⇒ PW infeasible, falls back to `batch_pw[t]`).
    pub batch: Vec<usize>,
    pub power: Vec<Option<f64>>,
    pub batch_pw: Vec<usize>,
    /// Healthy-replica throughput factor in a nonuniform group —
    /// [`healthy_reshard_factor`] (CopyPlan traffic over the scale-up
    /// link) instead of the former hard-coded `0.995`.
    pub reshard_overhead: f64,
}

impl StrategyTable {
    pub fn build(sim: &IterationModel, cfg: &ParallelConfig, rack: &RackDesign) -> StrategyTable {
        let full_tp = cfg.tp;
        let min_tp = min_supported_tp(full_tp);
        let full_local = (sim.work.global_batch() / cfg.dp.max(1)).max(1);
        let healthy_time = sim.healthy_iteration(cfg).total();
        let mut batch = Vec::new();
        let mut power = Vec::new();
        let mut batch_pw = Vec::new();
        for tp in min_tp..full_tp {
            batch.push(max_batch_within(sim, cfg, tp, full_local, healthy_time, 1.0));
            match min_boost_for(sim, cfg, tp, full_local, healthy_time, rack, &sim.cluster.gpu) {
                BoostDecision::NotNeeded => {
                    power.push(Some(1.0));
                    batch_pw.push(full_local);
                }
                BoostDecision::Boost { power_frac } => {
                    power.push(Some(power_frac));
                    batch_pw.push(full_local);
                }
                BoostDecision::Infeasible { max_power_frac } => {
                    power.push(None);
                    let perf = sim.cluster.gpu.perf_at_power(max_power_frac);
                    batch_pw.push(max_batch_within(
                        sim, cfg, tp, full_local, healthy_time, perf,
                    ));
                }
            }
        }
        StrategyTable {
            full_tp,
            full_local_batch: full_local,
            min_tp,
            batch,
            power,
            batch_pw,
            reshard_overhead: healthy_reshard_factor(sim, cfg),
        }
    }

    /// Local batch a replica at TP `tp` contributes under `strategy`
    /// (0 = dropped).
    pub fn replica_batch(&self, tp: usize, strategy: FtStrategy) -> usize {
        if tp >= self.full_tp {
            return self.full_local_batch;
        }
        match strategy {
            FtStrategy::DpDrop => 0,
            _ if tp < self.min_tp => 0,
            FtStrategy::Ntp => self.batch[tp - self.min_tp],
            FtStrategy::NtpPw => self.batch_pw[tp - self.min_tp],
        }
    }

    /// Fraction of the target minibatch the group processes (no overhead
    /// terms — the fixed-minibatch pause criterion).
    pub fn group_minibatch_frac(&self, replica_tp: &[usize], strategy: FtStrategy) -> f64 {
        let processed: usize =
            replica_tp.iter().map(|&tp| self.replica_batch(tp, strategy)).sum();
        processed as f64 / (self.full_local_batch * replica_tp.len()) as f64
    }

    /// Group relative throughput for per-replica TP degrees.
    pub fn group_throughput(&self, replica_tp: &[usize], strategy: FtStrategy) -> f64 {
        let processed: usize =
            replica_tp.iter().map(|&tp| self.replica_batch(tp, strategy)).sum();
        let capacity = self.full_local_batch * replica_tp.len();
        let frac = processed as f64 / capacity as f64;
        let nonuniform = strategy != FtStrategy::DpDrop
            && replica_tp.iter().any(|&t| t < self.full_tp && t >= self.min_tp);
        if nonuniform {
            frac * self.reshard_overhead // healthy-replica reshard overhead (§6.2)
        } else {
            frac
        }
    }
}

/// Time-integrated fleet statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FleetStats {
    /// Time-weighted mean relative throughput (steady-state, i.e. not
    /// including transition downtime — see [`FleetStats::net_throughput`]).
    pub mean_throughput: f64,
    /// Fraction of time the job was paused (fixed minibatch unmet).
    pub paused_frac: f64,
    /// Mean spares in use.
    pub mean_spares_used: f64,
    /// Throughput normalized per *provisioned* GPU (incl. spares).
    pub throughput_per_gpu: f64,
    /// Fraction of fleet GPU-time lost to policy reconfiguration
    /// transitions. Exactly `0.0` when the sim runs without a
    /// [`TransitionCosts`] model.
    pub downtime_frac: f64,
    /// Sampled health changes that triggered a policy transition.
    pub transitions: usize,
    /// Mean secondary-channel capacity fraction
    /// ([`crate::policy::PolicyResponse::donated`]): low-priority
    /// donation or saved dark-spare power, per provisioned GPU. Exactly
    /// `0.0` for policies with no secondary channel.
    pub mean_donated: f64,
}

impl FleetStats {
    /// Mean throughput net of modeled transition downtime (first-order:
    /// transitions produce zero useful work while they last).
    pub fn net_throughput(&self) -> f64 {
        (self.mean_throughput * (1.0 - self.downtime_frac)).max(0.0)
    }

    /// Per-provisioned-GPU throughput net of transition downtime.
    pub fn net_throughput_per_gpu(&self) -> f64 {
        (self.throughput_per_gpu * (1.0 - self.downtime_frac)).max(0.0)
    }
}

/// Fleet simulator over a failure trace: drives any [`FtPolicy`]
/// through the event-driven sweep and integrates steady-state
/// throughput plus modeled reconfiguration downtime.
pub struct FleetSim<'a> {
    pub topo: &'a Topology,
    pub table: &'a StrategyTable,
    pub domains_per_replica: usize,
    /// Fault-tolerance policy under evaluation (legacy strategies via
    /// [`FtStrategy::policy`], new ones via [`crate::policy::registry`]).
    pub policy: &'a dyn FtPolicy,
    /// `None` ⇒ flexible minibatch (Fig. 6 semantics: reduced replicas
    /// just shrink the batch). `Some(policy)` ⇒ fixed minibatch with
    /// spares + pausing (Fig. 7 semantics).
    pub spares: Option<SparePolicy>,
    pub packed: bool,
    pub blast: BlastRadius,
    /// `Some` ⇒ charge each policy's transition cost whenever the
    /// sampled per-domain health changes; `None` ⇒ reconfigurations are
    /// free (the pre-policy-layer model, and the setting under which
    /// the legacy ports are bit-identical to the old `FtStrategy` paths).
    pub transition: Option<TransitionCosts>,
}

impl<'a> FleetSim<'a> {
    /// Run the trace, sampling at `step_hours`, and integrate.
    ///
    /// The trace is swept *once* by a [`FleetReplayer`] — O(events)
    /// instead of the O(steps × events) per-step
    /// [`Trace::replay_to`] rebuild (kept as
    /// [`FleetSim::run_replay_per_step`] for the equivalence tests and
    /// the perf benches). Samples between which no failure/recovery
    /// landed reuse the previous evaluation verbatim
    /// ([`crate::cluster::FleetHealth::version`]), so the result is
    /// bit-identical.
    pub fn run(&self, trace: &Trace, step_hours: f64) -> FleetStats {
        let n_steps = (trace.horizon_hours / step_hours).ceil() as usize;
        let mut rep = FleetReplayer::new(trace, self.topo, self.blast);
        let mut acc = Accum::default();
        let mut last: Option<(u64, EvalOut)> = None;
        let mut prev_counts: Vec<usize> = Vec::new();
        for step in 0..n_steps {
            let t = step as f64 * step_hours;
            let fleet = rep.advance(t);
            let out = match last {
                Some((version, out)) if version == fleet.version() => out,
                _ => {
                    let counts = fleet.domain_healthy_counts();
                    if step == 0 {
                        prev_counts = counts.to_vec();
                    } else if counts != &prev_counts[..] {
                        acc.charge(
                            self.policy,
                            &self.ctx(self.live_spares_in(counts)),
                            &prev_counts,
                            counts,
                        );
                        prev_counts.clear();
                        prev_counts.extend_from_slice(counts);
                    }
                    self.evaluate(counts)
                }
            };
            last = Some((fleet.version(), out));
            acc.sample(out);
        }
        self.integrate(n_steps, step_hours, acc)
    }

    /// Reference implementation of [`FleetSim::run`]: rebuild the fleet
    /// state from scratch at every sample via [`Trace::replay_to`].
    /// O(steps × events) — exists to demonstrate (tests) and measure
    /// (benches/perf_hotpath.rs) the event-driven path's equivalence and
    /// speedup.
    pub fn run_replay_per_step(&self, trace: &Trace, step_hours: f64) -> FleetStats {
        let n_steps = (trace.horizon_hours / step_hours).ceil() as usize;
        let mut acc = Accum::default();
        let mut prev_counts: Vec<usize> = Vec::new();
        for step in 0..n_steps {
            let t = step as f64 * step_hours;
            let fleet = trace.replay_to(self.topo, self.blast, t);
            let healthy = fleet.domain_healthy_counts();
            if step == 0 {
                prev_counts = healthy.to_vec();
            } else if healthy != &prev_counts[..] {
                acc.charge(
                    self.policy,
                    &self.ctx(self.live_spares_in(healthy)),
                    &prev_counts,
                    healthy,
                );
                prev_counts.clear();
                prev_counts.extend_from_slice(healthy);
            }
            acc.sample(self.evaluate(healthy));
        }
        self.integrate(n_steps, step_hours, acc)
    }

    fn integrate(&self, n_steps: usize, step_hours: f64, acc: Accum) -> FleetStats {
        let spare_gpus = self
            .spares
            .map(|p| p.spare_domains * self.topo.domain_size)
            .unwrap_or(0);
        acc.finalize(n_steps, step_hours, self.topo.n_gpus, spare_gpus)
    }

    /// The policy context for one evaluation. `live_spares` is the
    /// fixed-minibatch pool after removing failed spare domains.
    pub(crate) fn ctx(&self, live_spares: Option<SparePolicy>) -> PolicyCtx<'_> {
        PolicyCtx {
            table: self.table,
            domain_size: self.topo.domain_size,
            domains_per_replica: self.domains_per_replica,
            packed: self.packed,
            spares: live_spares,
            n_gpus: self.topo.n_gpus,
            transition: self.transition,
        }
    }

    /// The live-spare-adjusted pool for one *full-fleet* snapshot —
    /// [`super::spares::split_job_spares`], which both the steady-state
    /// evaluation and the transition charge (and the shared-sweep
    /// engine) derive the policy context through, so a failed spare
    /// domain is reflected identically in throughput and in the charged
    /// reconfiguration cost.
    pub(crate) fn live_spares_in(&self, domain_healthy: &[usize]) -> Option<SparePolicy> {
        self.spares.map(|pool| {
            super::spares::split_job_spares(domain_healthy, self.topo.domain_size, &pool).1
        })
    }

    /// Evaluate one snapshot: the integrated [`EvalOut`] quantities.
    pub fn evaluate(&self, domain_healthy: &[usize]) -> EvalOut {
        match self.spares {
            None => {
                let resp = self.policy.respond(&self.ctx(None), domain_healthy);
                EvalOut::of(&resp, self.table.full_local_batch)
            }
            Some(pool) => {
                let (job_healthy, live) = super::spares::split_job_spares(
                    domain_healthy,
                    self.topo.domain_size,
                    &pool,
                );
                let resp = self.policy.respond(&self.ctx(Some(live)), job_healthy);
                EvalOut::of(&resp, self.table.full_local_batch)
            }
        }
    }
}

/// Shared integration state of every sweep implementation
/// (event-driven, per-step, and the shared multi-policy engine in
/// [`super::sweep`]), so all paths stay operation-for-operation
/// identical (the bit-identity the equivalence tests assert).
#[derive(Clone, Default)]
pub(crate) struct Accum {
    tput_sum: f64,
    paused: usize,
    spares_sum: f64,
    donated_sum: f64,
    transitions: usize,
    cost_gpu_secs: f64,
}

impl Accum {
    pub(crate) fn sample(&mut self, out: EvalOut) {
        self.tput_sum += out.tput;
        self.paused += usize::from(out.paused);
        self.spares_sum += out.spares_used as f64;
        self.donated_sum += out.donated;
    }

    /// Charge the policy's transition cost for a sampled health change
    /// (events landing between two samples collapse into one charge —
    /// all sweep paths sample on the same grid, so all see the same
    /// transitions). `ctx` must carry the live-spare-adjusted pool of
    /// the `next` snapshot ([`FleetSim::live_spares_in`]).
    pub(crate) fn charge(
        &mut self,
        policy: &dyn FtPolicy,
        ctx: &PolicyCtx,
        prev: &[usize],
        next: &[usize],
    ) {
        self.charge_cost(policy.transition_cost(ctx, prev, next));
    }

    /// [`Accum::charge`] with the cost already computed — the shared
    /// sweep's count-keyed transition memo
    /// ([`crate::manager::ResponseMemo`]) lands here, so the memoized
    /// and direct paths add the identical `f64`.
    pub(crate) fn charge_cost(&mut self, cost_gpu_secs: f64) {
        self.transitions += 1;
        self.cost_gpu_secs += cost_gpu_secs;
    }

    /// Integrate the accumulated samples into a [`FleetStats`]
    /// (verbatim the former `FleetSim::integrate` body, shared so every
    /// sweep path produces bit-identical statistics).
    pub(crate) fn finalize(
        &self,
        n_steps: usize,
        step_hours: f64,
        n_gpus: usize,
        spare_gpus: usize,
    ) -> FleetStats {
        let n = n_steps as f64;
        let job_gpus = n_gpus - spare_gpus;
        let mean_tput = self.tput_sum / n;
        let horizon_secs = n * step_hours * 3600.0;
        let downtime_frac = if horizon_secs > 0.0 {
            (self.cost_gpu_secs / (n_gpus as f64 * horizon_secs)).min(1.0)
        } else {
            0.0
        };
        FleetStats {
            mean_throughput: mean_tput,
            paused_frac: self.paused as f64 / n,
            mean_spares_used: self.spares_sum / n,
            throughput_per_gpu: mean_tput * job_gpus as f64 / n_gpus as f64,
            downtime_frac,
            transitions: self.transitions,
            mean_donated: self.donated_sum / n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Dtype, WorkloadConfig};
    use crate::failure::FailureModel;
    use crate::sim::SimParams;
    use crate::util::prng::Rng;

    fn small_setup() -> (IterationModel, ParallelConfig) {
        let sim = IterationModel::new(
            presets::model("gpt-480b").unwrap(),
            WorkloadConfig {
                seq_len: 16_384,
                minibatch_tokens: 2 * 1024 * 1024,
                dtype: Dtype::BF16,
            },
            presets::cluster("paper-32k-nvl32").unwrap(),
            SimParams::default(),
        );
        // 16 replicas x 4 domains x 32 GPUs = 2048 GPUs (kept small for tests)
        let cfg = ParallelConfig { tp: 32, pp: 4, dp: 16, microbatch: 1 };
        (sim, cfg)
    }

    #[test]
    fn table_matches_engine_semantics() {
        let (sim, cfg) = small_setup();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let t = StrategyTable::build(&sim, &cfg, &rack);
        assert_eq!(t.full_tp, 32);
        assert_eq!(t.min_tp, 28);
        // NTP batch decreases with deeper reduction
        for w in t.batch.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // PW keeps full batch wherever feasible
        for (i, p) in t.power.iter().enumerate() {
            if p.is_some() {
                assert_eq!(t.batch_pw[i], t.full_local_batch);
            }
        }
        // modeled reshard overhead is sub-percent, bounded by the
        // retired 0.995 constant
        assert!((0.995..1.0).contains(&t.reshard_overhead), "{}", t.reshard_overhead);
    }

    #[test]
    fn group_throughput_ordering() {
        let (sim, cfg) = small_setup();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let t = StrategyTable::build(&sim, &cfg, &rack);
        let tps = vec![32, 31, 30, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32];
        let drop = t.group_throughput(&tps, FtStrategy::DpDrop);
        let ntp = t.group_throughput(&tps, FtStrategy::Ntp);
        let pw = t.group_throughput(&tps, FtStrategy::NtpPw);
        assert!(drop < ntp && ntp <= pw, "drop {drop} ntp {ntp} pw {pw}");
        assert!((drop - 14.0 / 16.0).abs() < 1e-9);
        assert!(pw > 0.985);
    }

    #[test]
    fn fleet_sim_runs_and_integrates() {
        let (sim, cfg) = small_setup();
        let topo = Topology::of(cfg.n_gpus(), 32, 4);
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let table = StrategyTable::build(&sim, &cfg, &rack);
        let model = FailureModel::llama3().scaled(30.0); // dense failures for a small cluster
        let mut rng = Rng::new(5);
        let trace = Trace::generate(&topo, &model, 24.0 * 15.0, &mut rng);
        let fs = FleetSim {
            topo: &topo,
            table: &table,
            domains_per_replica: cfg.pp,
            policy: FtStrategy::Ntp.policy(),
            spares: None,
            packed: true,
            blast: BlastRadius::Single,
            transition: None,
        };
        let stats = fs.run(&trace, 6.0);
        assert!(stats.mean_throughput > 0.5 && stats.mean_throughput <= 1.0);
        assert_eq!(stats.paused_frac, 0.0);
        assert_eq!(stats.downtime_frac, 0.0);

        // DP-DROP must do worse on the same trace.
        let fs_drop = FleetSim { policy: FtStrategy::DpDrop.policy(), ..fs };
        let stats_drop = fs_drop.run(&trace, 6.0);
        assert!(stats_drop.mean_throughput < stats.mean_throughput);
    }

    #[test]
    fn event_driven_run_matches_per_step_replay() {
        let (sim, cfg) = small_setup();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let table = StrategyTable::build(&sim, &cfg, &rack);
        let topo = Topology::of(cfg.n_gpus(), 32, 4);
        let model = FailureModel::llama3().scaled(40.0);
        let mut rng = Rng::new(23);
        let trace = Trace::generate(&topo, &model, 24.0 * 20.0, &mut rng);
        for strategy in [FtStrategy::DpDrop, FtStrategy::Ntp] {
            let fs = FleetSim {
                topo: &topo,
                table: &table,
                domains_per_replica: cfg.pp,
                policy: strategy.policy(),
                spares: None,
                packed: true,
                blast: BlastRadius::Single,
                transition: None,
            };
            assert_eq!(fs.run(&trace, 2.0), fs.run_replay_per_step(&trace, 2.0));
        }
        let fs = FleetSim {
            topo: &topo,
            table: &table,
            domains_per_replica: cfg.pp,
            policy: FtStrategy::Ntp.policy(),
            spares: Some(SparePolicy { spare_domains: 4, min_tp: 28 }),
            packed: true,
            blast: BlastRadius::Node,
            transition: None,
        };
        assert_eq!(fs.run(&trace, 2.0), fs.run_replay_per_step(&trace, 2.0));
        // ... and with transition costs switched on, both sweep paths
        // must still agree exactly (downtime included).
        let fs_t = FleetSim {
            transition: Some(crate::policy::TransitionCosts::model(&sim, &cfg)),
            ..fs
        };
        let a = fs_t.run(&trace, 2.0);
        let b = fs_t.run_replay_per_step(&trace, 2.0);
        assert_eq!(a, b);
        assert!(a.transitions > 0 && a.downtime_frac > 0.0);
    }

    #[test]
    fn transition_charge_uses_live_spare_pool() {
        // Regression for the configured-vs-live spare mismatch: the
        // charge path used to build its PolicyCtx from the *configured*
        // `fs.spares` while `evaluate` used the live-adjusted pool. Both
        // now go through `live_spares_in`, so a failed spare domain
        // shrinks the pool seen by `transition_cost` — observable with
        // SPARE-MIG, whose migration bill is capped by the live pool.
        let (sim, cfg) = small_setup();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let table = StrategyTable::build(&sim, &cfg, &rack);
        // 16 job domains (4 replicas x 4) + 2 spare domains.
        let topo = Topology::of(18 * 32, 32, 4);
        let fs = FleetSim {
            topo: &topo,
            table: &table,
            domains_per_replica: 4,
            policy: crate::policy::registry::parse("spare-mig").unwrap(),
            spares: Some(SparePolicy { spare_domains: 2, min_tp: 28 }),
            packed: true,
            blast: BlastRadius::Single,
            transition: Some(crate::policy::TransitionCosts::model(&sim, &cfg)),
        };
        let prev = vec![32usize; 18];
        // Three fresh job-domain failures, and the last spare domain
        // also fails: live pool 1, configured pool 2.
        let mut next = prev.clone();
        next[0] = 31;
        next[4] = 31;
        next[8] = 31;
        next[17] = 31;
        let live = fs.live_spares_in(&next).unwrap();
        assert_eq!(live.spare_domains, 1);
        let charged = fs.policy.transition_cost(&fs.ctx(Some(live)), &prev, &next);
        let misconfigured = fs.policy.transition_cost(&fs.ctx(fs.spares), &prev, &next);
        // 4 degraded domains: the live pool migrates 1, the configured
        // pool would have billed 2 — the old derivation overcharged.
        assert!(
            charged < misconfigured,
            "live-pool charge {charged} should be below configured-pool {misconfigured}"
        );
        // With every spare alive, the two derivations agree.
        let mut next_spares_ok = prev.clone();
        next_spares_ok[0] = 31;
        next_spares_ok[4] = 31;
        next_spares_ok[8] = 31;
        let live_ok = fs.live_spares_in(&next_spares_ok).unwrap();
        assert_eq!(live_ok.spare_domains, 2);
        assert_eq!(
            fs.policy.transition_cost(&fs.ctx(Some(live_ok)), &prev, &next_spares_ok),
            fs.policy.transition_cost(&fs.ctx(fs.spares), &prev, &next_spares_ok),
        );
        // And the two sweep paths still agree bit-for-bit with the fix.
        let model = FailureModel::llama3().scaled(60.0);
        let mut rng = Rng::new(9);
        let trace = Trace::generate(&topo, &model, 24.0 * 20.0, &mut rng);
        assert_eq!(fs.run(&trace, 2.0), fs.run_replay_per_step(&trace, 2.0));
    }

    #[test]
    fn packing_improves_throughput_under_spread_failures() {
        let (sim, cfg) = small_setup();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let table = StrategyTable::build(&sim, &cfg, &rack);
        let topo = Topology::of(cfg.n_gpus(), 32, 4);
        // failures in 4 different replicas (one per 4-domain block)
        let mut healthy = vec![32usize; 64];
        healthy[0] = 31;
        healthy[5] = 31;
        healthy[9] = 31;
        healthy[13] = 31;
        let packed = FleetSim {
            topo: &topo,
            table: &table,
            domains_per_replica: 4,
            policy: FtStrategy::Ntp.policy(),
            spares: None,
            packed: true,
            blast: BlastRadius::Single,
            transition: None,
        };
        let unpacked = FleetSim { packed: false, ..packed };
        let tp_packed = packed.evaluate(&healthy).tput;
        let tp_unpacked = unpacked.evaluate(&healthy).tput;
        assert!(tp_packed >= tp_unpacked);
    }
}
