//! Fleet simulation: evolve a failure trace against a training job and
//! integrate throughput over time (Figs. 4, 6, 7 and the fleet_sim
//! example). A precomputed [`StrategyTable`] makes per-event evaluation
//! O(#replicas) instead of re-running the iteration model.
//!
//! Integration is **exact** by default ([`StepMode::Exact`]): the sweep
//! steps the [`FleetReplayer`] from one health-change boundary to the
//! next and weights every evaluation by the *duration* the snapshot was
//! live, so the integrated [`FleetStats`] carry no sampling
//! quantization at all — the result is a pure function of the trace.
//! The legacy fixed-grid sampling survives as [`StepMode::Grid`] (with
//! its former partial-last-step bias fixed by clamping the final
//! interval to the horizon) and converges to the exact stats as
//! `step_hours → 0` (`rust/tests/exact_integration.rs`).

use super::spares::SparePolicy;
use crate::cluster::Topology;
use crate::failure::{BlastRadius, DetectionModel, EventKind, FleetReplayer, Trace};
use crate::parallel::ParallelConfig;
use crate::policy::{EvalOut, FtPolicy, PolicyCtx, TransitionCosts};
use crate::power::{min_boost_for, BoostDecision, RackDesign};
use crate::sim::engine::{
    healthy_reshard_factor, max_batch_within, min_supported_tp, FtStrategy,
};
use crate::sim::IterationModel;

/// Precomputed per-TP-degree responses for one (sim, cfg, strategy).
#[derive(Clone, Debug)]
pub struct StrategyTable {
    pub full_tp: usize,
    pub full_local_batch: usize,
    pub min_tp: usize,
    /// `batch[t]` — local batch the replica can run at TP degree
    /// `min_tp + t` (plain NTP); `power[t]` — boost under NTP-PW
    /// (`None` ⇒ PW infeasible, falls back to `batch_pw[t]`).
    pub batch: Vec<usize>,
    pub power: Vec<Option<f64>>,
    pub batch_pw: Vec<usize>,
    /// Healthy-replica throughput factor in a nonuniform group —
    /// [`healthy_reshard_factor`] (CopyPlan traffic over the scale-up
    /// link) instead of the former hard-coded `0.995`.
    pub reshard_overhead: f64,
    /// Perf-sensitive fraction of the healthy iteration
    /// ([`IterationModel::perf_sensitive_fraction`]): the share of
    /// iteration time that stretches when a straggler paces its TP
    /// group. Exposed comm terms are insensitive, so a group paced by a
    /// GPU at slowdown `s` runs at `1/((1-phi) + phi/s)` of healthy
    /// speed ([`StrategyTable::straggler_drag`]).
    pub straggler_phi: f64,
    /// The rack power/thermal design the table was built against —
    /// load-bearing fleet-wide since the energy co-simulation: every
    /// policy's power snapshot ([`crate::policy::snapshot_power`]) reads
    /// the idle/standby/derate fractions from here, and NTP-PW's
    /// row-boost allowance ([`RackDesign::row_boost_allowance`]) caps
    /// how many boosted domains may coexist per row.
    pub rack: RackDesign,
}

impl StrategyTable {
    pub fn build(sim: &IterationModel, cfg: &ParallelConfig, rack: &RackDesign) -> StrategyTable {
        let full_tp = cfg.tp;
        let min_tp = min_supported_tp(full_tp);
        let full_local = (sim.work.global_batch() / cfg.dp.max(1)).max(1);
        let healthy_time = sim.healthy_iteration(cfg).total();
        let mut batch = Vec::new();
        let mut power = Vec::new();
        let mut batch_pw = Vec::new();
        for tp in min_tp..full_tp {
            batch.push(max_batch_within(sim, cfg, tp, full_local, healthy_time, 1.0));
            match min_boost_for(sim, cfg, tp, full_local, healthy_time, rack, &sim.cluster.gpu) {
                BoostDecision::NotNeeded => {
                    power.push(Some(1.0));
                    batch_pw.push(full_local);
                }
                BoostDecision::Boost { power_frac } => {
                    power.push(Some(power_frac));
                    batch_pw.push(full_local);
                }
                BoostDecision::Infeasible { max_power_frac } => {
                    power.push(None);
                    let perf = sim.cluster.gpu.perf_at_power(max_power_frac);
                    batch_pw.push(max_batch_within(
                        sim, cfg, tp, full_local, healthy_time, perf,
                    ));
                }
            }
        }
        StrategyTable {
            full_tp,
            full_local_batch: full_local,
            min_tp,
            batch,
            power,
            batch_pw,
            reshard_overhead: healthy_reshard_factor(sim, cfg),
            straggler_phi: sim.perf_sensitive_fraction(cfg, full_local),
            rack: *rack,
        }
    }

    /// Throughput multiplier of a TP group paced by a member delivering
    /// slowdown-fraction `s` of nominal speed: the perf-sensitive share
    /// of the iteration stretches by `1/s`, the exposed-communication
    /// remainder does not. Exactly `1.0` at `s = 1` (the guard keeps
    /// the no-straggler case bit-exact regardless of how
    /// `straggler_phi` rounds).
    pub fn straggler_drag(&self, slowdown: f64) -> f64 {
        if slowdown >= 1.0 {
            return 1.0;
        }
        let phi = self.straggler_phi;
        1.0 / ((1.0 - phi) + phi / slowdown.max(1e-9))
    }

    /// Capacity-weighted mean TP-group drag over a snapshot:
    /// `Σ_d healthy_d · drag(slowdown_d) / Σ_d healthy_d`. Each domain's
    /// group paces at its own slowest member (the flexible-minibatch
    /// model already lets groups contribute independently), so domains
    /// with no degraded member contribute drag exactly `1.0`.
    pub fn group_drag(&self, domain_healthy: &[usize], domain_slowdowns: &[f64]) -> f64 {
        let mut capacity = 0.0;
        let mut weighted = 0.0;
        for (&h, &s) in domain_healthy.iter().zip(domain_slowdowns) {
            let w = h as f64;
            capacity += w;
            weighted += w * self.straggler_drag(s);
        }
        if capacity <= 0.0 {
            1.0
        } else {
            weighted / capacity
        }
    }

    /// Local batch a replica at TP `tp` contributes under `strategy`
    /// (0 = dropped).
    pub fn replica_batch(&self, tp: usize, strategy: FtStrategy) -> usize {
        if tp >= self.full_tp {
            return self.full_local_batch;
        }
        match strategy {
            FtStrategy::DpDrop => 0,
            _ if tp < self.min_tp => 0,
            FtStrategy::Ntp => self.batch[tp - self.min_tp],
            FtStrategy::NtpPw => self.batch_pw[tp - self.min_tp],
        }
    }

    /// Fraction of the target minibatch the group processes (no overhead
    /// terms — the fixed-minibatch pause criterion).
    pub fn group_minibatch_frac(&self, replica_tp: &[usize], strategy: FtStrategy) -> f64 {
        let processed: usize =
            replica_tp.iter().map(|&tp| self.replica_batch(tp, strategy)).sum();
        processed as f64 / (self.full_local_batch * replica_tp.len()) as f64
    }

    /// Group relative throughput for per-replica TP degrees.
    pub fn group_throughput(&self, replica_tp: &[usize], strategy: FtStrategy) -> f64 {
        let processed: usize =
            replica_tp.iter().map(|&tp| self.replica_batch(tp, strategy)).sum();
        let capacity = self.full_local_batch * replica_tp.len();
        let frac = processed as f64 / capacity as f64;
        let nonuniform = strategy != FtStrategy::DpDrop
            && replica_tp.iter().any(|&t| t < self.full_tp && t >= self.min_tp);
        if nonuniform {
            frac * self.reshard_overhead // healthy-replica reshard overhead (§6.2)
        } else {
            frac
        }
    }
}

/// Time-integrated fleet statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FleetStats {
    /// Time-weighted mean relative throughput (steady-state, i.e. not
    /// including transition downtime — see [`FleetStats::net_throughput`]).
    pub mean_throughput: f64,
    /// Fraction of time the job was paused (fixed minibatch unmet).
    pub paused_frac: f64,
    /// Mean spares in use.
    pub mean_spares_used: f64,
    /// Throughput normalized per *provisioned* GPU (incl. spares).
    pub throughput_per_gpu: f64,
    /// Fraction of fleet GPU-time lost to policy reconfiguration
    /// transitions. Exactly `0.0` when the sim runs without a
    /// [`TransitionCosts`] model.
    pub downtime_frac: f64,
    /// Sampled health changes that triggered a policy transition.
    pub transitions: usize,
    /// Mean secondary-channel capacity fraction
    /// ([`crate::policy::PolicyResponse::donated`]): low-priority
    /// donation or saved dark-spare power, per provisioned GPU. Exactly
    /// `0.0` for policies with no secondary channel.
    pub mean_donated: f64,
    /// Time-weighted mean fleet power fraction
    /// ([`crate::policy::PolicyResponse::power`]): the second exact
    /// integrand, riding the same duration-weighted accumulator as
    /// throughput. Exactly `1.0` over a failure-free horizon with no
    /// spares (every GPU at nominal draw the whole time).
    pub mean_power_frac: f64,
    /// Peak single-domain power fraction observed across the horizon
    /// ([`crate::policy::PolicyResponse::rack_power`]): above `1.0`
    /// only when a policy boosted survivors past TDP on a flexible
    /// rack. A max, not an integral — but still a pure function of the
    /// trace (every snapshot between event boundaries is visited).
    pub peak_rack_power_frac: f64,
}

impl FleetStats {
    /// Mean throughput net of modeled transition downtime (first-order:
    /// transitions produce zero useful work while they last).
    pub fn net_throughput(&self) -> f64 {
        (self.mean_throughput * (1.0 - self.downtime_frac)).max(0.0)
    }

    /// Per-provisioned-GPU throughput net of transition downtime.
    pub fn net_throughput_per_gpu(&self) -> f64 {
        (self.throughput_per_gpu * (1.0 - self.downtime_frac)).max(0.0)
    }

    /// Energy per useful token, in units of (fleet-TDP-hours per
    /// healthy-fleet-token-hour): mean power fraction over net
    /// throughput. Lower is better — the throughput-per-watt ranking
    /// of the `fig13_energy` bench is the reciprocal. `0.0` (not
    /// `inf`/NaN) when the job made no progress, so the value survives
    /// the hand-rolled JSON emitters.
    pub fn energy_per_token(&self) -> f64 {
        let net = self.net_throughput();
        if net <= 0.0 {
            0.0
        } else {
            self.mean_power_frac / net
        }
    }
}

/// How a fleet sweep steps through time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepMode {
    /// Exact event-boundary integration: evaluate once per actual
    /// health change, weight by the interval the snapshot was live.
    /// The integrated [`FleetStats`] are a pure function of the trace —
    /// no sampling grid, no quantization, and invariant to any added
    /// sampling refinement ([`FleetSim::run_exact_with_refinement`]).
    Exact,
    /// Legacy fixed-grid sampling every `.0` hours: events landing
    /// between two samples collapse into one observed change (one
    /// transition charge), and state changes are only seen at sample
    /// times. Kept for convergence tests and step-size studies;
    /// converges to [`StepMode::Exact`] as the step shrinks.
    Grid(f64),
}

/// Start time and clamped duration of grid step `step`, or `None` once
/// the step would begin at/after the horizon. The final interval is
/// clamped to `horizon_hours`: the former `n_steps =
/// ceil(horizon/step)` loop integrated a full step past the horizon,
/// overweighting whatever state the last sample happened to see
/// (regression-tested in `rust/tests/exact_integration.rs`).
pub(crate) fn grid_step(step: usize, step_hours: f64, horizon_hours: f64) -> Option<(f64, f64)> {
    assert!(step_hours > 0.0, "grid step must be positive (got {step_hours})");
    let t = step as f64 * step_hours;
    if t >= horizon_hours {
        return None;
    }
    let end = ((step + 1) as f64 * step_hours).min(horizon_hours);
    Some((t, end - t))
}

/// Candidate state-change times of a trace within `(0, horizon)`:
/// every failure arrival and recovery deadline, time-sorted and
/// deduplicated — the boundary set the per-step exact reference
/// ([`FleetSim::run_replay_per_step`]) walks. The event-driven sweep
/// discovers the same set incrementally via
/// [`FleetReplayer::next_change_hours`] (its lazily-deleted recovery
/// entries are a subset of the `recover_at_hours` values collected
/// here, and boundaries where nothing actually changes are no-ops in
/// both paths).
pub(crate) fn exact_boundaries(trace: &Trace) -> Vec<f64> {
    let mut ts: Vec<f64> = Vec::with_capacity(trace.events.len() * 2);
    for ev in &trace.events {
        if ev.at_hours > 0.0 && ev.at_hours < trace.horizon_hours {
            ts.push(ev.at_hours);
        }
        if ev.recover_at_hours > 0.0 && ev.recover_at_hours < trace.horizon_hours {
            ts.push(ev.recover_at_hours);
        }
    }
    ts.sort_by(f64::total_cmp);
    ts.dedup();
    ts
}

/// Detection-lag rollback bill of every SDC event in the trace,
/// GPU-seconds. A silent corruption at `corrupt_at_hours` is invisible
/// until the validation sweep detects it at `at_hours`; the whole job
/// then discards the work done during the detection lag plus (on
/// average) half a checkpoint interval to roll back behind the
/// corruption. Policy-independent — every policy trusts the validation
/// sweep, so all sweep paths (single-policy, per-step reference and the
/// shared multi-policy engine) charge the identical `f64` via
/// [`Accum::charge_rollback`]. Zero for traces without SDC events and
/// when reconfigurations are free (no [`TransitionCosts`] model).
pub(crate) fn sdc_rollback_gpu_secs(trace: &Trace, costs: &TransitionCosts, n_gpus: usize) -> f64 {
    let mut total = 0.0;
    for ev in &trace.events {
        if let EventKind::Sdc { corrupt_at_hours } = ev.kind {
            if ev.at_hours > 0.0 && ev.at_hours < trace.horizon_hours {
                let lag_secs = (ev.at_hours - corrupt_at_hours) * 3600.0;
                total += (lag_secs + 0.5 * costs.checkpoint_interval_secs) * n_gpus as f64;
            }
        }
    }
    total
}

/// [`sdc_rollback_gpu_secs`] computed from the `(detect hours, corrupt
/// hours)` pairs a [`crate::failure::ReplayCore`] records while pulling
/// events, instead of a trace scan — the form the streaming sweep
/// needs, since it never materializes a trace. The replayer applies the
/// same in-horizon filter at record time and pulls events in trace
/// order, so the per-event terms here are added in the identical order
/// with the identical operands: the two functions MUST stay in lockstep
/// (same term, same order) or the stream/materialized bit-identity
/// contract breaks (`rust/tests/replay_equivalence.rs` pins it).
pub(crate) fn sdc_rollback_from_pairs(
    pairs: &[(f64, f64)],
    costs: &TransitionCosts,
    n_gpus: usize,
) -> f64 {
    let mut total = 0.0;
    for &(at_hours, corrupt_at_hours) in pairs {
        let lag_secs = (at_hours - corrupt_at_hours) * 3600.0;
        total += (lag_secs + 0.5 * costs.checkpoint_interval_secs) * n_gpus as f64;
    }
    total
}

/// Amortized periodic validation-sweep stall over the whole horizon,
/// GPU-seconds: [`TransitionCosts::validation_sweep_secs`] is the
/// per-GPU stall per simulated hour, so the fleet-wide bill is `field ×
/// horizon × n_gpus`. Policy- and trace-independent (the sweep runs on
/// a wall-clock cadence whether or not corruption ever fires), charged
/// through the rollback channel by every sweep path. Zero at the
/// default `validation_sweep_secs = 0.0`, which keeps every golden
/// output bitwise unchanged.
pub(crate) fn validation_sweep_gpu_secs(
    costs: &TransitionCosts,
    horizon_hours: f64,
    n_gpus: usize,
) -> f64 {
    costs.validation_sweep_secs * horizon_hours * n_gpus as f64
}

/// Fleet simulator over a failure trace: drives any [`FtPolicy`]
/// through the event-driven sweep and integrates steady-state
/// throughput plus modeled reconfiguration downtime.
pub struct FleetSim<'a> {
    pub topo: &'a Topology,
    pub table: &'a StrategyTable,
    pub domains_per_replica: usize,
    /// Fault-tolerance policy under evaluation (legacy strategies via
    /// [`FtStrategy::policy`], new ones via [`crate::policy::registry`]).
    pub policy: &'a dyn FtPolicy,
    /// `None` ⇒ flexible minibatch (Fig. 6 semantics: reduced replicas
    /// just shrink the batch). `Some(policy)` ⇒ fixed minibatch with
    /// spares + pausing (Fig. 7 semantics).
    pub spares: Option<SparePolicy>,
    pub packed: bool,
    pub blast: BlastRadius,
    /// `Some` ⇒ charge each policy's transition cost whenever the
    /// sampled per-domain health changes; `None` ⇒ reconfigurations are
    /// free (the pre-policy-layer model, and the setting under which
    /// the legacy ports are bit-identical to the old `FtStrategy` paths).
    pub transition: Option<TransitionCosts>,
    /// Imperfect failure detection: when active, the trace is first
    /// materialized through [`DetectionModel::delay_trace`] — the
    /// policy sweeps the *detected* view, undetected stall is billed
    /// through the rollback channel, and the expected false-positive
    /// evictions are charged via
    /// [`FtPolicy::false_positive_cost`]. `None` (or the all-zero
    /// model) is bit-identical to the pre-detection path.
    pub detect: Option<DetectionModel>,
}

impl<'a> FleetSim<'a> {
    /// Run the trace under `mode` and integrate.
    ///
    /// The trace is swept *once* by a [`FleetReplayer`] — O(events)
    /// instead of the O(steps × events) per-step
    /// [`Trace::replay_to`] rebuild (kept as
    /// [`FleetSim::run_replay_per_step`] for the equivalence tests and
    /// the perf benches). In [`StepMode::Exact`] the sweep jumps from
    /// one health-change boundary to the next
    /// ([`FleetReplayer::next_change_hours`]), evaluates once per
    /// actual change, and weights every evaluation by the interval it
    /// was live — the stats are exact for the trace and every
    /// transition is charged at the event that caused it. In
    /// [`StepMode::Grid`] the legacy fixed-grid semantics apply
    /// (samples between which no failure/recovery landed reuse the
    /// previous evaluation verbatim via
    /// [`crate::cluster::FleetHealth::version`]).
    pub fn run(&self, trace: &Trace, mode: StepMode) -> FleetStats {
        if let Some(d) = DetectionModel::active(&self.detect) {
            let (seen, stall) = d.delay_trace(trace, self.topo.n_gpus);
            return match mode {
                StepMode::Exact => self.run_exact(&seen, &[], stall),
                StepMode::Grid(step_hours) => self.run_grid(&seen, step_hours, stall),
            };
        }
        match mode {
            StepMode::Exact => self.run_exact(trace, &[], 0.0),
            StepMode::Grid(step_hours) => self.run_grid(trace, step_hours, 0.0),
        }
    }

    /// [`StepMode::Exact`] with extra *refinement* sample times merged
    /// into the boundary stream (must be sorted ascending). The result
    /// is bit-identical to `run(trace, StepMode::Exact)` for ANY
    /// refinement: integration intervals close only when the per-domain
    /// health actually changes, so an added sample evaluates to the
    /// state already live and contributes nothing — the invariance
    /// property `rust/tests/exact_integration.rs` pins.
    pub fn run_exact_with_refinement(&self, trace: &Trace, extra: &[f64]) -> FleetStats {
        if let Some(d) = DetectionModel::active(&self.detect) {
            let (seen, stall) = d.delay_trace(trace, self.topo.n_gpus);
            return self.run_exact(&seen, extra, stall);
        }
        self.run_exact(trace, extra, 0.0)
    }

    fn run_exact(&self, trace: &Trace, extra: &[f64], stall_gpu_hours: f64) -> FleetStats {
        assert!(
            extra.windows(2).all(|w| w[0] <= w[1]),
            "refinement times must be sorted ascending"
        );
        let horizon = trace.horizon_hours;
        let mut acc = Accum::default();
        if horizon <= 0.0 {
            return self.integrate(acc);
        }
        let mut rep = FleetReplayer::new(trace, self.topo, self.blast);
        let start = rep.advance(0.0);
        let mut prev_counts = start.domain_healthy_counts().to_vec();
        let mut prev_degraded = start.domain_degraded_counts().to_vec();
        let mut prev_slow = start.domain_slowdowns().to_vec();
        let mut out = self.evaluate_degraded(&prev_counts, &prev_degraded, &prev_slow);
        let mut seg_start = 0.0;
        let mut ei = 0usize;
        loop {
            // Refinement times already behind the sweep are no-ops.
            while ei < extra.len() && extra[ei] <= rep.now_hours() {
                ei += 1;
            }
            let change = rep.next_change_hours().filter(|&t| t < horizon);
            let refine = extra.get(ei).copied().filter(|&t| t < horizon);
            let t = match (change, refine) {
                (None, None) => break,
                (Some(c), None) => c,
                (None, Some(r)) => r,
                (Some(c), Some(r)) => c.min(r),
            };
            let fleet = rep.advance(t);
            let changed = fleet.domain_healthy_counts() != &prev_counts[..]
                || fleet.domain_degraded_counts() != &prev_degraded[..]
                || fleet.domain_slowdowns() != &prev_slow[..];
            if changed {
                // Close the interval the previous snapshot was live
                // for, charge the reconfiguration at its actual event
                // time, and evaluate the new snapshot.
                acc.sample(out, t - seg_start);
                self.charge_boundary(
                    &mut acc,
                    &prev_counts,
                    fleet.domain_healthy_counts(),
                    &prev_degraded,
                    fleet.domain_degraded_counts(),
                );
                prev_counts.clear();
                prev_counts.extend_from_slice(fleet.domain_healthy_counts());
                prev_degraded.clear();
                prev_degraded.extend_from_slice(fleet.domain_degraded_counts());
                prev_slow.clear();
                prev_slow.extend_from_slice(fleet.domain_slowdowns());
                out = self.evaluate_degraded(&prev_counts, &prev_degraded, &prev_slow);
                seg_start = t;
            }
        }
        acc.sample(out, horizon - seg_start);
        self.integrate_with_rollback(acc, trace, stall_gpu_hours)
    }

    fn run_grid(&self, trace: &Trace, step_hours: f64, stall_gpu_hours: f64) -> FleetStats {
        let mut rep = FleetReplayer::new(trace, self.topo, self.blast);
        let mut acc = Accum::default();
        let mut last: Option<(u64, EvalOut)> = None;
        let mut prev_counts: Vec<usize> = Vec::new();
        let mut prev_degraded: Vec<usize> = Vec::new();
        let mut step = 0usize;
        while let Some((t, dt)) = grid_step(step, step_hours, trace.horizon_hours) {
            let fleet = rep.advance(t);
            let out = match last {
                Some((version, out)) if version == fleet.version() => out,
                _ => {
                    let counts = fleet.domain_healthy_counts();
                    let degraded = fleet.domain_degraded_counts();
                    if step == 0 {
                        prev_counts = counts.to_vec();
                        prev_degraded = degraded.to_vec();
                    } else if counts != &prev_counts[..] || degraded != &prev_degraded[..] {
                        self.charge_boundary(&mut acc, &prev_counts, counts, &prev_degraded, degraded);
                        prev_counts.clear();
                        prev_counts.extend_from_slice(counts);
                        prev_degraded.clear();
                        prev_degraded.extend_from_slice(degraded);
                    }
                    self.evaluate_degraded(counts, degraded, fleet.domain_slowdowns())
                }
            };
            last = Some((fleet.version(), out));
            acc.sample(out, dt);
            step += 1;
        }
        self.integrate_with_rollback(acc, trace, stall_gpu_hours)
    }

    /// Reference implementation of [`FleetSim::run`]: rebuild the fleet
    /// state from scratch at every sample via [`Trace::replay_to`].
    /// O(steps × events) in grid mode, O(boundaries × events) in exact
    /// mode — exists to demonstrate (tests) and measure
    /// (benches/perf_hotpath.rs) the event-driven path's equivalence
    /// and speedup.
    pub fn run_replay_per_step(&self, trace: &Trace, mode: StepMode) -> FleetStats {
        if let Some(d) = DetectionModel::active(&self.detect) {
            let (seen, stall) = d.delay_trace(trace, self.topo.n_gpus);
            return match mode {
                StepMode::Exact => self.run_exact_per_step(&seen, stall),
                StepMode::Grid(step_hours) => self.run_grid_per_step(&seen, step_hours, stall),
            };
        }
        match mode {
            StepMode::Exact => self.run_exact_per_step(trace, 0.0),
            StepMode::Grid(step_hours) => self.run_grid_per_step(trace, step_hours, 0.0),
        }
    }

    fn run_grid_per_step(&self, trace: &Trace, step_hours: f64, stall_gpu_hours: f64) -> FleetStats {
        let mut acc = Accum::default();
        let mut prev_counts: Vec<usize> = Vec::new();
        let mut prev_degraded: Vec<usize> = Vec::new();
        let mut step = 0usize;
        while let Some((t, dt)) = grid_step(step, step_hours, trace.horizon_hours) {
            let fleet = trace.replay_to(self.topo, self.blast, t);
            let healthy = fleet.domain_healthy_counts();
            let degraded = fleet.domain_degraded_counts();
            if step == 0 {
                prev_counts = healthy.to_vec();
                prev_degraded = degraded.to_vec();
            } else if healthy != &prev_counts[..] || degraded != &prev_degraded[..] {
                self.charge_boundary(&mut acc, &prev_counts, healthy, &prev_degraded, degraded);
                prev_counts.clear();
                prev_counts.extend_from_slice(healthy);
                prev_degraded.clear();
                prev_degraded.extend_from_slice(degraded);
            }
            acc.sample(
                self.evaluate_degraded(healthy, degraded, fleet.domain_slowdowns()),
                dt,
            );
            step += 1;
        }
        self.integrate_with_rollback(acc, trace, stall_gpu_hours)
    }

    fn run_exact_per_step(&self, trace: &Trace, stall_gpu_hours: f64) -> FleetStats {
        let horizon = trace.horizon_hours;
        let mut acc = Accum::default();
        if horizon <= 0.0 {
            return self.integrate(acc);
        }
        let start = trace.replay_to(self.topo, self.blast, 0.0);
        let mut prev_counts = start.domain_healthy_counts().to_vec();
        let mut prev_degraded = start.domain_degraded_counts().to_vec();
        let mut prev_slow = start.domain_slowdowns().to_vec();
        let mut out = self.evaluate_degraded(&prev_counts, &prev_degraded, &prev_slow);
        let mut seg_start = 0.0;
        for &t in &exact_boundaries(trace) {
            let fleet = trace.replay_to(self.topo, self.blast, t);
            let changed = fleet.domain_healthy_counts() != &prev_counts[..]
                || fleet.domain_degraded_counts() != &prev_degraded[..]
                || fleet.domain_slowdowns() != &prev_slow[..];
            if changed {
                acc.sample(out, t - seg_start);
                self.charge_boundary(
                    &mut acc,
                    &prev_counts,
                    fleet.domain_healthy_counts(),
                    &prev_degraded,
                    fleet.domain_degraded_counts(),
                );
                prev_counts.clear();
                prev_counts.extend_from_slice(fleet.domain_healthy_counts());
                prev_degraded.clear();
                prev_degraded.extend_from_slice(fleet.domain_degraded_counts());
                prev_slow.clear();
                prev_slow.extend_from_slice(fleet.domain_slowdowns());
                out = self.evaluate_degraded(&prev_counts, &prev_degraded, &prev_slow);
                seg_start = t;
            }
        }
        acc.sample(out, horizon - seg_start);
        self.integrate_with_rollback(acc, trace, stall_gpu_hours)
    }

    /// Close one observed change boundary: charge whichever transition
    /// kinds actually changed — healthy counts through
    /// [`FtPolicy::transition_cost`], degraded counts through
    /// [`FtPolicy::degrade_transition_cost`] — as **one** transition
    /// event. Fail-only traces never change the degraded counts, so
    /// they charge exactly the pre-straggler cost (the second term is
    /// never added, keeping those paths bit-identical); slowdown-only
    /// boundaries (a deeper degrade landing on an already-degraded GPU)
    /// re-evaluate throughput but reconfigure nothing and are not
    /// charged. The shared multi-policy sweep
    /// ([`super::MultiPolicySim`]) mirrors this structure
    /// operation-for-operation.
    fn charge_boundary(
        &self,
        acc: &mut Accum,
        prev_counts: &[usize],
        next_counts: &[usize],
        prev_degraded: &[usize],
        next_degraded: &[usize],
    ) {
        let counts_changed = prev_counts != next_counts;
        let degraded_changed = prev_degraded != next_degraded;
        if !(counts_changed || degraded_changed) {
            return;
        }
        let ctx = self.ctx(self.live_spares_in(next_counts));
        let mut cost = 0.0;
        if counts_changed {
            cost += self.policy.transition_cost(&ctx, prev_counts, next_counts);
        }
        if degraded_changed {
            cost += self.policy.degrade_transition_cost(&ctx, prev_degraded, next_degraded);
        }
        acc.charge_cost(cost);
    }

    /// [`FleetSim::integrate`] with the trace-global SDC rollback bill
    /// ([`sdc_rollback_gpu_secs`]) charged first — every sweep path
    /// funnels through here so all add the identical `f64`. Free when
    /// reconfigurations are free (`transition: None`), like every other
    /// downtime charge.
    fn integrate_with_rollback(
        &self,
        mut acc: Accum,
        trace: &Trace,
        stall_gpu_hours: f64,
    ) -> FleetStats {
        if let Some(costs) = &self.transition {
            let bill = sdc_rollback_gpu_secs(trace, costs, self.topo.n_gpus);
            if bill > 0.0 {
                acc.charge_rollback(bill);
            }
            // Periodic validation-sweep stall, billed after the SDC
            // rollback in every path (the multi-policy engine mirrors
            // this order exactly for bit-identity).
            let sweep_bill =
                validation_sweep_gpu_secs(costs, trace.horizon_hours, self.topo.n_gpus);
            if sweep_bill > 0.0 {
                acc.charge_rollback(sweep_bill);
            }
            // Undetected-stall bill from the detection-delay view
            // ([`DetectionModel::delay_trace`]): GPU-hours faulty
            // domains sat live-but-unnoticed. Third in the billing
            // order, identical in `MultiPolicySim::charge_rollback_all`.
            if stall_gpu_hours > 0.0 {
                acc.charge_rollback(stall_gpu_hours * 3600.0);
            }
            // Expected false-positive evictions, priced by the policy
            // against the *configured* pool — an expected-value bill
            // like the validation sweep, via `charge_rollback` so the
            // `transitions` counter keeps counting only real
            // reconfigurations.
            if let Some(d) = DetectionModel::active(&self.detect) {
                let fp = d.false_positive_events(self.topo.n_gpus, trace.horizon_hours);
                let fp_bill = fp * self.policy.false_positive_cost(&self.ctx(self.spares));
                if fp_bill > 0.0 {
                    acc.charge_rollback(fp_bill);
                }
            }
        }
        self.integrate(acc)
    }

    fn integrate(&self, acc: Accum) -> FleetStats {
        let spare_gpus = self
            .spares
            .map(|p| p.spare_domains * self.topo.domain_size)
            .unwrap_or(0);
        acc.finalize(self.topo.n_gpus, spare_gpus)
    }

    /// The policy context for one evaluation. `live_spares` is the
    /// fixed-minibatch pool after removing failed spare domains.
    pub(crate) fn ctx(&self, live_spares: Option<SparePolicy>) -> PolicyCtx<'_> {
        PolicyCtx {
            table: self.table,
            domain_size: self.topo.domain_size,
            domains_per_replica: self.domains_per_replica,
            packed: self.packed,
            spares: live_spares,
            n_gpus: self.topo.n_gpus,
            transition: self.transition,
        }
    }

    /// The live-spare-adjusted pool for one *full-fleet* snapshot —
    /// [`super::spares::split_job_spares`], which both the steady-state
    /// evaluation and the transition charge (and the shared-sweep
    /// engine) derive the policy context through, so a failed spare
    /// domain is reflected identically in throughput and in the charged
    /// reconfiguration cost.
    pub(crate) fn live_spares_in(&self, domain_healthy: &[usize]) -> Option<SparePolicy> {
        self.spares.map(|pool| {
            super::spares::split_job_spares(domain_healthy, self.topo.domain_size, &pool).1
        })
    }

    /// Evaluate one snapshot: the integrated [`EvalOut`] quantities.
    pub fn evaluate(&self, domain_healthy: &[usize]) -> EvalOut {
        match self.spares {
            None => {
                let resp = self.policy.respond(&self.ctx(None), domain_healthy);
                EvalOut::of(&resp, self.table.full_local_batch)
            }
            Some(pool) => {
                let (job_healthy, live) = super::spares::split_job_spares(
                    domain_healthy,
                    self.topo.domain_size,
                    &pool,
                );
                let resp = self.policy.respond(&self.ctx(Some(live)), job_healthy);
                EvalOut::of(&resp, self.table.full_local_batch)
            }
        }
    }

    /// [`FleetSim::evaluate`] for a snapshot that carries degradation
    /// info ([`crate::cluster::FleetHealth::domain_degraded_counts`] /
    /// [`crate::cluster::FleetHealth::domain_slowdowns`]). Snapshots
    /// with no degraded *job* domain short-circuit to the plain
    /// [`FleetSim::evaluate`] path — fail-only traces never see the
    /// degrade-aware machinery, which is what keeps their stats
    /// bit-identical to the pre-straggler engine. Degraded GPUs in
    /// *spare* domains are ignored: a degraded spare is still alive and
    /// still counts toward the live pool; it only drags once migrated
    /// into the job (a second-order effect this model does not charge).
    pub fn evaluate_degraded(
        &self,
        domain_healthy: &[usize],
        domain_degraded: &[usize],
        domain_slowdowns: &[f64],
    ) -> EvalOut {
        match self.spares {
            None => {
                if domain_degraded.iter().all(|&d| d == 0) {
                    return self.evaluate(domain_healthy);
                }
                self.policy.eval_degraded(
                    &self.ctx(None),
                    domain_healthy,
                    domain_degraded,
                    domain_slowdowns,
                )
            }
            Some(pool) => {
                let (job_healthy, live) = super::spares::split_job_spares(
                    domain_healthy,
                    self.topo.domain_size,
                    &pool,
                );
                let n_job = job_healthy.len();
                if domain_degraded[..n_job].iter().all(|&d| d == 0) {
                    return self.evaluate(domain_healthy);
                }
                self.policy.eval_degraded(
                    &self.ctx(Some(live)),
                    job_healthy,
                    &domain_degraded[..n_job],
                    &domain_slowdowns[..n_job],
                )
            }
        }
    }
}

/// Shared integration state of every sweep implementation
/// (event-driven, per-step, and the shared multi-policy engine in
/// [`super::sweep`]), so all paths stay operation-for-operation
/// identical (the bit-identity the equivalence tests assert).
///
/// Integration is duration-weighted: every sampled [`EvalOut`] carries
/// the hours the snapshot was live, so the exact event-boundary sweep
/// (one sample per health change, arbitrary interval lengths) and the
/// fixed grid (uniform intervals, clamped at the horizon) ride the
/// same accumulator. A helpful bit-level property falls out: when a
/// quantity is constant (e.g. `tput == 1.0` on a healthy fleet),
/// `out.tput * dt == dt` exactly, so its mean divides two bitwise-equal
/// sums and is exactly that constant regardless of how the horizon was
/// partitioned.
#[derive(Clone, Default)]
pub(crate) struct Accum {
    /// ∫ tput dt (hours).
    tput_sum: f64,
    /// ∫ dt — total integrated hours (the normalization denominator).
    time_hours: f64,
    /// Hours spent paused.
    paused_hours: f64,
    /// ∫ spares_used dt.
    spares_sum: f64,
    /// ∫ donated dt.
    donated_sum: f64,
    /// ∫ power dt (hours) — the energy integral, in fleet-TDP-hours.
    power_sum: f64,
    /// max rack_power over every sampled snapshot with dt > 0.
    rack_peak: f64,
    transitions: usize,
    cost_gpu_secs: f64,
}

impl Accum {
    /// Integrate one snapshot evaluation over the `dt_hours` it was
    /// live.
    pub(crate) fn sample(&mut self, out: EvalOut, dt_hours: f64) {
        self.tput_sum += out.tput * dt_hours;
        self.time_hours += dt_hours;
        if out.paused {
            self.paused_hours += dt_hours;
        }
        self.spares_sum += out.spares_used as f64 * dt_hours;
        self.donated_sum += out.donated * dt_hours;
        self.power_sum += out.power * dt_hours;
        // Zero-duration snapshots never existed on the timeline — they
        // must not move the peak, or grid refinement (which samples
        // extra zero-length boundaries) would break the
        // refinement-invariance of the stats.
        if dt_hours > 0.0 && out.rack_power > self.rack_peak {
            self.rack_peak = out.rack_power;
        }
    }

    /// Charge one observed change boundary's transition cost. In
    /// [`StepMode::Exact`] every change boundary charges at its actual
    /// event time; in [`StepMode::Grid`] events landing between two
    /// samples collapse into one charge (all grid paths sample the same
    /// grid, so all see the same transitions). The cost arrives already
    /// computed — `FleetSim::charge_boundary` and the shared sweep's
    /// count-keyed transition memo ([`crate::manager::ResponseMemo`])
    /// both land here, so the memoized and direct paths add the
    /// identical `f64`.
    pub(crate) fn charge_cost(&mut self, cost_gpu_secs: f64) {
        self.transitions += 1;
        self.cost_gpu_secs += cost_gpu_secs;
    }

    /// Charge downtime that is *not* a reconfiguration transition —
    /// the SDC detection-lag rollback bill
    /// ([`sdc_rollback_gpu_secs`]): adds GPU-seconds to the downtime
    /// pool without bumping the transition counter (the job did not
    /// reconfigure, it rolled back and replayed).
    pub(crate) fn charge_rollback(&mut self, cost_gpu_secs: f64) {
        self.cost_gpu_secs += cost_gpu_secs;
    }

    /// Integrate the accumulated duration-weighted samples into a
    /// [`FleetStats`] (shared by every sweep path so all produce
    /// bit-identical statistics). Normalizes by the integrated time —
    /// not a step count — so partial final intervals carry exactly
    /// their duration's weight.
    pub(crate) fn finalize(&self, n_gpus: usize, spare_gpus: usize) -> FleetStats {
        let t = self.time_hours;
        if t <= 0.0 {
            return FleetStats { transitions: self.transitions, ..FleetStats::default() };
        }
        let job_gpus = n_gpus - spare_gpus;
        let mean_tput = self.tput_sum / t;
        let horizon_secs = t * 3600.0;
        let downtime_frac = (self.cost_gpu_secs / (n_gpus as f64 * horizon_secs)).min(1.0);
        FleetStats {
            mean_throughput: mean_tput,
            paused_frac: self.paused_hours / t,
            mean_spares_used: self.spares_sum / t,
            throughput_per_gpu: mean_tput * job_gpus as f64 / n_gpus as f64,
            downtime_frac,
            transitions: self.transitions,
            mean_donated: self.donated_sum / t,
            mean_power_frac: self.power_sum / t,
            peak_rack_power_frac: self.rack_peak,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Dtype, WorkloadConfig};
    use crate::failure::FailureModel;
    use crate::sim::SimParams;
    use crate::util::prng::Rng;

    fn small_setup() -> (IterationModel, ParallelConfig) {
        let sim = IterationModel::new(
            presets::model("gpt-480b").unwrap(),
            WorkloadConfig {
                seq_len: 16_384,
                minibatch_tokens: 2 * 1024 * 1024,
                dtype: Dtype::BF16,
            },
            presets::cluster("paper-32k-nvl32").unwrap(),
            SimParams::default(),
        );
        // 16 replicas x 4 domains x 32 GPUs = 2048 GPUs (kept small for tests)
        let cfg = ParallelConfig { tp: 32, pp: 4, dp: 16, microbatch: 1 };
        (sim, cfg)
    }

    #[test]
    fn table_matches_engine_semantics() {
        let (sim, cfg) = small_setup();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let t = StrategyTable::build(&sim, &cfg, &rack);
        assert_eq!(t.full_tp, 32);
        assert_eq!(t.min_tp, 28);
        // NTP batch decreases with deeper reduction
        for w in t.batch.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // PW keeps full batch wherever feasible
        for (i, p) in t.power.iter().enumerate() {
            if p.is_some() {
                assert_eq!(t.batch_pw[i], t.full_local_batch);
            }
        }
        // modeled reshard overhead is sub-percent, bounded by the
        // retired 0.995 constant
        assert!((0.995..1.0).contains(&t.reshard_overhead), "{}", t.reshard_overhead);
    }

    #[test]
    fn straggler_drag_interpolates_between_comm_and_compute_bound() {
        let (sim, cfg) = small_setup();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let t = StrategyTable::build(&sim, &cfg, &rack);
        // the paper's workload is strongly compute-bound, so most of
        // the iteration stretches with a slow member
        assert!(
            t.straggler_phi > 0.5 && t.straggler_phi <= 1.0,
            "phi {}",
            t.straggler_phi
        );
        // no straggler: exactly no drag (bit-exact guard)
        assert_eq!(t.straggler_drag(1.0), 1.0);
        // deeper slowdown drags harder, bounded below by the slowdown
        // itself (only the perf-sensitive share stretches)
        assert!(t.straggler_drag(0.5) < t.straggler_drag(0.9));
        let half = t.straggler_drag(0.5);
        assert!((0.5..1.0).contains(&half), "drag(0.5) = {half}");
        // capacity-weighted aggregate: one dragged domain out of four
        let drag = t.group_drag(&[32, 32, 32, 32], &[1.0, 1.0, 0.5, 1.0]);
        assert!((drag - (3.0 + half) / 4.0).abs() < 1e-12, "drag {drag} half {half}");
        // all-healthy snapshot: exactly 1.0
        assert_eq!(t.group_drag(&[32; 4], &[1.0; 4]), 1.0);
    }

    #[test]
    fn group_throughput_ordering() {
        let (sim, cfg) = small_setup();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let t = StrategyTable::build(&sim, &cfg, &rack);
        let tps = vec![32, 31, 30, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32];
        let drop = t.group_throughput(&tps, FtStrategy::DpDrop);
        let ntp = t.group_throughput(&tps, FtStrategy::Ntp);
        let pw = t.group_throughput(&tps, FtStrategy::NtpPw);
        assert!(drop < ntp && ntp <= pw, "drop {drop} ntp {ntp} pw {pw}");
        assert!((drop - 14.0 / 16.0).abs() < 1e-9);
        assert!(pw > 0.985);
    }

    #[test]
    fn fleet_sim_runs_and_integrates() {
        let (sim, cfg) = small_setup();
        let topo = Topology::of(cfg.n_gpus(), 32, 4);
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let table = StrategyTable::build(&sim, &cfg, &rack);
        let model = FailureModel::llama3().scaled(30.0); // dense failures for a small cluster
        let mut rng = Rng::new(5);
        let trace = Trace::generate(&topo, &model, 24.0 * 15.0, &mut rng);
        let fs = FleetSim {
            topo: &topo,
            table: &table,
            domains_per_replica: cfg.pp,
            policy: FtStrategy::Ntp.policy(),
            spares: None,
            packed: true,
            blast: BlastRadius::Single,
            transition: None,
            detect: None,
        };
        let stats = fs.run(&trace, StepMode::Grid(6.0));
        assert!(stats.mean_throughput > 0.5 && stats.mean_throughput <= 1.0);
        assert_eq!(stats.paused_frac, 0.0);
        assert_eq!(stats.downtime_frac, 0.0);

        // Exact integration agrees qualitatively and stays in range.
        let exact = fs.run(&trace, StepMode::Exact);
        assert!(exact.mean_throughput > 0.5 && exact.mean_throughput <= 1.0);
        assert!((exact.mean_throughput - stats.mean_throughput).abs() < 0.05);

        // DP-DROP must do worse on the same trace in both modes.
        let fs_drop = FleetSim { policy: FtStrategy::DpDrop.policy(), ..fs };
        assert!(fs_drop.run(&trace, StepMode::Grid(6.0)).mean_throughput < stats.mean_throughput);
        assert!(fs_drop.run(&trace, StepMode::Exact).mean_throughput < exact.mean_throughput);
    }

    #[test]
    fn grid_step_clamps_the_final_interval() {
        // horizon 10, step 4: intervals [0,4) [4,8) [8,10).
        assert_eq!(grid_step(0, 4.0, 10.0), Some((0.0, 4.0)));
        assert_eq!(grid_step(1, 4.0, 10.0), Some((4.0, 4.0)));
        assert_eq!(grid_step(2, 4.0, 10.0), Some((8.0, 2.0)));
        assert_eq!(grid_step(3, 4.0, 10.0), None);
        // exactly divisible horizon: no partial step, no overshoot
        assert_eq!(grid_step(1, 5.0, 10.0), Some((5.0, 5.0)));
        assert_eq!(grid_step(2, 5.0, 10.0), None);
        // degenerate horizon
        assert_eq!(grid_step(0, 1.0, 0.0), None);
    }

    #[test]
    fn accum_integrates_by_duration() {
        let half = EvalOut {
            tput: 0.5,
            paused: false,
            spares_used: 2,
            donated: 0.25,
            power: 0.75,
            rack_power: 1.2,
        };
        let paused = EvalOut {
            tput: 0.0,
            paused: true,
            spares_used: 0,
            donated: 0.0,
            power: 0.15,
            rack_power: 0.15,
        };
        let mut acc = Accum::default();
        acc.sample(half, 6.0);
        acc.sample(paused, 2.0);
        let s = acc.finalize(100, 10);
        assert!((s.mean_throughput - 3.0 / 8.0).abs() < 1e-15);
        assert!((s.paused_frac - 0.25).abs() < 1e-15);
        assert!((s.mean_spares_used - 12.0 / 8.0).abs() < 1e-15);
        assert!((s.mean_donated - 1.5 / 8.0).abs() < 1e-15);
        // power integrates duration-weighted: (0.75*6 + 0.15*2)/8
        assert!((s.mean_power_frac - 4.8 / 8.0).abs() < 1e-15);
        assert_eq!(s.peak_rack_power_frac, 1.2);
        // energy per token: mean power over net throughput
        assert!((s.energy_per_token() - s.mean_power_frac / s.net_throughput()).abs() < 1e-15);
        assert_eq!(s.transitions, 0);
        // zero integrated time: all-default stats, no NaNs
        let empty = Accum::default().finalize(100, 0);
        assert_eq!(empty, FleetStats::default());
        // a constant tput of exactly 1.0 survives any partition exactly
        let one = EvalOut {
            tput: 1.0,
            paused: false,
            spares_used: 0,
            donated: 0.0,
            power: 1.0,
            rack_power: 1.0,
        };
        let mut acc = Accum::default();
        for dt in [0.3, 1.7, 0.125, 4.0] {
            acc.sample(one, dt);
        }
        let s = acc.finalize(64, 0);
        assert_eq!(s.mean_throughput, 1.0);
        // ... and so does a constant power of exactly 1.0 (the
        // bit-level guarantee the zero-failure conformance point pins)
        assert_eq!(s.mean_power_frac, 1.0);
    }

    #[test]
    fn event_driven_run_matches_per_step_replay() {
        let (sim, cfg) = small_setup();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let table = StrategyTable::build(&sim, &cfg, &rack);
        let topo = Topology::of(cfg.n_gpus(), 32, 4);
        let model = FailureModel::llama3().scaled(40.0);
        let mut rng = Rng::new(23);
        let trace = Trace::generate(&topo, &model, 24.0 * 20.0, &mut rng);
        for mode in [StepMode::Grid(2.0), StepMode::Exact] {
            for strategy in [FtStrategy::DpDrop, FtStrategy::Ntp] {
                let fs = FleetSim {
                    topo: &topo,
                    table: &table,
                    domains_per_replica: cfg.pp,
                    policy: strategy.policy(),
                    spares: None,
                    packed: true,
                    blast: BlastRadius::Single,
                    transition: None,
                    detect: None,
                };
                assert_eq!(
                    fs.run(&trace, mode),
                    fs.run_replay_per_step(&trace, mode),
                    "{mode:?}"
                );
            }
            let fs = FleetSim {
                topo: &topo,
                table: &table,
                domains_per_replica: cfg.pp,
                policy: FtStrategy::Ntp.policy(),
                spares: Some(SparePolicy { spare_domains: 4, cold_domains: 0, min_tp: 28 }),
                packed: true,
                blast: BlastRadius::Node,
                transition: None,
                detect: None,
            };
            assert_eq!(fs.run(&trace, mode), fs.run_replay_per_step(&trace, mode), "{mode:?}");
            // ... and with transition costs switched on, both sweep
            // paths must still agree exactly (downtime included).
            let fs_t = FleetSim {
                transition: Some(crate::policy::TransitionCosts::model(&sim, &cfg)),
                ..fs
            };
            let a = fs_t.run(&trace, mode);
            let b = fs_t.run_replay_per_step(&trace, mode);
            assert_eq!(a, b, "{mode:?}");
            assert!(a.transitions > 0 && a.downtime_frac > 0.0, "{mode:?}");
        }
    }

    #[test]
    fn transition_charge_uses_live_spare_pool() {
        // Regression for the configured-vs-live spare mismatch: the
        // charge path used to build its PolicyCtx from the *configured*
        // `fs.spares` while `evaluate` used the live-adjusted pool. Both
        // now go through `live_spares_in`, so a failed spare domain
        // shrinks the pool seen by `transition_cost` — observable with
        // SPARE-MIG, whose migration bill is capped by the live pool.
        let (sim, cfg) = small_setup();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let table = StrategyTable::build(&sim, &cfg, &rack);
        // 16 job domains (4 replicas x 4) + 2 spare domains.
        let topo = Topology::of(18 * 32, 32, 4);
        let fs = FleetSim {
            topo: &topo,
            table: &table,
            domains_per_replica: 4,
            policy: crate::policy::registry::parse("spare-mig").unwrap(),
            spares: Some(SparePolicy { spare_domains: 2, cold_domains: 0, min_tp: 28 }),
            packed: true,
            blast: BlastRadius::Single,
            transition: Some(crate::policy::TransitionCosts::model(&sim, &cfg)),
            detect: None,
        };
        let prev = vec![32usize; 18];
        // Three fresh job-domain failures, and the last spare domain
        // also fails: live pool 1, configured pool 2.
        let mut next = prev.clone();
        next[0] = 31;
        next[4] = 31;
        next[8] = 31;
        next[17] = 31;
        let live = fs.live_spares_in(&next).unwrap();
        assert_eq!(live.spare_domains, 1);
        let charged = fs.policy.transition_cost(&fs.ctx(Some(live)), &prev, &next);
        let misconfigured = fs.policy.transition_cost(&fs.ctx(fs.spares), &prev, &next);
        // 4 degraded domains: the live pool migrates 1, the configured
        // pool would have billed 2 — the old derivation overcharged.
        assert!(
            charged < misconfigured,
            "live-pool charge {charged} should be below configured-pool {misconfigured}"
        );
        // With every spare alive, the two derivations agree.
        let mut next_spares_ok = prev.clone();
        next_spares_ok[0] = 31;
        next_spares_ok[4] = 31;
        next_spares_ok[8] = 31;
        let live_ok = fs.live_spares_in(&next_spares_ok).unwrap();
        assert_eq!(live_ok.spare_domains, 2);
        assert_eq!(
            fs.policy.transition_cost(&fs.ctx(Some(live_ok)), &prev, &next_spares_ok),
            fs.policy.transition_cost(&fs.ctx(fs.spares), &prev, &next_spares_ok),
        );
        // And the two sweep paths still agree bit-for-bit with the fix.
        let model = FailureModel::llama3().scaled(60.0);
        let mut rng = Rng::new(9);
        let trace = Trace::generate(&topo, &model, 24.0 * 20.0, &mut rng);
        for mode in [StepMode::Grid(2.0), StepMode::Exact] {
            assert_eq!(fs.run(&trace, mode), fs.run_replay_per_step(&trace, mode), "{mode:?}");
        }
    }

    #[test]
    fn packing_improves_throughput_under_spread_failures() {
        let (sim, cfg) = small_setup();
        let rack = RackDesign { rack_budget_frac: 1.3, ..RackDesign::default() };
        let table = StrategyTable::build(&sim, &cfg, &rack);
        let topo = Topology::of(cfg.n_gpus(), 32, 4);
        // failures in 4 different replicas (one per 4-domain block)
        let mut healthy = vec![32usize; 64];
        healthy[0] = 31;
        healthy[5] = 31;
        healthy[9] = 31;
        healthy[13] = 31;
        let packed = FleetSim {
            topo: &topo,
            table: &table,
            domains_per_replica: 4,
            policy: FtStrategy::Ntp.policy(),
            spares: None,
            packed: true,
            blast: BlastRadius::Single,
            transition: None,
            detect: None,
        };
        let unpacked = FleetSim { packed: false, ..packed };
        let tp_packed = packed.evaluate(&healthy).tput;
        let tp_unpacked = unpacked.evaluate(&healthy).tput;
        assert!(tp_packed >= tp_unpacked);
    }
}
