//! Adaptive Monte-Carlo trial allocation: run trials in fixed-size
//! *rounds* and stop as soon as the per-policy `net_throughput`
//! statistics are settled, instead of spending a fixed budget on
//! comparisons that were decided hundreds of trials earlier. The
//! paper's headline results (Figs. 6–7) are policy *orderings* — NTP
//! vs dp-drop vs spares — and at fleet-scale failure rates those
//! orderings typically separate long before a fixed `--trials` budget
//! is exhausted (the ROADMAP item-5 follow-on).
//!
//! The stop decision is taken ONLY at round boundaries, on per-policy
//! [`Welford`] moments folded in trial-index order — so the stopping
//! trial count is a pure function of `(seed, StopRule)`, and in
//! particular independent of `--threads` and of the work-stealing
//! schedule (`rust/tests/adaptive_mc.rs` pins this). Entry points live
//! on [`super::sweep::MultiPolicySim`]: `run_trials_adaptive`
//! (parallel, per-worker memos) and `run_trials_adaptive_with`
//! (sequential on a caller-shared memo, for grid sweeps).

use super::sweep::{MemoStats, PolicyAggregate};
use crate::util::stats::Welford;

/// Why an adaptive Monte-Carlo run stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Every pairwise policy ordering is settled: for any two policies
    /// the 95% confidence intervals on mean net throughput do not
    /// overlap, with at least [`StopRule::margin`] of clearance.
    Separated,
    /// Every policy's CI95 half-width dropped below
    /// [`StopRule::rel_ci`] of its mean — the estimates are precise
    /// even where orderings are genuinely tied.
    RelCi,
    /// The [`StopRule::max_trials`] budget ran out before either
    /// criterion held (e.g. an adversarially-close policy pair).
    MaxTrials,
}

impl StopReason {
    /// Stable lowercase key for JSON reports.
    pub fn as_str(&self) -> &'static str {
        match self {
            StopReason::Separated => "separated",
            StopReason::RelCi => "rel_ci",
            StopReason::MaxTrials => "max_trials",
        }
    }
}

/// Round-boundary stop rule for adaptive Monte-Carlo. Checked against
/// the per-policy net-throughput [`Welford`] accumulators after each
/// whole round, in fixed precedence: minimum-trial gate, then pairwise
/// [`StopReason::Separated`], then [`StopReason::RelCi`], then the
/// [`StopReason::MaxTrials`] budget.
#[derive(Clone, Copy, Debug)]
pub struct StopRule {
    /// Trials per round. Decisions happen only after whole rounds, so
    /// the stopping point depends on this and the seed — never on the
    /// worker count or schedule.
    pub round: usize,
    /// No stop check passes (except the budget) below this many trials
    /// — guards against a lucky early round separating by accident.
    pub min_trials: usize,
    /// Hard trial budget; the run never draws past it.
    pub max_trials: usize,
    /// Relative precision target: stop once every policy satisfies
    /// `ci95 ≤ rel_ci · |mean|`. `<= 0` disables the precision stop
    /// (useful when only the ordering matters).
    pub rel_ci: f64,
    /// Extra absolute clearance (net-throughput units) required
    /// between two policies' intervals before they count as separated.
    pub margin: f64,
}

impl Default for StopRule {
    fn default() -> StopRule {
        StopRule { round: 16, min_trials: 16, max_trials: 256, rel_ci: 0.01, margin: 0.0 }
    }
}

impl StopRule {
    /// Copy with degenerate fields clamped sane: at least one trial
    /// per round, a positive budget, and `min_trials` within it.
    pub fn normalized(&self) -> StopRule {
        let max_trials = self.max_trials.max(1);
        StopRule {
            round: self.round.max(1),
            min_trials: self.min_trials.max(1).min(max_trials),
            max_trials,
            ..*self
        }
    }

    /// Round-boundary decision on the per-policy net-throughput
    /// accumulators (one per policy, all with equal counts): `None`
    /// keeps sampling, `Some(reason)` stops. Pure — same accumulators,
    /// same verdict, which is what makes the stopping point
    /// thread-count-independent.
    pub fn check(&self, net: &[Welford]) -> Option<StopReason> {
        let n = net.first().map(|w| w.count() as usize).unwrap_or(0);
        // Below the gate (or below n = 2, where no CI exists) only the
        // budget can stop the run.
        if n < self.min_trials.max(2) {
            return (n >= self.max_trials).then_some(StopReason::MaxTrials);
        }
        // A single policy has no ordering to settle; rel_ci governs.
        if net.len() >= 2 && self.separated(net) {
            return Some(StopReason::Separated);
        }
        if self.precise(net) {
            return Some(StopReason::RelCi);
        }
        (n >= self.max_trials).then_some(StopReason::MaxTrials)
    }

    /// Every pair of policies has non-overlapping CI95s with `margin`
    /// clearance: `|mᵢ − mⱼ| > ciᵢ + ciⱼ + margin`.
    fn separated(&self, net: &[Welford]) -> bool {
        for i in 0..net.len() {
            for j in (i + 1)..net.len() {
                let gap = (net[i].mean() - net[j].mean()).abs();
                if gap <= net[i].ci95() + net[j].ci95() + self.margin {
                    return false;
                }
            }
        }
        true
    }

    /// Every policy's CI95 half-width is within `rel_ci` of its mean.
    fn precise(&self, net: &[Welford]) -> bool {
        self.rel_ci > 0.0 && net.iter().all(|w| w.ci95() <= self.rel_ci * w.mean().abs())
    }
}

/// Result of an adaptive Monte-Carlo run.
#[derive(Clone, Debug)]
pub struct AdaptiveOutcome {
    /// Per-policy aggregates over the `trials_run` trials actually
    /// drawn, folded in trial-index order (bit-identical for any
    /// thread count).
    pub aggs: Vec<PolicyAggregate>,
    /// Trials actually integrated — a whole number of rounds, except
    /// when the budget cuts the last round short.
    pub trials_run: usize,
    /// Which criterion stopped the run.
    pub reason: StopReason,
    /// Merged response-memo counters (diagnostics; the hit/miss split
    /// depends on the work-stealing schedule, the total does not).
    pub memo: MemoStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn welford_of(xs: &[f64]) -> Welford {
        let mut w = Welford::default();
        for &x in xs {
            w.push(x);
        }
        w
    }

    /// `n` samples tightly clustered around `mean` (tiny but nonzero
    /// spread, so CIs are finite and small).
    fn tight(mean: f64, n: usize) -> Welford {
        let xs: Vec<f64> =
            (0..n).map(|i| mean + 1e-6 * if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        welford_of(&xs)
    }

    /// `n` samples around `mean` with ±`spread` alternation.
    fn wide(mean: f64, spread: f64, n: usize) -> Welford {
        let xs: Vec<f64> =
            (0..n).map(|i| mean + spread * if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        welford_of(&xs)
    }

    #[test]
    fn min_trials_gates_every_criterion_but_budget() {
        let rule = StopRule { min_trials: 16, max_trials: 64, ..StopRule::default() };
        // Clearly separated AND precise, but only 8 trials: keep going.
        let net = [tight(10.0, 8), tight(5.0, 8)];
        assert_eq!(rule.check(&net), None);
        // Same statistics past the gate: separated wins.
        let net = [tight(10.0, 16), tight(5.0, 16)];
        assert_eq!(rule.check(&net), Some(StopReason::Separated));
        // Budget overrides the gate (precision stop disabled so only
        // the budget can fire).
        let gated =
            StopRule { min_trials: 64, max_trials: 16, rel_ci: 0.0, ..StopRule::default() };
        assert_eq!(gated.normalized().check(&[wide(10.0, 3.0, 16)]), Some(StopReason::MaxTrials));
    }

    #[test]
    fn separation_precedes_rel_ci_and_respects_margin() {
        let rule = StopRule { min_trials: 4, max_trials: 1024, rel_ci: 0.5, margin: 0.0, round: 4 };
        // Separated pair also satisfies the loose rel_ci — Separated
        // has precedence.
        assert_eq!(rule.check(&[tight(10.0, 8), tight(5.0, 8)]), Some(StopReason::Separated));
        // A margin wider than the gap suppresses separation; the loose
        // rel_ci still stops.
        let wide_margin = StopRule { margin: 100.0, ..rule };
        assert_eq!(wide_margin.check(&[tight(10.0, 8), tight(5.0, 8)]), Some(StopReason::RelCi));
    }

    #[test]
    fn overlapping_pair_stops_on_rel_ci_or_budget() {
        // Means 10 ± wide CIs overlap: not separated.
        let net = [wide(10.0, 3.0, 8), wide(10.1, 3.0, 8)];
        let rule = StopRule { min_trials: 4, max_trials: 1024, rel_ci: 0.9, margin: 0.0, round: 4 };
        assert_eq!(rule.check(&net), Some(StopReason::RelCi));
        // rel_ci = 0 disables the precision stop; below budget → keep
        // sampling, at budget → MaxTrials.
        let strict = StopRule { rel_ci: 0.0, ..rule };
        assert_eq!(strict.check(&net), None);
        let capped = StopRule { max_trials: 8, ..strict };
        assert_eq!(capped.check(&net), Some(StopReason::MaxTrials));
    }

    #[test]
    fn single_policy_never_separates() {
        let rule = StopRule { min_trials: 4, max_trials: 1024, rel_ci: 0.5, margin: 0.0, round: 4 };
        assert_eq!(rule.check(&[tight(10.0, 8)]), Some(StopReason::RelCi));
        let strict = StopRule { rel_ci: 0.0, ..rule };
        assert_eq!(strict.check(&[tight(10.0, 8)]), None);
        // No policies at all: nothing to decide until the budget.
        assert_eq!(rule.check(&[]), None);
    }

    #[test]
    fn normalized_clamps_degenerate_fields() {
        let r = StopRule { round: 0, min_trials: 50, max_trials: 0, rel_ci: 0.0, margin: 0.0 }
            .normalized();
        assert_eq!(r.round, 1);
        assert_eq!(r.max_trials, 1);
        assert_eq!(r.min_trials, 1);
        let d = StopRule::default().normalized();
        assert_eq!(d.min_trials, StopRule::default().min_trials);
    }

    #[test]
    fn stop_reason_json_keys_stable() {
        assert_eq!(StopReason::Separated.as_str(), "separated");
        assert_eq!(StopReason::RelCi.as_str(), "rel_ci");
        assert_eq!(StopReason::MaxTrials.as_str(), "max_trials");
    }
}
