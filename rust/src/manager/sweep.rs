//! Shared-sweep multi-policy fleet engine.
//!
//! After the policy layer (PR 2), every bench and the `fleet` CLI
//! compared P policies by replaying the same failure trace P times —
//! one [`super::FleetSim::run`] per policy, each re-evaluating every
//! changed snapshot from scratch. The paper's headline claims (§7,
//! Figs. 6/7) are statistical: they only emerge from fleets of
//! 32K–100K+ GPUs swept over many Monte-Carlo traces × many policies,
//! and at SPARe scale (100K+ GPUs, arXiv 2603.00357) the per-policy
//! sweep cost explodes. This module turns a P-policy sweep into **one**
//! trace replay:
//!
//! * [`MultiPolicySim`] — one [`FleetReplayer`] pass per trace; every
//!   unique snapshot version is evaluated for *all* requested policies,
//!   with one accumulator per policy. Transition charges and
//!   integration reuse the exact `FleetSim` machinery — including the
//!   [`StepMode`] dispatch, so exact event-boundary integration and the
//!   legacy grid both come out bit-identical to P separate
//!   `FleetSim::run` calls (`rust/tests/multi_policy_sweep.rs`). Under
//!   [`StepMode::Exact`] the sweep is bounded by the trace's *event
//!   count*, not a sample grid, and
//!   [`MultiPolicySim::run_trials_par`] fans Monte-Carlo trials over a
//!   work-stealing scheduler (`util::par::par_steal_with_states` —
//!   per-worker replayers and memos, per-trial stats folded in
//!   trial-index order, bit-identical to one thread). The adaptive
//!   runners ([`MultiPolicySim::run_trials_adaptive`]) stack
//!   `manager::adaptive`'s round-boundary [`StopRule`] on the same
//!   scheduler to stop settled policy comparisons early.
//! * [`SnapshotSig`] — failures are rare, so a snapshot is keyed by the
//!   sorted multiset of *damaged* domains only, as `(deficit, count)`
//!   pairs with inline storage (no heap below
//!   [`SIG_INLINE`] distinct deficit values). In packed mode —
//!   and in fixed-minibatch mode, whose spare substitution and packing
//!   always reorder — every in-tree policy's response is a pure
//!   function of this signature (property-tested in
//!   `rust/tests/multi_policy_sweep.rs`; unpacked flexible mode is
//!   position-dependent and bypasses the memo, as do snapshots with
//!   degraded job domains — TP-group drag is position-weighted, so a
//!   degraded response is not a function of the damage multiset).
//! * [`ResponseMemo`] — a signature-keyed response cache (each unique
//!   key holds every policy's response, so a snapshot costs one hash),
//!   shared across snapshots, trials and sweep points, carrying the
//!   [`EvalScratch`] buffers so the steady-state sweep allocates
//!   nothing: a repeated damage pattern costs one hash lookup instead
//!   of a full pack + table walk per policy. It also carries a
//!   count-keyed **transition-cost memo**: for policies declaring
//!   [`FtPolicy::transition_cost_is_count_pure`], the charge is a pure
//!   function of `(changed, degraded, live spares, n_gpus)` under one
//!   cost model, so repeated change patterns skip the prev/next scan
//!   (hit counters in `fleet --json` and `perf_hotpath`).

use super::adaptive::{AdaptiveOutcome, StopReason, StopRule};
use super::fleet::{grid_step, Accum, FleetStats, StepMode, StrategyTable};
use super::spares::SparePolicy;
use crate::cluster::Topology;
use crate::failure::{
    BlastRadius, DelayedEvents, DetectionModel, EventSource, FleetReplayer, ReplayCore, Trace,
    TraceCursor, TraceStream, TrialGen,
};
use crate::policy::{
    changed_domains, degraded_domains, EvalOut, EvalScratch, FtPolicy, PolicyCtx, TransitionCosts,
};
use crate::util::par;
use crate::util::stats::Welford;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::ops::Range;

/// Distinct deficit values a [`SnapshotSig`] stores without touching
/// the heap. Failures are rare and quantized (most damaged domains are
/// missing exactly one GPU), so real sweeps essentially never spill.
pub const SIG_INLINE: usize = 8;

/// Sparse snapshot signature: the sorted multiset of damaged domains,
/// run-length encoded as `(deficit, count)` pairs in ascending deficit
/// order (`deficit = domain_size - healthy`, only `deficit > 0`
/// domains appear). Two snapshots with equal signatures have equal
/// damaged-domain multisets — and therefore equal packed-mode policy
/// responses, which is what makes [`ResponseMemo`] sound.
#[derive(Clone, Debug)]
pub struct SnapshotSig {
    /// Logical number of `(deficit, count)` pairs.
    len: u32,
    /// Inline pair storage (valid for `len <= SIG_INLINE`).
    inline: [(u32, u32); SIG_INLINE],
    /// Spill storage holding *all* pairs once `len > SIG_INLINE`.
    spill: Vec<(u32, u32)>,
}

impl SnapshotSig {
    pub fn new() -> SnapshotSig {
        SnapshotSig { len: 0, inline: [(0, 0); SIG_INLINE], spill: Vec::new() }
    }

    /// The `(deficit, count)` pairs, ascending in deficit.
    pub fn pairs(&self) -> &[(u32, u32)] {
        if self.len as usize <= SIG_INLINE {
            &self.inline[..self.len as usize]
        } else {
            &self.spill
        }
    }

    /// Total damaged domains in the snapshot.
    pub fn n_damaged(&self) -> usize {
        self.pairs().iter().map(|&(_, c)| c as usize).sum()
    }

    /// Whether the signature lives entirely in inline storage.
    pub fn is_inline(&self) -> bool {
        self.len as usize <= SIG_INLINE
    }

    /// Rebuild in place from per-domain healthy counts. `deficits` is
    /// caller-owned scratch (reused capacity ⇒ no steady-state
    /// allocation).
    pub fn rebuild(&mut self, counts: &[usize], domain_size: usize, deficits: &mut Vec<u32>) {
        deficits.clear();
        for &h in counts {
            if h < domain_size {
                deficits.push((domain_size - h) as u32);
            }
        }
        deficits.sort_unstable();
        self.len = 0;
        self.spill.clear();
        let mut i = 0;
        while i < deficits.len() {
            let d = deficits[i];
            let mut c = 1usize;
            while i + c < deficits.len() && deficits[i + c] == d {
                c += 1;
            }
            self.push((d, c as u32));
            i += c;
        }
    }

    /// Rebuild in place from a deficit *histogram* (`hist[k]` = number
    /// of domains missing exactly `k` GPUs; index 0 ignored) — the
    /// aggregate [`crate::failure::ReplayCore`] maintains incrementally.
    /// An ascending scan of the histogram yields exactly the sorted RLE
    /// pairs [`SnapshotSig::rebuild`] derives from the raw counts, so
    /// the two builds are interchangeable as memo keys (property-tested
    /// in `rust/tests/streaming_trials.rs` with the from-scratch build
    /// as the oracle).
    pub fn rebuild_from_histogram(&mut self, hist: &[u32]) {
        self.len = 0;
        self.spill.clear();
        for (deficit, &count) in hist.iter().enumerate().skip(1) {
            if count > 0 {
                self.push((deficit as u32, count));
            }
        }
    }

    fn push(&mut self, pair: (u32, u32)) {
        let len = self.len as usize;
        if len < SIG_INLINE {
            self.inline[len] = pair;
        } else {
            if len == SIG_INLINE {
                // First spill: move the inline prefix over.
                self.spill.extend_from_slice(&self.inline);
            }
            self.spill.push(pair);
        }
        self.len += 1;
    }
}

impl Default for SnapshotSig {
    fn default() -> Self {
        SnapshotSig::new()
    }
}

impl PartialEq for SnapshotSig {
    fn eq(&self, other: &SnapshotSig) -> bool {
        self.pairs() == other.pairs()
    }
}
impl Eq for SnapshotSig {}
impl Hash for SnapshotSig {
    fn hash<H: Hasher>(&self, state: &mut H) {
        self.pairs().hash(state);
    }
}

/// Memo key: the damage signature plus the two snapshot-dependent
/// scalars a response may consult — the job-domain count (sweep points
/// trade job domains for spares) and the live spare pool.
#[derive(Clone, PartialEq, Eq, Hash)]
struct MemoKey {
    sig: SnapshotSig,
    n_job: u32,
    /// Live spare-domain pool; `u32::MAX` ⇒ flexible-minibatch mode.
    live_spares: u32,
}

/// Sweep-configuration fingerprint: a [`ResponseMemo`] is only valid
/// for one evaluation context (same table *contents*, packing mode,
/// replica shape, spare `min_tp`, transition-cost model).
/// [`MultiPolicySim`] binds the memo on first use and panics if it is
/// later reused with an incompatible config — the table is
/// fingerprinted by its response-defining contents
/// ([`table_fingerprint`]), so e.g. two tables built for different
/// `RackDesign`s (identical shapes, different `batch_pw`) are correctly
/// rejected, and the [`TransitionCosts`] are fingerprinted too because
/// both the transition memo and `CKPT-ADAPTIVE`'s steady-state
/// write-overhead factor depend on them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct MemoCtx {
    domain_size: usize,
    domains_per_replica: usize,
    packed: bool,
    spare_min_tp: usize,
    /// Total provisioned GPUs: the donated-channel fractions cached in
    /// each `EvalOut` are normalized by `ctx.n_gpus`, so two sims with
    /// different GPU totals must not share cached responses even when
    /// every other field (and the memo key) coincides.
    n_gpus: usize,
    /// Cold tier of the configured spare pool: the two-tier transition
    /// bill (and the live cold-pool split) depends on it, so sweeps
    /// differing only in the warm/cold split must not share cached
    /// transition charges.
    spare_cold_domains: usize,
    table_fingerprint: u64,
    transition_fingerprint: u64,
    /// [`DetectionModel::fingerprint`] of the sweep's detection model
    /// (`0` = instant/no detection). Detection shifts which snapshots a
    /// sweep visits and adds model-dependent rollback bills, so memos
    /// must not cross detection configurations.
    detect_fingerprint: u64,
}

/// Content hash of the sweep's transition-cost model (bit patterns; `0`
/// reserved for "no model").
fn transition_fingerprint(transition: &Option<TransitionCosts>) -> u64 {
    use std::collections::hash_map::DefaultHasher;
    let Some(t) = transition else { return 0 };
    // Exhaustive destructuring on purpose: adding a field to
    // `TransitionCosts` without updating this fingerprint would be a
    // silent memo-aliasing hazard — make it a compile error instead.
    let TransitionCosts {
        restart_secs,
        checkpoint_interval_secs,
        reshard_secs,
        spare_load_secs,
        cold_spare_load_secs,
        preempt_secs,
        rejoin_secs,
        ckpt_write_secs,
        power_ramp_secs,
        failure_rate_per_hour,
        validation_sweep_secs,
    } = *t;
    let mut h = DefaultHasher::new();
    for v in [
        restart_secs,
        checkpoint_interval_secs,
        reshard_secs,
        spare_load_secs,
        cold_spare_load_secs,
        preempt_secs,
        rejoin_secs,
        ckpt_write_secs,
        power_ramp_secs,
        failure_rate_per_hour,
        validation_sweep_secs,
    ] {
        v.to_bits().hash(&mut h);
    }
    h.finish().max(1)
}

/// Content hash of everything in a [`StrategyTable`] that a policy
/// response can depend on. f64 values hash by bit pattern.
fn table_fingerprint(table: &StrategyTable) -> u64 {
    use std::collections::hash_map::DefaultHasher;
    let mut h = DefaultHasher::new();
    table.full_tp.hash(&mut h);
    table.min_tp.hash(&mut h);
    table.full_local_batch.hash(&mut h);
    table.batch.hash(&mut h);
    table.batch_pw.hash(&mut h);
    for p in &table.power {
        match p {
            None => 0u64.hash(&mut h),
            Some(v) => (1u64, v.to_bits()).hash(&mut h),
        }
    }
    table.reshard_overhead.to_bits().hash(&mut h);
    table.straggler_phi.to_bits().hash(&mut h);
    // The rack design shapes every policy's power snapshot (and
    // NTP-PW's row-boost allowance), so two tables differing only in
    // rack knobs must not share cached responses. Exhaustive
    // destructuring: adding a RackDesign field without hashing it here
    // becomes a compile error.
    let crate::power::RackDesign {
        gpu_boost_cap,
        rack_budget_frac,
        thermal: crate::power::ThermalModel { headroom_secs, recover_frac },
        standby_frac,
        idle_frac,
        degraded_derate,
        row_domains,
        row_budget_frac,
    } = table.rack;
    for v in [
        gpu_boost_cap,
        rack_budget_frac,
        headroom_secs,
        recover_frac,
        standby_frac,
        idle_frac,
        degraded_derate,
        row_budget_frac,
    ] {
        v.to_bits().hash(&mut h);
    }
    row_domains.hash(&mut h);
    h.finish()
}

/// Signature-keyed response cache — each unique snapshot key maps to
/// the responses of **every** policy in the sweep's list (one hash +
/// one key per snapshot, not per policy) — plus the scratch buffers
/// threaded through every evaluation. Create once and pass to
/// [`MultiPolicySim::run_with`] / [`MultiPolicySim::run_trials`] to
/// share memoized responses across snapshots, Monte-Carlo trials and
/// sweep points. The memo is bound on first use to one evaluation
/// context (table contents fingerprinted) **and one policy list**
/// (order included); reuse with a different config or list panics
/// instead of silently serving one policy's cached responses as
/// another's. Limitation: policies are identified by [`FtPolicy::name`]
/// — two instances of the same policy type with different *parameters*
/// but the same name would alias, so give parameterized policy variants
/// distinct names (every in-tree registry policy is a parameterless
/// singleton).
pub struct ResponseMemo {
    map: HashMap<MemoKey, MemoEntry>,
    n_policies: usize,
    policy_names: Vec<&'static str>,
    ctx: Option<MemoCtx>,
    hits: u64,
    misses: u64,
    // Count-keyed transition-cost memo: every in-tree policy's
    // `transition_cost` is a pure function of `(changed domains,
    // degraded domains, live spare pool, total GPUs)` given one cost
    // model ([`crate::policy::FtPolicy::transition_cost_is_count_pure`];
    // the model itself is pinned by `MemoCtx::transition_fingerprint`),
    // so a repeated change pattern costs one hash instead of a
    // prev/next scan per policy.
    tmap: HashMap<TransKey, (u64, f64)>,
    thits: u64,
    tmisses: u64,
    // Grid-sweep attribution: every cached entry remembers the sweep
    // point ([`ResponseMemo::begin_point`] generation) that computed
    // it, so hits served by an *earlier* point's entry are counted
    // separately — the cross-grid-point reuse the `sweep` CLI reports.
    // Epochs never affect cached values, only the counters.
    point_epoch: u64,
    cross_hits: u64,
    cross_thits: u64,
    // Scratch shared by every evaluation driven through this memo.
    sig: SnapshotSig,
    deficits: Vec<u32>,
    scratch: EvalScratch,
    // Previous-snapshot scratch of the exact sweep, owned here so the
    // per-trial sweep loop allocates nothing: the streaming Monte-Carlo
    // path's O(1)-memory gate (benches/perf_hotpath.rs) counts every
    // allocation per trial, and at 100K-GPU scale these three vectors
    // would otherwise dominate it.
    prev_counts: Vec<usize>,
    prev_degraded: Vec<usize>,
    prev_slow: Vec<f64>,
}

/// One cached snapshot response set plus the sweep point that computed
/// it (for cross-point hit attribution).
struct MemoEntry {
    epoch: u64,
    outs: Box<[EvalOut]>,
}

/// Transition-memo key: `(policy index, changed, degraded, live spare
/// pool, total provisioned GPUs)`. The live-pool component packs the
/// total live spares in the low half and the live *cold* spares in the
/// high half (`u64::MAX` ⇒ no pool): the two-tier spare bill depends
/// on the warm/cold split, not just the total.
type TransKey = (u32, u32, u32, u64, u64);

/// Pack a live spare pool into its [`TransKey`] component: total live
/// spares in the low 32 bits, live cold spares in the high 32
/// (`u64::MAX` ⇒ no pool configured — unreachable as a packed value,
/// since a real pool's cold tier never exceeds its total).
fn live_pool_key(spares: &Option<SparePolicy>) -> u64 {
    match spares {
        Some(pool) => pool.spare_domains as u64 | (pool.cold_domains as u64) << 32,
        None => u64::MAX,
    }
}

/// Constant-memory per-policy fold of a Monte-Carlo trial batch:
/// running sums of every per-trial reporting quantity (the means the
/// `fleet` CLI prints) plus [`Welford`] moments over per-trial mean and
/// net throughput, for confidence intervals without storing per-trial
/// stats. Built by [`MultiPolicySim::run_trials_stream_agg`] /
/// [`MultiPolicySim::run_trials_stream_agg_par`].
#[derive(Clone, Debug, Default)]
pub struct PolicyAggregate {
    /// Welford moments over per-trial `mean_throughput` (drives
    /// [`PolicyAggregate::tput_ci95`]).
    pub tput: Welford,
    /// Welford moments over per-trial `net_throughput()`.
    pub net_tput: Welford,
    sum_tput: f64,
    sum_net_tput: f64,
    sum_tput_per_gpu: f64,
    sum_paused_frac: f64,
    sum_downtime_frac: f64,
    sum_donated: f64,
    sum_spares_used: f64,
    sum_transitions: f64,
    sum_power_frac: f64,
    sum_energy_per_token: f64,
    peak_power: f64,
}

impl PolicyAggregate {
    /// Fold one trial's stats in. Derived quantities
    /// (`net_throughput()`, …) are computed per trial and then summed —
    /// exactly how the CLI averages a stored per-trial vector.
    pub fn push(&mut self, s: &FleetStats) {
        self.tput.push(s.mean_throughput);
        self.net_tput.push(s.net_throughput());
        self.sum_tput += s.mean_throughput;
        self.sum_net_tput += s.net_throughput();
        self.sum_tput_per_gpu += s.throughput_per_gpu;
        self.sum_paused_frac += s.paused_frac;
        self.sum_downtime_frac += s.downtime_frac;
        self.sum_donated += s.mean_donated;
        self.sum_spares_used += s.mean_spares_used;
        self.sum_transitions += s.transitions as f64;
        self.sum_power_frac += s.mean_power_frac;
        self.sum_energy_per_token += s.energy_per_token();
        if s.peak_rack_power_frac > self.peak_power {
            self.peak_power = s.peak_rack_power_frac;
        }
    }

    /// Merge another batch's fold. No longer on the parallel hot path
    /// (the steal scheduler folds per-trial stats in trial-index order
    /// instead, keeping aggregates bit-identical across thread
    /// counts); kept for callers combining independently-built
    /// aggregates, where Welford-merge rounding is acceptable.
    pub fn merge(&mut self, other: &PolicyAggregate) {
        self.tput.merge(&other.tput);
        self.net_tput.merge(&other.net_tput);
        self.sum_tput += other.sum_tput;
        self.sum_net_tput += other.sum_net_tput;
        self.sum_tput_per_gpu += other.sum_tput_per_gpu;
        self.sum_paused_frac += other.sum_paused_frac;
        self.sum_downtime_frac += other.sum_downtime_frac;
        self.sum_donated += other.sum_donated;
        self.sum_spares_used += other.sum_spares_used;
        self.sum_transitions += other.sum_transitions;
        self.sum_power_frac += other.sum_power_frac;
        self.sum_energy_per_token += other.sum_energy_per_token;
        if other.peak_power > self.peak_power {
            self.peak_power = other.peak_power;
        }
    }

    /// Trials folded in.
    pub fn trials(&self) -> u64 {
        self.tput.count()
    }

    fn mean(&self, sum: f64) -> f64 {
        sum / self.trials().max(1) as f64
    }

    /// Mean per-trial `mean_throughput` (plain sum-over-n, matching the
    /// stored-per-trial CLI path rather than the Welford running mean).
    pub fn mean_tput(&self) -> f64 {
        self.mean(self.sum_tput)
    }

    /// Mean per-trial `net_throughput()`.
    pub fn mean_net_tput(&self) -> f64 {
        self.mean(self.sum_net_tput)
    }

    /// Mean per-trial `throughput_per_gpu`.
    pub fn mean_tput_per_gpu(&self) -> f64 {
        self.mean(self.sum_tput_per_gpu)
    }

    /// Mean per-trial `paused_frac`.
    pub fn mean_paused_frac(&self) -> f64 {
        self.mean(self.sum_paused_frac)
    }

    /// Mean per-trial `downtime_frac`.
    pub fn mean_downtime_frac(&self) -> f64 {
        self.mean(self.sum_downtime_frac)
    }

    /// Mean per-trial `mean_donated`.
    pub fn mean_donated(&self) -> f64 {
        self.mean(self.sum_donated)
    }

    /// Mean per-trial `mean_spares_used`.
    pub fn mean_spares_used(&self) -> f64 {
        self.mean(self.sum_spares_used)
    }

    /// Mean per-trial reconfiguration count.
    pub fn mean_transitions(&self) -> f64 {
        self.mean(self.sum_transitions)
    }

    /// Mean per-trial `mean_power_frac`.
    pub fn mean_power_frac(&self) -> f64 {
        self.mean(self.sum_power_frac)
    }

    /// Mean per-trial `energy_per_token()` (computed per trial then
    /// averaged, exactly like the stored-per-trial CLI path).
    pub fn mean_energy_per_token(&self) -> f64 {
        self.mean(self.sum_energy_per_token)
    }

    /// Max per-trial `peak_rack_power_frac` — a max over trials of a
    /// max over the horizon, so batch order cannot change it.
    pub fn peak_rack_power_frac(&self) -> f64 {
        self.peak_power
    }

    /// Half-width of the 95% confidence interval on the mean
    /// throughput (`t·σ/√n` with the Student-t critical value for
    /// `n − 1` degrees of freedom, `crate::util::stats::t_critical_95`
    /// — 1.96 only for large n; `0` below two trials).
    pub fn tput_ci95(&self) -> f64 {
        self.tput.ci95()
    }
}

impl ResponseMemo {
    pub fn new(n_policies: usize) -> ResponseMemo {
        ResponseMemo {
            map: HashMap::new(),
            n_policies,
            policy_names: Vec::new(),
            ctx: None,
            hits: 0,
            misses: 0,
            tmap: HashMap::new(),
            thits: 0,
            tmisses: 0,
            point_epoch: 0,
            cross_hits: 0,
            cross_thits: 0,
            sig: SnapshotSig::new(),
            deficits: Vec::new(),
            scratch: EvalScratch::default(),
            prev_counts: Vec::new(),
            prev_degraded: Vec::new(),
            prev_slow: Vec::new(),
        }
    }

    /// Declare a new grid-sweep point: cache entries stay valid (the
    /// bind check still enforces one evaluation context), but hits
    /// served from entries computed by earlier points are counted as
    /// *cross-point* hits from here on. A memo that never sees
    /// `begin_point` reports zero cross-point hits.
    pub fn begin_point(&mut self) {
        self.point_epoch += 1;
    }

    /// Snapshot hits served from an entry computed by an earlier
    /// grid-sweep point.
    pub fn cross_hits(&self) -> u64 {
        self.cross_hits
    }

    /// Transition-memo hits served from an earlier grid-sweep point.
    pub fn cross_transition_hits(&self) -> u64 {
        self.cross_thits
    }

    /// Snapshot lookups served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Snapshot lookups that fell through to policy evaluations.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Fraction of snapshot lookups served from the cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Unique snapshot keys cached (each holds all policies' responses).
    pub fn unique_entries(&self) -> usize {
        self.map.len()
    }

    /// Transition-cost lookups served from the count-keyed memo.
    pub fn transition_hits(&self) -> u64 {
        self.thits
    }

    /// Transition-cost lookups that fell through to
    /// [`FtPolicy::transition_cost`].
    pub fn transition_misses(&self) -> u64 {
        self.tmisses
    }

    /// Fraction of transition charges served from the memo.
    pub fn transition_hit_rate(&self) -> f64 {
        let total = self.thits + self.tmisses;
        if total == 0 {
            0.0
        } else {
            self.thits as f64 / total as f64
        }
    }

    /// Memoized [`FtPolicy::transition_cost`]: served from the
    /// count-keyed cache for count-pure policies, computed directly
    /// otherwise (and when reconfigurations are free — the zero-cost
    /// contract stays with the policy).
    fn transition_cost(
        &mut self,
        key: TransKey,
        policy: &dyn FtPolicy,
        ctx: &PolicyCtx,
        prev: &[usize],
        next: &[usize],
    ) -> f64 {
        if ctx.transition.is_none() || !policy.transition_cost_is_count_pure() {
            return policy.transition_cost(ctx, prev, next);
        }
        if let Some(&(epoch, cost)) = self.tmap.get(&key) {
            self.thits += 1;
            if epoch != self.point_epoch {
                self.cross_thits += 1;
            }
            return cost;
        }
        self.tmisses += 1;
        let cost = policy.transition_cost(ctx, prev, next);
        self.tmap.insert(key, (self.point_epoch, cost));
        cost
    }

    /// Serve `outs` for `key` from the cache, or compute via `eval`
    /// (handed the shared scratch) and cache the result. The one
    /// snapshot-lookup funnel for every sweep path, so hit/miss and
    /// cross-point counters stay consistent between the slice-rebuild
    /// and incremental-histogram key builders.
    fn respond_cached(
        &mut self,
        key: MemoKey,
        outs: &mut [EvalOut],
        eval: impl FnOnce(&mut EvalScratch, &mut [EvalOut]),
    ) {
        if let Some(entry) = self.map.get(&key) {
            self.hits += 1;
            if entry.epoch != self.point_epoch {
                self.cross_hits += 1;
            }
            outs.copy_from_slice(&entry.outs);
            return;
        }
        self.misses += 1;
        eval(&mut self.scratch, outs);
        self.map.insert(
            key,
            MemoEntry { epoch: self.point_epoch, outs: outs.to_vec().into_boxed_slice() },
        );
    }

    /// Counter snapshot for reporting and for merging across the
    /// per-thread memos of [`MultiPolicySim::run_trials_par`].
    pub fn stats(&self) -> MemoStats {
        MemoStats {
            hits: self.hits,
            misses: self.misses,
            transition_hits: self.thits,
            transition_misses: self.tmisses,
            cross_hits: self.cross_hits,
            cross_transition_hits: self.cross_thits,
            unique_entries: self.map.len(),
        }
    }

    fn bind(&mut self, expect: MemoCtx, policies: &[&dyn FtPolicy]) {
        assert_eq!(
            self.n_policies,
            policies.len(),
            "ResponseMemo built for a different policy count"
        );
        match self.ctx {
            None => {
                self.ctx = Some(expect);
                self.policy_names = policies.iter().map(|p| p.name()).collect();
            }
            Some(have) => {
                assert_eq!(
                    have, expect,
                    "ResponseMemo reused across incompatible sweep configurations"
                );
                assert!(
                    self.policy_names.iter().zip(policies).all(|(&n, p)| n == p.name()),
                    "ResponseMemo reused across a different policy list \
                     (have {:?}, got {:?})",
                    self.policy_names,
                    policies.iter().map(|p| p.name()).collect::<Vec<_>>()
                );
            }
        }
    }
}

/// Mergeable snapshot of a [`ResponseMemo`]'s hit/miss counters.
/// [`MultiPolicySim::run_trials_par`] gives each worker thread its own
/// memo and merges their counters into one fleet-wide view (the
/// `memo_hit_rate` / `transition_memo_hit_rate` the `fleet --json`
/// report carries for parallel Monte-Carlo runs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemoStats {
    pub hits: u64,
    pub misses: u64,
    pub transition_hits: u64,
    pub transition_misses: u64,
    /// Snapshot hits served by an entry computed under an earlier
    /// [`ResponseMemo::begin_point`] generation (zero unless the caller
    /// marks grid points).
    pub cross_hits: u64,
    /// Transition-memo hits served from an earlier grid point.
    pub cross_transition_hits: u64,
    /// Unique snapshot keys cached. Merged across per-thread memos this
    /// *sums* — threads do not share entries, so a signature cached by
    /// two workers counts twice (duplicated work is exactly what the
    /// number then shows).
    pub unique_entries: usize,
}

impl MemoStats {
    /// Accumulate another memo's counters into this one.
    pub fn merge(&mut self, other: &MemoStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.transition_hits += other.transition_hits;
        self.transition_misses += other.transition_misses;
        self.cross_hits += other.cross_hits;
        self.cross_transition_hits += other.cross_transition_hits;
        self.unique_entries += other.unique_entries;
    }

    /// Fraction of *all* memo lookups (snapshot + transition) served by
    /// an entry computed under an earlier grid point — the cross-point
    /// reuse a shared-memo grid sweep exists for.
    pub fn cross_hit_rate(&self) -> f64 {
        let total = self.hits + self.misses + self.transition_hits + self.transition_misses;
        if total == 0 {
            0.0
        } else {
            (self.cross_hits + self.cross_transition_hits) as f64 / total as f64
        }
    }

    /// Fraction of snapshot lookups served from cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Fraction of transition charges served from the count-keyed memo.
    pub fn transition_hit_rate(&self) -> f64 {
        let total = self.transition_hits + self.transition_misses;
        if total == 0 {
            0.0
        } else {
            self.transition_hits as f64 / total as f64
        }
    }
}

/// One-replay-per-trace sweep over many fault-tolerance policies: the
/// shared-sweep counterpart of [`super::FleetSim`] (which remains the
/// per-policy reference implementation). Field semantics are identical
/// to `FleetSim`, with `policies` replacing the single `policy`.
pub struct MultiPolicySim<'a> {
    pub topo: &'a Topology,
    pub table: &'a StrategyTable,
    pub domains_per_replica: usize,
    /// Policies evaluated per snapshot; output order matches.
    pub policies: &'a [&'a dyn FtPolicy],
    pub spares: Option<SparePolicy>,
    pub packed: bool,
    pub blast: BlastRadius,
    pub transition: Option<TransitionCosts>,
    /// Imperfect failure detection: when active (see
    /// [`DetectionModel::active`]), every event source is wrapped in a
    /// [`DelayedEvents`] adapter — policies see faults late, undetected
    /// stall is billed, and the expected false-positive evictions are
    /// charged per policy. `None` (or the all-zero model) runs the
    /// instant-detection code path bit-for-bit.
    pub detect: Option<DetectionModel>,
}

/// Trials per work-stealing window in the non-adaptive entry points:
/// bounds the per-window result buffer (the fold itself is
/// window-size-invariant — stats are handed over in trial-index order
/// regardless of where window boundaries fall), preserving the
/// aggregate path's O(1) memory in the total trial count. In adaptive
/// mode the window is the stop rule's round instead.
const STEAL_WINDOW: usize = 1024;

/// Per-worker state of the work-stealing trial scheduler: one replayer
/// (reset per claimed trial, keeping the fleet-health allocation — the
/// O(1)-memory-per-trial property the perf gate counts) and one
/// private [`ResponseMemo`]. Workers persist across windows and
/// rounds, so replayer and memo reuse span the whole run.
struct TrialWorker<S: EventSource> {
    rep: Option<ReplayCore<S>>,
    memo: ResponseMemo,
}

impl<'a> MultiPolicySim<'a> {
    /// A fresh memo sized for this sim's policy list.
    pub fn memo(&self) -> ResponseMemo {
        ResponseMemo::new(self.policies.len())
    }

    fn trial_worker<S: EventSource>(&self) -> TrialWorker<S> {
        TrialWorker { rep: None, memo: self.memo() }
    }

    /// Sweep one source on a reusable replayer slot: the first call
    /// builds the replayer, later calls reset it in place
    /// ([`ReplayCore::reset_source`] keeps every allocation).
    fn sweep_source<S: EventSource>(
        &self,
        rep: &mut Option<ReplayCore<S>>,
        src: S,
        mode: StepMode,
        memo: &mut ResponseMemo,
    ) -> Vec<FleetStats> {
        match rep.as_mut() {
            Some(r) => r.reset_source(src),
            None => *rep = Some(ReplayCore::from_source(src, self.topo, self.blast)),
        }
        self.sweep(rep.as_mut().unwrap(), mode, memo)
    }

    /// The work-stealing trial scheduler behind every parallel
    /// Monte-Carlo entry point and the adaptive runner. Trials
    /// `0..max` are claimed one at a time from an atomic cursor
    /// ([`par::par_steal_with_states`]) by up to `threads` persistent
    /// [`TrialWorker`]s, in windows of `window` trials; a slow trial
    /// (correlated-blast trace with thousands of events) occupies one
    /// worker while the rest drain the remainder, instead of gating a
    /// static batch. After each window the per-trial stats are handed
    /// to `on_window` **in trial-index order** — claim order never
    /// leaks — and a `true` return stops the run at that window
    /// boundary. Returns the merged per-worker memo counters.
    ///
    /// **Determinism contract:** every per-trial stat, and any fold
    /// `on_window` performs, is bit-identical for any `threads` and
    /// any steal schedule. Each trial's integration touches only its
    /// own source plus the sim configuration, memoization is exact — a
    /// cached response or transition charge is the identical `f64`s a
    /// recompute would produce (`rust/tests/multi_policy_sweep.rs`) —
    /// and the coordinator folds in trial-index order, so neither the
    /// trial→worker assignment nor the window size can change any
    /// stat. Only the merged [`MemoStats`] depend on the schedule
    /// (which worker's private memo could serve a repeat); their total
    /// lookup count does not.
    fn steal_trials<S, Mk>(
        &self,
        max: usize,
        window: usize,
        threads: usize,
        mk_src: Mk,
        mode: StepMode,
        mut on_window: impl FnMut(Vec<Vec<FleetStats>>) -> bool,
    ) -> MemoStats
    where
        S: EventSource + Send,
        Mk: Fn(usize) -> S + Sync,
    {
        let t = threads.max(1).min(max.max(1));
        let window = window.max(1);
        let mut workers: Vec<TrialWorker<S>> = (0..t).map(|_| self.trial_worker()).collect();
        let mut start = 0usize;
        while start < max {
            let end = (start + window).min(max);
            let stats = par::par_steal_with_states(end - start, &mut workers, |w, i| {
                self.sweep_source(&mut w.rep, mk_src(start + i), mode, &mut w.memo)
            });
            start = end;
            if on_window(stats) {
                break;
            }
        }
        let mut merged = MemoStats::default();
        for w in &workers {
            merged.merge(&w.memo.stats());
        }
        merged
    }

    /// Sweep one trace with a private memo. Returns one [`FleetStats`]
    /// per policy, bit-identical to running [`super::FleetSim::run`]
    /// once per policy under the same [`StepMode`].
    pub fn run(&self, trace: &Trace, mode: StepMode) -> Vec<FleetStats> {
        self.run_with(trace, mode, &mut self.memo())
    }

    /// Sweep one trace, sharing `memo` with other sweeps of the same
    /// evaluation context (same table / packing / replica shape) and
    /// the same policy list — both enforced by the memo's bind check.
    pub fn run_with(
        &self,
        trace: &Trace,
        mode: StepMode,
        memo: &mut ResponseMemo,
    ) -> Vec<FleetStats> {
        if let Some(d) = DetectionModel::active(&self.detect) {
            let src = DelayedEvents::new(TraceCursor::new(trace), *d, self.topo.n_gpus);
            let mut rep = ReplayCore::from_source(src, self.topo, self.blast);
            return self.sweep(&mut rep, mode, memo);
        }
        let mut rep = FleetReplayer::new(trace, self.topo, self.blast);
        self.sweep(&mut rep, mode, memo)
    }

    /// Sweep many traces (Monte-Carlo trials) reusing one replayer
    /// ([`FleetReplayer::reset`] keeps the fleet-health allocation) and
    /// one shared memo. Returns per-trace, per-policy stats.
    pub fn run_trials(
        &self,
        traces: &[Trace],
        mode: StepMode,
        memo: &mut ResponseMemo,
    ) -> Vec<Vec<FleetStats>> {
        let mut out = Vec::with_capacity(traces.len());
        let Some(first) = traces.first() else {
            return out;
        };
        if let Some(d) = DetectionModel::active(&self.detect) {
            let wrap = |trace| DelayedEvents::new(TraceCursor::new(trace), *d, self.topo.n_gpus);
            let mut rep = ReplayCore::from_source(wrap(first), self.topo, self.blast);
            out.push(self.sweep(&mut rep, mode, memo));
            for trace in &traces[1..] {
                rep.reset_source(wrap(trace));
                out.push(self.sweep(&mut rep, mode, memo));
            }
            return out;
        }
        let mut rep = FleetReplayer::new(first, self.topo, self.blast);
        out.push(self.sweep(&mut rep, mode, memo));
        for trace in &traces[1..] {
            rep.reset(trace);
            out.push(self.sweep(&mut rep, mode, memo));
        }
        out
    }

    /// Parallel Monte-Carlo over materialized traces: up to `threads`
    /// work-stealing [`TrialWorker`]s (see [`Self::steal_trials`])
    /// claim traces one at a time from an atomic cursor, each sweeping
    /// on its own reusable replayer and its own [`ResponseMemo`]. The
    /// per-trace, per-policy stats come back in input order with the
    /// per-worker memo counters merged.
    ///
    /// **Determinism contract:** the result is bit-identical to
    /// [`Self::run_trials`] with one thread (and to any other thread
    /// count or steal schedule) — see [`Self::steal_trials`]. Only the
    /// merged [`MemoStats`] depend on the schedule: which worker's
    /// private memo could serve a repeated damage pattern.
    pub fn run_trials_par(
        &self,
        traces: &[Trace],
        mode: StepMode,
        threads: usize,
    ) -> (Vec<Vec<FleetStats>>, MemoStats) {
        let mut all = Vec::with_capacity(traces.len());
        let collect = |stats: Vec<Vec<FleetStats>>| {
            all.extend(stats);
            false
        };
        let ms = match DetectionModel::active(&self.detect) {
            Some(d) => self.steal_trials(
                traces.len(),
                STEAL_WINDOW,
                threads,
                |i| DelayedEvents::new(TraceCursor::new(&traces[i]), *d, self.topo.n_gpus),
                mode,
                collect,
            ),
            None => self.steal_trials(
                traces.len(),
                STEAL_WINDOW,
                threads,
                |i| TraceCursor::new(&traces[i]),
                mode,
                collect,
            ),
        };
        (all, ms)
    }

    /// Sweep one live [`TraceStream`] without materializing it. The
    /// stats are bit-identical to `run_with(&stream.collect_trace(), ..)`
    /// — the stream hands the replayer the same events in the same
    /// order, and SDC rollback is billed from the pairs accumulated
    /// during the sweep instead of a trace scan.
    pub fn run_stream(
        &self,
        stream: TraceStream,
        mode: StepMode,
        memo: &mut ResponseMemo,
    ) -> Vec<FleetStats> {
        if let Some(d) = DetectionModel::active(&self.detect) {
            let src = DelayedEvents::new(stream, *d, self.topo.n_gpus);
            let mut rep = ReplayCore::from_source(src, self.topo, self.blast);
            return self.sweep(&mut rep, mode, memo);
        }
        let mut rep = ReplayCore::from_source(stream, self.topo, self.blast);
        self.sweep(&mut rep, mode, memo)
    }

    /// Streaming Monte-Carlo: sweep every trial of `gen` without ever
    /// materializing a `Trace` — one replayer is reset from stream to
    /// stream ([`ReplayCore::reset_source`]), so the whole loop runs in
    /// O(1) memory per trial regardless of horizon. Bit-identical to
    /// `run_trials(&gen.traces(), ..)` with the same memo.
    pub fn run_trials_stream(
        &self,
        gen: &TrialGen,
        mode: StepMode,
        memo: &mut ResponseMemo,
    ) -> Vec<Vec<FleetStats>> {
        self.run_trials_stream_range(gen, 0..gen.trials, mode, memo)
    }

    fn run_trials_stream_range(
        &self,
        gen: &TrialGen,
        trials: Range<usize>,
        mode: StepMode,
        memo: &mut ResponseMemo,
    ) -> Vec<Vec<FleetStats>> {
        let mut out = Vec::with_capacity(trials.len());
        self.for_each_trial_stream(gen, trials, mode, memo, |stats| out.push(stats));
        out
    }

    /// Drive `f` with each trial's per-policy stats, reusing one
    /// replayer across the whole range ([`ReplayCore::reset_source`]
    /// keeps the fleet-health allocation — the O(1)-memory-per-trial
    /// property the perf gate counts). The single streaming trial loop:
    /// both the per-trial collector and the constant-memory aggregator
    /// run through here, so they cannot drift apart.
    fn for_each_trial_stream(
        &self,
        gen: &TrialGen,
        trials: Range<usize>,
        mode: StepMode,
        memo: &mut ResponseMemo,
        mut f: impl FnMut(Vec<FleetStats>),
    ) {
        if let Some(d) = DetectionModel::active(&self.detect) {
            let mut rep: Option<ReplayCore<DelayedEvents<TraceStream>>> = None;
            for trial in trials {
                let src = DelayedEvents::new(gen.stream_for(trial), *d, self.topo.n_gpus);
                if let Some(r) = rep.as_mut() {
                    r.reset_source(src);
                } else {
                    rep = Some(ReplayCore::from_source(src, self.topo, self.blast));
                }
                f(self.sweep(rep.as_mut().unwrap(), mode, memo));
            }
            return;
        }
        let mut rep: Option<ReplayCore<TraceStream>> = None;
        for trial in trials {
            let stream = gen.stream_for(trial);
            if let Some(r) = rep.as_mut() {
                r.reset_source(stream);
            } else {
                rep = Some(ReplayCore::from_source(stream, self.topo, self.blast));
            }
            f(self.sweep(rep.as_mut().unwrap(), mode, memo));
        }
    }

    /// Parallel streaming Monte-Carlo: [`MultiPolicySim::run_trials_par`]
    /// over a [`TrialGen`] instead of a trace slice. Trial PRNGs are
    /// random-access (`TrialGen::rng_for` forks from a fresh root), so
    /// a stealing worker draws whichever trial it claims with no shared
    /// generation pass, and each trial's stream is bit-identical to its
    /// materialized trace — the stats match the materialized path at
    /// every thread count ([`Self::steal_trials`] determinism
    /// contract).
    pub fn run_trials_stream_par(
        &self,
        gen: &TrialGen,
        mode: StepMode,
        threads: usize,
    ) -> (Vec<Vec<FleetStats>>, MemoStats) {
        let mut all = Vec::with_capacity(gen.trials);
        let collect = |stats: Vec<Vec<FleetStats>>| {
            all.extend(stats);
            false
        };
        let ms = match DetectionModel::active(&self.detect) {
            Some(d) => self.steal_trials(
                gen.trials,
                STEAL_WINDOW,
                threads,
                |i| DelayedEvents::new(gen.stream_for(i), *d, self.topo.n_gpus),
                mode,
                collect,
            ),
            None => self.steal_trials(
                gen.trials,
                STEAL_WINDOW,
                threads,
                |i| gen.stream_for(i),
                mode,
                collect,
            ),
        };
        (all, ms)
    }

    /// Streaming Monte-Carlo with **O(1) memory in the trial count**:
    /// instead of returning per-trial stats, fold every trial into one
    /// [`PolicyAggregate`] per policy (running sums + Welford moments).
    /// The per-trial stats folded in are bit-identical to
    /// [`MultiPolicySim::run_trials_stream`]'s — both run through the
    /// same trial loop.
    pub fn run_trials_stream_agg(
        &self,
        gen: &TrialGen,
        mode: StepMode,
        memo: &mut ResponseMemo,
    ) -> Vec<PolicyAggregate> {
        self.run_trials_stream_agg_range(gen, 0..gen.trials, mode, memo)
    }

    fn run_trials_stream_agg_range(
        &self,
        gen: &TrialGen,
        trials: Range<usize>,
        mode: StepMode,
        memo: &mut ResponseMemo,
    ) -> Vec<PolicyAggregate> {
        let mut aggs = vec![PolicyAggregate::default(); self.policies.len()];
        self.for_each_trial_stream(gen, trials, mode, memo, |stats| {
            for (agg, s) in aggs.iter_mut().zip(&stats) {
                agg.push(s);
            }
        });
        aggs
    }

    /// Parallel [`MultiPolicySim::run_trials_stream_agg`]: stealing
    /// workers compute per-trial stats, and the coordinator folds them
    /// into one [`PolicyAggregate`] per policy **in trial-index
    /// order** — exactly the push sequence the sequential aggregator
    /// performs, never a cross-worker [`crate::util::stats::Welford`]
    /// merge. Per-window hand-off keeps the memory O(1) in the trial
    /// count ([`STEAL_WINDOW`]).
    ///
    /// **Determinism contract:** the folded sums, Welford moments and
    /// [`PolicyAggregate::tput_ci95`] are bit-identical to the
    /// sequential [`Self::run_trials_stream_agg`] at any thread count
    /// and steal schedule (asserted across 1/2/5 workers in
    /// `rust/tests/detection_elastic.rs`). This replaces the pre-PR-10
    /// behavior, where per-worker partial aggregates merged in batch
    /// order and thread counts could differ in the last ulp.
    pub fn run_trials_stream_agg_par(
        &self,
        gen: &TrialGen,
        mode: StepMode,
        threads: usize,
    ) -> (Vec<PolicyAggregate>, MemoStats) {
        let mut aggs = vec![PolicyAggregate::default(); self.policies.len()];
        let fold = |stats: Vec<Vec<FleetStats>>| {
            for trial in &stats {
                for (agg, s) in aggs.iter_mut().zip(trial) {
                    agg.push(s);
                }
            }
            false
        };
        let ms = match DetectionModel::active(&self.detect) {
            Some(d) => self.steal_trials(
                gen.trials,
                STEAL_WINDOW,
                threads,
                |i| DelayedEvents::new(gen.stream_for(i), *d, self.topo.n_gpus),
                mode,
                fold,
            ),
            None => self.steal_trials(
                gen.trials,
                STEAL_WINDOW,
                threads,
                |i| gen.stream_for(i),
                mode,
                fold,
            ),
        };
        (aggs, ms)
    }

    /// Adaptive Monte-Carlo ([`super::adaptive`]): trials run in
    /// `rule.round`-sized rounds over the same work-stealing scheduler
    /// as [`Self::run_trials_stream_agg_par`]; after each round the
    /// [`StopRule`] inspects the per-policy net-throughput Welford
    /// accumulators (folded in trial-index order) and stops once every
    /// pairwise policy ordering is separated, every CI is tight, or
    /// the `rule.max_trials` budget is out. `gen` supplies the trial
    /// family (seed, scenario, horizon); its `trials` field is
    /// ignored — the rule's budget bounds the draw, and
    /// `TrialGen::rng_for` is random-access so any trial index is
    /// addressable.
    ///
    /// Decisions happen only at round boundaries on deterministic
    /// folds, so `trials_run`, the stop reason and every aggregate are
    /// a pure function of `(gen, mode, rule)` — independent of
    /// `threads` (`rust/tests/adaptive_mc.rs`).
    pub fn run_trials_adaptive(
        &self,
        gen: &TrialGen,
        mode: StepMode,
        rule: &StopRule,
        threads: usize,
    ) -> AdaptiveOutcome {
        let rule = rule.normalized();
        let mut aggs = vec![PolicyAggregate::default(); self.policies.len()];
        let mut trials_run = 0usize;
        let mut reason = StopReason::MaxTrials;
        let on_round = |stats: Vec<Vec<FleetStats>>| {
            trials_run += stats.len();
            for trial in &stats {
                for (agg, s) in aggs.iter_mut().zip(trial) {
                    agg.push(s);
                }
            }
            let net: Vec<Welford> = aggs.iter().map(|a| a.net_tput).collect();
            match rule.check(&net) {
                Some(r) => {
                    reason = r;
                    true
                }
                None => false,
            }
        };
        let memo = match DetectionModel::active(&self.detect) {
            Some(d) => self.steal_trials(
                rule.max_trials,
                rule.round,
                threads,
                |i| DelayedEvents::new(gen.stream_for(i), *d, self.topo.n_gpus),
                mode,
                on_round,
            ),
            None => self.steal_trials(
                rule.max_trials,
                rule.round,
                threads,
                |i| gen.stream_for(i),
                mode,
                on_round,
            ),
        };
        AdaptiveOutcome { aggs, trials_run, reason, memo }
    }

    /// Sequential adaptive runner on a caller-shared memo: same
    /// rounds, same trial-index fold, same [`StopRule`] — `trials_run`,
    /// the reason and every aggregate are bit-identical to
    /// [`Self::run_trials_adaptive`] at any thread count — but trials
    /// stream through `memo`, so cross-point reuse keeps accruing
    /// across the points of a grid sweep (`ntp sweep --adaptive`).
    pub fn run_trials_adaptive_with(
        &self,
        gen: &TrialGen,
        mode: StepMode,
        rule: &StopRule,
        memo: &mut ResponseMemo,
    ) -> AdaptiveOutcome {
        let rule = rule.normalized();
        let (aggs, trials_run, reason) = match DetectionModel::active(&self.detect) {
            Some(d) => self.adaptive_rounds(
                &rule,
                |i| DelayedEvents::new(gen.stream_for(i), *d, self.topo.n_gpus),
                mode,
                memo,
            ),
            None => self.adaptive_rounds(&rule, |i| gen.stream_for(i), mode, memo),
        };
        AdaptiveOutcome { aggs, trials_run, reason, memo: memo.stats() }
    }

    /// Round loop shared by the detect/plain arms of
    /// [`Self::run_trials_adaptive_with`]: one persistent replayer,
    /// fold-as-you-stream, stop checks at round boundaries.
    fn adaptive_rounds<S, Mk>(
        &self,
        rule: &StopRule,
        mk_src: Mk,
        mode: StepMode,
        memo: &mut ResponseMemo,
    ) -> (Vec<PolicyAggregate>, usize, StopReason)
    where
        S: EventSource,
        Mk: Fn(usize) -> S,
    {
        let mut aggs = vec![PolicyAggregate::default(); self.policies.len()];
        let mut rep: Option<ReplayCore<S>> = None;
        let mut reason = StopReason::MaxTrials;
        let mut done = 0usize;
        while done < rule.max_trials {
            let end = (done + rule.round).min(rule.max_trials);
            for trial in done..end {
                let stats = self.sweep_source(&mut rep, mk_src(trial), mode, memo);
                for (agg, s) in aggs.iter_mut().zip(&stats) {
                    agg.push(s);
                }
            }
            done = end;
            let net: Vec<Welford> = aggs.iter().map(|a| a.net_tput).collect();
            if let Some(r) = rule.check(&net) {
                reason = r;
                break;
            }
        }
        (aggs, done, reason)
    }

    /// Core sweep dispatch: mirrors `FleetSim::run` operation-for-
    /// operation in both modes, so the integrated stats are
    /// bit-identical per policy. Generic over the event source, so the
    /// same code path serves materialized traces ([`TraceCursor`]) and
    /// live streams ([`TraceStream`]).
    fn sweep<S: EventSource>(
        &self,
        rep: &mut ReplayCore<S>,
        mode: StepMode,
        memo: &mut ResponseMemo,
    ) -> Vec<FleetStats> {
        memo.bind(self.memo_ctx(), self.policies);
        match mode {
            StepMode::Exact => self.sweep_exact(rep, memo),
            StepMode::Grid(step_hours) => self.sweep_grid(rep, step_hours, memo),
        }
    }

    /// Exact event-boundary sweep: one evaluation per actual health
    /// change, duration-weighted, every change charged at its event
    /// time — `FleetSim::run(.., StepMode::Exact)` for all policies in
    /// one replay.
    ///
    /// Incremental inner loop: change detection walks only the
    /// replayer's dirty-domain set (a superset of the domains an event
    /// touched) against the tracked previous snapshot, and snapshot
    /// signatures rebuild from the replayer's live deficit histogram
    /// instead of re-scanning and re-sorting all domain counts per
    /// boundary. [`MultiPolicySim::run_rebuild`] keeps the from-scratch
    /// full-slice path as the oracle and perf baseline.
    fn sweep_exact<S: EventSource>(
        &self,
        rep: &mut ReplayCore<S>,
        memo: &mut ResponseMemo,
    ) -> Vec<FleetStats> {
        let n_policies = self.policies.len();
        let horizon = rep.horizon_hours();
        let mut accs = vec![Accum::default(); n_policies];
        if horizon <= 0.0 {
            return self.finalize_all(&accs);
        }
        let mut outs: Vec<EvalOut> = vec![EvalOut::default(); n_policies];
        // Previous-snapshot scratch lives in the memo so a Monte-Carlo
        // trial loop reuses the same three vectors for every trial.
        let mut prev_counts = std::mem::take(&mut memo.prev_counts);
        let mut prev_degraded = std::mem::take(&mut memo.prev_degraded);
        let mut prev_slow = std::mem::take(&mut memo.prev_slow);
        let n_domains = self.topo.n_domains();
        let n_job = match self.spares {
            None => n_domains,
            Some(pool) => n_domains - pool.spare_domains,
        };
        rep.advance(0.0);
        rep.set_job_domains(n_job);
        rep.clear_dirty();
        {
            let fleet = rep.fleet();
            prev_counts.clear();
            prev_counts.extend_from_slice(fleet.domain_healthy_counts());
            prev_degraded.clear();
            prev_degraded.extend_from_slice(fleet.domain_degraded_counts());
            prev_slow.clear();
            prev_slow.extend_from_slice(fleet.domain_slowdowns());
        }
        self.evaluate_all_inc(rep, memo, &mut outs);
        let mut seg_start = 0.0;
        while let Some(t) = rep.next_change_hours().filter(|&t| t < horizon) {
            rep.advance(t);
            // Exact change detection over the dirty superset: a touched
            // domain whose visible state round-tripped (e.g. a recovery
            // restoring the tracked counts) is NOT a change, matching
            // the full-slice compares of the rebuild path.
            let mut counts_changed = false;
            let mut degraded_changed = false;
            let mut slow_changed = false;
            {
                let fleet = rep.fleet();
                let counts = fleet.domain_healthy_counts();
                let degraded = fleet.domain_degraded_counts();
                let slow = fleet.domain_slowdowns();
                for &d in rep.dirty_domains() {
                    let d = d as usize;
                    counts_changed |= counts[d] != prev_counts[d];
                    degraded_changed |= degraded[d] != prev_degraded[d];
                    slow_changed |= slow[d] != prev_slow[d];
                }
            }
            if counts_changed || degraded_changed || slow_changed {
                for (acc, &out) in accs.iter_mut().zip(&outs) {
                    acc.sample(out, t - seg_start);
                }
                if counts_changed || degraded_changed {
                    self.charge_all_inc(
                        rep,
                        memo,
                        &mut accs,
                        &prev_counts,
                        &prev_degraded,
                        counts_changed,
                        degraded_changed,
                    );
                }
                {
                    let fleet = rep.fleet();
                    let counts = fleet.domain_healthy_counts();
                    let degraded = fleet.domain_degraded_counts();
                    let slow = fleet.domain_slowdowns();
                    for &d in rep.dirty_domains() {
                        let d = d as usize;
                        prev_counts[d] = counts[d];
                        prev_degraded[d] = degraded[d];
                        prev_slow[d] = slow[d];
                    }
                }
                self.evaluate_all_inc(rep, memo, &mut outs);
                seg_start = t;
            }
            rep.clear_dirty();
        }
        for (acc, &out) in accs.iter_mut().zip(&outs) {
            acc.sample(out, horizon - seg_start);
        }
        self.charge_rollback_all(rep, &mut accs);
        memo.prev_counts = prev_counts;
        memo.prev_degraded = prev_degraded;
        memo.prev_slow = prev_slow;
        self.finalize_all(&accs)
    }

    /// The pre-incremental exact sweep: full-slice change detection and
    /// slice-rebuilt snapshot signatures at every boundary. Kept as the
    /// property-test oracle for the incremental path and as the
    /// baseline the ≥2× event-boundary-throughput perf gate measures
    /// against (`benches/perf_hotpath.rs`). Bit-identical to
    /// `sweep_exact`.
    fn sweep_exact_rebuild<S: EventSource>(
        &self,
        rep: &mut ReplayCore<S>,
        memo: &mut ResponseMemo,
    ) -> Vec<FleetStats> {
        let n_policies = self.policies.len();
        let horizon = rep.horizon_hours();
        let mut accs = vec![Accum::default(); n_policies];
        if horizon <= 0.0 {
            return self.finalize_all(&accs);
        }
        let mut outs: Vec<EvalOut> = vec![EvalOut::default(); n_policies];
        let start = rep.advance(0.0);
        let mut prev_counts = start.domain_healthy_counts().to_vec();
        let mut prev_degraded = start.domain_degraded_counts().to_vec();
        let mut prev_slow = start.domain_slowdowns().to_vec();
        self.evaluate_all(&prev_counts, &prev_degraded, &prev_slow, memo, &mut outs);
        let mut seg_start = 0.0;
        while let Some(t) = rep.next_change_hours().filter(|&t| t < horizon) {
            rep.advance(t);
            let fleet = rep.fleet();
            let changed = fleet.domain_healthy_counts() != &prev_counts[..]
                || fleet.domain_degraded_counts() != &prev_degraded[..]
                || fleet.domain_slowdowns() != &prev_slow[..];
            if changed {
                for (acc, &out) in accs.iter_mut().zip(&outs) {
                    acc.sample(out, t - seg_start);
                }
                self.charge_all(
                    memo,
                    &mut accs,
                    &prev_counts,
                    fleet.domain_healthy_counts(),
                    &prev_degraded,
                    fleet.domain_degraded_counts(),
                );
                prev_counts.clear();
                prev_counts.extend_from_slice(fleet.domain_healthy_counts());
                prev_degraded.clear();
                prev_degraded.extend_from_slice(fleet.domain_degraded_counts());
                prev_slow.clear();
                prev_slow.extend_from_slice(fleet.domain_slowdowns());
                self.evaluate_all(&prev_counts, &prev_degraded, &prev_slow, memo, &mut outs);
                seg_start = t;
            }
        }
        for (acc, &out) in accs.iter_mut().zip(&outs) {
            acc.sample(out, horizon - seg_start);
        }
        self.charge_rollback_all(rep, &mut accs);
        self.finalize_all(&accs)
    }

    /// Exact-mode sweep of one trace through the from-scratch
    /// (`rebuild`) path — the oracle/baseline twin of
    /// `run_with(trace, StepMode::Exact, memo)`.
    pub fn run_rebuild(&self, trace: &Trace, memo: &mut ResponseMemo) -> Vec<FleetStats> {
        memo.bind(self.memo_ctx(), self.policies);
        if let Some(d) = DetectionModel::active(&self.detect) {
            let src = DelayedEvents::new(TraceCursor::new(trace), *d, self.topo.n_gpus);
            let mut rep = ReplayCore::from_source(src, self.topo, self.blast);
            return self.sweep_exact_rebuild(&mut rep, memo);
        }
        let mut rep = FleetReplayer::new(trace, self.topo, self.blast);
        self.sweep_exact_rebuild(&mut rep, memo)
    }

    /// Legacy fixed-grid sweep (clamped final interval), version-gated
    /// evaluation identical to `FleetSim::run(.., StepMode::Grid(..))`.
    fn sweep_grid<S: EventSource>(
        &self,
        rep: &mut ReplayCore<S>,
        step_hours: f64,
        memo: &mut ResponseMemo,
    ) -> Vec<FleetStats> {
        let n_policies = self.policies.len();
        let mut accs = vec![Accum::default(); n_policies];
        let mut outs: Vec<EvalOut> = vec![EvalOut::default(); n_policies];
        let mut last_version: Option<u64> = None;
        let mut prev_counts: Vec<usize> = Vec::new();
        let mut prev_degraded: Vec<usize> = Vec::new();
        let horizon = rep.horizon_hours();
        let mut step = 0usize;
        while let Some((t, dt)) = grid_step(step, step_hours, horizon) {
            let fleet = rep.advance(t);
            let version = fleet.version();
            if last_version != Some(version) {
                let counts = fleet.domain_healthy_counts();
                let degraded = fleet.domain_degraded_counts();
                if step == 0 {
                    prev_counts.clear();
                    prev_counts.extend_from_slice(counts);
                    prev_degraded.clear();
                    prev_degraded.extend_from_slice(degraded);
                } else if counts != &prev_counts[..] || degraded != &prev_degraded[..] {
                    self.charge_all(memo, &mut accs, &prev_counts, counts, &prev_degraded, degraded);
                    prev_counts.clear();
                    prev_counts.extend_from_slice(counts);
                    prev_degraded.clear();
                    prev_degraded.extend_from_slice(degraded);
                }
                self.evaluate_all(
                    &prev_counts,
                    &prev_degraded,
                    fleet.domain_slowdowns(),
                    memo,
                    &mut outs,
                );
                last_version = Some(version);
            }
            for (acc, &out) in accs.iter_mut().zip(&outs) {
                acc.sample(out, dt);
            }
            step += 1;
        }
        self.charge_rollback_all(rep, &mut accs);
        self.finalize_all(&accs)
    }

    /// Charge every policy for one observed boundary, through the
    /// count-keyed memo where sound — verbatim what `FleetSim` charges
    /// via `charge_boundary` (same ctx derivation from the
    /// live-spare-adjusted pool of `next`, same fail-layer + degrade
    /// split), so memoized and direct paths add identical `f64`s.
    /// Degrade charges stay outside the transition memo: they are cheap
    /// to compute and only two registry policies make them nonzero.
    fn charge_all(
        &self,
        memo: &mut ResponseMemo,
        accs: &mut [Accum],
        prev: &[usize],
        next: &[usize],
        prev_degraded: &[usize],
        next_degraded: &[usize],
    ) {
        let counts_changed = prev != next;
        let degraded_changed = prev_degraded != next_degraded;
        if !(counts_changed || degraded_changed) {
            return;
        }
        let ctx = self.ctx(self.live_spares_in(next));
        let changed = changed_domains(prev, next) as u32;
        let degraded = degraded_domains(prev, next) as u32;
        let live = live_pool_key(&ctx.spares);
        for (i, (acc, &policy)) in accs.iter_mut().zip(self.policies).enumerate() {
            let mut cost = 0.0;
            if counts_changed {
                let key = (i as u32, changed, degraded, live, self.topo.n_gpus as u64);
                cost += memo.transition_cost(key, policy, &ctx, prev, next);
            }
            if degraded_changed {
                cost += policy.degrade_transition_cost(&ctx, prev_degraded, next_degraded);
            }
            acc.charge_cost(cost);
        }
    }

    /// [`MultiPolicySim::charge_all`] driven from the replayer's
    /// incremental state instead of full prev/next slices: changed and
    /// degraded domain counts come from the dirty set (exact — every
    /// non-dirty domain is unchanged by construction) and the live
    /// spare pool from the maintained tail-full count. Charges the
    /// identical `f64`s.
    #[allow(clippy::too_many_arguments)]
    fn charge_all_inc<S: EventSource>(
        &self,
        rep: &ReplayCore<S>,
        memo: &mut ResponseMemo,
        accs: &mut [Accum],
        prev: &[usize],
        prev_degraded: &[usize],
        counts_changed: bool,
        degraded_changed: bool,
    ) {
        let fleet = rep.fleet();
        let next = fleet.domain_healthy_counts();
        let next_degraded = fleet.domain_degraded_counts();
        let live = self.live_spares_inc(rep);
        let ctx = self.ctx(live);
        let mut changed = 0u32;
        let mut degraded = 0u32;
        for &d in rep.dirty_domains() {
            let d = d as usize;
            changed += (next[d] != prev[d]) as u32;
            degraded += (next[d] < prev[d]) as u32;
        }
        let live_key = live_pool_key(&ctx.spares);
        for (i, (acc, &policy)) in accs.iter_mut().zip(self.policies).enumerate() {
            let mut cost = 0.0;
            if counts_changed {
                let key = (i as u32, changed, degraded, live_key, self.topo.n_gpus as u64);
                cost += memo.transition_cost(key, policy, &ctx, prev, next);
            }
            if degraded_changed {
                cost += policy.degrade_transition_cost(&ctx, prev_degraded, next_degraded);
            }
            acc.charge_cost(cost);
        }
    }

    /// Trace-global SDC detection-lag rollback plus the periodic
    /// validation-sweep bill, charged identically into every policy's
    /// accumulator — verbatim `FleetSim::integrate_with_rollback`
    /// (corruption is invisible until the validation sweep fires, so no
    /// policy can dodge the recompute). Billed from the `(lag, ci)`
    /// pairs the replayer recorded while pulling events, which lets the
    /// streaming path bill without a materialized trace;
    /// [`ReplayCore::drain_source`] first pulls any events a grid sweep
    /// left unconsumed past its last step so the pair list always
    /// matches a full trace scan.
    fn charge_rollback_all<S: EventSource>(&self, rep: &mut ReplayCore<S>, accs: &mut [Accum]) {
        if let Some(costs) = &self.transition {
            rep.drain_source();
            let bill =
                super::fleet::sdc_rollback_from_pairs(rep.sdc_pairs(), costs, self.topo.n_gpus);
            if bill > 0.0 {
                for acc in accs.iter_mut() {
                    acc.charge_rollback(bill);
                }
            }
            let sweep_bill = super::fleet::validation_sweep_gpu_secs(
                costs,
                rep.horizon_hours(),
                self.topo.n_gpus,
            );
            if sweep_bill > 0.0 {
                for acc in accs.iter_mut() {
                    acc.charge_rollback(sweep_bill);
                }
            }
            // Undetected-stall bill: GPU-hours the job spent wedged (or
            // straggler-gated) by live-but-unnoticed faults under
            // imperfect detection (accumulated by the [`DelayedEvents`]
            // source; `0` for every other source). Complete after the
            // `drain_source` above. Same rollback channel as SDC —
            // pure lost work, no reconfiguration counted.
            let stall = rep.detect_stall_gpu_hours();
            if stall > 0.0 {
                for acc in accs.iter_mut() {
                    acc.charge_rollback(stall * 3600.0);
                }
            }
            // Expected false-positive evictions, priced per policy —
            // billed in expectation against the *configured* pool (a
            // deterministic bill, like the validation sweep), via
            // `charge_rollback` so the `transitions` counter keeps
            // counting only real reconfigurations.
            if let Some(d) = DetectionModel::active(&self.detect) {
                let fp = d.false_positive_events(self.topo.n_gpus, rep.horizon_hours());
                if fp > 0.0 {
                    let ctx = self.ctx(self.spares);
                    for (acc, &policy) in accs.iter_mut().zip(self.policies) {
                        let bill = fp * policy.false_positive_cost(&ctx);
                        if bill > 0.0 {
                            acc.charge_rollback(bill);
                        }
                    }
                }
            }
        }
    }

    fn finalize_all(&self, accs: &[Accum]) -> Vec<FleetStats> {
        let spare_gpus = self
            .spares
            .map(|p| p.spare_domains * self.topo.domain_size)
            .unwrap_or(0);
        accs.iter().map(|acc| acc.finalize(self.topo.n_gpus, spare_gpus)).collect()
    }

    /// Evaluate one snapshot for every policy, through the memo when
    /// sound. Job/spare split and live-pool derivation are verbatim
    /// `FleetSim::evaluate` / `FleetSim::live_spares_in`; snapshots with
    /// degraded job domains take the degradation-aware path, verbatim
    /// `FleetSim::evaluate_degraded`.
    fn evaluate_all(
        &self,
        counts: &[usize],
        degraded: &[usize],
        slowdowns: &[f64],
        memo: &mut ResponseMemo,
        outs: &mut [EvalOut],
    ) {
        let (job_healthy, live, live_key) = match self.spares {
            None => (counts, None, u32::MAX),
            Some(pool) => {
                let (job, live) = super::spares::split_job_spares(
                    counts,
                    self.topo.domain_size,
                    &pool,
                );
                let live_key = live.spare_domains as u32;
                (job, Some(live), live_key)
            }
        };
        let ctx = self.ctx(live);
        // Degraded snapshots BYPASS the response memo: `group_drag` sums
        // drag in domain-position order, so a degraded response is NOT a
        // pure function of the damage multiset the signature encodes —
        // memoizing would serve another permutation's bits. Failures are
        // the common case and stragglers heal, so fail-only traces (and
        // fail-only stretches of mixed traces) keep the full memo.
        // Degraded SPARE domains are ignored, like `FleetSim`: a slow
        // spare is still alive and still counts in the live pool.
        let n_job = job_healthy.len();
        if degraded[..n_job].iter().any(|&d| d > 0) {
            for (out, &policy) in outs.iter_mut().zip(self.policies) {
                *out = policy.eval_degraded_with(
                    &ctx,
                    job_healthy,
                    &degraded[..n_job],
                    &slowdowns[..n_job],
                    &mut memo.scratch,
                );
            }
            return;
        }
        // Memoization is sound iff the response is a pure function of
        // the damaged-domain multiset: packed mode, or fixed-minibatch
        // mode (spare substitution + packing always reorder). Unpacked
        // flexible mode keys replicas by domain *position* and must
        // bypass the memo (see the counterexample test in
        // rust/tests/multi_policy_sweep.rs).
        if !(self.packed || self.spares.is_some()) {
            for (out, &policy) in outs.iter_mut().zip(self.policies) {
                *out = policy.respond_with(&ctx, job_healthy, &mut memo.scratch);
            }
            return;
        }
        // One key + one hash per snapshot: the cached entry holds every
        // policy's response in list order (the bind check guarantees the
        // memo's list matches this sim's).
        memo.sig.rebuild(job_healthy, self.topo.domain_size, &mut memo.deficits);
        let key = MemoKey {
            sig: memo.sig.clone(),
            n_job: job_healthy.len() as u32,
            live_spares: live_key,
        };
        let policies = self.policies;
        memo.respond_cached(key, outs, |scratch, outs| {
            for (out, &policy) in outs.iter_mut().zip(policies) {
                *out = policy.respond_with(&ctx, job_healthy, scratch);
            }
        });
    }

    /// [`MultiPolicySim::evaluate_all`] driven from the replayer's
    /// incremental state: the job/spare split comes from the maintained
    /// tail-full count (verbatim `split_job_spares` semantics, see
    /// `rust/src/failure/replayer.rs`), the degraded-job-domain test
    /// from the maintained counter, and the memo key from the live
    /// deficit histogram — no per-boundary scan-and-sort of all domain
    /// counts. Produces bit-identical `EvalOut`s and identical memo
    /// keys to the slice path.
    fn evaluate_all_inc<S: EventSource>(
        &self,
        rep: &ReplayCore<S>,
        memo: &mut ResponseMemo,
        outs: &mut [EvalOut],
    ) {
        let fleet = rep.fleet();
        let counts = fleet.domain_healthy_counts();
        let n_job = rep.job_domains();
        let job_healthy = &counts[..n_job];
        let live = self.live_spares_inc(rep);
        let live_key = match &live {
            Some(pool) => pool.spare_domains as u32,
            None => u32::MAX,
        };
        let ctx = self.ctx(live);
        // Same memo-soundness rules as `evaluate_all`: degraded job
        // domains and unpacked flexible mode bypass the cache.
        if rep.job_degraded_domains() > 0 {
            let degraded = fleet.domain_degraded_counts();
            let slowdowns = fleet.domain_slowdowns();
            for (out, &policy) in outs.iter_mut().zip(self.policies) {
                *out = policy.eval_degraded_with(
                    &ctx,
                    job_healthy,
                    &degraded[..n_job],
                    &slowdowns[..n_job],
                    &mut memo.scratch,
                );
            }
            return;
        }
        if !(self.packed || self.spares.is_some()) {
            for (out, &policy) in outs.iter_mut().zip(self.policies) {
                *out = policy.respond_with(&ctx, job_healthy, &mut memo.scratch);
            }
            return;
        }
        memo.sig.rebuild_from_histogram(rep.deficit_histogram());
        let key = MemoKey {
            sig: memo.sig.clone(),
            n_job: n_job as u32,
            live_spares: live_key,
        };
        let policies = self.policies;
        memo.respond_cached(key, outs, |scratch, outs| {
            for (out, &policy) in outs.iter_mut().zip(policies) {
                *out = policy.respond_with(&ctx, job_healthy, scratch);
            }
        });
    }

    fn ctx(&self, live_spares: Option<SparePolicy>) -> PolicyCtx<'_> {
        PolicyCtx {
            table: self.table,
            domain_size: self.topo.domain_size,
            domains_per_replica: self.domains_per_replica,
            packed: self.packed,
            spares: live_spares,
            n_gpus: self.topo.n_gpus,
            transition: self.transition,
        }
    }

    /// [`super::spares::split_job_spares`] — the one live-pool
    /// derivation shared with `FleetSim`.
    fn live_spares_in(&self, domain_healthy: &[usize]) -> Option<SparePolicy> {
        self.spares.map(|pool| {
            super::spares::split_job_spares(domain_healthy, self.topo.domain_size, &pool).1
        })
    }

    /// The live pool from the replayer's maintained tail counters —
    /// verbatim [`super::spares::split_job_spares`] semantics per tier
    /// (a failed cold spare shrinks the cold pool, not the warm one).
    fn live_spares_inc<S: EventSource>(&self, rep: &ReplayCore<S>) -> Option<SparePolicy> {
        self.spares.map(|pool| SparePolicy {
            spare_domains: rep.live_spare_domains(),
            cold_domains: rep.live_cold_spare_domains(pool.cold_domains),
            min_tp: pool.min_tp,
        })
    }

    fn memo_ctx(&self) -> MemoCtx {
        MemoCtx {
            domain_size: self.topo.domain_size,
            domains_per_replica: self.domains_per_replica,
            packed: self.packed,
            spare_min_tp: self.spares.map(|p| p.min_tp).unwrap_or(0),
            n_gpus: self.topo.n_gpus,
            spare_cold_domains: self.spares.map(|p| p.cold_domains).unwrap_or(0),
            table_fingerprint: table_fingerprint(self.table),
            transition_fingerprint: transition_fingerprint(&self.transition),
            detect_fingerprint: DetectionModel::fingerprint(&self.detect),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn sig_of(counts: &[usize], domain_size: usize) -> SnapshotSig {
        let mut sig = SnapshotSig::new();
        let mut scratch = Vec::new();
        sig.rebuild(counts, domain_size, &mut scratch);
        sig
    }

    #[test]
    fn signature_encodes_damage_multiset() {
        let sig = sig_of(&[32, 31, 32, 29, 31, 0], 32);
        // deficits: 1, 3, 1, 32 -> sorted RLE: (1,2), (3,1), (32,1)
        assert_eq!(sig.pairs(), &[(1, 2), (3, 1), (32, 1)]);
        assert_eq!(sig.n_damaged(), 4);
        assert!(sig.is_inline());
        // healthy snapshot: empty signature
        let healthy = sig_of(&[32; 64], 32);
        assert_eq!(healthy.pairs(), &[]);
        assert_eq!(healthy.n_damaged(), 0);
    }

    #[test]
    fn signature_is_permutation_invariant() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let n = 8 + rng.index(40);
            let counts: Vec<usize> = (0..n)
                .map(|_| if rng.chance(0.3) { rng.index(33) } else { 32 })
                .collect();
            let mut shuffled = counts.clone();
            // Fisher-Yates
            for i in (1..shuffled.len()).rev() {
                let j = rng.index(i + 1);
                shuffled.swap(i, j);
            }
            let a = sig_of(&counts, 32);
            let b = sig_of(&shuffled, 32);
            assert_eq!(a, b, "counts={counts:?}");
        }
    }

    #[test]
    fn signature_spills_beyond_inline_capacity() {
        // 10 distinct deficit values: 1..=10 -> spills past SIG_INLINE.
        let counts: Vec<usize> = (1..=10).map(|d| 32 - d).collect();
        let sig = sig_of(&counts, 32);
        assert!(!sig.is_inline());
        assert_eq!(sig.pairs().len(), 10);
        assert_eq!(sig.pairs()[0], (1, 1));
        assert_eq!(sig.pairs()[9], (10, 1));
        // rebuilding the same storage back to a small signature works
        let mut sig = sig;
        let mut scratch = Vec::new();
        sig.rebuild(&[32, 30], 32, &mut scratch);
        assert!(sig.is_inline());
        assert_eq!(sig.pairs(), &[(2, 1)]);
    }

    #[test]
    fn distinct_damage_distinct_signatures() {
        let a = sig_of(&[31, 31, 32, 32], 32);
        let b = sig_of(&[30, 32, 32, 32], 32);
        let c = sig_of(&[31, 32, 32, 32], 32);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
    }

    #[test]
    fn memo_counts_hits_and_misses() {
        let mut memo = ResponseMemo::new(2);
        assert_eq!(memo.hit_rate(), 0.0);
        assert_eq!(memo.unique_entries(), 0);
        memo.hits = 3;
        memo.misses = 1;
        assert!((memo.hit_rate() - 0.75).abs() < 1e-12);
    }

    fn test_memo_ctx() -> MemoCtx {
        MemoCtx {
            domain_size: 32,
            domains_per_replica: 4,
            packed: true,
            spare_min_tp: 0,
            n_gpus: 1024,
            spare_cold_domains: 0,
            table_fingerprint: 0xFEED,
            transition_fingerprint: 0,
            detect_fingerprint: 0,
        }
    }

    #[test]
    #[should_panic(expected = "incompatible sweep configurations")]
    fn memo_rejects_a_different_detection_model() {
        use crate::policy::registry;
        let a = [registry::parse("straggler-evict").unwrap()];
        let mut memo = ResponseMemo::new(1);
        memo.bind(test_memo_ctx(), &a);
        memo.bind(MemoCtx { detect_fingerprint: 42, ..test_memo_ctx() }, &a);
    }

    #[test]
    #[should_panic(expected = "incompatible sweep configurations")]
    fn memo_rejects_a_different_gpu_total() {
        use crate::policy::registry;
        // Cached donated fractions are normalized by n_gpus, so two sims
        // differing only in fleet size must not share a memo.
        let a = [registry::parse("power-spares").unwrap()];
        let mut memo = ResponseMemo::new(1);
        memo.bind(test_memo_ctx(), &a);
        memo.bind(MemoCtx { n_gpus: 896, ..test_memo_ctx() }, &a);
    }

    #[test]
    #[should_panic(expected = "different policy list")]
    fn memo_rejects_a_different_policy_list() {
        use crate::policy::registry;
        let a = [registry::parse("ntp").unwrap(), registry::parse("dp-drop").unwrap()];
        let b = [
            registry::parse("ckpt-restart").unwrap(),
            registry::parse("spare-mig").unwrap(),
        ];
        let mut memo = ResponseMemo::new(2);
        memo.bind(test_memo_ctx(), &a);
        memo.bind(test_memo_ctx(), &a); // same list: fine
        memo.bind(test_memo_ctx(), &b); // different policies: must panic
    }

    #[test]
    #[should_panic(expected = "incompatible sweep configurations")]
    fn memo_rejects_an_incompatible_context() {
        use crate::policy::registry;
        let a = [registry::parse("ntp").unwrap()];
        let mut memo = ResponseMemo::new(1);
        memo.bind(test_memo_ctx(), &a);
        // a different table fingerprint (e.g. same-shaped tables built
        // for different RackDesigns) must be rejected
        memo.bind(MemoCtx { table_fingerprint: 0xBEEF, ..test_memo_ctx() }, &a);
    }

    #[test]
    #[should_panic(expected = "incompatible sweep configurations")]
    fn memo_rejects_a_different_transition_model() {
        use crate::policy::registry;
        let a = [registry::parse("ckpt-adaptive").unwrap()];
        let mut memo = ResponseMemo::new(1);
        memo.bind(test_memo_ctx(), &a);
        // CKPT-ADAPTIVE's steady state depends on the cost model (rate,
        // write cost), so two sweeps differing only in TransitionCosts
        // must not share a memo.
        memo.bind(MemoCtx { transition_fingerprint: 7, ..test_memo_ctx() }, &a);
    }

    #[test]
    fn transition_fingerprints_distinguish_models() {
        assert_eq!(transition_fingerprint(&None), 0);
        let t = TransitionCosts {
            restart_secs: 900.0,
            checkpoint_interval_secs: 3600.0,
            reshard_secs: 2.0,
            spare_load_secs: 300.0,
            cold_spare_load_secs: 1800.0,
            preempt_secs: 0.0,
            rejoin_secs: 45.0,
            ckpt_write_secs: 120.0,
            power_ramp_secs: 60.0,
            failure_rate_per_hour: 0.0,
            validation_sweep_secs: 0.0,
        };
        let a = transition_fingerprint(&Some(t));
        assert_ne!(a, 0);
        assert_eq!(a, transition_fingerprint(&Some(t)));
        let b = transition_fingerprint(&Some(TransitionCosts {
            failure_rate_per_hour: 1.5,
            ..t
        }));
        assert_ne!(a, b);
        // the validation-sweep bill is part of the model identity too
        let c = transition_fingerprint(&Some(TransitionCosts {
            validation_sweep_secs: 0.25,
            ..t
        }));
        assert_ne!(a, c);
        // ... as are the PR-8 fields (cold tier, preemption, rejoin)
        let d = transition_fingerprint(&Some(TransitionCosts {
            cold_spare_load_secs: 900.0,
            ..t
        }));
        assert_ne!(a, d);
        let e = transition_fingerprint(&Some(TransitionCosts { preempt_secs: 30.0, ..t }));
        assert_ne!(a, e);
        let f = transition_fingerprint(&Some(TransitionCosts { rejoin_secs: 90.0, ..t }));
        assert_ne!(a, f);
    }

    #[test]
    fn aggregate_folds_and_merges_like_stored_trials() {
        let mk = |tput: f64, transitions: usize| FleetStats {
            mean_throughput: tput,
            paused_frac: 0.1,
            mean_spares_used: 1.5,
            throughput_per_gpu: tput / 2.0,
            downtime_frac: 0.05,
            transitions,
            mean_donated: 0.2,
            mean_power_frac: 0.5 + tput / 4.0,
            peak_rack_power_frac: tput + 0.3,
        };
        let trials = [mk(0.9, 3), mk(0.8, 5), mk(0.95, 1), mk(0.7, 9)];
        let mut whole = PolicyAggregate::default();
        for s in &trials {
            whole.push(s);
        }
        assert_eq!(whole.trials(), 4);
        let n = trials.len() as f64;
        let mean: f64 = trials.iter().map(|s| s.mean_throughput).sum::<f64>() / n;
        assert_eq!(whole.mean_tput(), mean);
        assert_eq!(
            whole.mean_net_tput(),
            trials.iter().map(|s| s.net_throughput()).sum::<f64>() / n
        );
        assert_eq!(whole.mean_transitions(), (3 + 5 + 1 + 9) as f64 / n);
        assert_eq!(
            whole.mean_power_frac(),
            trials.iter().map(|s| s.mean_power_frac).sum::<f64>() / n
        );
        assert_eq!(
            whole.mean_energy_per_token(),
            trials.iter().map(|s| s.energy_per_token()).sum::<f64>() / n
        );
        // Peak is a max over trials: 0.95 + 0.3.
        assert_eq!(whole.peak_rack_power_frac(), 0.95 + 0.3);
        // CI against the direct two-pass sample variance (4 trials ⇒
        // df = 3 ⇒ Student-t critical value, not the normal 1.96).
        let var =
            trials.iter().map(|s| (s.mean_throughput - mean).powi(2)).sum::<f64>() / (n - 1.0);
        let ci = crate::util::stats::t_critical_95(3) * (var / n).sqrt();
        assert!((whole.tput_ci95() - ci).abs() < 1e-12, "{} vs {ci}", whole.tput_ci95());
        // Split-and-merge agrees to floating-point reassociation noise.
        let mut a = PolicyAggregate::default();
        let mut b = PolicyAggregate::default();
        for s in &trials[..2] {
            a.push(s);
        }
        for s in &trials[2..] {
            b.push(s);
        }
        a.merge(&b);
        assert_eq!(a.trials(), 4);
        assert!((a.mean_tput() - whole.mean_tput()).abs() < 1e-12);
        assert!((a.tput_ci95() - whole.tput_ci95()).abs() < 1e-12);
        // Merging an empty fold is the identity.
        let mut c = whole.clone();
        c.merge(&PolicyAggregate::default());
        assert_eq!(c.trials(), 4);
        assert_eq!(c.mean_tput().to_bits(), whole.mean_tput().to_bits());
        assert_eq!(c.tput_ci95().to_bits(), whole.tput_ci95().to_bits());
    }

    #[test]
    fn histogram_rebuild_matches_counts_rebuild() {
        let mut rng = Rng::new(17);
        for _ in 0..200 {
            let n = 4 + rng.index(60);
            let ds = 32;
            let counts: Vec<usize> =
                (0..n).map(|_| if rng.chance(0.4) { rng.index(ds + 1) } else { ds }).collect();
            let mut hist = vec![0u32; ds + 1];
            for &h in &counts {
                if h < ds {
                    hist[ds - h] += 1;
                }
            }
            let from_counts = sig_of(&counts, ds);
            let mut from_hist = SnapshotSig::new();
            from_hist.rebuild_from_histogram(&hist);
            assert_eq!(from_counts, from_hist, "counts={counts:?}");
        }
    }
}
